#!/usr/bin/env python3
"""CI bench-regression gate.

Reads bench output (the ``{"bench": ...}`` JSON lines emitted by
``bench_support::json_line``, mixed freely with human-readable tables),
aggregates each gated metric as the mean over matching lines, and fails
(exit 1) when a metric drops more than ``tolerance_pct`` below its
committed baseline in ``BENCH_BASELINE.json``.

Metrics are keyed ``<bench>.<field>`` (e.g. ``fig6.throughput_mb_s``).
A baseline of 0/null records the metric without gating it. The current
means are always written to ``--out`` so a CI artifact of a healthy run
can be copied over the baseline to re-calibrate:

    python3 scripts/check_bench_regression.py bench.out \
        --baseline BENCH_BASELINE.json --out bench-results.json

``--update-baseline`` turns that manual copy into one command: it
rewrites the gated values in the baseline file to ``--headroom`` (default
60%) of the run's measured means — conservative floors derived from a
healthy run, so runner jitter keeps clearing the gate. Record-only (0)
metrics stay record-only unless named via ``--promote KEY`` (which turns
them into gated floors from the same run), and metrics missing from the
run are left untouched. Run it on a healthy main build's ``bench.out``
(or on the downloaded ``bench-results.json`` artifact's source output)
and commit the result.
"""

import argparse
import json
import sys


def parse_bench_lines(paths):
    """Collect {metric_key: [values]} from bench output files."""
    values = {}
    for path in paths:
        with open(path, "r", encoding="utf-8", errors="replace") as f:
            for line in f:
                line = line.strip()
                if not line.startswith('{"bench"'):
                    continue
                try:
                    obj = json.loads(line)
                except json.JSONDecodeError:
                    print(f"warning: unparseable bench line: {line[:120]}")
                    continue
                bench = obj.get("bench")
                if not bench:
                    continue
                for field, val in obj.items():
                    if field == "bench" or not isinstance(val, (int, float)):
                        continue
                    values.setdefault(f"{bench}.{field}", []).append(float(val))
    return values


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("bench_out", nargs="+", help="bench output file(s)")
    ap.add_argument("--baseline", default="BENCH_BASELINE.json")
    ap.add_argument("--out", default="bench-results.json",
                    help="write current metric means here (artifact)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the baseline's gated values from this "
                         "run's means (at --headroom), then exit 0")
    ap.add_argument("--headroom", type=float, default=0.6,
                    help="fraction of the measured mean committed as the "
                         "new floor with --update-baseline (default 0.6)")
    ap.add_argument("--promote", action="append", default=[], metavar="KEY",
                    help="with --update-baseline: also turn these "
                         "record-only (0) metrics into gated floors from "
                         "this run's means (repeatable)")
    args = ap.parse_args()

    if args.promote and not args.update_baseline:
        print("error: --promote only makes sense with --update-baseline")
        sys.exit(2)

    with open(args.baseline, "r", encoding="utf-8") as f:
        baseline = json.load(f)
    tolerance = float(baseline.get("tolerance_pct", 15))
    gated = baseline.get("metrics", {})

    values = parse_bench_lines(args.bench_out)
    means = {k: sum(v) / len(v) for k, v in values.items()}

    if args.update_baseline:
        # A typo'd or unmeasured --promote key must not silently leave
        # the metric record-only while the operator believes it gates:
        # refuse to rewrite anything.
        bad = []
        for key in args.promote:
            if key not in gated:
                bad.append(f"--promote {key}: not in the baseline's "
                           f"metrics; add a record-only entry first")
            elif key not in means:
                bad.append(f"--promote {key}: not present in this run's "
                           f"bench output")
        if bad:
            for b in bad:
                print(f"error: {b}")
            print("baseline NOT rewritten.")
            sys.exit(2)
        updated = {}
        for key, base in sorted(gated.items()):
            cur = means.get(key)
            promote = key in args.promote
            if cur is None or (not base and not promote):
                updated[key] = base  # record-only / not measured: keep
                continue
            updated[key] = round(cur * args.headroom, 1)
            verb = "promoted to floor" if promote and not base else "floor"
            print(f"  {key}: {verb} {base} -> {updated[key]} "
                  f"({args.headroom:.0%} of measured {cur:.2f})")
        baseline["metrics"] = updated
        with open(args.baseline, "w", encoding="utf-8") as f:
            json.dump(baseline, f, indent=2)
            f.write("\n")
        print(f"re-baselined {args.baseline} from "
              f"{len(args.bench_out)} bench output file(s)")
        return

    with open(args.out, "w", encoding="utf-8") as f:
        json.dump(
            {
                "_comment": "mean per metric over one CI bench run; copy the "
                            "gated keys into BENCH_BASELINE.json to re-baseline",
                "tolerance_pct": tolerance,
                "metrics": {k: round(v, 3) for k, v in sorted(means.items())},
            },
            f,
            indent=2,
        )
        f.write("\n")

    failures = []
    width = max((len(k) for k in gated), default=10)
    print(f"bench regression gate (tolerance {tolerance:.0f}%):")
    for key, base in sorted(gated.items()):
        cur = means.get(key)
        if cur is None:
            failures.append(f"{key}: gated metric missing from bench output")
            print(f"  {key:<{width}}  MISSING (baseline {base})")
            continue
        if not base:
            print(f"  {key:<{width}}  {cur:10.2f}  (record-only)")
            continue
        floor = base * (1.0 - tolerance / 100.0)
        delta = (cur - base) / base * 100.0
        status = "ok" if cur >= floor else "FAIL"
        print(f"  {key:<{width}}  {cur:10.2f}  vs baseline {base:10.2f} "
              f"({delta:+6.1f}%)  {status}")
        if cur < floor:
            failures.append(
                f"{key}: {cur:.2f} is {-delta:.1f}% below baseline {base:.2f} "
                f"(allowed drop {tolerance:.0f}%)"
            )

    if failures:
        print("\nbench regression gate FAILED:")
        for f_ in failures:
            print(f"  - {f_}")
        sys.exit(1)
    print("bench regression gate passed.")


if __name__ == "__main__":
    main()
