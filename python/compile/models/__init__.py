"""Layer-2 JAX training workloads (build-time only)."""
