"""Small transformer LM + Adam — the RoBERTa-fine-tune analog (paper §4.1).

Functional, flat-parameter-list style so the train step lowers to an HLO
module with a stable positional signature the Rust runtime can drive.
Parameters are fp32 masters; checkpoints/gradients/optimizer state are
exported as bf16 bit patterns (`bitcast -> uint16`) matching the paper's
"BF16 version of RoBERTa" setup, so the Rust side reads raw bits directly.
"""

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LMConfig:
    """Transformer LM hyperparameters."""

    vocab: int = 1024
    d_model: int = 192
    n_heads: int = 4
    n_blocks: int = 3
    seq_len: int = 64
    batch: int = 8

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads


TINY = LMConfig(vocab=128, d_model=32, n_heads=2, n_blocks=1, seq_len=16, batch=4)
SMALL = LMConfig()


def param_spec(cfg: LMConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the flattening contract with Rust."""
    d = cfg.d_model
    spec = [("embed.weight", (cfg.vocab, d))]
    for b in range(cfg.n_blocks):
        p = f"blocks.{b}"
        spec += [
            (f"{p}.ln1.scale", (d,)),
            (f"{p}.ln1.bias", (d,)),
            (f"{p}.attn.wq", (d, d)),
            (f"{p}.attn.wk", (d, d)),
            (f"{p}.attn.wv", (d, d)),
            (f"{p}.attn.wo", (d, d)),
            (f"{p}.ln2.scale", (d,)),
            (f"{p}.ln2.bias", (d,)),
            (f"{p}.mlp.up", (d, 4 * d)),
            (f"{p}.mlp.up_bias", (4 * d,)),
            (f"{p}.mlp.down", (4 * d, d)),
            (f"{p}.mlp.down_bias", (d,)),
        ]
    spec += [("ln_f.scale", (d,)), ("ln_f.bias", (d,))]
    # Untied output head: with a tied head the softmax would feed gradient
    # into *every* embedding row, destroying the Fig. 7 sparsity effect the
    # paper observes (their RoBERTa fine-tune has a separate head too).
    spec += [("head.weight", (cfg.vocab, d))]
    return spec


def init(cfg: LMConfig, seed):
    """Initialize parameters from a scalar uint32 seed."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith((".scale",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith((".bias", ".up_bias", ".down_bias")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[-1]
            std = 0.02 if name == "embed.weight" else fan_in ** -0.5
            params.append(jax.random.normal(sub, shape, jnp.float32) * std)
    return params


def _layernorm(x, scale, bias):
    mu = x.mean(-1, keepdims=True)
    var = ((x - mu) ** 2).mean(-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-5) * scale + bias


def _gelu(y):
    return 0.5 * y * (1.0 + jnp.tanh(0.7978845608028654 * (y + 0.044715 * y**3)))


def forward(cfg: LMConfig, params, tokens, *, pallas_mlp: bool = False):
    """Logits for next-token prediction. tokens: int32[B, S]."""
    it = iter(params)

    def nxt():
        return next(it)

    emb = nxt()
    x = emb[tokens]  # [B, S, D]
    b_, s, d = x.shape
    pos = jnp.arange(s)
    # fixed sinusoidal positions (no learned pos table: keeps spec small)
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half) / half * 5.0)
    pe = jnp.concatenate(
        [jnp.sin(pos[:, None] * freqs[None, :]), jnp.cos(pos[:, None] * freqs[None, :])],
        axis=-1,
    )
    x = x + pe[None]
    mask = jnp.tril(jnp.ones((s, s), jnp.float32))
    for _ in range(cfg.n_blocks):
        ln1s, ln1b = nxt(), nxt()
        wq, wk, wv, wo = nxt(), nxt(), nxt(), nxt()
        ln2s, ln2b = nxt(), nxt()
        up, upb, down, downb = nxt(), nxt(), nxt(), nxt()
        h = _layernorm(x, ln1s, ln1b)
        q = (h @ wq).reshape(b_, s, cfg.n_heads, cfg.d_head)
        k = (h @ wk).reshape(b_, s, cfg.n_heads, cfg.d_head)
        v = (h @ wv).reshape(b_, s, cfg.n_heads, cfg.d_head)
        att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / (cfg.d_head**0.5)
        att = jnp.where(mask[None, None], att, -1e9)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b_, s, d)
        x = x + o @ wo
        h2 = _layernorm(x, ln2s, ln2b)
        if pallas_mlp:
            from ..kernels.fused_linear import fused_linear

            hid = fused_linear(h2.reshape(b_ * s, d), up, upb).reshape(b_, s, 4 * d)
        else:
            hid = _gelu(h2 @ up + upb)
        x = x + hid @ down + downb
    lnfs, lnfb = nxt(), nxt()
    x = _layernorm(x, lnfs, lnfb)
    head = nxt()
    return x @ head.T


def loss_fn(cfg: LMConfig, params, tokens, *, pallas_mlp: bool = False):
    """Mean next-token cross-entropy."""
    logits = forward(cfg, params, tokens[:, :-1], pallas_mlp=pallas_mlp)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return nll.mean()


def adam_init(cfg: LMConfig):
    """Zeroed Adam moments, same structure as params."""
    zeros = [jnp.zeros(s, jnp.float32) for _, s in param_spec(cfg)]
    return zeros, [z.copy() for z in zeros]


def train_step(cfg: LMConfig, params, m, v, tokens, lr, step):
    """One Adam step. Returns (params', m', v', loss)."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, tokens))(params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    t = step + 1.0
    new_p, new_m, new_v = [], [], []
    for p, mi, vi, g in zip(params, m, v, grads):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * g * g
        mhat = mi / (1 - b1**t)
        vhat = vi / (1 - b2**t)
        new_p.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v, loss


def grads_of(cfg: LMConfig, params, tokens):
    """Raw gradients at `params` (the Fig. 7 gradient artifact)."""
    return jax.grad(lambda p: loss_fn(cfg, p, tokens))(params)


def export_bf16(arrays):
    """Bitcast arrays to bf16 bit patterns (uint16) for Rust-side bytes."""
    return [
        jax.lax.bitcast_convert_type(a.astype(jnp.bfloat16), jnp.uint16) for a in arrays
    ]
