"""Small residual CNN + SGD/momentum — the ResNet-18-fine-tune analog
(paper §4.2, Figs. 8–9).

FP32 throughout, step-wise LR schedule driven from Rust (the paper's
Fig. 8 "steps coincide with the LR scheduler" effect). Checkpoints are
exported as fp32 bit patterns (`bitcast -> uint32`).
"""

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    """Residual CNN hyperparameters."""

    image: int = 16
    channels: int = 3
    width: int = 16
    n_blocks: int = 2
    classes: int = 10
    batch: int = 16


TINY = CNNConfig(image=8, width=8, n_blocks=1, batch=4)
SMALL = CNNConfig()


def param_spec(cfg: CNNConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Ordered (name, shape) list — the flattening contract with Rust."""
    w = cfg.width
    spec = [("stem.conv", (3, 3, cfg.channels, w)), ("stem.bias", (w,))]
    for b in range(cfg.n_blocks):
        p = f"layer.{b}"
        spec += [
            (f"{p}.conv1", (3, 3, w, w)),
            (f"{p}.bias1", (w,)),
            (f"{p}.conv2", (3, 3, w, w)),
            (f"{p}.bias2", (w,)),
        ]
    spec += [("head.fc", (w, cfg.classes)), ("head.bias", (cfg.classes,))]
    return spec


def init(cfg: CNNConfig, seed):
    """He-init parameters from a scalar uint32 seed."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("bias") or ".bias" in name:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for s in shape[:-1]:
                fan_in *= s
            params.append(
                jax.random.normal(sub, shape, jnp.float32) * (2.0 / fan_in) ** 0.5
            )
    return params


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def forward(cfg: CNNConfig, params, images):
    """Class logits. images: f32[B, H, W, C]."""
    it = iter(params)
    x = jax.nn.relu(_conv(images, next(it)) + next(it))
    for _ in range(cfg.n_blocks):
        h = jax.nn.relu(_conv(x, next(it)) + next(it))
        h = _conv(h, next(it)) + next(it)
        x = jax.nn.relu(x + h)
    x = x.mean(axis=(1, 2))  # global average pool
    return x @ next(it) + next(it)


def loss_fn(cfg: CNNConfig, params, images, labels):
    """Mean cross-entropy."""
    logits = forward(cfg, params, images)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()


def momentum_init(cfg: CNNConfig):
    """Zeroed momentum buffers."""
    return [jnp.zeros(s, jnp.float32) for _, s in param_spec(cfg)]


def train_step(cfg: CNNConfig, params, mom, images, labels, lr):
    """One SGD+momentum(0.9) step. Returns (params', mom', loss)."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, images, labels))(params)
    new_p, new_m = [], []
    for p, m, g in zip(params, mom, grads):
        m = 0.9 * m + g
        new_p.append(p - lr * m)
        new_m.append(m)
    return new_p, new_m, loss


def export_f32(arrays):
    """Bitcast fp32 arrays to uint32 bit patterns for Rust-side bytes."""
    return [jax.lax.bitcast_convert_type(a, jnp.uint32) for a in arrays]
