"""AOT pipeline: lower every Layer-2 graph to HLO **text** + manifest.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """Convert a jax Lowered to XLA HLO text with a tuple root."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _dtype_name(dt) -> str:
    import numpy as np

    return {
        np.dtype("uint8"): "u8",
        np.dtype("uint16"): "u16",
        np.dtype("uint32"): "u32",
        np.dtype("int32"): "i32",
        np.dtype("float32"): "f32",
    }[np.dtype(dt)]


def lower_all(out_dir: str, only=None) -> dict:
    """Lower all artifacts into `out_dir`; return the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    entries = model.all_entries()
    manifest = {"version": 1, "artifacts": {}, "models": model.model_manifests()}
    for name, (fn, args) in sorted(entries.items()):
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *args)
        if not isinstance(out_avals, (tuple, list)):
            out_avals = (out_avals,)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [
                {"shape": list(a.shape), "dtype": _dtype_name(a.dtype)} for a in args
            ],
            "outputs": [
                {"shape": list(a.shape), "dtype": _dtype_name(a.dtype)}
                for a in out_avals
            ],
        }
        print(f"lowered {name}: {len(text)} chars, "
              f"{len(args)} inputs, {len(out_avals)} outputs")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument("--only", nargs="*", help="subset of artifact names")
    args = parser.parse_args()
    lower_all(args.out, only=set(args.only) if args.only else None)
    print(f"manifest written to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
