"""Pallas XOR-delta kernel (paper §4.2): elementwise XOR of two
checkpoints' raw bits. Pure VPU op, BlockSpec-tiled."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 32 * 1024


def _xor_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = a_ref[...] ^ b_ref[...]


def xor_delta_u32(a_u32, b_u32):
    """XOR two uint32 buffers. N % BLOCK == 0."""
    n = a_u32.shape[0]
    grid = n // BLOCK
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _xor_kernel,
        grid=(grid,),
        in_specs=[spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=True,
    )(a_u32, b_u32)
