"""Pallas byte-plane kernels: the codec's data-movement hot-spot.

The ZipNN byte-group transform (paper Fig. 3/5) expressed as Pallas
kernels. On TPU this is a pure VPU permute/mask pipeline tiled by
BlockSpec into VMEM-sized blocks; `interpret=True` is mandatory here —
the CPU PJRT client cannot execute Mosaic custom-calls (see DESIGN.md
§Hardware-Adaptation).

Block size: 32Ki elements per grid step, so a 128Ki-element chunk (one
256 KiB bf16 chunk, the paper's granularity) runs as a 4-step grid. Per
step the bf16 kernel touches 32Ki*2 B in + 2*32Ki B out = 128 KiB, far
under the ~16 MiB VMEM budget; the fp32 kernel 256 KiB.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 32 * 1024


def _split_bf16_kernel(x_ref, hi_ref, lo_ref):
    x = x_ref[...]
    hi_ref[...] = (x >> 8).astype(jnp.uint8)
    lo_ref[...] = (x & 0xFF).astype(jnp.uint8)


def split_bf16(x_u16):
    """Split bf16 words into (hi, lo) byte planes. N % BLOCK == 0."""
    n = x_u16.shape[0]
    grid = n // BLOCK
    return pl.pallas_call(
        _split_bf16_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=(
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n,), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.uint8),
        ),
        interpret=True,
    )(x_u16)


def _merge_bf16_kernel(hi_ref, lo_ref, o_ref):
    o_ref[...] = (hi_ref[...].astype(jnp.uint16) << 8) | lo_ref[...].astype(jnp.uint16)


def merge_bf16(hi_u8, lo_u8):
    """Inverse of :func:`split_bf16`."""
    n = hi_u8.shape[0]
    grid = n // BLOCK
    return pl.pallas_call(
        _merge_bf16_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint16),
        interpret=True,
    )(hi_u8, lo_u8)


def _split_fp32_kernel(x_ref, b3_ref, b2_ref, b1_ref, b0_ref):
    x = x_ref[...]
    b3_ref[...] = (x >> 24).astype(jnp.uint8)
    b2_ref[...] = ((x >> 16) & 0xFF).astype(jnp.uint8)
    b1_ref[...] = ((x >> 8) & 0xFF).astype(jnp.uint8)
    b0_ref[...] = (x & 0xFF).astype(jnp.uint8)


def split_fp32(x_u32):
    """Split fp32 words into 4 byte planes (msb first). N % BLOCK == 0."""
    n = x_u32.shape[0]
    grid = n // BLOCK
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _split_fp32_kernel,
        grid=(grid,),
        in_specs=[spec],
        out_specs=(spec, spec, spec, spec),
        out_shape=tuple(jax.ShapeDtypeStruct((n,), jnp.uint8) for _ in range(4)),
        interpret=True,
    )(x_u32)


def _merge_fp32_kernel(b3_ref, b2_ref, b1_ref, b0_ref, o_ref):
    o_ref[...] = (
        (b3_ref[...].astype(jnp.uint32) << 24)
        | (b2_ref[...].astype(jnp.uint32) << 16)
        | (b1_ref[...].astype(jnp.uint32) << 8)
        | b0_ref[...].astype(jnp.uint32)
    )


def merge_fp32(b3, b2, b1, b0):
    """Inverse of :func:`split_fp32`."""
    n = b3.shape[0]
    grid = n // BLOCK
    spec = pl.BlockSpec((BLOCK,), lambda i: (i,))
    return pl.pallas_call(
        _merge_fp32_kernel,
        grid=(grid,),
        in_specs=[spec, spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=True,
    )(b3, b2, b1, b0)


@functools.partial(jax.jit, static_argnames=())
def analysis_bf16(x_u16):
    """The L2 analysis graph the Rust hot path can offload to PJRT:
    byte planes + exponent histogram of one bf16 chunk, in one HLO.
    """
    from . import exp_hist

    hi, lo = split_bf16(x_u16)
    hist = exp_hist.exp_hist_bf16(x_u16)
    return hi, lo, hist
