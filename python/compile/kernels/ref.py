"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernel tests `assert_allclose` (bit-exact
for the integer kernels) against, and double as readable specifications.
"""

import jax.numpy as jnp


def split_bf16_ref(x_u16):
    """Split bf16 words into (exponent-carrying hi byte, lo byte) planes.

    Args:
      x_u16: uint16[N] — raw bf16 bit patterns.
    Returns:
      (hi uint8[N], lo uint8[N]) — hi = sign+exp[7:1], lo = exp[0]+mantissa.
    """
    hi = (x_u16 >> 8).astype(jnp.uint8)
    lo = (x_u16 & 0xFF).astype(jnp.uint8)
    return hi, lo


def merge_bf16_ref(hi_u8, lo_u8):
    """Inverse of :func:`split_bf16_ref`."""
    return (hi_u8.astype(jnp.uint16) << 8) | lo_u8.astype(jnp.uint16)


def split_fp32_ref(x_u32):
    """Split fp32 words into 4 byte planes, most significant first.

    Returns (b3, b2, b1, b0) where b3 = sign+exp[7:1] (the paper's
    "exponent" group) and b0 = mantissa low byte.
    """
    b3 = (x_u32 >> 24).astype(jnp.uint8)
    b2 = ((x_u32 >> 16) & 0xFF).astype(jnp.uint8)
    b1 = ((x_u32 >> 8) & 0xFF).astype(jnp.uint8)
    b0 = (x_u32 & 0xFF).astype(jnp.uint8)
    return b3, b2, b1, b0


def merge_fp32_ref(b3, b2, b1, b0):
    """Inverse of :func:`split_fp32_ref`."""
    return (
        (b3.astype(jnp.uint32) << 24)
        | (b2.astype(jnp.uint32) << 16)
        | (b1.astype(jnp.uint32) << 8)
        | b0.astype(jnp.uint32)
    )


def exp_hist_bf16_ref(x_u16):
    """256-bin histogram of the bf16 exponent field (paper Fig. 2).

    exponent = bits[14:7] of the bf16 word.
    """
    exp = (x_u16.astype(jnp.uint32) >> 7) & 0xFF
    return jnp.zeros((256,), jnp.uint32).at[exp].add(1)


def xor_delta_ref(a_u32, b_u32):
    """Elementwise XOR of two raw-bits buffers (paper §4.2 delta)."""
    return a_u32 ^ b_u32


def fused_linear_ref(x, w, b):
    """GELU(x @ w + b) — the transformer MLP hot block."""
    y = x @ w + b
    return 0.5 * y * (1.0 + jnp.tanh(0.7978845608028654 * (y + 0.044715 * y**3)))
