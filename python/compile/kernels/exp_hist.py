"""Pallas exponent-histogram kernel (paper Fig. 2 statistic).

TPU-shaped formulation: per block, bin membership is computed as a
one-hot comparison matrix and reduced with a `ones @ onehot` matmul so
the MXU does the binning; grid steps accumulate into the output ref
(grid-carried accumulation, the standard Pallas reduction idiom).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 32 * 1024


def _exp_hist_kernel(x_ref, o_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...].astype(jnp.uint32)
    exp = ((x >> 7) & 0xFF).astype(jnp.int32)
    # one-hot[B, 256] via broadcast compare; reduce with a matmul so the
    # MXU performs the binning on real hardware.
    bins = jax.lax.broadcasted_iota(jnp.int32, (1, 256), 1)
    onehot = (exp[:, None] == bins).astype(jnp.float32)
    counts = jnp.ones((1, exp.shape[0]), jnp.float32) @ onehot
    o_ref[...] += counts[0].astype(jnp.uint32)


def exp_hist_bf16(x_u16):
    """256-bin histogram of bf16 exponent fields. N % BLOCK == 0."""
    n = x_u16.shape[0]
    grid = n // BLOCK
    return pl.pallas_call(
        _exp_hist_kernel,
        grid=(grid,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((256,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((256,), jnp.uint32),
        interpret=True,
    )(x_u16)
