"""Pallas fused linear kernel: GELU(x @ w + b).

The transformer MLP hot block as a blocked MXU matmul with grid-carried
accumulation over K, bias + GELU fused into the final K step. Tile sizes
are MXU-friendly (128-multiples); the fp32 accumulator lives in the
output block across K steps (VMEM-resident on TPU).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_M = 128
TILE_N = 128
TILE_K = 128


def _gelu(y):
    return 0.5 * y * (1.0 + jnp.tanh(0.7978845608028654 * (y + 0.044715 * y**3)))


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, k_steps):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _finish():
        o_ref[...] = _gelu(o_ref[...] + b_ref[...])


def fused_linear(x, w, b):
    """GELU(x @ w + b) with shapes x[M,K], w[K,N], b[N].

    K and N must be 128-multiples (weight dims — true by construction for
    the LM configs); M is padded internally to the tile size.
    """
    m_orig = x.shape[0]
    if m_orig % TILE_M != 0:
        pad = TILE_M - m_orig % TILE_M
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
    m, kdim = x.shape
    _, n = w.shape
    k_steps = kdim // TILE_K
    grid = (m // TILE_M, n // TILE_N, k_steps)
    return pl.pallas_call(
        functools.partial(_fused_linear_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_M, TILE_K), lambda i, j, k: (i, k)),
            pl.BlockSpec((TILE_K, TILE_N), lambda i, j, k: (k, j)),
            pl.BlockSpec((TILE_N,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((TILE_M, TILE_N), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)[:m_orig]
