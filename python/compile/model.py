"""Layer-2 graph assembly: every function that gets AOT-lowered, with its
example arguments — the single source of truth `aot.py` iterates over.

Each entry returns `(fn, example_args)` where `fn` is jit-able and
`example_args` are `ShapeDtypeStruct`s. Parameters travel as flat
positional lists (see `models.transformer.param_spec`) so the Rust
runtime can drive the HLO with plain literal vectors.
"""

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels import byteplanes, exp_hist, fused_linear, xor_delta
from .models import resnet, transformer

CHUNK_ELEMS_BF16 = 128 * 1024  # one 256 KiB bf16 chunk
CHUNK_ELEMS_FP32 = 64 * 1024  # one 256 KiB fp32 chunk


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def kernel_entries() -> Dict[str, Tuple]:
    """Codec-kernel artifacts (the Rust hot path's PJRT offload)."""
    u16c = _sds((CHUNK_ELEMS_BF16,), jnp.uint16)
    u8c = _sds((CHUNK_ELEMS_BF16,), jnp.uint8)
    u32c = _sds((CHUNK_ELEMS_FP32,), jnp.uint32)
    u8c4 = _sds((CHUNK_ELEMS_FP32,), jnp.uint8)
    return {
        "byteplanes_bf16_split": (
            lambda x: tuple(byteplanes.split_bf16(x)),
            [u16c],
        ),
        "byteplanes_bf16_merge": (
            lambda hi, lo: (byteplanes.merge_bf16(hi, lo),),
            [u8c, u8c],
        ),
        "byteplanes_fp32_split": (
            lambda x: tuple(byteplanes.split_fp32(x)),
            [u32c],
        ),
        "byteplanes_fp32_merge": (
            lambda b3, b2, b1, b0: (byteplanes.merge_fp32(b3, b2, b1, b0),),
            [u8c4, u8c4, u8c4, u8c4],
        ),
        "exp_hist_bf16": (
            lambda x: (exp_hist.exp_hist_bf16(x),),
            [u16c],
        ),
        "analysis_bf16": (
            lambda x: (
                *byteplanes.split_bf16(x),
                exp_hist.exp_hist_bf16(x),
            ),
            [u16c],
        ),
        "xor_delta_u32": (
            lambda a, b: (xor_delta.xor_delta_u32(a, b),),
            [u32c, u32c],
        ),
        "fused_linear_demo": (
            lambda x, w, b: (fused_linear.fused_linear(x, w, b),),
            [_sds((128, 128), jnp.float32), _sds((128, 128), jnp.float32),
             _sds((128,), jnp.float32)],
        ),
    }


def lm_entries(cfg: transformer.LMConfig, prefix: str) -> Dict[str, Tuple]:
    """Transformer-LM artifacts for one preset."""
    spec = transformer.param_spec(cfg)
    p_sds = [_sds(s, jnp.float32) for _, s in spec]
    tok = _sds((cfg.batch, cfg.seq_len), jnp.int32)
    scalar = _sds((), jnp.float32)
    seed = _sds((), jnp.uint32)
    n = len(spec)

    def step_fn(*args):
        params = list(args[:n])
        m = list(args[n : 2 * n])
        v = list(args[2 * n : 3 * n])
        tokens, lr, stp = args[3 * n], args[3 * n + 1], args[3 * n + 2]
        new_p, new_m, new_v, loss = transformer.train_step(
            cfg, params, m, v, tokens, lr, stp
        )
        return (*new_p, *new_m, *new_v, loss)

    def init_fn(s):
        params = transformer.init(cfg, s)
        m, v = transformer.adam_init(cfg)
        return (*params, *m, *v)

    def grads_fn(*args):
        params = list(args[:n])
        tokens = args[n]
        g = transformer.grads_of(cfg, params, tokens)
        return tuple(transformer.export_bf16(g))

    def export_fn(*args):
        return tuple(transformer.export_bf16(list(args)))

    def loss_fn(*args):
        params = list(args[:n])
        tokens = args[n]
        return (transformer.loss_fn(cfg, params, tokens),)

    return {
        f"{prefix}_init": (init_fn, [seed]),
        f"{prefix}_step": (step_fn, p_sds * 3 + [tok, scalar, scalar]),
        f"{prefix}_grads": (grads_fn, p_sds + [tok]),
        f"{prefix}_export": (export_fn, p_sds),
        f"{prefix}_loss": (loss_fn, p_sds + [tok]),
    }


def cnn_entries(cfg: resnet.CNNConfig, prefix: str) -> Dict[str, Tuple]:
    """Residual-CNN artifacts for one preset."""
    spec = resnet.param_spec(cfg)
    p_sds = [_sds(s, jnp.float32) for _, s in spec]
    img = _sds((cfg.batch, cfg.image, cfg.image, cfg.channels), jnp.float32)
    lbl = _sds((cfg.batch,), jnp.int32)
    scalar = _sds((), jnp.float32)
    seed = _sds((), jnp.uint32)
    n = len(spec)

    def init_fn(s):
        params = resnet.init(cfg, s)
        mom = resnet.momentum_init(cfg)
        return (*params, *mom)

    def step_fn(*args):
        params = list(args[:n])
        mom = list(args[n : 2 * n])
        images, labels, lr = args[2 * n], args[2 * n + 1], args[2 * n + 2]
        new_p, new_m, loss = resnet.train_step(cfg, params, mom, images, labels, lr)
        return (*new_p, *new_m, loss)

    def export_fn(*args):
        return tuple(resnet.export_f32(list(args)))

    return {
        f"{prefix}_init": (init_fn, [seed]),
        f"{prefix}_step": (step_fn, p_sds * 2 + [img, lbl, scalar]),
        f"{prefix}_export": (export_fn, p_sds),
    }


def model_manifests() -> Dict[str, Dict]:
    """Per-preset metadata recorded in the manifest for the Rust runtime."""

    def lm_meta(cfg):
        return {
            "kind": "lm",
            "params": [
                {"name": n, "shape": list(s), "dtype": "f32"}
                for n, s in transformer.param_spec(cfg)
            ],
            "config": {
                "vocab": cfg.vocab, "d_model": cfg.d_model,
                "n_heads": cfg.n_heads, "n_blocks": cfg.n_blocks,
                "seq_len": cfg.seq_len, "batch": cfg.batch,
            },
            "export_dtype": "bf16",
        }

    def cnn_meta(cfg):
        return {
            "kind": "cnn",
            "params": [
                {"name": n, "shape": list(s), "dtype": "f32"}
                for n, s in resnet.param_spec(cfg)
            ],
            "config": {
                "image": cfg.image, "channels": cfg.channels,
                "width": cfg.width, "n_blocks": cfg.n_blocks,
                "classes": cfg.classes, "batch": cfg.batch,
            },
            "export_dtype": "f32",
        }

    return {
        "lm_tiny": lm_meta(transformer.TINY),
        "lm_small": lm_meta(transformer.SMALL),
        "cnn_tiny": cnn_meta(resnet.TINY),
        "cnn_small": cnn_meta(resnet.SMALL),
    }


def all_entries() -> Dict[str, Tuple]:
    """Every artifact to lower."""
    entries: Dict[str, Tuple] = {}
    entries.update(kernel_entries())
    entries.update(lm_entries(transformer.TINY, "lm_tiny"))
    entries.update(lm_entries(transformer.SMALL, "lm_small"))
    entries.update(cnn_entries(resnet.TINY, "cnn_tiny"))
    entries.update(cnn_entries(resnet.SMALL, "cnn_small"))
    return entries


def spec_names(kind: str, preset: str) -> List[str]:
    """Parameter names for a preset (layer labels for Fig. 7)."""
    if kind == "lm":
        cfg = {"lm_tiny": transformer.TINY, "lm_small": transformer.SMALL}[preset]
        return [n for n, _ in transformer.param_spec(cfg)]
    cfg = {"cnn_tiny": resnet.TINY, "cnn_small": resnet.SMALL}[preset]
    return [n for n, _ in resnet.param_spec(cfg)]
