"""L2 model tests: shapes, loss decrease, optimizer behaviour, exports."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.models import resnet, transformer


class TestTransformer:
    cfg = transformer.TINY

    def _params(self, seed=0):
        return transformer.init(self.cfg, jnp.uint32(seed))

    def test_param_spec_matches_init(self):
        params = self._params()
        spec = transformer.param_spec(self.cfg)
        assert len(params) == len(spec)
        for p, (name, shape) in zip(params, spec):
            assert p.shape == shape, name

    def test_forward_shape(self):
        params = self._params()
        tokens = jnp.zeros((self.cfg.batch, self.cfg.seq_len), jnp.int32)
        logits = transformer.forward(self.cfg, params, tokens)
        assert logits.shape == (self.cfg.batch, self.cfg.seq_len, self.cfg.vocab)

    def test_initial_loss_near_uniform(self):
        params = self._params()
        rng = np.random.default_rng(0)
        tokens = jnp.asarray(
            rng.integers(0, self.cfg.vocab, (self.cfg.batch, self.cfg.seq_len)),
            jnp.int32,
        )
        loss = transformer.loss_fn(self.cfg, params, tokens)
        assert abs(float(loss) - np.log(self.cfg.vocab)) < 0.5

    def test_loss_decreases_under_training(self):
        params = self._params()
        m, v = transformer.adam_init(self.cfg)
        rng = np.random.default_rng(1)
        # learnable structure: deterministic token cycle
        base = rng.integers(0, self.cfg.vocab, self.cfg.seq_len + 1)
        tokens = jnp.asarray(
            np.stack([base] * self.cfg.batch)[:, : self.cfg.seq_len], jnp.int32
        )
        step = jax.jit(
            lambda p, m, v, t, s: transformer.train_step(
                self.cfg, p, m, v, t, 1e-2, s
            )
        )
        first = None
        for i in range(8):
            params, m, v, loss = step(params, m, v, tokens, jnp.float32(i))
            first = first if first is not None else float(loss)
        assert float(loss) < first * 0.7, (first, float(loss))

    def test_pallas_mlp_matches_jnp(self):
        # d_model must be 128-divisible for the pallas path: use a custom cfg
        cfg = transformer.LMConfig(
            vocab=64, d_model=128, n_heads=2, n_blocks=1, seq_len=16, batch=8
        )
        params = transformer.init(cfg, jnp.uint32(0))
        tokens = jnp.asarray(
            np.random.default_rng(2).integers(0, cfg.vocab, (cfg.batch, cfg.seq_len)),
            jnp.int32,
        )
        a = transformer.loss_fn(cfg, params, tokens, pallas_mlp=False)
        b = transformer.loss_fn(cfg, params, tokens, pallas_mlp=True)
        np.testing.assert_allclose(float(a), float(b), rtol=1e-5)

    def test_export_bf16_bitcast(self):
        params = self._params()
        out = transformer.export_bf16(params)
        assert all(o.dtype == jnp.uint16 for o in out)
        # bitcast of 1.0 (ln scale) must be 0x3F80
        scale_idx = [n for n, _ in transformer.param_spec(self.cfg)].index(
            "blocks.0.ln1.scale"
        )
        assert int(np.asarray(out[scale_idx])[0]) == 0x3F80

    def test_grads_shapes(self):
        params = self._params()
        tokens = jnp.zeros((self.cfg.batch, self.cfg.seq_len), jnp.int32)
        g = transformer.grads_of(self.cfg, params, tokens)
        assert len(g) == len(params)
        for gi, pi in zip(g, params):
            assert gi.shape == pi.shape


class TestCNN:
    cfg = resnet.TINY

    def _params(self, seed=0):
        return resnet.init(self.cfg, jnp.uint32(seed))

    def test_forward_shape(self):
        params = self._params()
        imgs = jnp.zeros(
            (self.cfg.batch, self.cfg.image, self.cfg.image, self.cfg.channels),
            jnp.float32,
        )
        logits = resnet.forward(self.cfg, params, imgs)
        assert logits.shape == (self.cfg.batch, self.cfg.classes)

    def test_loss_decreases(self):
        params = self._params()
        mom = resnet.momentum_init(self.cfg)
        rng = np.random.default_rng(3)
        labels = jnp.asarray(rng.integers(0, self.cfg.classes, self.cfg.batch), jnp.int32)
        # class-dependent mean makes the task learnable
        imgs = rng.normal(
            0, 1, (self.cfg.batch, self.cfg.image, self.cfg.image, self.cfg.channels)
        ).astype(np.float32)
        imgs += np.asarray(labels)[:, None, None, None] * 0.3
        imgs = jnp.asarray(imgs)
        step = jax.jit(
            lambda p, m: resnet.train_step(self.cfg, p, m, imgs, labels, 0.05)
        )
        first = None
        for _ in range(10):
            params, mom, loss = step(params, mom)
            first = first if first is not None else float(loss)
        assert float(loss) < first, (first, float(loss))

    def test_export_f32_bitcast(self):
        params = self._params()
        out = resnet.export_f32(params)
        assert all(o.dtype == jnp.uint32 for o in out)
        flat = np.asarray(out[0]).reshape(-1)
        back = flat.view(np.float32)
        np.testing.assert_array_equal(back, np.asarray(params[0]).reshape(-1))
