"""Pallas fused-linear kernel vs oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import fused_linear, ref


def _mats(m, k, n, seed):
    rng = np.random.default_rng(seed)
    return (
        rng.normal(0, 1, (m, k)).astype(np.float32),
        rng.normal(0, k**-0.5, (k, n)).astype(np.float32),
        rng.normal(0, 0.1, (n,)).astype(np.float32),
    )


def test_matches_ref_single_tile():
    x, w, b = _mats(128, 128, 128, 0)
    got = np.asarray(fused_linear.fused_linear(x, w, b))
    want = np.asarray(ref.fused_linear_ref(x, w, b))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matches_ref_multi_tile():
    x, w, b = _mats(256, 384, 256, 1)
    got = np.asarray(fused_linear.fused_linear(x, w, b))
    want = np.asarray(ref.fused_linear_ref(x, w, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


def test_zero_bias_zero_input():
    x = np.zeros((128, 128), np.float32)
    w = np.ones((128, 128), np.float32)
    b = np.zeros((128,), np.float32)
    got = np.asarray(fused_linear.fused_linear(x, w, b))
    np.testing.assert_allclose(got, 0.0, atol=1e-7)


@settings(max_examples=5, deadline=None)
@given(
    mt=st.integers(1, 2), kt=st.integers(1, 3), nt=st.integers(1, 2),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_shapes(mt, kt, nt, seed):
    x, w, b = _mats(128 * mt, 128 * kt, 128 * nt, seed)
    got = np.asarray(fused_linear.fused_linear(x, w, b))
    want = np.asarray(ref.fused_linear_ref(x, w, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
