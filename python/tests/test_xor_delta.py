"""Pallas XOR-delta kernel vs oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, xor_delta

BLOCK = xor_delta.BLOCK


def _rand(n, seed):
    return np.random.default_rng(seed).integers(0, 1 << 32, size=n, dtype=np.uint32)


def test_matches_ref():
    a, b = _rand(BLOCK, 0), _rand(BLOCK, 1)
    np.testing.assert_array_equal(
        np.asarray(xor_delta.xor_delta_u32(a, b)),
        np.asarray(ref.xor_delta_ref(a, b)),
    )


def test_self_inverse():
    a, b = _rand(2 * BLOCK, 2), _rand(2 * BLOCK, 3)
    d = np.asarray(xor_delta.xor_delta_u32(a, b))
    back = np.asarray(xor_delta.xor_delta_u32(a, d))
    np.testing.assert_array_equal(back, b)


def test_identical_inputs_zero():
    a = _rand(BLOCK, 4)
    assert not np.asarray(xor_delta.xor_delta_u32(a, a)).any()


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis(seed):
    a, b = _rand(BLOCK, seed), _rand(BLOCK, seed + 1)
    np.testing.assert_array_equal(
        np.asarray(xor_delta.xor_delta_u32(a, b)), a ^ b
    )
