"""AOT pipeline tests: HLO text emitted, parseable, manifest consistent."""

import json
import os

import jax
import numpy as np
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model


@pytest.fixture(scope="module")
def kernel_artifacts(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    names = {"byteplanes_bf16_split", "exp_hist_bf16", "xor_delta_u32",
             "lm_tiny_init", "lm_tiny_step", "cnn_tiny_init"}
    manifest = aot.lower_all(out, only=names)
    return out, manifest


def test_hlo_text_emitted_and_loads(kernel_artifacts):
    out, manifest = kernel_artifacts
    for name, meta in manifest["artifacts"].items():
        path = os.path.join(out, meta["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert "HloModule" in text, name
        # The CPU client must accept the text round-trip (the exact check
        # the Rust loader performs via HloModuleProto::from_text_file).
        comp = xc._xla.hlo_module_from_text(text)
        assert comp is not None


def test_manifest_records_shapes(kernel_artifacts):
    _, manifest = kernel_artifacts
    art = manifest["artifacts"]["byteplanes_bf16_split"]
    assert art["inputs"] == [{"shape": [131072], "dtype": "u16"}]
    assert art["outputs"] == [
        {"shape": [131072], "dtype": "u8"},
        {"shape": [131072], "dtype": "u8"},
    ]
    hist = manifest["artifacts"]["exp_hist_bf16"]
    assert hist["outputs"] == [{"shape": [256], "dtype": "u32"}]


def test_manifest_models_block(kernel_artifacts):
    _, manifest = kernel_artifacts
    lm = manifest["models"]["lm_tiny"]
    assert lm["kind"] == "lm"
    assert lm["params"][0]["name"] == "embed.weight"
    n_params = len(lm["params"])
    step = manifest["artifacts"]["lm_tiny_step"]
    # step signature: params + m + v + tokens + lr + step
    assert len(step["inputs"]) == 3 * n_params + 3
    assert len(step["outputs"]) == 3 * n_params + 1


def test_step_artifact_executes_via_xla_client(kernel_artifacts):
    """End-to-end smoke at the Python level: compile the lowered text with
    the raw XLA client and run one LM step, mirroring the Rust runtime."""
    out, manifest = kernel_artifacts
    text = open(os.path.join(out, "lm_tiny_init.hlo.txt")).read()
    # executing via jax against the original function is covered in
    # test_models; here we only assert the text parses into a module with
    # the right program shape.
    mod = xc._xla.hlo_module_from_text(text)
    assert mod.computations() is not None


def test_manifest_json_round_trips(kernel_artifacts):
    out, _ = kernel_artifacts
    with open(os.path.join(out, "manifest.json")) as f:
        m = json.load(f)
    assert m["version"] == 1
    assert "artifacts" in m and "models" in m


def test_analysis_graph_consistent_with_parts():
    """The fused analysis graph equals split + hist run separately."""
    entries = model.kernel_entries()
    fn, args = entries["analysis_bf16"]
    x = np.random.default_rng(0).integers(
        0, 1 << 16, size=args[0].shape, dtype=np.uint16
    )
    hi, lo, hist = jax.jit(fn)(x)
    sfn, _ = entries["byteplanes_bf16_split"]
    hfn, _ = entries["exp_hist_bf16"]
    hi2, lo2 = jax.jit(sfn)(x)
    (hist2,) = jax.jit(hfn)(x)
    np.testing.assert_array_equal(np.asarray(hi), np.asarray(hi2))
    np.testing.assert_array_equal(np.asarray(lo), np.asarray(lo2))
    np.testing.assert_array_equal(np.asarray(hist), np.asarray(hist2))
