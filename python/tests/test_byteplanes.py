"""Pallas byte-plane kernels vs pure-jnp oracle — bit-exact, hypothesis-swept."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import byteplanes
from compile.kernels import ref

BLOCK = byteplanes.BLOCK


def _rand_u16(n, seed):
    return np.random.default_rng(seed).integers(0, 1 << 16, size=n, dtype=np.uint16)


def _rand_u32(n, seed):
    return np.random.default_rng(seed).integers(0, 1 << 32, size=n, dtype=np.uint32)


class TestBF16Planes:
    def test_split_matches_ref(self):
        x = _rand_u16(2 * BLOCK, 0)
        hi, lo = byteplanes.split_bf16(x)
        rhi, rlo = ref.split_bf16_ref(x)
        np.testing.assert_array_equal(np.asarray(hi), np.asarray(rhi))
        np.testing.assert_array_equal(np.asarray(lo), np.asarray(rlo))

    def test_merge_inverts_split(self):
        x = _rand_u16(BLOCK, 1)
        hi, lo = byteplanes.split_bf16(x)
        back = byteplanes.merge_bf16(hi, lo)
        np.testing.assert_array_equal(np.asarray(back), x)

    def test_known_values(self):
        x = np.zeros(BLOCK, np.uint16)
        x[0] = 0x3F80  # bf16 1.0
        x[1] = 0xBF00
        hi, lo = byteplanes.split_bf16(x)
        assert np.asarray(hi)[0] == 0x3F and np.asarray(lo)[0] == 0x80
        assert np.asarray(hi)[1] == 0xBF and np.asarray(lo)[1] == 0x00

    @settings(max_examples=10, deadline=None)
    @given(grid=st.integers(1, 3), seed=st.integers(0, 2**31 - 1))
    def test_roundtrip_hypothesis(self, grid, seed):
        x = _rand_u16(grid * BLOCK, seed)
        hi, lo = byteplanes.split_bf16(x)
        np.testing.assert_array_equal(
            np.asarray(byteplanes.merge_bf16(hi, lo)), x
        )


class TestFP32Planes:
    def test_split_matches_ref(self):
        x = _rand_u32(BLOCK, 2)
        planes = byteplanes.split_fp32(x)
        rplanes = ref.split_fp32_ref(x)
        for p, r in zip(planes, rplanes):
            np.testing.assert_array_equal(np.asarray(p), np.asarray(r))

    def test_merge_inverts_split(self):
        x = _rand_u32(2 * BLOCK, 3)
        b3, b2, b1, b0 = byteplanes.split_fp32(x)
        back = byteplanes.merge_fp32(b3, b2, b1, b0)
        np.testing.assert_array_equal(np.asarray(back), x)

    def test_exponent_plane_extracts_sign_exp(self):
        x = np.array([np.float32(1.0).view(np.uint32)] * BLOCK, dtype=np.uint32)
        b3, _, _, _ = byteplanes.split_fp32(x)
        # 1.0f32 = 0x3F800000 -> high byte 0x3F
        assert (np.asarray(b3) == 0x3F).all()

    @settings(max_examples=8, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_roundtrip_hypothesis(self, seed):
        x = _rand_u32(BLOCK, seed)
        back = byteplanes.merge_fp32(*byteplanes.split_fp32(x))
        np.testing.assert_array_equal(np.asarray(back), x)


@pytest.mark.parametrize("special", [0x0000, 0xFFFF, 0x7F80, 0x8000])
def test_bf16_specials_roundtrip(special):
    x = np.full(BLOCK, special, np.uint16)
    hi, lo = byteplanes.split_bf16(x)
    np.testing.assert_array_equal(np.asarray(byteplanes.merge_bf16(hi, lo)), x)
