"""Pallas exponent-histogram kernel vs oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import exp_hist, ref

BLOCK = exp_hist.BLOCK


def test_matches_ref_random():
    x = np.random.default_rng(0).integers(0, 1 << 16, size=2 * BLOCK, dtype=np.uint16)
    got = np.asarray(exp_hist.exp_hist_bf16(x))
    want = np.asarray(ref.exp_hist_bf16_ref(x))
    np.testing.assert_array_equal(got, want)
    assert got.sum() == 2 * BLOCK


def test_constant_stream_single_bin():
    # bf16 1.0 = 0x3F80 -> exponent 127
    x = np.full(BLOCK, 0x3F80, np.uint16)
    h = np.asarray(exp_hist.exp_hist_bf16(x))
    assert h[127] == BLOCK
    assert h.sum() == BLOCK


def test_gaussian_weights_are_skewed():
    rng = np.random.default_rng(1)
    w = (rng.normal(0, 0.02, size=BLOCK)).astype(np.float32)
    bits = ((w.view(np.uint32) >> 16).astype(np.uint16))  # truncate to bf16
    h = np.asarray(exp_hist.exp_hist_bf16(bits))
    nonzero = (h > 0).sum()
    top12 = np.sort(h)[-12:].sum() / h.sum()
    assert nonzero < 70
    assert top12 > 0.99


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), grid=st.integers(1, 3))
def test_hypothesis_matches_ref(seed, grid):
    x = np.random.default_rng(seed).integers(
        0, 1 << 16, size=grid * BLOCK, dtype=np.uint16
    )
    np.testing.assert_array_equal(
        np.asarray(exp_hist.exp_hist_bf16(x)), np.asarray(ref.exp_hist_bf16_ref(x))
    )
