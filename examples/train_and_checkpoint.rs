//! END-TO-END DRIVER: the full three-layer system on a real workload.
//!
//! Trains the transformer LM for a few hundred steps **from Rust via the
//! PJRT runtime** (L2 JAX graphs embedding the L1 Pallas kernels, AOT-
//! lowered by `make artifacts`), logs the loss curve, checkpoints
//! periodically, and runs the full ZipNN pipeline over the artifacts:
//! standalone model compression, gradient/optimizer compression (paper
//! §4.1) and delta-compressed checkpoints (paper §4.2).
//!
//! ```bash
//! make artifacts && cargo run --release --example train_and_checkpoint
//! # faster smoke run:
//! ZIPNN_E2E_STEPS=40 cargo run --release --example train_and_checkpoint
//! ```

use zipnn::bench_support::Table;
use zipnn::codec::{CodecConfig, Compressor};
use zipnn::delta::{BaseStrategy, CheckpointStore};
use zipnn::fp::DType;
use zipnn::runtime::Runtime;
use zipnn::train::LmTrainer;
use zipnn::util::{human_bytes, Timer};

fn pct(comp: usize, raw: usize) -> f64 {
    comp as f64 / raw as f64 * 100.0
}

fn main() -> anyhow::Result<()> {
    let steps: usize = std::env::var("ZIPNN_E2E_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(300);
    let ckpt_every = (steps / 10).max(1);

    let rt = Runtime::open("artifacts")
        .map_err(|e| anyhow::anyhow!("{e}\nrun `make artifacts` first"))?;
    println!("PJRT platform: {}", rt.platform());

    let preset = std::env::var("ZIPNN_E2E_PRESET").unwrap_or_else(|_| "lm_small".into());
    let mut tr = LmTrainer::new(&rt, &preset, 2024)?;
    let first_ckpt = tr.export_model()?;
    println!(
        "model: {} — {} tensors, {} ({} params, bf16 export)",
        preset,
        first_ckpt.tensors.len(),
        human_bytes(first_ckpt.size_bytes() as u64),
        first_ckpt.numel()
    );

    // ---- training loop with periodic checkpoints ----
    let comp = Compressor::new(CodecConfig::for_dtype(DType::BF16));
    let mut store = CheckpointStore::new(DType::BF16, BaseStrategy::Chain(5));
    let mut ckpt_rows = Vec::new();
    let t_train = Timer::start();
    for step in 0..steps {
        // 3-phase step LR schedule (the paper's Fig. 8 setup)
        let lr = match step * 3 / steps {
            0 => 3e-3,
            1 => 1e-3,
            _ => 3e-4,
        };
        let loss = tr.step(lr)?;
        if step % ckpt_every == ckpt_every - 1 {
            let ckpt = tr.export_model()?;
            let raw = ckpt.to_bytes();
            let standalone = comp.compress(&raw)?;
            let entry = store.push(&raw)?;
            ckpt_rows.push((
                step + 1,
                loss,
                pct(standalone.len(), raw.len()),
                entry.pct(),
                entry.is_base,
            ));
            println!(
                "step {:>4}  loss {:.4}  standalone {:>5.1}%  {} {:>5.1}%",
                step + 1,
                loss,
                pct(standalone.len(), raw.len()),
                if entry.is_base { "base " } else { "delta" },
                entry.pct()
            );
        }
    }
    let train_secs = t_train.secs();
    println!(
        "\ntrained {steps} steps in {train_secs:.1}s ({:.2} s/step); loss {:.4} -> {:.4}",
        train_secs / steps as f64,
        tr.losses.first().unwrap(),
        tr.losses.last().unwrap()
    );

    // ---- verify checkpoint recovery through the delta chain ----
    let last_idx = store.entries().len() - 1;
    let recovered = store.recover(last_idx)?;
    let current = tr.export_model()?.to_bytes();
    assert_eq!(recovered, current, "delta-chain recovery must be bit-exact");
    println!("checkpoint {last_idx} recovered bit-exact through the delta chain");

    // ---- paper §4.1: model vs gradients vs optimizer compressibility ----
    let model_m = tr.export_model()?;
    let grads_m = tr.export_grads()?;
    let (adam_m, adam_v) = tr.export_optimizer()?;
    let mut table = Table::new(&["artifact", "raw", "zipnn %", "embed-layer %"]);
    for (label, m) in [
        ("model", &model_m),
        ("gradients", &grads_m),
        ("optimizer (m)", &adam_m),
        ("optimizer (v)", &adam_v),
    ] {
        let raw = m.to_bytes();
        let c = comp.compress(&raw)?;
        let emb = m.tensor("embed.weight").expect("embed");
        let emb_c = comp.compress(&emb.data)?;
        table.row(&[
            label.to_string(),
            human_bytes(raw.len() as u64),
            format!("{:.1}", pct(c.len(), raw.len())),
            format!("{:.1}", pct(emb_c.len(), emb.data.len())),
        ]);
    }
    table.print();
    println!("(paper Fig. 7: model ≈ 66%, optimizer ≈ 54%, gradients ≈ 47%, with the\n embedding layer far more compressible in grads/optimizer than in the model)");

    // ---- loss curve + checkpoint summary for EXPERIMENTS.md ----
    println!("\nloss curve (every {ckpt_every} steps):");
    for (step, loss, s_pct, d_pct, is_base) in &ckpt_rows {
        println!(
            "  step {:>4}: loss {:.4}, standalone {:.1}%, {} {:.1}%",
            step,
            loss,
            s_pct,
            if *is_base { "base" } else { "delta" },
            d_pct
        );
    }
    let total_stored = store.total_bytes();
    let total_raw: usize = store.entries().iter().map(|e| e.raw_len).sum();
    println!(
        "\ncheckpoint store: {} checkpoints, {} raw -> {} stored ({:.1}%)",
        store.entries().len(),
        human_bytes(total_raw as u64),
        human_bytes(total_stored as u64),
        pct(total_stored, total_raw)
    );
    Ok(())
}
