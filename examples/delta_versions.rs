//! Delta compression across fine-tuned model variants (paper §4.2's
//! RoBERTa-tweets case: three variants of one base compress to ~56% as
//! deltas vs ~84% standalone).
//!
//! ```bash
//! cargo run --release --example delta_versions
//! ```

use zipnn::bench_support::Table;
use zipnn::codec::{CodecConfig, Compressor};
use zipnn::delta::DeltaCodec;
use zipnn::fp::dtype::{bf16_bits_to_f32, f32_to_bf16_bits};
use zipnn::fp::DType;
use zipnn::model::synthetic::{generate, Category, SyntheticSpec};
use zipnn::model::Model;
use zipnn::util::Xoshiro256;

/// "Fine-tune" a model: perturb every weight slightly (small updates on
/// all parameters, like a few epochs of task tuning).
fn finetune(base: &Model, strength: f64, seed: u64) -> Model {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    let mut out = base.clone();
    for t in &mut out.tensors {
        for ch in t.data.chunks_exact_mut(2) {
            let bits = u16::from_le_bytes([ch[0], ch[1]]);
            let w = bf16_bits_to_f32(bits);
            let w2 = w + (rng.normal() as f32) * strength as f32 * (w.abs() + 1e-3);
            ch.copy_from_slice(&f32_to_bf16_bits(w2).to_le_bytes());
        }
    }
    out.name = format!("{}-ft{}", base.name, seed);
    out
}

fn main() -> anyhow::Result<()> {
    let base = generate(&SyntheticSpec::new(
        "roberta-tweets-base",
        Category::RegularBF16,
        32 << 20,
        7,
    ));
    let variants = [
        ("irony", finetune(&base, 0.04, 1)),
        ("offensive", finetune(&base, 0.04, 2)),
        ("abuse", finetune(&base, 0.04, 3)),
    ];

    let comp = Compressor::new(CodecConfig::for_dtype(DType::BF16));
    let dc = DeltaCodec::new(DType::BF16);
    let base_raw = base.to_bytes();

    let mut table = Table::new(&["variant", "standalone %", "delta vs base %"]);
    let mut standalone_sum = 0.0;
    let mut delta_sum = 0.0;
    for (name, m) in &variants {
        let raw = m.to_bytes();
        let standalone = comp.compress(&raw)?;
        let delta = dc.encode(&base_raw, &raw)?;
        // verify exact recovery through the delta path
        assert_eq!(dc.decode(&base_raw, &delta)?, raw);
        let s_pct = standalone.len() as f64 / raw.len() as f64 * 100.0;
        let d_pct = delta.len() as f64 / raw.len() as f64 * 100.0;
        standalone_sum += s_pct;
        delta_sum += d_pct;
        table.row(&[name.to_string(), format!("{s_pct:.1}"), format!("{d_pct:.1}")]);
    }
    table.print();
    println!(
        "\nmean standalone {:.1}%  vs  mean delta {:.1}%  (paper: 83.7% -> 56%)",
        standalone_sum / 3.0,
        delta_sum / 3.0
    );
    Ok(())
}
