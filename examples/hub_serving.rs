//! Model-hub serving: start the hub, push a batch of models through the
//! streaming coordinator, then serve uploads/downloads with and without
//! compression across the paper's network regimes (§5.3 / Fig. 10 shape).
//!
//! ```bash
//! cargo run --release --example hub_serving
//! ```
//!
//! ## The async hub
//!
//! `HubServer` is readiness-driven: one reactor thread multiplexes every
//! connection over epoll (poll(2) off Linux) and a fixed worker pool —
//! sized here via `builder().workers(..)`, defaulting to ncpu or the
//! `ZIPNN_HUB_WORKERS` env var — executes ready requests. Idle
//! keep-alive connections cost no threads, so a serving deployment sizes
//! the pool to cores, not to its connection count; `max_conns` (env
//! `ZIPNN_HUB_MAX_CONNS`, default 4096) caps acceptance. CI scales the
//! bench workloads with `ZIPNN_BENCH_MB` / `ZIPNN_BENCH_REPS` (see the
//! bench-regression job in `.github/workflows/ci.yml`).

use zipnn::bench_support::Table;
use zipnn::codec::CodecConfig;
use zipnn::coordinator::{PipelineBuilder, WorkItem};
use zipnn::fp::DType;
use zipnn::hub::{HubClient, HubServer, NetProfile, NetSim};
use zipnn::model::synthetic::{generate, Category, SyntheticSpec};
use zipnn::util::human_bytes;

fn main() -> anyhow::Result<()> {
    // -- 1. Batch-compress a model zoo through the coordinator pipeline --
    let zoo = [
        ("llama-bf16", Category::RegularBF16),
        ("olmo-fp32", Category::RegularF32),
        ("xlmR-clean", Category::CleanF32 { keep_bits: 10, frac_clean: 1.0 }),
    ];
    let mut pipeline = PipelineBuilder::new(CodecConfig::for_dtype(DType::BF16))
        .workers(2)
        .queue_depth(2)
        .start();
    let mut models = Vec::new();
    for (i, (name, cat)) in zoo.iter().enumerate() {
        let m = generate(&SyntheticSpec::new(name, *cat, 32 << 20, 100 + i as u64));
        // Shared buffer: the pipeline and the hub section below use the
        // same allocation — WorkItem clones the Arc, not the bytes.
        let raw: std::sync::Arc<[u8]> = m.to_bytes().into();
        pipeline.submit(WorkItem::new(*name, std::sync::Arc::clone(&raw)))?;
        models.push((name.to_string(), m.dominant_dtype(), raw));
    }
    let (results, metrics) = pipeline.finish();
    println!("coordinator pipeline: {} items, {:.1}% mean compressed size, {} stalls",
        results.len(),
        metrics.compressed_pct(),
        metrics.stalls.load(std::sync::atomic::Ordering::Relaxed));

    // -- 2. Serve them over the hub, timing each regime (Fig. 10) --
    // Reactor + fixed worker pool: `workers` bounds request-execution
    // threads no matter how many clients connect.
    let server = HubServer::builder().workers(2).max_conns(256).start()?;
    println!("hub listening on {}", server.addr());
    let mut client = HubClient::connect(server.addr())?.with_threads(2);

    let mut table = Table::new(&[
        "model", "size", "regime", "raw (s)", "zipnn (s)", "saving",
    ]);
    for (name, dtype, raw) in &models {
        let mut up = NetSim::new(NetProfile::UPLOAD, 1);
        let rep_up_raw = client.upload(name, raw, None, &mut up)?;
        let rep_up_c = client.upload(name, raw, Some(CodecConfig::for_dtype(*dtype)), &mut up)?;
        table.row(&[
            name.clone(),
            human_bytes(raw.len() as u64),
            "upload".into(),
            format!("{:.2}", rep_up_raw.total_secs()),
            format!("{:.2}", rep_up_c.total_secs()),
            format!("{:+.0}%", (1.0 - rep_up_c.total_secs() / rep_up_raw.total_secs()) * 100.0),
        ]);
        for profile in [
            NetProfile::CLOUD_FIRST,
            NetProfile::CLOUD_CACHED,
            NetProfile::HOME_FIRST,
            NetProfile::HOME_CACHED,
        ] {
            let mut sim = NetSim::new(profile, 2);
            let (raw_back, rep_r) = client.download(name, false, &mut sim)?;
            let (comp_back, rep_c) = client.download(name, true, &mut sim)?;
            assert_eq!(raw_back[..], raw[..]);
            assert_eq!(comp_back[..], raw[..]);
            table.row(&[
                name.clone(),
                human_bytes(raw.len() as u64),
                profile.name.into(),
                format!("{:.2}", rep_r.total_secs()),
                format!("{:.2}", rep_c.total_secs()),
                format!("{:+.0}%", (1.0 - rep_c.total_secs() / rep_r.total_secs()) * 100.0),
            ]);
        }
    }
    table.print();
    println!("\n(total secs = simulated WAN transfer + measured codec time)");
    server.shutdown();
    Ok(())
}
