//! Quickstart: compress a model with ZipNN, inspect the breakdown, verify
//! the roundtrip, and compare against vanilla Zstd.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use zipnn::codec::{compress_with_report, decompress, CodecConfig, Compressor};
use zipnn::fp::DType;
use zipnn::model::synthetic::{generate, Category, SyntheticSpec};
use zipnn::model::{read_model, write_model};
use zipnn::util::{human_bytes, Timer};

fn main() -> anyhow::Result<()> {
    // 1. A Llama-class BF16 model (synthetic analog; see DESIGN.md §2).
    let spec = SyntheticSpec::new("llama-analog", Category::RegularBF16, 64 << 20, 42);
    println!("generating {} ...", spec.name);
    let model = generate(&spec);
    let raw = model.to_bytes();
    println!(
        "  {} tensors, {} ({} params)",
        model.tensors.len(),
        human_bytes(raw.len() as u64),
        model.numel()
    );

    // 2. ZipNN compression (exponent extraction + byte grouping + Huffman).
    let cfg = CodecConfig::for_dtype(DType::BF16);
    let t = Timer::start();
    let (compressed, groups) = compress_with_report(cfg, &raw)?;
    let secs = t.secs();
    println!(
        "\nZipNN: {} -> {}  ({:.1}% of original, {:.2} GB/s)",
        human_bytes(raw.len() as u64),
        human_bytes(compressed.len() as u64),
        compressed.len() as f64 / raw.len() as f64 * 100.0,
        raw.len() as f64 / secs / 1e9,
    );
    println!("  byte-group breakdown (exponent group first):");
    for (i, g) in groups.iter().enumerate() {
        println!("    group {i}: {:.1}%", g.pct());
    }

    // 3. Exact roundtrip.
    let t = Timer::start();
    let restored = decompress(&compressed)?;
    println!(
        "decompress: {:.2} GB/s, roundtrip {}",
        raw.len() as f64 / t.secs() / 1e9,
        if restored == raw { "OK (bit-exact)" } else { "FAILED" }
    );
    assert_eq!(restored, raw);

    // 4. Baseline comparison.
    let vanilla = Compressor::new(CodecConfig::vanilla_zstd()).compress(&raw)?;
    println!(
        "\nvanilla zstd: {:.1}%  |  ZipNN: {:.1}%  ({:.1}% better)",
        vanilla.len() as f64 / raw.len() as f64 * 100.0,
        compressed.len() as f64 / raw.len() as f64 * 100.0,
        (1.0 - compressed.len() as f64 / vanilla.len() as f64) * 100.0,
    );

    // 5. Streaming codec: compress/decompress through std::io adapters
    //    without ever materializing the compressed blob's peer buffer.
    {
        use std::io::{Read, Write};
        use zipnn::codec::{ZnnReader, ZnnWriter};
        let mut w = ZnnWriter::new(Vec::new(), CodecConfig::for_dtype(DType::BF16))?;
        for part in raw.chunks(1 << 20) {
            w.write_all(part)?; // arrives in arbitrary pieces
        }
        let streamed = w.finish()?;
        let mut r = ZnnReader::new(streamed.as_slice())?;
        let mut back = Vec::new();
        r.read_to_end(&mut back)?;
        assert_eq!(back, raw);
        println!(
            "\nstreaming container: {} ({:.1}%), roundtrip OK",
            human_bytes(streamed.len() as u64),
            streamed.len() as f64 / raw.len() as f64 * 100.0
        );
    }

    // 6. Model container I/O.
    let dir = std::env::temp_dir().join("zipnn_quickstart");
    std::fs::create_dir_all(&dir)?;
    let path = dir.join("model.znnm");
    write_model(&path, &model)?;
    let back = read_model(&path)?;
    assert_eq!(back, model);
    println!("\nmodel container roundtrip via {} OK", path.display());
    Ok(())
}
