//! Table 1: compressed size of top-downloaded Hugging Face models.
//!
//! Paper values (compressed size, lower is better): Bge 42.1%, Mpnet 82.9%,
//! Bert 83.9%, Qwen 66.9%, Whisper 42.7%, xlm-RoBERTa 42.3%, Clip 49.7%,
//! Llama-3.1 67.2%. Models are synthetic analogs per category (DESIGN.md §2).

use zipnn::bench_support::{BenchEnv, Table};
use zipnn::codec::{compress_with_report, CodecConfig};
use zipnn::model::synthetic::{generate, Category, SyntheticSpec};

fn main() {
    let env = BenchEnv::from_env();
    let rows: Vec<(&str, Category, f64)> = vec![
        ("Bge (clean FP32)", Category::CleanF32 { keep_bits: 10, frac_clean: 1.0 }, 42.1),
        ("Mpnet (FP32)", Category::RegularF32, 82.9),
        ("Bert (FP32)", Category::RegularF32, 83.9),
        ("Qwen (BF16)", Category::RegularBF16, 66.9),
        ("Whisper (clean FP32)", Category::CleanF32 { keep_bits: 10, frac_clean: 1.0 }, 42.7),
        ("xlm-RoBERTa (clean FP32)", Category::CleanF32 { keep_bits: 10, frac_clean: 1.0 }, 42.3),
        ("Clip (clean FP32 mix)", Category::CleanF32 { keep_bits: 10, frac_clean: 0.85 }, 49.7),
        ("Llama 3.1 (BF16)", Category::RegularBF16, 67.2),
    ];
    let mut table = Table::new(&["model analog", "paper %", "measured %", "delta"]);
    for (i, (name, cat, paper)) in rows.iter().enumerate() {
        let m = generate(&SyntheticSpec::new(name, *cat, env.model_bytes(), 200 + i as u64));
        let raw = m.to_bytes();
        let (comp, _) =
            compress_with_report(CodecConfig::for_dtype(m.dominant_dtype()), &raw).unwrap();
        let pct = comp.len() as f64 / raw.len() as f64 * 100.0;
        table.row(&[
            name.to_string(),
            format!("{paper:.1}"),
            format!("{pct:.1}"),
            format!("{:+.1}", pct - paper),
        ]);
    }
    println!("== Table 1: compressed size of top-ranked hub models ==");
    table.print();
}
