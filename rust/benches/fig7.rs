//! Figure 7: per-layer compressibility of the model, gradients and
//! optimizer during fine-tuning (RoBERTa-analog transformer + Adam, run
//! live via the PJRT runtime).
//!
//! Paper: model ≈ 66% everywhere; in gradients/optimizer the *embedding*
//! layer is dramatically more compressible (token sparsity), general
//! layers slightly better than the model's.

use zipnn::bench_support::Table;
use zipnn::codec::{CodecConfig, Compressor};
use zipnn::fp::DType;
use zipnn::model::Model;
use zipnn::runtime::Runtime;
use zipnn::train::LmTrainer;

fn layer_of(name: &str) -> String {
    if name.starts_with("embed") {
        "embedding".into()
    } else if let Some(rest) = name.strip_prefix("blocks.") {
        format!("block {}", rest.split('.').next().unwrap_or("?"))
    } else {
        "head/norm".into()
    }
}

fn per_layer_pct(m: &Model, comp: &Compressor) -> Vec<(String, f64, u64)> {
    use std::collections::BTreeMap;
    let mut by_layer: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    for t in &m.tensors {
        let c = comp.compress(&t.data).unwrap();
        let e = by_layer.entry(layer_of(&t.name)).or_default();
        e.0 += c.len() as u64;
        e.1 += t.data.len() as u64;
    }
    by_layer
        .into_iter()
        .map(|(k, (c, r))| (k, c as f64 / r as f64 * 100.0, r))
        .collect()
}

fn main() {
    let steps: usize = std::env::var("ZIPNN_FIG7_STEPS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(30);
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("fig7 requires artifacts: {e}");
            return;
        }
    };
    let preset = std::env::var("ZIPNN_FIG7_PRESET").unwrap_or_else(|_| "lm_tiny".into());
    let mut tr = LmTrainer::new(&rt, &preset, 77).unwrap();
    println!("fine-tuning {preset} for {steps} steps ...");
    for _ in 0..steps {
        tr.step(1e-3).unwrap();
    }
    let comp = Compressor::new(CodecConfig::for_dtype(DType::BF16));
    let model = tr.export_model().unwrap();
    let grads = tr.export_grads().unwrap();
    let (adam_m, adam_v) = tr.export_optimizer().unwrap();

    let mut table = Table::new(&["layer", "model %", "grads %", "adam-m %", "adam-v %"]);
    let lm = per_layer_pct(&model, &comp);
    let lg = per_layer_pct(&grads, &comp);
    let lo = per_layer_pct(&adam_m, &comp);
    let lv = per_layer_pct(&adam_v, &comp);
    for (((m, g), o), v) in lm.iter().zip(&lg).zip(&lo).zip(&lv) {
        table.row(&[
            m.0.clone(),
            format!("{:.1}", m.1),
            format!("{:.1}", g.1),
            format!("{:.1}", o.1),
            format!("{:.1}", v.1),
        ]);
    }
    println!("== Figure 7: per-layer compressibility (model / gradients / optimizer) ==");
    table.print();
    println!(
        "(paper: embedding layer ≈ as compressible as others in the MODEL, but far\n more compressible in GRADIENTS/OPTIMIZER — loss {:.3} -> {:.3} over the run)",
        tr.losses.first().unwrap(),
        tr.losses.last().unwrap()
    );
}
