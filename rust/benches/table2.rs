//! Table 2: compressed size for the model zoo, with per-byte-group
//! breakdown (exponent group first, then mantissa bytes high→low).
//!
//! Paper rows, e.g.: FALCON-7B BF16 66.4% (32.8, 100); XLM-ROBERTA FP32
//! 41.8% (33.9, 95.6, 37.5, 0.0); T5-BASE 33.7% (34.6, 100, 0, 0);
//! LLAMA2-13B FP16 66.6% (64.2, 69.0).

use zipnn::bench_support::{BenchEnv, Table};
use zipnn::codec::{compress_with_report, CodecConfig};
use zipnn::model::synthetic::{generate, paper_zoo};

fn main() {
    let env = BenchEnv::from_env();
    let paper: &[(&str, f64, &str)] = &[
        ("falcon-7b-analog", 66.4, "(32.8, 100)"),
        ("bloom-analog", 67.4, "(34.8, 100)"),
        ("openllama-3b-analog", 66.4, "(32.7, 100)"),
        ("mistral-analog", 66.3, "(32.5, 100)"),
        ("llama-3.1-analog", 66.4, "(32.8, 99.9)"),
        ("wav2vec-analog", 83.3, "(33.0, 100, 100, 100)"),
        ("bert-analog", 83.0, "(32.6, 99.5, 100, 100)"),
        ("olmo-analog", 83.1, "(32.5, 100, 100, 100)"),
        ("stable-video-diffusion-analog", 84.8, "(69.6, 100)"),
        ("capybarahermes-analog", 84.4, "(68.8, 100)"),
        ("xlm-roberta-analog", 41.8, "(33.9, 95.6, 37.5, 0.0)"),
        ("clip-analog", 48.1, "(33.1, 100, 45.9, 13.4)"),
        ("t5-base-analog", 33.7, "(34.6, 100, 0.0, 0.0)"),
        ("llama2-13b-fp16-analog", 66.6, "(64.2, 69.0)"),
        ("tulu-7b-fp16-analog", 66.6, "(64.2, 68.9)"),
    ];
    let scale = env.model_mb / 64.0;
    let zoo = paper_zoo(scale);
    let mut table = Table::new(&[
        "model", "dtype", "paper %", "meas %", "paper groups", "measured groups",
    ]);
    for spec in &zoo {
        let m = generate(spec);
        let raw = m.to_bytes();
        let (comp, reps) =
            compress_with_report(CodecConfig::for_dtype(m.dominant_dtype()), &raw).unwrap();
        let pct = comp.len() as f64 / raw.len() as f64 * 100.0;
        let groups = reps
            .iter()
            .map(|r| format!("{:.1}", r.pct()))
            .collect::<Vec<_>>()
            .join(", ");
        let (ppct, pgroups) = paper
            .iter()
            .find(|(n, _, _)| *n == spec.name)
            .map(|(_, p, g)| (*p, *g))
            .unwrap_or((f64::NAN, "?"));
        table.row(&[
            spec.name.clone(),
            m.dominant_dtype().name().to_string(),
            format!("{ppct:.1}"),
            format!("{pct:.1}"),
            pgroups.to_string(),
            format!("({groups})"),
        ]);
    }
    println!("== Table 2: compressed size + byte-group breakdown ==");
    table.print();
}
