//! Figure 8: ResNet-analog fine-tune with per-epoch checkpoints.
//! (a) fraction of changed parameters/bytes per epoch;
//! (b) changed bytes per byte group;
//! (c) delta compression with Huffman vs Zstd vs Auto.
//!
//! The LR schedule steps down twice; the paper's "steps in the graphs
//! coincide with the LR scheduler" effect should be visible.

use zipnn::bench_support::Table;
use zipnn::codec::MethodPolicy;
use zipnn::delta::{xor_delta, DeltaCodec};
use zipnn::fp::{split_groups, DType, GroupLayout};
use zipnn::runtime::Runtime;
use zipnn::stats::changed_byte_frac;
use zipnn::train::CnnTrainer;

fn main() {
    let epochs: usize = std::env::var("ZIPNN_FIG8_EPOCHS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12);
    let steps_per_epoch: usize = std::env::var("ZIPNN_FIG8_SPE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(8);
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("fig8 requires artifacts: {e}");
            return;
        }
    };
    let preset = std::env::var("ZIPNN_FIG8_PRESET").unwrap_or_else(|_| "cnn_tiny".into());
    let mut tr = CnnTrainer::new(&rt, &preset, 88).unwrap();
    println!("training {preset}: {epochs} epochs x {steps_per_epoch} steps, step-LR");

    let layout = GroupLayout::for_dtype(DType::F32);
    let dc_auto = DeltaCodec::new(DType::F32);
    let dc_huff = DeltaCodec::new(DType::F32).with_policy(MethodPolicy::Huffman);
    let dc_zstd = DeltaCodec::new(DType::F32).with_policy(MethodPolicy::Zstd);

    let mut prev = tr.export_model().unwrap().to_bytes();
    let mut table = Table::new(&[
        "epoch", "lr", "loss", "chg bytes %", "chg g0/g1/g2/g3 %",
        "huff %", "zstd %", "auto %",
    ]);
    for e in 0..epochs {
        // 3-phase step schedule (drops at 1/3 and 2/3)
        let lr = match e * 3 / epochs {
            0 => 0.05,
            1 => 0.01,
            _ => 0.002,
        };
        let mut loss = 0.0;
        for _ in 0..steps_per_epoch {
            loss = tr.step(lr).unwrap();
        }
        let cur = tr.export_model().unwrap().to_bytes();
        let delta = xor_delta(&prev, &cur).unwrap();
        let groups = split_groups(&delta, layout).unwrap();
        let chg: Vec<f64> = groups
            .iter()
            .map(|g| {
                let zeros = g.iter().filter(|&&b| b == 0).count();
                (1.0 - zeros as f64 / g.len() as f64) * 100.0
            })
            .collect();
        let h = dc_huff.encode(&prev, &cur).unwrap();
        let z = dc_zstd.encode(&prev, &cur).unwrap();
        let a = dc_auto.encode(&prev, &cur).unwrap();
        // auto must be at least as good as the better of the two (within
        // per-chunk granularity slack)
        table.row(&[
            format!("{}", e + 1),
            format!("{lr}"),
            format!("{loss:.3}"),
            format!("{:.1}", changed_byte_frac(&prev, &cur) * 100.0),
            chg.iter().map(|c| format!("{c:.0}")).collect::<Vec<_>>().join("/"),
            format!("{:.1}", h.len() as f64 / cur.len() as f64 * 100.0),
            format!("{:.1}", z.len() as f64 / cur.len() as f64 * 100.0),
            format!("{:.1}", a.len() as f64 / cur.len() as f64 * 100.0),
        ]);
        prev = cur;
    }
    println!("== Figure 8: checkpoint deltas during CNN fine-tune ==");
    table.print();
    println!("(paper shape: changed bytes fall as LR steps down; exponent group\n changes least; Auto ≤ min(Huffman, Zstd) as convergence flips the winner)");
}
