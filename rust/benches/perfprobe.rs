//! Phase-level profiling probe for the L3 hot path (used by the §Perf
//! iteration loop; not a paper table). Times each codec phase in
//! isolation so optimization work can target the real bottleneck.

use zipnn::bench_support::{time_n, BenchEnv};
use zipnn::codec::{decompress_with, CodecConfig, Compressor};
use zipnn::fp::{merge_groups, simd, split_groups, DType, GroupLayout};
use zipnn::huffman;
use zipnn::lz;
use zipnn::model::synthetic::{generate, Category, SyntheticSpec};
use zipnn::stats::{byte_histogram, zero_stats};

fn gbps(bytes: usize, secs: f64) -> f64 {
    bytes as f64 / secs / 1e9
}

fn main() {
    let env = BenchEnv::from_env();
    let m = generate(&SyntheticSpec::new(
        "probe",
        Category::RegularBF16,
        env.model_bytes(),
        900,
    ));
    let raw = m.to_bytes();
    let n = raw.len();
    let layout = GroupLayout::for_dtype(DType::BF16);
    println!(
        "probe buffer: {} MB bf16 (byte-group kernels: {})",
        n >> 20,
        simd::dispatched().isa()
    );

    let groups = split_groups(&raw, layout).unwrap();
    let exp = &groups[0];
    let man = &groups[1];
    let enc_exp = huffman::compress(exp);

    let reps = env.reps;
    let t = time_n(reps, || {
        std::hint::black_box(split_groups(&raw, layout).unwrap());
    });
    println!("split_groups          : {:6.2} GB/s", gbps(n, t.min));

    let t = time_n(reps, || {
        std::hint::black_box(merge_groups(&groups, layout).unwrap());
    });
    println!("merge_groups          : {:6.2} GB/s", gbps(n, t.min));

    let t = time_n(reps, || {
        std::hint::black_box(byte_histogram(exp));
    });
    println!("byte_histogram        : {:6.2} GB/s", gbps(exp.len(), t.min));

    let t = time_n(reps, || {
        std::hint::black_box(zero_stats(man));
    });
    println!("zero_stats (random)   : {:6.2} GB/s", gbps(man.len(), t.min));

    let t = time_n(reps, || {
        std::hint::black_box(huffman::compress(exp));
    });
    println!("huffman encode (exp)  : {:6.2} GB/s", gbps(exp.len(), t.min));

    let t = time_n(reps, || {
        std::hint::black_box(huffman::compress(man));
    });
    println!("huffman encode (rand) : {:6.2} GB/s  (raw fallback path)", gbps(man.len(), t.min));

    let t = time_n(reps, || {
        std::hint::black_box(huffman::decompress(&enc_exp, exp.len()).unwrap());
    });
    println!("huffman decode (exp)  : {:6.2} GB/s", gbps(exp.len(), t.min));

    let z = lz::zstd_compress(exp, 3).unwrap();
    let t = time_n(reps, || {
        std::hint::black_box(lz::zstd_compress(exp, 3).unwrap());
    });
    println!("zstd-3 encode (exp)   : {:6.2} GB/s", gbps(exp.len(), t.min));
    let t = time_n(reps, || {
        std::hint::black_box(lz::zstd_decompress(&z, exp.len()).unwrap());
    });
    println!("zstd-3 decode (exp)   : {:6.2} GB/s", gbps(exp.len(), t.min));

    // end-to-end
    let comp = Compressor::new(CodecConfig::for_dtype(DType::BF16));
    let compressed = comp.compress(&raw).unwrap();
    let t = time_n(reps, || {
        std::hint::black_box(comp.compress(&raw).unwrap());
    });
    println!("E2E zipnn compress    : {:6.2} GB/s", gbps(n, t.min));
    let t = time_n(reps, || {
        std::hint::black_box(decompress_with(&compressed, 1).unwrap());
    });
    println!("E2E zipnn decompress  : {:6.2} GB/s", gbps(n, t.min));
}
