//! Figure 6: the clean model xlm-RoBERTa (FP32) with and without byte
//! grouping, including the per-fraction-byte breakdown.
//!
//! Paper: without grouping the fraction compresses poorly; with grouping
//! byte1 ≈ 95.6% (barely), byte2 ≈ 37.5%, byte3 ≈ 0% (all zeros).

use std::io::Write;
use zipnn::bench_support::{alloc_count, json_line, peak_rss_kb, time_n, BenchEnv, Table};
use zipnn::codec::{
    compress_with_report, CodecConfig, CodecProfile, ProfileSelector, ZnnWriter,
};
use zipnn::fp::{simd, split_groups, DType, GroupLayout};
use zipnn::huffman;
use zipnn::model::synthetic::{generate, mixed_precision_model, Category, SyntheticSpec};
use zipnn::model::tensor_spans;
use zipnn::util::Timer;

#[global_allocator]
static ALLOC: zipnn::bench_support::CountingAlloc = zipnn::bench_support::CountingAlloc;

fn main() {
    let env = BenchEnv::from_env();
    let m = generate(&SyntheticSpec::new(
        "xlm-roberta-analog",
        Category::CleanF32 { keep_bits: 10, frac_clean: 1.0 },
        env.model_bytes(),
        601,
    ));
    let raw = m.to_bytes();

    // With byte grouping (ZipNN):
    let allocs_before = alloc_count();
    let t = Timer::start();
    let (comp_bg, reps) =
        compress_with_report(CodecConfig::for_dtype(DType::F32), &raw).unwrap();
    let comp_secs = t.secs();
    let comp_allocs = alloc_count() - allocs_before;
    // Without byte grouping: exponent extracted, fraction kept interleaved.
    // Emulate by splitting exp group out and huffman-compressing the rest
    // as one stream (the paper's "no BG" configuration).
    let groups = split_groups(&raw, GroupLayout::for_dtype(DType::F32)).unwrap();
    let exp_comp = huffman::compress(&groups[0]);
    let mut fraction = Vec::with_capacity(groups[1].len() * 3);
    // re-interleave fraction bytes to model the un-grouped layout
    for i in 0..groups[1].len() {
        fraction.push(groups[1][i]);
        fraction.push(groups[2][i]);
        fraction.push(groups[3][i]);
    }
    let frac_comp_nobg = zipnn::lz::zstd_compress(&fraction, 3).unwrap();
    let frac_comp_nobg_h = huffman::compress(&fraction);

    let mut table = Table::new(&["stream", "no BG %", "with BG % (paper)"]);
    table.row(&[
        "exponent".into(),
        format!("{:.1}", exp_comp.len() as f64 / groups[0].len() as f64 * 100.0),
        format!("{:.1} (33.9)", reps[0].pct()),
    ]);
    table.row(&[
        "fraction b1 (high)".into(),
        "-".into(),
        format!("{:.1} (95.6)", reps[1].pct()),
    ]);
    table.row(&[
        "fraction b2".into(),
        "-".into(),
        format!("{:.1} (37.5)", reps[2].pct()),
    ]);
    table.row(&[
        "fraction b3 (low)".into(),
        "-".into(),
        format!("{:.1} (0.0)", reps[3].pct()),
    ]);
    let frac_bg_pct = (reps[1].comp + reps[2].comp + reps[3].comp) as f64
        / (reps[1].raw + reps[2].raw + reps[3].raw) as f64
        * 100.0;
    table.row(&[
        "fraction total".into(),
        format!(
            "{:.1} (zstd) / {:.1} (huff)",
            frac_comp_nobg.len() as f64 / fraction.len() as f64 * 100.0,
            frac_comp_nobg_h.len() as f64 / fraction.len() as f64 * 100.0
        ),
        format!("{frac_bg_pct:.1}"),
    ]);
    table.row(&[
        "TOTAL".into(),
        format!(
            "{:.1}",
            (exp_comp.len() + frac_comp_nobg.len()) as f64 / raw.len() as f64 * 100.0
        ),
        format!("{:.1} (41.8)", comp_bg.len() as f64 / raw.len() as f64 * 100.0),
    ]);
    println!("== Figure 6: clean FP32 model with/without Byte Grouping ==");
    table.print();
    let mb = raw.len() as f64 / (1024.0 * 1024.0);
    json_line(
        "fig6",
        &[
            ("raw_mb", mb),
            ("compressed_pct", comp_bg.len() as f64 / raw.len() as f64 * 100.0),
            ("throughput_mb_s", mb / comp_secs),
            ("allocs_per_mb", comp_allocs as f64 / mb),
            ("peak_rss_kb", peak_rss_kb().unwrap_or(0) as f64),
        ],
    );

    // Pooled pipelined encode (the ZnnWriter on the shared sticky pool,
    // double-buffered: batch N's frames serialize while batch N+1
    // compresses). 8 KiB chunks keep the batch at `threads * 128 KiB` —
    // at most 1 MiB even at 8 threads — so the 4 MiB CI payload always
    // spans >= 4 batches and actually exercises the pipeline on every
    // machine, not just one submit.
    let threads = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(2)
        .min(8);
    let cfg = CodecConfig::for_dtype(DType::F32)
        .with_chunk_size(8 * 1024)
        .with_threads(threads);
    let t = Timer::start();
    let mut w = ZnnWriter::new(Vec::with_capacity(raw.len()), cfg).unwrap();
    w.write_all(&raw).unwrap();
    let pooled = w.finish().unwrap();
    let pooled_secs = t.secs();
    println!(
        "pooled writer ({threads} threads): {:.1}% in {pooled_secs:.3}s",
        pooled.len() as f64 / raw.len() as f64 * 100.0
    );

    // Mixed-precision model (fp32 embedding/norms + bf16 attention + fp8
    // MLPs): per-tensor profiles vs the uniform writer stuck with the
    // dominant dtype's single profile. `mixed_precision_ratio` is the
    // profiled container's compressed % of raw (record-only baseline).
    let mm = mixed_precision_model("mixed-precision-analog", env.model_bytes(), 602);
    let mraw = mm.to_bytes();
    let spans = tensor_spans(&mm);
    let mmb = mraw.len() as f64 / (1024.0 * 1024.0);
    let mcfg = CodecConfig::for_dtype(mm.dominant_dtype()).with_chunk_size(32 * 1024);
    let mut w = ZnnWriter::new(Vec::with_capacity(mraw.len()), mcfg.clone()).unwrap();
    w.write_all(&mraw).unwrap();
    let uniform = w.finish().unwrap();
    let sel = ProfileSelector::auto_with_data(
        &spans,
        CodecProfile::for_dtype(mm.dominant_dtype()),
        &mraw,
    )
    .unwrap();
    let t = Timer::start();
    let mut w = ZnnWriter::new(Vec::with_capacity(mraw.len()), mcfg)
        .unwrap()
        .with_profiles(sel)
        .unwrap();
    w.write_all(&mraw).unwrap();
    let profiled = w.finish().unwrap();
    let profiled_secs = t.secs();
    let uniform_pct = uniform.len() as f64 / mraw.len() as f64 * 100.0;
    let mixed_ratio = profiled.len() as f64 / mraw.len() as f64 * 100.0;
    println!(
        "mixed-precision model ({mmb:.0} MiB): uniform {uniform_pct:.1}% -> per-tensor {mixed_ratio:.1}%"
    );

    json_line(
        "fig6_compress",
        &[
            ("pooled_comp_mb_s", mb / pooled_secs),
            ("threads", threads as f64),
            ("mixed_precision_ratio", mixed_ratio),
            ("mixed_uniform_ratio", uniform_pct),
            ("mixed_profiled_mb_s", mmb / profiled_secs),
        ],
    );

    // Byte-group transpose kernels: the runtime-dispatched SIMD layer
    // under `split_groups`/`merge_groups`, measured in isolation on the
    // k = 4 position-ordered transpose (the F32 fast path). The scalar
    // numbers put the dispatched ISA's speedup in context; both are
    // record-only in the regression gate (per-machine, re-baseline after
    // hardware moves).
    let kn = raw.len() / 4 * 4;
    let kdata = &raw[..kn];
    let kmb = kn as f64 / (1024.0 * 1024.0);
    let q = kn / 4;
    let mut d: Vec<Vec<u8>> = (0..4).map(|_| vec![0u8; q]).collect();
    let mut merged = vec![0u8; kn];
    let mut bench_pair = |k: &'static simd::Kernels| {
        let ts = time_n(env.reps, || {
            let [d0, d1, d2, d3] = &mut d[..] else { unreachable!() };
            k.split4(kdata, d0, d1, d2, d3);
            std::hint::black_box(&mut d);
        });
        let tm = time_n(env.reps, || {
            k.merge4(&d[0], &d[1], &d[2], &d[3], &mut merged);
            std::hint::black_box(&mut merged);
        });
        (kmb / ts.min, kmb / tm.min)
    };
    let (split_mb_s, merge_mb_s) = bench_pair(simd::dispatched());
    let (scalar_split, scalar_merge) = bench_pair(simd::scalar());
    assert_eq!(merged, kdata, "kernel roundtrip");
    println!(
        "k=4 transpose kernels ({}): split {split_mb_s:.0} MB/s, merge {merge_mb_s:.0} MB/s \
         (scalar: {scalar_split:.0} / {scalar_merge:.0})",
        simd::dispatched().isa()
    );
    json_line(
        "fig6_kernel",
        &[
            ("split_mb_s", split_mb_s),
            ("merge_mb_s", merge_mb_s),
            ("scalar_split_mb_s", scalar_split),
            ("scalar_merge_mb_s", scalar_merge),
        ],
    );
}
