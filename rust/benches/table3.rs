//! Table 3: compression method speed comparison on three representative
//! models — vanilla Zstd vs EE+Zstd (exponent extraction + zstd) vs ZipNN
//! (EE + byte grouping + Huffman).
//!
//! Paper (M1 Max, 1 core, 1 GB buffers):
//!   Llama-3.1 BF16:  zstd 77.7% 0.71/1.02 GB/s | EE+zstd 68.8% 0.51/1.21 | ZipNN 66.4% 1.15/1.65
//!   Olmo-1b  FP32:   zstd 92.3% 0.97/1.02 | EE+zstd 84.4% 0.82/1.97 | ZipNN 83.2% 1.64/2.48
//!   xlm-R    FP32cl: zstd 57.4% 0.18/0.77 | EE+zstd 46.7% 0.42/0.89 | ZipNN 42.9% 0.83/1.41
//!
//! Absolute GB/s differ on this testbed; the *ordering* (ZipNN fastest and
//! smallest) is the reproduced claim.

use zipnn::bench_support::{time_n, BenchEnv, Table};
use zipnn::codec::{decompress, CodecConfig, Compressor, MethodPolicy};
use zipnn::fp::GroupLayout;
use zipnn::model::synthetic::{generate, Category, SyntheticSpec};

fn main() {
    let env = BenchEnv::from_env();
    let models = [
        ("Llama-3.1 BF16", Category::RegularBF16, 301u64),
        ("Olmo FP32", Category::RegularF32, 302),
        ("xlm-RoBERTa FP32 clean",
         Category::CleanF32 { keep_bits: 10, frac_clean: 1.0 }, 303),
    ];
    let mut table = Table::new(&[
        "model", "method", "comp size %", "comp GB/s", "decomp GB/s",
    ]);
    for (name, cat, seed) in models {
        let m = generate(&SyntheticSpec::new(name, cat, env.model_bytes(), seed));
        let raw = m.to_bytes();
        let dtype = m.dominant_dtype();
        let configs: [(&str, CodecConfig); 3] = [
            ("Zstd", CodecConfig::vanilla_zstd()),
            ("EE+Zstd", {
                let mut c = CodecConfig::for_dtype(dtype);
                c.policy = MethodPolicy::Zstd;
                c
            }),
            ("ZipNN", CodecConfig::for_dtype(dtype)),
        ];
        for (method, cfg) in configs {
            let comp = Compressor::new(cfg.clone());
            let compressed = comp.compress(&raw).unwrap();
            let c_stats = time_n(env.reps, || {
                std::hint::black_box(comp.compress(&raw).unwrap());
            });
            let d_stats = time_n(env.reps, || {
                std::hint::black_box(decompress(&compressed).unwrap());
            });
            table.row(&[
                name.to_string(),
                method.to_string(),
                format!("{:.1}", compressed.len() as f64 / raw.len() as f64 * 100.0),
                format!("{:.2}", raw.len() as f64 / c_stats.mean / 1e9),
                format!("{:.2}", raw.len() as f64 / d_stats.mean / 1e9),
            ]);
        }
        // sanity: every method must roundtrip
        let _ = GroupLayout::flat();
    }
    println!(
        "== Table 3: method speed comparison ({} MB buffers, {} reps) ==",
        env.model_mb, env.reps
    );
    table.print();
}
