//! Figure 2: histogram of exponent values for four models — highly skewed,
//! strikingly similar across models; ~40 distinct values (50 for the image
//! model); top-12 cover ≈99.9% (17 for the image model).

use zipnn::bench_support::BenchEnv;
use zipnn::fp::stats::{exponent_histogram, summarize_exponents};
use zipnn::model::synthetic::{generate, Category, SyntheticSpec};

fn main() {
    let env = BenchEnv::from_env();
    // Image models (ResNet) have a wider σ spread across layers -> more
    // distinct exponents; mimic with a different category/seed mix.
    let models = [
        ("Qwen2-VL-analog (BF16)", Category::RegularBF16, 401u64),
        ("Llama-3.1-analog (BF16)", Category::RegularBF16, 402),
        ("granite-analog (BF16)", Category::RegularBF16, 403),
        ("resnet50-analog (FP32)", Category::RegularF32, 404),
    ];
    println!("== Figure 2: exponent-value histograms ==");
    for (name, cat, seed) in models {
        let m = generate(&SyntheticSpec::new(name, cat, env.model_bytes(), seed));
        let hist = exponent_histogram(&m.to_bytes(), m.dominant_dtype());
        let s = summarize_exponents(&hist);
        println!(
            "\n{name}: {} distinct exponents, top-12 cover {:.2}%, entropy {:.2} bits",
            s.distinct,
            s.top12_coverage * 100.0,
            s.entropy_bits
        );
        let total: u64 = hist.iter().sum();
        // print the central window like the paper's figure
        let lo = s.top.iter().map(|&(v, _)| v).min().unwrap_or(100);
        let hi = s.top.iter().map(|&(v, _)| v).max().unwrap_or(132);
        for e in lo.saturating_sub(2)..=hi.saturating_add(2).min(255) {
            let frac = hist[e as usize] as f64 / total as f64;
            if frac > 0.0005 {
                println!(
                    "  exp {e:>3}: {:>6.2}% {}",
                    frac * 100.0,
                    "#".repeat((frac * 150.0) as usize)
                );
            }
        }
    }
    println!("\n(paper: ~40 values for LMs, ~50 for the image model; top-12 ≈ 99.9%)");
}
