//! Figure 10: end-to-end download/upload times of three models, compressed
//! vs not, across the measured network regimes (§5.3).
//!
//! Transfer seconds are simulated from the paper's bandwidth regimes
//! (first/cached download, upload) with their observed variance; codec
//! seconds are *measured* on this machine. Error bars come from repeated
//! simulated transfers (the paper: variance was almost entirely network).

use std::io::Read;
use zipnn::bench_support::{alloc_count, json_line, peak_rss_kb, time_n, BenchEnv, Table};
use zipnn::codec::{CodecConfig, Compressor, ZnnReader};
use zipnn::hub::{HubClient, HubServer, NetProfile, NetSim};
use zipnn::model::synthetic::{generate, Category, SyntheticSpec};
use zipnn::util::{human_bytes, Timer, Xoshiro256};

#[global_allocator]
static ALLOC: zipnn::bench_support::CountingAlloc = zipnn::bench_support::CountingAlloc;

fn main() {
    let env = BenchEnv::from_env();

    // Huffman decode in isolation: the four-lane two-level multi-symbol
    // LUT decoder on a BF16-exponent-shaped stream — the hottest loop of
    // every compressed download. Record-only baseline in the regression
    // gate (per-machine; re-baseline after hardware moves).
    let mut rng = Xoshiro256::seed_from_u64(710);
    let mut exp = vec![0u8; 8 * 1024 * 1024];
    for b in &mut exp {
        *b = 120 + (rng.uniform().powi(2) * 12.0) as u8;
    }
    let enc = zipnn::huffman::compress(&exp);
    let mut dec = vec![0u8; exp.len()];
    let t = time_n(env.reps, || {
        zipnn::huffman::decompress_into(&enc, &mut dec).unwrap();
    });
    assert_eq!(dec, exp, "huffman decode roundtrip");
    let huff_mb = exp.len() as f64 / (1024.0 * 1024.0);
    println!(
        "huffman decode (4-lane two-level LUT): {:.0} MB/s on skewed exponents",
        huff_mb / t.min
    );
    json_line("fig10", &[("huff_decode_mb_s", huff_mb / t.min)]);

    let models = [
        ("Llama-3.1 BF16", Category::RegularBF16, 701u64),
        ("Olmo FP32", Category::RegularF32, 702),
        (
            "xlm-RoBERTa clean",
            Category::CleanF32 { keep_bits: 10, frac_clean: 1.0 },
            703,
        ),
    ];
    let server = HubServer::start().unwrap();
    let mut client = HubClient::connect(server.addr()).unwrap();

    let mut table = Table::new(&[
        "model", "regime", "raw mean±std (s)", "zipnn mean±std (s)", "saving",
    ]);
    for (name, cat, seed) in models {
        let m = generate(&SyntheticSpec::new(name, cat, env.model_bytes(), seed));
        let raw = m.to_bytes();
        let dtype = m.dominant_dtype();

        // uploads (5 sims like the paper's 1st-timer runs)
        let mut sim = NetSim::new(NetProfile::UPLOAD, seed);
        let rep_raw = client.upload(name, &raw, None, &mut sim).unwrap();
        let allocs_before = alloc_count();
        let rep_c = client
            .upload(name, &raw, Some(CodecConfig::for_dtype(dtype)), &mut sim)
            .unwrap();
        let upload_allocs = alloc_count() - allocs_before;
        let mb = raw.len() as f64 / (1024.0 * 1024.0);
        json_line(
            "fig10",
            &[
                ("model_seed", seed as f64),
                ("raw_mb", mb),
                ("wire_pct", rep_c.pct()),
                ("codec_mb_s", mb / rep_c.codec_secs.max(1e-9)),
                ("allocs_per_mb", upload_allocs as f64 / mb),
                ("peak_rss_kb", peak_rss_kb().unwrap_or(0) as f64),
            ],
        );
        let stats = |wire: usize, codec: f64, profile: NetProfile, reps: usize| {
            let mut s = NetSim::new(profile, seed * 31);
            let times: Vec<f64> =
                (0..reps).map(|_| codec + s.transfer_secs(wire as u64)).collect();
            let mean = times.iter().sum::<f64>() / reps as f64;
            let var =
                times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / reps as f64;
            (mean, var.sqrt())
        };
        let (um_r, us_r) = stats(rep_raw.wire_len, 0.0, NetProfile::UPLOAD, 5);
        let (um_c, us_c) = stats(rep_c.wire_len, rep_c.codec_secs, NetProfile::UPLOAD, 5);
        table.row(&[
            format!("{name} ({})", human_bytes(raw.len() as u64)),
            "upload".into(),
            format!("{um_r:.2}±{us_r:.2}"),
            format!("{um_c:.2}±{us_c:.2}"),
            format!("{:+.0}%", (1.0 - um_c / um_r) * 100.0),
        ]);

        // decompress throughput (the CI regression gate's decode metric):
        // one timed compressed download, decoded as frames arrive
        let mut dsim = NetSim::new(NetProfile::CLOUD_CACHED, seed);
        let (_, drep) = client.download(name, true, &mut dsim).unwrap();
        json_line(
            "fig10_download",
            &[
                ("model_seed", seed as f64),
                ("raw_mb", mb),
                ("decomp_mb_s", mb / drep.codec_secs.max(1e-9)),
                ("wire_pct", drep.pct()),
            ],
        );

        // mmap-vs-read decode (the zero-copy fast path's gate metric):
        // compress the model to a file once, then decode it through the
        // buffered io::Read path and through the memory-mapped zero-copy
        // path on a warm page cache. Both run on the persistent decode
        // pool; the first pass of each warms cache, pool, and arenas.
        let decode_threads = 2usize;
        let comp_path = std::env::temp_dir()
            .join(format!("zipnn-fig10-{}-{seed}.znn", std::process::id()));
        std::fs::write(
            &comp_path,
            Compressor::new(CodecConfig::for_dtype(dtype)).compress(&raw).unwrap(),
        )
        .unwrap();
        let time_read_path = |path: &std::path::Path| {
            let t = Timer::start();
            let f = std::fs::File::open(path).unwrap();
            let mut r = ZnnReader::new(std::io::BufReader::new(f))
                .unwrap()
                .with_threads(decode_threads);
            let mut out = Vec::new();
            r.read_to_end(&mut out).unwrap();
            assert_eq!(out.len(), raw.len());
            t.secs()
        };
        let time_mmap_path = |path: &std::path::Path| {
            let t = Timer::start();
            let mut r = ZnnReader::open(path).unwrap().with_threads(decode_threads);
            let mut out = Vec::new();
            r.read_to_end(&mut out).unwrap();
            assert_eq!(out.len(), raw.len());
            t.secs()
        };
        let _ = time_mmap_path(&comp_path);
        let _ = time_read_path(&comp_path);
        let read_mb_s = mb / time_read_path(&comp_path).max(1e-9);
        let mmap_mb_s = mb / time_mmap_path(&comp_path).max(1e-9);
        std::fs::remove_file(&comp_path).unwrap();
        json_line(
            "fig10_download",
            &[
                ("model_seed", seed as f64),
                ("read_decomp_mb_s", read_mb_s),
                ("mmap_decomp_mb_s", mmap_mb_s),
            ],
        );
        println!(
            "{name}: warm-cache decode {mmap_mb_s:.0} MB/s mmap vs {read_mb_s:.0} MB/s read \
             ({decode_threads} threads, persistent pool)"
        );

        // Tensor range-GET (the ROADMAP "Range-GET of individual
        // tensors" metric): upload the model with a tensor index, then
        // fetch its largest tensor — only the covering frames travel the
        // wire, decoded client-side as they arrive.
        let spans = zipnn::model::tensor_spans(&m);
        let biggest = spans
            .iter()
            .max_by_key(|t| t.len)
            .expect("models have tensors")
            .clone();
        let idx_name = format!("idx-{seed}");
        client
            .upload_indexed(&idx_name, &raw, spans, CodecConfig::for_dtype(dtype), &mut dsim)
            .unwrap();
        let (stored_total, _, _) = client.stat(&format!("{idx_name}.znn")).unwrap();
        let _ = client.get_tensor(&idx_name, &biggest.name).unwrap(); // warm pools
        let t = Timer::start();
        let (tensor_bytes, wire) = client.get_tensor(&idx_name, &biggest.name).unwrap();
        let range_secs = t.secs();
        assert_eq!(tensor_bytes.len() as u64, biggest.len);
        let tensor_mb = biggest.len as f64 / (1024.0 * 1024.0);
        json_line(
            "fig10_range",
            &[
                ("model_seed", seed as f64),
                ("tensor_mb", tensor_mb),
                ("range_get_mb_s", tensor_mb / range_secs.max(1e-9)),
                ("wire_frac", wire as f64 / stored_total as f64),
            ],
        );
        println!(
            "{name}: tensor range-GET {:.0} MB/s ({} tensor, {:.0}% of the container on the wire)",
            tensor_mb / range_secs.max(1e-9),
            human_bytes(biggest.len),
            wire as f64 / stored_total as f64 * 100.0
        );

        // downloads across regimes (10 cached / 5 first, like the paper)
        for (profile, reps) in [
            (NetProfile::CLOUD_FIRST, 5),
            (NetProfile::CLOUD_CACHED, 10),
            (NetProfile::HOME_FIRST, 5),
            (NetProfile::HOME_CACHED, 10),
        ] {
            let mut sim = NetSim::new(profile, seed);
            let (_, drep_r) = client.download(name, false, &mut sim).unwrap();
            let (_, drep_c) = client.download(name, true, &mut sim).unwrap();
            let (dm_r, ds_r) = stats(drep_r.wire_len, 0.0, profile, reps);
            let (dm_c, ds_c) = stats(drep_c.wire_len, drep_c.codec_secs, profile, reps);
            table.row(&[
                format!("{name} ({})", human_bytes(raw.len() as u64)),
                profile.name.into(),
                format!("{dm_r:.2}±{ds_r:.2}"),
                format!("{dm_c:.2}±{ds_c:.2}"),
                format!("{:+.0}%", (1.0 - dm_c / dm_r) * 100.0),
            ]);
        }
    }
    println!("== Figure 10: end-to-end upload/download times ==");
    table.print();
    println!("(paper shape: biggest savings on slow links and compressible models;\n upload savings < download savings at equal bandwidth because compression\n is slower than decompression)");

    // Resilient-transfer goodput (the PR 8 fault-injection metric): one
    // compressed download runs clean, one runs through a scripted fault
    // proxy (three mid-stream connection drops plus one flipped byte).
    // Goodput counts raw payload MB per wall second including every
    // reconnect, resume, and frame refetch. The wire accounting proves
    // the faulted run resumed from its verified prefix: a
    // restart-from-zero client under the same schedule moves at least
    // 1.9x the container. Record-only baseline (wall time includes real
    // reconnect backoff sleeps, which dwarf codec time on small models).
    {
        use zipnn::codec::ZnnWriter;
        use zipnn::hub::{FaultKind, FaultProxy, ScriptedFault};
        let m = generate(&SyntheticSpec::new(
            "resil",
            Category::RegularBF16,
            env.model_bytes(),
            711,
        ));
        let raw = m.to_bytes();
        let cfg = CodecConfig::for_dtype(m.dominant_dtype()).with_chunk_size(8 * 1024);
        let mut w = ZnnWriter::new(Vec::new(), cfg).unwrap().with_frame_checksums().unwrap();
        std::io::Write::write_all(&mut w, &raw).unwrap();
        let container = w.finish().unwrap();
        let total = container.len() as u64;
        let mut sim = NetSim::new(NetProfile::UPLOAD, 711);
        client.upload("resil.znn", &container, None, &mut sim).unwrap();

        let t = Timer::start();
        let (clean, _) = client.download("resil", true, &mut sim).unwrap();
        let clean_secs = t.secs();
        assert_eq!(clean, raw, "clean resilience download");

        let proxy = FaultProxy::start_scripted(
            server.addr(),
            vec![
                ScriptedFault { after_bytes: total * 2 / 5, kind: FaultKind::Drop },
                ScriptedFault { after_bytes: total * 3 / 10, kind: FaultKind::Drop },
                ScriptedFault { after_bytes: total / 5, kind: FaultKind::Drop },
                ScriptedFault { after_bytes: total / 20, kind: FaultKind::Flip },
            ],
        )
        .unwrap();
        // connect_direct: the scripted proxy IS the fault schedule; an
        // env-armed second proxy would wreck the wire accounting.
        let mut faulted = HubClient::connect_direct(proxy.addr()).unwrap();
        let t = Timer::start();
        let (got, rep) = faulted.download("resil", true, &mut sim).unwrap();
        let fault_secs = t.secs();
        assert_eq!(got, raw, "faulted resilience download");
        // Frame-granular resume slack only discriminates once the
        // container spans many frames (ZIPNN_BENCH_MB can shrink it).
        if total > 1 << 20 {
            assert!(
                rep.wire_total < total + total * 4 / 5,
                "resume moved {} of {total} wire bytes — restart-from-zero territory",
                rep.wire_total
            );
        }
        proxy.shutdown();

        let mb = raw.len() as f64 / (1024.0 * 1024.0);
        let goodput = mb / fault_secs.max(1e-9);
        json_line(
            "fig10_resilience",
            &[
                ("goodput_mb_s", goodput),
                ("clean_goodput_mb_s", mb / clean_secs.max(1e-9)),
                ("wire_overhead_pct", (rep.wire_total - total) as f64 / total as f64 * 100.0),
            ],
        );
        println!(
            "resilience: {goodput:.0} MB/s goodput under 3 drops + 1 flip \
             ({:.0} MB/s clean, {:.0}% extra wire vs a >=90% restart-from-zero floor)",
            mb / clean_secs.max(1e-9),
            (rep.wire_total - total) as f64 / total as f64 * 100.0
        );
    }
    server.shutdown();

    // Fleet multi-peer download (the PR 9 sharded-hub metric): a 3-hub
    // R=2 fleet serves one indexed container as concurrent stripes from
    // both replicas; a single peer serves the same container whole on
    // the same run. Aggregate simulated time for the striped path is
    // the slowest peer's (peers transfer in parallel), so the striped
    // throughput must beat the single-peer one. Record-only baseline
    // (per-machine codec time feeds the goodput denominator).
    {
        use zipnn::hub::{Fleet, FleetClient, FleetConfig, RetryPolicy};
        let fleet = Fleet::start(3).unwrap();
        let cfg = FleetConfig {
            replication: 2,
            peers: 3,
            vnodes: 64,
            retry: RetryPolicy::default(),
        };
        let mut fc = FleetClient::connect_direct(&fleet.members(), cfg);
        // Floor the model at 2 MiB: striping needs several frames no
        // matter how small ZIPNN_BENCH_MB squeezes the other figures.
        let m = generate(&SyntheticSpec::new(
            "fleet-bench",
            Category::RegularBF16,
            env.model_bytes().max(2 << 20),
            712,
        ));
        let raw = m.to_bytes();
        let spans = zipnn::model::tensor_spans(&m);
        // Small chunks => many container frames => stripe boundaries to
        // split at, even when ZIPNN_BENCH_MB shrinks the model.
        let ccfg = CodecConfig::for_dtype(m.dominant_dtype()).with_chunk_size(16 * 1024);
        let mut sim = NetSim::new(NetProfile::UPLOAD, 712);
        fc.upload_indexed("fleet-bench", &raw, spans, ccfg, &mut sim).unwrap();

        let mut dsim = NetSim::new(NetProfile::CLOUD_CACHED, 713);
        let t = Timer::start();
        let (got, frep) = fc.download("fleet-bench", true, &mut dsim).unwrap();
        let wall_secs = t.secs();
        assert_eq!(got, raw, "fleet bench download");
        assert!(frep.stripes >= 2, "bench container must stripe");
        let wire_mb = frep.report.wire_len as f64 / (1024.0 * 1024.0);
        let multi_mb_s = wire_mb / frep.report.transfer_secs.max(1e-9);
        let single_mb_s = wire_mb / dsim.transfer_secs(frep.report.wire_len as u64).max(1e-9);
        assert!(
            multi_mb_s > single_mb_s,
            "striping across {} peers must beat one peer ({multi_mb_s:.0} vs {single_mb_s:.0} MB/s)",
            frep.peers
        );
        json_line(
            "fig10_fleet",
            &[
                ("multi_peer_mb_s", multi_mb_s),
                ("single_peer_mb_s", single_mb_s),
                ("stripes", frep.stripes as f64),
                ("peers", frep.peers as f64),
                ("wall_goodput_mb_s", raw.len() as f64 / (1024.0 * 1024.0) / wall_secs.max(1e-9)),
            ],
        );
        println!(
            "fleet: {multi_mb_s:.0} MB/s striped across {} peers vs {single_mb_s:.0} MB/s \
             single-peer ({} stripes, cloud-cached regime)",
            frep.peers, frep.stripes
        );
        fleet.shutdown();
    }
}
