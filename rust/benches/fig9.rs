//! Figure 9: checkpoint compression with periodic bases, period k ∈ {1
//! (consecutive), 5, 10}, vs standalone, for three training runs:
//! (a) ResNet-analog FP32, (b) Amber-analog BF16 LM, (c) OLMo-analog FP32.
//!
//! (Full-base space is excluded, as in the paper.)

use zipnn::bench_support::Table;
use zipnn::delta::{BaseStrategy, CheckpointStore};
use zipnn::fp::DType;
use zipnn::runtime::Runtime;
use zipnn::train::{CnnTrainer, LmTrainer};

fn run_store(
    dtype: DType,
    strategy: BaseStrategy,
    ckpts: &[Vec<u8>],
) -> (f64, Vec<f64>) {
    let mut store = CheckpointStore::new(dtype, strategy);
    for c in ckpts {
        store.push(c).unwrap();
    }
    let per: Vec<f64> = store.entries().iter().map(|e| e.pct()).collect();
    (store.mean_delta_pct(), per)
}

fn report(name: &str, dtype: DType, ckpts: &[Vec<u8>]) {
    let (_, standalone) = run_store(dtype, BaseStrategy::Standalone, ckpts);
    let (c1, per1) = run_store(dtype, BaseStrategy::Chain(ckpts.len()), ckpts);
    let (c5, _) = run_store(dtype, BaseStrategy::Chain(5), ckpts);
    let (f5, _) = run_store(dtype, BaseStrategy::FixedBase(5), ckpts);
    let (c10, _) = run_store(dtype, BaseStrategy::Chain(10), ckpts);
    let (f10, _) = run_store(dtype, BaseStrategy::FixedBase(10), ckpts);
    let mean_standalone = standalone.iter().sum::<f64>() / standalone.len() as f64;
    let mut table = Table::new(&["strategy", "mean delta %"]);
    table.row(&["standalone".into(), format!("{mean_standalone:.1}")]);
    table.row(&["consecutive deltas (k=1)".into(), format!("{c1:.1}")]);
    table.row(&["chain, base every 5".into(), format!("{c5:.1}")]);
    table.row(&["fixed base every 5".into(), format!("{f5:.1}")]);
    table.row(&["chain, base every 10".into(), format!("{c10:.1}")]);
    table.row(&["fixed base every 10".into(), format!("{f10:.1}")]);
    println!("\n-- {name} --");
    table.print();
    println!(
        "  consecutive-delta trend (first->last): {}",
        per1.iter()
            .skip(1)
            .map(|p| format!("{p:.0}"))
            .collect::<Vec<_>>()
            .join(" ")
    );
}

fn main() {
    let n_ckpts: usize = std::env::var("ZIPNN_FIG9_CKPTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(12);
    let spe: usize = std::env::var("ZIPNN_FIG9_SPE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(6);
    let rt = match Runtime::open("artifacts") {
        Ok(rt) => rt,
        Err(e) => {
            eprintln!("fig9 requires artifacts: {e}");
            return;
        }
    };
    println!("== Figure 9: periodic-base checkpoint compression ==");
    println!("({n_ckpts} checkpoints, {spe} steps between checkpoints)");

    // (a) ResNet-analog FP32 via SGD
    let mut cnn = CnnTrainer::new(&rt, "cnn_tiny", 91).unwrap();
    let mut ckpts = Vec::new();
    for e in 0..n_ckpts {
        let lr = match e * 3 / n_ckpts {
            0 => 0.05,
            1 => 0.01,
            _ => 0.002,
        };
        for _ in 0..spe {
            cnn.step(lr).unwrap();
        }
        ckpts.push(cnn.export_model().unwrap().to_bytes());
    }
    report("(a) ResNet-analog (FP32)", DType::F32, &ckpts);

    // (b) Amber-analog BF16 LM via Adam
    let mut lm = LmTrainer::new(&rt, "lm_tiny", 92).unwrap();
    let mut ckpts = Vec::new();
    for _ in 0..n_ckpts {
        for _ in 0..spe {
            lm.step(1e-3).unwrap();
        }
        ckpts.push(lm.export_model().unwrap().to_bytes());
    }
    report("(b) Amber-analog (BF16)", DType::BF16, &ckpts);

    // (c) OLMo-analog: same LM trajectory stored in FP32 (fp32 bit
    // patterns of the bf16 values would be trivially compressible, so use
    // the CNN's fp32 run at lower LR as the fp32-LM stand-in).
    let mut cnn2 = CnnTrainer::new(&rt, "cnn_tiny", 93).unwrap();
    let mut ckpts = Vec::new();
    for _ in 0..n_ckpts {
        for _ in 0..spe {
            cnn2.step(0.005).unwrap();
        }
        ckpts.push(cnn2.export_model().unwrap().to_bytes());
    }
    report("(c) OLMo-analog (FP32, slow LR)", DType::F32, &ckpts);

    println!("\n(paper shape: deltas ≪ standalone; fixed-base at distance k worse than\n consecutive chain but still far better than standalone)");
}
