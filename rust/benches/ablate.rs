//! Ablations and side-claims from the paper's text:
//!   §3.1 shuffle test — shuffling parameters barely changes the exponent
//!        stream's compression (repetitions found by LZ are "random");
//!   §3.1 LZ-only — LZ4/Snappy-class compression saves ≈ 0% on tensors;
//!   §6.1 quantized models — GPTQ/AWQ-like still compress to 85–91%,
//!        GGUF-like do not compress;
//!   §3.2 skip heuristic — probe-and-skip costs ≈ nothing in ratio.

use zipnn::bench_support::{BenchEnv, Table};
use zipnn::codec::{CodecConfig, Compressor};
use zipnn::fp::{split_groups, GroupLayout};
use zipnn::lz;
use zipnn::model::synthetic::{generate, Category, SyntheticSpec};
use zipnn::util::Xoshiro256;

fn main() {
    let env = BenchEnv::from_env();

    // --- shuffle test ---
    let m = generate(&SyntheticSpec::new(
        "llama-analog",
        Category::RegularBF16,
        env.model_bytes(),
        801,
    ));
    let raw = m.to_bytes();
    let layout = GroupLayout::for_dtype(m.dominant_dtype());
    let exp = split_groups(&raw, layout).unwrap().remove(0);
    let mut shuffled = exp.clone();
    Xoshiro256::seed_from_u64(5).shuffle(&mut shuffled);
    let z_orig = lz::zstd_compress(&exp, 3).unwrap();
    let z_shuf = lz::zstd_compress(&shuffled, 3).unwrap();
    println!("== §3.1 shuffle test (zstd on the exponent stream) ==");
    println!(
        "  original: {:.2}%   shuffled: {:.2}%   |diff| = {:.3}pp (paper: ≤ ~0.05)",
        z_orig.len() as f64 / exp.len() as f64 * 100.0,
        z_shuf.len() as f64 / shuffled.len() as f64 * 100.0,
        (z_orig.len() as f64 - z_shuf.len() as f64).abs() / exp.len() as f64 * 100.0
    );

    // --- LZ-only on tensors ---
    let l = lz::lz77::compress(&raw[..raw.len().min(8 << 20)]);
    println!("\n== §3.1 pure-LZ on model bytes ==");
    println!(
        "  lz77 (lz4-class): {:.1}% (paper: no gains at all)",
        l.len() as f64 / raw.len().min(8 << 20) as f64 * 100.0
    );

    // --- quantized models ---
    println!("\n== §6.1 quantized models ==");
    let mut table = Table::new(&["analog", "compressed %", "paper"]);
    for (name, cat, paper) in [
        ("GPTQ/AWQ-like int8", Category::QuantizedSkewed, "85-91%"),
        ("GGUF-like int8", Category::QuantizedUniform, "~100%"),
    ] {
        let q = generate(&SyntheticSpec::new(name, cat, env.model_bytes() / 2, 802));
        let qraw = q.to_bytes();
        let c = Compressor::new(CodecConfig::for_dtype(q.dominant_dtype()))
            .compress(&qraw)
            .unwrap();
        table.row(&[
            name.to_string(),
            format!("{:.1}", c.len() as f64 / qraw.len() as f64 * 100.0),
            paper.to_string(),
        ]);
    }
    table.print();

    // --- skip heuristic cost ---
    println!("\n== §3.2 probe-and-skip ablation ==");
    let mut cfg_noskip = CodecConfig::for_dtype(m.dominant_dtype());
    cfg_noskip.skip_window = 0;
    let with_skip = Compressor::new(CodecConfig::for_dtype(m.dominant_dtype()))
        .compress(&raw)
        .unwrap();
    let no_skip = Compressor::new(cfg_noskip).compress(&raw).unwrap();
    println!(
        "  skip_window=8: {:.2}%   skip_window=0: {:.2}%   (ratio cost of skipping ≈ {:+.3}pp)",
        with_skip.len() as f64 / raw.len() as f64 * 100.0,
        no_skip.len() as f64 / raw.len() as f64 * 100.0,
        (with_skip.len() as f64 - no_skip.len() as f64) / raw.len() as f64 * 100.0
    );

    // --- chunk-size ablation (the §5.1 design choice) ---
    println!("\n== §5.1 chunk-size ablation ==");
    let mut table = Table::new(&["chunk size", "compressed %"]);
    for ks in [64usize, 128, 256, 512, 1024] {
        let cfg = CodecConfig::for_dtype(m.dominant_dtype()).with_chunk_size(ks * 1024);
        let c = Compressor::new(cfg).compress(&raw).unwrap();
        table.row(&[
            format!("{ks} KiB"),
            format!("{:.2}", c.len() as f64 / raw.len() as f64 * 100.0),
        ]);
    }
    table.print();
    println!("(larger chunks amortize Huffman tables; 256 KiB is the paper's default)");
}
