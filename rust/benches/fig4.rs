//! Figure 4: breakdown of the contribution of Exponent Extraction (EE) and
//! Huffman-only encoding to compression ratio, on three BF16 models.
//!
//! Four bars per model: Zstd / Huffman (no EE) / EE+Zstd / EE+Huffman
//! (=ZipNN). Paper: Huffman without EE only helps speed; with EE it beats
//! Zstd on ratio too.

use zipnn::bench_support::{BenchEnv, Table};
use zipnn::codec::{CodecConfig, Compressor, MethodPolicy};
use zipnn::fp::GroupLayout;
use zipnn::model::synthetic::{generate, Category, SyntheticSpec};

fn main() {
    let env = BenchEnv::from_env();
    let models = [
        ("Llama-3.1-analog", 501u64),
        ("granite-analog", 502),
        ("OLMo-analog", 503),
    ];
    let mut table = Table::new(&["model", "Zstd", "Huffman", "EE+Zstd", "EE+Huffman (ZipNN)"]);
    for (name, seed) in models {
        let m = generate(&SyntheticSpec::new(
            name,
            Category::RegularBF16,
            env.model_bytes(),
            seed,
        ));
        let raw = m.to_bytes();
        let dtype = m.dominant_dtype();
        let pct = |cfg: CodecConfig| {
            let c = Compressor::new(cfg).compress(&raw).unwrap();
            c.len() as f64 / raw.len() as f64 * 100.0
        };
        let zstd = pct(CodecConfig::vanilla_zstd());
        let huff_flat = {
            let mut c = CodecConfig::vanilla_zstd();
            c.policy = MethodPolicy::Huffman;
            c.layout = GroupLayout::flat();
            pct(c)
        };
        let ee_zstd = {
            let mut c = CodecConfig::for_dtype(dtype);
            c.policy = MethodPolicy::Zstd;
            pct(c)
        };
        let zipnn = {
            let mut c = CodecConfig::for_dtype(dtype);
            c.policy = MethodPolicy::Huffman;
            pct(c)
        };
        table.row(&[
            name.to_string(),
            format!("{zstd:.1}"),
            format!("{huff_flat:.1}"),
            format!("{ee_zstd:.1}"),
            format!("{zipnn:.1}"),
        ]);
    }
    println!("== Figure 4: EE + Huffman contribution breakdown (compressed size %) ==");
    table.print();
    println!("(paper shape: Huffman alone ≈ Zstd; EE improves both; EE+Huffman smallest)");
}
