//! Compressibility probes: byte histograms, zero statistics, and the
//! heuristics the paper uses to pick a method per chunk (§3.2, §4.2).

/// 256-bin byte histogram using 4 interleaved sub-tables to break the
/// store-to-load dependency chain (the classic histogram trick).
pub fn byte_histogram(data: &[u8]) -> [u64; 256] {
    let mut h0 = [0u64; 256];
    let mut h1 = [0u64; 256];
    let mut h2 = [0u64; 256];
    let mut h3 = [0u64; 256];
    let mut chunks = data.chunks_exact(4);
    for c in &mut chunks {
        h0[c[0] as usize] += 1;
        h1[c[1] as usize] += 1;
        h2[c[2] as usize] += 1;
        h3[c[3] as usize] += 1;
    }
    for &b in chunks.remainder() {
        h0[b as usize] += 1;
    }
    for i in 0..256 {
        h0[i] += h1[i] + h2[i] + h3[i];
    }
    h0
}

/// Zero statistics of a buffer: the two signals of the paper's
/// Huffman-vs-Zstd auto selector for deltas (§4.2).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ZeroStats {
    /// Fraction of bytes equal to zero.
    pub zero_frac: f64,
    /// Length of the longest run of zero bytes.
    pub longest_run: usize,
}

/// Scan a buffer for zero fraction and longest zero run in one pass.
pub fn zero_stats(data: &[u8]) -> ZeroStats {
    let mut zeros = 0usize;
    let mut run = 0usize;
    let mut longest = 0usize;
    let mut i = 0;
    // Word-at-a-time skip of all-zero regions keeps this O(n/8) on the
    // highly-zero delta buffers where it matters.
    while i + 8 <= data.len() {
        let w = u64::from_le_bytes(data[i..i + 8].try_into().unwrap());
        if w == 0 {
            zeros += 8;
            run += 8;
            i += 8;
            continue;
        }
        for &b in &data[i..i + 8] {
            if b == 0 {
                zeros += 1;
                run += 1;
            } else {
                longest = longest.max(run);
                run = 0;
            }
        }
        i += 8;
    }
    for &b in &data[i..] {
        if b == 0 {
            zeros += 1;
            run += 1;
        } else {
            longest = longest.max(run);
            run = 0;
        }
    }
    longest = longest.max(run);
    ZeroStats {
        zero_frac: if data.is_empty() { 0.0 } else { zeros as f64 / data.len() as f64 },
        longest_run: longest,
    }
}

/// Fraction of bytes that differ between two equal-length buffers
/// (Fig. 8a "changed bytes" metric).
pub fn changed_byte_frac(a: &[u8], b: &[u8]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    let changed = a.iter().zip(b).filter(|(x, y)| x != y).count();
    changed as f64 / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    #[test]
    fn histogram_counts() {
        let data = [0u8, 0, 1, 2, 2, 2, 255];
        let h = byte_histogram(&data);
        assert_eq!(h[0], 2);
        assert_eq!(h[1], 1);
        assert_eq!(h[2], 3);
        assert_eq!(h[255], 1);
        assert_eq!(h.iter().sum::<u64>(), 7);
    }

    #[test]
    fn histogram_matches_naive_on_random() {
        let mut rng = Xoshiro256::seed_from_u64(21);
        let mut data = vec![0u8; 100_003]; // odd length exercises remainder
        rng.fill_bytes(&mut data);
        let fast = byte_histogram(&data);
        let mut naive = [0u64; 256];
        for &b in &data {
            naive[b as usize] += 1;
        }
        assert_eq!(fast, naive);
    }

    #[test]
    fn zero_stats_basic() {
        let s = zero_stats(&[0, 0, 1, 0, 0, 0, 2]);
        assert!((s.zero_frac - 5.0 / 7.0).abs() < 1e-12);
        assert_eq!(s.longest_run, 3);
    }

    #[test]
    fn zero_stats_all_zero_and_empty() {
        let s = zero_stats(&[0u8; 100]);
        assert_eq!(s.zero_frac, 1.0);
        assert_eq!(s.longest_run, 100);
        let e = zero_stats(&[]);
        assert_eq!(e.zero_frac, 0.0);
        assert_eq!(e.longest_run, 0);
    }

    #[test]
    fn zero_stats_run_across_word_boundary() {
        // run straddles the 8-byte fast path boundary
        let mut data = vec![1u8; 6];
        data.extend(vec![0u8; 12]);
        data.extend(vec![1u8; 6]);
        let s = zero_stats(&data);
        assert_eq!(s.longest_run, 12);
    }

    #[test]
    fn changed_bytes() {
        assert_eq!(changed_byte_frac(&[1, 2, 3, 4], &[1, 0, 3, 0]), 0.5);
        assert_eq!(changed_byte_frac(&[], &[]), 0.0);
    }
}
