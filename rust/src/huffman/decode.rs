//! Huffman decoder: single-level 2^12-entry lookup table, four interleaved
//! LSB-first bitstreams decoded in lockstep (independent dependency
//! chains → ILP), 4 symbols per lane refill.

use super::lengths::{canonical_codes, kraft_ok, rev_bits, unpack_lens, MAX_CODE_LEN};
use super::{MODE_HUFF, MODE_RAW, MODE_SINGLE};
use crate::error::{Error, Result};
use crate::util::read_u32_le;

/// Decode table: `entry[peek] = (symbol << 4) | len`. `len == 0` marks an
/// unreachable bit pattern (corrupt stream). Boxed fixed-size array so the
/// 12-bit peek indexes without bounds checks.
pub struct DecodeTable {
    entries: Box<[u16; 1 << MAX_CODE_LEN]>,
}

impl DecodeTable {
    /// Build the table from code lengths.
    pub fn from_lengths(lens: &[u8; 256]) -> Result<DecodeTable> {
        if !kraft_ok(lens) {
            return Err(Error::Corrupt("code lengths violate Kraft inequality".into()));
        }
        let size = 1usize << MAX_CODE_LEN;
        let mut entries: Box<[u16; 1 << MAX_CODE_LEN]> =
            vec![0u16; size].into_boxed_slice().try_into().unwrap();
        Self::fill(&mut entries, lens);
        Ok(DecodeTable { entries })
    }

    /// Rebuild in place from new code lengths — no allocation. This is
    /// the steady-state eviction path of [`DecodeTableCache`]: the 8 KiB
    /// box is recycled instead of re-boxed per stream.
    pub fn rebuild(&mut self, lens: &[u8; 256]) -> Result<()> {
        if !kraft_ok(lens) {
            return Err(Error::Corrupt("code lengths violate Kraft inequality".into()));
        }
        self.entries.fill(0);
        Self::fill(&mut self.entries, lens);
        Ok(())
    }

    /// Populate a zeroed table from (Kraft-valid) code lengths.
    fn fill(entries: &mut [u16; 1 << MAX_CODE_LEN], lens: &[u8; 256]) {
        let size = 1usize << MAX_CODE_LEN;
        let codes = canonical_codes(lens);
        for s in 0..256u16 {
            let l = lens[s as usize];
            if l == 0 {
                continue;
            }
            let rc = rev_bits(codes[s as usize].0, l) as usize;
            let step = 1usize << l;
            let entry = (s << 4) | l as u16;
            // every table slot whose low `l` bits equal the reversed code
            let mut idx = rc;
            while idx < size {
                entries[idx] = entry;
                idx += step;
            }
        }
    }

    /// Decode one symbol from the peeked bits; returns `(symbol, len)`.
    /// (Tests and the fallback lane use it; the hot loops inline the load.)
    #[inline(always)]
    #[cfg_attr(not(test), allow(dead_code))]
    fn lookup(&self, peek: u32) -> (u8, u32) {
        // peek is masked to MAX_CODE_LEN bits -> always in bounds
        let e = self.entries[(peek & ((1 << MAX_CODE_LEN) - 1)) as usize];
        ((e >> 4) as u8, (e & 0xF) as u32)
    }
}

/// Bytes of the packed on-wire code-length table (256 nibbles).
const PACKED_LENS: usize = 128;
/// Cached tables per worker. Model byte-group streams cycle through a
/// handful of length tables (one shape per group), so a small
/// fully-associative cache hits in practice; a miss with a full cache
/// recycles a slot's box via [`DecodeTable::rebuild`], so steady state
/// allocates nothing either way.
const CACHE_SLOTS: usize = 8;

/// Per-worker cache of built [`DecodeTable`]s keyed by the stream's
/// 128-byte packed length table. Lives in the codec's
/// [`crate::codec::ScratchArena`] so each decode worker reuses tables
/// across the chunks it touches instead of rebuilding (and re-boxing
/// 8 KiB) per stream.
#[derive(Default)]
pub struct DecodeTableCache {
    slots: Vec<([u8; PACKED_LENS], DecodeTable)>,
    clock: usize,
}

impl DecodeTableCache {
    /// New, empty cache (tables build on first use).
    pub fn new() -> DecodeTableCache {
        DecodeTableCache::default()
    }

    /// The decode table for a packed length table, built (or rebuilt into
    /// a recycled slot) on miss.
    pub fn get(&mut self, packed: &[u8; PACKED_LENS]) -> Result<&DecodeTable> {
        if let Some(i) = self.slots.iter().position(|(k, _)| k == packed) {
            return Ok(&self.slots[i].1);
        }
        let lens = unpack_lens(packed);
        if self.slots.len() < CACHE_SLOTS {
            let table = DecodeTable::from_lengths(&lens)?;
            self.slots.push((*packed, table));
            return Ok(&self.slots.last().expect("just pushed").1);
        }
        let i = self.clock;
        self.clock = (self.clock + 1) % CACHE_SLOTS;
        // Validate-then-fill: a corrupt table leaves the slot's key/table
        // pair untouched.
        let slot = &mut self.slots[i];
        slot.1.rebuild(&lens)?;
        slot.0 = *packed;
        Ok(&self.slots[i].1)
    }
}

/// Decode two lanes in lockstep. Each symbol's table load depends on the
/// previous shift (a ~6-cycle chain); interleaving two independent chains
/// hides that latency while the state (2 × {pos, buf, nbits}) still fits
/// in registers — four lanes at once spills and is slower.
#[inline(never)]
fn decode_lane2(
    table: &DecodeTable,
    da: &[u8],
    db: &[u8],
    oa: &mut [u8],
    ob: &mut [u8],
) -> bool {
    let entries = &table.entries;
    let mut ok = true;
    let (mut pa, mut ba, mut na) = (0usize, 0u64, 0u32);
    let (mut pb, mut bb, mut nb) = (0usize, 0u64, 0u32);

    macro_rules! refill {
        ($d:ident, $p:ident, $b:ident, $n:ident) => {
            if $p + 8 <= $d.len() {
                let w = u64::from_le_bytes($d[$p..$p + 8].try_into().unwrap());
                $b |= w << $n;
                let take = (63 - $n) >> 3;
                $p += take as usize;
                $n += take * 8;
            } else {
                while $n <= 56 && $p < $d.len() {
                    $b |= ($d[$p] as u64) << $n;
                    $p += 1;
                    $n += 8;
                }
            }
        };
    }
    macro_rules! decode1 {
        ($b:ident, $n:ident) => {{
            let e = entries[($b & ((1 << MAX_CODE_LEN) - 1)) as usize];
            let l = (e & 0xF) as u32;
            ok &= l != 0 && l <= $n;
            $b >>= l;
            $n -= l.min($n);
            (e >> 4) as u8
        }};
    }

    let q = oa.len().min(ob.len());
    let mut i = 0;
    // main loop: 4 symbols per lane per refill (4 × 12 = 48 ≤ 56 bits)
    while i + 4 <= q {
        refill!(da, pa, ba, na);
        refill!(db, pb, bb, nb);
        oa[i] = decode1!(ba, na);
        ob[i] = decode1!(bb, nb);
        oa[i + 1] = decode1!(ba, na);
        ob[i + 1] = decode1!(bb, nb);
        oa[i + 2] = decode1!(ba, na);
        ob[i + 2] = decode1!(bb, nb);
        oa[i + 3] = decode1!(ba, na);
        ob[i + 3] = decode1!(bb, nb);
        i += 4;
    }
    for slot in oa[i..].iter_mut() {
        refill!(da, pa, ba, na);
        *slot = decode1!(ba, na);
    }
    for slot in ob[i..].iter_mut() {
        refill!(db, pb, bb, nb);
        *slot = decode1!(bb, nb);
    }
    ok
}

/// Decode one lane into `out` (tail/fallback path).
#[inline(never)]
#[allow(dead_code)]
fn decode_lane(table: &DecodeTable, data: &[u8], out: &mut [u8]) -> bool {
    let entries = &table.entries;
    let mut pos: usize = 0;
    let mut buf: u64 = 0;
    let mut nbits: u32 = 0;
    let mut ok = true;

    macro_rules! refill {
        () => {
            if pos + 8 <= data.len() {
                let w = u64::from_le_bytes(data[pos..pos + 8].try_into().unwrap());
                buf |= w << nbits;
                let take = (63 - nbits) >> 3;
                pos += take as usize;
                nbits += take * 8;
            } else {
                while nbits <= 56 && pos < data.len() {
                    buf |= (data[pos] as u64) << nbits;
                    pos += 1;
                    nbits += 8;
                }
            }
        };
    }
    macro_rules! decode1 {
        () => {{
            let e = entries[(buf & ((1 << MAX_CODE_LEN) - 1)) as usize];
            let l = (e & 0xF) as u32;
            ok &= l != 0 && l <= nbits;
            buf >>= l;
            nbits -= l.min(nbits);
            (e >> 4) as u8
        }};
    }

    let mut chunks = out.chunks_exact_mut(4);
    for ch in &mut chunks {
        refill!();
        ch[0] = decode1!();
        ch[1] = decode1!();
        ch[2] = decode1!();
        ch[3] = decode1!();
    }
    for slot in chunks.into_remainder() {
        refill!();
        *slot = decode1!();
    }
    ok
}

/// Decompress a stream produced by [`super::compress`]. `expected_len` is
/// the known raw size (stored in the codec's chunk table); it is validated
/// against the stream header.
pub fn decompress(data: &[u8], expected_len: usize) -> Result<Vec<u8>> {
    let mut out = vec![0u8; expected_len];
    decompress_into(data, &mut out)?;
    Ok(out)
}

/// Decompress directly into `out` (its length is the expected raw size).
/// The allocation-free path the chunk pipeline uses.
pub fn decompress_into(data: &[u8], out: &mut [u8]) -> Result<()> {
    decompress_into_inner(data, out, None)
}

/// [`decompress_into`] with a per-worker [`DecodeTableCache`]: repeated
/// length tables skip the build, and misses recycle a cached 8 KiB box —
/// the decode side's steady state performs no allocations.
pub fn decompress_into_cached(
    data: &[u8],
    out: &mut [u8],
    cache: &mut DecodeTableCache,
) -> Result<()> {
    decompress_into_inner(data, out, Some(cache))
}

fn decompress_into_inner(
    data: &[u8],
    out: &mut [u8],
    cache: Option<&mut DecodeTableCache>,
) -> Result<()> {
    let expected_len = out.len();
    let mode = *data.first().ok_or_else(|| Error::Corrupt("empty stream".into()))?;
    match mode {
        MODE_RAW => {
            if data.len() < 5 {
                return Err(Error::Corrupt("raw header truncated".into()));
            }
            let n = read_u32_le(data, 1) as usize;
            if n != expected_len {
                return Err(Error::Corrupt(format!(
                    "raw length {n} != expected {expected_len}"
                )));
            }
            if data.len() < 5 + n {
                return Err(Error::Corrupt("raw payload truncated".into()));
            }
            out.copy_from_slice(&data[5..5 + n]);
            Ok(())
        }
        MODE_SINGLE => {
            if data.len() < 6 {
                return Err(Error::Corrupt("single header truncated".into()));
            }
            let sym = data[1];
            let n = read_u32_le(data, 2) as usize;
            if n != expected_len {
                return Err(Error::Corrupt(format!(
                    "single length {n} != expected {expected_len}"
                )));
            }
            out.fill(sym);
            Ok(())
        }
        MODE_HUFF => decode_huff(data, out, cache),
        other => Err(Error::Corrupt(format!("bad stream mode {other}"))),
    }
}

fn decode_huff(data: &[u8], out: &mut [u8], cache: Option<&mut DecodeTableCache>) -> Result<()> {
    const HDR: usize = 1 + 128 + 4 + 12 + 4;
    let expected_len = out.len();
    if data.len() < HDR {
        return Err(Error::Corrupt("huffman header truncated".into()));
    }
    let packed: &[u8; PACKED_LENS] = data[1..129].try_into().expect("slice of 128");
    let count = read_u32_le(data, 129) as usize;
    let s0len = read_u32_le(data, 133) as usize;
    let s1len = read_u32_le(data, 137) as usize;
    let s2len = read_u32_le(data, 141) as usize;
    let paylen = read_u32_le(data, 145) as usize;
    if count != expected_len {
        return Err(Error::Corrupt(format!(
            "huffman count {count} != expected {expected_len}"
        )));
    }
    if data.len() < HDR + paylen || s0len + s1len + s2len > paylen {
        return Err(Error::Corrupt("huffman payload truncated".into()));
    }
    let owned;
    let table: &DecodeTable = match cache {
        Some(c) => c.get(packed)?,
        None => {
            owned = DecodeTable::from_lengths(&unpack_lens(packed))?;
            &owned
        }
    };
    let payload = &data[HDR..HDR + paylen];
    let (p0, rest) = payload.split_at(s0len);
    let (p1, rest) = rest.split_at(s1len);
    let (p2, p3) = rest.split_at(s2len);

    let q = count / 4;
    let (o0, rest) = out.split_at_mut(q);
    let (o1, rest) = rest.split_at_mut(q);
    let (o2, o3) = rest.split_at_mut(q);

    let ok = decode_lane2(table, p0, p1, o0, o1)
        & decode_lane2(table, p2, p3, o2, o3);
    if !ok {
        return Err(Error::Corrupt("invalid code in huffman stream".into()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::compress;

    #[test]
    fn table_marks_unused_patterns_invalid() {
        let mut lens = [0u8; 256];
        lens[0] = 1;
        lens[1] = 2; // Kraft slack -> some patterns invalid
        let t = DecodeTable::from_lengths(&lens).unwrap();
        let mut saw_invalid = false;
        for p in 0..(1usize << MAX_CODE_LEN) {
            let (_, l) = t.lookup(p as u32);
            if l == 0 {
                saw_invalid = true;
            }
        }
        assert!(saw_invalid);
    }

    #[test]
    fn rejects_kraft_violation() {
        let mut lens = [0u8; 256];
        for l in lens.iter_mut().take(5) {
            *l = 1; // five 1-bit codes: impossible
        }
        assert!(DecodeTable::from_lengths(&lens).is_err());
    }

    #[test]
    fn corrupt_payload_detected_or_differs() {
        let data: Vec<u8> = (0..2048u32).map(|i| (i % 7) as u8).collect();
        let mut enc = compress(&data);
        assert_eq!(enc[0], MODE_HUFF);
        let last = enc.len() - 1;
        enc[last] ^= 0xFF;
        match decompress(&enc, data.len()) {
            Ok(dec) => assert_ne!(dec, data),
            Err(_) => {}
        }
    }

    #[test]
    fn header_length_mismatch_rejected() {
        let data = vec![1u8, 2, 3, 4, 1, 2, 3, 4];
        let enc = compress(&data);
        assert!(decompress(&enc, 7).is_err());
    }

    #[test]
    fn cached_decode_matches_uncached_across_tables() {
        // More distinct length tables than cache slots: exercises insert,
        // hit and rebuild-eviction paths.
        let mut cache = DecodeTableCache::new();
        let streams: Vec<Vec<u8>> = (0..(CACHE_SLOTS + 5))
            .map(|t| (0..4096usize).map(|i| (i % (3 + t)) as u8).collect())
            .collect();
        for _round in 0..3 {
            for data in &streams {
                let enc = compress(data);
                let mut out = vec![0u8; data.len()];
                decompress_into_cached(&enc, &mut out, &mut cache).unwrap();
                assert_eq!(&out, data);
            }
        }
    }

    #[test]
    fn rebuild_matches_fresh_build() {
        let mut lens_a = [0u8; 256];
        lens_a[0] = 1;
        lens_a[1] = 2;
        lens_a[2] = 2;
        let mut lens_b = [0u8; 256];
        for l in lens_b.iter_mut().take(4) {
            *l = 2;
        }
        let fresh = DecodeTable::from_lengths(&lens_b).unwrap();
        let mut recycled = DecodeTable::from_lengths(&lens_a).unwrap();
        recycled.rebuild(&lens_b).unwrap();
        for p in 0..(1usize << MAX_CODE_LEN) {
            assert_eq!(fresh.lookup(p as u32), recycled.lookup(p as u32));
        }
        // A Kraft-violating rebuild fails and leaves the table usable.
        let mut bad = [0u8; 256];
        for l in bad.iter_mut().take(5) {
            *l = 1;
        }
        assert!(recycled.rebuild(&bad).is_err());
        assert_eq!(fresh.lookup(0), recycled.lookup(0));
    }

    #[test]
    fn lane_lengths_cover_all_counts() {
        // every count mod 4, incl. < 4
        for count in [1usize, 2, 3, 4, 5, 7, 1023, 4096, 4097, 4098, 4099] {
            let data: Vec<u8> = (0..count).map(|i| (i % 5) as u8).collect();
            let enc = compress(&data);
            assert_eq!(decompress(&enc, count).unwrap(), data, "count {count}");
        }
    }
}
