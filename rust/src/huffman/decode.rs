//! Huffman decoder: flat two-level **multi-symbol** lookup table, four
//! interleaved LSB-first bitstreams decoded in lockstep (independent
//! dependency chains → ILP), up to 8 symbols per lane refill.
//!
//! The primary table is indexed by 8 peeked bits and packs *up to two*
//! short symbols per entry — byte-group streams are dominated by 1–6-bit
//! exponent codes, so most probes emit two symbols for one load+shift.
//! Codes of 9–12 bits take a sentinel-flagged entry linking to a 16-entry
//! secondary block indexed by the next 4 bits. Dead bit patterns decode as
//! `consumed = 0` entries that poison the `ok` flag but still advance one
//! output slot, so corrupt input terminates without a validity branch in
//! the hot loop.

use super::lengths::{canonical_codes, kraft_ok, rev_bits, unpack_lens, MAX_CODE_LEN};
use super::{MODE_HUFF, MODE_RAW, MODE_SINGLE};
use crate::error::{Error, Result};
use crate::util::read_u32_le;

/// Sentinel bit: the primary entry links to a secondary block.
const LONG_FLAG: u32 = 1 << 31;
/// Dead bit pattern: symbol 0, `consumed = 0` (flags `ok` false), one
/// output slot of advance so corrupt streams terminate.
const ENTRY_INVALID: u32 = 1 << 25;

/// Two-level decode table.
///
/// **Primary** (`primary[peek & 0xFF]`), short form (bit 31 clear):
/// `sym0` in bits 0..8, `sym1` in 8..16, total consumed bits in 16..21,
/// `len0` in 21..25, symbol count (1 or 2) in 25..27. Long form (bit 31
/// set): bits 0..16 hold the base index of a 16-entry **secondary** block,
/// indexed by peek bits 8..12; a secondary entry holds `sym` in bits 0..8
/// and `len` in 8..13, with 0 marking an invalid extension.
pub struct DecodeTable {
    primary: Box<[u32; 256]>,
    secondary: Vec<u32>,
}

impl DecodeTable {
    /// Build the table from code lengths.
    pub fn from_lengths(lens: &[u8; 256]) -> Result<DecodeTable> {
        if !kraft_ok(lens) {
            return Err(Error::Corrupt("code lengths violate Kraft inequality".into()));
        }
        let mut table = DecodeTable {
            primary: Box::new([0u32; 256]),
            secondary: Vec::new(),
        };
        table.fill(lens);
        Ok(table)
    }

    /// Rebuild in place from new code lengths — the steady-state eviction
    /// path of [`DecodeTableCache`]: the primary box and the secondary
    /// vector's high-water capacity are recycled instead of re-allocated
    /// per stream, so table churn stays allocation-free once warm.
    pub fn rebuild(&mut self, lens: &[u8; 256]) -> Result<()> {
        if !kraft_ok(lens) {
            return Err(Error::Corrupt("code lengths violate Kraft inequality".into()));
        }
        self.primary.fill(0);
        self.secondary.clear();
        self.fill(lens);
        Ok(())
    }

    /// Populate the cleared table from (Kraft-valid) code lengths.
    fn fill(&mut self, lens: &[u8; 256]) {
        // Stage 1: the classic single-level table — first symbol + length
        // for every 12-bit pattern — on the stack (8 KiB, build-time only).
        const SIZE: usize = 1 << MAX_CODE_LEN;
        let mut tmp = [0u16; SIZE];
        let codes = canonical_codes(lens);
        for s in 0..256u16 {
            let l = lens[s as usize];
            if l == 0 {
                continue;
            }
            let rc = rev_bits(codes[s as usize].0, l) as usize;
            let step = 1usize << l;
            let entry = (s << 4) | l as u16;
            // every table slot whose low `l` bits equal the reversed code
            let mut idx = rc;
            while idx < SIZE {
                tmp[idx] = entry;
                idx += step;
            }
        }
        // Stage 2: fold into the two-level multi-symbol layout. For a
        // short (≤ 8-bit) first code, the *second* symbol starting at bit
        // `len0` is `tmp[idx >> len0]` — its missing high bits are zero,
        // which is exact whenever `len1 ≤ 8 - len0` (the bits consumed all
        // lie inside the 8 peeked); prefix-freeness guarantees no short
        // code and long code ever claim the same pattern.
        for idx in 0..256usize {
            let e1 = tmp[idx];
            let len0 = (e1 & 0xF) as u32;
            self.primary[idx] = if (1..=8).contains(&len0) {
                let sym0 = (e1 >> 4) as u32;
                let e2 = tmp[idx >> len0];
                let len1 = (e2 & 0xF) as u32;
                if len1 != 0 && len1 <= 8 - len0 {
                    let sym1 = (e2 >> 4) as u32;
                    sym0 | (sym1 << 8) | ((len0 + len1) << 16) | (len0 << 21) | (2 << 25)
                } else {
                    sym0 | (len0 << 16) | (len0 << 21) | (1 << 25)
                }
            } else {
                // no ≤8-bit code matches these low bits: either a 9–12-bit
                // code (resolved by 4 more bits) or a dead pattern
                let mut block = [0u32; 16];
                let mut any_valid = false;
                for (sub, slot) in block.iter_mut().enumerate() {
                    let t = tmp[idx | (sub << 8)];
                    let l = (t & 0xF) as u32;
                    if l != 0 {
                        any_valid = true;
                        *slot = (t >> 4) as u32 | (l << 8);
                    }
                }
                if any_valid {
                    let base = self.secondary.len() as u32;
                    debug_assert!(base <= 0xFFFF, "secondary table exceeds base field");
                    self.secondary.extend_from_slice(&block);
                    LONG_FLAG | base
                } else {
                    ENTRY_INVALID
                }
            };
        }
    }

    /// Decode one symbol from the peeked bits; returns `(symbol, len)` —
    /// the *first* symbol of multi-symbol entries, matching the old
    /// single-level table's contract. (Tests and the reference-equivalence
    /// proptest use it; the hot loops inline the loads.)
    #[inline(always)]
    #[cfg_attr(not(test), allow(dead_code))]
    fn lookup(&self, peek: u32) -> (u8, u32) {
        let e = self.primary[(peek & 0xFF) as usize];
        if e & LONG_FLAG == 0 {
            (e as u8, (e >> 21) & 0xF)
        } else {
            let e2 = self.secondary[(e & 0xFFFF) as usize + ((peek >> 8) & 0xF) as usize];
            (e2 as u8, (e2 >> 8) & 0x1F)
        }
    }
}

/// Bytes of the packed on-wire code-length table (256 nibbles).
const PACKED_LENS: usize = 128;
/// Cached tables per worker. Model byte-group streams cycle through a
/// handful of length tables (one shape per group), so a small
/// fully-associative cache hits in practice; a miss with a full cache
/// recycles a slot's buffers via [`DecodeTable::rebuild`], so steady state
/// allocates nothing either way.
const CACHE_SLOTS: usize = 8;

/// Per-worker cache of built [`DecodeTable`]s keyed by the stream's
/// 128-byte packed length table. Lives in the codec's
/// [`crate::codec::ScratchArena`] so each decode worker reuses tables
/// across the chunks it touches instead of rebuilding (and re-allocating
/// primary + secondary storage) per stream.
#[derive(Default)]
pub struct DecodeTableCache {
    slots: Vec<([u8; PACKED_LENS], DecodeTable)>,
    clock: usize,
}

impl DecodeTableCache {
    /// New, empty cache (tables build on first use).
    pub fn new() -> DecodeTableCache {
        DecodeTableCache::default()
    }

    /// The decode table for a packed length table, built (or rebuilt into
    /// a recycled slot) on miss.
    pub fn get(&mut self, packed: &[u8; PACKED_LENS]) -> Result<&DecodeTable> {
        if let Some(i) = self.slots.iter().position(|(k, _)| k == packed) {
            return Ok(&self.slots[i].1);
        }
        let lens = unpack_lens(packed);
        if self.slots.len() < CACHE_SLOTS {
            let table = DecodeTable::from_lengths(&lens)?;
            self.slots.push((*packed, table));
            return Ok(&self.slots.last().expect("just pushed").1);
        }
        let i = self.clock;
        self.clock = (self.clock + 1) % CACHE_SLOTS;
        // Validate-then-fill: a corrupt table leaves the slot's key/table
        // pair untouched.
        let slot = &mut self.slots[i];
        slot.1.rebuild(&lens)?;
        slot.0 = *packed;
        Ok(&self.slots[i].1)
    }
}

/// Decode two lanes in lockstep. Each probe's table load depends on the
/// previous shift (a ~6-cycle chain); interleaving two independent chains
/// hides that latency while the state (2 × {pos, buf, nbits}) still fits
/// in registers — four lanes at once spills and is slower.
#[inline(never)]
fn decode_lane2(
    table: &DecodeTable,
    da: &[u8],
    db: &[u8],
    oa: &mut [u8],
    ob: &mut [u8],
) -> bool {
    let primary = &table.primary;
    let secondary = table.secondary.as_slice();
    let mut ok = true;
    let (mut pa, mut ba, mut na) = (0usize, 0u64, 0u32);
    let (mut pb, mut bb, mut nb) = (0usize, 0u64, 0u32);

    macro_rules! refill {
        ($d:ident, $p:ident, $b:ident, $n:ident) => {
            if $p + 8 <= $d.len() {
                let w = u64::from_le_bytes($d[$p..$p + 8].try_into().unwrap());
                $b |= w << $n;
                let take = (63 - $n) >> 3;
                $p += take as usize;
                $n += take * 8;
            } else {
                while $n <= 56 && $p < $d.len() {
                    $b |= ($d[$p] as u64) << $n;
                    $p += 1;
                    $n += 8;
                }
            }
        };
    }
    // One multi-symbol probe: a short entry writes both symbol bytes
    // unconditionally (the main loop's `+ 8` slack guarantees room) and
    // advances by its symbol count; a long entry resolves one symbol
    // through the secondary block.
    macro_rules! probe {
        ($b:ident, $n:ident, $o:ident, $i:ident) => {
            let e = primary[($b & 0xFF) as usize];
            if e & LONG_FLAG == 0 {
                $o[$i] = e as u8;
                $o[$i + 1] = (e >> 8) as u8;
                let consumed = (e >> 16) & 0x1F;
                $i += ((e >> 25) & 0x3) as usize;
                ok &= consumed != 0 && consumed <= $n;
                $b >>= consumed;
                $n -= consumed.min($n);
            } else {
                let e2 = secondary[(e & 0xFFFF) as usize + (($b >> 8) & 0xF) as usize];
                let l = (e2 >> 8) & 0x1F;
                $o[$i] = e2 as u8;
                $i += 1;
                ok &= l != 0 && l <= $n;
                $b >>= l;
                $n -= l.min($n);
            }
        };
    }
    // Strict single-symbol step for the tails: never writes past the
    // emitted slot, so it runs to the exact lane end.
    macro_rules! decode1 {
        ($b:ident, $n:ident) => {{
            let e = primary[($b & 0xFF) as usize];
            let (sym, l) = if e & LONG_FLAG == 0 {
                (e as u8, (e >> 21) & 0xF)
            } else {
                let e2 = secondary[(e & 0xFFFF) as usize + (($b >> 8) & 0xF) as usize];
                (e2 as u8, (e2 >> 8) & 0x1F)
            };
            ok &= l != 0 && l <= $n;
            $b >>= l;
            $n -= l.min($n);
            sym
        }};
    }

    let qa = oa.len();
    let qb = ob.len();
    let (mut ia, mut ib) = (0usize, 0usize);
    // Main loop: four probes per lane per refill. Worst case 4 × 12 = 48
    // bits ≤ the ≥ 56 a refill guarantees; best case (four 2-symbol
    // probes) emits 8 symbols per lane per refill — the `+ 8` bound also
    // caps the highest written index at `i + 7`. Lanes advance at
    // data-dependent rates, so each tracks its own cursor.
    while ia + 8 <= qa && ib + 8 <= qb {
        refill!(da, pa, ba, na);
        refill!(db, pb, bb, nb);
        probe!(ba, na, oa, ia);
        probe!(bb, nb, ob, ib);
        probe!(ba, na, oa, ia);
        probe!(bb, nb, ob, ib);
        probe!(ba, na, oa, ia);
        probe!(bb, nb, ob, ib);
        probe!(ba, na, oa, ia);
        probe!(bb, nb, ob, ib);
    }
    for slot in oa[ia..].iter_mut() {
        refill!(da, pa, ba, na);
        *slot = decode1!(ba, na);
    }
    for slot in ob[ib..].iter_mut() {
        refill!(db, pb, bb, nb);
        *slot = decode1!(bb, nb);
    }
    ok
}

/// Decompress a stream produced by [`super::compress`]. `expected_len` is
/// the known raw size (stored in the codec's chunk table); it is validated
/// against the stream header.
pub fn decompress(data: &[u8], expected_len: usize) -> Result<Vec<u8>> {
    let mut out = vec![0u8; expected_len];
    decompress_into(data, &mut out)?;
    Ok(out)
}

/// Decompress directly into `out` (its length is the expected raw size).
/// The allocation-free path the chunk pipeline uses.
pub fn decompress_into(data: &[u8], out: &mut [u8]) -> Result<()> {
    decompress_into_inner(data, out, None)
}

/// [`decompress_into`] with a per-worker [`DecodeTableCache`]: repeated
/// length tables skip the build, and misses recycle a cached table's
/// storage — the decode side's steady state performs no allocations.
pub fn decompress_into_cached(
    data: &[u8],
    out: &mut [u8],
    cache: &mut DecodeTableCache,
) -> Result<()> {
    decompress_into_inner(data, out, Some(cache))
}

fn decompress_into_inner(
    data: &[u8],
    out: &mut [u8],
    cache: Option<&mut DecodeTableCache>,
) -> Result<()> {
    let expected_len = out.len();
    let mode = *data.first().ok_or_else(|| Error::Corrupt("empty stream".into()))?;
    match mode {
        MODE_RAW => {
            if data.len() < 5 {
                return Err(Error::Corrupt("raw header truncated".into()));
            }
            let n = read_u32_le(data, 1) as usize;
            if n != expected_len {
                return Err(Error::Corrupt(format!(
                    "raw length {n} != expected {expected_len}"
                )));
            }
            if data.len() < 5 + n {
                return Err(Error::Corrupt("raw payload truncated".into()));
            }
            out.copy_from_slice(&data[5..5 + n]);
            Ok(())
        }
        MODE_SINGLE => {
            if data.len() < 6 {
                return Err(Error::Corrupt("single header truncated".into()));
            }
            let sym = data[1];
            let n = read_u32_le(data, 2) as usize;
            if n != expected_len {
                return Err(Error::Corrupt(format!(
                    "single length {n} != expected {expected_len}"
                )));
            }
            out.fill(sym);
            Ok(())
        }
        MODE_HUFF => decode_huff(data, out, cache),
        other => Err(Error::Corrupt(format!("bad stream mode {other}"))),
    }
}

fn decode_huff(data: &[u8], out: &mut [u8], cache: Option<&mut DecodeTableCache>) -> Result<()> {
    const HDR: usize = 1 + 128 + 4 + 12 + 4;
    let expected_len = out.len();
    if data.len() < HDR {
        return Err(Error::Corrupt("huffman header truncated".into()));
    }
    let packed: &[u8; PACKED_LENS] = data[1..129].try_into().expect("slice of 128");
    let count = read_u32_le(data, 129) as usize;
    let s0len = read_u32_le(data, 133) as usize;
    let s1len = read_u32_le(data, 137) as usize;
    let s2len = read_u32_le(data, 141) as usize;
    let paylen = read_u32_le(data, 145) as usize;
    if count != expected_len {
        return Err(Error::Corrupt(format!(
            "huffman count {count} != expected {expected_len}"
        )));
    }
    if data.len() < HDR + paylen || s0len + s1len + s2len > paylen {
        return Err(Error::Corrupt("huffman payload truncated".into()));
    }
    let owned;
    let table: &DecodeTable = match cache {
        Some(c) => c.get(packed)?,
        None => {
            owned = DecodeTable::from_lengths(&unpack_lens(packed))?;
            &owned
        }
    };
    let payload = &data[HDR..HDR + paylen];
    let (p0, rest) = payload.split_at(s0len);
    let (p1, rest) = rest.split_at(s1len);
    let (p2, p3) = rest.split_at(s2len);

    let q = count / 4;
    let (o0, rest) = out.split_at_mut(q);
    let (o1, rest) = rest.split_at_mut(q);
    let (o2, o3) = rest.split_at_mut(q);

    let ok = decode_lane2(table, p0, p1, o0, o1)
        & decode_lane2(table, p2, p3, o2, o3);
    if !ok {
        return Err(Error::Corrupt("invalid code in huffman stream".into()));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::huffman::compress;
    use crate::huffman::lengths::build_lengths;
    use crate::util::Xoshiro256;

    #[test]
    fn table_marks_unused_patterns_invalid() {
        let mut lens = [0u8; 256];
        lens[0] = 1;
        lens[1] = 2; // Kraft slack -> some patterns invalid
        let t = DecodeTable::from_lengths(&lens).unwrap();
        let mut saw_invalid = false;
        for p in 0..(1usize << MAX_CODE_LEN) {
            let (_, l) = t.lookup(p as u32);
            if l == 0 {
                saw_invalid = true;
            }
        }
        assert!(saw_invalid);
    }

    #[test]
    fn rejects_kraft_violation() {
        let mut lens = [0u8; 256];
        for l in lens.iter_mut().take(5) {
            *l = 1; // five 1-bit codes: impossible
        }
        assert!(DecodeTable::from_lengths(&lens).is_err());
    }

    #[test]
    fn corrupt_payload_detected_or_differs() {
        let data: Vec<u8> = (0..2048u32).map(|i| (i % 7) as u8).collect();
        let mut enc = compress(&data);
        assert_eq!(enc[0], MODE_HUFF);
        let last = enc.len() - 1;
        enc[last] ^= 0xFF;
        match decompress(&enc, data.len()) {
            Ok(dec) => assert_ne!(dec, data),
            Err(_) => {}
        }
    }

    #[test]
    fn header_length_mismatch_rejected() {
        let data = vec![1u8, 2, 3, 4, 1, 2, 3, 4];
        let enc = compress(&data);
        assert!(decompress(&enc, 7).is_err());
    }

    #[test]
    fn cached_decode_matches_uncached_across_tables() {
        // More distinct length tables than cache slots: exercises insert,
        // hit and rebuild-eviction paths.
        let mut cache = DecodeTableCache::new();
        let streams: Vec<Vec<u8>> = (0..(CACHE_SLOTS + 5))
            .map(|t| (0..4096usize).map(|i| (i % (3 + t)) as u8).collect())
            .collect();
        for _round in 0..3 {
            for data in &streams {
                let enc = compress(data);
                let mut out = vec![0u8; data.len()];
                decompress_into_cached(&enc, &mut out, &mut cache).unwrap();
                assert_eq!(&out, data);
            }
        }
    }

    #[test]
    fn rebuild_matches_fresh_build() {
        let mut lens_a = [0u8; 256];
        lens_a[0] = 1;
        lens_a[1] = 2;
        lens_a[2] = 2;
        let mut lens_b = [0u8; 256];
        for l in lens_b.iter_mut().take(4) {
            *l = 2;
        }
        let fresh = DecodeTable::from_lengths(&lens_b).unwrap();
        let mut recycled = DecodeTable::from_lengths(&lens_a).unwrap();
        recycled.rebuild(&lens_b).unwrap();
        for p in 0..(1usize << MAX_CODE_LEN) {
            assert_eq!(fresh.lookup(p as u32), recycled.lookup(p as u32));
        }
        // A Kraft-violating rebuild fails and leaves the table usable.
        let mut bad = [0u8; 256];
        for l in bad.iter_mut().take(5) {
            *l = 1;
        }
        assert!(recycled.rebuild(&bad).is_err());
        assert_eq!(fresh.lookup(0), recycled.lookup(0));
    }

    #[test]
    fn lane_lengths_cover_all_counts() {
        // every count mod 4, incl. < 4
        for count in [1usize, 2, 3, 4, 5, 7, 1023, 4096, 4097, 4098, 4099] {
            let data: Vec<u8> = (0..count).map(|i| (i % 5) as u8).collect();
            let enc = compress(&data);
            assert_eq!(decompress(&enc, count).unwrap(), data, "count {count}");
        }
    }

    /// Random histogram with a skew knob; deep skews force 9–12-bit codes
    /// (the secondary-table path).
    fn random_lens(rng: &mut Xoshiro256, max_syms: usize, skew: i32) -> Option<[u8; 256]> {
        let mut hist = [0u64; 256];
        let nsyms = 2 + rng.below(max_syms - 1);
        for _ in 0..nsyms {
            let s = rng.below(256);
            hist[s] += 1 + (rng.uniform().powi(skew) * 1_000_000.0) as u64;
        }
        build_lengths(&hist)
    }

    #[test]
    fn lookup_matches_reference_over_random_tables() {
        // The two-level table must agree with a bit-by-bit canonical
        // decoder on the (first symbol, length) of **every** 12-bit
        // pattern, across random Kraft-valid length tables.
        let mut rng = Xoshiro256::seed_from_u64(0xDEC0DE);
        let mut long_tables = 0usize;
        for _ in 0..30 {
            let Some(lens) = random_lens(&mut rng, 256, 6) else {
                continue;
            };
            if lens.iter().any(|&l| l > 8) {
                long_tables += 1;
            }
            let table = DecodeTable::from_lengths(&lens).unwrap();
            // (reversed code, len, sym), any scan order works: prefix-free
            // codes match at most one entry per pattern.
            let codes = canonical_codes(&lens);
            let ref_tab: Vec<(u16, u8, u8)> = (0..256usize)
                .filter(|&s| lens[s] > 0)
                .map(|s| (rev_bits(codes[s].0, lens[s]), lens[s], s as u8))
                .collect();
            for peek in 0..(1u32 << MAX_CODE_LEN) {
                let want = ref_tab
                    .iter()
                    .find(|&&(rc, l, _)| peek & ((1 << l) - 1) == rc as u32)
                    .map(|&(_, l, s)| (s, l as u32));
                let (sym, l) = table.lookup(peek);
                match want {
                    Some(w) => assert_eq!((sym, l), w, "peek {peek:03x}"),
                    None => assert_eq!(l, 0, "peek {peek:03x} should be invalid"),
                }
            }
        }
        assert!(long_tables > 0, "no trial produced >8-bit codes");
    }

    #[test]
    fn decode_matches_reference_bitwise_decoder() {
        // Full-stream equivalence: the multi-symbol fast path (2-symbol
        // entries, secondary blocks, strict tails) must reproduce what a
        // bit-by-bit canonical decoder extracts from each lane.
        let mut rng = Xoshiro256::seed_from_u64(0xB17D);

        // Deterministic Fibonacci skew guarantees 12-bit codes.
        let mut fib_data = Vec::new();
        let (mut a, mut b) = (1u64, 1u64);
        for s in 0..20u8 {
            for _ in 0..a {
                fib_data.push(s);
            }
            let c = a + b;
            a = b;
            b = c;
        }

        let mut cases: Vec<Vec<u8>> = vec![fib_data];
        for _ in 0..25 {
            let Some(lens) = random_lens(&mut rng, 200, 4) else {
                continue;
            };
            let pop: Vec<u8> = (0..256usize).filter(|&s| lens[s] > 0).map(|s| s as u8).collect();
            let count = 1 + rng.below(5000);
            let mut data = Vec::with_capacity(count);
            for _ in 0..count {
                let u = rng.uniform();
                let idx = ((u * u) * pop.len() as f64) as usize;
                data.push(pop[idx.min(pop.len() - 1)]);
            }
            cases.push(data);
        }

        let mut huff_streams = 0usize;
        for data in &cases {
            let enc = compress(data);
            if enc[0] != MODE_HUFF {
                continue;
            }
            huff_streams += 1;
            assert_eq!(&decompress(&enc, data.len()).unwrap(), data);

            // Reference decode, lane by lane.
            const HDR: usize = 1 + 128 + 4 + 12 + 4;
            let lens = unpack_lens(&enc[1..129]);
            let count = read_u32_le(&enc, 129) as usize;
            let s0 = read_u32_le(&enc, 133) as usize;
            let s1 = read_u32_le(&enc, 137) as usize;
            let s2 = read_u32_le(&enc, 141) as usize;
            let paylen = read_u32_le(&enc, 145) as usize;
            let payload = &enc[HDR..HDR + paylen];
            let q = count / 4;
            let lanes = [
                (&payload[..s0], q),
                (&payload[s0..s0 + s1], q),
                (&payload[s0 + s1..s0 + s1 + s2], q),
                (&payload[s0 + s1 + s2..], count - 3 * q),
            ];
            let mut ref_out = Vec::with_capacity(count);
            for (lane, n) in lanes {
                ref_out.extend(reference_decode_lane(&lens, lane, n).expect("valid stream"));
            }
            assert_eq!(&ref_out, data);
        }
        assert!(huff_streams > 2, "too few Huffman-mode cases");
    }

    /// Bit-by-bit LSB-first canonical decode of one lane — the oracle.
    fn reference_decode_lane(lens: &[u8; 256], data: &[u8], n: usize) -> Option<Vec<u8>> {
        let codes = canonical_codes(lens);
        let tab: Vec<(u16, u8, u8)> = (0..256usize)
            .filter(|&s| lens[s] > 0)
            .map(|s| (rev_bits(codes[s].0, lens[s]), lens[s], s as u8))
            .collect();
        let total_bits = data.len() * 8;
        let mut out = Vec::with_capacity(n);
        let mut at = 0usize;
        while out.len() < n {
            let mut matched = false;
            for &(rc, l, s) in &tab {
                let l = l as usize;
                if at + l > total_bits {
                    continue;
                }
                let mut v = 0u16;
                for k in 0..l {
                    let bit = (data[(at + k) / 8] >> ((at + k) % 8)) & 1;
                    v |= (bit as u16) << k;
                }
                if v == rc {
                    out.push(s);
                    at += l;
                    matched = true;
                    break;
                }
            }
            if !matched {
                return None;
            }
        }
        Some(out)
    }
}
