//! From-scratch length-limited canonical Huffman coder (paper §3.1).
//!
//! ZipNN's observation: on model byte-group streams, LZ matching finds only
//! "random" short repetitions that *hurt* the entropy stage, so a pure
//! Huffman coder both compresses better and runs faster. This module is the
//! hot path of the whole system.
//!
//! Design (mirrors the zstd Huffman stage the paper built on, reimplemented
//! from scratch):
//! - code lengths from a two-queue Huffman build over the byte histogram,
//!   limited to [`MAX_CODE_LEN`] bits with a Kraft-debt repair pass;
//! - canonical code assignment, so the table serializes as 256 nibble
//!   lengths (128 bytes);
//! - LSB-first bitstream with 64-bit buffered writer/reader;
//! - two-level multi-symbol decode table: an 8-bit primary packing up to
//!   two short symbols per probe, with sentinel-linked 16-entry secondary
//!   blocks for 9–12-bit codes — up to 8 symbols decoded per refill.
//!
//! Stream framing (self-contained; callers may still prefer raw when the
//! encoded form is larger):
//!
//! ```text
//! [mode u8]
//!   mode 0 RAW:    [len u32][bytes]
//!   mode 1 SINGLE: [sym u8][count u32]
//!   mode 2 HUFF:   [table 128B][count u32][s0 u32][s1 u32][s2 u32]
//!                  [paylen u32][4 concatenated lane payloads]
//! ```
//!
//! The payload is **four independent lanes** over the input quarters
//! (lanes 0–2 cover `count/4` bytes each, lane 3 the rest): interleaving
//! four bit-buffer chains gives the out-of-order core ~3× the throughput
//! of one chain, on both sides (the same trick zstd's Huffman uses).

mod decode;
mod encode;
mod lengths;

pub use decode::{
    decompress, decompress_into, decompress_into_cached, DecodeTable, DecodeTableCache,
};
pub use encode::{compress, compress_into, compress_with_hist, compressed_bound, EncodeTable};
pub use lengths::{build_lengths, MAX_CODE_LEN};

/// Stream mode tags.
pub(crate) const MODE_RAW: u8 = 0;
pub(crate) const MODE_SINGLE: u8 = 1;
pub(crate) const MODE_HUFF: u8 = 2;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::byte_histogram;
    use crate::util::Xoshiro256;

    fn roundtrip(data: &[u8]) -> usize {
        let enc = compress(data);
        let dec = decompress(&enc, data.len()).unwrap();
        assert_eq!(dec, data, "roundtrip mismatch (len {})", data.len());
        enc.len()
    }

    #[test]
    fn empty_and_tiny() {
        roundtrip(&[]);
        roundtrip(&[7]);
        roundtrip(&[1, 2]);
        roundtrip(b"abracadabra");
    }

    #[test]
    fn single_symbol_collapses() {
        let data = vec![0xABu8; 1 << 16];
        let n = roundtrip(&data);
        assert!(n < 16, "single-symbol stream must collapse, got {n}");
    }

    #[test]
    fn skewed_exponent_like_stream_compresses_3x() {
        // Reproduce the paper's headline: exponent streams compress ~3x.
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut data = vec![0u8; 1 << 20];
        for b in &mut data {
            // ~12 values covering 99.9%, geometric-ish like Fig. 2
            let u = rng.uniform();
            *b = if u < 0.35 {
                123
            } else if u < 0.62 {
                124
            } else if u < 0.80 {
                122
            } else if u < 0.90 {
                125
            } else if u < 0.95 {
                121
            } else {
                120 + (rng.next_u32() % 12) as u8
            };
        }
        let n = roundtrip(&data);
        let ratio = n as f64 / data.len() as f64;
        assert!(ratio < 0.40, "expected ~3x, got ratio {ratio}");
    }

    #[test]
    fn random_data_does_not_explode() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut data = vec![0u8; 1 << 18];
        rng.fill_bytes(&mut data);
        let n = roundtrip(&data);
        // Huffman on uniform bytes ≈ 100%; header overhead bounded.
        assert!(n <= data.len() + 256, "n={n}");
    }

    #[test]
    fn all_byte_values_present() {
        let mut data: Vec<u8> = (0..=255u8).collect();
        data.extend((0..=255u8).rev());
        roundtrip(&data);
    }

    #[test]
    fn near_optimal_vs_entropy() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut data = vec![0u8; 1 << 20];
        for b in &mut data {
            *b = (rng.normal().abs() * 20.0).min(255.0) as u8;
        }
        let hist = byte_histogram(&data);
        let entropy = crate::fp::stats::shannon_entropy(&hist);
        let n = roundtrip(&data);
        let bits_per_sym = n as f64 * 8.0 / data.len() as f64;
        // Huffman is within 1 bit/symbol of entropy; with header slack:
        assert!(
            bits_per_sym < entropy + 1.1,
            "bits/sym {bits_per_sym} vs entropy {entropy}"
        );
    }

    #[test]
    fn decompress_rejects_truncated() {
        let data = b"the quick brown fox jumps over the lazy dog".repeat(64);
        let enc = compress(&data);
        for cut in [0, 1, 5, enc.len() / 2] {
            assert!(
                decompress(&enc[..cut], data.len()).is_err(),
                "truncation at {cut} must fail"
            );
        }
    }

    #[test]
    fn decompress_rejects_wrong_expected_len() {
        let data = b"hello world hello world".to_vec();
        let enc = compress(&data);
        assert!(decompress(&enc, data.len() + 1).is_err());
    }

    #[test]
    fn decompress_rejects_bad_mode() {
        assert!(decompress(&[9, 0, 0, 0, 0], 4).is_err());
    }

    #[test]
    fn fuzz_roundtrip_many_distributions() {
        let mut rng = Xoshiro256::seed_from_u64(77);
        for trial in 0..60 {
            let len = rng.below(40_000);
            let alphabet = 1 + rng.below(256);
            let skew = 0.5 + rng.uniform() * 3.0;
            let mut data = vec![0u8; len];
            for b in &mut data {
                let u = rng.uniform().powf(skew);
                *b = ((u * alphabet as f64) as usize).min(alphabet - 1) as u8;
            }
            let enc = compress(&data);
            let dec = decompress(&enc, data.len()).unwrap();
            assert_eq!(dec, data, "trial {trial} len {len} alphabet {alphabet}");
        }
    }
}
