//! Huffman encoder: histogram → lengths → canonical codes → four
//! interleaved LSB-first bitstreams, with RAW / SINGLE fallbacks.
//!
//! Four independent streams (zstd's trick) break the single bit-buffer
//! dependency chain: the four encode (and decode) chains run in parallel
//! on an out-of-order core, ~3× faster than one stream.

use super::lengths::{build_lengths, canonical_codes, pack_lens, rev_bits};
use super::{MODE_HUFF, MODE_RAW, MODE_SINGLE};
use crate::stats::byte_histogram;
use crate::util::push_u32_le;

/// Per-symbol encode table: `entry[s] = code | (len << 16)` with the code
/// bit-reversed for LSB-first emission — one load per input byte.
pub struct EncodeTable {
    entry: [u32; 256],
}

impl EncodeTable {
    /// Build from code lengths.
    pub fn from_lengths(lens: &[u8; 256]) -> EncodeTable {
        let codes = canonical_codes(lens);
        let mut entry = [0u32; 256];
        for s in 0..256 {
            let (c, l) = codes[s];
            if l > 0 {
                entry[s] = rev_bits(c, l) as u32 | ((l as u32) << 16);
            }
        }
        EncodeTable { entry }
    }

    /// Expected encoded size in bits for a histogram (header excluded).
    pub fn cost_bits(&self, hist: &[u64; 256]) -> u64 {
        (0..256)
            .map(|s| hist[s] * (self.entry[s] >> 16) as u64)
            .sum()
    }
}

/// Worst-case compressed size for `n` input bytes (RAW fallback + header).
pub fn compressed_bound(n: usize) -> usize {
    n + 5
}

/// Encode one lane (`data`) into a preallocated byte buffer, returning the
/// number of bytes written. Accumulator state lives in locals so the hot
/// loop keeps everything in registers (the Lane-struct version spilled to
/// the stack and ran 2× slower).
#[inline(never)]
fn encode_lane(table: &EncodeTable, data: &[u8], out: &mut [u8]) -> usize {
    let e = &table.entry;
    let mut buf: u64 = 0;
    let mut nbits: u32 = 0;
    let mut idx: usize = 0;
    let mut it = data.chunks_exact(2);
    for pair in &mut it {
        // two symbols (≤ 24 bits) per flush check: after a flush nbits ≤ 31,
        // so the accumulator stays < 55 bits.
        let a = e[pair[0] as usize];
        buf |= ((a & 0xFFFF) as u64) << nbits;
        nbits += a >> 16;
        let b = e[pair[1] as usize];
        buf |= ((b & 0xFFFF) as u64) << nbits;
        nbits += b >> 16;
        if nbits >= 32 {
            out[idx..idx + 4].copy_from_slice(&(buf as u32).to_le_bytes());
            buf >>= 32;
            nbits -= 32;
            idx += 4;
        }
    }
    if let [last] = it.remainder() {
        let a = e[*last as usize];
        buf |= ((a & 0xFFFF) as u64) << nbits;
        nbits += a >> 16;
    }
    while nbits > 0 {
        out[idx] = buf as u8;
        idx += 1;
        buf >>= 8;
        nbits = nbits.saturating_sub(8);
    }
    idx
}

/// Compress `data` into a self-contained Huffman stream.
///
/// Picks SINGLE for ≤1 distinct symbols, and falls back to RAW whenever the
/// encoded form (incl. the 128-byte table) would not beat raw storage.
pub fn compress(data: &[u8]) -> Vec<u8> {
    let hist = byte_histogram(data);
    compress_with_hist(data, &hist)
}

/// [`compress`] with a precomputed histogram (the codec's auto-selector
/// already has one — saves a full pass over the data).
pub fn compress_with_hist(data: &[u8], hist: &[u64; 256]) -> Vec<u8> {
    let mut out = Vec::new();
    compress_into(data, hist, &mut out);
    out
}

/// [`compress_with_hist`] appending into `out`, returning the number of
/// bytes written. The encoded bytes are identical to [`compress`]; the
/// difference is that a caller recycling `out` (the streaming codec's
/// scratch arena) performs no allocations once the buffer has warmed up.
pub fn compress_into(data: &[u8], hist: &[u64; 256], out: &mut Vec<u8>) -> usize {
    let base = out.len();
    if data.is_empty() {
        out.extend_from_slice(&[MODE_RAW, 0, 0, 0, 0]);
        return out.len() - base;
    }
    let Some(lens) = build_lengths(hist) else {
        // exactly one distinct symbol
        out.push(MODE_SINGLE);
        out.push(data[0]);
        push_u32_le(out, data.len() as u32);
        return out.len() - base;
    };
    let table = EncodeTable::from_lengths(&lens);
    let payload_bits = table.cost_bits(hist);
    // 4 lanes each pad to a byte boundary: ≤ 4 bytes slack
    let payload_bound = payload_bits.div_ceil(8) as usize + 4;
    const HDR: usize = 1 + 128 + 4 + 12 + 4;
    if HDR + payload_bound >= compressed_bound(data.len()) {
        out.push(MODE_RAW);
        push_u32_le(out, data.len() as u32);
        out.extend_from_slice(data);
        return out.len() - base;
    }

    // Split into 4 lanes: lanes 0..2 hold q bytes, lane 3 the remainder.
    let n = data.len();
    let q = n / 4;
    let (d0, rest) = data.split_at(q);
    let (d1, rest) = rest.split_at(q);
    let (d2, d3) = rest.split_at(q);
    // Worst case per lane: MAX_CODE_LEN bits/symbol + flush slack.
    let lane_bound =
        |len: usize| len * super::lengths::MAX_CODE_LEN as usize / 8 + 16;
    out.resize(base + HDR + lane_bound(d0.len()) * 3 + lane_bound(d3.len()), 0);
    let mut at = base + HDR;
    let mut lane_lens = [0usize; 4];
    for (li, d) in [d0, d1, d2, d3].into_iter().enumerate() {
        let written = encode_lane(&table, d, &mut out[at..]);
        lane_lens[li] = written;
        at += written;
    }
    let paylen: usize = lane_lens.iter().sum();
    out.truncate(base + HDR + paylen);
    let hdr = &mut out[base..base + HDR];
    hdr[0] = MODE_HUFF;
    hdr[1..129].copy_from_slice(&pack_lens(&lens));
    hdr[129..133].copy_from_slice(&(n as u32).to_le_bytes());
    hdr[133..137].copy_from_slice(&(lane_lens[0] as u32).to_le_bytes());
    hdr[137..141].copy_from_slice(&(lane_lens[1] as u32).to_le_bytes());
    hdr[141..145].copy_from_slice(&(lane_lens[2] as u32).to_le_bytes());
    hdr[145..149].copy_from_slice(&(paylen as u32).to_le_bytes());
    out.len() - base
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn raw_fallback_on_uniform() {
        let mut data = vec![0u8; 4096];
        let mut rng = crate::util::Xoshiro256::seed_from_u64(1);
        rng.fill_bytes(&mut data);
        let enc = compress(&data);
        assert_eq!(enc[0], MODE_RAW);
        assert_eq!(enc.len(), data.len() + 5);
    }

    #[test]
    fn huff_chosen_on_skewed() {
        let data: Vec<u8> = (0..4096).map(|i| if i % 10 == 0 { 1 } else { 0 }).collect();
        let enc = compress(&data);
        assert_eq!(enc[0], MODE_HUFF);
        assert!(enc.len() < data.len() / 2);
    }

    #[test]
    fn cost_bits_accurate() {
        let data = b"aaaabbbcc".to_vec();
        let hist = byte_histogram(&data);
        let lens = build_lengths(&hist).unwrap();
        let t = EncodeTable::from_lengths(&lens);
        // optimal lens: a=1, b=2, c=2 -> 4*1+3*2+2*2 = 14 bits
        assert_eq!(t.cost_bits(&hist), 14);
    }

    #[test]
    fn hist_variant_matches() {
        let data: Vec<u8> = (0..10_000).map(|i| (i % 23) as u8).collect();
        let hist = byte_histogram(&data);
        assert_eq!(compress(&data), compress_with_hist(&data, &hist));
    }

    #[test]
    fn compress_into_appends_identical_bytes() {
        for data in [
            Vec::new(),
            vec![7u8; 100],
            (0..4096u32).map(|i| (i % 7) as u8).collect::<Vec<u8>>(),
            {
                let mut d = vec![0u8; 4096];
                crate::util::Xoshiro256::seed_from_u64(3).fill_bytes(&mut d);
                d // RAW fallback path
            },
        ] {
            let hist = byte_histogram(&data);
            let one_shot = compress(&data);
            let mut out = b"prefix".to_vec();
            let written = compress_into(&data, &hist, &mut out);
            assert_eq!(written, one_shot.len());
            assert_eq!(&out[..6], b"prefix");
            assert_eq!(&out[6..], &one_shot[..]);
        }
    }
}
