//! Code-length computation: two-queue Huffman build + Kraft-debt length
//! limiting, and canonical code assignment shared by encoder and decoder.

/// Maximum code length in bits. 12 keeps the decode table at 4096 entries
//  (8 KiB of u16), resident in L1.
pub const MAX_CODE_LEN: u32 = 12;

/// Compute length-limited Huffman code lengths for a byte histogram.
///
/// Returns `None` when fewer than 2 symbols occur (callers emit RAW/SINGLE
/// modes instead). Lengths are 0 for absent symbols, otherwise in
/// `1..=MAX_CODE_LEN`, and always satisfy Kraft: `Σ 2^-len ≤ 1`.
pub fn build_lengths(hist: &[u64; 256]) -> Option<[u8; 256]> {
    // Gather present symbols sorted by ascending count (stable by symbol).
    // Everything below lives on the stack: this runs once per compressed
    // stream, and the streaming codec's steady state must not allocate.
    let mut syms = [(0u64, 0u16); 256];
    let mut m = 0usize;
    for s in 0..256u16 {
        if hist[s as usize] > 0 {
            syms[m] = (hist[s as usize], s);
            m += 1;
        }
    }
    if m < 2 {
        return None;
    }
    let syms = &mut syms[..m];
    syms.sort_unstable();

    // Two-queue Huffman: leaves (sorted) + internal nodes (created in
    // non-decreasing weight order). parent[] links let us derive depths.
    // total nodes = 2m-1 ≤ 511; the internal-node queue holds ≤ m-1
    // entries and is a fixed ring buffer.
    let total_nodes = 2 * m - 1;
    let mut weight = [0u64; 511];
    let mut parent = [usize::MAX; 511];
    for (i, &(c, _)) in syms.iter().enumerate() {
        weight[i] = c;
    }
    let mut leaf = 0usize; // next unconsumed leaf
    let mut inode = m; // next internal node slot
    let mut ring = [0usize; 256];
    let (mut head, mut tail) = (0usize, 0usize); // ring[head..tail] pending
    for _ in 0..m - 1 {
        let mut pick =
            |weight: &[u64], ring: &[usize; 256], head: &mut usize, tail: &usize| -> usize {
                let take_leaf = if *head == *tail {
                    true
                } else {
                    leaf < m && weight[leaf] <= weight[ring[*head % 256]]
                };
                if take_leaf {
                    leaf += 1;
                    leaf - 1
                } else {
                    let i = ring[*head % 256];
                    *head += 1;
                    i
                }
            };
        let a = pick(&weight, &ring, &mut head, &tail);
        let b = pick(&weight, &ring, &mut head, &tail);
        weight[inode] = weight[a] + weight[b];
        parent[a] = inode;
        parent[b] = inode;
        ring[tail % 256] = inode;
        tail += 1;
        inode += 1;
    }

    // Depth of each leaf: root (last node) has depth 0; children depth+1.
    // Nodes were created in increasing index order with parent > child, so
    // a reverse sweep computes depths in one pass.
    let mut depth = [0u32; 511];
    for i in (0..total_nodes - 1).rev() {
        depth[i] = depth[parent[i]] + 1;
    }

    let mut lens = [0u8; 256];
    for (i, &(_, s)) in syms.iter().enumerate() {
        lens[s as usize] = depth[i].max(1) as u8;
    }

    limit_lengths(&mut lens, hist);
    debug_assert!(kraft_ok(&lens), "Kraft violated");
    Some(lens)
}

/// Clamp lengths to `MAX_CODE_LEN` and repair the Kraft inequality.
///
/// Clamping over-long codes makes the tree over-full (Σ2^-len > 1); we pay
/// the debt back by lengthening the cheapest (lowest-count) symbols among
/// the currently-longest sub-max lengths, then spend any surplus by
/// shortening max-length symbols — the classic zlib/zstd repair.
fn limit_lengths(lens: &mut [u8; 256], hist: &[u64; 256]) {
    let max = MAX_CODE_LEN as u8;
    let budget: i64 = 1 << MAX_CODE_LEN;
    let mut total: i64 = 0;
    for i in 0..256 {
        if lens[i] > 0 {
            if lens[i] > max {
                lens[i] = max;
            }
            total += 1 << (MAX_CODE_LEN - lens[i] as u32);
        }
    }
    // Pay back over-full debt: lengthen symbols, longest lengths first
    // (smallest per-step cost), rarest symbol at that length first.
    while total > budget {
        let mut best: Option<usize> = None;
        let mut best_key = (0u8, u64::MAX);
        for i in 0..256 {
            if lens[i] > 0 && lens[i] < max {
                let key = (lens[i], hist[i]);
                // prefer longer current length; tie-break on lower count
                if best.is_none()
                    || key.0 > best_key.0
                    || (key.0 == best_key.0 && key.1 < best_key.1)
                {
                    best = Some(i);
                    best_key = key;
                }
            }
        }
        let i = best.expect("repairable: not all symbols at max");
        total -= 1 << (MAX_CODE_LEN - lens[i] as u32 - 1);
        lens[i] += 1;
    }
    // Spend surplus: shorten the most frequent symbol whose upgrade still
    // fits; repeat until nothing fits. Each step grows `total`, so this
    // terminates.
    loop {
        let mut best: Option<usize> = None;
        for i in 0..256 {
            if lens[i] > 1 {
                let gain = 1i64 << (MAX_CODE_LEN - lens[i] as u32); // doubles its slot
                if total + gain <= budget && best.is_none_or(|j| hist[i] > hist[j]) {
                    best = Some(i);
                }
            }
        }
        match best {
            Some(i) => {
                total += 1 << (MAX_CODE_LEN - lens[i] as u32);
                lens[i] -= 1;
            }
            None => break,
        }
    }
}

/// Check `Σ 2^-len ≤ 1` (in units of `2^-MAX_CODE_LEN`).
pub(crate) fn kraft_ok(lens: &[u8; 256]) -> bool {
    let mut total: u64 = 0;
    for &l in lens.iter() {
        if l > 0 {
            if l as u32 > MAX_CODE_LEN {
                return false;
            }
            total += 1 << (MAX_CODE_LEN - l as u32);
        }
    }
    total <= (1 << MAX_CODE_LEN)
}

/// Canonical code assignment from lengths (MSB-first convention), returned
/// as `(code, len)` pairs. Symbols are ordered by `(len, symbol)`; codes
/// increase within a length and shift left across lengths.
pub(crate) fn canonical_codes(lens: &[u8; 256]) -> [(u16, u8); 256] {
    let mut count_per_len = [0u16; (MAX_CODE_LEN + 1) as usize];
    for &l in lens.iter() {
        if l > 0 {
            count_per_len[l as usize] += 1;
        }
    }
    let mut next = [0u16; (MAX_CODE_LEN + 2) as usize];
    let mut code = 0u16;
    for l in 1..=MAX_CODE_LEN as usize {
        code = (code + count_per_len[l - 1]) << 1;
        next[l] = code;
    }
    let mut out = [(0u16, 0u8); 256];
    for s in 0..256 {
        let l = lens[s];
        if l > 0 {
            out[s] = (next[l as usize], l);
            next[l as usize] += 1;
        }
    }
    out
}

/// Reverse the low `len` bits of `code` (MSB-canonical -> LSB-first stream).
#[inline]
pub(crate) fn rev_bits(code: u16, len: u8) -> u16 {
    code.reverse_bits() >> (16 - len as u32)
}

/// Pack 256 nibble lengths into 128 bytes (low nibble = even symbol).
pub(crate) fn pack_lens(lens: &[u8; 256]) -> [u8; 128] {
    let mut out = [0u8; 128];
    for i in 0..128 {
        debug_assert!(lens[2 * i] <= 15 && lens[2 * i + 1] <= 15);
        out[i] = lens[2 * i] | (lens[2 * i + 1] << 4);
    }
    out
}

/// Inverse of [`pack_lens`].
pub(crate) fn unpack_lens(packed: &[u8]) -> [u8; 256] {
    let mut lens = [0u8; 256];
    for i in 0..128 {
        lens[2 * i] = packed[i] & 0x0F;
        lens[2 * i + 1] = packed[i] >> 4;
    }
    lens
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    #[test]
    fn two_symbols_get_one_bit() {
        let mut h = [0u64; 256];
        h[10] = 100;
        h[20] = 1;
        let lens = build_lengths(&h).unwrap();
        assert_eq!(lens[10], 1);
        assert_eq!(lens[20], 1);
    }

    #[test]
    fn absent_symbols_zero_length() {
        let mut h = [0u64; 256];
        h[0] = 5;
        h[1] = 5;
        let lens = build_lengths(&h).unwrap();
        for s in 2..256 {
            assert_eq!(lens[s], 0);
        }
    }

    #[test]
    fn single_symbol_returns_none() {
        let mut h = [0u64; 256];
        h[42] = 1000;
        assert!(build_lengths(&h).is_none());
        assert!(build_lengths(&[0u64; 256]).is_none());
    }

    #[test]
    fn extreme_skew_is_length_limited() {
        // Fibonacci-ish counts force unlimited Huffman depth > 12.
        let mut h = [0u64; 256];
        let mut a = 1u64;
        let mut b = 1u64;
        for s in 0..40 {
            h[s] = a;
            let c = a + b;
            a = b;
            b = c;
        }
        let lens = build_lengths(&h).unwrap();
        assert!(lens.iter().all(|&l| l as u32 <= MAX_CODE_LEN));
        assert!(kraft_ok(&lens));
        // most frequent symbol should still get a short code
        assert!(lens[39] <= 2, "lens[39]={}", lens[39]);
    }

    #[test]
    fn kraft_holds_on_random_histograms() {
        let mut rng = Xoshiro256::seed_from_u64(123);
        for _ in 0..200 {
            let mut h = [0u64; 256];
            let m = 2 + rng.below(255);
            for _ in 0..m {
                let s = rng.below(256);
                h[s] += 1 + (rng.next_u64() % 1_000_000);
            }
            if let Some(lens) = build_lengths(&h) {
                assert!(kraft_ok(&lens));
                // all present symbols coded, all absent not
                for s in 0..256 {
                    assert_eq!(h[s] > 0, lens[s] > 0, "symbol {s}");
                }
            }
        }
    }

    #[test]
    fn canonical_codes_are_prefix_free() {
        let mut h = [0u64; 256];
        for s in 0..32 {
            h[s] = (s as u64 + 1) * (s as u64 + 1);
        }
        let lens = build_lengths(&h).unwrap();
        let codes = canonical_codes(&lens);
        for a in 0..256 {
            for b in 0..256 {
                if a == b || lens[a] == 0 || lens[b] == 0 {
                    continue;
                }
                let (ca, la) = codes[a];
                let (cb, lb) = codes[b];
                if la <= lb {
                    // a must not be a prefix of b (MSB-aligned comparison)
                    assert_ne!(
                        cb >> (lb - la),
                        ca,
                        "code {a} (len {la}) prefixes {b} (len {lb})"
                    );
                }
            }
        }
    }

    #[test]
    fn pack_unpack_lens_roundtrip() {
        let mut lens = [0u8; 256];
        for (i, l) in lens.iter_mut().enumerate() {
            *l = (i % 13) as u8;
        }
        assert_eq!(unpack_lens(&pack_lens(&lens)), lens);
    }

    #[test]
    fn rev_bits_examples() {
        assert_eq!(rev_bits(0b1, 1), 0b1);
        assert_eq!(rev_bits(0b10, 2), 0b01);
        assert_eq!(rev_bits(0b110, 3), 0b011);
        assert_eq!(rev_bits(0xFFF, 12), 0xFFF);
    }
}
