//! Bounded-queue worker pipeline with in-order delivery.
//!
//! Work items hold their bytes behind `Arc<[u8]>`, so producers that keep
//! (or fan out) a buffer share it with the pipeline instead of cloning a
//! `Vec<u8>` per item — submission is a pointer move end to end.

use crate::codec::{CodecConfig, Compressor};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pool::WorkerPool;
use crate::error::{Error, Result};
use std::collections::BTreeMap;
use std::sync::mpsc::{channel, sync_channel, Receiver, Sender, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// One unit of work: a named buffer to compress. `data` is shared, not
/// owned: cloning a `WorkItem` (or keeping the buffer on the producer
/// side) never copies the bytes.
#[derive(Debug, Clone)]
pub struct WorkItem {
    /// Item name (tensor/file/checkpoint id).
    pub name: String,
    /// Raw bytes (shared; cheap to clone).
    pub data: Arc<[u8]>,
}

impl WorkItem {
    /// New work item; accepts `Vec<u8>`, `Box<[u8]>` or an existing
    /// `Arc<[u8]>` without copying.
    pub fn new(name: impl Into<String>, data: impl Into<Arc<[u8]>>) -> WorkItem {
        WorkItem { name: name.into(), data: data.into() }
    }
}

/// A finished item, delivered in submission order.
#[derive(Debug)]
pub struct PipelineResult {
    /// Item name.
    pub name: String,
    /// Compressed container.
    pub compressed: Vec<u8>,
    /// Raw length.
    pub raw_len: usize,
    /// Worker compression time (seconds).
    pub secs: f64,
}

/// Builder for a compression pipeline.
pub struct PipelineBuilder {
    cfg: CodecConfig,
    workers: usize,
    queue_depth: usize,
}

impl PipelineBuilder {
    /// New builder around a codec configuration.
    pub fn new(cfg: CodecConfig) -> PipelineBuilder {
        PipelineBuilder { cfg, workers: 1, queue_depth: 4 }
    }

    /// Number of worker threads.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = n.max(1);
        self
    }

    /// Bounded job-queue depth — the backpressure knob.
    pub fn queue_depth(mut self, n: usize) -> Self {
        self.queue_depth = n.max(1);
        self
    }

    /// Start the pipeline on its own private [`WorkerPool`].
    pub fn start(self) -> Pipeline {
        let pool = WorkerPool::new(self.workers);
        let mut p = self.start_on(&pool);
        p.own_pool = Some(pool);
        p
    }

    /// Start the pipeline on a shared [`WorkerPool`]: one worker loop per
    /// pipeline worker (capped at the pool size) is submitted as a
    /// long-running job. The loops exit — freeing the pool threads — once
    /// the pipeline is closed and the job queue drains. The caller keeps
    /// ownership of the pool; [`Pipeline::finish`] does not join it.
    ///
    /// **Sizing caveat:** each loop occupies a pool thread for the
    /// pipeline's whole lifetime. Jobs submitted behind them (including a
    /// second pipeline's loops) wait until this pipeline closes, so a
    /// pool must keep at least one thread free per *concurrently live*
    /// pipeline or a producer blocked in [`Pipeline::submit`] can
    /// deadlock against loops that never get to run. On a closed pool no
    /// loops start and `submit` fails cleanly instead of blocking.
    pub fn start_on(self, pool: &WorkerPool) -> Pipeline {
        let metrics = Arc::new(Metrics::new());
        let (job_tx, job_rx) = sync_channel::<(u64, WorkItem)>(self.queue_depth);
        // The done channel is unbounded on purpose: results wait in the
        // consumer-side reorder buffer, and a bounded done channel would
        // deadlock a producer that submits everything before receiving
        // (workers stuck sending, job queue full, submit blocked).
        let (done_tx, done_rx) = channel::<(u64, PipelineResult)>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        for _ in 0..self.workers.min(pool.threads()) {
            let rx = Arc::clone(&job_rx);
            let tx = done_tx.clone();
            let cfg = self.cfg.clone();
            let metrics = Arc::clone(&metrics);
            if pool.execute(move || worker_loop(&rx, &tx, &cfg, &metrics)).is_err() {
                // Closed pool: with zero loops the job receiver drops and
                // `submit` errors cleanly instead of blocking forever.
                break;
            }
        }
        drop(done_tx);
        Pipeline {
            job_tx: Some(job_tx),
            done_rx,
            reorder: BTreeMap::new(),
            next_deliver: 0,
            next_seq: 0,
            metrics,
            own_pool: None,
        }
    }
}

/// One pipeline worker: pull jobs until the queue closes, compress, send
/// `(seq, result)` to the consumer.
fn worker_loop(
    rx: &Mutex<Receiver<(u64, WorkItem)>>,
    tx: &Sender<(u64, PipelineResult)>,
    cfg: &CodecConfig,
    metrics: &Metrics,
) {
    let comp = Compressor::new(cfg.clone());
    loop {
        let job = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => break, // a sibling worker panicked mid-dequeue
        };
        let (seq, item) = match job {
            Ok(j) => j,
            Err(_) => break, // producers gone
        };
        let t = Instant::now();
        let compressed = comp.compress(&item.data).expect("compress");
        let secs = t.elapsed().as_secs_f64();
        metrics.record(
            item.data.len() as u64,
            compressed.len() as u64,
            (secs * 1e9) as u64,
        );
        let res = PipelineResult {
            name: item.name,
            raw_len: item.data.len(),
            compressed,
            secs,
        };
        if tx.send((seq, res)).is_err() {
            break; // consumer gone
        }
    }
}

/// A running pipeline. Submit items with [`Pipeline::submit`]; collect
/// in-order results with [`Pipeline::recv`] or drain with
/// [`Pipeline::finish`].
pub struct Pipeline {
    job_tx: Option<SyncSender<(u64, WorkItem)>>,
    done_rx: Receiver<(u64, PipelineResult)>,
    reorder: BTreeMap<u64, PipelineResult>,
    next_deliver: u64,
    next_seq: u64,
    metrics: Arc<Metrics>,
    /// The private pool when started via [`PipelineBuilder::start`];
    /// `None` when running on a caller-owned shared pool.
    own_pool: Option<WorkerPool>,
}

impl Pipeline {
    /// Submit an item, blocking when the queue is full (backpressure).
    /// Returns the item's sequence number.
    pub fn submit(&mut self, item: WorkItem) -> Result<u64> {
        let seq = self.next_seq;
        self.next_seq += 1;
        self.metrics
            .items_in
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let tx = self
            .job_tx
            .as_ref()
            .ok_or_else(|| Error::Invalid("pipeline already finished".into()))?;
        // try_send first so genuine backpressure is observable in metrics
        match tx.try_send((seq, item)) {
            Ok(()) => Ok(seq),
            Err(TrySendError::Full(job)) => {
                self.metrics
                    .stalls
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                tx.send(job)
                    .map_err(|_| Error::Invalid("pipeline workers exited".into()))?;
                Ok(seq)
            }
            Err(TrySendError::Disconnected(_)) => {
                Err(Error::Invalid("pipeline workers exited".into()))
            }
        }
    }

    /// Receive the next result in submission order (blocking). Returns
    /// `None` when all submitted items have been delivered and the
    /// pipeline has been closed via [`Pipeline::close`].
    pub fn recv(&mut self) -> Option<PipelineResult> {
        loop {
            if let Some(r) = self.reorder.remove(&self.next_deliver) {
                self.next_deliver += 1;
                return Some(r);
            }
            match self.done_rx.recv() {
                Ok((seq, res)) => {
                    self.reorder.insert(seq, res);
                }
                Err(_) => return None,
            }
        }
    }

    /// Stop accepting new items (lets workers drain and exit).
    pub fn close(&mut self) {
        self.job_tx = None;
    }

    /// Close, drain all remaining results in order, and join the private
    /// pool (a shared pool is left to its owner — the worker loops have
    /// already exited by the time the done channel disconnects).
    pub fn finish(mut self) -> (Vec<PipelineResult>, Arc<Metrics>) {
        self.close();
        let mut out = Vec::new();
        while let Some(r) = self.recv() {
            out.push(r);
        }
        drop(self.own_pool.take());
        (out, self.metrics)
    }

    /// Shared metrics handle.
    pub fn metrics(&self) -> Arc<Metrics> {
        Arc::clone(&self.metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::decompress;
    use crate::fp::DType;
    use crate::util::Xoshiro256;

    fn items(n: usize, bytes: usize, seed: u64) -> Vec<WorkItem> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        (0..n)
            .map(|i| {
                let mut data = Vec::with_capacity(bytes);
                for _ in 0..bytes / 2 {
                    let w = (rng.normal() * 0.03) as f32;
                    data.extend_from_slice(
                        &crate::fp::dtype::f32_to_bf16_bits(w).to_le_bytes(),
                    );
                }
                WorkItem::new(format!("t{i}"), data)
            })
            .collect()
    }

    #[test]
    fn in_order_delivery_multi_worker() {
        let its = items(24, 40_000, 1);
        let originals: Vec<Arc<[u8]>> = its.iter().map(|i| Arc::clone(&i.data)).collect();
        let mut p = PipelineBuilder::new(CodecConfig::for_dtype(DType::BF16))
            .workers(4)
            .queue_depth(2)
            .start();
        for it in its {
            p.submit(it).unwrap();
        }
        let (results, metrics) = p.finish();
        assert_eq!(results.len(), 24);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.name, format!("t{i}"), "order preserved");
            assert_eq!(decompress(&r.compressed).unwrap()[..], originals[i][..]);
        }
        assert_eq!(
            metrics.items_out.load(std::sync::atomic::Ordering::Relaxed),
            24
        );
    }

    #[test]
    fn backpressure_counted() {
        // Tiny queue + many items: the producer must stall at least once.
        let its = items(32, 200_000, 2);
        let mut p = PipelineBuilder::new(CodecConfig::for_dtype(DType::BF16))
            .workers(1)
            .queue_depth(1)
            .start();
        for it in its {
            p.submit(it).unwrap();
        }
        let (results, metrics) = p.finish();
        assert_eq!(results.len(), 32);
        assert!(
            metrics.stalls.load(std::sync::atomic::Ordering::Relaxed) > 0,
            "expected backpressure stalls"
        );
    }

    #[test]
    fn empty_pipeline_finishes() {
        let p = PipelineBuilder::new(CodecConfig::for_dtype(DType::F32)).start();
        let (results, _) = p.finish();
        assert!(results.is_empty());
    }

    #[test]
    fn shared_pool_runs_pipeline_and_outlives_it() {
        let pool = WorkerPool::new(2);
        let its = items(12, 30_000, 3);
        let originals: Vec<Arc<[u8]>> = its.iter().map(|i| Arc::clone(&i.data)).collect();
        let mut p = PipelineBuilder::new(CodecConfig::for_dtype(DType::BF16))
            .workers(2)
            .start_on(&pool);
        for it in its {
            p.submit(it).unwrap();
        }
        let (results, _) = p.finish();
        assert_eq!(results.len(), 12);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(decompress(&r.compressed).unwrap()[..], originals[i][..]);
        }
        // The pool is still usable after the pipeline released its loops.
        let (tx, rx) = std::sync::mpsc::channel();
        pool.execute(move || tx.send(42).unwrap()).unwrap();
        assert_eq!(
            rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(),
            42
        );
        pool.join();
    }

    #[test]
    fn submit_after_close_errors() {
        let mut p = PipelineBuilder::new(CodecConfig::for_dtype(DType::F32)).start();
        p.close();
        assert!(p.submit(WorkItem::new("x", vec![1, 2, 3, 4])).is_err());
    }
}
