//! Lock-free pipeline counters.

use std::sync::atomic::{AtomicU64, Ordering};

/// Shared counters for one pipeline run. All methods are thread-safe;
/// `Relaxed` ordering is sufficient for statistics.
#[derive(Debug, Default)]
pub struct Metrics {
    /// Items submitted.
    pub items_in: AtomicU64,
    /// Items completed.
    pub items_out: AtomicU64,
    /// Raw bytes in.
    pub bytes_in: AtomicU64,
    /// Compressed bytes out.
    pub bytes_out: AtomicU64,
    /// Nanoseconds workers spent compressing.
    pub work_ns: AtomicU64,
    /// Times the producer blocked on a full queue (backpressure events).
    pub stalls: AtomicU64,
}

impl Metrics {
    /// New zeroed metrics.
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// Record a completed item.
    pub fn record(&self, raw: u64, comp: u64, ns: u64) {
        self.items_out.fetch_add(1, Ordering::Relaxed);
        self.bytes_in.fetch_add(raw, Ordering::Relaxed);
        self.bytes_out.fetch_add(comp, Ordering::Relaxed);
        self.work_ns.fetch_add(ns, Ordering::Relaxed);
    }

    /// Compressed-size percentage over everything recorded so far.
    pub fn compressed_pct(&self) -> f64 {
        let raw = self.bytes_in.load(Ordering::Relaxed);
        let comp = self.bytes_out.load(Ordering::Relaxed);
        if raw == 0 {
            0.0
        } else {
            comp as f64 / raw as f64 * 100.0
        }
    }

    /// Aggregate worker throughput in GB/s of raw input.
    pub fn throughput_gbps(&self) -> f64 {
        let ns = self.work_ns.load(Ordering::Relaxed);
        if ns == 0 {
            return 0.0;
        }
        self.bytes_in.load(Ordering::Relaxed) as f64 / ns as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_accumulate() {
        let m = Metrics::new();
        m.record(100, 50, 1000);
        m.record(100, 30, 1000);
        assert_eq!(m.items_out.load(Ordering::Relaxed), 2);
        assert!((m.compressed_pct() - 40.0).abs() < 1e-9);
    }

    #[test]
    fn empty_is_zero() {
        let m = Metrics::new();
        assert_eq!(m.compressed_pct(), 0.0);
        assert_eq!(m.throughput_gbps(), 0.0);
    }
}
