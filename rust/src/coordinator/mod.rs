//! Streaming orchestration (the L3 "coordinator" role): a bounded-queue,
//! multi-worker compression pipeline with backpressure and metrics.
//!
//! The paper's §5.1 design point — fixed-size chunks compressed
//! independently, metadata enabling parallel decode — extends naturally to
//! a *stream* of items (tensors, files, checkpoints). This module provides
//! that stream layer: items flow through a bounded job queue to a worker
//! pool and come out in submission order; a full queue blocks the producer
//! (backpressure) instead of buffering unboundedly.
//!
//! It also owns the process-wide [`shared_pool`]: one lazily-spawned
//! [`WorkerPool`] that long-lived batch work (streaming decode) runs on,
//! so worker threads — and their sticky per-worker scratch state — are
//! created once and stay warm across batches, readers, and files.

pub mod metrics;
pub mod pipeline;
pub mod pool;

pub use metrics::Metrics;
pub use pipeline::{Pipeline, PipelineBuilder, PipelineResult, WorkItem};
pub use pool::{StickyMap, WorkerPool};

use std::sync::OnceLock;

/// Cap on the shared pool's default size; decode batches rarely have more
/// than this many independent chunks in flight.
const SHARED_POOL_MAX: usize = 16;

static SHARED_POOL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide shared worker pool, spawned on first use.
///
/// Sized from `ZIPNN_DECODE_WORKERS` when set, else `ncpu` capped at 16.
/// The pool lives for the rest of the process (its threads idle on an
/// empty queue), which is exactly what keeps per-worker sticky state —
/// decode arenas, Huffman table caches — warm across files.
pub fn shared_pool() -> &'static WorkerPool {
    SHARED_POOL.get_or_init(|| {
        let threads = std::env::var("ZIPNN_DECODE_WORKERS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(2)
                    .min(SHARED_POOL_MAX)
            });
        WorkerPool::new(threads)
    })
}
