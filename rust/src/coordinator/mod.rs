//! Streaming orchestration (the L3 "coordinator" role): a bounded-queue,
//! multi-worker compression pipeline with backpressure and metrics.
//!
//! The paper's §5.1 design point — fixed-size chunks compressed
//! independently, metadata enabling parallel decode — extends naturally to
//! a *stream* of items (tensors, files, checkpoints). This module provides
//! that stream layer: items flow through a bounded job queue to a worker
//! pool and come out in submission order; a full queue blocks the producer
//! (backpressure) instead of buffering unboundedly.

pub mod metrics;
pub mod pipeline;
pub mod pool;

pub use metrics::Metrics;
pub use pipeline::{Pipeline, PipelineBuilder, PipelineResult, WorkItem};
pub use pool::WorkerPool;
