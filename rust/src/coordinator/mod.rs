//! Streaming orchestration (the L3 "coordinator" role): a bounded-queue,
//! multi-worker compression pipeline with backpressure and metrics.
//!
//! The paper's §5.1 design point — fixed-size chunks compressed
//! independently, metadata enabling parallel decode — extends naturally to
//! a *stream* of items (tensors, files, checkpoints). This module provides
//! that stream layer: items flow through a bounded job queue to a worker
//! pool and come out in submission order; a full queue blocks the producer
//! (backpressure) instead of buffering unboundedly.
//!
//! It also owns the process-wide [`shared_pool`]: one lazily-spawned
//! [`WorkerPool`] that long-lived batch work — streaming decode *and* the
//! pooled pipelined encode — runs on, so worker threads and their sticky
//! per-worker scratch state are created once and stay warm across
//! batches, writers, readers, and files.

pub mod metrics;
pub mod pipeline;
pub mod pool;

pub use metrics::Metrics;
pub use pipeline::{Pipeline, PipelineBuilder, PipelineResult, WorkItem};
pub use pool::{StickyMap, WorkerPool};

use std::sync::OnceLock;

/// Cap on the shared pool's default size; codec batches rarely have more
/// than this many independent chunks or super-chunks in flight.
const SHARED_POOL_MAX: usize = 16;

static SHARED_POOL: OnceLock<WorkerPool> = OnceLock::new();

/// The process-wide shared worker pool, spawned on first use.
///
/// `ZIPNN_DECODE_WORKERS` sets the pool size outright (it always has —
/// tests pin small pools with it); otherwise the default is `ncpu`
/// capped at 16. `ZIPNN_ENCODE_WORKERS` can only **raise** that size, so
/// capping encode parallelism never throttles decode as a side effect.
/// The pool lives for the rest of the process (its threads idle on an
/// empty queue), which is exactly what keeps per-worker sticky state —
/// codec scratch arenas, Huffman table caches — warm across files.
pub fn shared_pool() -> &'static WorkerPool {
    SHARED_POOL.get_or_init(|| {
        let base = crate::util::env::decode_workers().unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(2)
                .min(SHARED_POOL_MAX)
        });
        let threads = match crate::util::env::encode_workers() {
            Some(e) => base.max(e),
            None => base,
        };
        WorkerPool::new(threads)
    })
}
