//! Fixed-size shared worker pool.
//!
//! One pool serves many producers: the compression [`crate::coordinator::Pipeline`]
//! runs its worker loops on it, and the hub's readiness reactor
//! ([`crate::hub`]) executes ready PUT/GET/Stat work on it. Threads are
//! spawned once at construction — submitting work never spawns a thread,
//! which is what keeps the hub's thread count flat under thousands of
//! connections.

use crate::error::{Error, Result};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// A fixed pool of worker threads executing submitted closures.
///
/// Dropping the pool closes the job queue and joins every worker, so all
/// submitted jobs run to completion before `drop` returns (graceful
/// drain). Panics inside a job kill only that worker's thread.
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// Spawn a pool of `threads` workers (at least one).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || worker_loop(&rx))
            })
            .collect();
        WorkerPool { tx: Some(tx), handles, threads }
    }

    /// Pool size chosen from the machine: `ncpu`, clamped to `1..=max`.
    pub fn with_default_threads(max: usize) -> WorkerPool {
        let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        WorkerPool::new(ncpu.min(max.max(1)))
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Submit a job. Errors only after [`WorkerPool::close`] (or during
    /// teardown).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) -> Result<()> {
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| Error::Invalid("worker pool closed".into()))?;
        tx.send(Box::new(job))
            .map_err(|_| Error::Invalid("worker pool threads exited".into()))
    }

    /// Stop accepting jobs; queued jobs still run. Workers exit once the
    /// queue drains.
    pub fn close(&mut self) {
        self.tx = None;
    }

    /// Close and join every worker (all queued jobs have run on return).
    pub fn join(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.tx = None;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    loop {
        // Hold the lock only while dequeuing, never while running a job.
        let job = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => break, // a job panicked while dequeuing; bail out
        };
        match job {
            Ok(job) => job(),
            Err(_) => break, // queue closed and drained
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs_before_join() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn execute_after_close_errors() {
        let mut pool = WorkerPool::new(1);
        pool.close();
        assert!(pool.execute(|| {}).is_err());
    }

    #[test]
    fn jobs_run_concurrently_on_many_threads() {
        // Two jobs that must overlap: each waits for the other's signal.
        let pool = WorkerPool::new(2);
        let (tx_a, rx_a) = channel::<()>();
        let (tx_b, rx_b) = channel::<()>();
        pool.execute(move || {
            tx_a.send(()).unwrap();
            rx_b.recv().unwrap();
        })
        .unwrap();
        pool.execute(move || {
            rx_a.recv().unwrap();
            tx_b.send(()).unwrap();
        })
        .unwrap();
        pool.join(); // deadlocks (test timeout) if jobs were serialized
    }

    #[test]
    fn default_threads_bounded() {
        let pool = WorkerPool::with_default_threads(3);
        assert!((1..=3).contains(&pool.threads()));
    }
}
