//! Fixed-size shared worker pool.
//!
//! One pool serves many producers: the compression [`crate::coordinator::Pipeline`]
//! runs its worker loops on it, the hub's readiness reactor
//! ([`crate::hub`]) executes ready PUT/GET/Stat work on it, and the
//! streaming codec runs both its batch decode ([`crate::codec::ZnnReader`])
//! and its pipelined batch encode ([`crate::codec::ZnnWriter`], the
//! one-shot compressor) on the shared pool. Threads are spawned once at
//! construction — submitting work never spawns a thread, which is what
//! keeps the hub's thread count flat under thousands of connections and
//! both codec directions free of per-batch spawns.
//!
//! Every worker additionally owns a **sticky state map** ([`StickyMap`]):
//! a per-thread, type-keyed store that jobs submitted through
//! [`WorkerPool::execute_with_state`] can borrow. State lives as long as
//! the worker, so a codec job's scratch arena (its byte-group buffers,
//! zstd destination scratch, and Huffman decode-table cache) stays warm
//! across batches — and across files — instead of being rebuilt per
//! submission.

use crate::error::{Error, Result};
use std::any::{Any, TypeId};
use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce(&mut StickyMap) + Send + 'static>;

/// Per-worker sticky state: one slot per Rust type, created on first use
/// and kept for the worker's lifetime.
///
/// Jobs from unrelated subsystems share a worker without coordination —
/// each subsystem keys its state by its own type, and a job only ever
/// touches its slot while it runs.
#[derive(Default)]
pub struct StickyMap {
    slots: HashMap<TypeId, Box<dyn Any + Send>>,
}

impl StickyMap {
    /// The worker's slot for `T`, default-constructed on first access.
    pub fn slot<T: Default + Send + 'static>(&mut self) -> &mut T {
        self.slots
            .entry(TypeId::of::<T>())
            .or_insert_with(|| Box::<T>::default())
            .downcast_mut::<T>()
            .expect("sticky slot holds the type it was keyed by")
    }
}

/// A fixed pool of worker threads executing submitted closures.
///
/// Dropping the pool closes the job queue and joins every worker, so all
/// submitted jobs run to completion before `drop` returns (graceful
/// drain). Panics inside a job are caught: the worker survives — a
/// long-lived shared pool (see [`crate::coordinator::shared_pool`]) must
/// not shrink permanently because one submission misbehaved. The
/// worker's sticky state is kept; sticky users must tolerate a value a
/// panicked job left mid-update (the codec's scratch arenas do: every
/// buffer is re-sized before use).
pub struct WorkerPool {
    tx: Option<Sender<Job>>,
    handles: Vec<JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// Spawn a pool of `threads` workers (at least one).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..threads)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || worker_loop(&rx))
            })
            .collect();
        WorkerPool { tx: Some(tx), handles, threads }
    }

    /// Pool size chosen from the machine: `ncpu`, clamped to `1..=max`.
    pub fn with_default_threads(max: usize) -> WorkerPool {
        let ncpu = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2);
        WorkerPool::new(ncpu.min(max.max(1)))
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Submit a job. Errors only after [`WorkerPool::close`] (or during
    /// teardown).
    pub fn execute(&self, job: impl FnOnce() + Send + 'static) -> Result<()> {
        self.execute_with_state(move |_| job())
    }

    /// Submit a job that borrows the executing worker's [`StickyMap`].
    /// Errors only after [`WorkerPool::close`] (or during teardown).
    pub fn execute_with_state(
        &self,
        job: impl FnOnce(&mut StickyMap) + Send + 'static,
    ) -> Result<()> {
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| Error::Invalid("worker pool closed".into()))?;
        tx.send(Box::new(job))
            .map_err(|_| Error::Invalid("worker pool threads exited".into()))
    }

    /// Stop accepting jobs; queued jobs still run. Workers exit once the
    /// queue drains.
    pub fn close(&mut self) {
        self.tx = None;
    }

    /// Close and join every worker (all queued jobs have run on return).
    pub fn join(mut self) {
        self.close_and_join();
    }

    fn close_and_join(&mut self) {
        self.tx = None;
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.close_and_join();
    }
}

fn worker_loop(rx: &Mutex<Receiver<Job>>) {
    let mut sticky = StickyMap::default();
    loop {
        // Hold the lock only while dequeuing, never while running a job.
        let job = match rx.lock() {
            Ok(guard) => guard.recv(),
            Err(_) => break, // lock poisoned; bail out
        };
        match job {
            Ok(job) => {
                // Contain the unwind: one bad job must not cost the pool
                // a thread for the rest of the process.
                let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| job(&mut sticky)));
            }
            Err(_) => break, // queue closed and drained
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_jobs_before_join() {
        let pool = WorkerPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::Relaxed);
            })
            .unwrap();
        }
        pool.join();
        assert_eq!(counter.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn execute_after_close_errors() {
        let mut pool = WorkerPool::new(1);
        pool.close();
        assert!(pool.execute(|| {}).is_err());
    }

    #[test]
    fn jobs_run_concurrently_on_many_threads() {
        // Two jobs that must overlap: each waits for the other's signal.
        let pool = WorkerPool::new(2);
        let (tx_a, rx_a) = channel::<()>();
        let (tx_b, rx_b) = channel::<()>();
        pool.execute(move || {
            tx_a.send(()).unwrap();
            rx_b.recv().unwrap();
        })
        .unwrap();
        pool.execute(move || {
            rx_a.recv().unwrap();
            tx_b.send(()).unwrap();
        })
        .unwrap();
        pool.join(); // deadlocks (test timeout) if jobs were serialized
    }

    #[test]
    fn default_threads_bounded() {
        let pool = WorkerPool::with_default_threads(3);
        assert!((1..=3).contains(&pool.threads()));
    }

    #[test]
    fn panicking_job_does_not_kill_the_worker() {
        // One worker: if the panic killed it, the second job would never
        // run and recv_timeout would fail.
        let pool = WorkerPool::new(1);
        let (tx, rx) = channel::<u32>();
        pool.execute(|| panic!("boom (expected in test output)")).unwrap();
        pool.execute(move || tx.send(7).unwrap()).unwrap();
        assert_eq!(rx.recv_timeout(std::time::Duration::from_secs(10)).unwrap(), 7);
        pool.join();
    }

    #[test]
    fn sticky_state_persists_across_jobs() {
        // One worker: every job sees the same counter slot, so the values
        // observed must be exactly 1..=N in submission order.
        let pool = WorkerPool::new(1);
        let (tx, rx) = channel::<usize>();
        for _ in 0..10 {
            let tx = tx.clone();
            pool.execute_with_state(move |sticky| {
                let counter = sticky.slot::<usize>();
                *counter += 1;
                tx.send(*counter).unwrap();
            })
            .unwrap();
        }
        drop(tx);
        pool.join();
        let seen: Vec<usize> = rx.iter().collect();
        assert_eq!(seen, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn sticky_slots_are_type_keyed() {
        let pool = WorkerPool::new(1);
        let (tx, rx) = channel::<(usize, String)>();
        pool.execute_with_state(move |sticky| {
            *sticky.slot::<usize>() = 7;
            sticky.slot::<String>().push_str("warm");
            tx.send((*sticky.slot::<usize>(), sticky.slot::<String>().clone()))
                .unwrap();
        })
        .unwrap();
        pool.join();
        assert_eq!(rx.recv().unwrap(), (7, "warm".to_string()));
    }
}
