//! Per-stream method auto-selection (paper §3.2 "identifying
//! compressibility" and §4.2 "auto detection of compression method"),
//! plus the per-tensor [`ProfileSelector`] that maps tensor spans to
//! [`CodecProfile`]s for the profiled streaming path.

use crate::codec::index::TensorMeta;
use crate::codec::CodecProfile;
use crate::stats::zero_stats;

/// Compression method applied to one `(chunk, group)` stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Method {
    /// Stored verbatim.
    Raw = 0,
    /// ZipNN Huffman-only entropy coding.
    Huffman = 1,
    /// Zstd (LZ + FSE) — wins on high-zero / long-zero-run streams.
    Zstd = 2,
    /// All-zero stream, truncated to nothing.
    Zero = 3,
}

impl Method {
    /// Stable on-disk tag.
    pub fn tag(self) -> u8 {
        self as u8
    }

    /// Inverse of [`Method::tag`].
    pub fn from_tag(t: u8) -> Option<Method> {
        match t {
            0 => Some(Method::Raw),
            1 => Some(Method::Huffman),
            2 => Some(Method::Zstd),
            3 => Some(Method::Zero),
            _ => None,
        }
    }
}

/// Zstd-over-Huffman trigger: fraction of zero bytes (§4.2, found by the
/// authors' simulation to be the crossover).
pub const ZSTD_ZERO_FRAC: f64 = 0.90;
/// Zstd-over-Huffman trigger: longest zero run as a fraction of the stream.
pub const ZSTD_ZERO_RUN_FRAC: f64 = 0.03;
/// A Huffman probe "fails" when it saves less than this fraction —
/// the stream is ruled incompressible and the group enters skip mode.
pub const PROBE_MIN_SAVING: f64 = 0.02;

/// Per-group probe-and-skip state (§3.2): after an incompressible probe,
/// store Raw without probing for `skip_window` chunks, then probe again to
/// catch behaviour changes between layers.
#[derive(Debug, Clone)]
pub struct AutoPolicy {
    skip_window: usize,
    /// Remaining chunks to skip, per group.
    skip_left: Vec<usize>,
}

/// What the selector decided for one stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Skip mode active: store raw, don't probe.
    SkipRaw,
    /// All-zero stream.
    Zero,
    /// Try Zstd (zero-heavy stream).
    TryZstd,
    /// Try Huffman (the default).
    TryHuffman,
}

impl AutoPolicy {
    /// New policy for `groups` byte groups.
    pub fn new(groups: usize, skip_window: usize) -> AutoPolicy {
        AutoPolicy { skip_window, skip_left: vec![0; groups] }
    }

    /// True when the next stream of `group` should skip straight to Raw
    /// (consumes one skip credit).
    pub fn take_skip(&mut self, group: usize) -> bool {
        if self.skip_left[group] > 0 {
            self.skip_left[group] -= 1;
            true
        } else {
            false
        }
    }

    /// Decide how to handle the next stream of `group`.
    pub fn decide(&mut self, group: usize, data: &[u8]) -> Decision {
        if self.take_skip(group) {
            return Decision::SkipRaw;
        }
        let hist = crate::stats::byte_histogram(data);
        self.decide_with_hist(data, &hist)
    }

    /// [`AutoPolicy::decide`] with a precomputed histogram (skip state must
    /// already have been consumed via [`AutoPolicy::take_skip`]).
    ///
    /// The zero fraction comes straight from `hist[0]`; the longest-run
    /// scan — the only extra pass — runs only when the zero count alone
    /// makes a qualifying run possible.
    pub fn decide_with_hist(&mut self, data: &[u8], hist: &[u64; 256]) -> Decision {
        let n = data.len() as f64;
        let zeros = hist[0] as f64;
        if !data.is_empty() && zeros >= n {
            return Decision::Zero;
        }
        if zeros > ZSTD_ZERO_FRAC * n {
            return Decision::TryZstd;
        }
        // A run of 3% of the chunk requires at least that many zeros.
        if zeros >= ZSTD_ZERO_RUN_FRAC * n
            && zero_stats(data).longest_run as f64 > ZSTD_ZERO_RUN_FRAC * n
        {
            return Decision::TryZstd;
        }
        Decision::TryHuffman
    }

    /// Report a probe outcome so the skip window can engage.
    pub fn report(&mut self, group: usize, raw_len: usize, comp_len: usize) {
        let saved = raw_len.saturating_sub(comp_len) as f64;
        if saved < PROBE_MIN_SAVING * raw_len as f64 {
            self.skip_left[group] = self.skip_window;
        }
    }
}

/// Byte-entropy above which a tensor is ruled incompressible and stored
/// raw (8.0 bits = uniform; Huffman on > 7.8-bit bytes saves < ~2%,
/// matching [`PROBE_MIN_SAVING`]).
pub const RAW_ENTROPY_BITS: f64 = 7.8;
/// At most this many bytes of a tensor are histogrammed when refining
/// its profile from data — plenty for a 256-bin byte histogram.
const REFINE_SAMPLE: usize = 256 * 1024;

/// Maps positions in the raw payload to the [`CodecProfile`] that should
/// compress them: the per-tensor extension of this module's per-stream
/// auto-selection, consumed by `ZnnWriter::with_profiles`.
///
/// Build one with [`ProfileSelector::auto`] (dtype-driven defaults per
/// tensor, optionally refined by each tensor's byte histogram via
/// [`ProfileSelector::auto_with_data`]) or [`ProfileSelector::uniform`],
/// then override individual tensors by name with
/// [`ProfileSelector::with_override`].
#[derive(Debug, Clone)]
pub struct ProfileSelector {
    /// `(start, end, profile)` per tensor, sorted by `start`,
    /// non-overlapping (enforced at construction).
    spans: Vec<(u64, u64, CodecProfile)>,
    /// Names aligned with `spans` (override lookups).
    names: Vec<String>,
    /// Profile for bytes outside every span (padding, headers, and the
    /// whole payload when no spans were given).
    default: CodecProfile,
}

impl ProfileSelector {
    /// One profile for every byte — the degenerate selector that makes
    /// the profiled writer behave like the classic single-profile one.
    pub fn uniform(profile: CodecProfile) -> ProfileSelector {
        ProfileSelector { spans: Vec::new(), names: Vec::new(), default: profile }
    }

    /// Dtype-driven selection: each tensor gets its dtype's default
    /// profile (byte-grouping for multi-byte floats, flat single-stream
    /// for one-byte dtypes). `spans` must be sorted by offset and
    /// non-overlapping — the layout `Model::tensor_spans` produces.
    pub fn auto(spans: &[TensorMeta], default: CodecProfile) -> crate::error::Result<ProfileSelector> {
        Self::build(spans, default, |_, _| None)
    }

    /// [`ProfileSelector::auto`], refined per tensor from its actual
    /// bytes (`data` is the raw payload the spans index into): a
    /// near-uniform byte histogram demotes the tensor to store-raw, a
    /// zero-heavy one to flat Zstd; everything else keeps the dtype
    /// profile. Sampling is capped at 256 KiB per tensor.
    pub fn auto_with_data(
        spans: &[TensorMeta],
        default: CodecProfile,
        data: &[u8],
    ) -> crate::error::Result<ProfileSelector> {
        Self::build(spans, default, |m, base| {
            let start = usize::try_from(m.offset).ok()?;
            let end = usize::try_from(m.offset.checked_add(m.len)?).ok()?;
            let bytes = data.get(start..end)?;
            let cut = bytes.len().min(REFINE_SAMPLE);
            let sample = &bytes[..cut - cut % base.layout.elem.max(1)];
            if sample.is_empty() {
                return None;
            }
            let hist = crate::stats::byte_histogram(sample);
            let n = sample.len() as f64;
            if hist[0] as f64 > ZSTD_ZERO_FRAC * n {
                return Some(CodecProfile::zstd_flat());
            }
            if crate::fp::stats::shannon_entropy(&hist) > RAW_ENTROPY_BITS {
                // Check the *grouped* view before giving up: a bf16
                // tensor is near-uniform as whole elements while its
                // exponent stream alone is highly skewed.
                let skewed_group = crate::fp::stats::group_entropies(sample, base.layout)
                    .iter()
                    .any(|&h| h <= RAW_ENTROPY_BITS);
                if !skewed_group {
                    return Some(CodecProfile::store_raw());
                }
            }
            None
        })
    }

    fn build(
        spans: &[TensorMeta],
        default: CodecProfile,
        refine: impl Fn(&TensorMeta, &CodecProfile) -> Option<CodecProfile>,
    ) -> crate::error::Result<ProfileSelector> {
        let mut out = Vec::with_capacity(spans.len());
        let mut names = Vec::with_capacity(spans.len());
        let mut prev_end = 0u64;
        for m in spans {
            let end = m.offset.checked_add(m.len).ok_or_else(|| {
                crate::error::Error::Invalid(format!("tensor '{}' span overflows", m.name))
            })?;
            if m.offset < prev_end {
                return Err(crate::error::Error::Invalid(format!(
                    "tensor '{}' overlaps the previous span (offset {} < {})",
                    m.name, m.offset, prev_end
                )));
            }
            prev_end = end;
            let base = CodecProfile::for_dtype(m.dtype);
            let profile = refine(m, &base).unwrap_or(base);
            out.push((m.offset, end, profile));
            names.push(m.name.clone());
        }
        Ok(ProfileSelector { spans: out, names, default })
    }

    /// Override one tensor's profile by exact name (no-op when the name
    /// is unknown — overrides are advisory tuning, not addressing).
    pub fn with_override(mut self, name: &str, profile: CodecProfile) -> Self {
        if let Some(i) = self.names.iter().position(|n| n == name) {
            self.spans[i].2 = profile;
        }
        self
    }

    /// Replace the out-of-span default profile.
    pub fn with_default(mut self, profile: CodecProfile) -> Self {
        self.default = profile;
        self
    }

    /// The profile of the tensor named `name`, if known.
    pub fn profile_of(&self, name: &str) -> Option<CodecProfile> {
        let i = self.names.iter().position(|n| n == name)?;
        Some(self.spans[i].2)
    }

    /// The profile governing raw range `[start, end)`: the profile of
    /// the tensor with the largest byte overlap (first-by-offset wins
    /// ties deterministically), or the default when nothing overlaps.
    /// Frame-granular callers pass one frame's raw extent — the dominant
    /// tensor of the frame picks its codec.
    pub fn profile_for_range(&self, start: u64, end: u64) -> CodecProfile {
        let mut best: Option<(u64, CodecProfile)> = None;
        // spans are sorted; find the first that could overlap
        let from = self.spans.partition_point(|&(_, e, _)| e <= start);
        for &(s, e, p) in &self.spans[from..] {
            if s >= end {
                break;
            }
            let overlap = e.min(end).saturating_sub(s.max(start));
            if overlap > best.map_or(0, |(b, _)| b) {
                best = Some((overlap, p));
            }
        }
        best.map_or(self.default, |(_, p)| p)
    }

    /// Every profile this selector can yield: each span's profile plus
    /// the out-of-span default. Used by the writer to validate the whole
    /// selection up front, before any frame is emitted.
    pub fn profiles(&self) -> impl Iterator<Item = &CodecProfile> {
        self.spans.iter().map(|(_, _, p)| p).chain(std::iter::once(&self.default))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_stream_detected() {
        let mut p = AutoPolicy::new(2, 4);
        assert_eq!(p.decide(0, &[0u8; 1000]), Decision::Zero);
    }

    #[test]
    fn high_zero_goes_zstd() {
        let mut p = AutoPolicy::new(1, 4);
        let mut data = vec![0u8; 1000];
        for i in 0..50 {
            data[i * 20] = 7; // 95% zeros, no long runs relative to 3%? runs=19 < 30
        }
        assert_eq!(p.decide(0, &data), Decision::TryZstd);
    }

    #[test]
    fn long_zero_run_goes_zstd() {
        let mut data = vec![1u8; 10_000];
        for b in data.iter_mut().skip(100).take(400) {
            *b = 0; // 4% contiguous zeros
        }
        let mut p = AutoPolicy::new(1, 4);
        assert_eq!(p.decide(0, &data), Decision::TryZstd);
    }

    #[test]
    fn default_is_huffman() {
        let data: Vec<u8> = (0..255u8).cycle().take(5000).collect();
        let mut p = AutoPolicy::new(1, 4);
        assert_eq!(p.decide(0, &data), Decision::TryHuffman);
    }

    #[test]
    fn skip_engages_and_expires() {
        let mut p = AutoPolicy::new(1, 3);
        let data = vec![5u8, 6, 7, 8].repeat(100);
        assert_eq!(p.decide(0, &data), Decision::TryHuffman);
        p.report(0, 1000, 1000); // no saving -> skip mode
        assert_eq!(p.decide(0, &data), Decision::SkipRaw);
        assert_eq!(p.decide(0, &data), Decision::SkipRaw);
        assert_eq!(p.decide(0, &data), Decision::SkipRaw);
        // window exhausted -> probes again
        assert_eq!(p.decide(0, &data), Decision::TryHuffman);
    }

    #[test]
    fn good_probe_keeps_probing() {
        let mut p = AutoPolicy::new(1, 3);
        p.report(0, 1000, 500); // 50% saving
        let data = vec![5u8; 4]; // non-zero
        assert_ne!(p.decide(0, &data), Decision::SkipRaw);
    }

    #[test]
    fn groups_independent() {
        let mut p = AutoPolicy::new(2, 2);
        p.report(0, 100, 100);
        let data = vec![9u8; 100];
        assert_eq!(p.decide(0, &data), Decision::SkipRaw);
        assert_ne!(p.decide(1, &data), Decision::SkipRaw);
    }

    #[test]
    fn method_tags_roundtrip() {
        for m in [Method::Raw, Method::Huffman, Method::Zstd, Method::Zero] {
            assert_eq!(Method::from_tag(m.tag()), Some(m));
        }
        assert_eq!(Method::from_tag(9), None);
    }

    use crate::codec::index::TensorMeta;
    use crate::codec::MethodPolicy;
    use crate::fp::DType;

    fn meta(name: &str, dtype: DType, offset: u64, len: u64) -> TensorMeta {
        TensorMeta { name: name.into(), dtype, offset, len }
    }

    #[test]
    fn selector_picks_dtype_profiles() {
        let spans = [
            meta("trunk", DType::BF16, 0, 1000),
            meta("norm", DType::F32, 1000, 400),
            meta("mlp", DType::F8E4M3, 1400, 600),
        ];
        let sel = ProfileSelector::auto(&spans, CodecProfile::for_dtype(DType::BF16)).unwrap();
        assert_eq!(sel.profile_of("trunk").unwrap().layout.elem, 2);
        assert_eq!(sel.profile_of("norm").unwrap().layout.elem, 4);
        assert_eq!(sel.profile_of("mlp").unwrap().layout.elem, 1);
        assert!(sel.profile_of("nope").is_none());
    }

    #[test]
    fn selector_dominant_overlap() {
        let spans = [
            meta("a", DType::BF16, 0, 100),
            meta("b", DType::F32, 100, 1000),
        ];
        let sel = ProfileSelector::auto(&spans, CodecProfile::store_raw()).unwrap();
        // range [0,150): 100 bytes of a vs 50 of b -> a's profile
        assert_eq!(sel.profile_for_range(0, 150).layout.elem, 2);
        // range [50,300): 50 bytes of a vs 200 of b -> b's profile
        assert_eq!(sel.profile_for_range(50, 300).layout.elem, 4);
        // out of range -> default
        assert_eq!(
            sel.profile_for_range(5000, 6000).policy,
            MethodPolicy::Raw
        );
    }

    #[test]
    fn selector_rejects_overlapping_spans() {
        let spans = [
            meta("a", DType::BF16, 0, 100),
            meta("b", DType::F32, 50, 100),
        ];
        assert!(ProfileSelector::auto(&spans, CodecProfile::for_dtype(DType::BF16)).is_err());
    }

    #[test]
    fn selector_override_by_name() {
        let spans = [meta("a", DType::BF16, 0, 100)];
        let sel = ProfileSelector::auto(&spans, CodecProfile::for_dtype(DType::BF16))
            .unwrap()
            .with_override("a", CodecProfile::store_raw());
        assert_eq!(sel.profile_of("a").unwrap().policy, MethodPolicy::Raw);
    }

    #[test]
    fn data_refinement_demotes_uniform_and_zero_tensors() {
        let mut rng = crate::util::Xoshiro256::seed_from_u64(9);
        let mut data = vec![0u8; 24_000];
        rng.fill_bytes(&mut data[..8000]); // uniform bytes: incompressible
        // [8000,16000): zeros
        for (i, b) in data[16_000..].iter_mut().enumerate() {
            *b = if i % 2 == 0 { 0x3F } else { 0x80 } // skewed bf16-ish
        }
        let spans = [
            meta("rand", DType::I8, 0, 8000),
            meta("zeros", DType::F32, 8000, 8000),
            meta("skewed", DType::BF16, 16_000, 8000),
        ];
        let sel = ProfileSelector::auto_with_data(
            &spans,
            CodecProfile::for_dtype(DType::BF16),
            &data,
        )
        .unwrap();
        assert_eq!(sel.profile_of("rand").unwrap().policy, MethodPolicy::Raw);
        assert_eq!(sel.profile_of("zeros").unwrap().policy, MethodPolicy::Zstd);
        assert_eq!(sel.profile_of("skewed").unwrap().policy, MethodPolicy::Auto);
        assert_eq!(sel.profile_of("skewed").unwrap().layout.elem, 2);
    }
}
