//! Per-stream method auto-selection (paper §3.2 "identifying
//! compressibility" and §4.2 "auto detection of compression method").

use crate::stats::zero_stats;

/// Compression method applied to one `(chunk, group)` stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Method {
    /// Stored verbatim.
    Raw = 0,
    /// ZipNN Huffman-only entropy coding.
    Huffman = 1,
    /// Zstd (LZ + FSE) — wins on high-zero / long-zero-run streams.
    Zstd = 2,
    /// All-zero stream, truncated to nothing.
    Zero = 3,
}

impl Method {
    /// Stable on-disk tag.
    pub fn tag(self) -> u8 {
        self as u8
    }

    /// Inverse of [`Method::tag`].
    pub fn from_tag(t: u8) -> Option<Method> {
        match t {
            0 => Some(Method::Raw),
            1 => Some(Method::Huffman),
            2 => Some(Method::Zstd),
            3 => Some(Method::Zero),
            _ => None,
        }
    }
}

/// Zstd-over-Huffman trigger: fraction of zero bytes (§4.2, found by the
/// authors' simulation to be the crossover).
pub const ZSTD_ZERO_FRAC: f64 = 0.90;
/// Zstd-over-Huffman trigger: longest zero run as a fraction of the stream.
pub const ZSTD_ZERO_RUN_FRAC: f64 = 0.03;
/// A Huffman probe "fails" when it saves less than this fraction —
/// the stream is ruled incompressible and the group enters skip mode.
pub const PROBE_MIN_SAVING: f64 = 0.02;

/// Per-group probe-and-skip state (§3.2): after an incompressible probe,
/// store Raw without probing for `skip_window` chunks, then probe again to
/// catch behaviour changes between layers.
#[derive(Debug, Clone)]
pub struct AutoPolicy {
    skip_window: usize,
    /// Remaining chunks to skip, per group.
    skip_left: Vec<usize>,
}

/// What the selector decided for one stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Skip mode active: store raw, don't probe.
    SkipRaw,
    /// All-zero stream.
    Zero,
    /// Try Zstd (zero-heavy stream).
    TryZstd,
    /// Try Huffman (the default).
    TryHuffman,
}

impl AutoPolicy {
    /// New policy for `groups` byte groups.
    pub fn new(groups: usize, skip_window: usize) -> AutoPolicy {
        AutoPolicy { skip_window, skip_left: vec![0; groups] }
    }

    /// True when the next stream of `group` should skip straight to Raw
    /// (consumes one skip credit).
    pub fn take_skip(&mut self, group: usize) -> bool {
        if self.skip_left[group] > 0 {
            self.skip_left[group] -= 1;
            true
        } else {
            false
        }
    }

    /// Decide how to handle the next stream of `group`.
    pub fn decide(&mut self, group: usize, data: &[u8]) -> Decision {
        if self.take_skip(group) {
            return Decision::SkipRaw;
        }
        let hist = crate::stats::byte_histogram(data);
        self.decide_with_hist(data, &hist)
    }

    /// [`AutoPolicy::decide`] with a precomputed histogram (skip state must
    /// already have been consumed via [`AutoPolicy::take_skip`]).
    ///
    /// The zero fraction comes straight from `hist[0]`; the longest-run
    /// scan — the only extra pass — runs only when the zero count alone
    /// makes a qualifying run possible.
    pub fn decide_with_hist(&mut self, data: &[u8], hist: &[u64; 256]) -> Decision {
        let n = data.len() as f64;
        let zeros = hist[0] as f64;
        if !data.is_empty() && zeros >= n {
            return Decision::Zero;
        }
        if zeros > ZSTD_ZERO_FRAC * n {
            return Decision::TryZstd;
        }
        // A run of 3% of the chunk requires at least that many zeros.
        if zeros >= ZSTD_ZERO_RUN_FRAC * n
            && zero_stats(data).longest_run as f64 > ZSTD_ZERO_RUN_FRAC * n
        {
            return Decision::TryZstd;
        }
        Decision::TryHuffman
    }

    /// Report a probe outcome so the skip window can engage.
    pub fn report(&mut self, group: usize, raw_len: usize, comp_len: usize) {
        let saved = raw_len.saturating_sub(comp_len) as f64;
        if saved < PROBE_MIN_SAVING * raw_len as f64 {
            self.skip_left[group] = self.skip_window;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_stream_detected() {
        let mut p = AutoPolicy::new(2, 4);
        assert_eq!(p.decide(0, &[0u8; 1000]), Decision::Zero);
    }

    #[test]
    fn high_zero_goes_zstd() {
        let mut p = AutoPolicy::new(1, 4);
        let mut data = vec![0u8; 1000];
        for i in 0..50 {
            data[i * 20] = 7; // 95% zeros, no long runs relative to 3%? runs=19 < 30
        }
        assert_eq!(p.decide(0, &data), Decision::TryZstd);
    }

    #[test]
    fn long_zero_run_goes_zstd() {
        let mut data = vec![1u8; 10_000];
        for b in data.iter_mut().skip(100).take(400) {
            *b = 0; // 4% contiguous zeros
        }
        let mut p = AutoPolicy::new(1, 4);
        assert_eq!(p.decide(0, &data), Decision::TryZstd);
    }

    #[test]
    fn default_is_huffman() {
        let data: Vec<u8> = (0..255u8).cycle().take(5000).collect();
        let mut p = AutoPolicy::new(1, 4);
        assert_eq!(p.decide(0, &data), Decision::TryHuffman);
    }

    #[test]
    fn skip_engages_and_expires() {
        let mut p = AutoPolicy::new(1, 3);
        let data = vec![5u8, 6, 7, 8].repeat(100);
        assert_eq!(p.decide(0, &data), Decision::TryHuffman);
        p.report(0, 1000, 1000); // no saving -> skip mode
        assert_eq!(p.decide(0, &data), Decision::SkipRaw);
        assert_eq!(p.decide(0, &data), Decision::SkipRaw);
        assert_eq!(p.decide(0, &data), Decision::SkipRaw);
        // window exhausted -> probes again
        assert_eq!(p.decide(0, &data), Decision::TryHuffman);
    }

    #[test]
    fn good_probe_keeps_probing() {
        let mut p = AutoPolicy::new(1, 3);
        p.report(0, 1000, 500); // 50% saving
        let data = vec![5u8; 4]; // non-zero
        assert_ne!(p.decide(0, &data), Decision::SkipRaw);
    }

    #[test]
    fn groups_independent() {
        let mut p = AutoPolicy::new(2, 2);
        p.report(0, 100, 100);
        let data = vec![9u8; 100];
        assert_eq!(p.decide(0, &data), Decision::SkipRaw);
        assert_ne!(p.decide(1, &data), Decision::SkipRaw);
    }

    #[test]
    fn method_tags_roundtrip() {
        for m in [Method::Raw, Method::Huffman, Method::Zstd, Method::Zero] {
            assert_eq!(Method::from_tag(m.tag()), Some(m));
        }
        assert_eq!(Method::from_tag(9), None);
    }
}
