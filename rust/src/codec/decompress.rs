//! Decompression side of the ZipNN codec: a thin wrapper over the shared
//! chunk-decode core in [`crate::codec::stream`]. Accepts both the
//! one-shot `ZNN1` container (table-driven, chunk-parallel) and the
//! streaming `ZNS1` container (decoded through [`crate::codec::ZnnReader`]).

use crate::codec::checksum64;
use crate::codec::container::{parse, ContainerInfo};
use crate::codec::stream::{decode_chunks, decompress_reader, STREAM_MAGIC};
use crate::error::{Error, Result};

/// Decompress a `.znn` container (single-threaded).
pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    decompress_with(data, 1)
}

/// Parse a one-shot container's metadata without touching the payload.
pub fn inspect(data: &[u8]) -> Result<ContainerInfo> {
    parse(data)
}

/// Decompress with `threads` workers. For `ZNN1`, the metadata table gives
/// every stream's payload offset and every chunk's output placement up
/// front, so chunks decode independently (paper §5.1) as claimed tasks on
/// the process-shared sticky worker pool — the same batch engine the
/// streaming reader and both encode paths run on. `ZNS1` containers are
/// decoded frame by frame.
pub fn decompress_with(data: &[u8], threads: usize) -> Result<Vec<u8>> {
    if data.len() >= 4 && data[0..4] == STREAM_MAGIC {
        return decompress_reader(data, threads);
    }
    let info = parse(data)?;
    let h = &info.header;
    let payload = &data[info.payload_start..];
    let mut out = vec![0u8; h.total_len as usize];
    decode_chunks(h.layout, &info.entries, payload, &mut out, threads.max(1))?;
    if let Some(expect) = h.checksum {
        let got = checksum64(&out);
        if got != expect {
            return Err(Error::Corrupt(format!(
                "checksum mismatch: {got:#018x} != {expect:#018x}"
            )));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::auto::Method;
    use crate::codec::{CodecConfig, Compressor};
    use crate::fp::DType;

    #[test]
    fn inspect_reports_metadata() {
        let data = vec![0u8; 1 << 20];
        let comp = Compressor::new(CodecConfig::for_dtype(DType::BF16))
            .compress(&data)
            .unwrap();
        let info = inspect(&comp).unwrap();
        assert_eq!(info.header.total_len, 1 << 20);
        assert_eq!(info.groups(), 2);
        assert!(info.entries.iter().all(|e| e.method == Method::Zero));
    }

    #[test]
    fn garbage_rejected() {
        assert!(decompress(b"not a container").is_err());
        assert!(decompress(&[]).is_err());
    }
}
