//! Decompression side of the ZipNN codec: a thin wrapper over the shared
//! chunk-decode core in [`crate::codec::stream`]. Accepts both the
//! one-shot `ZNN1` container (table-driven, chunk-parallel) and the
//! streaming `ZNS1` container (decoded through [`crate::codec::ZnnReader`]).

use crate::codec::checksum64;
use crate::codec::container::{parse, ContainerInfo};
use crate::codec::parallel::{run_tasks_with, SUPER_CHUNK};
use crate::codec::stream::{decode_chunk_into, decompress_reader, ScratchArena, STREAM_MAGIC};
use crate::error::{Error, Result};

/// Decompress a `.znn` container (single-threaded).
pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    decompress_with(data, 1)
}

/// Parse a one-shot container's metadata without touching the payload.
pub fn inspect(data: &[u8]) -> Result<ContainerInfo> {
    parse(data)
}

/// Decompress with `threads` workers. For `ZNN1`, the metadata table gives
/// every stream's payload offset and every chunk's output placement up
/// front, so chunks decode independently (paper §5.1). `ZNS1` containers
/// are decoded frame by frame.
pub fn decompress_with(data: &[u8], threads: usize) -> Result<Vec<u8>> {
    if data.len() >= 4 && data[0..4] == STREAM_MAGIC {
        return decompress_reader(data, threads);
    }
    let info = parse(data)?;
    let h = &info.header;
    let groups = info.groups();
    let layout = h.layout;
    let payload = &data[info.payload_start..];
    let n_chunks = h.n_chunks as usize;

    let n_super = n_chunks.div_ceil(SUPER_CHUNK);
    let pieces: Vec<Result<Vec<u8>>> = run_tasks_with(
        n_super,
        threads.max(1),
        ScratchArena::new,
        |arena: &mut ScratchArena, si| {
            let lo = si * SUPER_CHUNK;
            let hi = ((si + 1) * SUPER_CHUNK).min(n_chunks);
            let piece_len: usize = info.entries[lo * groups..hi * groups]
                .iter()
                .map(|e| e.raw_len as usize)
                .sum();
            let mut out = vec![0u8; piece_len];
            let mut at = 0usize;
            for c in lo..hi {
                let es = &info.entries[c * groups..(c + 1) * groups];
                let chunk_raw: usize = es.iter().map(|e| e.raw_len as usize).sum();
                let chunk_comp: usize = es.iter().map(|e| e.comp_len as usize).sum();
                let off = info.offsets[c * groups] as usize;
                let comp = payload
                    .get(off..off + chunk_comp)
                    .ok_or_else(|| Error::Corrupt("payload shorter than table".into()))?;
                decode_chunk_into(layout, es, comp, arena, &mut out[at..at + chunk_raw])?;
                at += chunk_raw;
            }
            Ok(out)
        },
    );

    let mut out = Vec::with_capacity(h.total_len as usize);
    for p in pieces {
        out.extend_from_slice(&p?);
    }
    if out.len() as u64 != h.total_len {
        return Err(Error::Corrupt(format!(
            "decompressed {} bytes, expected {}",
            out.len(),
            h.total_len
        )));
    }
    if let Some(expect) = h.checksum {
        let got = checksum64(&out);
        if got != expect {
            return Err(Error::Corrupt(format!(
                "checksum mismatch: {got:#018x} != {expect:#018x}"
            )));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::auto::Method;
    use crate::codec::{CodecConfig, Compressor};
    use crate::fp::DType;

    #[test]
    fn inspect_reports_metadata() {
        let data = vec![0u8; 1 << 20];
        let comp = Compressor::new(CodecConfig::for_dtype(DType::BF16))
            .compress(&data)
            .unwrap();
        let info = inspect(&comp).unwrap();
        assert_eq!(info.header.total_len, 1 << 20);
        assert_eq!(info.groups(), 2);
        assert!(info.entries.iter().all(|e| e.method == Method::Zero));
    }

    #[test]
    fn garbage_rejected() {
        assert!(decompress(b"not a container").is_err());
        assert!(decompress(&[]).is_err());
    }
}
