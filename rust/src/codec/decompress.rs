//! Decompression side of the ZipNN codec: table-driven, chunk-parallel.

use crate::codec::auto::Method;
use crate::codec::container::{parse, ContainerInfo};
use crate::codec::parallel::{run_tasks, SUPER_CHUNK};
use crate::codec::checksum64;
use crate::error::{Error, Result};
use crate::fp::merge_groups_into;
use crate::huffman;
use crate::lz;

/// Decompress a `.znn` container (single-threaded).
pub fn decompress(data: &[u8]) -> Result<Vec<u8>> {
    decompress_with(data, 1)
}

/// Parse a container's metadata without touching the payload.
pub fn inspect(data: &[u8]) -> Result<ContainerInfo> {
    parse(data)
}

/// Decompress with `threads` workers. The metadata table gives every
/// stream's payload offset and every chunk's output placement up front, so
/// chunks decode independently (paper §5.1).
pub fn decompress_with(data: &[u8], threads: usize) -> Result<Vec<u8>> {
    let info = parse(data)?;
    let h = &info.header;
    let groups = info.groups();
    let payload = &data[info.payload_start..];
    let n_chunks = h.n_chunks as usize;

    let n_super = n_chunks.div_ceil(SUPER_CHUNK);
    let pieces: Vec<Result<Vec<u8>>> = run_tasks(n_super, threads.max(1), |si| {
        let lo = si * SUPER_CHUNK;
        let hi = ((si + 1) * SUPER_CHUNK).min(n_chunks);
        let piece_len: usize = (lo..hi)
            .map(|c| {
                (0..groups)
                    .map(|g| info.entry(c, g).raw_len as usize)
                    .sum::<usize>()
            })
            .sum();
        let mut out = vec![0u8; piece_len];
        // group scratch buffers are reused across the super-chunk
        let mut scratch: Vec<Vec<u8>> = vec![Vec::new(); groups];
        let mut at = 0usize;
        for c in lo..hi {
            let mut chunk_raw = 0usize;
            for (g, buf) in scratch.iter_mut().enumerate() {
                let e = info.entry(c, g);
                let off = info.offsets[c * groups + g] as usize;
                let stream = &payload[off..off + e.comp_len as usize];
                buf.resize(e.raw_len as usize, 0);
                decode_stream_into(e.method, stream, buf)?;
                chunk_raw += e.raw_len as usize;
            }
            let refs: Vec<&[u8]> = scratch.iter().map(|b| b.as_slice()).collect();
            merge_groups_into(&refs, h.layout, &mut out[at..at + chunk_raw])?;
            at += chunk_raw;
        }
        Ok(out)
    });

    let mut out = Vec::with_capacity(h.total_len as usize);
    for p in pieces {
        out.extend_from_slice(&p?);
    }
    if out.len() as u64 != h.total_len {
        return Err(Error::Corrupt(format!(
            "decompressed {} bytes, expected {}",
            out.len(),
            h.total_len
        )));
    }
    if let Some(expect) = h.checksum {
        let got = checksum64(&out);
        if got != expect {
            return Err(Error::Corrupt(format!(
                "checksum mismatch: {got:#018x} != {expect:#018x}"
            )));
        }
    }
    Ok(out)
}

fn decode_stream_into(method: Method, stream: &[u8], out: &mut [u8]) -> Result<()> {
    match method {
        Method::Raw => {
            if stream.len() != out.len() {
                return Err(Error::Corrupt("raw stream length mismatch".into()));
            }
            out.copy_from_slice(stream);
            Ok(())
        }
        Method::Zero => {
            out.fill(0);
            Ok(())
        }
        Method::Huffman => huffman::decompress_into(stream, out),
        Method::Zstd => {
            let dec = lz::zstd_decompress(stream, out.len())?;
            if dec.len() != out.len() {
                return Err(Error::Corrupt("zstd stream length mismatch".into()));
            }
            out.copy_from_slice(&dec);
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{CodecConfig, Compressor};
    use crate::fp::DType;

    #[test]
    fn inspect_reports_metadata() {
        let data = vec![0u8; 1 << 20];
        let comp = Compressor::new(CodecConfig::for_dtype(DType::BF16))
            .compress(&data)
            .unwrap();
        let info = inspect(&comp).unwrap();
        assert_eq!(info.header.total_len, 1 << 20);
        assert_eq!(info.groups(), 2);
        assert!(info.entries.iter().all(|e| e.method == Method::Zero));
    }

    #[test]
    fn garbage_rejected() {
        assert!(decompress(b"not a container").is_err());
        assert!(decompress(&[]).is_err());
    }
}
