//! The ZipNN codec (paper §3, §5.1): chunked, byte-grouped, entropy-coded
//! compression of model tensor bytes.
//!
//! A buffer is cut into fixed-size **chunks** (default 256 KiB). Each chunk
//! is split into per-byte-position **groups** (exponent group first), and
//! every `(chunk, group)` stream is compressed independently with an
//! auto-selected method — Huffman (the common case), Zstd (high-zero
//! streams, deltas), Zero (all-zero truncation) or Raw (incompressible,
//! with a probe-and-skip heuristic so we stop *trying* on streams that
//! repeatedly fail, §3.2). Fixed raw chunk sizes plus a per-stream metadata
//! table make both directions embarrassingly parallel (§5.1).
//!
//! Both directions are also **streamable**: [`stream::ZnnWriter`] /
//! [`stream::ZnnReader`] compress and decompress chunk-incrementally over
//! `std::io` adapters without materializing either side, backed by a
//! reusable per-worker [`stream::ScratchArena`]. The one-shot
//! [`Compressor`] / [`decompress`] entry points are thin wrappers over the
//! same super-chunk core.

pub mod auto;
pub mod compress;
pub mod container;
pub mod decompress;
pub mod index;
pub mod stream;

pub use auto::{AutoPolicy, Method};
pub use compress::{compress_with_report, Compressor, GroupReport};
pub use container::{ContainerHeader, ContainerInfo, StreamEntry};
pub use decompress::{decompress, decompress_with, inspect};
pub use index::{ContainerKind, TensorIndex, TensorMeta};
pub use stream::{
    decompress_path, decompress_reader, ByteSource, MappedBytes, ScratchArena, ZnnReader,
    ZnnWriter, STREAM_MAGIC, SUPER_CHUNK,
};

use crate::fp::{DType, GroupLayout};

/// Default chunk size (paper §5.1).
pub const DEFAULT_CHUNK_SIZE: usize = 256 * 1024;

/// Compression method selection policy for a whole run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodPolicy {
    /// Full ZipNN auto-selection (per-stream Huffman/Zstd/Zero/Raw).
    Auto,
    /// Force Huffman (with Raw fallback only when Huffman expands).
    Huffman,
    /// Force Zstd on every stream (the "EE+Zstd" baseline of Table 3).
    Zstd,
    /// Store raw (identity; for measurement plumbing).
    Raw,
}

/// Codec configuration.
#[derive(Debug, Clone)]
pub struct CodecConfig {
    /// Byte-group layout (element size + exponent group). `GroupLayout::flat()`
    /// disables exponent extraction (the "vanilla" baselines).
    pub layout: GroupLayout,
    /// Raw bytes per chunk. Must be a multiple of `layout.elem`.
    pub chunk_size: usize,
    /// Method policy.
    pub policy: MethodPolicy,
    /// Zstd level for Zstd-method streams (paper uses default = 3).
    pub zstd_level: i32,
    /// After a stream of some group probes incompressible, skip the probe
    /// (store Raw directly) for this many subsequent chunks of that group.
    pub skip_window: usize,
    /// Worker threads for chunk-parallel compress/decompress (1 = inline).
    pub threads: usize,
    /// Record a (cheap) checksum of the raw buffer for integrity checking.
    pub checksum: bool,
}

impl CodecConfig {
    /// ZipNN defaults for a dtype: byte grouping on, auto methods,
    /// 256 KiB chunks, probe-skip window of 8.
    pub fn for_dtype(d: DType) -> CodecConfig {
        CodecConfig {
            layout: GroupLayout::for_dtype(d),
            chunk_size: DEFAULT_CHUNK_SIZE,
            policy: MethodPolicy::Auto,
            zstd_level: 3,
            skip_window: 8,
            threads: 1,
            checksum: true,
        }
    }

    /// Vanilla baseline: no grouping, Zstd everywhere.
    pub fn vanilla_zstd() -> CodecConfig {
        CodecConfig {
            layout: GroupLayout::flat(),
            chunk_size: DEFAULT_CHUNK_SIZE,
            policy: MethodPolicy::Zstd,
            zstd_level: 3,
            skip_window: 0,
            threads: 1,
            checksum: true,
        }
    }

    /// Builder-style: set thread count.
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Builder-style: set method policy.
    pub fn with_policy(mut self, p: MethodPolicy) -> Self {
        self.policy = p;
        self
    }

    /// Builder-style: set chunk size (clamped to a layout multiple).
    pub fn with_chunk_size(mut self, n: usize) -> Self {
        let e = self.layout.elem;
        self.chunk_size = (n.max(e) / e) * e;
        self
    }
}

/// Cheap 64-bit checksum: wrapping sum of little-endian words mixed with
/// length. Fast enough to be on by default; catches the corruption classes
/// the tests inject (bit flips, truncation, reordering).
pub fn checksum64(data: &[u8]) -> u64 {
    let mut acc: u64 = 0x9E37_79B9_7F4A_7C15 ^ (data.len() as u64);
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes(c.try_into().unwrap());
        acc = acc.wrapping_add(w).rotate_left(17).wrapping_mul(0xA24B_AED4_963E_E407);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut b = [0u8; 8];
        b[..rem.len()].copy_from_slice(rem);
        acc = acc.wrapping_add(u64::from_le_bytes(b)).rotate_left(17);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn gaussian_bf16(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut out = Vec::with_capacity(2 * n);
        for _ in 0..n {
            let w = (rng.normal() * 0.02) as f32;
            out.extend_from_slice(&crate::fp::dtype::f32_to_bf16_bits(w).to_le_bytes());
        }
        out
    }

    #[test]
    fn roundtrip_bf16_model() {
        let raw = gaussian_bf16(500_000, 1);
        let cfg = CodecConfig::for_dtype(DType::BF16);
        let comp = Compressor::new(cfg).compress(&raw).unwrap();
        let back = decompress(&comp).unwrap();
        assert_eq!(back, raw);
        // paper headline: BF16 models compress to ~66%
        let ratio = comp.len() as f64 / raw.len() as f64;
        assert!(ratio < 0.72, "ratio={ratio}");
        assert!(ratio > 0.55, "ratio={ratio} suspiciously small for regular bf16");
    }

    #[test]
    fn roundtrip_empty_and_small() {
        for n in [0usize, 1, 2, 100, 4096] {
            let raw = gaussian_bf16(n, 2);
            let cfg = CodecConfig::for_dtype(DType::BF16);
            let comp = Compressor::new(cfg).compress(&raw).unwrap();
            assert_eq!(decompress(&comp).unwrap(), raw, "n={n}");
        }
    }

    #[test]
    fn roundtrip_odd_tail_chunk() {
        // buffer not a multiple of chunk size
        let raw = gaussian_bf16(DEFAULT_CHUNK_SIZE / 2 + 12_345, 3);
        let cfg = CodecConfig::for_dtype(DType::BF16);
        let comp = Compressor::new(cfg).compress(&raw).unwrap();
        assert_eq!(decompress(&comp).unwrap(), raw);
    }

    #[test]
    fn zipnn_beats_vanilla_zstd_on_bf16() {
        let raw = gaussian_bf16(1_000_000, 4);
        let zipnn = Compressor::new(CodecConfig::for_dtype(DType::BF16))
            .compress(&raw)
            .unwrap();
        let vanilla = Compressor::new(CodecConfig::vanilla_zstd())
            .compress(&raw)
            .unwrap();
        assert!(
            (zipnn.len() as f64) < vanilla.len() as f64 * 0.95,
            "zipnn={} vanilla={}",
            zipnn.len(),
            vanilla.len()
        );
        assert_eq!(decompress(&vanilla).unwrap(), raw);
    }

    #[test]
    fn all_zero_buffer_collapses() {
        let raw = vec![0u8; 1 << 20];
        let cfg = CodecConfig::for_dtype(DType::F32);
        let comp = Compressor::new(cfg).compress(&raw).unwrap();
        assert!(comp.len() < 1024, "len={}", comp.len());
        assert_eq!(decompress(&comp).unwrap(), raw);
    }

    #[test]
    fn random_buffer_stored_near_raw() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut raw = vec![0u8; 1 << 20];
        rng.fill_bytes(&mut raw);
        let cfg = CodecConfig::for_dtype(DType::F32);
        let comp = Compressor::new(cfg).compress(&raw).unwrap();
        assert!(comp.len() < raw.len() + raw.len() / 100 + 1024);
        assert_eq!(decompress(&comp).unwrap(), raw);
    }

    #[test]
    fn corruption_detected() {
        let raw = gaussian_bf16(300_000, 6);
        let cfg = CodecConfig::for_dtype(DType::BF16);
        let mut comp = Compressor::new(cfg).compress(&raw).unwrap();
        // flip a payload byte near the end
        let n = comp.len();
        comp[n - 3] ^= 0x40;
        match decompress(&comp) {
            Err(_) => {}
            Ok(back) => assert_ne!(back, raw, "corruption must not roundtrip silently"),
        }
    }

    #[test]
    fn truncation_detected() {
        let raw = gaussian_bf16(100_000, 7);
        let cfg = CodecConfig::for_dtype(DType::BF16);
        let comp = Compressor::new(cfg).compress(&raw).unwrap();
        for cut in [0, 3, 16, comp.len() / 2, comp.len() - 1] {
            assert!(decompress(&comp[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn parallel_threads_equal_serial() {
        let raw = gaussian_bf16(800_000, 8);
        let serial = Compressor::new(CodecConfig::for_dtype(DType::BF16))
            .compress(&raw)
            .unwrap();
        let par = Compressor::new(CodecConfig::for_dtype(DType::BF16).with_threads(4))
            .compress(&raw)
            .unwrap();
        assert_eq!(serial, par, "parallel output must be byte-identical");
        assert_eq!(decompress_with(&par, 4).unwrap(), raw);
    }

    #[test]
    fn checksum_mixes() {
        assert_ne!(checksum64(b"abc"), checksum64(b"abd"));
        assert_ne!(checksum64(b"abc"), checksum64(b"ab"));
        assert_ne!(checksum64(&[0u8; 8]), checksum64(&[0u8; 16]));
    }
}
