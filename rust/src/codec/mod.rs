//! The ZipNN codec (paper §3, §5.1): chunked, byte-grouped, entropy-coded
//! compression of model tensor bytes.
//!
//! A buffer is cut into fixed-size **chunks** (default 256 KiB). Each chunk
//! is split into per-byte-position **groups** (exponent group first), and
//! every `(chunk, group)` stream is compressed independently with an
//! auto-selected method — Huffman (the common case), Zstd (high-zero
//! streams, deltas), Zero (all-zero truncation) or Raw (incompressible,
//! with a probe-and-skip heuristic so we stop *trying* on streams that
//! repeatedly fail, §3.2). Fixed raw chunk sizes plus a per-stream metadata
//! table make both directions embarrassingly parallel (§5.1).
//!
//! Both directions are also **streamable**: [`stream::ZnnWriter`] /
//! [`stream::ZnnReader`] compress and decompress chunk-incrementally over
//! `std::io` adapters without materializing either side, backed by a
//! reusable per-worker [`stream::ScratchArena`]. The one-shot
//! [`Compressor`] / [`decompress`] entry points are thin wrappers over the
//! same super-chunk core.

pub mod auto;
pub mod compress;
pub mod container;
pub mod decompress;
pub mod index;
pub mod stream;

pub use auto::{AutoPolicy, Method, ProfileSelector};
pub use compress::{compress_with_report, Compressor, GroupReport};
pub use container::{ContainerHeader, ContainerInfo, StreamEntry};
pub use decompress::{decompress, decompress_with, inspect};
pub use index::{ContainerKind, TensorIndex, TensorMeta};
pub use stream::{
    decompress_path, decompress_reader, ByteSource, MappedBytes, SalvageReport, ScratchArena,
    ZnnReader, ZnnReaderBuilder, ZnnWriter, STREAM_MAGIC, SUPER_CHUNK,
};

use crate::fp::{DType, GroupLayout};

/// Default chunk size (paper §5.1).
pub const DEFAULT_CHUNK_SIZE: usize = 256 * 1024;

/// Compression method selection policy for a whole run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MethodPolicy {
    /// Full ZipNN auto-selection (per-stream Huffman/Zstd/Zero/Raw).
    Auto,
    /// Force Huffman (with Raw fallback only when Huffman expands).
    Huffman,
    /// Force Zstd on every stream (the "EE+Zstd" baseline of Table 3).
    Zstd,
    /// Store raw (identity; for measurement plumbing).
    Raw,
}

/// *How bytes compress*: the per-tensor (or per-frame) half of the old
/// monolithic [`CodecConfig`]. A profile is everything the decoder needs
/// to reverse — layout — plus the encode-side method knobs; it carries
/// **no** run-wide execution state (threads, checksum, chunk size — see
/// [`RunConfig`]). Profiles are what a
/// [`auto::ProfileSelector`] hands out per tensor and what a profiled
/// `ZNS1` frame records on disk.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CodecProfile {
    /// Byte-group layout (element size + exponent group).
    /// `GroupLayout::flat()` disables exponent extraction.
    pub layout: GroupLayout,
    /// Method policy.
    pub policy: MethodPolicy,
    /// Zstd level for Zstd-method streams (paper uses default = 3).
    pub zstd_level: i32,
    /// After a stream of some group probes incompressible, skip the probe
    /// (store Raw directly) for this many subsequent chunks of that group.
    pub skip_window: usize,
}

impl CodecProfile {
    /// ZipNN defaults for a dtype: byte grouping on, auto methods,
    /// probe-skip window of 8.
    pub fn for_dtype(d: DType) -> CodecProfile {
        CodecProfile {
            layout: GroupLayout::for_dtype(d),
            policy: MethodPolicy::Auto,
            zstd_level: 3,
            skip_window: 8,
        }
    }

    /// Huffman-only over ungrouped bytes — the fp8/int8 shape, where the
    /// single byte already carries the skewed exponent bits.
    pub fn huffman_flat() -> CodecProfile {
        CodecProfile {
            layout: GroupLayout::flat(),
            policy: MethodPolicy::Huffman,
            zstd_level: 3,
            skip_window: 8,
        }
    }

    /// Zstd over ungrouped bytes (zero-heavy or delta-like tensors).
    pub fn zstd_flat() -> CodecProfile {
        CodecProfile {
            layout: GroupLayout::flat(),
            policy: MethodPolicy::Zstd,
            zstd_level: 3,
            skip_window: 0,
        }
    }

    /// Store raw (near-uniform bytes that never compress).
    pub fn store_raw() -> CodecProfile {
        CodecProfile {
            layout: GroupLayout::flat(),
            policy: MethodPolicy::Raw,
            zstd_level: 3,
            skip_window: 0,
        }
    }

    /// Builder-style: set method policy.
    pub fn with_policy(mut self, p: MethodPolicy) -> Self {
        self.policy = p;
        self
    }

    /// Builder-style: set the zstd level.
    pub fn with_zstd_level(mut self, level: i32) -> Self {
        self.zstd_level = level;
        self
    }
}

/// *How the run executes*: the run-wide half of the old monolithic
/// [`CodecConfig`] — settings that apply to a whole container regardless
/// of which [`CodecProfile`] each tensor gets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RunConfig {
    /// Raw bytes per chunk. Must be a multiple of every profile's
    /// `layout.elem`.
    pub chunk_size: usize,
    /// Worker threads for chunk-parallel compress/decompress (1 = inline).
    pub threads: usize,
    /// Record a (cheap) checksum of the raw buffer for integrity checking.
    pub checksum: bool,
}

impl Default for RunConfig {
    fn default() -> RunConfig {
        RunConfig { chunk_size: DEFAULT_CHUNK_SIZE, threads: 1, checksum: true }
    }
}

/// Codec configuration: one [`CodecProfile`] plus one [`RunConfig`],
/// kept as a flat struct for source compatibility. Prefer
/// [`CodecConfig::builder`] for new code — it validates the
/// profile/chunk-size combination regardless of the order the knobs are
/// set in, which the legacy `with_*` chain does not (see
/// [`CodecConfig::with_chunk_size`]).
#[derive(Debug, Clone)]
pub struct CodecConfig {
    /// Byte-group layout (element size + exponent group). `GroupLayout::flat()`
    /// disables exponent extraction (the "vanilla" baselines).
    pub layout: GroupLayout,
    /// Raw bytes per chunk. Must be a multiple of `layout.elem`.
    pub chunk_size: usize,
    /// Method policy.
    pub policy: MethodPolicy,
    /// Zstd level for Zstd-method streams (paper uses default = 3).
    pub zstd_level: i32,
    /// After a stream of some group probes incompressible, skip the probe
    /// (store Raw directly) for this many subsequent chunks of that group.
    pub skip_window: usize,
    /// Worker threads for chunk-parallel compress/decompress (1 = inline).
    pub threads: usize,
    /// Record a (cheap) checksum of the raw buffer for integrity checking.
    pub checksum: bool,
}

impl CodecConfig {
    /// ZipNN defaults for a dtype: byte grouping on, auto methods,
    /// 256 KiB chunks, probe-skip window of 8.
    pub fn for_dtype(d: DType) -> CodecConfig {
        CodecConfig::from_parts(CodecProfile::for_dtype(d), RunConfig::default())
    }

    /// Vanilla baseline: no grouping, Zstd everywhere.
    pub fn vanilla_zstd() -> CodecConfig {
        CodecConfig::from_parts(CodecProfile::zstd_flat(), RunConfig::default())
    }

    /// Assemble a config from its two halves. No validation — pair with
    /// [`CodecConfig::builder`] when the inputs aren't known-good.
    pub fn from_parts(profile: CodecProfile, run: RunConfig) -> CodecConfig {
        CodecConfig {
            layout: profile.layout,
            chunk_size: run.chunk_size,
            policy: profile.policy,
            zstd_level: profile.zstd_level,
            skip_window: profile.skip_window,
            threads: run.threads,
            checksum: run.checksum,
        }
    }

    /// The per-tensor half of this config.
    pub fn profile(&self) -> CodecProfile {
        CodecProfile {
            layout: self.layout,
            policy: self.policy,
            zstd_level: self.zstd_level,
            skip_window: self.skip_window,
        }
    }

    /// The run-wide half of this config.
    pub fn run(&self) -> RunConfig {
        RunConfig {
            chunk_size: self.chunk_size,
            threads: self.threads,
            checksum: self.checksum,
        }
    }

    /// An order-insensitive, validating builder. Unlike the legacy
    /// `with_*` chain, every knob can be set in any order; alignment of
    /// `chunk_size` against the **final** layout is checked once at
    /// [`CodecConfigBuilder::build`].
    pub fn builder() -> CodecConfigBuilder {
        CodecConfigBuilder::default()
    }

    /// Builder-style: set thread count.
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Builder-style: set method policy.
    pub fn with_policy(mut self, p: MethodPolicy) -> Self {
        self.policy = p;
        self
    }

    /// Builder-style: set chunk size (clamped to a layout multiple).
    ///
    /// **Pitfall** (the reason [`CodecConfig::builder`] exists): the
    /// clamp uses the layout at the time of *this* call, so assigning
    /// `layout` afterwards can leave `chunk_size` misaligned to the new
    /// `layout.elem`. The builder validates against the final layout
    /// instead.
    pub fn with_chunk_size(mut self, n: usize) -> Self {
        let e = self.layout.elem;
        self.chunk_size = (n.max(e) / e) * e;
        self
    }
}

/// Order-insensitive builder for [`CodecConfig`]; see
/// [`CodecConfig::builder`]. Knobs default to the BF16 profile and
/// [`RunConfig::default`]; `build` validates the combination as a whole.
#[derive(Debug, Clone)]
pub struct CodecConfigBuilder {
    profile: CodecProfile,
    run: RunConfig,
}

impl Default for CodecConfigBuilder {
    fn default() -> CodecConfigBuilder {
        CodecConfigBuilder {
            profile: CodecProfile::for_dtype(DType::BF16),
            run: RunConfig::default(),
        }
    }
}

impl CodecConfigBuilder {
    /// Start from a dtype's default profile (layout + auto methods).
    pub fn dtype(mut self, d: DType) -> Self {
        self.profile = CodecProfile::for_dtype(d);
        self
    }

    /// Replace the whole per-tensor profile.
    pub fn profile(mut self, p: CodecProfile) -> Self {
        self.profile = p;
        self
    }

    /// Set the byte-group layout.
    pub fn layout(mut self, l: GroupLayout) -> Self {
        self.profile.layout = l;
        self
    }

    /// Set the method policy.
    pub fn policy(mut self, p: MethodPolicy) -> Self {
        self.profile.policy = p;
        self
    }

    /// Set the zstd level.
    pub fn zstd_level(mut self, level: i32) -> Self {
        self.profile.zstd_level = level;
        self
    }

    /// Set the incompressible-probe skip window.
    pub fn skip_window(mut self, n: usize) -> Self {
        self.profile.skip_window = n;
        self
    }

    /// Set the raw chunk size (validated against the final layout at
    /// [`CodecConfigBuilder::build`], **not** clamped here).
    pub fn chunk_size(mut self, n: usize) -> Self {
        self.run.chunk_size = n;
        self
    }

    /// Set the worker thread count.
    pub fn threads(mut self, n: usize) -> Self {
        self.run.threads = n.max(1);
        self
    }

    /// Enable or disable the raw-buffer checksum.
    pub fn checksum(mut self, on: bool) -> Self {
        self.run.checksum = on;
        self
    }

    /// Validate and assemble. Errors (instead of silently clamping) when
    /// the chunk size is zero, exceeds the container limit, or is not a
    /// multiple of the **final** layout's element size — regardless of
    /// the order `chunk_size`/`layout` were set in.
    pub fn build(self) -> crate::error::Result<CodecConfig> {
        let CodecProfile { layout, .. } = self.profile;
        if layout.elem == 0 || layout.elem > 16 || layout.exp_group >= layout.elem {
            return Err(crate::error::Error::Invalid(format!(
                "bad group layout: elem={} exp_group={}",
                layout.elem, layout.exp_group
            )));
        }
        let cs = self.run.chunk_size;
        if cs == 0 || cs as u64 > container::MAX_CHUNK_SIZE as u64 {
            return Err(crate::error::Error::Invalid(format!(
                "chunk_size {cs} out of range"
            )));
        }
        if cs % layout.elem != 0 {
            return Err(crate::error::Error::Invalid(format!(
                "chunk_size {cs} is not a multiple of the element size {}",
                layout.elem
            )));
        }
        Ok(CodecConfig::from_parts(self.profile, self.run))
    }
}

/// Cheap 64-bit checksum: wrapping sum of little-endian words mixed with
/// length. Fast enough to be on by default; catches the corruption classes
/// the tests inject (bit flips, truncation, reordering).
pub fn checksum64(data: &[u8]) -> u64 {
    let mut acc: u64 = 0x9E37_79B9_7F4A_7C15 ^ (data.len() as u64);
    let mut chunks = data.chunks_exact(8);
    for c in &mut chunks {
        let w = u64::from_le_bytes(c.try_into().unwrap());
        acc = acc.wrapping_add(w).rotate_left(17).wrapping_mul(0xA24B_AED4_963E_E407);
    }
    let rem = chunks.remainder();
    if !rem.is_empty() {
        let mut b = [0u8; 8];
        b[..rem.len()].copy_from_slice(rem);
        acc = acc.wrapping_add(u64::from_le_bytes(b)).rotate_left(17);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn gaussian_bf16(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut out = Vec::with_capacity(2 * n);
        for _ in 0..n {
            let w = (rng.normal() * 0.02) as f32;
            out.extend_from_slice(&crate::fp::dtype::f32_to_bf16_bits(w).to_le_bytes());
        }
        out
    }

    #[test]
    fn roundtrip_bf16_model() {
        let raw = gaussian_bf16(500_000, 1);
        let cfg = CodecConfig::for_dtype(DType::BF16);
        let comp = Compressor::new(cfg).compress(&raw).unwrap();
        let back = decompress(&comp).unwrap();
        assert_eq!(back, raw);
        // paper headline: BF16 models compress to ~66%
        let ratio = comp.len() as f64 / raw.len() as f64;
        assert!(ratio < 0.72, "ratio={ratio}");
        assert!(ratio > 0.55, "ratio={ratio} suspiciously small for regular bf16");
    }

    #[test]
    fn roundtrip_empty_and_small() {
        for n in [0usize, 1, 2, 100, 4096] {
            let raw = gaussian_bf16(n, 2);
            let cfg = CodecConfig::for_dtype(DType::BF16);
            let comp = Compressor::new(cfg).compress(&raw).unwrap();
            assert_eq!(decompress(&comp).unwrap(), raw, "n={n}");
        }
    }

    #[test]
    fn roundtrip_odd_tail_chunk() {
        // buffer not a multiple of chunk size
        let raw = gaussian_bf16(DEFAULT_CHUNK_SIZE / 2 + 12_345, 3);
        let cfg = CodecConfig::for_dtype(DType::BF16);
        let comp = Compressor::new(cfg).compress(&raw).unwrap();
        assert_eq!(decompress(&comp).unwrap(), raw);
    }

    #[test]
    fn zipnn_beats_vanilla_zstd_on_bf16() {
        let raw = gaussian_bf16(1_000_000, 4);
        let zipnn = Compressor::new(CodecConfig::for_dtype(DType::BF16))
            .compress(&raw)
            .unwrap();
        let vanilla = Compressor::new(CodecConfig::vanilla_zstd())
            .compress(&raw)
            .unwrap();
        assert!(
            (zipnn.len() as f64) < vanilla.len() as f64 * 0.95,
            "zipnn={} vanilla={}",
            zipnn.len(),
            vanilla.len()
        );
        assert_eq!(decompress(&vanilla).unwrap(), raw);
    }

    #[test]
    fn all_zero_buffer_collapses() {
        let raw = vec![0u8; 1 << 20];
        let cfg = CodecConfig::for_dtype(DType::F32);
        let comp = Compressor::new(cfg).compress(&raw).unwrap();
        assert!(comp.len() < 1024, "len={}", comp.len());
        assert_eq!(decompress(&comp).unwrap(), raw);
    }

    #[test]
    fn random_buffer_stored_near_raw() {
        let mut rng = Xoshiro256::seed_from_u64(5);
        let mut raw = vec![0u8; 1 << 20];
        rng.fill_bytes(&mut raw);
        let cfg = CodecConfig::for_dtype(DType::F32);
        let comp = Compressor::new(cfg).compress(&raw).unwrap();
        assert!(comp.len() < raw.len() + raw.len() / 100 + 1024);
        assert_eq!(decompress(&comp).unwrap(), raw);
    }

    #[test]
    fn corruption_detected() {
        let raw = gaussian_bf16(300_000, 6);
        let cfg = CodecConfig::for_dtype(DType::BF16);
        let mut comp = Compressor::new(cfg).compress(&raw).unwrap();
        // flip a payload byte near the end
        let n = comp.len();
        comp[n - 3] ^= 0x40;
        match decompress(&comp) {
            Err(_) => {}
            Ok(back) => assert_ne!(back, raw, "corruption must not roundtrip silently"),
        }
    }

    #[test]
    fn truncation_detected() {
        let raw = gaussian_bf16(100_000, 7);
        let cfg = CodecConfig::for_dtype(DType::BF16);
        let comp = Compressor::new(cfg).compress(&raw).unwrap();
        for cut in [0, 3, 16, comp.len() / 2, comp.len() - 1] {
            assert!(decompress(&comp[..cut]).is_err(), "cut={cut}");
        }
    }

    #[test]
    fn parallel_threads_equal_serial() {
        let raw = gaussian_bf16(800_000, 8);
        let serial = Compressor::new(CodecConfig::for_dtype(DType::BF16))
            .compress(&raw)
            .unwrap();
        let par = Compressor::new(CodecConfig::for_dtype(DType::BF16).with_threads(4))
            .compress(&raw)
            .unwrap();
        assert_eq!(serial, par, "parallel output must be byte-identical");
        assert_eq!(decompress_with(&par, 4).unwrap(), raw);
    }

    #[test]
    fn builder_is_order_insensitive() {
        // The legacy chain's documented pitfall: with_chunk_size clamps
        // against the layout *at call time*, so setting the layout
        // afterwards leaves chunk_size misaligned.
        let mut legacy = CodecConfig::for_dtype(DType::I8).with_chunk_size(4097);
        legacy.layout = GroupLayout::for_dtype(DType::F32);
        assert_ne!(legacy.chunk_size % legacy.layout.elem, 0, "the bug this guards");

        // The builder validates against the final layout in either order.
        let a = CodecConfig::builder()
            .chunk_size(4096)
            .dtype(DType::F32)
            .build()
            .unwrap();
        let b = CodecConfig::builder()
            .dtype(DType::F32)
            .chunk_size(4096)
            .build()
            .unwrap();
        assert_eq!(a.chunk_size, b.chunk_size);
        assert_eq!(a.layout, b.layout);

        // Misaligned chunk sizes error instead of silently clamping,
        // in both orders.
        assert!(CodecConfig::builder()
            .chunk_size(4097)
            .dtype(DType::F32)
            .build()
            .is_err());
        assert!(CodecConfig::builder()
            .dtype(DType::F32)
            .chunk_size(4097)
            .build()
            .is_err());
        assert!(CodecConfig::builder().chunk_size(0).build().is_err());
    }

    #[test]
    fn config_splits_and_reassembles() {
        let cfg = CodecConfig::for_dtype(DType::BF16)
            .with_threads(4)
            .with_chunk_size(8192);
        let back = CodecConfig::from_parts(cfg.profile(), cfg.run());
        assert_eq!(back.layout, cfg.layout);
        assert_eq!(back.chunk_size, cfg.chunk_size);
        assert_eq!(back.policy, cfg.policy);
        assert_eq!(back.zstd_level, cfg.zstd_level);
        assert_eq!(back.skip_window, cfg.skip_window);
        assert_eq!(back.threads, cfg.threads);
        assert_eq!(back.checksum, cfg.checksum);
    }

    #[test]
    fn checksum_mixes() {
        assert_ne!(checksum64(b"abc"), checksum64(b"abd"));
        assert_ne!(checksum64(b"abc"), checksum64(b"ab"));
        assert_ne!(checksum64(&[0u8; 8]), checksum64(&[0u8; 16]));
    }
}
