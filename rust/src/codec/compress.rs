//! Compression side of the ZipNN codec.

use crate::codec::auto::{AutoPolicy, Decision, Method};
use crate::codec::container::{write_header, ContainerHeader, StreamEntry};
use crate::codec::parallel::{run_tasks, SUPER_CHUNK};
use crate::codec::{checksum64, CodecConfig, MethodPolicy};
use crate::error::Result;
use crate::fp::{split_groups, GroupLayout};
use crate::huffman;
use crate::lz;
use crate::stats::zero_stats;

/// One compressed stream plus its table entry.
struct StreamOut {
    entry: StreamEntry,
    bytes: Vec<u8>,
}

/// The ZipNN compressor. Construct with a [`CodecConfig`], then call
/// [`Compressor::compress`] — thread-safe and reusable.
pub struct Compressor {
    cfg: CodecConfig,
}

/// Per-byte-group compression report (`Table 2` breakdown numbers).
#[derive(Debug, Clone, Copy)]
pub struct GroupReport {
    /// Compressed bytes of this group across all chunks.
    pub comp: u64,
    /// Raw bytes of this group.
    pub raw: u64,
}

impl GroupReport {
    /// Compressed size in percent (paper's "lower is better" metric).
    pub fn pct(&self) -> f64 {
        if self.raw == 0 {
            0.0
        } else {
            self.comp as f64 / self.raw as f64 * 100.0
        }
    }
}

impl Compressor {
    /// New compressor with the given configuration.
    pub fn new(cfg: CodecConfig) -> Compressor {
        Compressor { cfg }
    }

    /// Compress `data` into a self-contained `.znn` container.
    pub fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        // Buffers that are not element-aligned cannot be byte-grouped;
        // fall back to a flat layout for the whole buffer.
        let layout = if data.len() % self.cfg.layout.elem == 0 {
            self.cfg.layout
        } else {
            GroupLayout::flat()
        };
        let chunk_size = self.cfg.chunk_size.max(layout.elem) / layout.elem * layout.elem;
        let n_chunks = data.len().div_ceil(chunk_size).max(if data.is_empty() { 0 } else { 1 });
        let groups = layout.groups();

        // Super-chunk tasks: deterministic under any thread count.
        let n_super = n_chunks.div_ceil(SUPER_CHUNK);
        let outs: Vec<Vec<StreamOut>> = run_tasks(n_super, self.cfg.threads, |si| {
            let mut policy = AutoPolicy::new(groups, self.cfg.skip_window);
            let lo = si * SUPER_CHUNK;
            let hi = ((si + 1) * SUPER_CHUNK).min(n_chunks);
            let mut streams = Vec::with_capacity((hi - lo) * groups);
            for c in lo..hi {
                let start = c * chunk_size;
                let end = (start + chunk_size).min(data.len());
                let chunk = &data[start..end];
                let gs = split_groups(chunk, layout).expect("aligned by construction");
                for (gi, g) in gs.iter().enumerate() {
                    streams.push(self.compress_stream(gi, g, &mut policy));
                }
            }
            streams
        });

        let mut entries = Vec::with_capacity(n_chunks * groups);
        let mut payload_len = 0usize;
        for s in outs.iter().flatten() {
            entries.push(s.entry);
            payload_len += s.bytes.len();
        }
        let header = ContainerHeader {
            layout,
            chunk_size: chunk_size as u32,
            total_len: data.len() as u64,
            n_chunks: n_chunks as u32,
            checksum: self.cfg.checksum.then(|| checksum64(data)),
        };
        let mut out = write_header(&header, &entries);
        out.reserve(payload_len);
        for s in outs.iter().flatten() {
            out.extend_from_slice(&s.bytes);
        }
        Ok(out)
    }

    /// Compress one group stream according to the configured policy.
    fn compress_stream(&self, group: usize, data: &[u8], policy: &mut AutoPolicy) -> StreamOut {
        let raw_len = data.len() as u32;
        let raw = |data: &[u8]| StreamOut {
            entry: StreamEntry { method: Method::Raw, comp_len: raw_len, raw_len },
            bytes: data.to_vec(),
        };
        match self.cfg.policy {
            MethodPolicy::Raw => raw(data),
            MethodPolicy::Huffman => self.huffman_or_raw(data, None, group, policy, false),
            MethodPolicy::Zstd => self.zstd_or_raw(data),
            MethodPolicy::Auto => {
                if policy.take_skip(group) {
                    return raw(data);
                }
                // One histogram pass feeds both the decision and Huffman.
                let hist = crate::stats::byte_histogram(data);
                match policy.decide_with_hist(data, &hist) {
                    Decision::SkipRaw => raw(data),
                    Decision::Zero => StreamOut {
                        entry: StreamEntry { method: Method::Zero, comp_len: 0, raw_len },
                        bytes: Vec::new(),
                    },
                    Decision::TryZstd => self.zstd_or_raw(data),
                    Decision::TryHuffman => {
                        self.huffman_or_raw(data, Some(&hist), group, policy, true)
                    }
                }
            }
        }
    }

    fn huffman_or_raw(
        &self,
        data: &[u8],
        hist: Option<&[u64; 256]>,
        group: usize,
        policy: &mut AutoPolicy,
        report: bool,
    ) -> StreamOut {
        let enc = match hist {
            Some(h) => huffman::compress_with_hist(data, h),
            None => huffman::compress(data),
        };
        if report {
            policy.report(group, data.len(), enc.len());
        }
        if enc.len() < data.len() {
            StreamOut {
                entry: StreamEntry {
                    method: Method::Huffman,
                    comp_len: enc.len() as u32,
                    raw_len: data.len() as u32,
                },
                bytes: enc,
            }
        } else {
            StreamOut {
                entry: StreamEntry {
                    method: Method::Raw,
                    comp_len: data.len() as u32,
                    raw_len: data.len() as u32,
                },
                bytes: data.to_vec(),
            }
        }
    }

    fn zstd_or_raw(&self, data: &[u8]) -> StreamOut {
        // An all-zero stream is cheaper as Zero even under forced-Zstd.
        if !data.is_empty() && zero_stats(data).zero_frac >= 1.0 {
            return StreamOut {
                entry: StreamEntry {
                    method: Method::Zero,
                    comp_len: 0,
                    raw_len: data.len() as u32,
                },
                bytes: Vec::new(),
            };
        }
        match lz::zstd_compress(data, self.cfg.zstd_level) {
            Ok(enc) if enc.len() < data.len() => StreamOut {
                entry: StreamEntry {
                    method: Method::Zstd,
                    comp_len: enc.len() as u32,
                    raw_len: data.len() as u32,
                },
                bytes: enc,
            },
            _ => StreamOut {
                entry: StreamEntry {
                    method: Method::Raw,
                    comp_len: data.len() as u32,
                    raw_len: data.len() as u32,
                },
                bytes: data.to_vec(),
            },
        }
    }
}

/// Compress and return `(container, per-group reports)` — the breakdown
/// used by the Table 2 / Fig. 6 benches.
pub fn compress_with_report(cfg: CodecConfig, data: &[u8]) -> Result<(Vec<u8>, Vec<GroupReport>)> {
    let out = Compressor::new(cfg).compress(data)?;
    let info = crate::codec::container::parse(&out)?;
    let reports = info
        .group_totals()
        .into_iter()
        .map(|(comp, raw)| GroupReport { comp, raw })
        .collect();
    Ok((out, reports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::decompress;
    use crate::fp::DType;

    #[test]
    fn unaligned_buffer_falls_back_to_flat() {
        let data = vec![7u8; 1001]; // not a multiple of 4
        let cfg = CodecConfig::for_dtype(DType::F32);
        let comp = Compressor::new(cfg).compress(&data).unwrap();
        let info = crate::codec::container::parse(&comp).unwrap();
        assert_eq!(info.header.layout.elem, 1);
        assert_eq!(decompress(&comp).unwrap(), data);
    }

    #[test]
    fn report_groups_sum_to_total() {
        let mut rng = crate::util::Xoshiro256::seed_from_u64(12);
        let mut data = Vec::new();
        for _ in 0..200_000 {
            let w = (rng.normal() * 0.02) as f32;
            data.extend_from_slice(&crate::fp::dtype::f32_to_bf16_bits(w).to_le_bytes());
        }
        let (comp, reps) = compress_with_report(CodecConfig::for_dtype(DType::BF16), &data).unwrap();
        let raw_sum: u64 = reps.iter().map(|r| r.raw).sum();
        assert_eq!(raw_sum, data.len() as u64);
        let comp_sum: u64 = reps.iter().map(|r| r.comp).sum();
        assert!(comp_sum <= comp.len() as u64);
        // exponent group compresses ~3x; mantissa ~raw (paper §3.1)
        assert!(reps[0].pct() < 45.0, "exp pct {}", reps[0].pct());
        assert!(reps[1].pct() > 95.0, "mantissa pct {}", reps[1].pct());
    }

    #[test]
    fn forced_zstd_policy_marks_zstd() {
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 17) as u8).collect();
        let cfg = CodecConfig::vanilla_zstd();
        let comp = Compressor::new(cfg).compress(&data).unwrap();
        let info = crate::codec::container::parse(&comp).unwrap();
        assert!(info.entries.iter().all(|e| e.method == Method::Zstd));
        assert_eq!(decompress(&comp).unwrap(), data);
    }
}
