//! Compression side of the ZipNN codec: a thin wrapper over the
//! super-chunk streaming core ([`crate::codec::stream`]) that assembles the
//! one-shot `.znn` (`ZNN1`) container — header, full stream table, payload.
//! The emitted bytes are identical to the historical monolithic
//! implementation (the golden-bytes test pins this).

use crate::codec::container::{write_header, ContainerHeader};
use crate::codec::stream::{compress_supers, encode_workers};
use crate::codec::{checksum64, CodecConfig, CodecProfile};
use crate::error::Result;
use crate::fp::GroupLayout;

/// The ZipNN compressor. Construct with a [`CodecConfig`], then call
/// [`Compressor::compress`] — thread-safe and reusable. For
/// chunk-incremental compression that never materializes the input or
/// output, use [`crate::codec::ZnnWriter`] instead.
pub struct Compressor {
    cfg: CodecConfig,
}

/// Per-byte-group compression report (`Table 2` breakdown numbers).
#[derive(Debug, Clone, Copy)]
pub struct GroupReport {
    /// Compressed bytes of this group across all chunks.
    pub comp: u64,
    /// Raw bytes of this group.
    pub raw: u64,
}

impl GroupReport {
    /// Compressed size in percent (paper's "lower is better" metric).
    pub fn pct(&self) -> f64 {
        if self.raw == 0 {
            0.0
        } else {
            self.comp as f64 / self.raw as f64 * 100.0
        }
    }
}

impl Compressor {
    /// New compressor with the given configuration.
    pub fn new(cfg: CodecConfig) -> Compressor {
        Compressor { cfg }
    }

    /// Compress `data` into a self-contained `.znn` container.
    pub fn compress(&self, data: &[u8]) -> Result<Vec<u8>> {
        // Buffers that are not element-aligned cannot be byte-grouped;
        // fall back to a flat layout for the whole buffer. (The streaming
        // writer instead carries the sub-element tail in its trailer.)
        let layout = if data.len() % self.cfg.layout.elem == 0 {
            self.cfg.layout
        } else {
            GroupLayout::flat()
        };
        let chunk_size = self.cfg.chunk_size.max(layout.elem) / layout.elem * layout.elem;
        let n_chunks = data.len().div_ceil(chunk_size);
        let groups = layout.groups();

        // Super-chunk tasks over the shared streaming core: deterministic
        // under any thread count. Parallel runs execute as claimed tasks
        // on the process-shared sticky-state pool (the calling thread
        // helps; no scoped thread spawns per call) — the encode mirror of
        // the persistent decode engine.
        let profile = CodecProfile { layout, ..self.cfg.profile() };
        let supers = compress_supers(
            &profile,
            chunk_size,
            data,
            encode_workers(self.cfg.threads),
        )?;

        let mut entries = Vec::with_capacity(n_chunks * groups);
        let mut payload_len = 0usize;
        for (es, payload) in &supers {
            entries.extend_from_slice(es);
            payload_len += payload.len();
        }
        let header = ContainerHeader {
            layout,
            chunk_size: chunk_size as u32,
            total_len: data.len() as u64,
            n_chunks: n_chunks as u32,
            checksum: self.cfg.checksum.then(|| checksum64(data)),
        };
        let mut out = write_header(&header, &entries);
        out.reserve(payload_len);
        for (_, payload) in &supers {
            out.extend_from_slice(payload);
        }
        Ok(out)
    }
}

/// Compress and return `(container, per-group reports)` — the breakdown
/// used by the Table 2 / Fig. 6 benches.
pub fn compress_with_report(cfg: CodecConfig, data: &[u8]) -> Result<(Vec<u8>, Vec<GroupReport>)> {
    let out = Compressor::new(cfg).compress(data)?;
    let info = crate::codec::container::parse(&out)?;
    let reports = info
        .group_totals()
        .into_iter()
        .map(|(comp, raw)| GroupReport { comp, raw })
        .collect();
    Ok((out, reports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::decompress;
    use crate::fp::DType;

    #[test]
    fn unaligned_buffer_falls_back_to_flat() {
        let data = vec![7u8; 1001]; // not a multiple of 4
        let cfg = CodecConfig::for_dtype(DType::F32);
        let comp = Compressor::new(cfg).compress(&data).unwrap();
        let info = crate::codec::container::parse(&comp).unwrap();
        assert_eq!(info.header.layout.elem, 1);
        assert_eq!(decompress(&comp).unwrap(), data);
    }

    #[test]
    fn report_groups_sum_to_total() {
        let mut rng = crate::util::Xoshiro256::seed_from_u64(12);
        let mut data = Vec::new();
        for _ in 0..200_000 {
            let w = (rng.normal() * 0.02) as f32;
            data.extend_from_slice(&crate::fp::dtype::f32_to_bf16_bits(w).to_le_bytes());
        }
        let (comp, reps) = compress_with_report(CodecConfig::for_dtype(DType::BF16), &data).unwrap();
        let raw_sum: u64 = reps.iter().map(|r| r.raw).sum();
        assert_eq!(raw_sum, data.len() as u64);
        let comp_sum: u64 = reps.iter().map(|r| r.comp).sum();
        assert!(comp_sum <= comp.len() as u64);
        // exponent group compresses ~3x; mantissa ~raw (paper §3.1)
        assert!(reps[0].pct() < 45.0, "exp pct {}", reps[0].pct());
        assert!(reps[1].pct() > 95.0, "mantissa pct {}", reps[1].pct());
    }

    #[test]
    fn forced_zstd_policy_marks_zstd() {
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 17) as u8).collect();
        let cfg = CodecConfig::vanilla_zstd();
        let comp = Compressor::new(cfg).compress(&data).unwrap();
        let info = crate::codec::container::parse(&comp).unwrap();
        assert!(info.entries.iter().all(|e| e.method == crate::codec::Method::Zstd));
        assert_eq!(decompress(&comp).unwrap(), data);
    }
}
