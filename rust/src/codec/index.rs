//! Optional tensor→chunk index section for `.znn` containers (ROADMAP
//! "Range-GET of individual tensors").
//!
//! The index maps tensor names to byte ranges of the *raw* payload and —
//! for the streaming `ZNS1` format — records the file offset of every
//! frame, so a random-access reader can decode exactly the chunks covering
//! one tensor instead of the whole container, and a hub server can slice
//! the covering frames straight out of a spooled memory mapping.
//!
//! The section is appended **after** the container payload (`ZNN1`) or
//! trailer (`ZNS1`), so readers that do not know about it keep decoding
//! unchanged: the streaming [`crate::codec::ZnnReader`] stops at the
//! trailer / table end and never sees the extra bytes. A fixed-size footer
//! at the very end lets random-access readers locate the section without
//! scanning:
//!
//! ```text
//! section: "ZIDX" [version u8] [kind u8: 1 = ZNN1, 2 = ZNS1]
//!          [total_len u64] [chunk_size u32]
//!          [tail_len u8] [tail bytes]            (ZNS1 trailer tail copy)
//!          [trailer_off u64]     (ZNS1: offset of the 0xF6 trailer;
//!                                 ZNN1: payload end = index start)
//!          [n_frames u32] [frame_off u64 × n]    (ZNS1 frame directory)
//!          [n_tensors u32]
//!          tensor: [name_len u16] [name] [dtype u8] [offset u64] [len u64]
//! footer:  [section_len u64] "ZIDX"
//! ```
//!
//! `ZNN1` containers flag the section with
//! [`crate::codec::container::FLAG_INDEX`] so the strict one-shot parser
//! can account for the trailing bytes; `ZNS1` needs no flag (the trailer
//! delimits the payload).

use crate::error::{Error, Result};
use crate::fp::DType;

/// Index section (and footer) magic.
pub const INDEX_MAGIC: [u8; 4] = *b"ZIDX";
/// Index section version.
pub const INDEX_VERSION: u8 = 1;
/// Fixed footer size: section length (u64) + magic.
pub const INDEX_FOOTER_LEN: usize = 12;

/// Caps guarding against absurd allocations from corrupt sections.
const NAME_MAX: usize = 4096;
const COUNT_MAX: u64 = 1 << 24;

/// Which container format the index describes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ContainerKind {
    /// `ZNN1` one-shot: stream table up front, payload offsets derivable.
    OneShot,
    /// `ZNS1` streaming: per-frame offsets recorded in the directory.
    Streaming,
}

impl ContainerKind {
    fn tag(self) -> u8 {
        match self {
            ContainerKind::OneShot => 1,
            ContainerKind::Streaming => 2,
        }
    }

    fn from_tag(t: u8) -> Option<ContainerKind> {
        match t {
            1 => Some(ContainerKind::OneShot),
            2 => Some(ContainerKind::Streaming),
            _ => None,
        }
    }
}

/// One tensor's placement within the raw (decompressed) payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorMeta {
    /// Tensor name (e.g. `"blocks.3.attn.wq"`).
    pub name: String,
    /// Element dtype.
    pub dtype: DType,
    /// Byte offset within the raw payload.
    pub offset: u64,
    /// Byte length within the raw payload.
    pub len: u64,
}

/// Parsed tensor→chunk index of a container.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorIndex {
    /// Container format the index describes.
    pub kind: ContainerKind,
    /// Total raw payload length.
    pub total_len: u64,
    /// Raw bytes per chunk.
    pub chunk_size: u32,
    /// Copy of the `ZNS1` trailer tail (< 16 non-element-aligned bytes;
    /// empty for `ZNN1`) so range decodes covering the tail need not
    /// touch the trailer.
    pub tail: Vec<u8>,
    /// `ZNS1`: file offset of the `0xF6` trailer marker (= end of the
    /// last frame). `ZNN1`: offset of the payload end (= index start).
    pub trailer_off: u64,
    /// `ZNS1`: file offset of each frame's `0xF5` marker (empty for
    /// `ZNN1`, whose table makes payload offsets derivable).
    pub frame_offsets: Vec<u64>,
    /// Tensor directory, in payload order.
    pub tensors: Vec<TensorMeta>,
}

impl TensorIndex {
    /// Look a tensor up by name.
    pub fn find(&self, name: &str) -> Option<&TensorMeta> {
        self.tensors.iter().find(|t| t.name == name)
    }

    /// Raw payload length covered by whole chunks (everything but the
    /// trailer tail).
    pub fn aligned_len(&self) -> u64 {
        self.total_len.saturating_sub(self.tail.len() as u64)
    }

    /// Serialize section + footer (the bytes appended to a container).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            64 + 8 * self.frame_offsets.len()
                + self.tensors.iter().map(|t| 27 + t.name.len()).sum::<usize>(),
        );
        out.extend_from_slice(&INDEX_MAGIC);
        out.push(INDEX_VERSION);
        out.push(self.kind.tag());
        out.extend_from_slice(&self.total_len.to_le_bytes());
        out.extend_from_slice(&self.chunk_size.to_le_bytes());
        out.push(self.tail.len() as u8);
        out.extend_from_slice(&self.tail);
        out.extend_from_slice(&self.trailer_off.to_le_bytes());
        out.extend_from_slice(&(self.frame_offsets.len() as u32).to_le_bytes());
        for f in &self.frame_offsets {
            out.extend_from_slice(&f.to_le_bytes());
        }
        out.extend_from_slice(&(self.tensors.len() as u32).to_le_bytes());
        for t in &self.tensors {
            out.extend_from_slice(&(t.name.len() as u16).to_le_bytes());
            out.extend_from_slice(t.name.as_bytes());
            out.push(t.dtype.tag());
            out.extend_from_slice(&t.offset.to_le_bytes());
            out.extend_from_slice(&t.len.to_le_bytes());
        }
        let section_len = out.len() as u64;
        out.extend_from_slice(&section_len.to_le_bytes());
        out.extend_from_slice(&INDEX_MAGIC);
        out
    }

    /// Parse a section (without the footer), validating magic and caps.
    pub fn parse_section(data: &[u8]) -> Result<TensorIndex> {
        let mut c = Cursor { data, at: 0 };
        if c.bytes(4)? != INDEX_MAGIC {
            return Err(Error::Corrupt("bad index section magic".into()));
        }
        let version = c.u8()?;
        if version != INDEX_VERSION {
            return Err(Error::Corrupt(format!("unsupported index version {version}")));
        }
        let kind = ContainerKind::from_tag(c.u8()?)
            .ok_or_else(|| Error::Corrupt("bad index container kind".into()))?;
        let total_len = c.u64()?;
        let chunk_size = c.u32()?;
        if chunk_size == 0 {
            return Err(Error::Corrupt("index chunk size zero".into()));
        }
        let tail_len = c.u8()? as usize;
        if tail_len >= 16 {
            return Err(Error::Corrupt(format!("bad index tail length {tail_len}")));
        }
        let tail = c.bytes(tail_len)?.to_vec();
        if (tail.len() as u64) > total_len {
            return Err(Error::Corrupt("index tail longer than payload".into()));
        }
        let trailer_off = c.u64()?;
        let n_frames = c.u32()? as u64;
        if n_frames > COUNT_MAX {
            return Err(Error::Corrupt(format!("implausible frame count {n_frames}")));
        }
        // Capped pre-allocation: a corrupt count must not trigger a huge
        // allocation before its bytes — which would have to exist — are
        // read (same guard as the container table parsers).
        let mut frame_offsets = Vec::with_capacity((n_frames as usize).min(1 << 16));
        let mut prev = 0u64;
        for _ in 0..n_frames {
            let off = c.u64()?;
            if off < prev || off > trailer_off {
                return Err(Error::Corrupt("index frame offsets not monotonic".into()));
            }
            prev = off;
            frame_offsets.push(off);
        }
        let n_tensors = c.u32()? as u64;
        if n_tensors > COUNT_MAX {
            return Err(Error::Corrupt(format!("implausible tensor count {n_tensors}")));
        }
        let mut tensors = Vec::with_capacity((n_tensors as usize).min(1 << 16));
        for _ in 0..n_tensors {
            let name_len = c.u16()? as usize;
            if name_len > NAME_MAX {
                return Err(Error::Corrupt("index tensor name too long".into()));
            }
            let name = String::from_utf8(c.bytes(name_len)?.to_vec())
                .map_err(|_| Error::Corrupt("index tensor name not utf8".into()))?;
            let dtype = DType::from_tag(c.u8()?)?;
            let offset = c.u64()?;
            let len = c.u64()?;
            let end = offset
                .checked_add(len)
                .ok_or_else(|| Error::Corrupt("index tensor range overflows".into()))?;
            if end > total_len {
                return Err(Error::Corrupt(format!(
                    "index tensor '{name}' extends past payload ({end} > {total_len})"
                )));
            }
            tensors.push(TensorMeta { name, dtype, offset, len });
        }
        if c.at != data.len() {
            return Err(Error::Corrupt("trailing bytes after index section".into()));
        }
        Ok(TensorIndex { kind, total_len, chunk_size, tail, trailer_off, frame_offsets, tensors })
    }
}

/// Partition a container's byte range `[0, container_len)` into at most
/// `parts` contiguous stripes whose internal boundaries all fall on
/// frame starts from the index's frame directory. Returns
/// `(offset, len)` spans in file order; they tile the container exactly.
///
/// The first stripe always carries the stream header, the last carries
/// the trailer and the index tail, and every boundary is a `0xF5` frame
/// offset — so a multi-peer client can fetch stripes from different
/// replicas, scan each stripe's frames independently (prepending the
/// header bytes it already holds), and concatenate without re-framing.
/// Fewer than `parts` spans come back when the frame directory is too
/// small to honor the requested split.
pub fn stripe_spans(idx: &TensorIndex, container_len: u64, parts: usize) -> Vec<(u64, u64)> {
    let parts = parts.max(1) as u64;
    // Boundary candidates: every frame start strictly inside the file.
    // (frame_offsets are validated monotonic ≤ trailer_off at parse.)
    let candidates: Vec<u64> = idx
        .frame_offsets
        .iter()
        .copied()
        .filter(|&o| o > 0 && o < container_len)
        .collect();
    let mut bounds = vec![0u64];
    for k in 1..parts {
        let target = container_len * k / parts;
        // First candidate ≥ the even-split target that still advances.
        let i = candidates.partition_point(|&o| o < target);
        if let Some(&off) = candidates.get(i) {
            if off > *bounds.last().unwrap() {
                bounds.push(off);
            }
        }
    }
    bounds.push(container_len);
    bounds.windows(2).map(|w| (w[0], w[1] - w[0])).collect()
}

/// Given a container's total byte length and its last
/// [`INDEX_FOOTER_LEN`] bytes, locate the index section. Returns
/// `(section_offset, section_len)`, or `None` when no index is present
/// (the footer does not parse as one).
pub fn section_span(container_len: u64, footer: &[u8]) -> Option<(u64, usize)> {
    if footer.len() != INDEX_FOOTER_LEN || footer[8..12] != INDEX_MAGIC {
        return None;
    }
    let section_len = u64::from_le_bytes(footer[..8].try_into().unwrap());
    let budget = container_len.checked_sub(INDEX_FOOTER_LEN as u64)?;
    if section_len < 6 || section_len > budget {
        return None;
    }
    Some((budget - section_len, section_len as usize))
}

/// Probe in-memory container bytes for an index. `Ok(None)` when the
/// container carries no index; `Err` only when a footer *claims* an index
/// whose section fails to parse.
pub fn probe_bytes(data: &[u8]) -> Result<Option<TensorIndex>> {
    if data.len() < INDEX_FOOTER_LEN {
        return Ok(None);
    }
    let footer = &data[data.len() - INDEX_FOOTER_LEN..];
    let Some((off, len)) = section_span(data.len() as u64, footer) else {
        return Ok(None);
    };
    let section = &data[off as usize..off as usize + len];
    if section.len() < 4 || section[..4] != INDEX_MAGIC {
        // The trailing bytes merely *looked* like a footer.
        return Ok(None);
    }
    TensorIndex::parse_section(section).map(Some)
}

/// Probe a container file's tail for an index without mapping or reading
/// the body (the `ZIPNN_NO_MMAP` / unmappable-filesystem fallback path).
pub fn probe_file(path: &std::path::Path) -> Result<Option<TensorIndex>> {
    use std::io::{Read, Seek, SeekFrom};
    let mut f = std::fs::File::open(path)?;
    let flen = f.seek(SeekFrom::End(0))?;
    if flen < INDEX_FOOTER_LEN as u64 {
        return Ok(None);
    }
    let mut footer = [0u8; INDEX_FOOTER_LEN];
    f.seek(SeekFrom::End(-(INDEX_FOOTER_LEN as i64)))?;
    f.read_exact(&mut footer)?;
    let Some((off, len)) = section_span(flen, &footer) else {
        return Ok(None);
    };
    let mut section = vec![0u8; len];
    f.seek(SeekFrom::Start(off))?;
    f.read_exact(&mut section)?;
    if section.len() < 4 || section[..4] != INDEX_MAGIC {
        return Ok(None);
    }
    TensorIndex::parse_section(&section).map(Some)
}

/// Byte length of the trailing index (section + footer) of `data`, when
/// present and plausibly framed. Used by the strict `ZNN1` parser to
/// account for indexed containers' trailing bytes.
pub(crate) fn trailing_len(data: &[u8]) -> Option<usize> {
    if data.len() < INDEX_FOOTER_LEN {
        return None;
    }
    let footer = &data[data.len() - INDEX_FOOTER_LEN..];
    let (off, len) = section_span(data.len() as u64, footer)?;
    if data[off as usize..off as usize + 4] != INDEX_MAGIC {
        return None;
    }
    Some(len + INDEX_FOOTER_LEN)
}

/// Append a tensor index to an existing (index-free) `ZNN1` container and
/// set [`crate::codec::container::FLAG_INDEX`] in its header. The
/// container's payload bytes are untouched, so index-unaware streaming
/// readers keep decoding it.
pub fn append_to_znn1(container: &mut Vec<u8>, tensors: Vec<TensorMeta>) -> Result<()> {
    let info = crate::codec::container::parse(container)?;
    if container[5] & crate::codec::container::FLAG_INDEX != 0 {
        return Err(Error::Invalid("container already carries an index".into()));
    }
    for t in &tensors {
        let end = t
            .offset
            .checked_add(t.len)
            .ok_or_else(|| Error::Invalid(format!("tensor '{}' range overflows", t.name)))?;
        if end > info.header.total_len {
            return Err(Error::Invalid(format!(
                "tensor '{}' extends past payload ({end} > {})",
                t.name, info.header.total_len
            )));
        }
    }
    let idx = TensorIndex {
        kind: ContainerKind::OneShot,
        total_len: info.header.total_len,
        chunk_size: info.header.chunk_size,
        tail: Vec::new(),
        trailer_off: container.len() as u64,
        frame_offsets: Vec::new(),
        tensors,
    };
    container[5] |= crate::codec::container::FLAG_INDEX;
    container.extend_from_slice(&idx.encode());
    Ok(())
}

struct Cursor<'a> {
    data: &'a [u8],
    at: usize,
}

impl<'a> Cursor<'a> {
    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .at
            .checked_add(n)
            .filter(|&e| e <= self.data.len())
            .ok_or_else(|| Error::Corrupt("index section truncated".into()))?;
        let s = &self.data[self.at..end];
        self.at = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.bytes(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TensorIndex {
        TensorIndex {
            kind: ContainerKind::Streaming,
            total_len: 1000,
            chunk_size: 64,
            tail: vec![1, 2, 3],
            trailer_off: 700,
            frame_offsets: vec![12, 300, 650],
            tensors: vec![
                TensorMeta { name: "a".into(), dtype: DType::BF16, offset: 0, len: 600 },
                TensorMeta { name: "b.c".into(), dtype: DType::F32, offset: 600, len: 400 },
                TensorMeta { name: "empty".into(), dtype: DType::I8, offset: 600, len: 0 },
            ],
        }
    }

    #[test]
    fn encode_parse_roundtrip() {
        let idx = sample();
        let enc = idx.encode();
        let (off, len) =
            section_span(enc.len() as u64, &enc[enc.len() - INDEX_FOOTER_LEN..]).unwrap();
        assert_eq!(off, 0);
        let back = TensorIndex::parse_section(&enc[..len]).unwrap();
        assert_eq!(back, idx);
        assert_eq!(back.find("b.c").unwrap().offset, 600);
        assert!(back.find("nope").is_none());
        assert_eq!(back.aligned_len(), 997);
    }

    #[test]
    fn probe_bytes_absent_and_corrupt() {
        assert!(probe_bytes(b"short").unwrap().is_none());
        assert!(probe_bytes(&[0u8; 64]).unwrap().is_none());
        // A present-but-corrupt section must error, not be ignored.
        let idx = sample();
        let mut enc = idx.encode();
        let n = enc.len();
        enc[n - 20] ^= 0xFF; // corrupt inside the section
        let mut blob = vec![9u8; 40];
        blob.extend_from_slice(&enc);
        assert!(probe_bytes(&blob).is_err());
    }

    #[test]
    fn oversized_counts_rejected() {
        let idx = sample();
        let mut enc = idx.encode();
        // Patch n_frames (offset: 4+1+1+8+4+1+tail(3)+8 = 30) to a huge value.
        enc[30..34].copy_from_slice(&u32::MAX.to_le_bytes());
        let len = enc.len() - INDEX_FOOTER_LEN;
        assert!(TensorIndex::parse_section(&enc[..len]).is_err());
    }

    #[test]
    fn stripe_spans_tile_and_align() {
        let mut idx = sample();
        idx.frame_offsets = vec![12, 100, 220, 300, 420, 560, 650];
        let total = 1000u64;
        for parts in 1..=8 {
            let spans = stripe_spans(&idx, total, parts);
            assert!(!spans.is_empty() && spans.len() <= parts.max(1));
            // Spans tile [0, total) exactly.
            let mut at = 0u64;
            for &(off, len) in &spans {
                assert_eq!(off, at);
                assert!(len > 0);
                at += len;
            }
            assert_eq!(at, total);
            // Every internal boundary is a frame offset.
            for &(off, _) in &spans[1..] {
                assert!(idx.frame_offsets.contains(&off), "boundary {off} not a frame start");
            }
        }
    }

    #[test]
    fn stripe_spans_degenerate() {
        let mut idx = sample();
        idx.frame_offsets = Vec::new();
        // No frame directory: one span covering everything.
        assert_eq!(stripe_spans(&idx, 500, 4), vec![(0, 500)]);
        idx.frame_offsets = vec![12];
        // One usable boundary can satisfy at most two spans.
        let spans = stripe_spans(&idx, 500, 4);
        assert!(spans.len() <= 2);
        assert_eq!(spans.iter().map(|s| s.1).sum::<u64>(), 500);
    }

    #[test]
    fn tensor_past_payload_rejected() {
        let mut idx = sample();
        idx.tensors[0].len = 2000;
        let enc = idx.encode();
        let len = enc.len() - INDEX_FOOTER_LEN;
        assert!(TensorIndex::parse_section(&enc[..len]).is_err());
    }
}
