//! Deterministic chunk-parallel execution.
//!
//! Work is partitioned into **super-chunks** of [`SUPER_CHUNK`] chunks.
//! The probe-and-skip state ([`crate::codec::auto::AutoPolicy`]) resets at
//! every super-chunk boundary, in serial and parallel mode alike, so the
//! compressed output is byte-identical regardless of thread count — a
//! property the integration tests assert.
//!
//! Results are collected through an indexed channel: each worker sends
//! `(task_index, result)` and the caller slots results into a pre-sized
//! output vector after the scope joins. Workers never contend on a shared
//! lock per task (the previous `Mutex<Vec<Option<T>>>` serialized every
//! task completion).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Chunks per super-chunk (auto-policy reset interval / work unit).
pub const SUPER_CHUNK: usize = 16;

/// Run `f(task_index)` for `n_tasks` tasks on `threads` workers, returning
/// results in task order. `threads == 1` runs inline with zero overhead.
pub fn run_tasks<T, F>(n_tasks: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    run_tasks_with(n_tasks, threads, || (), |_state, i| f(i))
}

/// [`run_tasks`] with per-worker state: `init()` runs once on each worker
/// (and once for the inline path) and the resulting value is threaded
/// through every task that worker executes. This is how the codec reuses a
/// [`crate::codec::stream::ScratchArena`] across the tasks of one worker —
/// O(workers) arenas instead of O(tasks) scratch allocations.
pub fn run_tasks_with<S, T, I, F>(n_tasks: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if threads <= 1 || n_tasks <= 1 {
        let mut state = init();
        return (0..n_tasks).map(|i| f(&mut state, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, T)>();
    std::thread::scope(|s| {
        let next = &next;
        let init = &init;
        let f = &f;
        for _ in 0..threads.min(n_tasks) {
            let tx = tx.clone();
            s.spawn(move || {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_tasks {
                        break;
                    }
                    let r = f(&mut state, i);
                    if tx.send((i, r)).is_err() {
                        break; // receiver gone (caller panicked)
                    }
                }
            });
        }
        drop(tx);
    });
    let mut out: Vec<Option<T>> = (0..n_tasks).map(|_| None).collect();
    for (i, r) in rx {
        out[i] = Some(r);
    }
    out.into_iter()
        .map(|o| o.expect("task completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_order() {
        let out = run_tasks(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn serial_matches_parallel() {
        let f = |i: usize| (i as u64).wrapping_mul(0x9E3779B9) % 97;
        assert_eq!(run_tasks(257, 1, f), run_tasks(257, 8, f));
    }

    #[test]
    fn zero_tasks() {
        let out: Vec<u32> = run_tasks(0, 4, |_| 1);
        assert!(out.is_empty());
    }

    #[test]
    fn worker_state_reused_across_tasks() {
        // Each worker counts the tasks it ran; the per-task results must
        // still come back complete and in order.
        let out = run_tasks_with(
            64,
            4,
            || 0usize,
            |seen, i| {
                *seen += 1;
                (i, *seen)
            },
        );
        assert_eq!(out.len(), 64);
        for (slot, (i, seen)) in out.iter().enumerate() {
            assert_eq!(*i, slot);
            assert!(*seen >= 1);
        }
        // Per-worker counters rise 1..=k, so across workers the number of
        // tasks observing counter value v (= workers that ran >= v tasks)
        // must be non-increasing in v — a structural check that state
        // really persisted within each worker.
        let mut hist = std::collections::BTreeMap::new();
        for (_, seen) in &out {
            *hist.entry(*seen).or_insert(0usize) += 1;
        }
        let mut prev = usize::MAX;
        for (&v, &c) in &hist {
            assert!(c <= prev, "counter value {v} seen {c} times, more than {prev}");
            prev = c;
        }
    }

    #[test]
    fn inline_path_shares_one_state() {
        let out = run_tasks_with(10, 1, || 0usize, |acc, _| {
            *acc += 1;
            *acc
        });
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }
}
