//! Deterministic chunk-parallel execution.
//!
//! Work is partitioned into **super-chunks** of [`SUPER_CHUNK`] chunks.
//! The probe-and-skip state ([`crate::codec::auto::AutoPolicy`]) resets at
//! every super-chunk boundary, in serial and parallel mode alike, so the
//! compressed output is byte-identical regardless of thread count — a
//! property the integration tests assert.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Chunks per super-chunk (auto-policy reset interval / work unit).
pub const SUPER_CHUNK: usize = 16;

/// Run `f(task_index)` for `n_tasks` tasks on `threads` workers, returning
/// results in task order. `threads == 1` runs inline with zero overhead.
pub fn run_tasks<T, F>(n_tasks: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if threads <= 1 || n_tasks <= 1 {
        return (0..n_tasks).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let results: Mutex<Vec<Option<T>>> =
        Mutex::new((0..n_tasks).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..threads.min(n_tasks) {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_tasks {
                    break;
                }
                let r = f(i);
                results.lock().unwrap()[i] = Some(r);
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("task completed"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_in_order() {
        let out = run_tasks(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn serial_matches_parallel() {
        let f = |i: usize| (i as u64).wrapping_mul(0x9E3779B9) % 97;
        assert_eq!(run_tasks(257, 1, f), run_tasks(257, 8, f));
    }

    #[test]
    fn zero_tasks() {
        let out: Vec<u32> = run_tasks(0, 4, |_| 1);
        assert!(out.is_empty());
    }
}
