//! Chunk-incremental streaming codec core (paper §5.1).
//!
//! ZipNN's fixed raw chunk sizes and per-stream metadata make both
//! directions streamable: a writer can emit each **super-chunk**'s
//! compressed streams as soon as that super-chunk's raw bytes have
//! arrived, and a reader can yield raw bytes as soon as one super-chunk's
//! compressed streams have been read. Neither side ever materializes the
//! whole payload.
//!
//! This module provides that core:
//!
//! - [`ZnnWriter`] — a [`std::io::Write`] adapter that accepts raw bytes
//!   incrementally and emits a framed streaming container (`ZNS1`) to an
//!   inner sink, one frame per super-chunk;
//! - [`ZnnReader`] — a [`std::io::Read`] adapter that pulls from a
//!   [`ByteSource`] holding either container format (`ZNN1` one-shot or
//!   `ZNS1` streaming) and yields decompressed bytes;
//! - [`ByteSource`] / [`MappedBytes`] — where the compressed bytes come
//!   from: any `io::Read` (sockets, pipes), or a memory-mapped file whose
//!   payload slices the decoder borrows **zero-copy** straight out of the
//!   OS page cache ([`ZnnReader::open`] is the mmap fast path; see the
//!   README's "mmap fast path" section for the knobs);
//! - [`ScratchArena`] — the per-worker reusable scratch buffers that make
//!   steady-state compression perform O(workers) allocations instead of
//!   O(chunks × groups).
//!
//! With `with_threads(n > 1)` **both directions** run on the
//! process-wide [`crate::coordinator::shared_pool`] — workers are spawned
//! once per process, their arenas and Huffman decode tables stay warm in
//! per-worker sticky state, and both sides are **double-buffered**: the
//! reader fetches batch N+1's compressed bytes (or mapped pages) while
//! batch N decodes, and the writer serializes batch N's frames to the
//! inner sink while batch N+1's super-chunks compress on the pool
//! (`ZIPNN_ENCODE_WORKERS` overrides the writer's thread count without
//! an API change). The emitted bytes are identical for any thread count
//! and write split — frame boundaries are fixed at super-chunk
//! granularity.
//!
//! The one-shot [`crate::codec::Compressor`] and
//! [`crate::codec::decompress`] are thin wrappers over the same
//! super-chunk core, so the `.znn` (`ZNN1`) bytes they produce are
//! unchanged.
//!
//! ## Formats
//!
//! `ZNN1` (one-shot): header, full stream table, payload — random access,
//! but the table's size depends on the total length, so it can only be
//! written once the whole input has been seen.
//!
//! `ZNS1` (streaming), emitted by [`ZnnWriter`]:
//!
//! ```text
//! header:  "ZNS1" [version u8] [flags u8] [elem u8] [exp_group u8] [chunk_size u32]
//! frame:   0xF5 [n_streams u32] [entries: n_streams × (method u8, comp u32, raw u32)]
//!          [payload: concatenated streams]
//! pframe:  0xF7 [elem u8] [exp_group u8] [n_streams u32] [entries …] [payload …]
//! trailer: 0xF6 [tail_len u8] [tail bytes] [total_len u64] [checksum u64 if flagged]
//! ```
//!
//! One frame holds one super-chunk ([`SUPER_CHUNK`] chunks), so the frame
//! boundaries — and therefore the emitted bytes — are identical for any
//! split of the incoming writes and any thread count. A non-element-aligned
//! tail (< `elem` ≤ 16 bytes) rides in the trailer verbatim, so every chunk
//! keeps the full byte-group layout.
//!
//! A writer built with [`ZnnWriter::with_profiles`] selects a
//! [`CodecProfile`] per frame (the dominant tensor of the frame's raw
//! range picks it) and records the chosen byte-group layout in a `0xF7`
//! **profiled frame** prefix, so readers decode each frame with the
//! layout it was encoded with. Containers written without profiles are
//! byte-identical to previous releases (`0xF5` frames only); a profiled
//! container is flagged in the header (`flags` bit 1) and rejected
//! cleanly — "bad frame marker" — by profile-unaware readers.
//!
//! ## Worked example
//!
//! ```
//! use std::io::{Read, Write};
//! use zipnn::codec::{CodecConfig, ZnnReader, ZnnWriter};
//! use zipnn::fp::DType;
//!
//! // Compress incrementally: feed whatever slices arrive.
//! let cfg = CodecConfig::for_dtype(DType::BF16);
//! let mut w = ZnnWriter::new(Vec::new(), cfg).unwrap();
//! for part in [&[1u8, 2][..], &[3, 4, 5, 6][..], &[7, 8][..]] {
//!     w.write_all(part).unwrap();
//! }
//! let container: Vec<u8> = w.finish().unwrap();
//!
//! // Decompress incrementally from any reader.
//! let mut r = ZnnReader::new(container.as_slice()).unwrap();
//! let mut back = Vec::new();
//! r.read_to_end(&mut back).unwrap();
//! assert_eq!(back, [1, 2, 3, 4, 5, 6, 7, 8]);
//! ```

use crate::codec::auto::{AutoPolicy, Decision, Method, ProfileSelector};
// MAX_CHUNK_SIZE is shared with the ZNN1 parser so the two formats'
// corruption guards cannot drift.
use crate::codec::container::{StreamEntry, MAX_CHUNK_SIZE};
use crate::codec::index::{self, ContainerKind, TensorIndex, TensorMeta};
use crate::codec::{CodecConfig, CodecProfile, MethodPolicy};
use crate::coordinator::{shared_pool, StickyMap, WorkerPool};
use crate::error::{Error, Result};
use crate::fp::{merge_groups_into, split_groups_into, GroupLayout};
use crate::huffman;
use crate::lz;
use crate::stats::{byte_histogram, zero_stats};
use crate::util::mmap::Mmap;
use std::io::{self, Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Chunks per super-chunk: the work granule of both batch engine
/// directions and the `ZNS1` frame size. The probe-and-skip state
/// ([`crate::codec::auto::AutoPolicy`]) resets at every super-chunk
/// boundary, in serial and parallel mode alike, so compressed output is
/// byte-identical regardless of thread count — a property the
/// integration tests assert.
pub const SUPER_CHUNK: usize = 16;

/// Streaming container magic.
pub const STREAM_MAGIC: [u8; 4] = *b"ZNS1";
/// Streaming container version.
pub const STREAM_VERSION: u8 = 1;
/// Frame marker byte.
pub(crate) const MARK_FRAME: u8 = 0xF5;
/// Trailer marker byte.
pub(crate) const MARK_END: u8 = 0xF6;
/// Profiled-frame marker byte: the frame carries a 2-byte
/// `[elem, exp_group]` layout prefix before the stream count.
pub(crate) const MARK_PFRAME: u8 = 0xF7;
/// Header flag: trailer carries a checksum.
pub(crate) const SFLAG_CHECKSUM: u8 = 1;
/// Header flag: frames record per-frame codec profiles (`0xF7` frames).
/// Informational — the frame markers alone drive decoding — but it lets
/// tools distinguish profiled containers without scanning frames.
pub(crate) const SFLAG_PROFILES: u8 = 2;
/// Header flag: every frame carries a checksum of its stream table +
/// payload (a `u64` right after the stream count). Opt-in
/// ([`ZnnWriter::with_frame_checksums`]); flag-free containers are
/// byte-identical to writers without the feature. Frame granularity is
/// what resilient transfer needs: a corrupt byte pins down one frame to
/// refetch (or salvage around) instead of failing only at the
/// whole-stream trailer checksum, and ranged reads (`decode_range`,
/// `decode_tensor`) can verify just their covering frames.
pub(crate) const SFLAG_FRAME_CK: u8 = 4;
/// `ZNS1` header length.
pub(crate) const STREAM_HEADER_LEN: usize = 12;

/// Patch a 12-byte `ZNS1` header to drop its checksum flag, and build the
/// matching trailer for a sub-container of `raw_len` decoded bytes plus
/// `tail` trailing bytes. Used by the hub's tensor range-GET path: the
/// server re-heads the covering frames so a plain [`ZnnReader`] on the
/// client decodes them (a sub-range cannot verify the whole-stream
/// checksum, hence the flag strip).
pub fn sub_container_parts(header: &[u8], raw_len: u64, tail: &[u8]) -> Result<(Vec<u8>, Vec<u8>)> {
    if header.len() != STREAM_HEADER_LEN || header[0..4] != STREAM_MAGIC {
        return Err(Error::Corrupt("not a ZNS1 header".into()));
    }
    let mut head = header.to_vec();
    head[5] &= !SFLAG_CHECKSUM;
    let mut trailer = Vec::with_capacity(2 + tail.len() + 8);
    trailer.push(MARK_END);
    trailer.push(tail.len() as u8);
    trailer.extend_from_slice(tail);
    trailer.extend_from_slice(&(raw_len + tail.len() as u64).to_le_bytes());
    Ok((head, trailer))
}

// ---------------------------------------------------------------------------
// Scratch arena
// ---------------------------------------------------------------------------

/// Reusable per-worker scratch for the codec hot paths.
///
/// One arena serves one worker for its whole lifetime; every buffer is
/// length-set and refilled per chunk or per super-chunk (reusing its
/// initialized spare capacity — no memset of bytes about to be
/// overwritten), so after a few super-chunks of warm-up the steady state
/// performs no allocations at all on the Huffman/Raw/Zero paths, and the
/// Zstd path reuses one worst-case-bound destination buffer. Both engine
/// directions — the decode pool (PR 3) and the encode pool — keep one
/// arena per shared-pool worker in its sticky state, warm across batches,
/// writers, readers, and files.
///
/// The decode side additionally caches built Huffman decode tables per
/// `(worker, table-bytes)` in [`huffman::DecodeTableCache`]: repeated
/// tables skip the 8 KiB build entirely, and evictions recycle the box.
#[derive(Default)]
pub struct ScratchArena {
    /// Per-group split (compress) / decode (decompress) buffers.
    pub(crate) groups: Vec<Vec<u8>>,
    /// Stream-table entries of the super-chunk in flight.
    pub(crate) entries: Vec<StreamEntry>,
    /// Concatenated compressed streams of the super-chunk in flight.
    pub(crate) payload: Vec<u8>,
    /// Zstd destination scratch (compress only): one worst-case-bound
    /// buffer per worker instead of a fresh `Vec` per Zstd stream.
    pub(crate) zstd_dst: Vec<u8>,
    /// Decode-table cache (decompress only; empty on the compress side).
    pub(crate) tables: huffman::DecodeTableCache,
}

impl ScratchArena {
    /// New, empty arena (buffers grow on first use and are then reused).
    pub fn new() -> ScratchArena {
        ScratchArena::default()
    }
}

// ---------------------------------------------------------------------------
// Incremental checksum
// ---------------------------------------------------------------------------

const CK_INIT: u64 = 0x9E37_79B9_7F4A_7C15;
const CK_MUL: u64 = 0xA24B_AED4_963E_E407;

/// Incremental form of [`crate::codec::checksum64`].
///
/// `with_total_len` reproduces `checksum64` exactly when the total length
/// is known up front (the `ZNN1` reading path). `streaming` defers the
/// length mix to `finalize` for writers that do not know the length yet
/// (the `ZNS1` trailer checksum) — same word mixing, different whole-stream
/// value.
pub(crate) struct Checksummer {
    acc: u64,
    pending: [u8; 8],
    pending_len: usize,
    total: u64,
    mix_len_at_end: bool,
}

impl Checksummer {
    /// `checksum64`-compatible: the caller knows the total length.
    pub(crate) fn with_total_len(len: u64) -> Checksummer {
        Checksummer {
            acc: CK_INIT ^ len,
            pending: [0; 8],
            pending_len: 0,
            total: 0,
            mix_len_at_end: false,
        }
    }

    /// Length mixed at the end (the `ZNS1` trailer variant).
    pub(crate) fn streaming() -> Checksummer {
        Checksummer {
            acc: CK_INIT,
            pending: [0; 8],
            pending_len: 0,
            total: 0,
            mix_len_at_end: true,
        }
    }

    /// Fold more bytes in. Word boundaries are absolute stream offsets, so
    /// any split of the input produces the same result.
    pub(crate) fn update(&mut self, mut data: &[u8]) {
        self.total += data.len() as u64;
        if self.pending_len > 0 {
            while self.pending_len < 8 && !data.is_empty() {
                self.pending[self.pending_len] = data[0];
                self.pending_len += 1;
                data = &data[1..];
            }
            if self.pending_len < 8 {
                return;
            }
            let w = u64::from_le_bytes(self.pending);
            self.acc = self.acc.wrapping_add(w).rotate_left(17).wrapping_mul(CK_MUL);
            self.pending_len = 0;
        }
        let mut chunks = data.chunks_exact(8);
        for c in &mut chunks {
            let w = u64::from_le_bytes(c.try_into().unwrap());
            self.acc = self.acc.wrapping_add(w).rotate_left(17).wrapping_mul(CK_MUL);
        }
        let rem = chunks.remainder();
        self.pending[..rem.len()].copy_from_slice(rem);
        self.pending_len = rem.len();
    }

    /// Finish and return the checksum.
    pub(crate) fn finalize(self) -> u64 {
        let mut acc = self.acc;
        if self.pending_len > 0 {
            let mut b = [0u8; 8];
            b[..self.pending_len].copy_from_slice(&self.pending[..self.pending_len]);
            acc = acc.wrapping_add(u64::from_le_bytes(b)).rotate_left(17);
        }
        if self.mix_len_at_end {
            acc = (acc ^ self.total).rotate_left(29).wrapping_mul(CK_MUL);
        }
        acc
    }
}

// ---------------------------------------------------------------------------
// Shared compression core
// ---------------------------------------------------------------------------

/// Compress one super-chunk's raw bytes, appending table entries to
/// `entries` and the concatenated streams to `payload`.
///
/// `data` must be the super-chunk's exact raw bytes (1..=[`SUPER_CHUNK`]
/// chunks; the last may be short) and a multiple of the profile's
/// `layout.elem`. The probe-and-skip state resets here, at the
/// super-chunk boundary, which is what makes the output independent of
/// thread count and write splits.
pub(crate) fn compress_super_chunk(
    profile: &CodecProfile,
    chunk_size: usize,
    data: &[u8],
    scratch: CompressScratch<'_>,
    entries: &mut Vec<StreamEntry>,
    payload: &mut Vec<u8>,
) {
    let CompressScratch { groups: group_scratch, zstd_dst } = scratch;
    let layout = profile.layout;
    let groups = layout.groups();
    let mut policy = AutoPolicy::new(groups, profile.skip_window);
    for chunk in data.chunks(chunk_size) {
        split_groups_into(chunk, layout, group_scratch).expect("aligned by construction");
        for (gi, g) in group_scratch.iter().enumerate() {
            entries.push(compress_stream_into(profile, gi, g, &mut policy, zstd_dst, payload));
        }
    }
}

/// The compression-side pieces of a [`ScratchArena`] — the per-group
/// split buffers and the zstd destination buffer — borrowed together so
/// the same arena's `entries`/`payload` stay independently borrowable.
pub(crate) struct CompressScratch<'a> {
    pub(crate) groups: &'a mut Vec<Vec<u8>>,
    pub(crate) zstd_dst: &'a mut Vec<u8>,
}

/// Compress one group stream according to the configured policy, appending
/// its bytes to `payload`. Decision logic is shared verbatim with the
/// historical one-shot path, so containers stay byte-identical.
fn compress_stream_into(
    profile: &CodecProfile,
    group: usize,
    data: &[u8],
    policy: &mut AutoPolicy,
    zstd_scratch: &mut Vec<u8>,
    payload: &mut Vec<u8>,
) -> StreamEntry {
    let raw_len = data.len() as u32;
    let store_raw = |payload: &mut Vec<u8>| {
        payload.extend_from_slice(data);
        StreamEntry { method: Method::Raw, comp_len: raw_len, raw_len }
    };
    match profile.policy {
        MethodPolicy::Raw => store_raw(payload),
        MethodPolicy::Huffman => huffman_or_raw_into(data, None, group, policy, false, payload),
        MethodPolicy::Zstd => zstd_or_raw_into(profile.zstd_level, data, zstd_scratch, payload),
        MethodPolicy::Auto => {
            if policy.take_skip(group) {
                return store_raw(payload);
            }
            // One histogram pass feeds both the decision and Huffman.
            let hist = byte_histogram(data);
            match policy.decide_with_hist(data, &hist) {
                Decision::SkipRaw => store_raw(payload),
                Decision::Zero => StreamEntry { method: Method::Zero, comp_len: 0, raw_len },
                Decision::TryZstd => {
                    zstd_or_raw_into(profile.zstd_level, data, zstd_scratch, payload)
                }
                Decision::TryHuffman => {
                    huffman_or_raw_into(data, Some(&hist), group, policy, true, payload)
                }
            }
        }
    }
}

fn huffman_or_raw_into(
    data: &[u8],
    hist: Option<&[u64; 256]>,
    group: usize,
    policy: &mut AutoPolicy,
    report: bool,
    payload: &mut Vec<u8>,
) -> StreamEntry {
    let base = payload.len();
    let enc_len = match hist {
        Some(h) => huffman::compress_into(data, h, payload),
        None => {
            let h = byte_histogram(data);
            huffman::compress_into(data, &h, payload)
        }
    };
    if report {
        policy.report(group, data.len(), enc_len);
    }
    if enc_len < data.len() {
        StreamEntry {
            method: Method::Huffman,
            comp_len: enc_len as u32,
            raw_len: data.len() as u32,
        }
    } else {
        payload.truncate(base);
        payload.extend_from_slice(data);
        StreamEntry {
            method: Method::Raw,
            comp_len: data.len() as u32,
            raw_len: data.len() as u32,
        }
    }
}

fn zstd_or_raw_into(
    level: i32,
    data: &[u8],
    scratch: &mut Vec<u8>,
    payload: &mut Vec<u8>,
) -> StreamEntry {
    // An all-zero stream is cheaper as Zero even under forced-Zstd.
    if !data.is_empty() && zero_stats(data).zero_frac >= 1.0 {
        return StreamEntry {
            method: Method::Zero,
            comp_len: 0,
            raw_len: data.len() as u32,
        };
    }
    // Compress into the sticky per-worker scratch (grown once to the
    // worst-case bound) instead of a fresh `Vec` per stream; the bytes
    // are identical to the allocating path the golden test freezes.
    match lz::zstd_compress_into(data, level, scratch) {
        Ok(n) if n < data.len() => {
            payload.extend_from_slice(&scratch[..n]);
            StreamEntry {
                method: Method::Zstd,
                comp_len: n as u32,
                raw_len: data.len() as u32,
            }
        }
        _ => {
            payload.extend_from_slice(data);
            StreamEntry {
                method: Method::Raw,
                comp_len: data.len() as u32,
                raw_len: data.len() as u32,
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Shared decompression core
// ---------------------------------------------------------------------------

/// Decode one compressed stream into an exactly-sized output buffer.
/// `tables` is the worker's decode-table cache.
pub(crate) fn decode_stream_into(
    method: Method,
    stream: &[u8],
    out: &mut [u8],
    tables: &mut huffman::DecodeTableCache,
) -> Result<()> {
    match method {
        Method::Raw => {
            if stream.len() != out.len() {
                return Err(Error::Corrupt("raw stream length mismatch".into()));
            }
            out.copy_from_slice(stream);
            Ok(())
        }
        Method::Zero => {
            out.fill(0);
            Ok(())
        }
        Method::Huffman => huffman::decompress_into_cached(stream, out, tables),
        Method::Zstd => {
            let dec = lz::zstd_decompress(stream, out.len())?;
            if dec.len() != out.len() {
                return Err(Error::Corrupt("zstd stream length mismatch".into()));
            }
            out.copy_from_slice(&dec);
            Ok(())
        }
    }
}

/// Decode one chunk: its `groups` streams (concatenated in `comp`) into
/// `out`, which must be exactly the chunk's raw size. `arena` supplies
/// the per-group buffers and the worker's decode-table cache.
pub(crate) fn decode_chunk_into(
    layout: GroupLayout,
    entries: &[StreamEntry],
    comp: &[u8],
    arena: &mut ScratchArena,
    out: &mut [u8],
) -> Result<()> {
    let groups = layout.groups();
    if entries.len() != groups {
        return Err(Error::Corrupt("chunk entry count mismatch".into()));
    }
    let ScratchArena { groups: scratch, tables, .. } = arena;
    scratch.resize_with(groups, Vec::new);
    let mut off = 0usize;
    for (g, e) in entries.iter().enumerate() {
        let end = off + e.comp_len as usize;
        let stream = comp
            .get(off..end)
            .ok_or_else(|| Error::Corrupt("stream extends past payload".into()))?;
        off = end;
        let buf = &mut scratch[g];
        // Length-set through spare capacity (every decode method fully
        // overwrites `buf` or errors): steady-state chunks of equal size
        // never memset bytes they are about to overwrite.
        crate::fp::bytegroup::set_group_len(buf, e.raw_len as usize);
        decode_stream_into(e.method, stream, buf, tables)?;
    }
    if off != comp.len() {
        return Err(Error::Corrupt("chunk payload length mismatch".into()));
    }
    // group refs on the stack: elem ≤ 16 by container validation
    let mut refs: [&[u8]; 16] = [&[]; 16];
    for (g, b) in scratch.iter().enumerate().take(groups) {
        refs[g] = b.as_slice();
    }
    merge_groups_into(&refs[..groups], layout, out)
}

// ---------------------------------------------------------------------------
// Byte sources: streamed or memory-mapped
// ---------------------------------------------------------------------------

/// Owned in-memory container bytes — a memory mapping or an
/// already-materialized buffer. Either way the decoder borrows payload
/// slices out of it without copying.
pub struct MappedBytes(MapInner);

enum MapInner {
    Map(Mmap),
    Owned(Vec<u8>),
}

impl MappedBytes {
    /// Wrap a memory mapping.
    pub fn from_mmap(map: Mmap) -> MappedBytes {
        MappedBytes(MapInner::Map(map))
    }

    /// Wrap an already-materialized buffer (the decoder borrows from it
    /// exactly like from a mapping).
    pub fn from_vec(bytes: Vec<u8>) -> MappedBytes {
        MappedBytes(MapInner::Owned(bytes))
    }

    /// True when backed by an actual memory mapping (page-cache served).
    pub fn is_mapped(&self) -> bool {
        matches!(self.0, MapInner::Map(_))
    }

    /// Best-effort prefetch hint for an upcoming byte range.
    fn prefetch(&self, off: usize, len: usize) {
        if let MapInner::Map(m) = &self.0 {
            m.advise_willneed(off, len);
        }
    }
}

impl std::ops::Deref for MappedBytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match &self.0 {
            MapInner::Map(m) => m.as_slice(),
            MapInner::Owned(v) => v.as_slice(),
        }
    }
}

/// Where a [`ZnnReader`] pulls compressed bytes from: any [`Read`]
/// (sockets, pipes, buffered files), or [`MappedBytes`] whose payload the
/// decoder borrows without copying.
pub struct ByteSource<R>(SourceInner<R>);

enum SourceInner<R> {
    Stream { inner: R, consumed: u64 },
    Mapped { bytes: MappedBytes, pos: usize },
}

impl<R: Read> ByteSource<R> {
    /// A sequential `io::Read` source (bytes are copied into the reader's
    /// batch buffer).
    pub fn stream(inner: R) -> ByteSource<R> {
        ByteSource(SourceInner::Stream { inner, consumed: 0 })
    }

    /// Container byte offset of the next unread byte, for both source
    /// kinds — so truncation errors can name where the container was cut
    /// instead of a source-dependent I/O message.
    fn consumed(&self) -> u64 {
        match &self.0 {
            SourceInner::Stream { consumed, .. } => *consumed,
            SourceInner::Mapped { pos, .. } => *pos as u64,
        }
    }

    /// Read exactly `out.len()` bytes (headers and small fields).
    fn read_exact(&mut self, out: &mut [u8]) -> io::Result<()> {
        match &mut self.0 {
            SourceInner::Stream { inner, consumed } => {
                inner.read_exact(out)?;
                *consumed += out.len() as u64;
                Ok(())
            }
            SourceInner::Mapped { bytes, pos } => {
                let data: &[u8] = bytes;
                let end = pos
                    .checked_add(out.len())
                    .filter(|&e| e <= data.len())
                    .ok_or_else(|| {
                        io::Error::new(io::ErrorKind::UnexpectedEof, "mapped container truncated")
                    })?;
                out.copy_from_slice(&data[*pos..end]);
                *pos = end;
                Ok(())
            }
        }
    }

    /// A payload slice previously recorded by `fetch_batch` (mapped
    /// sources only; the range was bounds-checked when recorded).
    fn mapped_slice(&self, off: usize, len: usize) -> &[u8] {
        match &self.0 {
            SourceInner::Mapped { bytes, .. } => &bytes[off..off + len],
            SourceInner::Stream { .. } => {
                unreachable!("payload recorded as mapped on a stream source")
            }
        }
    }

    /// The whole in-memory container, when this source is mapped/owned.
    fn mapped_bytes(&self) -> Option<&MappedBytes> {
        match &self.0 {
            SourceInner::Mapped { bytes, .. } => Some(bytes),
            SourceInner::Stream { .. } => None,
        }
    }
}

impl ByteSource<std::io::Empty> {
    /// A zero-copy source over owned bytes or a memory mapping.
    pub fn mapped(bytes: MappedBytes) -> ByteSource<std::io::Empty> {
        ByteSource(SourceInner::Mapped { bytes, pos: 0 })
    }
}

impl ByteSource<std::io::BufReader<std::fs::File>> {
    /// Open a file: memory-mapped zero-copy when the platform allows it
    /// (and `ZIPNN_NO_MMAP` is unset), otherwise a **streaming** buffered
    /// read — never a whole-file heap buffer, so multi-GB containers keep
    /// bounded memory on the fallback too.
    pub fn open(path: &Path) -> io::Result<ByteSource<std::io::BufReader<std::fs::File>>> {
        let file = std::fs::File::open(path)?;
        if !crate::util::env::no_mmap() {
            if let Ok(map) = Mmap::map(&file) {
                map.advise_sequential();
                return Ok(ByteSource(SourceInner::Mapped {
                    bytes: MappedBytes::from_mmap(map),
                    pos: 0,
                }));
            }
        }
        Ok(ByteSource(SourceInner::Stream {
            inner: std::io::BufReader::new(file),
            consumed: 0,
        }))
    }
}

/// Grow `v` to at least `len` initialized bytes. The length only ever
/// rises to the high-water mark, so steady-state refills never memset:
/// callers overwrite `v[..len]` and slice by their own length.
fn ensure_len(v: &mut Vec<u8>, len: usize) {
    if v.len() < len {
        v.resize(len, 0);
    }
}

// ---------------------------------------------------------------------------
// ZnnWriter
// ---------------------------------------------------------------------------

/// Streaming compressor: a [`Write`] adapter that emits a `ZNS1` container
/// to an inner sink, one frame per completed super-chunk.
///
/// Buffering is bounded: at most `threads × SUPER_CHUNK × chunk_size` raw
/// bytes are accumulated per batch (two batches in pooled mode),
/// independent of the total input size. Call [`ZnnWriter::finish`] to
/// compress the final partial chunk and write the trailer — dropping the
/// writer without finishing produces a truncated container that readers
/// reject.
///
/// With `threads > 1` (or `ZIPNN_ENCODE_WORKERS` set) batches compress on
/// the process-shared [`crate::coordinator::shared_pool`] — workers are
/// spawned once per process, their scratch arenas stay warm in per-worker
/// sticky state, and the writer is **double-buffered**: while batch N's
/// frames serialize to the inner sink (the I/O-bound tail), batch N+1's
/// super-chunks are already compressing on the pool. Frame boundaries are
/// fixed at super-chunk granularity, so the emitted bytes are identical
/// for any thread count, batch split, and write pattern.
pub struct ZnnWriter<W: Write> {
    inner: W,
    cfg: CodecConfig,
    layout: GroupLayout,
    chunk_size: usize,
    /// Effective encode parallelism (`ZIPNN_ENCODE_WORKERS` override or
    /// `cfg.threads`); `> 1` routes batches through the encode pipeline.
    threads: usize,
    /// `ZNS1` header, pending until the first byte reaches the sink —
    /// deferred so [`ZnnWriter::with_profiles`] can still patch its
    /// flags after construction. `None` once written.
    header: Option<[u8; STREAM_HEADER_LEN]>,
    /// Per-tensor profile selection (profile mode); `None` = the classic
    /// uniform writer, whose output bytes are unchanged.
    selector: Option<ProfileSelector>,
    /// Raw bytes already handed to `flush_compressible` — the raw offset
    /// of `buf[0]`, which profile mode maps through the selector to pick
    /// each frame's codec.
    flushed: u64,
    /// Scratch: the per-super-chunk profile table of the batch being
    /// submitted (copied into the pipeline at submit).
    profile_scratch: Vec<CodecProfile>,
    buf: Vec<u8>,
    batch_bytes: usize,
    arena: ScratchArena,
    /// Pooled pipelined encode state (`threads > 1` only, built on first
    /// flush). Owns the in-flight batch the pool compresses.
    pipe: Option<EncodePipeline>,
    head_buf: Vec<u8>,
    ck: Option<Checksummer>,
    total: u64,
    /// Container bytes emitted so far (header + frames).
    bytes_out: u64,
    /// File offset of every emitted frame (tracked only when indexing).
    frame_offsets: Vec<u64>,
    /// Tensor directory to append as an index section at `finish`.
    index_tensors: Option<Vec<TensorMeta>>,
    /// Set when a frame emission failed. A frame may then be *partially*
    /// on the sink, so no retry can produce a valid container — every
    /// later `write`/`flush`/`finish` reports the writer as broken
    /// instead of silently appending past the corruption.
    failed: bool,
    /// Emit a per-frame checksum after each frame's stream count
    /// ([`SFLAG_FRAME_CK`]); off by default so existing containers stay
    /// byte-identical.
    frame_ck: bool,
}

/// Double-buffered pooled encode state of a [`ZnnWriter`].
///
/// While the finished frames of batch N sit in `done` waiting to
/// serialize to the inner sink, batch N+1's super-chunks are already
/// compressing on the shared pool (`pending`, over `in_buf`/`in_slots`).
/// Dropping the pipeline joins any in-flight batch first — the pool
/// helpers hold raw pointers into its buffers.
struct EncodePipeline {
    engine: Engine,
    /// Profiles of the in-flight batch, behind a stable heap address: the
    /// task frame points at this vector's buffer, and the writer (or this
    /// pipeline) may move between writes. One entry per super-chunk in
    /// profile mode (`stride` 1), a single shared entry otherwise
    /// (`stride` 0).
    in_profiles: Vec<CodecProfile>,
    /// Profile-table stride of the batches this pipeline carries (fixed
    /// per writer: 1 = profiled, 0 = uniform).
    stride: usize,
    /// Raw bytes of the in-flight batch (swapped with the writer's fill
    /// buffer at submit, so the two ping-pong without reallocating).
    in_buf: Vec<u8>,
    /// Per-super-chunk `(entries, payload)` output slots, in flight.
    in_slots: Vec<EncodeSlot>,
    /// Profiles matching `done[..done_n]` — `emit_done` reads each
    /// finished frame's layout from here when serializing profiled
    /// frames.
    done_profiles: Vec<CodecProfile>,
    /// Finished frames awaiting serialization (`done[..done_n]`); their
    /// spare capacity becomes the next submission's slots.
    done: Vec<EncodeSlot>,
    done_n: usize,
    pending: Option<TaskFrame>,
    /// Caller-helps scratch for [`Engine::wait`].
    arena: ScratchArena,
}

impl EncodePipeline {
    fn new(stride: usize, threads: usize, batch_bytes: usize) -> EncodePipeline {
        EncodePipeline {
            engine: Engine::new(threads),
            in_profiles: Vec::new(),
            stride,
            in_buf: Vec::with_capacity(batch_bytes),
            in_slots: Vec::new(),
            done_profiles: Vec::new(),
            done: Vec::new(),
            done_n: 0,
            pending: None,
            arena: ScratchArena::new(),
        }
    }

    /// Join the in-flight batch, if any; its finished frames (and their
    /// profiles) rotate into `done`/`done_profiles` (and the previously
    /// emitted slots rotate in as spares).
    fn join(&mut self) -> Result<()> {
        if let Some(frame) = self.pending.take() {
            self.engine.wait(frame, &mut self.arena)?;
            std::mem::swap(&mut self.in_slots, &mut self.done);
            std::mem::swap(&mut self.in_profiles, &mut self.done_profiles);
            self.done_n = frame.n;
        }
        Ok(())
    }

    /// Swap `batch` (its first `len` bytes are the batch's raw input)
    /// into the pipeline, copy the batch's profile table (one entry per
    /// super-chunk at `stride` 1, a single shared entry at `stride` 0),
    /// and submit its super-chunks to the shared pool. Non-blocking; the
    /// previous batch must already be joined.
    fn submit(&mut self, batch: &mut Vec<u8>, len: usize, profiles: &[CodecProfile], chunk_size: usize) {
        debug_assert!(self.pending.is_none(), "previous batch must be joined");
        std::mem::swap(&mut self.in_buf, batch);
        let n_super = len.div_ceil(chunk_size).div_ceil(SUPER_CHUNK);
        debug_assert_eq!(profiles.len(), if self.stride == 0 { 1 } else { n_super });
        self.in_profiles.clear();
        self.in_profiles.extend_from_slice(profiles);
        if self.in_slots.len() < n_super {
            self.in_slots.resize_with(n_super, Default::default);
        }
        self.engine.epoch += 1;
        let frame = TaskFrame {
            epoch: self.engine.epoch,
            n: n_super,
            kind: TaskKind::Encode(EncodeFrame {
                profiles: self.in_profiles.as_ptr(),
                stride: self.stride,
                chunk_size,
                buf: self.in_buf.as_ptr(),
                len,
                slots: self.in_slots.as_mut_ptr(),
            }),
        };
        self.engine.submit(frame);
        self.pending = Some(frame);
    }
}

impl Drop for EncodePipeline {
    /// Join any in-flight encode before the batch buffers are freed (the
    /// pool helpers hold raw pointers into them while tasks are claimed).
    fn drop(&mut self) {
        if let Some(frame) = self.pending.take() {
            let _ = self.engine.wait(frame, &mut self.arena);
        }
    }
}

/// Effective encode parallelism: the `ZIPNN_ENCODE_WORKERS` environment
/// knob overrides the config's thread count, so deployments can put every
/// existing consumer — CLI `compress`, hub PUT/`upload_indexed`, delta
/// encodes, the checkpoint store — on the pooled pipelined path without
/// an API change. Batch sizing moves with it, but the emitted bytes never
/// do (frame boundaries are fixed at super-chunk granularity).
pub(crate) fn encode_workers(cfg_threads: usize) -> usize {
    crate::util::env::encode_workers().unwrap_or_else(|| cfg_threads.max(1))
}

/// Compress every super-chunk of `data` in order, returning one
/// `(entries, payload)` pair per super-chunk — the shared body of the
/// one-shot [`crate::codec::Compressor`]. `threads <= 1` compresses
/// inline with one scratch arena; otherwise the super-chunks run as
/// claimed tasks on the process-shared sticky pool (no per-call thread
/// spawns), with the calling thread helping so a busy pool can never
/// stall the caller. Output is byte-identical either way.
pub(crate) fn compress_supers(
    profile: &CodecProfile,
    chunk_size: usize,
    data: &[u8],
    threads: usize,
) -> Result<Vec<EncodeSlot>> {
    let groups = profile.layout.groups();
    let n_super = data.len().div_ceil(chunk_size).div_ceil(SUPER_CHUNK);
    let mut arena = ScratchArena::new();
    if threads <= 1 || n_super <= 1 {
        return Ok((0..n_super)
            .map(|si| {
                let (lo, hi) = super_chunk_span(chunk_size, data.len(), si);
                let mut entries = Vec::with_capacity(SUPER_CHUNK * groups);
                let mut payload = Vec::new();
                let ScratchArena { groups: scratch, zstd_dst, .. } = &mut arena;
                compress_super_chunk(
                    profile,
                    chunk_size,
                    &data[lo..hi],
                    CompressScratch { groups: scratch, zstd_dst },
                    &mut entries,
                    &mut payload,
                );
                (entries, payload)
            })
            .collect());
    }
    let mut slots: Vec<EncodeSlot> = Vec::new();
    slots.resize_with(n_super, Default::default);
    let mut engine = Engine::new(threads);
    engine.epoch += 1;
    let frame = TaskFrame {
        epoch: engine.epoch,
        n: n_super,
        kind: TaskKind::Encode(EncodeFrame {
            profiles: profile as *const CodecProfile,
            stride: 0,
            chunk_size,
            buf: data.as_ptr(),
            len: data.len(),
            slots: slots.as_mut_ptr(),
        }),
    };
    engine.submit(frame);
    // Joined before returning, so the frame's pointers (into `data`,
    // `slots`, and `profile`) never outlive this call; stale queued
    // helpers exit on the sealed progress without dereferencing them.
    engine.wait(frame, &mut arena)?;
    Ok(slots)
}

/// Decode every chunk of a `ZNN1` payload into `out` — the shared body
/// of the one-shot [`crate::codec::decompress`] wrapper, and the decode
/// twin of [`compress_supers`]. The stream table gives every chunk's
/// compressed span and output placement up front (the payload is the
/// streams concatenated in table order), so chunks decode independently
/// (paper §5.1). `threads <= 1` decodes inline with one scratch arena;
/// otherwise the chunks run as claimed tasks on the process-shared
/// sticky pool (no per-call thread spawns), with the calling thread
/// helping so a busy pool can never stall the caller.
pub(crate) fn decode_chunks(
    layout: GroupLayout,
    entries: &[StreamEntry],
    payload: &[u8],
    out: &mut [u8],
    threads: usize,
) -> Result<()> {
    let groups = layout.groups();
    if groups == 0 || entries.len() % groups != 0 {
        return Err(Error::Corrupt("stream table not a whole number of chunks".into()));
    }
    let n_chunks = entries.len() / groups;
    let mut spans = Vec::with_capacity(n_chunks);
    let (mut comp_off, mut out_off) = (0usize, 0usize);
    for (c, es) in entries.chunks_exact(groups).enumerate() {
        let comp_len: usize = es.iter().map(|e| e.comp_len as usize).sum();
        let out_len: usize = es.iter().map(|e| e.raw_len as usize).sum();
        spans.push(ChunkSpan {
            comp_off,
            comp_len,
            out_off,
            out_len,
            entry_off: c * groups,
            layout,
            groups,
        });
        comp_off += comp_len;
        out_off += out_len;
    }
    if comp_off != payload.len() {
        return Err(Error::Corrupt(format!(
            "payload is {} bytes, stream table covers {comp_off}",
            payload.len()
        )));
    }
    if out_off != out.len() {
        return Err(Error::Corrupt(format!(
            "output is {} bytes, stream table covers {out_off}",
            out.len()
        )));
    }
    let mut arena = ScratchArena::new();
    if threads <= 1 || n_chunks <= 1 {
        for (span, es) in spans.iter().zip(entries.chunks_exact(groups)) {
            let comp = &payload[span.comp_off..span.comp_off + span.comp_len];
            let dst = &mut out[span.out_off..span.out_off + span.out_len];
            decode_chunk_into(layout, es, comp, &mut arena, dst)?;
        }
        return Ok(());
    }
    let mut engine = Engine::new(threads);
    engine.epoch += 1;
    let frame = TaskFrame {
        epoch: engine.epoch,
        n: n_chunks,
        kind: TaskKind::Decode(DecodeFrame {
            entries: entries.as_ptr(),
            comp: payload.as_ptr(),
            spans: spans.as_ptr(),
            out: out.as_mut_ptr(),
        }),
    };
    engine.submit(frame);
    // Joined before returning, so the frame's pointers (into `entries`,
    // `payload`, `spans`, and `out`) never outlive this call.
    engine.wait(frame, &mut arena)
}

impl<W: Write> ZnnWriter<W> {
    /// Start a streaming container on `inner`. The header reaches the
    /// sink with the first flushed frame (or at `finish` for empty
    /// input), so builder methods like [`ZnnWriter::with_profiles`] can
    /// still adjust it.
    pub fn new(inner: W, cfg: CodecConfig) -> Result<ZnnWriter<W>> {
        let layout = cfg.layout;
        let elem = layout.elem;
        if elem == 0 || elem > 16 || layout.exp_group >= elem {
            return Err(Error::Invalid(format!(
                "bad layout elem={elem} exp_group={}",
                layout.exp_group
            )));
        }
        let chunk_size = cfg.chunk_size.max(elem) / elem * elem;
        let threads = encode_workers(cfg.threads);
        let batch_bytes = threads * SUPER_CHUNK * chunk_size;
        let mut header = [0u8; STREAM_HEADER_LEN];
        header[0..4].copy_from_slice(&STREAM_MAGIC);
        header[4] = STREAM_VERSION;
        header[5] = if cfg.checksum { SFLAG_CHECKSUM } else { 0 };
        header[6] = elem as u8;
        header[7] = layout.exp_group as u8;
        header[8..12].copy_from_slice(&(chunk_size as u32).to_le_bytes());
        Ok(ZnnWriter {
            inner,
            ck: cfg.checksum.then(Checksummer::streaming),
            cfg,
            layout,
            chunk_size,
            threads,
            header: Some(header),
            selector: None,
            flushed: 0,
            profile_scratch: Vec::new(),
            buf: Vec::with_capacity(batch_bytes),
            batch_bytes,
            arena: ScratchArena::new(),
            pipe: None,
            head_buf: Vec::new(),
            total: 0,
            bytes_out: STREAM_HEADER_LEN as u64,
            frame_offsets: Vec::new(),
            index_tensors: None,
            failed: false,
            frame_ck: false,
        })
    }

    /// Builder-style: compress each frame with the [`CodecProfile`] the
    /// selector picks for the frame's raw range (the dominant tensor by
    /// byte overlap decides; see [`ProfileSelector::profile_for_range`]),
    /// recording the chosen layout in a `0xF7` profiled-frame prefix so
    /// readers reverse each frame with the layout it was written with.
    ///
    /// Must be called before any bytes are written. Every profile the
    /// selector can hand out must have a layout whose `elem` (1..=16)
    /// divides this writer's chunk size — rejected here rather than
    /// producing an undecodable container. A final partial frame that is
    /// not aligned to its profile's element falls back to the flat
    /// (single-group) variant of that profile, so profile mode never
    /// carries a trailer tail.
    pub fn with_profiles(mut self, selector: ProfileSelector) -> Result<Self> {
        if self.total > 0 || self.header.is_none() {
            return Err(Error::Invalid(
                "with_profiles must be configured before any write".into(),
            ));
        }
        for p in selector.profiles() {
            let elem = p.layout.elem;
            if elem == 0 || elem > 16 || p.layout.exp_group >= elem {
                return Err(Error::Invalid(format!(
                    "bad profile layout elem={elem} exp_group={}",
                    p.layout.exp_group
                )));
            }
            if self.chunk_size % elem != 0 {
                return Err(Error::Invalid(format!(
                    "profile element size {elem} does not divide chunk size {}",
                    self.chunk_size
                )));
            }
        }
        if let Some(h) = self.header.as_mut() {
            h[5] |= SFLAG_PROFILES;
        }
        self.selector = Some(selector);
        Ok(self)
    }

    /// Builder-style: stamp every frame with a checksum of its stream
    /// table + payload (a `u64` after the stream count, flagged by
    /// [`SFLAG_FRAME_CK`] in the header), verified on every decode path.
    /// Corruption is then pinned to one frame — resumable downloads
    /// refetch just that frame, and salvage decodes around it — instead
    /// of only failing the whole-stream trailer checksum. Costs 8 bytes
    /// per `SUPER_CHUNK × chunk_size` raw bytes (~0.0005% at defaults).
    /// Must be called before any bytes are written; containers without
    /// the flag are byte-identical to prior writers.
    pub fn with_frame_checksums(mut self) -> Result<Self> {
        if self.total > 0 || self.header.is_none() {
            return Err(Error::Invalid(
                "with_frame_checksums must be configured before any write".into(),
            ));
        }
        if let Some(h) = self.header.as_mut() {
            h[5] |= SFLAG_FRAME_CK;
        }
        self.frame_ck = true;
        Ok(self)
    }

    /// Write the deferred header once, ahead of the first frame, the
    /// trailer, or an explicit flush.
    fn write_header_once(&mut self) -> Result<()> {
        if let Some(h) = self.header.take() {
            self.inner.write_all(&h)?;
        }
        Ok(())
    }

    /// The profile compressing the super-chunk at raw range
    /// `[start, start + len)`, with the flat fallback for a final
    /// non-element-aligned partial frame.
    fn profile_for_super(&self, start: u64, len: usize) -> CodecProfile {
        match &self.selector {
            Some(sel) => {
                let p = sel.profile_for_range(start, start + len as u64);
                if len % p.layout.elem != 0 {
                    CodecProfile { layout: GroupLayout::flat(), ..p }
                } else {
                    p
                }
            }
            None => self.cfg.profile(),
        }
    }

    /// Raw bytes accepted so far.
    pub fn raw_len(&self) -> u64 {
        self.total
    }

    /// Builder-style: append a tensor→chunk index section after the
    /// trailer at [`ZnnWriter::finish`] (see [`crate::codec::index`]).
    /// `tensors` describe byte ranges of the *raw* payload; ranges are
    /// validated against the total length at finish. Index-unaware
    /// readers decode the container unchanged.
    pub fn with_index(mut self, tensors: Vec<TensorMeta>) -> Self {
        self.index_tensors = Some(tensors);
        self
    }

    /// Record one emitted frame's placement and size.
    fn note_frame(&mut self, n_entries: usize, payload_len: usize, profiled: bool) {
        note_frame_at(
            self.index_tensors.is_some(),
            &mut self.frame_offsets,
            &mut self.bytes_out,
            n_entries,
            payload_len,
            profiled,
            self.frame_ck,
        );
    }

    /// Compress and emit every super-chunk in `buf[..len]`.
    ///
    /// Serial mode (`threads <= 1`) compresses inline and emits each
    /// frame immediately. Pooled mode is **pipelined**: the previous
    /// batch is joined (its frames land in the pipeline's `done` list),
    /// this batch is swapped in and submitted to the shared pool, and
    /// only then are the previous batch's frames serialized — the
    /// I/O-bound tail overlaps this batch's compression. `finish` drains
    /// the last in-flight batch.
    fn flush_compressible(&mut self, len: usize) -> Result<()> {
        if len == 0 {
            return Ok(());
        }
        self.write_header_once()?;
        let profiled = self.selector.is_some();
        let base = self.flushed;
        self.flushed += len as u64;
        if self.threads <= 1 {
            let n_chunks = len.div_ceil(self.chunk_size);
            let n_super = n_chunks.div_ceil(SUPER_CHUNK);
            for si in 0..n_super {
                let (lo, hi) = super_chunk_span(self.chunk_size, len, si);
                let profile = self.profile_for_super(base + lo as u64, hi - lo);
                let ScratchArena { groups, zstd_dst, entries, payload, .. } = &mut self.arena;
                entries.clear();
                payload.clear();
                compress_super_chunk(
                    &profile,
                    self.chunk_size,
                    &self.buf[lo..hi],
                    CompressScratch { groups, zstd_dst },
                    entries,
                    payload,
                );
                let (n_entries, payload_len) = (entries.len(), payload.len());
                emit_frame(
                    &mut self.inner,
                    &mut self.head_buf,
                    profiled.then_some(profile.layout),
                    self.frame_ck,
                    entries,
                    payload,
                )?;
                self.note_frame(n_entries, payload_len, profiled);
            }
            return Ok(());
        }
        // Resolve the batch's profile table before borrowing the
        // pipeline (one entry per super-chunk in profile mode, a single
        // shared entry otherwise).
        self.profile_scratch.clear();
        if profiled {
            let n_super = len.div_ceil(self.chunk_size).div_ceil(SUPER_CHUNK);
            for si in 0..n_super {
                let (lo, hi) = super_chunk_span(self.chunk_size, len, si);
                let p = self.profile_for_super(base + lo as u64, hi - lo);
                self.profile_scratch.push(p);
            }
        } else {
            self.profile_scratch.push(self.cfg.profile());
        }
        if self.pipe.is_none() {
            let stride = if profiled { 1 } else { 0 };
            self.pipe = Some(EncodePipeline::new(stride, self.threads, self.batch_bytes));
        }
        let pipe = self.pipe.as_mut().expect("just created");
        pipe.join()?;
        // `buf` and the pipeline's batch buffer swap roles: the full
        // batch moves in for compression, the previous (already
        // compressed) buffer comes back as the next fill buffer.
        pipe.submit(&mut self.buf, len, &self.profile_scratch, self.chunk_size);
        self.buf.clear();
        self.emit_done()
    }

    /// Serialize the pipeline's finished frames (the *previous* batch) to
    /// the inner sink, recording their placement. No-op when nothing is
    /// waiting.
    fn emit_done(&mut self) -> Result<()> {
        let profiled = self.selector.is_some();
        let frame_ck = self.frame_ck;
        let Some(pipe) = self.pipe.as_mut() else {
            return Ok(());
        };
        for (i, (entries, payload)) in pipe.done[..pipe.done_n].iter().enumerate() {
            let layout = profiled.then(|| pipe.done_profiles[i].layout);
            emit_frame(&mut self.inner, &mut self.head_buf, layout, frame_ck, entries, payload)?;
            // Field-level borrows: the live borrow of `pipe` keeps the
            // whole-`self` `note_frame` method out of reach here.
            note_frame_at(
                self.index_tensors.is_some(),
                &mut self.frame_offsets,
                &mut self.bytes_out,
                entries.len(),
                payload.len(),
                profiled,
                frame_ck,
            );
        }
        pipe.done_n = 0;
        Ok(())
    }

    /// Join and serialize whatever the pipeline still holds (the
    /// in-flight final batch); called by `finish` before the trailer.
    fn drain_pipe(&mut self) -> Result<()> {
        if let Some(pipe) = self.pipe.as_mut() {
            pipe.join()?;
        }
        self.emit_done()
    }

    /// Compress the final partial chunk, write the trailer, flush, and
    /// return the inner sink.
    pub fn finish(mut self) -> Result<W> {
        if self.failed {
            return Err(Error::Invalid(BROKEN_WRITER.into()));
        }
        self.write_header_once()?;
        // Profile mode never leaves a trailer tail: an unaligned final
        // frame compresses under the flat fallback layout instead.
        let tail_len = if self.selector.is_some() {
            0
        } else {
            self.buf.len() % self.layout.elem
        };
        let comp_len = self.buf.len() - tail_len;
        // Captured before the flush: the pipelined path swaps `buf` into
        // the encode pipeline.
        let tail = self.buf[comp_len..comp_len + tail_len].to_vec();
        self.flush_compressible(comp_len)?;
        self.drain_pipe()?;
        let trailer_off = self.bytes_out;
        let mut trailer = Vec::with_capacity(2 + tail_len + 16);
        trailer.push(MARK_END);
        trailer.push(tail_len as u8);
        trailer.extend_from_slice(&tail);
        trailer.extend_from_slice(&self.total.to_le_bytes());
        if let Some(ck) = self.ck.take() {
            trailer.extend_from_slice(&ck.finalize().to_le_bytes());
        }
        self.inner.write_all(&trailer)?;
        if let Some(tensors) = self.index_tensors.take() {
            for t in &tensors {
                let end = t.offset.checked_add(t.len).ok_or_else(|| {
                    Error::Invalid(format!("tensor '{}' range overflows", t.name))
                })?;
                if end > self.total {
                    return Err(Error::Invalid(format!(
                        "tensor '{}' extends past payload ({end} > {})",
                        t.name, self.total
                    )));
                }
            }
            let idx = TensorIndex {
                kind: ContainerKind::Streaming,
                total_len: self.total,
                chunk_size: self.chunk_size as u32,
                tail,
                trailer_off,
                frame_offsets: std::mem::take(&mut self.frame_offsets),
                tensors,
            };
            self.inner.write_all(&idx.encode())?;
        }
        self.inner.flush()?;
        Ok(self.inner)
    }
}

/// Container bytes one frame occupies on the wire: marker (+ 2-byte
/// layout prefix for profiled `0xF7` frames) + stream count (+ 8-byte
/// frame checksum when flagged) + the 9-byte entry rows + the payload.
/// Must mirror [`emit_frame`]'s serialization exactly —
/// `bytes_out`/`frame_offsets` (and through them the tensor index and
/// `trailer_off`) are derived from it.
fn frame_wire_len(n_entries: usize, payload_len: usize, profiled: bool, frame_ck: bool) -> u64 {
    let prefix = if profiled { 2 } else { 0 };
    let ck = if frame_ck { 8 } else { 0 };
    5 + prefix + ck + 9 * n_entries as u64 + payload_len as u64
}

/// Record one emitted frame's placement into the index bookkeeping and
/// the running container byte count — the one accounting body behind
/// both the serial emit path and the pooled `emit_done` loop.
#[allow(clippy::too_many_arguments)]
fn note_frame_at(
    index_on: bool,
    frame_offsets: &mut Vec<u64>,
    bytes_out: &mut u64,
    n_entries: usize,
    payload_len: usize,
    profiled: bool,
    frame_ck: bool,
) {
    if index_on {
        frame_offsets.push(*bytes_out);
    }
    *bytes_out += frame_wire_len(n_entries, payload_len, profiled, frame_ck);
}

/// The byte range of super-chunk `si` within a batch of `len` raw bytes
/// — the one definition of super-chunk geometry shared by the serial
/// writer, the serial one-shot, and the pooled engine task.
fn super_chunk_span(chunk_size: usize, len: usize, si: usize) -> (usize, usize) {
    let super_bytes = SUPER_CHUNK * chunk_size;
    (si * super_bytes, ((si + 1) * super_bytes).min(len))
}

/// Serialize and write one frame (`entries` + `payload` of one
/// super-chunk). `head_buf` is recycled scratch for the entry table.
/// `profile` adds the `0xF7` per-frame layout prefix; `None` emits the
/// classic `0xF5` frame byte-for-byte. `frame_ck` inserts the
/// [`SFLAG_FRAME_CK`] checksum — a `u64` over entry rows + payload —
/// right after the stream count.
fn emit_frame<W: Write>(
    inner: &mut W,
    head_buf: &mut Vec<u8>,
    profile: Option<GroupLayout>,
    frame_ck: bool,
    entries: &[StreamEntry],
    payload: &[u8],
) -> Result<()> {
    head_buf.clear();
    match profile {
        Some(layout) => {
            head_buf.push(MARK_PFRAME);
            head_buf.push(layout.elem as u8);
            head_buf.push(layout.exp_group as u8);
        }
        None => head_buf.push(MARK_FRAME),
    }
    head_buf.extend_from_slice(&(entries.len() as u32).to_le_bytes());
    let ck_at = frame_ck.then(|| {
        let at = head_buf.len();
        head_buf.extend_from_slice(&[0u8; 8]);
        at
    });
    let rows_at = head_buf.len();
    for e in entries {
        head_buf.push(e.method.tag());
        head_buf.extend_from_slice(&e.comp_len.to_le_bytes());
        head_buf.extend_from_slice(&e.raw_len.to_le_bytes());
    }
    if let Some(at) = ck_at {
        let mut ck = Checksummer::streaming();
        ck.update(&head_buf[rows_at..]);
        ck.update(payload);
        let sum = ck.finalize().to_le_bytes();
        head_buf[at..at + 8].copy_from_slice(&sum);
    }
    inner.write_all(head_buf)?;
    inner.write_all(payload)?;
    Ok(())
}

/// Error text for operations on a writer whose emission already failed.
const BROKEN_WRITER: &str = "ZnnWriter previously failed; container is incomplete";

impl<W: Write> Write for ZnnWriter<W> {
    fn write(&mut self, data: &[u8]) -> io::Result<usize> {
        if self.failed {
            return Err(io::Error::new(io::ErrorKind::Other, BROKEN_WRITER));
        }
        if let Some(ck) = self.ck.as_mut() {
            ck.update(data);
        }
        self.total += data.len() as u64;
        let mut rest = data;
        while !rest.is_empty() {
            let space = self.batch_bytes - self.buf.len();
            let take = space.min(rest.len());
            self.buf.extend_from_slice(&rest[..take]);
            rest = &rest[take..];
            if self.buf.len() == self.batch_bytes {
                if let Err(e) = self.flush_compressible(self.batch_bytes) {
                    self.failed = true;
                    return Err(to_io_err(e));
                }
                self.buf.clear();
            }
        }
        Ok(data.len())
    }

    /// Flushes the inner sink. Every completed batch's frames reach the
    /// sink first — pooled mode joins and serializes the in-flight batch
    /// (this is the durability point a caller is asking for) — while a
    /// partial chunk stays buffered until [`ZnnWriter::finish`].
    fn flush(&mut self) -> io::Result<()> {
        if self.failed {
            return Err(io::Error::new(io::ErrorKind::Other, BROKEN_WRITER));
        }
        if let Err(e) = self.write_header_once().and_then(|()| self.drain_pipe()) {
            self.failed = true;
            return Err(to_io_err(e));
        }
        self.inner.flush()
    }
}

fn to_io_err(e: Error) -> io::Error {
    match e {
        Error::Io(io) => io,
        other => io::Error::new(io::ErrorKind::InvalidData, other.to_string()),
    }
}

fn from_io_err(e: io::Error) -> Error {
    if e.kind() == io::ErrorKind::InvalidData {
        Error::Corrupt(e.to_string())
    } else {
        Error::Io(e)
    }
}

// ---------------------------------------------------------------------------
// ZnnReader
// ---------------------------------------------------------------------------

enum ReaderState {
    /// One-shot `ZNN1` container: table read up front, payload streamed.
    V1 {
        layout: GroupLayout,
        total_len: u64,
        checksum: Option<u64>,
        entries: Vec<StreamEntry>,
        groups: usize,
        next_chunk: usize,
        n_chunks: usize,
    },
    /// Streaming `ZNS1` container: frame by frame.
    V2 {
        layout: GroupLayout,
        chunk_size: u32,
        has_checksum: bool,
        /// Frames carry a [`SFLAG_FRAME_CK`] checksum, verified per fetch.
        frame_ck: bool,
        groups: usize,
        /// Frames fetched so far — names the frame in truncation and
        /// checksum-mismatch errors.
        frame: u64,
    },
    Done,
}

/// One decode batch's staging and output buffers. Two of these
/// double-buffer the pipelined refill; every vector keeps its high-water
/// capacity (and the byte buffers their high-water *length*) across
/// batches, so steady-state refills neither allocate nor memset.
struct BatchBuf {
    /// Stream entries of the batch, chunk-major (copied from the table
    /// for `ZNN1`, parsed from the frame for `ZNS1`).
    entries: Vec<StreamEntry>,
    /// Compressed payload copy (stream sources; unused when mapped).
    comp: Vec<u8>,
    /// Per-chunk placement within the payload and the output.
    spans: Vec<ChunkSpan>,
    /// Decoded raw bytes; only `out[..out_len]` is meaningful.
    out: Vec<u8>,
    /// Where the batch's payload bytes live.
    payload: PayloadAt,
    comp_len: usize,
    out_len: usize,
    n_chunks: usize,
    layout: GroupLayout,
    groups: usize,
}

impl BatchBuf {
    fn new() -> BatchBuf {
        BatchBuf {
            entries: Vec::new(),
            comp: Vec::new(),
            spans: Vec::new(),
            out: Vec::new(),
            payload: PayloadAt::Buf,
            comp_len: 0,
            out_len: 0,
            n_chunks: 0,
            layout: GroupLayout::flat(),
            groups: 0,
        }
    }
}

#[derive(Clone, Copy)]
enum PayloadAt {
    /// In the batch's own `comp` buffer.
    Buf,
    /// Borrowed zero-copy from the mapped source at this offset.
    Mapped(usize),
}

#[derive(Clone, Copy)]
struct ChunkSpan {
    comp_off: usize,
    comp_len: usize,
    out_off: usize,
    out_len: usize,
    /// Index of this chunk's first entry in the batch entry list.
    entry_off: usize,
    /// Byte-group geometry the chunk was encoded with — per-frame in a
    /// profiled `ZNS1` container, the container layout otherwise.
    layout: GroupLayout,
    groups: usize,
}

/// Outcome of fetching the next decode batch from the source.
enum Fetch {
    /// One batch's entries + compressed bytes are staged in the buffer.
    Batch,
    /// Container exhausted (`ZNS1` trailer or `ZNN1` table end).
    End(EndInfo),
}

/// Everything needed to finalize a container once all batches decoded.
#[derive(Clone, Copy)]
struct EndInfo {
    /// Non-element-aligned trailing bytes (`ZNS1` trailer; empty for `ZNN1`).
    tail: [u8; 16],
    tail_len: usize,
    total_len: u64,
    checksum: Option<u64>,
}

// ---------------------------------------------------------------------------
// Persistent-pool batch engine (decode chunks / encode super-chunks)
// ---------------------------------------------------------------------------

/// Raw view of one submitted batch, captured by pool helper jobs.
///
/// Plain pointers and scalars (`Copy`), so a queued helper holds no
/// borrow; it only dereferences the pointers after claiming a task under
/// the frame's epoch, which guarantees the buffers are still alive. One
/// task is one decode chunk or one encode super-chunk.
#[derive(Clone, Copy)]
struct TaskFrame {
    epoch: u64,
    /// Number of claimable tasks in the batch.
    n: usize,
    kind: TaskKind,
}

#[derive(Clone, Copy)]
enum TaskKind {
    Decode(DecodeFrame),
    Encode(EncodeFrame),
}

/// Decode batch: task `c` decodes chunk `c` into its disjoint output
/// span. Each span carries its own layout/entry placement, so one batch
/// can mix frame geometries (profiled containers).
#[derive(Clone, Copy)]
struct DecodeFrame {
    entries: *const StreamEntry,
    comp: *const u8,
    spans: *const ChunkSpan,
    out: *mut u8,
}

/// Encode batch: task `si` compresses super-chunk `si` of `buf[..len]`
/// into its exclusively owned `(entries, payload)` slot.
#[derive(Clone, Copy)]
struct EncodeFrame {
    /// Profile table: task `si` compresses with `profiles[si * stride]`.
    /// `stride` 0 shares one profile batch-wide (the classic uniform
    /// writer and the one-shot compressor); `stride` 1 is the profiled
    /// writer's per-super-chunk table.
    profiles: *const CodecProfile,
    stride: usize,
    chunk_size: usize,
    buf: *const u8,
    len: usize,
    slots: *mut EncodeSlot,
}

/// One super-chunk's frame output: its stream-table entries and
/// concatenated compressed streams.
type EncodeSlot = (Vec<StreamEntry>, Vec<u8>);

// SAFETY: the pointers reference buffers owned by the submitting reader,
// writer, or one-shot compressor, which blocks (`Engine::wait`, also on
// drop) until every claimed task completes; decode output spans and
// encode slots are disjoint per task index, and stale helpers are fenced
// off by the epoch check before any dereference.
unsafe impl Send for TaskFrame {}

/// Shared progress of the (single) in-flight batch; one per reader,
/// reused across batches — allocated once.
#[derive(Default)]
struct BatchCtl {
    prog: Mutex<Progress>,
    cv: Condvar,
    /// Helper jobs currently queued or running on the pool; bounds the
    /// per-batch submission top-up.
    queued: AtomicUsize,
}

#[derive(Default)]
struct Progress {
    /// Epoch of the batch these counters describe; claims under any other
    /// epoch are refused (fences off stale queued helpers).
    epoch: u64,
    /// Next unclaimed task index.
    next: usize,
    /// Task count of the batch.
    n: usize,
    /// Claimed-but-unfinished tasks.
    active: usize,
    /// Finished tasks (success or failure).
    done: usize,
    /// First task error, if any (seals the batch).
    error: Option<Error>,
}

/// Decrements `active` (and seals on error/panic) even when a task
/// unwinds, so [`Engine::wait`] can never hang on a lost task.
struct ChunkDone<'a> {
    ctl: &'a BatchCtl,
    err: Option<Error>,
}

impl Drop for ChunkDone<'_> {
    fn drop(&mut self) {
        let mut p = self.ctl.prog.lock().unwrap();
        p.active -= 1;
        p.done += 1;
        if std::thread::panicking() && self.err.is_none() {
            self.err = Some(Error::Invalid("batch worker panicked".into()));
        }
        if let Some(e) = self.err.take() {
            if p.error.is_none() {
                p.error = Some(e);
            }
            p.next = p.n; // seal: no further chunks are claimed
        }
        let finished = p.active == 0 && p.next >= p.n;
        drop(p);
        if finished {
            self.ctl.cv.notify_all();
        }
    }
}

/// Claim-and-run loop shared by pool helpers and the calling thread:
/// tasks are decode chunks or encode super-chunks, claimed one at a time
/// under the frame's epoch.
fn run_frame_tasks(ctl: &BatchCtl, frame: TaskFrame, arena: &mut ScratchArena) {
    loop {
        let c = {
            let mut p = ctl.prog.lock().unwrap();
            // A claim is only valid under the frame's epoch: a helper left
            // over from a previous batch must never touch the current
            // batch's pointers.
            if p.epoch != frame.epoch || p.next >= p.n {
                return;
            }
            let c = p.next;
            p.next += 1;
            p.active += 1;
            c
        };
        let mut done = ChunkDone { ctl, err: None };
        // SAFETY: task `c` was claimed under the live epoch, so the batch
        // buffers behind the frame's pointers stay alive until the waiter
        // observes this task's completion, and no other task touches this
        // task's output span or slot.
        done.err = unsafe { run_task_raw(&frame, c, arena) }.err();
        drop(done);
    }
}

/// Run one claimed task of `frame` through its raw pointers.
///
/// # Safety
///
/// The frame's pointers must reference live batch buffers whose geometry
/// was validated at staging time (upheld by `stage_payload` +
/// `submit_back` on the decode side, `EncodePipeline::submit` /
/// [`compress_supers`] on the encode side), and `c` must be a uniquely
/// claimed index `< frame.n`.
unsafe fn run_task_raw(frame: &TaskFrame, c: usize, arena: &mut ScratchArena) -> Result<()> {
    match &frame.kind {
        TaskKind::Decode(f) => decode_chunk_raw(f, c, arena),
        TaskKind::Encode(f) => {
            encode_super_raw(f, c, arena);
            Ok(())
        }
    }
}

/// Decode one claimed chunk through the frame's raw slices.
unsafe fn decode_chunk_raw(f: &DecodeFrame, c: usize, arena: &mut ScratchArena) -> Result<()> {
    let span = *f.spans.add(c);
    let es = std::slice::from_raw_parts(f.entries.add(span.entry_off), span.groups);
    let comp = std::slice::from_raw_parts(f.comp.add(span.comp_off), span.comp_len);
    let out = std::slice::from_raw_parts_mut(f.out.add(span.out_off), span.out_len);
    decode_chunk_into(span.layout, es, comp, arena, out)
}

/// Compress one claimed super-chunk into its exclusively owned output
/// slot, using the worker's sticky scratch. Infallible (panics are
/// reported through the `ChunkDone` guard).
unsafe fn encode_super_raw(f: &EncodeFrame, si: usize, arena: &mut ScratchArena) {
    let profile = &*f.profiles.add(si * f.stride);
    let (lo, hi) = super_chunk_span(f.chunk_size, f.len, si);
    let data = std::slice::from_raw_parts(f.buf.add(lo), hi - lo);
    let (entries, payload) = &mut *f.slots.add(si);
    entries.clear();
    payload.clear();
    let ScratchArena { groups, zstd_dst, .. } = arena;
    compress_super_chunk(
        profile,
        f.chunk_size,
        data,
        CompressScratch { groups, zstd_dst },
        entries,
        payload,
    );
}

/// Persistent batch executor: helper jobs on the process-shared
/// [`WorkerPool`] plus the calling thread run each batch's tasks —
/// decode chunks for readers, encode super-chunks for writers and the
/// one-shot compressor. No thread is ever spawned per batch; pool
/// workers keep their sticky [`ScratchArena`] (group buffers, zstd
/// destination scratch, Huffman decode-table cache) warm across batches,
/// writers, readers, and files.
struct Engine {
    pool: &'static WorkerPool,
    ctl: Arc<BatchCtl>,
    runners: usize,
    epoch: u64,
}

impl Engine {
    fn new(threads: usize) -> Engine {
        let pool = shared_pool();
        Engine {
            pool,
            ctl: Arc::new(BatchCtl::default()),
            runners: threads.saturating_sub(1).clamp(1, pool.threads()),
            epoch: 0,
        }
    }

    /// Publish a batch and top the pool up to `runners` helper jobs.
    /// Non-blocking: the batch runs while the caller fetches (decode) or
    /// serializes (encode) other bytes; [`Engine::wait`] joins (and helps
    /// finish) it.
    fn submit(&self, frame: TaskFrame) {
        {
            let mut p = self.ctl.prog.lock().unwrap();
            p.epoch = frame.epoch;
            p.n = frame.n;
            p.next = 0;
            p.active = 0;
            p.done = 0;
            p.error = None;
        }
        // Helpers still queued from earlier batches exit on the epoch
        // check without helping, so top up only to the configured bound —
        // the queue cannot grow past `runners` outstanding jobs.
        while self.ctl.queued.load(Ordering::Acquire) < self.runners {
            self.ctl.queued.fetch_add(1, Ordering::AcqRel);
            let ctl = Arc::clone(&self.ctl);
            let submitted = self.pool.execute_with_state(move |sticky: &mut StickyMap| {
                // Decrement on every exit, unwinds included: a leaked
                // count would permanently stop helper top-up for this
                // reader (the `ChunkDone` guard already reports the
                // panicked chunk itself).
                struct QueuedGuard(Arc<BatchCtl>);
                impl Drop for QueuedGuard {
                    fn drop(&mut self) {
                        self.0.queued.fetch_sub(1, Ordering::AcqRel);
                    }
                }
                let guard = QueuedGuard(ctl);
                run_frame_tasks(&guard.0, frame, sticky.slot::<ScratchArena>());
            });
            if submitted.is_err() {
                self.ctl.queued.fetch_sub(1, Ordering::AcqRel);
                break; // pool unavailable: the caller runs the batch in wait()
            }
        }
    }

    /// Help run the in-flight batch on the calling thread, then block
    /// until every claimed task has finished. On return (even `Err`) no
    /// task references the batch buffers any more.
    fn wait(&self, frame: TaskFrame, arena: &mut ScratchArena) -> Result<()> {
        // The caller's claims race with the pool helpers', so a busy (or
        // absent) pool can never deadlock a batch — worst case the caller
        // runs every task itself.
        run_frame_tasks(&self.ctl, frame, arena);
        let mut p = self.ctl.prog.lock().unwrap();
        while p.active > 0 || p.next < p.n {
            p = self.ctl.cv.wait(p).unwrap();
        }
        if let Some(e) = p.error.take() {
            return Err(e);
        }
        if p.done != p.n {
            return Err(Error::Invalid("batch lost tasks to a worker failure".into()));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Batch fetch + serial decode
// ---------------------------------------------------------------------------

/// Read the next batch's metadata and payload from the source into `buf`
/// (no decoding), or report the container's end.
fn fetch_batch<R: Read>(
    state: &mut ReaderState,
    src: &mut ByteSource<R>,
    buf: &mut BatchBuf,
    threads: usize,
) -> Result<Fetch> {
    match state {
        ReaderState::Done => Err(Error::Invalid("read past container end".into())),
        ReaderState::V1 { layout, total_len, checksum, entries, groups, next_chunk, n_chunks } => {
            let (layout, groups) = (*layout, *groups);
            if *next_chunk >= *n_chunks {
                return Ok(Fetch::End(EndInfo {
                    tail: [0; 16],
                    tail_len: 0,
                    total_len: *total_len,
                    checksum: *checksum,
                }));
            }
            let batch = threads.max(1) * SUPER_CHUNK;
            let lo = *next_chunk;
            let hi = (lo + batch).min(*n_chunks);
            *next_chunk = hi;
            buf.entries.clear();
            buf.entries.extend_from_slice(&entries[lo * groups..hi * groups]);
            stage_payload(src, buf, layout, groups)?;
            Ok(Fetch::Batch)
        }
        ReaderState::V2 { layout, chunk_size, has_checksum, frame_ck, groups, frame } => {
            let (layout, groups) = (*layout, *groups);
            let (chunk_size, has_checksum) = (*chunk_size, *has_checksum);
            let frame_ck = *frame_ck;
            let f = *frame;
            *frame += 1;
            let start = src.consumed();
            // A short read anywhere in the frame — marker, rows, payload,
            // trailer fields — reports the same source-independent
            // message naming the frame and where the container was cut.
            fetch_v2_batch(src, buf, layout, groups, chunk_size, has_checksum, frame_ck, f)
                .map_err(|e| match e {
                    Error::Io(io) if io.kind() == io::ErrorKind::UnexpectedEof => {
                        Error::Corrupt(format!(
                            "container truncated in frame {f} at byte offset {off} \
                             (frame starts at byte {start})",
                            off = src.consumed()
                        ))
                    }
                    other => other,
                })
        }
    }
}

/// One `ZNS1` fetch step: dispatch on the next marker byte — plain
/// frame, profiled frame, or trailer.
#[allow(clippy::too_many_arguments)]
fn fetch_v2_batch<R: Read>(
    src: &mut ByteSource<R>,
    buf: &mut BatchBuf,
    layout: GroupLayout,
    groups: usize,
    chunk_size: u32,
    has_checksum: bool,
    frame_ck: bool,
    frame: u64,
) -> Result<Fetch> {
    let mut marker = [0u8; 1];
    src.read_exact(&mut marker)?;
    match marker[0] {
        MARK_FRAME => fetch_v2_frame(src, buf, layout, groups, chunk_size, frame_ck, frame),
        MARK_PFRAME => {
            // Profiled frame: a 2-byte layout prefix overrides
            // the header geometry for this frame only.
            let mut ph = [0u8; 2];
            src.read_exact(&mut ph)?;
            let (elem, exp_group) = (ph[0] as usize, ph[1] as usize);
            if elem == 0 || elem > 16 || exp_group >= elem {
                return Err(Error::Corrupt(format!(
                    "bad frame layout elem={elem} exp_group={exp_group}"
                )));
            }
            let f_layout = GroupLayout { elem, exp_group };
            fetch_v2_frame(src, buf, f_layout, f_layout.groups(), chunk_size, frame_ck, frame)
        }
        MARK_END => {
            let mut t = [0u8; 1];
            src.read_exact(&mut t)?;
            let tail_len = t[0] as usize;
            if tail_len >= layout.elem {
                return Err(Error::Corrupt(format!("bad tail length {tail_len}")));
            }
            let mut tail = [0u8; 16];
            src.read_exact(&mut tail[..tail_len])?;
            let mut n8 = [0u8; 8];
            src.read_exact(&mut n8)?;
            let total_len = u64::from_le_bytes(n8);
            let checksum = if has_checksum {
                src.read_exact(&mut n8)?;
                Some(u64::from_le_bytes(n8))
            } else {
                None
            };
            Ok(Fetch::End(EndInfo { tail, tail_len, total_len, checksum }))
        }
        other => Err(Error::Corrupt(format!("bad frame marker {other:#x}"))),
    }
}

/// Read one `ZNS1` frame body — stream count, entry rows, payload
/// staging — under the given per-frame geometry. Shared by plain `0xF5`
/// frames (header layout) and profiled `0xF7` frames (prefix layout).
/// With `frame_ck` the [`SFLAG_FRAME_CK`] checksum after the stream
/// count is verified over rows + payload before the batch is accepted,
/// so corruption surfaces here — pinned to this frame — on every decode
/// path that fetches frames, mapped and streamed alike.
fn fetch_v2_frame<R: Read>(
    src: &mut ByteSource<R>,
    buf: &mut BatchBuf,
    layout: GroupLayout,
    groups: usize,
    chunk_size: u32,
    frame_ck: bool,
    frame: u64,
) -> Result<Fetch> {
    let mut n4 = [0u8; 4];
    src.read_exact(&mut n4)?;
    let n_streams = u32::from_le_bytes(n4) as usize;
    if n_streams == 0 || n_streams > SUPER_CHUNK * 16 || n_streams % groups != 0 {
        return Err(Error::Corrupt(format!("bad frame stream count {n_streams}")));
    }
    let expect = if frame_ck {
        let mut n8 = [0u8; 8];
        src.read_exact(&mut n8)?;
        Some(u64::from_le_bytes(n8))
    } else {
        None
    };
    let mut ck = frame_ck.then(Checksummer::streaming);
    buf.entries.clear();
    let mut row = [0u8; 9];
    for _ in 0..n_streams {
        src.read_exact(&mut row)?;
        if let Some(ck) = ck.as_mut() {
            ck.update(&row);
        }
        let e = parse_entry(&row)?;
        if e.comp_len > e.raw_len || e.raw_len > chunk_size {
            return Err(Error::Corrupt("implausible stream entry".into()));
        }
        buf.entries.push(e);
    }
    stage_payload(src, buf, layout, groups)?;
    if let (Some(mut ck), Some(expect)) = (ck, expect) {
        let payload: &[u8] = match buf.payload {
            PayloadAt::Buf => &buf.comp[..buf.comp_len],
            PayloadAt::Mapped(off) => src.mapped_slice(off, buf.comp_len),
        };
        ck.update(payload);
        if ck.finalize() != expect {
            return Err(Error::Corrupt(format!("frame {frame} checksum mismatch")));
        }
    }
    Ok(Fetch::Batch)
}

/// Build the batch's chunk spans from its staged entries, then stage the
/// compressed payload: copied into the batch buffer for stream sources
/// (into high-water-length storage — no per-refill zero-fill), recorded
/// as a borrowed range plus a prefetch hint for mapped sources.
fn stage_payload<R: Read>(
    src: &mut ByteSource<R>,
    buf: &mut BatchBuf,
    layout: GroupLayout,
    groups: usize,
) -> Result<()> {
    buf.layout = layout;
    buf.groups = groups;
    if groups == 0 || buf.entries.len() % groups != 0 {
        return Err(Error::Corrupt("stream count not a multiple of groups".into()));
    }
    buf.n_chunks = buf.entries.len() / groups;
    buf.spans.clear();
    let (mut comp_off, mut out_off) = (0usize, 0usize);
    for (c, es) in buf.entries.chunks_exact(groups).enumerate() {
        let comp_len: usize = es.iter().map(|e| e.comp_len as usize).sum();
        let out_len: usize = es.iter().map(|e| e.raw_len as usize).sum();
        buf.spans.push(ChunkSpan {
            comp_off,
            comp_len,
            out_off,
            out_len,
            entry_off: c * groups,
            layout,
            groups,
        });
        comp_off += comp_len;
        out_off += out_len;
    }
    buf.comp_len = comp_off;
    buf.out_len = out_off;
    ensure_len(&mut buf.out, out_off);
    match &mut src.0 {
        SourceInner::Stream { inner, consumed } => {
            ensure_len(&mut buf.comp, comp_off);
            inner.read_exact(&mut buf.comp[..comp_off])?;
            *consumed += comp_off as u64;
            buf.payload = PayloadAt::Buf;
        }
        SourceInner::Mapped { bytes, pos } => {
            let end = pos
                .checked_add(comp_off)
                .filter(|&e| e <= bytes.len())
                .ok_or_else(|| {
                    Error::Io(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        "mapped container truncated",
                    ))
                })?;
            buf.payload = PayloadAt::Mapped(*pos);
            *pos = end;
            // Page-fault overlap: start paging in roughly the next batch
            // while this one decodes.
            bytes.prefetch(end, comp_off.max(1));
        }
    }
    Ok(())
}

/// Decode every chunk of a staged batch inline on the calling thread.
fn decode_batch_serial<R: Read>(
    src: &ByteSource<R>,
    buf: &mut BatchBuf,
    arena: &mut ScratchArena,
) -> Result<()> {
    let BatchBuf { entries, comp, spans, out, comp_len, payload, .. } = buf;
    let comp_all: &[u8] = match payload {
        PayloadAt::Buf => &comp[..*comp_len],
        PayloadAt::Mapped(off) => src.mapped_slice(*off, *comp_len),
    };
    for s in spans.iter() {
        let es = &entries[s.entry_off..s.entry_off + s.groups];
        let comp_chunk = &comp_all[s.comp_off..s.comp_off + s.comp_len];
        decode_chunk_into(
            s.layout,
            es,
            comp_chunk,
            arena,
            &mut out[s.out_off..s.out_off + s.out_len],
        )?;
    }
    Ok(())
}

/// Fold a freshly decoded batch into the running checksum/length.
fn note_decoded(ck: &mut Option<Checksummer>, produced: &mut u64, buf: &BatchBuf) {
    if let Some(ck) = ck.as_mut() {
        ck.update(&buf.out[..buf.out_len]);
    }
    *produced += buf.out_len as u64;
}

/// Streaming decompressor: a [`Read`] adapter over either container
/// format. Holds at most one decode batch (a few super-chunks) in memory,
/// never the whole payload — this is how the hub client and the runtime
/// decompress straight off a socket or a file. Over a [`MappedBytes`]
/// source ([`ZnnReader::open`]) the compressed payload is additionally
/// **zero-copy**: decode reads borrow straight from the mapping.
pub struct ZnnReader<R: Read> {
    src: ByteSource<R>,
    threads: usize,
    state: ReaderState,
    /// Batch being consumed through `pos`.
    cur: BatchBuf,
    /// Batch being decoded (pipelined mode) or staged next.
    back: BatchBuf,
    pos: usize,
    /// In-flight decode of `back` on the shared pool. While set, `back`'s
    /// buffers must not be touched; `complete_pending` (or drop) joins it.
    pending: Option<TaskFrame>,
    /// Container end seen by fetch, applied once all batches are served.
    end: Option<EndInfo>,
    engine: Option<Engine>,
    arena: ScratchArena,
    ck: Option<Checksummer>,
    produced: u64,
    /// Raw bytes handed to the caller through `read` so far (the
    /// sequential range path's notion of position).
    served: u64,
    /// Mapped sources: byte offset where the payload/frames begin
    /// (recorded right after the header parse, before any batch fetch).
    payload_base: u64,
    /// Lazily probed tensor index: `None` = not probed yet,
    /// `Some(None)` = probed, container carries none.
    index: Option<Option<TensorIndex>>,
    /// `ZNN1` random access: cached per-chunk compressed/raw prefix
    /// offsets (`n_chunks + 1` entries each).
    range_v1: Option<RangeAccessV1>,
    /// `ZNN1` stream table retained past the sequential `Done` transition
    /// (mapped sources only), so `decode_range` keeps serving after a
    /// full sequential read.
    v1_table: Option<(GroupLayout, usize, Vec<StreamEntry>)>,
    /// `ZNS1` geometry (layout, groups, chunk size), captured at open so
    /// index-driven random access outlives the sequential state machine.
    v2_meta: Option<(GroupLayout, usize, u32)>,
    /// Staging for `decode_range` (kept across calls like the batch
    /// buffers, so repeated tensor reads reuse capacity).
    range_buf: BatchBuf,
    /// Dedicated engine for range decodes: its batch control is separate
    /// from the sequential pipeline's, so a `decode_range` can run even
    /// while a pipelined batch is in flight.
    range_engine: Option<Engine>,
}

/// `ZNN1` random-access offsets: prefix sums over the stream table.
struct RangeAccessV1 {
    /// Compressed payload offset of each chunk (relative to the payload
    /// start); `comp_off[n_chunks]` is the payload length.
    comp_off: Vec<u64>,
    /// Raw offset of each chunk; `raw_off[n_chunks]` is the total length.
    raw_off: Vec<u64>,
}

/// Order-insensitive open options for [`ZnnReader`]: set decode threads
/// and index probing once, then open from any source kind. The direct
/// constructors ([`ZnnReader::open`], [`ZnnReader::new`],
/// [`ZnnReader::from_mapped`], [`ZnnReader::with_source`]) remain and
/// behave exactly as before; the builder is where new open-time options
/// land without widening every constructor.
#[derive(Debug, Clone, Copy, Default)]
pub struct ZnnReaderBuilder {
    threads: usize,
    probe_index: bool,
}

impl ZnnReaderBuilder {
    /// Worker threads for chunk-parallel decoding (0 or 1 = serial);
    /// same semantics as [`ZnnReader::with_threads`].
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n;
        self
    }

    /// Probe the tensor index eagerly at open time instead of lazily on
    /// the first `decode_tensor`/`decode_range`/`index()` call, so a
    /// missing index surfaces before any decode work is staged.
    pub fn probe_index(mut self, yes: bool) -> Self {
        self.probe_index = yes;
        self
    }

    /// Open a container file (zero-copy mmap fast path; see
    /// [`ZnnReader::open`]).
    pub fn open(
        self,
        path: impl AsRef<Path>,
    ) -> Result<ZnnReader<std::io::BufReader<std::fs::File>>> {
        self.finish(ZnnReader::open(path)?)
    }

    /// Open over a sequential reader (see [`ZnnReader::new`]).
    pub fn reader<R: Read>(self, inner: R) -> Result<ZnnReader<R>> {
        self.finish(ZnnReader::new(inner)?)
    }

    /// Open over already-mapped (or owned) container bytes (see
    /// [`ZnnReader::from_mapped`]).
    pub fn mapped(self, bytes: MappedBytes) -> Result<ZnnReader<std::io::Empty>> {
        self.finish(ZnnReader::from_mapped(bytes)?)
    }

    /// Open over an explicit [`ByteSource`].
    pub fn source<R: Read>(self, src: ByteSource<R>) -> Result<ZnnReader<R>> {
        self.finish(ZnnReader::with_source(src)?)
    }

    fn finish<R: Read>(self, mut r: ZnnReader<R>) -> Result<ZnnReader<R>> {
        if self.threads > 0 {
            r = r.with_threads(self.threads);
        }
        if self.probe_index {
            r.ensure_index()?;
        }
        Ok(r)
    }
}

impl ZnnReader<std::io::Empty> {
    /// Start building open options; terminal methods
    /// ([`ZnnReaderBuilder::open`], [`ZnnReaderBuilder::reader`],
    /// [`ZnnReaderBuilder::mapped`], [`ZnnReaderBuilder::source`])
    /// produce the reader.
    pub fn builder() -> ZnnReaderBuilder {
        ZnnReaderBuilder::default()
    }

    /// Decode from already-mapped (or owned) container bytes.
    pub fn from_mapped(bytes: MappedBytes) -> Result<ZnnReader<std::io::Empty>> {
        Self::with_source(ByteSource::mapped(bytes))
    }
}

impl ZnnReader<std::io::BufReader<std::fs::File>> {
    /// Open a container file on the zero-copy fast path: the file is
    /// memory-mapped and decode borrows payload bytes straight from the
    /// OS page cache. Where mapping is unavailable (or `ZIPNN_NO_MMAP=1`)
    /// this degrades to the plain buffered streaming path — same bounded
    /// memory as [`ZnnReader::new`] over a file.
    pub fn open(path: impl AsRef<Path>) -> Result<ZnnReader<std::io::BufReader<std::fs::File>>> {
        let path = path.as_ref();
        let src = ByteSource::open(path)?;
        let stream_fallback = matches!(&src.0, SourceInner::Stream { .. });
        let mut r = Self::with_source(src)?;
        if stream_fallback {
            // The mapped path probes the index from the mapping on demand;
            // the buffered fallback reads it from the file tail here, so
            // `decode_tensor` keeps working without a mapping (the decode
            // itself then runs on the sequential skip path).
            r.index = Some(index::probe_file(path)?);
        }
        Ok(r)
    }
}

impl<R: Read> ZnnReader<R> {
    /// Open a container over a sequential reader: reads and validates the
    /// header (and, for `ZNN1`, the stream table).
    pub fn new(inner: R) -> Result<ZnnReader<R>> {
        Self::with_source(ByteSource::stream(inner))
    }

    /// Open a container over an explicit [`ByteSource`].
    pub fn with_source(mut src: ByteSource<R>) -> Result<ZnnReader<R>> {
        let mut magic = [0u8; 4];
        src.read_exact(&mut magic)?;
        let (state, ck) = if magic == crate::codec::container::MAGIC {
            Self::open_v1(&mut src)?
        } else if magic == STREAM_MAGIC {
            Self::open_v2(&mut src)?
        } else {
            return Err(Error::Corrupt("bad magic".into()));
        };
        let payload_base = match &src.0 {
            SourceInner::Mapped { pos, .. } => *pos as u64,
            SourceInner::Stream { .. } => 0,
        };
        let v2_meta = match &state {
            ReaderState::V2 { layout, groups, chunk_size, .. } => {
                Some((*layout, *groups, *chunk_size))
            }
            _ => None,
        };
        Ok(ZnnReader {
            src,
            threads: 1,
            state,
            cur: BatchBuf::new(),
            back: BatchBuf::new(),
            pos: 0,
            pending: None,
            end: None,
            engine: None,
            arena: ScratchArena::new(),
            ck,
            produced: 0,
            served: 0,
            payload_base,
            index: None,
            range_v1: None,
            v1_table: None,
            v2_meta,
            range_buf: BatchBuf::new(),
            range_engine: None,
        })
    }

    /// Worker threads for chunk-parallel decoding of each batch. With
    /// `n > 1` batches decode on the process-shared worker pool
    /// ([`crate::coordinator::shared_pool`]) with a double-buffered,
    /// pipelined refill; no thread is spawned per batch.
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Raw bytes yielded so far.
    pub fn raw_len(&self) -> u64 {
        self.produced
    }

    /// True when payload bytes are borrowed from a memory mapping
    /// (page-cache served, no copy into reader buffers).
    pub fn is_zero_copy(&self) -> bool {
        matches!(&self.src.0, SourceInner::Mapped { bytes, .. } if bytes.is_mapped())
    }

    fn open_v1(inner: &mut ByteSource<R>) -> Result<(ReaderState, Option<Checksummer>)> {
        let mut head = [0u8; 20];
        inner.read_exact(&mut head)?;
        // head[i] corresponds to container byte 4 + i; validation is
        // shared with the buffer parser.
        let (flags, layout, _chunk_size, total_len, n_chunks) =
            crate::codec::container::parse_fixed_header(&head)?;
        let n_chunks = n_chunks as usize;
        let checksum = if flags & crate::codec::container::FLAG_CHECKSUM != 0 {
            let mut c = [0u8; 8];
            inner.read_exact(&mut c)?;
            Some(u64::from_le_bytes(c))
        } else {
            None
        };
        let groups = layout.groups();
        let n_entries = n_chunks * groups;
        // Grow incrementally (capped pre-allocation): a corrupt header
        // must not trigger a huge allocation before its table bytes —
        // which would have to actually exist — are read.
        let mut entries = Vec::with_capacity(n_entries.min(1 << 16));
        let mut raw_sum = 0u64;
        let mut row = [0u8; 9];
        for _ in 0..n_entries {
            inner.read_exact(&mut row)?;
            let e = parse_entry(&row)?;
            // The compressor never stores a stream larger than raw (it
            // falls back to Raw); enforcing that bounds the payload
            // buffers the reader sizes from the table.
            if e.comp_len > e.raw_len {
                return Err(Error::Corrupt("implausible stream entry".into()));
            }
            raw_sum += e.raw_len as u64;
            entries.push(e);
        }
        if raw_sum != total_len {
            return Err(Error::Corrupt(format!(
                "stream raw lengths sum {raw_sum} != total {total_len}"
            )));
        }
        let ck = checksum.map(|_| Checksummer::with_total_len(total_len));
        let state = if n_chunks == 0 {
            // Verify the (empty-input) checksum immediately.
            if let (Some(expect), Some(c)) = (checksum, ck) {
                let got = c.finalize();
                if got != expect {
                    return Err(Error::Corrupt(format!(
                        "checksum mismatch: {got:#018x} != {expect:#018x}"
                    )));
                }
            }
            (ReaderState::Done, None)
        } else {
            (
                ReaderState::V1 {
                    layout,
                    total_len,
                    checksum,
                    entries,
                    groups,
                    next_chunk: 0,
                    n_chunks,
                },
                ck,
            )
        };
        Ok(state)
    }

    fn open_v2(inner: &mut ByteSource<R>) -> Result<(ReaderState, Option<Checksummer>)> {
        let mut head = [0u8; 8];
        inner.read_exact(&mut head)?;
        let version = head[0];
        if version != STREAM_VERSION {
            return Err(Error::Corrupt(format!(
                "unsupported stream version {version}"
            )));
        }
        let flags = head[1];
        let elem = head[2] as usize;
        let exp_group = head[3] as usize;
        if elem == 0 || elem > 16 || exp_group >= elem {
            return Err(Error::Corrupt(format!(
                "bad layout elem={elem} exp_group={exp_group}"
            )));
        }
        let chunk_size = u32::from_le_bytes(head[4..8].try_into().unwrap());
        if chunk_size == 0 || chunk_size > MAX_CHUNK_SIZE {
            return Err(Error::Corrupt("bad chunk size".into()));
        }
        let has_checksum = flags & SFLAG_CHECKSUM != 0;
        Ok((
            ReaderState::V2 {
                layout: GroupLayout { elem, exp_group },
                chunk_size,
                has_checksum,
                frame_ck: flags & SFLAG_FRAME_CK != 0,
                groups: elem,
                frame: 0,
            },
            has_checksum.then(Checksummer::streaming),
        ))
    }

    /// Make the next decoded bytes available in `cur`; a finished
    /// container leaves `cur` empty with the state `Done`.
    fn refill(&mut self) -> Result<()> {
        self.pos = 0;
        self.cur.out_len = 0;
        if self.threads <= 1 {
            self.refill_serial()
        } else {
            self.refill_pipelined()
        }
    }

    /// Single-threaded path: fetch one batch and decode it inline.
    fn refill_serial(&mut self) -> Result<()> {
        if matches!(self.state, ReaderState::Done) {
            return Ok(());
        }
        match fetch_batch(&mut self.state, &mut self.src, &mut self.cur, 1)? {
            Fetch::Batch => {
                decode_batch_serial(&self.src, &mut self.cur, &mut self.arena)?;
                note_decoded(&mut self.ck, &mut self.produced, &self.cur);
                Ok(())
            }
            Fetch::End(end) => self.finish(end),
        }
    }

    /// Pipelined path: while the previous batch decodes on the shared
    /// pool (into `back`), this thread fetches the next batch's bytes
    /// into `cur`'s spare buffers — I/O (or mapped page-faults) of batch
    /// N+1 overlaps the decode of batch N. Then the buffers rotate:
    /// decoded data is served from `cur`, the fetched bytes are submitted
    /// from `back`.
    fn refill_pipelined(&mut self) -> Result<()> {
        loop {
            if matches!(self.state, ReaderState::Done) && self.pending.is_none() {
                return Ok(());
            }
            // 1. Fetch the next batch's bytes. `cur` is fully consumed, so
            //    its buffers are free — the in-flight decode only touches
            //    `back`.
            let mut fetched = false;
            if self.end.is_none() && !matches!(self.state, ReaderState::Done) {
                let threads = self.threads;
                match fetch_batch(&mut self.state, &mut self.src, &mut self.cur, threads)? {
                    Fetch::Batch => fetched = true,
                    Fetch::End(end) => self.end = Some(end),
                }
            }
            // 2. Join the in-flight decode (helping on this thread).
            self.complete_pending()?;
            // 3. Rotate: decoded data (if any) moves to `cur` for serving,
            //    freshly fetched bytes move to `back` for decoding.
            std::mem::swap(&mut self.cur, &mut self.back);
            self.pos = 0;
            // 4. Kick off the fetched batch on the pool.
            if fetched {
                self.submit_back();
            }
            if self.cur.out_len > 0 {
                return Ok(());
            }
            if self.end.is_some() && self.pending.is_none() {
                let end = self.end.take().expect("just checked");
                return self.finish(end);
            }
            // Pipeline warm-up (first batch just submitted): go around to
            // fetch the next batch and join this one.
        }
    }

    /// Join the in-flight decode of `back`, folding its output into the
    /// running checksum. No-op when nothing is pending.
    fn complete_pending(&mut self) -> Result<()> {
        match self.pending.take() {
            Some(frame) => {
                let engine = self.engine.as_ref().expect("pending implies engine");
                engine.wait(frame, &mut self.arena)?;
                note_decoded(&mut self.ck, &mut self.produced, &self.back);
                Ok(())
            }
            None => {
                self.back.out_len = 0;
                Ok(())
            }
        }
    }

    /// Submit the staged batch in `back` to the decode engine.
    fn submit_back(&mut self) {
        if self.engine.is_none() {
            self.engine = Some(Engine::new(self.threads));
        }
        let comp_ptr: *const u8 = match self.back.payload {
            PayloadAt::Buf => self.back.comp.as_ptr(),
            PayloadAt::Mapped(off) => self.src.mapped_slice(off, self.back.comp_len).as_ptr(),
        };
        let engine = self.engine.as_mut().expect("just created");
        engine.epoch += 1;
        let b = &mut self.back;
        debug_assert_eq!(b.spans.len(), b.n_chunks);
        debug_assert_eq!(b.entries.len(), b.n_chunks * b.groups);
        debug_assert!(b.out.len() >= b.out_len);
        let frame = TaskFrame {
            epoch: engine.epoch,
            n: b.n_chunks,
            kind: TaskKind::Decode(DecodeFrame {
                entries: b.entries.as_ptr(),
                comp: comp_ptr,
                spans: b.spans.as_ptr(),
                out: b.out.as_mut_ptr(),
            }),
        };
        engine.submit(frame);
        self.pending = Some(frame);
    }

    /// Apply the container end: serve the trailer tail (if any), verify
    /// totals and checksum, and mark the reader done.
    fn finish(&mut self, end: EndInfo) -> Result<()> {
        ensure_len(&mut self.cur.out, end.tail_len);
        self.cur.out[..end.tail_len].copy_from_slice(&end.tail[..end.tail_len]);
        self.cur.out_len = end.tail_len;
        self.pos = 0;
        if let Some(ck) = self.ck.as_mut() {
            ck.update(&end.tail[..end.tail_len]);
        }
        self.produced += end.tail_len as u64;
        self.end = None;
        // Keep the ZNN1 table alive past Done on mapped sources, so
        // `decode_range` stays random-access after a full sequential
        // read (a move, not a copy; stream sources can't seek anyway).
        let old = std::mem::replace(&mut self.state, ReaderState::Done);
        if let ReaderState::V1 { layout, groups, entries, .. } = old {
            if self.src.mapped_bytes().is_some() && self.v1_table.is_none() {
                self.v1_table = Some((layout, groups, entries));
            }
        }
        if self.produced != end.total_len {
            return Err(Error::Corrupt(format!(
                "decompressed {} bytes, expected {}",
                self.produced, end.total_len
            )));
        }
        if let (Some(expect), Some(ck)) = (end.checksum, self.ck.take()) {
            let got = ck.finalize();
            if got != expect {
                return Err(Error::Corrupt(format!(
                    "checksum mismatch: {got:#018x} != {expect:#018x}"
                )));
            }
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // Partial decode: tensor-addressable range reads
    // -----------------------------------------------------------------

    /// The container's tensor→chunk index, if it carries one (see
    /// [`crate::codec::index`]). Mapped sources probe the mapping's tail;
    /// [`ZnnReader::open`]'s buffered fallback reads it from the file
    /// tail; a pure stream source (socket) reports `None`.
    pub fn index(&mut self) -> Result<Option<&TensorIndex>> {
        self.ensure_index()?;
        Ok(self.index.as_ref().expect("just probed").as_ref())
    }

    /// True when `decode_range` on this reader is random access (an
    /// in-memory/mapped source plus the table or index needed to locate
    /// chunks) rather than the sequential skip fallback. Random-access
    /// readers serve ranges in any order, repeatedly; sequential ones
    /// only decode forward. (`&mut`: probing the index may be needed.)
    pub fn supports_random_access(&mut self) -> Result<bool> {
        if self.src.mapped_bytes().is_none() {
            return Ok(false);
        }
        self.ensure_index()?;
        let v1 = matches!(self.state, ReaderState::V1 { .. }) || self.v1_table.is_some();
        let v2 = self.v2_meta.is_some()
            && matches!(
                self.cached_index(),
                Some(TensorIndex { kind: ContainerKind::Streaming, .. })
            );
        Ok(v1 || v2)
    }

    fn ensure_index(&mut self) -> Result<()> {
        if self.index.is_none() {
            let probed = match self.src.mapped_bytes() {
                Some(bytes) => index::probe_bytes(bytes)?,
                None => None,
            };
            self.index = Some(probed);
        }
        Ok(())
    }

    fn cached_index(&self) -> Option<&TensorIndex> {
        self.index.as_ref().and_then(|o| o.as_ref())
    }

    /// Total raw length, when the reader can know it without decoding:
    /// the `ZNN1` header, a tensor index, or a fully consumed container.
    fn known_total(&self) -> Option<u64> {
        if let Some(idx) = self.cached_index() {
            return Some(idx.total_len);
        }
        match &self.state {
            ReaderState::V1 { total_len, .. } => Some(*total_len),
            ReaderState::Done if self.pending.is_none() && self.end.is_none() => {
                Some(self.produced)
            }
            _ => None,
        }
    }

    /// Decode exactly the raw bytes `[offset, offset + len)` of the
    /// container.
    ///
    /// Over a mapped source (`ZNN1`, or `ZNS1` with an index) this is
    /// **random access**: only the chunks covering the range are decoded
    /// (on the shared sticky pool when `with_threads(n > 1)`), and it is
    /// independent of — and does not disturb — the sequential `Read`
    /// position. On stream sources it degrades to a sequential
    /// skip-decode, which only supports ranges at or ahead of the current
    /// position. Range decodes skip whole-stream checksum verification
    /// (per-stream structural validation still applies).
    pub fn decode_range(&mut self, offset: u64, len: u64) -> Result<Vec<u8>> {
        let end = offset
            .checked_add(len)
            .ok_or_else(|| Error::Invalid(format!("range {offset}+{len} overflows u64")))?;
        self.ensure_index()?;
        if let Some(total) = self.known_total() {
            if end > total {
                return Err(Error::Invalid(format!(
                    "range [{offset}, {end}) out of bounds (total {total})"
                )));
            }
        }
        if len == 0 {
            return Ok(Vec::new());
        }
        if self.src.mapped_bytes().is_some() {
            if matches!(self.state, ReaderState::V1 { .. }) || self.v1_table.is_some() {
                return self.decode_range_v1(offset, len);
            }
            let v2_indexed = self.v2_meta.is_some()
                && matches!(
                    self.cached_index(),
                    Some(TensorIndex { kind: ContainerKind::Streaming, .. })
                );
            if v2_indexed {
                return self.decode_range_v2(offset, len);
            }
            // Empty one-shot, or an un-indexed ZNS1: sequential below.
        }
        self.decode_range_sequential(offset, len)
    }

    /// Decode the whole container, discarding the output: every integrity
    /// check on the sequential path runs — structural validation,
    /// per-frame checksums when the container carries them
    /// ([`SFLAG_FRAME_CK`]), and the whole-stream trailer checksum.
    /// Returns the raw byte count on success, the first error otherwise.
    pub fn verify(&mut self) -> Result<u64> {
        let mut scratch = [0u8; 64 * 1024];
        let mut total = 0u64;
        loop {
            let n = Read::read(self, &mut scratch).map_err(from_io_err)?;
            if n == 0 {
                return Ok(total);
            }
            total += n as u64;
        }
    }

    /// Best-effort decode of a damaged container: every frame decodes
    /// independently through the index's frame directory, corrupt frames
    /// are zero-filled instead of aborting the stream, and the report
    /// names exactly which frames — and which tensors — were lost.
    /// Needs a mapped/owned source and a streaming (`ZNS1`) tensor
    /// index; containers with per-frame checksums pin corruption
    /// precisely, while flag-free ones only catch structural damage.
    pub fn salvage(&mut self) -> Result<(Vec<u8>, SalvageReport)> {
        self.ensure_index()?;
        if self.src.mapped_bytes().is_none() {
            return Err(Error::Invalid("salvage needs a mapped or owned source".into()));
        }
        let (total_len, aligned, chunk, tail, n_frames, tensors) = {
            let idx = match self.cached_index() {
                Some(idx @ TensorIndex { kind: ContainerKind::Streaming, .. }) => idx,
                _ => {
                    return Err(Error::Invalid(
                        "salvage needs an indexed ZNS1 container".into(),
                    ))
                }
            };
            let tensors: Vec<(String, u64, u64)> =
                idx.tensors.iter().map(|t| (t.name.clone(), t.offset, t.len)).collect();
            (
                idx.total_len,
                idx.aligned_len(),
                idx.chunk_size as u64,
                idx.tail.clone(),
                idx.frame_offsets.len(),
                tensors,
            )
        };
        let frame_raw = SUPER_CHUNK as u64 * chunk;
        let frame_span = |f: usize| {
            let lo = f as u64 * frame_raw;
            (lo, ((f as u64 + 1) * frame_raw).min(aligned))
        };
        let mut out = vec![0u8; total_len as usize];
        let mut bad_frames = Vec::new();
        let mut recovered_bytes = tail.len() as u64;
        for f in 0..n_frames {
            let (lo, hi) = frame_span(f);
            match self.decode_range(lo, hi - lo) {
                Ok(bytes) if bytes.len() as u64 == hi - lo => {
                    out[lo as usize..hi as usize].copy_from_slice(&bytes);
                    recovered_bytes += hi - lo;
                }
                _ => bad_frames.push(f),
            }
        }
        out[aligned as usize..].copy_from_slice(&tail);
        let mut lost_tensors = Vec::new();
        for (name, t_off, t_len) in &tensors {
            if *t_len == 0 {
                continue;
            }
            let t_end = t_off + t_len;
            let hit = bad_frames.iter().any(|&f| {
                let (lo, hi) = frame_span(f);
                *t_off < hi && t_end > lo
            });
            if hit {
                lost_tensors.push(name.clone());
            }
        }
        Ok((
            out,
            SalvageReport {
                total_frames: n_frames,
                bad_frames,
                lost_tensors,
                recovered_bytes,
                total_len,
            },
        ))
    }

    /// Decode one tensor by name through the container's index.
    pub fn decode_tensor(&mut self, name: &str) -> Result<Vec<u8>> {
        let (offset, len) = {
            let idx = self
                .index()?
                .ok_or_else(|| Error::Invalid("container has no tensor index".into()))?;
            let t = idx
                .find(name)
                .ok_or_else(|| Error::Invalid(format!("no tensor '{name}' in index")))?;
            (t.offset, t.len)
        };
        self.decode_range(offset, len)
    }

    /// Build (once) the `ZNN1` per-chunk prefix offsets for random access.
    fn build_range_v1(&mut self) -> Result<()> {
        if self.range_v1.is_some() {
            return Ok(());
        }
        let (groups, entries): (usize, &[StreamEntry]) = match &self.state {
            ReaderState::V1 { entries, groups, .. } => (*groups, entries),
            _ => match &self.v1_table {
                Some((_, g, e)) => (*g, e),
                None => {
                    return Err(Error::Invalid("random access needs the one-shot table".into()))
                }
            },
        };
        let n_chunks = entries.len() / groups.max(1);
        let mut comp_off = Vec::with_capacity(n_chunks + 1);
        let mut raw_off = Vec::with_capacity(n_chunks + 1);
        let (mut ca, mut ra) = (0u64, 0u64);
        comp_off.push(0);
        raw_off.push(0);
        for es in entries.chunks_exact(groups) {
            ca += es.iter().map(|e| e.comp_len as u64).sum::<u64>();
            ra += es.iter().map(|e| e.raw_len as u64).sum::<u64>();
            comp_off.push(ca);
            raw_off.push(ra);
        }
        let map_len = self
            .src
            .mapped_bytes()
            .ok_or_else(|| Error::Invalid("random access needs a mapped source".into()))?
            .len() as u64;
        if self.payload_base + ca > map_len {
            return Err(Error::Corrupt("mapped container shorter than its table".into()));
        }
        self.range_v1 = Some(RangeAccessV1 { comp_off, raw_off });
        Ok(())
    }

    /// Random-access range decode of a mapped `ZNN1` container (live
    /// state, or the table retained past a full sequential read).
    fn decode_range_v1(&mut self, offset: u64, len: u64) -> Result<Vec<u8>> {
        self.build_range_v1()?;
        let end = offset + len;
        let (layout, groups, entries): (GroupLayout, usize, &[StreamEntry]) = match &self.state {
            ReaderState::V1 { layout, groups, entries, .. } => (*layout, *groups, entries),
            _ => match &self.v1_table {
                Some((l, g, e)) => (*l, *g, e),
                None => unreachable!("checked by caller"),
            },
        };
        let ra = self.range_v1.as_ref().expect("just built");
        // Covering chunks [c0, c1): the prefix arrays have n_chunks + 1
        // monotonically increasing entries ending at the totals.
        let c0 = ra.raw_off.partition_point(|&o| o <= offset) - 1;
        let c1 = ra.raw_off.partition_point(|&o| o < end);
        let buf = &mut self.range_buf;
        buf.layout = layout;
        buf.groups = groups;
        buf.entries.clear();
        buf.entries.extend_from_slice(&entries[c0 * groups..c1 * groups]);
        buf.spans.clear();
        let mut out_off = 0usize;
        for c in c0..c1 {
            let out_len = (ra.raw_off[c + 1] - ra.raw_off[c]) as usize;
            buf.spans.push(ChunkSpan {
                comp_off: (self.payload_base + ra.comp_off[c]) as usize,
                comp_len: (ra.comp_off[c + 1] - ra.comp_off[c]) as usize,
                out_off,
                out_len,
                entry_off: (c - c0) * groups,
                layout,
                groups,
            });
            out_off += out_len;
        }
        buf.n_chunks = c1 - c0;
        buf.out_len = out_off;
        buf.comp_len = (self.payload_base + ra.comp_off[c1]) as usize;
        buf.payload = PayloadAt::Mapped(0);
        ensure_len(&mut buf.out, out_off);
        let skip = (offset - ra.raw_off[c0]) as usize;
        self.decode_staged_range()?;
        Ok(self.range_buf.out[skip..skip + len as usize].to_vec())
    }

    /// Random-access range decode of a mapped `ZNS1` container through
    /// its index's frame directory (geometry from the open-time capture,
    /// so this outlives the sequential state machine).
    fn decode_range_v2(&mut self, offset: u64, len: u64) -> Result<Vec<u8>> {
        let (layout, groups, state_chunk) = self.v2_meta.expect("checked by caller");
        // Field access (not the `cached_index` helper) so the borrow is
        // of `self.index` alone and `range_buf` stays mutably borrowable.
        let idx = self
            .index
            .as_ref()
            .and_then(|o| o.as_ref())
            .expect("checked by caller");
        if idx.chunk_size != state_chunk {
            return Err(Error::Corrupt(format!(
                "index chunk size {} disagrees with header {state_chunk}",
                idx.chunk_size
            )));
        }
        let aligned = idx.aligned_len();
        // The tail is tiny (< 16 bytes); clone what the assembly below
        // needs so the index borrow ends before the decode mutates self.
        let tail: Vec<u8> = idx.tail.clone();
        let base_raw =
            stage_range_v2(idx, &self.src, &mut self.range_buf, layout, groups, offset, len)?;
        self.decode_staged_range()?;
        let end = offset + len;
        let mut out = Vec::with_capacity(len as usize);
        if offset < aligned {
            let s = (offset - base_raw) as usize;
            let e = (end.min(aligned) - base_raw) as usize;
            out.extend_from_slice(&self.range_buf.out[s..e]);
        }
        if end > aligned {
            let ts = (offset.max(aligned) - aligned) as usize;
            let te = (end - aligned) as usize;
            let got = tail.get(ts..te).ok_or_else(|| {
                Error::Corrupt("index tail shorter than the requested range".into())
            })?;
            out.extend_from_slice(got);
        }
        Ok(out)
    }

    /// Decode the chunks staged in `range_buf`: on the shared sticky pool
    /// (its own batch control, so an in-flight sequential batch is
    /// unaffected) when threaded, inline otherwise.
    fn decode_staged_range(&mut self) -> Result<()> {
        if self.range_buf.n_chunks == 0 {
            return Ok(());
        }
        if self.threads > 1 && self.range_buf.n_chunks > 1 {
            if self.range_engine.is_none() {
                self.range_engine = Some(Engine::new(self.threads));
            }
            let comp_ptr = self.src.mapped_slice(0, self.range_buf.comp_len).as_ptr();
            let engine = self.range_engine.as_mut().expect("just created");
            engine.epoch += 1;
            let b = &mut self.range_buf;
            let frame = TaskFrame {
                epoch: engine.epoch,
                n: b.n_chunks,
                kind: TaskKind::Decode(DecodeFrame {
                    entries: b.entries.as_ptr(),
                    comp: comp_ptr,
                    spans: b.spans.as_ptr(),
                    out: b.out.as_mut_ptr(),
                }),
            };
            engine.submit(frame);
            // Joined before returning, so the frame's pointers never
            // outlive this call.
            self.range_engine.as_ref().expect("just created").wait(frame, &mut self.arena)
        } else {
            decode_batch_serial(&self.src, &mut self.range_buf, &mut self.arena)
        }
    }

    /// Sequential fallback: decode (and discard) up to `offset`, then
    /// return the next `len` bytes. Works on any source, including
    /// sockets and the `ZIPNN_NO_MMAP` buffered-file path; ranges must be
    /// at or ahead of the current stream position.
    fn decode_range_sequential(&mut self, offset: u64, len: u64) -> Result<Vec<u8>> {
        if self.served > offset {
            return Err(Error::Invalid(format!(
                "range start {offset} is behind the stream position {} \
                 (sequential sources only decode forward)",
                self.served
            )));
        }
        let mut scratch = [0u8; 8192];
        while self.served < offset {
            let take = ((offset - self.served) as usize).min(scratch.len());
            let n = Read::read(self, &mut scratch[..take]).map_err(from_io_err)?;
            if n == 0 {
                return Err(Error::Invalid(format!(
                    "range start {offset} past the container's raw length {}",
                    self.served
                )));
            }
        }
        let mut out = vec![0u8; len as usize];
        let mut at = 0usize;
        while at < out.len() {
            let n = Read::read(self, &mut out[at..]).map_err(from_io_err)?;
            if n == 0 {
                return Err(Error::Invalid(format!(
                    "range [{offset}, {}) past the container's raw length {}",
                    offset + len,
                    self.served
                )));
            }
            at += n;
        }
        Ok(out)
    }
}

/// Stage the chunks of a mapped `ZNS1` container covering
/// `[offset, offset + len)` into `buf`, using the index's frame
/// directory: frame headers are parsed in place, non-covering chunks'
/// payloads are skipped by offset arithmetic, and spans address the
/// mapping absolutely (`PayloadAt::Mapped(0)`). Returns the raw offset of
/// the first staged chunk (`aligned_len` when the range lies entirely in
/// the trailer tail).
fn stage_range_v2<R: Read>(
    idx: &TensorIndex,
    src: &ByteSource<R>,
    buf: &mut BatchBuf,
    layout: GroupLayout,
    groups: usize,
    offset: u64,
    len: u64,
) -> Result<u64> {
    let bytes = src
        .mapped_bytes()
        .ok_or_else(|| Error::Invalid("random access needs a mapped source".into()))?;
    let data: &[u8] = bytes;
    // The mapping starts at the container header, so the frame-checksum
    // flag is read straight from it: ranged reads then verify every
    // covering frame before decoding — the only integrity check a
    // sub-range can have (the whole-stream trailer checksum needs every
    // byte).
    let frame_ck = data.len() >= STREAM_HEADER_LEN
        && data[0..4] == STREAM_MAGIC
        && data[5] & SFLAG_FRAME_CK != 0;
    let chunk = idx.chunk_size as u64;
    let aligned = idx.aligned_len();
    let n_chunks = aligned.div_ceil(chunk);
    let n_frames = n_chunks.div_ceil(SUPER_CHUNK as u64);
    if idx.frame_offsets.len() as u64 != n_frames {
        return Err(Error::Corrupt(format!(
            "index frame directory holds {} offsets, container needs {n_frames}",
            idx.frame_offsets.len()
        )));
    }
    buf.layout = layout;
    buf.groups = groups;
    buf.entries.clear();
    buf.spans.clear();
    buf.n_chunks = 0;
    buf.out_len = 0;
    buf.comp_len = 0;
    buf.payload = PayloadAt::Mapped(0);
    if offset >= aligned {
        return Ok(aligned); // range lies entirely in the trailer tail
    }
    let end = offset + len;
    let c0 = offset / chunk;
    let c1 = end.min(aligned).div_ceil(chunk).min(n_chunks);
    let f0 = (c0 / SUPER_CHUNK as u64) as usize;
    let f1 = c1.div_ceil(SUPER_CHUNK as u64) as usize;
    let mut out_off = 0usize;
    let mut row = [0u8; 9];
    for f in f0..f1 {
        let foff = idx.frame_offsets[f] as usize;
        if foff >= data.len() {
            return Err(Error::Corrupt("index frame offset past container".into()));
        }
        // Plain frames (0xF5) decode with the container-wide layout;
        // pframes (0xF7) prefix the stream count with their own 2-byte
        // layout, so a single staged batch can mix geometries.
        let (f_layout, count_at) = match data[foff] {
            MARK_FRAME => (layout, foff + 1),
            MARK_PFRAME => {
                if foff + 3 > data.len() {
                    return Err(Error::Corrupt("frame layout prefix past container".into()));
                }
                let elem = data[foff + 1] as usize;
                let exp_group = data[foff + 2] as usize;
                if elem == 0 || elem > 16 || exp_group >= elem {
                    return Err(Error::Corrupt(format!(
                        "bad frame layout elem={elem} exp_group={exp_group}"
                    )));
                }
                (GroupLayout { elem, exp_group }, foff + 3)
            }
            m => {
                return Err(Error::Corrupt(format!(
                    "index frame offset not at a frame marker (0x{m:02x})"
                )))
            }
        };
        let f_groups = f_layout.groups();
        let count_end = count_at
            .checked_add(4)
            .filter(|&e| e <= data.len())
            .ok_or_else(|| Error::Corrupt("index frame offset past container".into()))?;
        let n_streams = u32::from_le_bytes(data[count_at..count_end].try_into().unwrap()) as usize;
        if n_streams == 0 || n_streams > SUPER_CHUNK * 16 || n_streams % f_groups != 0 {
            return Err(Error::Corrupt(format!("bad frame stream count {n_streams}")));
        }
        let rows_base = if frame_ck {
            count_end
                .checked_add(8)
                .filter(|&e| e <= data.len())
                .ok_or_else(|| Error::Corrupt("frame checksum past container".into()))?
        } else {
            count_end
        };
        let frame_chunks = n_streams / f_groups;
        let rows_end = rows_base
            .checked_add(9 * n_streams)
            .filter(|&e| e <= data.len())
            .ok_or_else(|| Error::Corrupt("frame table past container".into()))?;
        let mut cursor = rows_end as u64;
        for j in 0..frame_chunks {
            let c = f as u64 * SUPER_CHUNK as u64 + j as u64;
            if c >= n_chunks {
                return Err(Error::Corrupt("frame holds chunks past the container".into()));
            }
            let included = c >= c0 && c < c1;
            let entry_off = buf.entries.len();
            let (mut comp_sum, mut raw_sum) = (0u64, 0u64);
            for g in 0..f_groups {
                let base = rows_base + 9 * (j * f_groups + g);
                row.copy_from_slice(&data[base..base + 9]);
                let e = parse_entry(&row)?;
                if e.comp_len > e.raw_len || e.raw_len as u64 > chunk {
                    return Err(Error::Corrupt("implausible stream entry".into()));
                }
                comp_sum += e.comp_len as u64;
                raw_sum += e.raw_len as u64;
                if included {
                    buf.entries.push(e);
                }
            }
            if raw_sum != (aligned - c * chunk).min(chunk) {
                return Err(Error::Corrupt(format!(
                    "chunk {c} raw length {raw_sum} disagrees with its placement"
                )));
            }
            if included {
                buf.spans.push(ChunkSpan {
                    comp_off: cursor as usize,
                    comp_len: comp_sum as usize,
                    out_off,
                    out_len: raw_sum as usize,
                    entry_off,
                    layout: f_layout,
                    groups: f_groups,
                });
                out_off += raw_sum as usize;
                buf.n_chunks += 1;
            }
            cursor += comp_sum;
            if cursor > data.len() as u64 {
                return Err(Error::Corrupt("frame payload past container".into()));
            }
        }
        let frame_end = if f + 1 < idx.frame_offsets.len() {
            idx.frame_offsets[f + 1]
        } else {
            idx.trailer_off
        };
        if cursor > frame_end {
            return Err(Error::Corrupt("frame payload overruns its successor".into()));
        }
        if frame_ck {
            // Rows and payload are contiguous: [rows_base, cursor).
            let expect = u64::from_le_bytes(data[count_end..count_end + 8].try_into().unwrap());
            let mut ck = Checksummer::streaming();
            ck.update(&data[rows_base..cursor as usize]);
            if ck.finalize() != expect {
                return Err(Error::Corrupt(format!("frame {f} checksum mismatch")));
            }
        }
    }
    buf.out_len = out_off;
    buf.comp_len = buf.spans.iter().map(|s| s.comp_off + s.comp_len).max().unwrap_or(0);
    ensure_len(&mut buf.out, out_off);
    Ok(c0 * chunk)
}

impl<R: Read> Drop for ZnnReader<R> {
    /// Join any in-flight decode before the batch buffers are freed (the
    /// pool helpers hold raw pointers into them while chunks are claimed).
    fn drop(&mut self) {
        if let (Some(frame), Some(engine)) = (self.pending.take(), self.engine.as_ref()) {
            let _ = engine.wait(frame, &mut self.arena);
        }
    }
}

/// What [`ZnnReader::salvage`] recovered from a damaged container.
#[derive(Debug, Clone)]
pub struct SalvageReport {
    /// Frames in the container's directory.
    pub total_frames: usize,
    /// Frames that failed to decode (zero-filled in the salvaged output).
    pub bad_frames: Vec<usize>,
    /// Tensors whose raw ranges intersect a bad frame.
    pub lost_tensors: Vec<String>,
    /// Bytes of the output holding real decoded data (including the tail).
    pub recovered_bytes: u64,
    /// The container's raw length (= salvaged output length).
    pub total_len: u64,
}

impl SalvageReport {
    /// True when every frame decoded — the output is the full payload.
    pub fn is_clean(&self) -> bool {
        self.bad_frames.is_empty()
    }
}

/// Transfer-side verdict on a (possibly partial) byte buffer — see
/// [`scan_wire`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum WireScan {
    /// Not a `ZNS1` container: no frame structure to verify (the caller
    /// falls back to plain byte counting).
    Opaque,
    /// Clean so far; `verified` ends the last complete verified frame
    /// (or the header), and the rest is an incomplete suffix.
    NeedMore { verified: usize },
    /// The frame at `verified` is damaged. `frame_end` is its wire end
    /// when the frame parsed well enough to measure (checksum mismatch),
    /// `None` when the structure itself is garbage.
    Corrupt { verified: usize, frame_end: Option<usize> },
    /// Trailer complete at `verified`; any bytes after it belong to the
    /// opaque index section, whose length only the sender knows.
    Complete { verified: usize },
}

/// Scan a partially transferred `ZNS1` container and report the longest
/// verified prefix — the resumable-download primitive: after a broken or
/// corrupt transfer the client keeps `verified` bytes and re-requests
/// only the rest (or exactly the bad frame). Frames are verified by
/// their [`SFLAG_FRAME_CK`] checksum when the container carries one, by
/// structure alone otherwise. Never panics on arbitrary bytes.
pub(crate) fn scan_wire(data: &[u8]) -> WireScan {
    let have = data.len();
    if have < STREAM_HEADER_LEN {
        let n = have.min(4);
        return if data[..n] == STREAM_MAGIC[..n] {
            WireScan::NeedMore { verified: 0 }
        } else {
            WireScan::Opaque
        };
    }
    if data[0..4] != STREAM_MAGIC || data[4] != STREAM_VERSION {
        return WireScan::Opaque;
    }
    let flags = data[5];
    let frame_ck = flags & SFLAG_FRAME_CK != 0;
    let trailer_ck = if flags & SFLAG_CHECKSUM != 0 { 8 } else { 0 };
    let mut pos = STREAM_HEADER_LEN;
    loop {
        if pos >= have {
            return WireScan::NeedMore { verified: pos };
        }
        let prefix = match data[pos] {
            MARK_FRAME => 1,
            MARK_PFRAME => 3,
            MARK_END => {
                if pos + 2 > have {
                    return WireScan::NeedMore { verified: pos };
                }
                let tail_len = data[pos + 1] as usize;
                if tail_len >= 16 {
                    return WireScan::Corrupt { verified: pos, frame_end: None };
                }
                let end = pos + 2 + tail_len + 8 + trailer_ck;
                if end > have {
                    return WireScan::NeedMore { verified: pos };
                }
                return WireScan::Complete { verified: end };
            }
            _ => return WireScan::Corrupt { verified: pos, frame_end: None },
        };
        let count_at = pos + prefix;
        let rows_base = count_at + 4 + if frame_ck { 8 } else { 0 };
        if rows_base > have {
            return WireScan::NeedMore { verified: pos };
        }
        let n_streams =
            u32::from_le_bytes(data[count_at..count_at + 4].try_into().unwrap()) as usize;
        if n_streams == 0 || n_streams > SUPER_CHUNK * 16 {
            return WireScan::Corrupt { verified: pos, frame_end: None };
        }
        let rows_end = rows_base + 9 * n_streams;
        if rows_end > have {
            return WireScan::NeedMore { verified: pos };
        }
        let mut payload = 0usize;
        for r in 0..n_streams {
            let at = rows_base + 9 * r;
            let comp = u32::from_le_bytes(data[at + 1..at + 5].try_into().unwrap()) as usize;
            let raw = u32::from_le_bytes(data[at + 5..at + 9].try_into().unwrap()) as usize;
            if comp > raw || raw > MAX_CHUNK_SIZE as usize {
                return WireScan::Corrupt { verified: pos, frame_end: None };
            }
            payload += comp;
        }
        let frame_end = rows_end + payload;
        if frame_end > have {
            return WireScan::NeedMore { verified: pos };
        }
        if frame_ck {
            let expect =
                u64::from_le_bytes(data[count_at + 4..count_at + 12].try_into().unwrap());
            let mut ck = Checksummer::streaming();
            ck.update(&data[rows_base..frame_end]);
            if ck.finalize() != expect {
                return WireScan::Corrupt { verified: pos, frame_end: Some(frame_end) };
            }
        }
        pos = frame_end;
    }
}

fn parse_entry(row: &[u8; 9]) -> Result<StreamEntry> {
    let method = Method::from_tag(row[0])
        .ok_or_else(|| Error::Corrupt(format!("bad method tag {}", row[0])))?;
    let comp_len = u32::from_le_bytes(row[1..5].try_into().unwrap());
    let raw_len = u32::from_le_bytes(row[5..9].try_into().unwrap());
    if method == Method::Zero && comp_len != 0 {
        return Err(Error::Corrupt("zero stream with payload".into()));
    }
    Ok(StreamEntry { method, comp_len, raw_len })
}

impl<R: Read> Read for ZnnReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        loop {
            if self.pos < self.cur.out_len {
                let n = (self.cur.out_len - self.pos).min(buf.len());
                buf[..n].copy_from_slice(&self.cur.out[self.pos..self.pos + n]);
                self.pos += n;
                self.served += n as u64;
                return Ok(n);
            }
            if matches!(self.state, ReaderState::Done) && self.pending.is_none() {
                return Ok(0);
            }
            self.refill().map_err(to_io_err)?;
            if self.cur.out_len == 0
                && matches!(self.state, ReaderState::Done)
                && self.pending.is_none()
            {
                return Ok(0);
            }
        }
    }
}

/// Convenience: fully decompress a container through [`ZnnReader`].
pub fn decompress_reader(r: impl Read, threads: usize) -> Result<Vec<u8>> {
    let mut zr = ZnnReader::new(r)?.with_threads(threads);
    let mut out = Vec::new();
    zr.read_to_end(&mut out).map_err(from_io_err)?;
    Ok(out)
}

/// Convenience: fully decompress a container file on the zero-copy
/// mapped fast path (see [`ZnnReader::open`]).
pub fn decompress_path(path: impl AsRef<Path>, threads: usize) -> Result<Vec<u8>> {
    let mut zr = ZnnReader::open(path)?.with_threads(threads);
    let mut out = Vec::new();
    zr.read_to_end(&mut out).map_err(from_io_err)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::{checksum64, decompress, CodecConfig, Compressor};
    use crate::fp::DType;
    use crate::util::Xoshiro256;

    fn gaussian_bf16(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut out = Vec::with_capacity(2 * n);
        for _ in 0..n {
            let w = (rng.normal() * 0.02) as f32;
            out.extend_from_slice(&crate::fp::dtype::f32_to_bf16_bits(w).to_le_bytes());
        }
        out
    }

    #[test]
    fn incremental_checksum_matches_one_shot() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for len in [0usize, 1, 7, 8, 9, 63, 64, 1000, 4097] {
            let mut data = vec![0u8; len];
            rng.fill_bytes(&mut data);
            let expect = checksum64(&data);
            // whole-buffer update
            let mut c = Checksummer::with_total_len(len as u64);
            c.update(&data);
            assert_eq!(c.finalize(), expect, "len={len}");
            // byte-at-a-time
            let mut c = Checksummer::with_total_len(len as u64);
            for b in &data {
                c.update(std::slice::from_ref(b));
            }
            assert_eq!(c.finalize(), expect, "len={len} bytewise");
            // random splits
            let mut c = Checksummer::with_total_len(len as u64);
            let mut at = 0;
            while at < len {
                let take = (1 + rng.below(13)).min(len - at);
                c.update(&data[at..at + take]);
                at += take;
            }
            assert_eq!(c.finalize(), expect, "len={len} random splits");
        }
    }

    #[test]
    fn writer_reader_roundtrip_bf16() {
        let raw = gaussian_bf16(400_000, 2);
        let cfg = CodecConfig::for_dtype(DType::BF16);
        let mut w = ZnnWriter::new(Vec::new(), cfg).unwrap();
        w.write_all(&raw).unwrap();
        let container = w.finish().unwrap();
        assert!(container.len() < raw.len(), "must compress");
        let back = decompress_reader(container.as_slice(), 1).unwrap();
        assert_eq!(back, raw);
    }

    #[test]
    fn writer_output_independent_of_split_and_threads() {
        let raw = gaussian_bf16(300_000, 3);
        let cfg = CodecConfig::for_dtype(DType::BF16).with_chunk_size(16 * 1024);
        let mut one = ZnnWriter::new(Vec::new(), cfg.clone()).unwrap();
        one.write_all(&raw).unwrap();
        let one = one.finish().unwrap();

        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut many = ZnnWriter::new(Vec::new(), cfg.clone().with_threads(4)).unwrap();
        let mut at = 0;
        while at < raw.len() {
            let take = (1 + rng.below(50_000)).min(raw.len() - at);
            many.write_all(&raw[at..at + take]).unwrap();
            at += take;
        }
        let many = many.finish().unwrap();
        assert_eq!(one, many, "split pattern and threads must not change bytes");
    }

    #[test]
    fn unaligned_tail_rides_in_trailer() {
        let mut raw = gaussian_bf16(10_000, 5);
        raw.push(0xAB); // odd byte: not elem-aligned for BF16
        let cfg = CodecConfig::for_dtype(DType::BF16);
        let mut w = ZnnWriter::new(Vec::new(), cfg).unwrap();
        w.write_all(&raw).unwrap();
        let container = w.finish().unwrap();
        assert_eq!(decompress_reader(container.as_slice(), 1).unwrap(), raw);
    }

    #[test]
    fn empty_input_roundtrips() {
        let cfg = CodecConfig::for_dtype(DType::F32);
        let w = ZnnWriter::new(Vec::new(), cfg).unwrap();
        let container = w.finish().unwrap();
        assert_eq!(decompress_reader(container.as_slice(), 1).unwrap(), b"");
    }

    #[test]
    fn reader_decodes_one_shot_containers() {
        for n in [0usize, 1, 100, 200_000] {
            let raw = gaussian_bf16(n, 6);
            let comp = Compressor::new(CodecConfig::for_dtype(DType::BF16))
                .compress(&raw)
                .unwrap();
            assert_eq!(decompress_reader(comp.as_slice(), 1).unwrap(), raw, "n={n}");
            assert_eq!(decompress_reader(comp.as_slice(), 4).unwrap(), raw, "n={n} mt");
            assert_eq!(decompress(&comp).unwrap(), raw);
        }
    }

    #[test]
    fn reader_small_read_calls() {
        let raw = gaussian_bf16(50_000, 7);
        let cfg = CodecConfig::for_dtype(DType::BF16).with_chunk_size(4096);
        let mut w = ZnnWriter::new(Vec::new(), cfg).unwrap();
        w.write_all(&raw).unwrap();
        let container = w.finish().unwrap();
        let mut r = ZnnReader::new(container.as_slice()).unwrap();
        let mut back = Vec::new();
        let mut buf = [0u8; 997];
        loop {
            let n = r.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            back.extend_from_slice(&buf[..n]);
        }
        assert_eq!(back, raw);
    }

    #[test]
    fn truncated_stream_container_rejected() {
        let raw = gaussian_bf16(100_000, 8);
        let mut w = ZnnWriter::new(Vec::new(), CodecConfig::for_dtype(DType::BF16)).unwrap();
        w.write_all(&raw).unwrap();
        let container = w.finish().unwrap();
        for cut in [0, 3, 11, container.len() / 2, container.len() - 1] {
            assert!(
                decompress_reader(&container[..cut], 1).is_err(),
                "cut={cut}"
            );
        }
    }

    #[test]
    fn corrupt_stream_payload_detected() {
        let raw = gaussian_bf16(150_000, 9);
        let mut w = ZnnWriter::new(Vec::new(), CodecConfig::for_dtype(DType::BF16)).unwrap();
        w.write_all(&raw).unwrap();
        let mut container = w.finish().unwrap();
        let n = container.len();
        container[n - 20] ^= 0x10;
        match decompress_reader(container.as_slice(), 1) {
            Err(_) => {}
            Ok(back) => assert_ne!(back, raw, "corruption must not roundtrip silently"),
        }
    }

    #[test]
    fn flush_does_not_finalize() {
        let cfg = CodecConfig::for_dtype(DType::BF16);
        let mut w = ZnnWriter::new(Vec::new(), cfg).unwrap();
        w.write_all(&[1, 2, 3, 4]).unwrap();
        w.flush().unwrap(); // flush must not end the container
        w.write_all(&[5, 6]).unwrap();
        let container = w.finish().unwrap();
        assert_eq!(
            decompress_reader(container.as_slice(), 1).unwrap(),
            [1, 2, 3, 4, 5, 6]
        );
    }

    #[test]
    fn failed_emission_poisons_writer() {
        /// Sink that rejects any write past its first `ok_bytes`.
        struct FailAfter {
            ok_bytes: usize,
            written: usize,
        }
        impl Write for FailAfter {
            fn write(&mut self, b: &[u8]) -> io::Result<usize> {
                if self.written + b.len() > self.ok_bytes {
                    return Err(io::Error::new(io::ErrorKind::WriteZero, "sink full"));
                }
                self.written += b.len();
                Ok(b.len())
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        let raw = gaussian_bf16(120_000, 33);
        for threads in [1usize, 2] {
            let cfg = CodecConfig::for_dtype(DType::BF16)
                .with_chunk_size(4096)
                .with_threads(threads);
            // Room for the 12-byte header and little else: the first
            // emitted frame fails mid-write, leaving a partial frame on
            // the sink.
            let sink = FailAfter { ok_bytes: 64, written: 0 };
            let mut w = ZnnWriter::new(sink, cfg).unwrap();
            let mut failed = false;
            for part in raw.chunks(10_000) {
                if w.write_all(part).and_then(|()| w.flush()).is_err() {
                    failed = true;
                    break;
                }
            }
            assert!(failed, "threads={threads}: sink failure never surfaced");
            // Poisoned: no write can append past the corruption, and
            // finish refuses to cap a half-written container.
            assert!(w.write_all(&[0, 0]).is_err(), "threads={threads}: write after failure");
            assert!(w.flush().is_err(), "threads={threads}: flush after failure");
            assert!(w.finish().is_err(), "threads={threads}: finish after failure");
        }
    }

    #[test]
    fn pooled_flush_emits_completed_frames() {
        use std::sync::{Arc, Mutex};
        /// Cloneable sink so the test can watch bytes arrive while the
        /// writer still owns its copy.
        #[derive(Clone)]
        struct Shared(Arc<Mutex<Vec<u8>>>);
        impl Write for Shared {
            fn write(&mut self, b: &[u8]) -> io::Result<usize> {
                self.0.lock().unwrap().extend_from_slice(b);
                Ok(b.len())
            }
            fn flush(&mut self) -> io::Result<()> {
                Ok(())
            }
        }
        // Exactly one batch (2 threads x 16 chunks x 4 KiB = 128 KiB):
        // the batch is submitted to the pool the moment the buffer
        // fills, and `flush` must join it and emit its frames — not
        // leave the sink holding only the 12-byte header.
        let cfg = CodecConfig::for_dtype(DType::BF16)
            .with_chunk_size(4096)
            .with_threads(2);
        let raw = gaussian_bf16(65536, 31); // 131072 bytes
        let sink = Shared(Arc::new(Mutex::new(Vec::new())));
        let mut w = ZnnWriter::new(sink.clone(), cfg).unwrap();
        w.write_all(&raw).unwrap();
        w.flush().unwrap();
        let emitted = sink.0.lock().unwrap().len();
        assert!(
            emitted > STREAM_HEADER_LEN,
            "flush left the completed batch unemitted ({emitted} bytes on the sink)"
        );
        w.finish().unwrap();
        let full: Vec<u8> = sink.0.lock().unwrap().clone();
        assert_eq!(decompress_reader(full.as_slice(), 2).unwrap(), raw);
    }

    fn tmp_container(tag: &str, bytes: &[u8]) -> std::path::PathBuf {
        use std::sync::atomic::{AtomicU64, Ordering};
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let path = std::env::temp_dir().join(format!(
            "zipnn-stream-test-{}-{}-{tag}.znn",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&path, bytes).unwrap();
        path
    }

    #[test]
    fn mapped_reader_matches_stream_reader() {
        let raw = gaussian_bf16(200_000, 21);
        let cfg = CodecConfig::for_dtype(DType::BF16).with_chunk_size(16 * 1024);
        let mut w = ZnnWriter::new(Vec::new(), cfg.clone()).unwrap();
        w.write_all(&raw).unwrap();
        let zns = w.finish().unwrap();
        let znn = Compressor::new(cfg).compress(&raw).unwrap();
        for (tag, container) in [("zns", &zns), ("znn", &znn)] {
            let path = tmp_container(tag, container);
            for threads in [1usize, 4] {
                // mmap'd file (or its read fallback)
                let mut r = ZnnReader::open(&path).unwrap().with_threads(threads);
                #[cfg(unix)]
                if !crate::util::env::no_mmap() {
                    assert!(r.is_zero_copy(), "{tag}: expected the mapped fast path");
                }
                let mut got = Vec::new();
                r.read_to_end(&mut got).unwrap();
                assert_eq!(got, raw, "{tag} mapped threads={threads}");
                // owned bytes through the same zero-copy source machinery
                let mut r = ZnnReader::from_mapped(MappedBytes::from_vec(container.clone()))
                    .unwrap()
                    .with_threads(threads);
                assert!(!r.is_zero_copy());
                let mut got = Vec::new();
                r.read_to_end(&mut got).unwrap();
                assert_eq!(got, raw, "{tag} owned threads={threads}");
            }
            assert_eq!(decompress_path(&path, 2).unwrap(), raw, "{tag} decompress_path");
            std::fs::remove_file(&path).unwrap();
        }
    }

    #[test]
    fn pipelined_pool_decode_roundtrips() {
        // Many small frames so the pipelined refill cycles several times.
        let raw = gaussian_bf16(400_000, 22);
        let cfg = CodecConfig::for_dtype(DType::BF16).with_chunk_size(4096);
        let mut w = ZnnWriter::new(Vec::new(), cfg).unwrap();
        w.write_all(&raw).unwrap();
        let container = w.finish().unwrap();
        let mut r = ZnnReader::new(container.as_slice()).unwrap().with_threads(4);
        let mut back = Vec::new();
        let mut buf = [0u8; 10_007]; // odd size: crosses batch boundaries
        loop {
            let n = r.read(&mut buf).unwrap();
            if n == 0 {
                break;
            }
            back.extend_from_slice(&buf[..n]);
        }
        assert_eq!(back, raw);
        assert_eq!(r.raw_len(), raw.len() as u64);
    }

    #[test]
    fn dropping_reader_mid_stream_joins_pending_decode() {
        let raw = gaussian_bf16(300_000, 23);
        let cfg = CodecConfig::for_dtype(DType::BF16).with_chunk_size(4096);
        let mut w = ZnnWriter::new(Vec::new(), cfg).unwrap();
        w.write_all(&raw).unwrap();
        let container = w.finish().unwrap();
        let mut r = ZnnReader::new(container.as_slice()).unwrap().with_threads(4);
        let mut buf = [0u8; 4096];
        // One read leaves a batch in flight on the pool; drop must join it
        // (a dangling-buffer write would corrupt the next test's heap).
        let n = r.read(&mut buf).unwrap();
        assert!(n > 0);
        drop(r);
    }

    fn gaussian_f32(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut out = Vec::with_capacity(4 * n);
        for _ in 0..n {
            let w = (rng.normal() * 0.02) as f32;
            out.extend_from_slice(&w.to_le_bytes());
        }
        out
    }

    /// fp8-like bytes: skewed exponent field, random sign/mantissa bits —
    /// compressible as a flat stream, garbled by multi-byte grouping.
    fn skewed_f8(n: usize, seed: u64) -> Vec<u8> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            let e = (8.0 + rng.normal() * 1.5).clamp(1.0, 14.0) as u8;
            let r = rng.next_u32();
            out.push(((r >> 24) as u8 & 0x80) | (e << 3) | (r as u8 & 0x7));
        }
        out
    }

    /// A bf16 + fp32 + fp8 payload with its tensor spans — large enough
    /// that each dtype region dominates several 64 KiB (4 KiB x 16)
    /// frames on its own.
    fn mixed_payload(seed: u64) -> (Vec<u8>, Vec<TensorMeta>) {
        let mut raw = Vec::new();
        let mut metas = Vec::new();
        for (name, dtype, bytes) in [
            ("attn.w", DType::BF16, gaussian_bf16(120_000, seed)),
            ("embed.w", DType::F32, gaussian_f32(50_000, seed + 1)),
            ("mlp.w", DType::F8E4M3, skewed_f8(150_000, seed + 2)),
        ] {
            metas.push(TensorMeta {
                name: name.into(),
                dtype,
                offset: raw.len() as u64,
                len: bytes.len() as u64,
            });
            raw.extend_from_slice(&bytes);
        }
        (raw, metas)
    }

    #[test]
    fn profiled_writer_roundtrips_and_flags() {
        let (raw, metas) = mixed_payload(41);
        let cfg = CodecConfig::for_dtype(DType::BF16).with_chunk_size(4096);
        let sel = ProfileSelector::auto(&metas, CodecProfile::for_dtype(DType::BF16)).unwrap();
        let mut w = ZnnWriter::new(Vec::new(), cfg.clone())
            .unwrap()
            .with_profiles(sel.clone())
            .unwrap();
        w.write_all(&raw).unwrap();
        let container = w.finish().unwrap();
        assert_ne!(container[5] & SFLAG_PROFILES, 0, "profile flag must be set");
        assert_eq!(container[STREAM_HEADER_LEN], MARK_PFRAME, "first frame must be 0xF7");
        assert_eq!(decompress_reader(container.as_slice(), 1).unwrap(), raw);
        assert_eq!(decompress_reader(container.as_slice(), 4).unwrap(), raw);

        // Pooled writer with scattered write sizes: byte-identical output.
        let mut rng = Xoshiro256::seed_from_u64(42);
        let mut wt = ZnnWriter::new(Vec::new(), cfg.clone().with_threads(4))
            .unwrap()
            .with_profiles(sel)
            .unwrap();
        let mut at = 0;
        while at < raw.len() {
            let take = (1 + rng.below(30_000)).min(raw.len() - at);
            wt.write_all(&raw[at..at + take]).unwrap();
            at += take;
        }
        assert_eq!(wt.finish().unwrap(), container, "threads must not change bytes");

        // The profile-free writer stays on classic 0xF5 frames with the
        // flag clear (pre-profile readers keep working on its output).
        let mut wp = ZnnWriter::new(Vec::new(), cfg).unwrap();
        wp.write_all(&raw).unwrap();
        let plain = wp.finish().unwrap();
        assert_eq!(plain[5] & SFLAG_PROFILES, 0);
        assert_eq!(plain[STREAM_HEADER_LEN], MARK_FRAME);
        assert_eq!(decompress_reader(plain.as_slice(), 1).unwrap(), raw);
    }

    #[test]
    fn with_profiles_rejects_late_and_misaligned() {
        // elem 2 cannot divide an odd chunk size
        let cfg = CodecConfig::for_dtype(DType::I8).with_chunk_size(1001);
        let sel = ProfileSelector::uniform(CodecProfile::for_dtype(DType::BF16));
        assert!(ZnnWriter::new(Vec::new(), cfg)
            .unwrap()
            .with_profiles(sel.clone())
            .is_err());
        // configuring after bytes were accepted is an error
        let mut w = ZnnWriter::new(Vec::new(), CodecConfig::for_dtype(DType::BF16)).unwrap();
        w.write_all(&[1, 2, 3, 4]).unwrap();
        assert!(w.with_profiles(sel).is_err());
    }

    #[test]
    fn profiled_container_random_access() {
        let (raw, metas) = mixed_payload(43);
        let cfg = CodecConfig::for_dtype(DType::BF16).with_chunk_size(4096);
        let sel = ProfileSelector::auto(&metas, CodecProfile::for_dtype(DType::BF16)).unwrap();
        let mut w = ZnnWriter::new(Vec::new(), cfg)
            .unwrap()
            .with_profiles(sel)
            .unwrap()
            .with_index(metas.clone());
        w.write_all(&raw).unwrap();
        let container = w.finish().unwrap();
        for threads in [1usize, 4] {
            let mut r = ZnnReader::from_mapped(MappedBytes::from_vec(container.clone()))
                .unwrap()
                .with_threads(threads);
            for m in &metas {
                let got = r.decode_tensor(&m.name).unwrap();
                let want = &raw[m.offset as usize..(m.offset + m.len) as usize];
                assert_eq!(got.as_slice(), want, "tensor {} threads={threads}", m.name);
            }
            // ranges that straddle differently-profiled frames
            for m in &metas[1..] {
                let mid = m.offset as usize;
                let (a, b) = (mid.saturating_sub(70_000), (mid + 70_000).min(raw.len()));
                let got = r.decode_range(a as u64, (b - a) as u64).unwrap();
                assert_eq!(got.as_slice(), &raw[a..b], "range {a}..{b} threads={threads}");
            }
        }
    }

    #[test]
    fn pipelined_decode_detects_corruption() {
        let raw = gaussian_bf16(300_000, 24);
        let mut w = ZnnWriter::new(Vec::new(), CodecConfig::for_dtype(DType::BF16)).unwrap();
        w.write_all(&raw).unwrap();
        let mut container = w.finish().unwrap();
        let n = container.len();
        container[n - 20] ^= 0x10;
        match decompress_reader(container.as_slice(), 4) {
            Err(_) => {}
            Ok(back) => assert_ne!(back, raw, "corruption must not roundtrip silently"),
        }
        for cut in [11, container.len() / 2, container.len() - 1] {
            assert!(decompress_reader(&container[..cut], 4).is_err(), "cut={cut}");
        }
    }
}
