//! The `.znn` container: header + per-stream metadata table + payload.
//!
//! The metadata table stores, for every `(chunk, group)` stream, its
//! method, compressed length and raw length. Because raw chunk sizes are
//! fixed, a reader can compute every stream's output placement up front and
//! decompress streams in parallel (paper §5.1 "metadata and parallelism").

use crate::codec::auto::Method;
use crate::error::{Error, Result};
use crate::fp::GroupLayout;
use crate::util::{push_u32_le, push_u64_le, read_u32_le, read_u64_le};

/// Container magic: "ZNN1".
pub const MAGIC: [u8; 4] = *b"ZNN1";
/// Container format version.
pub const VERSION: u8 = 1;
/// Header flag: a checksum of the raw buffer is present.
pub const FLAG_CHECKSUM: u8 = 1;
/// Header flag: a tensor index section (see [`crate::codec::index`])
/// follows the payload. Readers that ignore the flag still decode the
/// payload unchanged — the index is strictly trailing.
pub const FLAG_INDEX: u8 = 2;

/// Fixed-size part of the container header.
#[derive(Debug, Clone, PartialEq)]
pub struct ContainerHeader {
    /// Byte-group layout used at compression time.
    pub layout: GroupLayout,
    /// Raw bytes per chunk.
    pub chunk_size: u32,
    /// Total raw length.
    pub total_len: u64,
    /// Number of chunks (= ceil(total_len / chunk_size)).
    pub n_chunks: u32,
    /// Checksum of the raw buffer, if `FLAG_CHECKSUM`.
    pub checksum: Option<u64>,
}

/// One `(chunk, group)` stream's table entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StreamEntry {
    /// Compression method.
    pub method: Method,
    /// Compressed byte length in the payload (0 for `Zero`).
    pub comp_len: u32,
    /// Raw (decompressed) byte length of the stream.
    pub raw_len: u32,
}

/// Parsed container metadata plus payload offsets — everything needed for
/// random access and parallel decompression.
#[derive(Debug, Clone)]
pub struct ContainerInfo {
    /// Fixed header.
    pub header: ContainerHeader,
    /// `entries[chunk * groups + group]`.
    pub entries: Vec<StreamEntry>,
    /// Byte offset of each stream inside the payload, same indexing.
    pub offsets: Vec<u64>,
    /// Offset of the payload within the container.
    pub payload_start: usize,
}

impl ContainerInfo {
    /// Number of byte groups.
    pub fn groups(&self) -> usize {
        self.header.layout.groups()
    }

    /// Entry accessor.
    pub fn entry(&self, chunk: usize, group: usize) -> StreamEntry {
        self.entries[chunk * self.groups() + group]
    }

    /// Total compressed payload size.
    pub fn payload_len(&self) -> u64 {
        self.entries.iter().map(|e| e.comp_len as u64).sum()
    }

    /// Per-group compressed/raw byte totals `(comp, raw)` — the Table 2
    /// breakdown numbers.
    pub fn group_totals(&self) -> Vec<(u64, u64)> {
        let g = self.groups();
        let mut totals = vec![(0u64, 0u64); g];
        for c in 0..self.header.n_chunks as usize {
            for gi in 0..g {
                let e = self.entry(c, gi);
                totals[gi].0 += e.comp_len as u64;
                totals[gi].1 += e.raw_len as u64;
            }
        }
        totals
    }
}

/// Serialize the header + table. `entries` must hold
/// `n_chunks * layout.groups()` items in chunk-major order.
pub fn write_header(h: &ContainerHeader, entries: &[StreamEntry]) -> Vec<u8> {
    let mut out = Vec::with_capacity(32 + entries.len() * 9);
    out.extend_from_slice(&MAGIC);
    out.push(VERSION);
    let flags = if h.checksum.is_some() { FLAG_CHECKSUM } else { 0 };
    out.push(flags);
    out.push(h.layout.elem as u8);
    out.push(h.layout.exp_group as u8);
    push_u32_le(&mut out, h.chunk_size);
    push_u64_le(&mut out, h.total_len);
    push_u32_le(&mut out, h.n_chunks);
    if let Some(c) = h.checksum {
        push_u64_le(&mut out, c);
    }
    for e in entries {
        out.push(e.method.tag());
        push_u32_le(&mut out, e.comp_len);
        push_u32_le(&mut out, e.raw_len);
    }
    out
}

/// Largest accepted declared chunk size (corruption guard: readers size
/// buffers from the header).
pub(crate) const MAX_CHUNK_SIZE: u32 = 1 << 30;

/// Parse and validate the fixed 20 header bytes that follow the magic
/// (version, flags, layout, chunk size, total length, chunk count).
/// Shared by the buffer parser and the streaming [`crate::codec::stream`]
/// reader so the two paths cannot drift. Returns
/// `(flags, layout, chunk_size, total_len, n_chunks)`.
pub(crate) fn parse_fixed_header(
    head: &[u8; 20],
) -> Result<(u8, GroupLayout, u32, u64, u32)> {
    if head[0] != VERSION {
        return Err(Error::Corrupt(format!("unsupported version {}", head[0])));
    }
    let flags = head[1];
    let elem = head[2] as usize;
    let exp_group = head[3] as usize;
    if elem == 0 || elem > 16 || exp_group >= elem {
        return Err(Error::Corrupt(format!(
            "bad layout elem={elem} exp_group={exp_group}"
        )));
    }
    let chunk_size = read_u32_le(&head[..], 4);
    let total_len = read_u64_le(&head[..], 8);
    let n_chunks = read_u32_le(&head[..], 16);
    if chunk_size == 0 || chunk_size > MAX_CHUNK_SIZE {
        return Err(Error::Corrupt("bad chunk size".into()));
    }
    let expect_chunks = total_len.div_ceil(chunk_size as u64);
    if n_chunks as u64 != expect_chunks {
        return Err(Error::Corrupt(format!(
            "chunk count {n_chunks} inconsistent with total {total_len}/{chunk_size}"
        )));
    }
    Ok((flags, GroupLayout { elem, exp_group }, chunk_size, total_len, n_chunks))
}

/// Parse and validate the header + table of a container.
pub fn parse(data: &[u8]) -> Result<ContainerInfo> {
    if data.len() < 24 {
        return Err(Error::Corrupt("container too short".into()));
    }
    if data[0..4] != MAGIC {
        return Err(Error::Corrupt("bad magic".into()));
    }
    let head: [u8; 20] = data[4..24].try_into().expect("length checked");
    let (flags, layout, chunk_size, total_len, n_chunks) = parse_fixed_header(&head)?;
    let mut off = 24usize;
    let checksum = if flags & FLAG_CHECKSUM != 0 {
        if data.len() < off + 8 {
            return Err(Error::Corrupt("truncated checksum".into()));
        }
        let c = read_u64_le(data, off);
        off += 8;
        Some(c)
    } else {
        None
    };
    let groups = layout.groups();
    let n_entries = n_chunks as usize * groups;
    let table_bytes = n_entries * 9;
    if data.len() < off + table_bytes {
        return Err(Error::Corrupt("truncated stream table".into()));
    }
    let mut entries = Vec::with_capacity(n_entries);
    let mut offsets = Vec::with_capacity(n_entries);
    let mut payload_off = 0u64;
    let mut raw_sum = 0u64;
    for i in 0..n_entries {
        let base = off + i * 9;
        let method = Method::from_tag(data[base])
            .ok_or_else(|| Error::Corrupt(format!("bad method tag {}", data[base])))?;
        let comp_len = read_u32_le(data, base + 1);
        let raw_len = read_u32_le(data, base + 5);
        if method == Method::Zero && comp_len != 0 {
            return Err(Error::Corrupt("zero stream with payload".into()));
        }
        entries.push(StreamEntry { method, comp_len, raw_len });
        offsets.push(payload_off);
        payload_off += comp_len as u64;
        raw_sum += raw_len as u64;
    }
    if raw_sum != total_len {
        return Err(Error::Corrupt(format!(
            "stream raw lengths sum {raw_sum} != total {total_len}"
        )));
    }
    let payload_start = off + table_bytes;
    // An indexed container carries a trailing index section (+ footer)
    // after the payload; account for it so the strict length check still
    // catches truncation and padding.
    let trailing = if flags & FLAG_INDEX != 0 {
        crate::codec::index::trailing_len(data)
            .ok_or_else(|| Error::Corrupt("index flag set but no index section".into()))?
    } else {
        0
    };
    let body = data.len() - payload_start;
    if body.checked_sub(trailing).map(|p| p as u64) != Some(payload_off) {
        return Err(Error::Corrupt(format!(
            "payload length {} (container minus {trailing} index bytes) != table \
             total {payload_off}",
            body.saturating_sub(trailing)
        )));
    }
    Ok(ContainerInfo {
        header: ContainerHeader { layout, chunk_size, total_len, n_chunks, checksum },
        entries,
        offsets,
        payload_start,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> (ContainerHeader, Vec<StreamEntry>) {
        let h = ContainerHeader {
            layout: GroupLayout { elem: 2, exp_group: 1 },
            chunk_size: 8,
            total_len: 20,
            n_chunks: 3,
            checksum: Some(0xDEAD_BEEF),
        };
        let entries = vec![
            StreamEntry { method: Method::Huffman, comp_len: 3, raw_len: 4 },
            StreamEntry { method: Method::Raw, comp_len: 4, raw_len: 4 },
            StreamEntry { method: Method::Zero, comp_len: 0, raw_len: 4 },
            StreamEntry { method: Method::Zstd, comp_len: 2, raw_len: 4 },
            StreamEntry { method: Method::Raw, comp_len: 2, raw_len: 2 },
            StreamEntry { method: Method::Huffman, comp_len: 1, raw_len: 2 },
        ];
        (h, entries)
    }

    #[test]
    fn header_roundtrip() {
        let (h, entries) = sample();
        let mut buf = write_header(&h, &entries);
        let payload_len: usize = entries.iter().map(|e| e.comp_len as usize).sum();
        buf.extend(std::iter::repeat_n(0u8, payload_len));
        let info = parse(&buf).unwrap();
        assert_eq!(info.header, h);
        assert_eq!(info.entries, entries);
        assert_eq!(info.offsets, vec![0, 3, 7, 7, 9, 11]);
        assert_eq!(info.payload_len(), 12);
    }

    #[test]
    fn rejects_bad_magic_version_layout() {
        let (h, entries) = sample();
        let mut buf = write_header(&h, &entries);
        buf.extend(std::iter::repeat_n(0u8, 12));
        let mut bad = buf.clone();
        bad[0] = b'X';
        assert!(parse(&bad).is_err());
        let mut bad = buf.clone();
        bad[4] = 99;
        assert!(parse(&bad).is_err());
        let mut bad = buf.clone();
        bad[6] = 0; // elem 0
        assert!(parse(&bad).is_err());
        let mut bad = buf;
        bad[7] = 9; // exp_group >= elem
        assert!(parse(&bad).is_err());
    }

    #[test]
    fn rejects_inconsistent_lengths() {
        let (h, mut entries) = sample();
        entries[0].raw_len = 5; // raw sum now wrong
        let mut buf = write_header(&h, &entries);
        buf.extend(std::iter::repeat_n(0u8, 12));
        assert!(parse(&buf).is_err());
    }

    #[test]
    fn rejects_short_payload() {
        let (h, entries) = sample();
        let mut buf = write_header(&h, &entries);
        buf.extend(std::iter::repeat_n(0u8, 11)); // one byte short
        assert!(parse(&buf).is_err());
    }

    #[test]
    fn group_totals() {
        let (h, entries) = sample();
        let mut buf = write_header(&h, &entries);
        buf.extend(std::iter::repeat_n(0u8, 12));
        let info = parse(&buf).unwrap();
        let t = info.group_totals();
        // group 0: entries 0,2,4 -> comp 3+0+2, raw 4+4+2
        assert_eq!(t[0], (5, 10));
        // group 1: entries 1,3,5 -> comp 4+2+1, raw 4+4+2
        assert_eq!(t[1], (7, 10));
    }
}
