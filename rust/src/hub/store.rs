//! Durable, crash-safe on-disk blob storage for the hub.
//!
//! With a **persist root** (builder
//! [`crate::hub::HubServerBuilder::persist_dir`] or `ZIPNN_HUB_PERSIST`),
//! every acknowledged PUT survives a crash: the body is written to
//! `<root>/tmp/`, fsynced, and atomically renamed into `<root>/blobs/`
//! next to a small sidecar record carrying the blob's name, length,
//! whole-blob checksum, and whether the container declares per-frame
//! checksums. The **sidecar rename is the commit point** — a blob is
//! acknowledged only after both files are durable and the directory is
//! fsynced, so a crash at any instant leaves either the old state or the
//! new state, never a half-written blob that could be served.
//!
//! ## On-disk layout
//!
//! ```text
//! <root>/blobs/<hash16>-<gen>.blob   # the PUT body, bytes as stored
//! <root>/blobs/<hash16>-<gen>.meta   # sidecar: name, total, ck, frame-ck flag
//! <root>/tmp/                        # in-flight writes; reaped wholesale on startup
//! <root>/quarantine/                 # damaged blob/sidecar pairs, never served
//! ```
//!
//! `<hash16>` is a hash of the blob name (filenames stay filesystem-safe;
//! the sidecar holds the authoritative name) and `<gen>` is a
//! monotonically increasing generation: a re-PUT of an existing name
//! commits a *new* pair before the old one is deleted, so even a crash
//! mid-overwrite preserves one fully-verified copy.
//!
//! ## Recovery
//!
//! [`PersistStore::recover`] re-indexes the directory on startup: temp
//! files and orphan `.blob`s (no committed sidecar) are reaped, every
//! committed pair is re-read from disk and verified — length, whole-blob
//! checksum, and a full [`scan_wire`] structural walk (per-frame
//! checksums) when the container carries them — and blobs that fail
//! verification are moved to `quarantine/` instead of being served. When
//! several generations of a name survive a crash, the newest verified one
//! wins.
//!
//! ## Scrubbing
//!
//! [`scrub_loop`] re-walks the stored blobs in the background (interval:
//! builder knob or `ZIPNN_HUB_SCRUB_SECS`), re-reading each from disk —
//! deliberately *not* through the serving mmap, whose resident pages
//! could mask on-disk bit rot — and quarantines any blob whose bytes no
//! longer match the sidecar, removing it from the serving store so the
//! fleet repair loop can re-replicate a good copy.

use crate::codec::stream::{scan_wire, Checksummer, WireScan, SFLAG_FRAME_CK, STREAM_VERSION};
use crate::codec::STREAM_MAGIC;
use crate::hub::protocol::FRAME_MAX;
use crate::hub::server::{Store, StoredBlob};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

/// Sidecar magic + format version.
const META_MAGIC: &[u8; 8] = b"ZNNMETA1";
/// Sidecar flag: the stored container declares per-frame checksums, so
/// recovery and scrubbing can (and must) verify frame structure too.
const MFLAG_FRAME_CK: u8 = 1;
/// Structural-walk budget: blobs beyond this are still fully verified by
/// the whole-blob checksum, just without buffering them for `scan_wire`.
const MAX_SCAN_BYTES: u64 = 1 << 28;

/// Stripe count for the per-name commit locks (see
/// [`PersistStore::commit_lock`]). Power of two, sized so concurrent PUTs
/// of *different* names practically never contend.
const COMMIT_STRIPES: usize = 64;

/// One blob's sidecar record.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Sidecar {
    name: String,
    total: u64,
    ck: u64,
    frame_ck: bool,
}

impl Sidecar {
    fn encode(&self) -> Vec<u8> {
        let name = self.name.as_bytes();
        let mut out = Vec::with_capacity(8 + 1 + 8 + 8 + 4 + name.len());
        out.extend_from_slice(META_MAGIC);
        out.push(if self.frame_ck { MFLAG_FRAME_CK } else { 0 });
        out.extend_from_slice(&self.total.to_le_bytes());
        out.extend_from_slice(&self.ck.to_le_bytes());
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name);
        out
    }

    fn parse(bytes: &[u8]) -> Option<Sidecar> {
        if bytes.len() < 29 || &bytes[..8] != META_MAGIC {
            return None;
        }
        let flags = bytes[8];
        let total = u64::from_le_bytes(bytes[9..17].try_into().ok()?);
        let ck = u64::from_le_bytes(bytes[17..25].try_into().ok()?);
        let name_len = u32::from_le_bytes(bytes[25..29].try_into().ok()?) as usize;
        if bytes.len() != 29 + name_len {
            return None;
        }
        let name = String::from_utf8(bytes[29..].to_vec()).ok()?;
        Some(Sidecar { name, total, ck, frame_ck: flags & MFLAG_FRAME_CK != 0 })
    }
}

/// What startup recovery found on disk.
#[derive(Debug, Default)]
pub struct RecoveryReport {
    /// Names re-indexed, verified, and served again.
    pub recovered: Vec<String>,
    /// Names whose stored bytes failed verification; their files were
    /// moved to `quarantine/` and they are not served.
    pub quarantined: Vec<String>,
    /// In-flight temp files reaped from `tmp/`.
    pub reaped_tmp: usize,
    /// Uncommitted `.blob` files (no sidecar — the crash hit between the
    /// two renames) deleted from `blobs/`.
    pub reaped_orphans: usize,
}

/// Result of re-reading one stored blob from disk.
enum VerifyOutcome {
    Ok,
    Missing,
    Damaged(String),
}

#[derive(Clone)]
struct Entry {
    gen: u64,
    sidecar: Sidecar,
}

/// The durable blob store: a directory of committed `(blob, sidecar)`
/// pairs plus an in-memory name index. All mutation goes through
/// tmp-write → fsync → rename, so the committed set is crash-consistent.
pub struct PersistStore {
    root: PathBuf,
    blobs: PathBuf,
    tmp: PathBuf,
    quarantine: PathBuf,
    seq: AtomicU64,
    index: Mutex<HashMap<String, Entry>>,
    /// Striped per-name commit locks — see [`PersistStore::commit_lock`].
    commit_locks: Vec<Mutex<()>>,
}

impl PersistStore {
    /// Open (creating if needed) a persist root. Call
    /// [`PersistStore::recover`] next to re-index committed blobs.
    pub fn open(root: impl Into<PathBuf>) -> std::io::Result<PersistStore> {
        let root = root.into();
        let blobs = root.join("blobs");
        let tmp = root.join("tmp");
        let quarantine = root.join("quarantine");
        std::fs::create_dir_all(&blobs)?;
        std::fs::create_dir_all(&tmp)?;
        std::fs::create_dir_all(&quarantine)?;
        Ok(PersistStore {
            root,
            blobs,
            tmp,
            quarantine,
            seq: AtomicU64::new(1),
            index: Mutex::new(HashMap::new()),
            commit_locks: (0..COMMIT_STRIPES).map(|_| Mutex::new(())).collect(),
        })
    }

    /// Per-name critical section for commit + publish. The durable commit
    /// ([`PersistStore::persist`] / [`PersistStore::remove`] /
    /// [`PersistStore::quarantine`]) and the serving-store update happen
    /// under separate locks; without a section spanning both, two
    /// concurrent same-name PUTs (or a PUT racing a Delete or the
    /// scrubber) can leave the served bytes and the on-disk generation
    /// pointing at different copies — and a restart or scrub would then
    /// silently revert what GET serves. Callers hold this guard across
    /// the whole mutate-disk-then-publish sequence. Lock order: the
    /// commit lock is always taken *before* the serving-store lock and
    /// the index lock, never after.
    pub(crate) fn commit_lock(&self, name: &str) -> std::sync::MutexGuard<'_, ()> {
        let i = (hash64(name.as_bytes()) as usize) % COMMIT_STRIPES;
        self.commit_locks[i].lock().unwrap()
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    /// The quarantine directory (damaged pairs land here, never served).
    pub fn quarantine_dir(&self) -> &Path {
        &self.quarantine
    }

    /// Path of the committed blob file for `name`, if one exists.
    pub fn blob_path(&self, name: &str) -> Option<PathBuf> {
        let index = self.index.lock().unwrap();
        let e = index.get(name)?;
        Some(self.pair(name, e.gen).0)
    }

    fn pair(&self, name: &str, gen: u64) -> (PathBuf, PathBuf) {
        let stem = format!("{:016x}-{gen}", hash64(name.as_bytes()));
        (
            self.blobs.join(format!("{stem}.blob")),
            self.blobs.join(format!("{stem}.meta")),
        )
    }

    /// Re-index the directory after a restart: reap `tmp/` and orphan
    /// blobs, verify every committed pair by re-reading it from disk, and
    /// quarantine damaged ones. Returns the verified blobs (ready to
    /// serve — mapped when mmap is available, heap-resident otherwise)
    /// plus a report of what was found.
    pub(crate) fn recover(&self) -> std::io::Result<(Vec<(String, StoredBlob)>, RecoveryReport)> {
        let mut report = RecoveryReport::default();

        // In-flight writes never committed: reap wholesale.
        for entry in std::fs::read_dir(&self.tmp)? {
            let entry = entry?;
            if std::fs::remove_file(entry.path()).is_ok() {
                report.reaped_tmp += 1;
            }
        }

        // Collect committed sidecars; group candidate generations by name.
        let mut by_name: HashMap<String, Vec<(u64, PathBuf, PathBuf, Sidecar)>> = HashMap::new();
        let mut meta_stems: Vec<PathBuf> = Vec::new();
        let mut blob_stems: Vec<PathBuf> = Vec::new();
        let mut max_gen = 0u64;
        for entry in std::fs::read_dir(&self.blobs)? {
            let path = entry?.path();
            match path.extension().and_then(|e| e.to_str()) {
                Some("meta") => meta_stems.push(path),
                Some("blob") => blob_stems.push(path),
                _ => {}
            }
        }
        for meta in &meta_stems {
            let Some(gen) = gen_of(meta) else { continue };
            max_gen = max_gen.max(gen);
            let blob = meta.with_extension("blob");
            let sidecar = std::fs::read(meta).ok().and_then(|b| Sidecar::parse(&b));
            match sidecar {
                Some(sc) if blob.exists() => {
                    by_name
                        .entry(sc.name.clone())
                        .or_default()
                        .push((gen, blob, meta.clone(), sc));
                }
                // A sidecar that doesn't parse, or whose blob is gone, is
                // damage: quarantine what's there rather than deleting
                // evidence.
                _ => {
                    self.move_to_quarantine(&blob, meta);
                }
            }
        }
        // Orphan blobs: written but never committed (crash between the
        // two renames) — by construction unacknowledged, safe to reap.
        // Count only actual unlinks: a blob already moved to quarantine
        // alongside its unparseable sidecar is not an orphan twice over.
        for blob in &blob_stems {
            if !blob.with_extension("meta").exists() && std::fs::remove_file(blob).is_ok() {
                report.reaped_orphans += 1;
            }
        }

        // Per name: newest generation that verifies wins; superseded
        // generations are deleted; damaged ones are quarantined.
        let mut recovered: Vec<(String, StoredBlob)> = Vec::new();
        let mut index = self.index.lock().unwrap();
        for (name, mut gens) in by_name {
            gens.sort_by_key(|(gen, ..)| std::cmp::Reverse(*gen));
            let mut chosen: Option<(u64, Sidecar, StoredBlob)> = None;
            for (gen, blob_path, meta_path, sc) in gens {
                if chosen.is_some() {
                    // Superseded by a newer verified generation.
                    let _ = std::fs::remove_file(&blob_path);
                    let _ = std::fs::remove_file(&meta_path);
                    continue;
                }
                match verify_file(&blob_path, &sc) {
                    VerifyOutcome::Ok => match load_blob(&blob_path, &sc) {
                        Ok(blob) => chosen = Some((gen, sc, blob)),
                        Err(_) => self.move_to_quarantine(&blob_path, &meta_path),
                    },
                    _ => self.move_to_quarantine(&blob_path, &meta_path),
                }
            }
            match chosen {
                Some((gen, sidecar, blob)) => {
                    index.insert(name.clone(), Entry { gen, sidecar });
                    report.recovered.push(name.clone());
                    recovered.push((name, blob));
                }
                None => report.quarantined.push(name),
            }
        }
        drop(index);
        self.seq.store(max_gen + 1, Ordering::Relaxed);
        sync_dir(&self.blobs);
        report.recovered.sort();
        report.quarantined.sort();
        Ok((recovered, report))
    }

    /// Durably commit one PUT body and return the blob to serve (mapped
    /// from the committed file when mmap is available, else the heap
    /// frames handed in). The returned blob exists on disk — with its
    /// sidecar, fsynced, directory synced — before this returns, so
    /// acknowledging the PUT is safe.
    pub(crate) fn persist(
        &self,
        name: &str,
        frames: Vec<Vec<u8>>,
        total: u64,
    ) -> std::io::Result<StoredBlob> {
        let gen = self.seq.fetch_add(1, Ordering::Relaxed);
        let mut ckh = Checksummer::streaming();
        for f in &frames {
            ckh.update(f);
        }
        let sidecar = Sidecar {
            name: name.to_string(),
            total,
            ck: ckh.finalize(),
            frame_ck: declares_frame_ck(&frames),
        };

        let tmp_blob = self.tmp.join(format!("{}-{gen}.blob", std::process::id()));
        let tmp_meta = self.tmp.join(format!("{}-{gen}.meta", std::process::id()));
        let write = (|| -> std::io::Result<()> {
            let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp_blob)?);
            for frame in &frames {
                f.write_all(frame)?;
            }
            f.flush()?;
            f.into_inner().map_err(|e| e.into_error())?.sync_all()?;
            let mut m = std::fs::File::create(&tmp_meta)?;
            m.write_all(&sidecar.encode())?;
            m.sync_all()?;
            Ok(())
        })();
        if let Err(e) = write {
            let _ = std::fs::remove_file(&tmp_blob);
            let _ = std::fs::remove_file(&tmp_meta);
            return Err(e);
        }

        // Commit: blob first, sidecar last — a crash in between leaves an
        // orphan blob recovery reaps; the sidecar's arrival is the moment
        // the blob becomes servable.
        let (blob_path, meta_path) = self.pair(name, gen);
        if let Err(e) = std::fs::rename(&tmp_blob, &blob_path)
            .and_then(|()| std::fs::rename(&tmp_meta, &meta_path))
        {
            let _ = std::fs::remove_file(&tmp_blob);
            let _ = std::fs::remove_file(&tmp_meta);
            let _ = std::fs::remove_file(&blob_path);
            return Err(e);
        }
        sync_dir(&self.blobs);

        // Serve from the committed file; fall back to the frames we
        // already hold when mapping is unavailable.
        let blob = match StoredBlob::from_mapped_file(&blob_path, total, sidecar.ck) {
            Ok(b) => b,
            Err(_) => StoredBlob::in_memory(frames, total),
        };

        // Swap the index entry and drop the superseded generation only
        // after the new one is fully committed.
        let old = self
            .index
            .lock()
            .unwrap()
            .insert(name.to_string(), Entry { gen, sidecar });
        if let Some(old) = old {
            let (ob, om) = self.pair(name, old.gen);
            let _ = std::fs::remove_file(om);
            let _ = std::fs::remove_file(ob);
            sync_dir(&self.blobs);
        }
        Ok(blob)
    }

    /// Delete `name`'s committed pair. Returns whether it existed.
    pub(crate) fn remove(&self, name: &str) -> bool {
        let Some(e) = self.index.lock().unwrap().remove(name) else {
            return false;
        };
        let (blob, meta) = self.pair(name, e.gen);
        // Sidecar first: if the crash hits between the two unlinks, the
        // leftover blob is an orphan recovery reaps, not a servable blob.
        let _ = std::fs::remove_file(meta);
        let _ = std::fs::remove_file(blob);
        sync_dir(&self.blobs);
        true
    }

    /// Move `name`'s committed pair to `quarantine/` and forget it.
    /// Returns whether there was a pair to move.
    pub(crate) fn quarantine(&self, name: &str) -> bool {
        let Some(e) = self.index.lock().unwrap().remove(name) else {
            return false;
        };
        let (blob, meta) = self.pair(name, e.gen);
        self.move_to_quarantine(&blob, &meta);
        true
    }

    fn move_to_quarantine(&self, blob: &Path, meta: &Path) {
        for p in [blob, meta] {
            if let Some(fname) = p.file_name() {
                let _ = std::fs::rename(p, self.quarantine.join(fname));
            }
        }
        sync_dir(&self.blobs);
        sync_dir(&self.quarantine);
    }

    /// Re-read one stored blob from disk and check it against its
    /// sidecar. A fresh file read on purpose: the serving mmap's resident
    /// pages can mask on-disk rot.
    fn verify_on_disk(&self, name: &str) -> VerifyOutcome {
        let Some(e) = self.index.lock().unwrap().get(name).cloned() else {
            return VerifyOutcome::Missing;
        };
        let (blob, _) = self.pair(name, e.gen);
        verify_file(&blob, &e.sidecar)
    }

    /// One scrub pass: re-verify every committed blob from disk,
    /// quarantining damaged ones and dropping them from the serving
    /// `store`. Returns the names quarantined this pass.
    pub(crate) fn scrub_pass(&self, store: &Store) -> Vec<String> {
        let names: Vec<String> = self.index.lock().unwrap().keys().cloned().collect();
        let mut quarantined = Vec::new();
        for name in names {
            match self.verify_on_disk(&name) {
                VerifyOutcome::Ok | VerifyOutcome::Missing => {}
                VerifyOutcome::Damaged(_) => {
                    // Re-verify under the commit lock: a racing re-PUT
                    // may have just committed a fresh generation, which
                    // must not be quarantined on the stale verdict.
                    let _commit = self.commit_lock(&name);
                    if !matches!(self.verify_on_disk(&name), VerifyOutcome::Damaged(_)) {
                        continue;
                    }
                    // Stop serving first (in-flight responses keep their
                    // Arc and finish from the still-mapped inode), then
                    // move the files out of the committed set.
                    store.lock().unwrap().remove(&name);
                    self.quarantine(&name);
                    quarantined.push(name);
                }
            }
        }
        quarantined
    }
}

/// Background scrubber: periodically re-verify every persisted blob from
/// disk, quarantining bit rot. Runs until `stop`; sleeps in small slices
/// so shutdown never waits out a full interval.
pub(crate) fn scrub_loop(
    persist: std::sync::Arc<PersistStore>,
    store: Store,
    stop: std::sync::Arc<AtomicBool>,
    interval: Duration,
) {
    while !stop.load(Ordering::Relaxed) {
        sleep_until(&stop, interval);
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let _ = persist.scrub_pass(&store);
    }
}

/// Sleep for `d` in small slices, returning early when `stop` is raised.
pub(crate) fn sleep_until(stop: &AtomicBool, d: Duration) {
    let slice = Duration::from_millis(25);
    let mut left = d;
    while !left.is_zero() {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        let step = slice.min(left);
        std::thread::sleep(step);
        left -= step;
    }
}

/// Fsync a directory so a just-renamed entry is durable, not merely
/// sitting in the directory's dirty page. Best-effort: platforms that
/// refuse to open or fsync directories still get the rename's atomicity,
/// just without the durability fence.
fn sync_dir(dir: &Path) {
    if let Ok(f) = std::fs::File::open(dir) {
        let _ = f.sync_all();
    }
}

/// Does the stored body declare per-frame checksums? (`ZNS1` header flag
/// — byte 5 of the container, which always sits in the first frame.)
fn declares_frame_ck(frames: &[Vec<u8>]) -> bool {
    match frames.first() {
        Some(f) if f.len() >= 6 => {
            f[0..4] == STREAM_MAGIC && f[4] == STREAM_VERSION && f[5] & SFLAG_FRAME_CK != 0
        }
        _ => false,
    }
}

/// Verify a blob file against its sidecar: length, whole-blob checksum
/// (streaming read), and — when the container declares per-frame
/// checksums — a full structural [`scan_wire`] walk.
fn verify_file(path: &Path, sc: &Sidecar) -> VerifyOutcome {
    let mut f = match std::fs::File::open(path) {
        Ok(f) => f,
        Err(_) => return VerifyOutcome::Missing,
    };
    // The whole-blob checksum catches every flipped bit on its own; the
    // structural walk adds frame attribution, so it is worth buffering
    // the body for — but not at any size.
    let scan = sc.frame_ck && sc.total <= MAX_SCAN_BYTES;
    let mut ckh = Checksummer::streaming();
    let mut len = 0u64;
    let mut body = if scan { Vec::with_capacity(sc.total as usize) } else { Vec::new() };
    let mut buf = vec![0u8; 256 * 1024];
    loop {
        match f.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => {
                ckh.update(&buf[..n]);
                len += n as u64;
                if scan {
                    body.extend_from_slice(&buf[..n]);
                }
                if len > sc.total {
                    return VerifyOutcome::Damaged(format!(
                        "file longer than sidecar total {}",
                        sc.total
                    ));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return VerifyOutcome::Damaged(format!("read failed: {e}")),
        }
    }
    if len != sc.total {
        return VerifyOutcome::Damaged(format!("length {len} != sidecar total {}", sc.total));
    }
    if ckh.finalize() != sc.ck {
        return VerifyOutcome::Damaged("whole-blob checksum mismatch".into());
    }
    if scan {
        match scan_wire(&body) {
            WireScan::Complete { .. } => {}
            WireScan::Corrupt { verified, .. } => {
                return VerifyOutcome::Damaged(format!("frame damaged at byte {verified}"));
            }
            WireScan::NeedMore { .. } => {
                return VerifyOutcome::Damaged("container truncated".into());
            }
            // The sidecar says this was a ZNS1 container at commit time;
            // an unrecognizable header now is damage the whole-blob
            // checksum should have caught — treat it as such regardless.
            WireScan::Opaque => {
                return VerifyOutcome::Damaged("container header unrecognizable".into());
            }
        }
    }
    VerifyOutcome::Ok
}

/// Load a verified blob file for serving: mapped (page-cache resident)
/// when mmap is available, heap frames otherwise.
fn load_blob(path: &Path, sc: &Sidecar) -> std::io::Result<StoredBlob> {
    match StoredBlob::from_mapped_file(path, sc.total, sc.ck) {
        Ok(b) => Ok(b),
        Err(_) => {
            let bytes = std::fs::read(path)?;
            if bytes.len() as u64 != sc.total {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    "blob changed during recovery",
                ));
            }
            let frames: Vec<Vec<u8>> = bytes.chunks(FRAME_MAX).map(<[u8]>::to_vec).collect();
            Ok(StoredBlob::in_memory(frames, sc.total))
        }
    }
}

/// Trailing `-<gen>` of a committed filename stem.
fn gen_of(path: &Path) -> Option<u64> {
    let stem = path.file_stem()?.to_str()?;
    stem.rsplit('-').next()?.parse().ok()
}

/// FNV-1a + splitmix64 finalizer (same construction as the ring hash):
/// filename-safe 64-bit name digest. Collisions are harmless — the
/// sidecar carries the authoritative name and generations keep stems
/// unique.
fn hash64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    let mut z = h.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn tmp_root(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "zipnn-persist-{tag}-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn frames_of(bytes: &[u8]) -> Vec<Vec<u8>> {
        bytes.chunks(FRAME_MAX).map(<[u8]>::to_vec).collect()
    }

    #[test]
    fn sidecar_roundtrip() {
        let sc = Sidecar { name: "a/b c".into(), total: 7, ck: 0xdead_beef, frame_ck: true };
        assert_eq!(Sidecar::parse(&sc.encode()), Some(sc));
        assert_eq!(Sidecar::parse(b"junk"), None);
        let mut enc = Sidecar { name: "x".into(), total: 1, ck: 2, frame_ck: false }.encode();
        enc.truncate(enc.len() - 1);
        assert_eq!(Sidecar::parse(&enc), None);
    }

    #[test]
    fn persist_commit_and_recover() {
        let root = tmp_root("roundtrip");
        let body: Vec<u8> = (0..200_000u32).map(|i| (i % 251) as u8).collect();
        {
            let ps = PersistStore::open(&root).unwrap();
            let blob = ps.persist("model.znn", frames_of(&body), body.len() as u64).unwrap();
            assert_eq!(blob.read_range(0, body.len()).unwrap(), body);
        }
        let ps = PersistStore::open(&root).unwrap();
        let (blobs, report) = ps.recover().unwrap();
        assert_eq!(report.recovered, vec!["model.znn".to_string()]);
        assert!(report.quarantined.is_empty());
        assert_eq!(blobs.len(), 1);
        assert_eq!(blobs[0].1.read_range(0, body.len()).unwrap(), body);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn reput_keeps_newest_generation() {
        let root = tmp_root("reput");
        let ps = PersistStore::open(&root).unwrap();
        ps.persist("m", frames_of(b"old-bytes"), 9).unwrap();
        ps.persist("m", frames_of(b"new-bytes!"), 10).unwrap();
        drop(ps);
        let ps = PersistStore::open(&root).unwrap();
        let (blobs, report) = ps.recover().unwrap();
        assert_eq!(report.recovered, vec!["m".to_string()]);
        assert_eq!(blobs[0].1.read_range(0, 10).unwrap(), b"new-bytes!");
        // the superseded generation is gone from disk
        let n = std::fs::read_dir(root.join("blobs")).unwrap().count();
        assert_eq!(n, 2, "one blob + one sidecar expected");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn recovery_reaps_tmp_and_orphans_and_quarantines_damage() {
        let root = tmp_root("recovery");
        let ps = PersistStore::open(&root).unwrap();
        ps.persist("good", frames_of(b"kept bytes"), 10).unwrap();
        ps.persist("bad", frames_of(b"soon damaged"), 12).unwrap();
        let bad_path = ps.blob_path("bad").unwrap();
        drop(ps);
        // bit rot in one blob
        let mut bytes = std::fs::read(&bad_path).unwrap();
        bytes[3] ^= 0x40;
        std::fs::write(&bad_path, &bytes).unwrap();
        // a half-written temp file and an uncommitted orphan blob
        std::fs::write(root.join("tmp").join("123-9.blob"), b"half").unwrap();
        std::fs::write(root.join("blobs").join("feedfeedfeedfeed-99.blob"), b"orphan").unwrap();

        let ps = PersistStore::open(&root).unwrap();
        let (blobs, report) = ps.recover().unwrap();
        assert_eq!(report.recovered, vec!["good".to_string()]);
        assert_eq!(report.quarantined, vec!["bad".to_string()]);
        assert_eq!(report.reaped_tmp, 1);
        assert_eq!(report.reaped_orphans, 1);
        assert_eq!(blobs.len(), 1);
        assert!(std::fs::read_dir(root.join("tmp")).unwrap().next().is_none());
        let quarantined = std::fs::read_dir(root.join("quarantine")).unwrap().count();
        assert_eq!(quarantined, 2, "damaged blob + its sidecar");
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn scrub_quarantines_bit_rot_and_stops_serving() {
        let root = tmp_root("scrub");
        let ps = PersistStore::open(&root).unwrap();
        let body: Vec<u8> = (0..50_000u32).map(|i| (i % 13) as u8).collect();
        let blob = ps.persist("rotting", frames_of(&body), body.len() as u64).unwrap();
        let store: Store = Arc::new(Mutex::new(HashMap::new()));
        store.lock().unwrap().insert("rotting".into(), Arc::new(blob));

        assert!(ps.scrub_pass(&store).is_empty(), "clean blob must not be quarantined");

        let path = ps.blob_path("rotting").unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[1000] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();

        assert_eq!(ps.scrub_pass(&store), vec!["rotting".to_string()]);
        assert!(store.lock().unwrap().is_empty(), "quarantined blob still served");
        assert!(ps.blob_path("rotting").is_none());
        assert!(std::fs::read_dir(root.join("quarantine")).unwrap().count() >= 2);
        let _ = std::fs::remove_dir_all(&root);
    }

    #[test]
    fn remove_deletes_the_pair() {
        let root = tmp_root("remove");
        let ps = PersistStore::open(&root).unwrap();
        ps.persist("gone", frames_of(b"bytes"), 5).unwrap();
        assert!(ps.remove("gone"));
        assert!(!ps.remove("gone"));
        assert!(std::fs::read_dir(root.join("blobs")).unwrap().next().is_none());
        let _ = std::fs::remove_dir_all(&root);
    }
}
