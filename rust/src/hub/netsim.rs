//! WAN bandwidth regimes measured in the paper (§5.3) and a simulated
//! clock that converts byte counts into transfer seconds with the paper's
//! observed variance.

use crate::util::Xoshiro256;

/// One network regime: mean bandwidth and relative jitter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetProfile {
    /// Human-readable label.
    pub name: &'static str,
    /// Mean bandwidth in MB/s.
    pub mbps: f64,
    /// Uniform relative jitter (±fraction of mean) per transfer.
    pub jitter: f64,
}

impl NetProfile {
    /// Cloud VM, first (uncached) download: 20–40 MB/s.
    pub const CLOUD_FIRST: NetProfile =
        NetProfile { name: "cloud-1st", mbps: 30.0, jitter: 0.33 };
    /// Cloud VM, cached download: 120–130 MB/s.
    pub const CLOUD_CACHED: NetProfile =
        NetProfile { name: "cloud-cached", mbps: 125.0, jitter: 0.04 };
    /// Home connection, first download ≈ 10 MB/s.
    pub const HOME_FIRST: NetProfile =
        NetProfile { name: "home-1st", mbps: 10.0, jitter: 0.15 };
    /// Home connection, cached ≈ 40 MB/s.
    pub const HOME_CACHED: NetProfile =
        NetProfile { name: "home-cached", mbps: 40.0, jitter: 0.08 };
    /// Upload ≈ 20 MB/s, near-constant.
    pub const UPLOAD: NetProfile = NetProfile { name: "upload", mbps: 20.0, jitter: 0.05 };
}

/// Deterministic transfer-time simulator.
pub struct NetSim {
    profile: NetProfile,
    rng: Xoshiro256,
}

impl NetSim {
    /// New simulator with a seed (deterministic benches).
    pub fn new(profile: NetProfile, seed: u64) -> NetSim {
        NetSim { profile, rng: Xoshiro256::seed_from_u64(seed) }
    }

    /// Simulated seconds to move `bytes` over this regime.
    pub fn transfer_secs(&mut self, bytes: u64) -> f64 {
        let jitter = 1.0 + (self.rng.uniform() * 2.0 - 1.0) * self.profile.jitter;
        let bw = (self.profile.mbps * jitter).max(0.1) * 1e6; // bytes/s
        bytes as f64 / bw
    }

    /// The regime.
    pub fn profile(&self) -> NetProfile {
        self.profile
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_scales_with_bytes() {
        let mut sim = NetSim::new(NetProfile::CLOUD_CACHED, 1);
        let t1 = sim.transfer_secs(125_000_000);
        // ~1 second ± jitter
        assert!((0.9..1.1).contains(&t1), "t1={t1}");
    }

    #[test]
    fn jitter_within_bounds() {
        let mut sim = NetSim::new(NetProfile::CLOUD_FIRST, 2);
        for _ in 0..1000 {
            let t = sim.transfer_secs(30_000_000); // nominal 1s
            assert!((0.7..1.55).contains(&t), "t={t}");
        }
    }

    #[test]
    fn deterministic() {
        let mut a = NetSim::new(NetProfile::HOME_FIRST, 3);
        let mut b = NetSim::new(NetProfile::HOME_FIRST, 3);
        for _ in 0..10 {
            assert_eq!(a.transfer_secs(1 << 20), b.transfer_secs(1 << 20));
        }
    }
}
