//! WAN bandwidth regimes measured in the paper (§5.3) and a simulated
//! clock that converts byte counts into transfer seconds with the paper's
//! observed variance.

use crate::util::Xoshiro256;

/// One network regime: mean bandwidth and relative jitter.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetProfile {
    /// Human-readable label.
    pub name: &'static str,
    /// Mean bandwidth in MB/s.
    pub mbps: f64,
    /// Uniform relative jitter (±fraction of mean) per transfer.
    pub jitter: f64,
}

/// Hard floor on the jittered bandwidth a transfer can draw, in MB/s.
/// A profile whose jitter range dips below this floor would have its
/// tail latencies silently flattened by the clamp — `transfer_secs`
/// debug-asserts every draw stays above it, so such a profile fails
/// loudly in tests instead of understating simulated tail latency. The
/// clamp itself still applies in release builds as a division guard.
pub const BANDWIDTH_FLOOR_MB_S: f64 = 0.1;

impl NetProfile {
    /// Worst-case bandwidth a jitter draw of this profile can produce
    /// (`mbps * (1 - jitter)`). Keep it above
    /// [`BANDWIDTH_FLOOR_MB_S`] or the clamp distorts tail latency.
    pub fn min_mbps(&self) -> f64 {
        self.mbps * (1.0 - self.jitter)
    }

    /// Cloud VM, first (uncached) download: 20–40 MB/s.
    pub const CLOUD_FIRST: NetProfile =
        NetProfile { name: "cloud-1st", mbps: 30.0, jitter: 0.33 };
    /// Cloud VM, cached download: 120–130 MB/s.
    pub const CLOUD_CACHED: NetProfile =
        NetProfile { name: "cloud-cached", mbps: 125.0, jitter: 0.04 };
    /// Home connection, first download ≈ 10 MB/s.
    pub const HOME_FIRST: NetProfile =
        NetProfile { name: "home-1st", mbps: 10.0, jitter: 0.15 };
    /// Home connection, cached ≈ 40 MB/s.
    pub const HOME_CACHED: NetProfile =
        NetProfile { name: "home-cached", mbps: 40.0, jitter: 0.08 };
    /// Upload ≈ 20 MB/s, near-constant.
    pub const UPLOAD: NetProfile = NetProfile { name: "upload", mbps: 20.0, jitter: 0.05 };
}

/// Deterministic transfer-time simulator.
pub struct NetSim {
    profile: NetProfile,
    rng: Xoshiro256,
}

impl NetSim {
    /// New simulator with a seed (deterministic benches).
    pub fn new(profile: NetProfile, seed: u64) -> NetSim {
        NetSim { profile, rng: Xoshiro256::seed_from_u64(seed) }
    }

    /// Simulated seconds to move `bytes` over this regime. The jittered
    /// bandwidth is clamped at [`BANDWIDTH_FLOOR_MB_S`]; a draw that
    /// actually hits the clamp trips a debug assertion, because a
    /// profile jittering below the floor would report flattened (too
    /// optimistic) tail latencies without any signal.
    pub fn transfer_secs(&mut self, bytes: u64) -> f64 {
        let jitter = 1.0 + (self.rng.uniform() * 2.0 - 1.0) * self.profile.jitter;
        let drawn = self.profile.mbps * jitter;
        debug_assert!(
            drawn >= BANDWIDTH_FLOOR_MB_S,
            "NetProfile '{}' drew {drawn:.4} MB/s, below the {BANDWIDTH_FLOOR_MB_S} MB/s floor \
             (min_mbps {:.4}): the clamp would understate simulated tail latency",
            self.profile.name,
            self.profile.min_mbps(),
        );
        let bw = drawn.max(BANDWIDTH_FLOOR_MB_S) * 1e6; // bytes/s
        bytes as f64 / bw
    }

    /// The regime.
    pub fn profile(&self) -> NetProfile {
        self.profile
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_scales_with_bytes() {
        let mut sim = NetSim::new(NetProfile::CLOUD_CACHED, 1);
        let t1 = sim.transfer_secs(125_000_000);
        // ~1 second ± jitter
        assert!((0.9..1.1).contains(&t1), "t1={t1}");
    }

    #[test]
    fn jitter_within_bounds() {
        let mut sim = NetSim::new(NetProfile::CLOUD_FIRST, 2);
        for _ in 0..1000 {
            let t = sim.transfer_secs(30_000_000); // nominal 1s
            assert!((0.7..1.55).contains(&t), "t={t}");
        }
    }

    #[test]
    fn clamp_boundary_profile_never_exceeds_floor_time() {
        // min_mbps sits exactly on the floor: every draw is legal, and
        // no transfer can take longer than the floor-rate time.
        let p = NetProfile { name: "floor-edge", mbps: 0.2, jitter: 0.5 };
        assert!((p.min_mbps() - BANDWIDTH_FLOOR_MB_S).abs() < 1e-12);
        let mut sim = NetSim::new(p, 7);
        let bytes = 1u64 << 20;
        let floor_secs = bytes as f64 / (BANDWIDTH_FLOOR_MB_S * 1e6);
        for _ in 0..1000 {
            let t = sim.transfer_secs(bytes);
            assert!(t <= floor_secs * (1.0 + 1e-9), "t={t} exceeds floor time {floor_secs}");
        }
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "below the 0.1 MB/s floor")]
    fn draw_below_floor_asserts() {
        // min_mbps is under the floor, so some draw in a long run must
        // land below it and trip the debug assertion instead of being
        // silently clamped.
        let p = NetProfile { name: "too-jittery", mbps: 0.15, jitter: 0.9 };
        assert!(p.min_mbps() < BANDWIDTH_FLOOR_MB_S);
        let mut sim = NetSim::new(p, 8);
        for _ in 0..1000 {
            let _ = sim.transfer_secs(1 << 10);
        }
    }

    #[test]
    fn deterministic() {
        let mut a = NetSim::new(NetProfile::HOME_FIRST, 3);
        let mut b = NetSim::new(NetProfile::HOME_FIRST, 3);
        for _ in 0..10 {
            assert_eq!(a.transfer_secs(1 << 20), b.transfer_secs(1 << 20));
        }
    }
}
