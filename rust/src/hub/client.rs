//! The hub client: upload/download with optional ZipNN compression and
//! Fig.-10-style end-to-end timing.

use crate::codec::{decompress_with, CodecConfig, Compressor};
use crate::error::Result;
use crate::hub::netsim::NetSim;
use crate::hub::protocol::{read_response, write_request, Op};
use crate::util::Timer;
use std::net::TcpStream;

/// End-to-end timing of one transfer (Fig. 10 bars).
#[derive(Debug, Clone)]
pub struct TransferReport {
    /// Model/blob name.
    pub name: String,
    /// Raw bytes.
    pub raw_len: usize,
    /// Bytes on the wire (= raw when uncompressed).
    pub wire_len: usize,
    /// Measured compression or decompression seconds (0 when off).
    pub codec_secs: f64,
    /// Simulated WAN transfer seconds for `wire_len`.
    pub transfer_secs: f64,
}

impl TransferReport {
    /// Total end-to-end seconds.
    pub fn total_secs(&self) -> f64 {
        self.codec_secs + self.transfer_secs
    }

    /// Compressed size in percent.
    pub fn pct(&self) -> f64 {
        self.wire_len as f64 / self.raw_len as f64 * 100.0
    }
}

/// Client connection to a [`crate::hub::HubServer`].
pub struct HubClient {
    stream: TcpStream,
    threads: usize,
}

impl HubClient {
    /// Connect to `addr`.
    pub fn connect(addr: &str) -> Result<HubClient> {
        Ok(HubClient { stream: TcpStream::connect(addr)?, threads: 1 })
    }

    /// Worker threads for codec work during transfers.
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Upload raw bytes, optionally compressing with `cfg`. The simulated
    /// WAN time is charged on the wire bytes via `sim`.
    pub fn upload(
        &mut self,
        name: &str,
        raw: &[u8],
        cfg: Option<CodecConfig>,
        sim: &mut NetSim,
    ) -> Result<TransferReport> {
        let (wire, codec_secs, stored_name) = match cfg {
            Some(cfg) => {
                let t = Timer::start();
                let comp = Compressor::new(cfg.with_threads(self.threads)).compress(raw)?;
                (comp, t.secs(), format!("{name}.znn"))
            }
            None => (raw.to_vec(), 0.0, name.to_string()),
        };
        write_request(&mut self.stream, Op::Put, &stored_name, &wire)?;
        read_response(&mut self.stream)?;
        Ok(TransferReport {
            name: name.to_string(),
            raw_len: raw.len(),
            wire_len: wire.len(),
            codec_secs,
            transfer_secs: sim.transfer_secs(wire.len() as u64),
        })
    }

    /// Download a blob; decompresses when it was stored as `.znn`.
    pub fn download(
        &mut self,
        name: &str,
        compressed: bool,
        sim: &mut NetSim,
    ) -> Result<(Vec<u8>, TransferReport)> {
        let stored_name = if compressed { format!("{name}.znn") } else { name.to_string() };
        write_request(&mut self.stream, Op::Get, &stored_name, b"")?;
        let wire = read_response(&mut self.stream)?;
        let transfer_secs = sim.transfer_secs(wire.len() as u64);
        let (raw, codec_secs) = if compressed {
            let t = Timer::start();
            let raw = decompress_with(&wire, self.threads)?;
            let s = t.secs();
            (raw, s)
        } else {
            (wire.clone(), 0.0)
        };
        Ok((
            raw.clone(),
            TransferReport {
                name: name.to_string(),
                raw_len: raw.len(),
                wire_len: wire.len(),
                codec_secs,
                transfer_secs,
            },
        ))
    }

    /// List stored blob names.
    pub fn list(&mut self) -> Result<Vec<String>> {
        write_request(&mut self.stream, Op::List, "", b"")?;
        let payload = read_response(&mut self.stream)?;
        let s = String::from_utf8_lossy(&payload);
        Ok(s.split('\n').filter(|x| !x.is_empty()).map(String::from).collect())
    }
}
