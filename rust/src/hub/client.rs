//! The hub client: upload/download with optional ZipNN compression,
//! Fig.-10-style end-to-end timing, and fault-resilient transfers.
//!
//! Transfers are streamed: an upload pipes raw bytes through a
//! [`ZnnWriter`] straight onto the socket (the compressed blob is never
//! materialized client-side). With `with_threads(n > 1)` codec work runs
//! on the process-shared sticky-state pool, pipelined with the socket.
//!
//! ## Resilience
//!
//! Every operation runs under a [`RetryPolicy`] (bounded attempts,
//! exponential backoff with full jitter, an overall deadline): transient
//! failures — connection drops, timeouts, a [`crate::error::Error::Busy`]
//! load-shed from the server — reconnect and retry. Uploads are
//! idempotent (the server only stores complete PUT bodies, and the
//! encode is deterministic), so a retried upload simply re-streams.
//!
//! Downloads are **resumable**: the client buffers the wire bytes,
//! verifies the structured prefix with the container scanner
//! ([`crate::codec::stream::scan_wire`] — frame markers, entry tables,
//! and per-frame checksums when the container carries them), and after a
//! mid-stream failure re-requests only the unverified tail via a ranged
//! read. A frame that arrives corrupt (checksum mismatch) triggers a
//! targeted refetch of just that frame's byte span. Completion is gated
//! on an end-to-end checksum against what the server holds
//! ([`HubClient::stat_full`]), which also covers raw blobs and the index
//! tail that frame checksums can't see.
//!
//! Set `ZIPNN_FAULT_PROFILE` (and optionally `ZIPNN_FAULT_SEED`) to
//! route every connection through an in-process fault-injecting proxy
//! ([`crate::hub::faultsim`]) — the whole client surface then runs under
//! deterministic injected drops/flips/stalls, which is how the CI fault
//! legs exercise this module.

use crate::codec::stream::{scan_wire, Checksummer, WireScan};
use crate::codec::{CodecConfig, MappedBytes, TensorMeta, ZnnReader, ZnnWriter};
use crate::error::{Error, Result};
use crate::hub::faultsim::{FaultProxy, FaultSpec};
use crate::hub::netsim::NetSim;
use crate::hub::protocol::{
    encode_range, read_response, read_response_header, write_request, write_request_header,
    ChunkedReader, ChunkedWriter, Op,
};
use crate::util::{Timer, Xoshiro256};
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

/// Default per-operation socket timeout: generous enough for multi-GB
/// streamed transfers (each read/write must make *some* progress within
/// it), small enough that a dead server fails the client promptly.
const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// How a client survives transient transfer failures: per-operation
/// attempt budget, exponential backoff with **full jitter** (each sleep
/// is uniform in `[0, ceiling]`, the ceiling doubling up to
/// `max_backoff`), and an overall wall-clock deadline.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Total tries per operation (including the first); min 1.
    pub attempts: u32,
    /// Initial backoff ceiling before the second attempt.
    pub base_backoff: Duration,
    /// Cap on the doubling backoff ceiling.
    pub max_backoff: Duration,
    /// Overall wall-clock budget for one operation; once exceeded, no
    /// further retries are attempted.
    pub deadline: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            attempts: 8,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(500),
            deadline: Duration::from_secs(60),
        }
    }
}

impl RetryPolicy {
    /// Fail fast: a single attempt, no retries.
    pub fn none() -> RetryPolicy {
        RetryPolicy { attempts: 1, ..RetryPolicy::default() }
    }
}

/// One full-jitter backoff draw under `policy`: uniform in
/// `[0, ceiling]`, after which the ceiling doubles up to the policy cap.
/// Both the operation retry loop and the connect path draw their sleeps
/// here, so a fleet restart never re-dials in lockstep — and the
/// schedule is a pure function of the rng, which is what the
/// seeded-divergence test pins.
pub(crate) fn jitter_backoff(
    policy: &RetryPolicy,
    ceiling: &mut Duration,
    rng: &mut Xoshiro256,
) -> Duration {
    let nanos = (rng.uniform() * ceiling.as_nanos() as f64) as u64;
    *ceiling = (*ceiling * 2).min(policy.max_backoff);
    Duration::from_nanos(nanos)
}

/// End-to-end timing of one transfer (Fig. 10 bars).
#[derive(Debug, Clone)]
pub struct TransferReport {
    /// Model/blob name.
    pub name: String,
    /// Raw bytes.
    pub raw_len: usize,
    /// Logical bytes on the wire for one clean copy (= raw when
    /// uncompressed).
    pub wire_len: usize,
    /// Cumulative wire payload bytes actually fetched across retries and
    /// resumed tails (== `wire_len` on a clean transfer; uploads report
    /// the final attempt only). The resilience tests assert on this to
    /// prove resumed downloads beat restart-from-zero.
    pub wire_total: u64,
    /// Measured codec wall seconds (0 when compression is off).
    pub codec_secs: f64,
    /// Simulated WAN transfer seconds for the bytes that traveled.
    pub transfer_secs: f64,
}

impl TransferReport {
    /// Total end-to-end seconds.
    pub fn total_secs(&self) -> f64 {
        self.codec_secs + self.transfer_secs
    }

    /// Compressed size in percent.
    pub fn pct(&self) -> f64 {
        self.wire_len as f64 / self.raw_len as f64 * 100.0
    }
}

/// One tensor fetched with its placement, from
/// [`HubClient::get_tensor_placed`].
#[derive(Debug, Clone)]
pub struct TensorFetch {
    /// Absolute byte offset of the tensor within the raw payload
    /// (the wire meta's base offset plus the tensor's offset relative
    /// to the shipped frames).
    pub offset: u64,
    /// The tensor's raw bytes.
    pub data: Vec<u8>,
    /// Response payload bytes on the wire.
    pub wire: u64,
}

/// Is this failure worth a reconnect-and-retry? Transport errors and
/// load-sheds are, and so are corruption verdicts: a checksum or decode
/// failure on bytes that just crossed the wire means the copy is bad,
/// not the stored blob, and a fresh fetch is the only fix (`download`
/// re-requests just the unverified span before ever surfacing one).
/// Server-reported semantic errors (missing blob, bad range) are not.
fn retryable(e: &Error) -> bool {
    matches!(e, Error::Busy | Error::Io(_) | Error::Corrupt(_))
}

/// Wrap a server error payload.
fn hub_error(msg: &[u8]) -> Error {
    Error::Format(format!("hub error: {}", String::from_utf8_lossy(msg)))
}

/// Whole-blob checksum matching the hash the server reports via Stat.
fn blob_ck(data: &[u8]) -> u64 {
    let mut ck = Checksummer::streaming();
    ck.update(data);
    ck.finalize()
}

/// Cheap per-process jitter seed: connect jitter must decorrelate
/// *between* processes, so the seed mixes the address with wall-clock
/// nanos and the pid (determinism here would recreate the thundering
/// herd the jitter exists to break).
fn jitter_seed(addr: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in addr.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos() as u64)
        .unwrap_or(0);
    h ^ t ^ (std::process::id() as u64).rotate_left(32)
}

/// Verdict on the wire bytes a download holds so far.
enum Verdict {
    /// All `total` bytes present and structurally sound.
    Done,
    /// Trim to the verified prefix and re-request the tail.
    Resume { verified: usize },
    /// One frame is corrupt but delimitable: refetch just its span.
    BadFrame { verified: usize, frame_end: usize },
}

fn verdict(buf: &[u8], total: u64) -> Verdict {
    match scan_wire(buf) {
        // Raw blob: no structure to verify mid-flight; resume by byte
        // count and rely on the end-to-end checksum at completion.
        WireScan::Opaque => {
            if buf.len() as u64 == total {
                Verdict::Done
            } else {
                Verdict::Resume { verified: buf.len().min(total as usize) }
            }
        }
        WireScan::Complete { .. } => {
            if buf.len() as u64 == total {
                Verdict::Done
            } else if (buf.len() as u64) < total {
                // Frames all verified; the index tail is still arriving.
                Verdict::Resume { verified: buf.len() }
            } else {
                // Longer than the server claims: restart.
                Verdict::Resume { verified: 0 }
            }
        }
        // Mid-frame (or, when all `total` bytes are already here, a
        // corrupt length pointing past the blob): drop the unverified
        // tail and refetch it.
        WireScan::NeedMore { verified } => Verdict::Resume { verified: verified.min(buf.len()) },
        WireScan::Corrupt { verified, frame_end } => match frame_end {
            Some(end) if end <= buf.len() && verified < end => {
                Verdict::BadFrame { verified, frame_end: end }
            }
            _ => Verdict::Resume { verified: verified.min(buf.len()) },
        },
    }
}

/// Client connection to a [`crate::hub::HubServer`].
pub struct HubClient {
    stream: TcpStream,
    threads: usize,
    /// Address reconnects dial (the fault proxy's, when one is armed).
    addr: String,
    timeout: Duration,
    retry: RetryPolicy,
    /// Backoff jitter source.
    rng: Xoshiro256,
    /// Env-armed fault proxy; owned so it outlives every reconnect.
    _fault: Option<FaultProxy>,
}

impl HubClient {
    /// Connect to `addr`, retrying briefly on refusal (the readiness
    /// reactor accepts in batches; a connect burst can momentarily fill
    /// the backlog). Backoff doubles up to a cap with full jitter, so
    /// concurrent clients decorrelate instead of re-colliding. When
    /// `ZIPNN_FAULT_PROFILE` is set, the connection runs through an
    /// in-process [`FaultProxy`]. Per-operation socket timeouts default
    /// to 30 s — tune with [`HubClient::with_timeout`].
    pub fn connect(addr: &str) -> Result<HubClient> {
        let mut fault = None;
        let mut target = addr.to_string();
        if let Some(spec) = FaultSpec::from_env() {
            let proxy = FaultProxy::start(addr, spec)?;
            target = proxy.addr().to_string();
            fault = Some(proxy);
        }
        HubClient::connect_inner(target, fault)
    }

    /// Connect to `addr` ignoring `ZIPNN_FAULT_PROFILE` — for tests and
    /// tools that wire their own [`FaultProxy`] (or none) and need exact
    /// fault counts / wire accounting, even when the environment arms a
    /// randomized schedule for the rest of the suite.
    pub fn connect_direct(addr: &str) -> Result<HubClient> {
        HubClient::connect_inner(addr.to_string(), None)
    }

    fn connect_inner(target: String, fault: Option<FaultProxy>) -> Result<HubClient> {
        let mut rng = Xoshiro256::seed_from_u64(jitter_seed(&target));
        let stream = connect_stream(&target, &RetryPolicy::default(), &mut rng)?;
        let client = HubClient {
            stream,
            threads: 1,
            addr: target,
            timeout: DEFAULT_IO_TIMEOUT,
            retry: RetryPolicy::default(),
            rng,
            _fault: fault,
        };
        client.with_timeout(DEFAULT_IO_TIMEOUT)
    }

    /// Worker threads for codec work during transfers.
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Per-operation read/write timeout: a transfer erroring instead of
    /// hanging when the server stops making progress for this long.
    pub fn with_timeout(mut self, timeout: Duration) -> Result<Self> {
        self.timeout = timeout;
        self.stream.set_read_timeout(Some(timeout))?;
        self.stream.set_write_timeout(Some(timeout))?;
        Ok(self)
    }

    /// Retry/backoff/deadline policy for every operation on this client.
    pub fn with_retry_policy(mut self, policy: RetryPolicy) -> Self {
        self.retry = policy;
        self
    }

    /// Replace the (dead) connection with a fresh one, under this
    /// client's own retry policy (connect retries draw from the same
    /// jittered backoff as every other operation).
    fn reconnect(&mut self) -> Result<()> {
        let stream = connect_stream(&self.addr, &self.retry, &mut self.rng)?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        self.stream = stream;
        Ok(())
    }

    /// One full-jitter backoff sleep; doubles the ceiling up to the cap.
    fn backoff_sleep(&mut self, ceiling: &mut Duration) {
        let retry = self.retry;
        std::thread::sleep(jitter_backoff(&retry, ceiling, &mut self.rng));
    }

    /// Run `f` under the retry policy: transient failures reconnect
    /// (the old connection is dead or out of sync) and retry with
    /// jittered backoff until the attempt or deadline budget runs out.
    fn with_retries<T>(&mut self, mut f: impl FnMut(&mut HubClient) -> Result<T>) -> Result<T> {
        let started = Instant::now();
        let mut ceiling = self.retry.base_backoff;
        let mut last_err: Option<Error> = None;
        for attempt in 0..self.retry.attempts.max(1) {
            if attempt > 0 {
                self.backoff_sleep(&mut ceiling);
                if let Err(e) = self.reconnect() {
                    last_err = Some(e);
                    continue;
                }
            }
            match f(self) {
                Ok(v) => return Ok(v),
                Err(e) if retryable(&e) && started.elapsed() < self.retry.deadline => {
                    last_err = Some(e);
                }
                Err(e) => return Err(e),
            }
        }
        Err(last_err.unwrap_or_else(|| Error::Invalid("retry budget exhausted".into())))
    }

    /// Upload raw bytes, optionally compressing with `cfg`. The body is
    /// streamed: compression output goes straight onto the socket in
    /// bounded frames. Retried attempts re-encode and re-stream from
    /// scratch — the server stores only complete PUT bodies, so the
    /// operation is idempotent. The simulated WAN time is charged on the
    /// wire bytes via `sim`.
    pub fn upload(
        &mut self,
        name: &str,
        raw: &[u8],
        cfg: Option<CodecConfig>,
        sim: &mut NetSim,
    ) -> Result<TransferReport> {
        let (wire_len, codec_secs) = self.with_retries(|c| match &cfg {
            Some(cfg) => {
                write_request_header(&mut c.stream, Op::Put, &format!("{name}.znn"))?;
                let t = Timer::start();
                let body = ChunkedWriter::new(&mut c.stream);
                let mut zw = ZnnWriter::new(body, cfg.clone().with_threads(c.threads))?;
                zw.write_all(raw)?;
                let body = zw.finish()?;
                let wire_len = body.payload_len() as usize;
                body.finish()?;
                let secs = t.secs();
                read_response(&mut c.stream)?;
                Ok((wire_len, secs))
            }
            None => {
                write_request_header(&mut c.stream, Op::Put, name)?;
                let mut body = ChunkedWriter::new(&mut c.stream);
                body.write_all(raw)?;
                body.finish()?;
                read_response(&mut c.stream)?;
                Ok((raw.len(), 0.0))
            }
        })?;
        Ok(TransferReport {
            name: name.to_string(),
            raw_len: raw.len(),
            wire_len,
            wire_total: wire_len as u64,
            codec_secs,
            transfer_secs: sim.transfer_secs(wire_len as u64),
        })
    }

    /// Download a blob; decompresses when it was stored as `.znn`.
    ///
    /// The transfer is resumable and verified end to end: wire bytes are
    /// scanned as container frames (including per-frame checksums when
    /// present), a mid-stream failure re-requests only the unverified
    /// tail via a ranged read, a corrupt frame is refetched by its exact
    /// byte span, and the assembled blob must hash to the checksum the
    /// server reports before it is decoded. `report.wire_total` counts
    /// every payload byte fetched across attempts.
    pub fn download(
        &mut self,
        name: &str,
        compressed: bool,
        sim: &mut NetSim,
    ) -> Result<(Vec<u8>, TransferReport)> {
        let stored = if compressed { format!("{name}.znn") } else { name.to_string() };
        let started = Instant::now();
        let (total, _, _, stored_ck) = self.stat_full(&stored)?;
        let mut wire_total = 0u64;
        let mut buf: Vec<u8> = Vec::new();
        let mut ceiling = self.retry.base_backoff;
        let mut last_err: Option<Error> = None;
        let mut corrupt_rounds = 0u32;
        let mut done = false;
        for attempt in 0..self.retry.attempts.max(1) {
            if attempt > 0 {
                if started.elapsed() >= self.retry.deadline {
                    break;
                }
                self.backoff_sleep(&mut ceiling);
                if let Err(e) = self.reconnect() {
                    last_err = Some(e);
                    continue;
                }
            }
            let fetched = if buf.is_empty() {
                self.fetch_get(&stored, &mut buf, &mut wire_total)
            } else {
                self.fetch_tail(&stored, total, &mut buf, &mut wire_total)
            };
            let conn_ok = match fetched {
                Ok(()) => true,
                Err(e) if retryable(&e) => {
                    last_err = Some(e);
                    false
                }
                Err(e) => return Err(e),
            };
            // Verify what we hold; corrupt frames are refetched in place
            // (on a live connection), everything else trims to the
            // verified prefix for a tail re-request next attempt.
            loop {
                match verdict(&buf, total) {
                    Verdict::Done => {
                        done = true;
                        break;
                    }
                    Verdict::Resume { verified } => {
                        buf.truncate(verified);
                        break;
                    }
                    Verdict::BadFrame { verified, frame_end } => {
                        corrupt_rounds += 1;
                        if !conn_ok
                            || corrupt_rounds > 4
                            || !self.refetch_span(
                                &stored,
                                verified,
                                frame_end,
                                &mut buf,
                                &mut wire_total,
                            )
                        {
                            buf.truncate(verified);
                            break;
                        }
                    }
                }
            }
            if done {
                // Structure checks out; gate on the end-to-end checksum
                // (covers the index tail and raw blobs).
                if blob_ck(&buf) == stored_ck {
                    break;
                }
                last_err = Some(Error::Corrupt(
                    "downloaded blob failed its end-to-end checksum".into(),
                ));
                buf.clear();
                corrupt_rounds = 0;
                done = false;
            }
        }
        if !done {
            return Err(last_err.unwrap_or_else(|| {
                Error::Corrupt("download could not complete within the retry budget".into())
            }));
        }
        let (raw, codec_secs) = if compressed {
            let t = Timer::start();
            let mapped = MappedBytes::from_vec(std::mem::take(&mut buf));
            let mut zr = ZnnReader::from_mapped(mapped)?.with_threads(self.threads);
            let mut out = Vec::new();
            zr.read_to_end(&mut out)?;
            drop(zr);
            (out, t.secs())
        } else {
            (std::mem::take(&mut buf), 0.0)
        };
        let raw_len = raw.len();
        let transfer_secs = sim.transfer_secs(wire_total);
        let report = TransferReport {
            name: name.to_string(),
            raw_len,
            wire_len: total as usize,
            wire_total,
            codec_secs,
            transfer_secs,
        };
        Ok((raw, report))
    }

    /// Issue a full GET and append the body to `buf`, counting every
    /// payload byte (even of a partial, failed body) into `wire`.
    fn fetch_get(&mut self, stored: &str, buf: &mut Vec<u8>, wire: &mut u64) -> Result<()> {
        write_request(&mut self.stream, Op::Get, stored, b"")?;
        let ok = read_response_header(&mut self.stream)?;
        let mut body = ChunkedReader::new(&mut self.stream);
        if !ok {
            let mut msg = Vec::new();
            body.read_to_end(&mut msg)?;
            return Err(hub_error(&msg));
        }
        let before = buf.len();
        let res = body.read_to_end(buf);
        *wire += (buf.len() - before) as u64;
        res?;
        body.drain()?; // stay in sync on the keep-alive connection
        Ok(())
    }

    /// Re-request the unfetched tail `[buf.len(), total)` via a ranged
    /// read and append it to `buf`.
    fn fetch_tail(
        &mut self,
        stored: &str,
        total: u64,
        buf: &mut Vec<u8>,
        wire: &mut u64,
    ) -> Result<()> {
        let from = buf.len() as u64;
        if from >= total {
            return Ok(());
        }
        write_request(&mut self.stream, Op::Range, stored, &encode_range(from, total - from))?;
        let ok = read_response_header(&mut self.stream)?;
        let mut body = ChunkedReader::new(&mut self.stream);
        if !ok {
            let mut msg = Vec::new();
            body.read_to_end(&mut msg)?;
            return Err(hub_error(&msg));
        }
        let before = buf.len();
        let res = body.read_to_end(buf);
        *wire += (buf.len() - before) as u64;
        res?;
        body.drain()?;
        Ok(())
    }

    /// Targeted refetch of a corrupt frame's exact span `[at, end)` on
    /// the live connection. `false` (conservative) on any failure — the
    /// caller falls back to trimming and refetching the tail.
    fn refetch_span(
        &mut self,
        stored: &str,
        at: usize,
        end: usize,
        buf: &mut Vec<u8>,
        wire: &mut u64,
    ) -> bool {
        let len = (end - at) as u64;
        match self.fetch_range_once(stored, at as u64, len) {
            Ok(patch) if patch.len() as u64 == len => {
                *wire += len;
                buf[at..end].copy_from_slice(&patch);
                true
            }
            _ => false,
        }
    }

    /// One Range request on the current connection, no retries.
    fn fetch_range_once(&mut self, name: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        write_request(&mut self.stream, Op::Range, name, &encode_range(offset, len))?;
        read_response(&mut self.stream)
    }

    /// Upload raw bytes compressed **with a tensor index**: `tensors`
    /// describe byte ranges of `raw` (e.g. from
    /// [`crate::model::tensor_spans`]), and the resulting `{name}.znn`
    /// container carries the index section, so single tensors can later
    /// be fetched with [`HubClient::get_tensor`]. Retries re-encode and
    /// re-stream from scratch, like [`HubClient::upload`].
    pub fn upload_indexed(
        &mut self,
        name: &str,
        raw: &[u8],
        tensors: Vec<TensorMeta>,
        cfg: CodecConfig,
        sim: &mut NetSim,
    ) -> Result<TransferReport> {
        let (wire_len, codec_secs) = self.with_retries(|c| {
            write_request_header(&mut c.stream, Op::Put, &format!("{name}.znn"))?;
            let t = Timer::start();
            let body = ChunkedWriter::new(&mut c.stream);
            let mut zw = ZnnWriter::new(body, cfg.clone().with_threads(c.threads))?
                .with_index(tensors.clone());
            zw.write_all(raw)?;
            let body = zw.finish()?;
            let wire_len = body.payload_len() as usize;
            body.finish()?;
            let secs = t.secs();
            read_response(&mut c.stream)?;
            Ok((wire_len, secs))
        })?;
        Ok(TransferReport {
            name: name.to_string(),
            raw_len: raw.len(),
            wire_len,
            wire_total: wire_len as u64,
            codec_secs,
            transfer_secs: sim.transfer_secs(wire_len as u64),
        })
    }

    /// Fetch a byte range `[offset, offset + len)` of a stored blob's
    /// bytes (compressed container bytes for `.znn` blobs). The server
    /// slices the range straight out of its spooled mapping.
    pub fn get_range(&mut self, name: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        self.with_retries(|c| c.fetch_range_once(name, offset, len))
    }

    /// Fetch a single tensor of an indexed `{name}.znn` container. Only
    /// the frames covering the tensor travel the wire; they are decoded
    /// as they arrive. Returns the tensor's raw bytes plus the response's
    /// payload bytes on the wire (the bytes-on-wire measure asserted in
    /// tests and reported by the fig10 bench).
    pub fn get_tensor(&mut self, name: &str, tensor: &str) -> Result<(Vec<u8>, u64)> {
        let f = self.get_tensor_placed(name, tensor)?;
        Ok((f.data, f.wire))
    }

    /// Like [`HubClient::get_tensor`], but also surfaces the placement:
    /// the raw-payload offset of the tensor's first byte. The multi-peer
    /// fleet client reassembles stripes with it, and callers laying
    /// tensors back into a model buffer need it too.
    ///
    /// The 24-byte placement meta is validated against the payload that
    /// actually arrived: a declared length the decoded bytes don't match,
    /// or a base/offset pair that doesn't add up, is an
    /// [`Error::Corrupt`] naming the mismatch — never bytes silently
    /// handed onward.
    pub fn get_tensor_placed(&mut self, name: &str, tensor: &str) -> Result<TensorFetch> {
        self.with_retries(|c| {
            write_request(
                &mut c.stream,
                Op::GetTensor,
                &format!("{name}.znn"),
                tensor.as_bytes(),
            )?;
            let ok = read_response_header(&mut c.stream)?;
            let mut body = ChunkedReader::new(&mut c.stream);
            if !ok {
                let mut msg = Vec::new();
                body.read_to_end(&mut msg)?;
                return Err(hub_error(&msg));
            }
            // 24-byte placement header, then a self-contained ZNS1
            // sub-container of the covering frames.
            let mut meta = [0u8; 24];
            body.read_exact(&mut meta)?;
            let base = u64::from_le_bytes(meta[0..8].try_into().unwrap());
            let rel = u64::from_le_bytes(meta[8..16].try_into().unwrap());
            let len = u64::from_le_bytes(meta[16..24].try_into().unwrap());
            let offset = base.checked_add(rel).ok_or_else(|| {
                Error::Corrupt(format!(
                    "tensor placement meta overflows: base {base} + rel {rel}"
                ))
            })?;
            let mut zr = ZnnReader::new(&mut body)?.with_threads(c.threads);
            let data = zr.decode_range(rel, len)?;
            drop(zr);
            body.drain()?; // stay in sync on the keep-alive connection
            if data.len() as u64 != len {
                return Err(Error::Corrupt(format!(
                    "tensor response declared {len} bytes but {} arrived",
                    data.len()
                )));
            }
            Ok(TensorFetch { offset, data, wire: body.payload_len() })
        })
    }

    /// Delete a stored blob. Idempotent: `Ok(true)` when a blob was
    /// removed, `Ok(false)` when the name was already absent — repair and
    /// rebalance re-issue deletes freely without treating "already gone"
    /// as failure. On a persisted hub the on-disk pair is removed too.
    pub fn delete(&mut self, name: &str) -> Result<bool> {
        self.with_retries(|c| {
            write_request(&mut c.stream, Op::Delete, name, b"")?;
            let payload = read_response(&mut c.stream)?;
            Ok(payload == b"1")
        })
    }

    /// Health probe: `Ok` iff the server answered. The fleet repair loop
    /// uses it (with a short timeout and no retries) to tell a live peer
    /// from a dead one before trusting its inventory.
    pub fn ping(&mut self) -> Result<()> {
        self.with_retries(|c| {
            write_request(&mut c.stream, Op::Ping, "", b"")?;
            let payload = read_response(&mut c.stream)?;
            if payload != b"pong" {
                return Err(Error::Format(format!(
                    "bad ping response '{}'",
                    String::from_utf8_lossy(&payload)
                )));
            }
            Ok(())
        })
    }

    /// List stored blob names.
    pub fn list(&mut self) -> Result<Vec<String>> {
        self.with_retries(|c| {
            write_request(&mut c.stream, Op::List, "", b"")?;
            let payload = read_response(&mut c.stream)?;
            let s = String::from_utf8_lossy(&payload);
            Ok(s.split('\n').filter(|x| !x.is_empty()).map(String::from).collect())
        })
    }

    /// Storage stats of a blob: `(total_bytes, n_frames, max_frame)` —
    /// how the server actually holds it (bounded frames, never one
    /// allocation).
    pub fn stat(&mut self, name: &str) -> Result<(u64, usize, usize)> {
        let (total, frames, max, _) = self.stat_full(name)?;
        Ok((total, frames, max))
    }

    /// Extended stat: `(total_bytes, n_frames, max_frame, checksum)`.
    /// The checksum is the server's whole-blob hash, computed once at
    /// store time — resilient downloads gate completion on it.
    pub fn stat_full(&mut self, name: &str) -> Result<(u64, usize, usize, u64)> {
        self.with_retries(|c| {
            write_request(&mut c.stream, Op::Stat, name, b"")?;
            let payload = read_response(&mut c.stream)?;
            let s = String::from_utf8_lossy(&payload);
            let mut it = s.split_whitespace();
            let parse_err = || Error::Format(format!("bad stat response '{s}'"));
            let total = it.next().and_then(|v| v.parse().ok()).ok_or_else(parse_err)?;
            let frames = it.next().and_then(|v| v.parse().ok()).ok_or_else(parse_err)?;
            let max = it.next().and_then(|v| v.parse().ok()).ok_or_else(parse_err)?;
            let ck = it.next().and_then(|v| v.parse().ok()).ok_or_else(parse_err)?;
            Ok((total, frames, max, ck))
        })
    }
}

/// Dial under `policy`: the attempt budget, backoff base/cap, and the
/// full-jitter sleep schedule are the same [`RetryPolicy`] machinery
/// every operation retries under (the connect path used to run its own
/// constants, so a fleet restart re-dialed on one shared schedule).
fn connect_stream(addr: &str, policy: &RetryPolicy, rng: &mut Xoshiro256) -> Result<TcpStream> {
    let mut ceiling = policy.base_backoff;
    let mut last_err = None;
    for attempt in 0..policy.attempts.max(1) {
        if attempt > 0 {
            std::thread::sleep(jitter_backoff(policy, &mut ceiling, rng));
        }
        match TcpStream::connect(addr) {
            Ok(stream) => return Ok(stream),
            // Only backlog-pressure shapes are worth retrying; a bad
            // address or unreachable host fails immediately.
            Err(e) if matches!(
                e.kind(),
                std::io::ErrorKind::ConnectionRefused
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::TimedOut
            ) =>
            {
                last_err = Some(e);
            }
            Err(e) => return Err(e.into()),
        }
    }
    Err(last_err.expect("at least one connect attempt").into())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The full sleep schedule `attempts` retries would draw.
    fn schedule(policy: &RetryPolicy, seed: u64) -> Vec<Duration> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut ceiling = policy.base_backoff;
        (1..policy.attempts).map(|_| jitter_backoff(policy, &mut ceiling, &mut rng)).collect()
    }

    #[test]
    fn seeded_connect_schedules_diverge() {
        // Two clients restarting against the same fleet must not re-dial
        // in lockstep: different jitter seeds produce different sleep
        // schedules, while the same seed replays exactly.
        let policy = RetryPolicy::default();
        let a = schedule(&policy, 1);
        let b = schedule(&policy, 2);
        assert_eq!(a.len(), b.len());
        assert_ne!(a, b, "distinct seeds drew identical connect backoff schedules");
        assert_eq!(a, schedule(&policy, 1), "same seed must replay the same schedule");
    }

    #[test]
    fn connect_backoff_respects_policy_cap() {
        let policy = RetryPolicy {
            attempts: 16,
            base_backoff: Duration::from_millis(10),
            max_backoff: Duration::from_millis(80),
            deadline: Duration::from_secs(60),
        };
        for seed in 0..32 {
            for sleep in schedule(&policy, seed) {
                assert!(sleep <= policy.max_backoff, "sleep {sleep:?} exceeds the cap");
            }
        }
    }

    #[test]
    fn per_process_jitter_seeds_decorrelate_by_time() {
        // Same address, two draws: the wall-clock/pid mix must not
        // collapse every process onto one schedule.
        let s1 = jitter_seed("127.0.0.1:4000");
        std::thread::sleep(Duration::from_micros(10));
        let s2 = jitter_seed("127.0.0.1:4000");
        assert_ne!(s1, s2);
    }
}
