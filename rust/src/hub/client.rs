//! The hub client: upload/download with optional ZipNN compression and
//! Fig.-10-style end-to-end timing.
//!
//! Transfers are streamed: an upload pipes raw bytes through a
//! [`ZnnWriter`] straight onto the socket (the compressed blob is never
//! materialized client-side), and a compressed download decompresses
//! through a [`ZnnReader`] as frames arrive off the wire. With
//! `with_threads(n > 1)` both directions run on the process-shared
//! sticky-state pool, pipelined: a PUT compresses batch N+1 while batch
//! N's frames drain onto the socket, and a GET fetches batch N+1's wire
//! bytes while batch N decodes.

use crate::codec::{CodecConfig, TensorMeta, ZnnReader, ZnnWriter};
use crate::error::{Error, Result};
use crate::hub::netsim::NetSim;
use crate::hub::protocol::{
    encode_range, read_response, read_response_header, write_request, write_request_header,
    ChunkedReader, ChunkedWriter, Op,
};
use crate::util::Timer;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Default per-operation socket timeout: generous enough for multi-GB
/// streamed transfers (each read/write must make *some* progress within
/// it), small enough that a dead server fails the client promptly.
const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// Connect retry budget: the reactor accepts in batches, so a connect
/// issued in a burst can land on a momentarily full backlog.
const CONNECT_ATTEMPTS: usize = 8;
const CONNECT_BACKOFF: Duration = Duration::from_millis(10);

/// End-to-end timing of one transfer (Fig. 10 bars).
#[derive(Debug, Clone)]
pub struct TransferReport {
    /// Model/blob name.
    pub name: String,
    /// Raw bytes.
    pub raw_len: usize,
    /// Bytes on the wire (= raw when uncompressed).
    pub wire_len: usize,
    /// Measured codec wall seconds, overlapping the loopback send/receive
    /// (0 when compression is off).
    pub codec_secs: f64,
    /// Simulated WAN transfer seconds for `wire_len`.
    pub transfer_secs: f64,
}

impl TransferReport {
    /// Total end-to-end seconds.
    pub fn total_secs(&self) -> f64 {
        self.codec_secs + self.transfer_secs
    }

    /// Compressed size in percent.
    pub fn pct(&self) -> f64 {
        self.wire_len as f64 / self.raw_len as f64 * 100.0
    }
}

/// Client connection to a [`crate::hub::HubServer`].
pub struct HubClient {
    stream: TcpStream,
    threads: usize,
}

impl HubClient {
    /// Connect to `addr`, retrying briefly on refusal (the readiness
    /// reactor accepts in batches; a connect burst can momentarily fill
    /// the backlog). Per-operation socket timeouts default to 30 s — tune
    /// with [`HubClient::with_timeout`].
    pub fn connect(addr: &str) -> Result<HubClient> {
        let mut backoff = CONNECT_BACKOFF;
        let mut last_err = None;
        for attempt in 0..CONNECT_ATTEMPTS {
            if attempt > 0 {
                std::thread::sleep(backoff);
                backoff *= 2;
            }
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    let client = HubClient { stream, threads: 1 };
                    return client.with_timeout(DEFAULT_IO_TIMEOUT);
                }
                // Only backlog-pressure shapes are worth retrying; a bad
                // address or unreachable host fails immediately.
                Err(e) if matches!(
                    e.kind(),
                    std::io::ErrorKind::ConnectionRefused
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::ConnectionAborted
                        | std::io::ErrorKind::TimedOut
                ) =>
                {
                    last_err = Some(e);
                }
                Err(e) => return Err(e.into()),
            }
        }
        Err(last_err.expect("at least one connect attempt").into())
    }

    /// Worker threads for codec work during transfers.
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Per-operation read/write timeout: a transfer erroring instead of
    /// hanging when the server stops making progress for this long.
    pub fn with_timeout(self, timeout: Duration) -> Result<Self> {
        self.stream.set_read_timeout(Some(timeout))?;
        self.stream.set_write_timeout(Some(timeout))?;
        Ok(self)
    }

    /// Upload raw bytes, optionally compressing with `cfg`. The body is
    /// streamed: compression output goes straight onto the socket in
    /// bounded frames. The simulated WAN time is charged on the wire bytes
    /// via `sim`.
    pub fn upload(
        &mut self,
        name: &str,
        raw: &[u8],
        cfg: Option<CodecConfig>,
        sim: &mut NetSim,
    ) -> Result<TransferReport> {
        let (wire_len, codec_secs) = match cfg {
            Some(cfg) => {
                write_request_header(&mut self.stream, Op::Put, &format!("{name}.znn"))?;
                let t = Timer::start();
                let body = ChunkedWriter::new(&mut self.stream);
                let mut zw = ZnnWriter::new(body, cfg.with_threads(self.threads))?;
                zw.write_all(raw)?;
                let body = zw.finish()?;
                let wire_len = body.payload_len() as usize;
                body.finish()?;
                (wire_len, t.secs())
            }
            None => {
                write_request_header(&mut self.stream, Op::Put, name)?;
                let mut body = ChunkedWriter::new(&mut self.stream);
                body.write_all(raw)?;
                body.finish()?;
                (raw.len(), 0.0)
            }
        };
        read_response(&mut self.stream)?;
        Ok(TransferReport {
            name: name.to_string(),
            raw_len: raw.len(),
            wire_len,
            codec_secs,
            transfer_secs: sim.transfer_secs(wire_len as u64),
        })
    }

    /// Download a blob; decompresses when it was stored as `.znn`. The
    /// compressed body is decoded as it arrives — only the raw result is
    /// materialized.
    pub fn download(
        &mut self,
        name: &str,
        compressed: bool,
        sim: &mut NetSim,
    ) -> Result<(Vec<u8>, TransferReport)> {
        let stored_name = if compressed { format!("{name}.znn") } else { name.to_string() };
        write_request(&mut self.stream, Op::Get, &stored_name, b"")?;
        let ok = read_response_header(&mut self.stream)?;
        let mut body = ChunkedReader::new(&mut self.stream);
        if !ok {
            let mut msg = Vec::new();
            body.read_to_end(&mut msg)?;
            return Err(Error::Format(format!(
                "hub error: {}",
                String::from_utf8_lossy(&msg)
            )));
        }
        let mut raw = Vec::new();
        let codec_secs = if compressed {
            let t = Timer::start();
            let mut zr = ZnnReader::new(&mut body)?.with_threads(self.threads);
            zr.read_to_end(&mut raw)?;
            drop(zr);
            t.secs()
        } else {
            body.read_to_end(&mut raw)?;
            0.0
        };
        body.drain()?; // stay in sync on the keep-alive connection
        let wire_len = body.payload_len() as usize;
        let transfer_secs = sim.transfer_secs(wire_len as u64);
        let report = TransferReport {
            name: name.to_string(),
            raw_len: raw.len(),
            wire_len,
            codec_secs,
            transfer_secs,
        };
        Ok((raw, report))
    }

    /// Upload raw bytes compressed **with a tensor index**: `tensors`
    /// describe byte ranges of `raw` (e.g. from
    /// [`crate::model::tensor_spans`]), and the resulting `{name}.znn`
    /// container carries the index section, so single tensors can later
    /// be fetched with [`HubClient::get_tensor`].
    pub fn upload_indexed(
        &mut self,
        name: &str,
        raw: &[u8],
        tensors: Vec<TensorMeta>,
        cfg: CodecConfig,
        sim: &mut NetSim,
    ) -> Result<TransferReport> {
        write_request_header(&mut self.stream, Op::Put, &format!("{name}.znn"))?;
        let t = Timer::start();
        let body = ChunkedWriter::new(&mut self.stream);
        let mut zw = ZnnWriter::new(body, cfg.with_threads(self.threads))?.with_index(tensors);
        zw.write_all(raw)?;
        let body = zw.finish()?;
        let wire_len = body.payload_len() as usize;
        body.finish()?;
        let codec_secs = t.secs();
        read_response(&mut self.stream)?;
        Ok(TransferReport {
            name: name.to_string(),
            raw_len: raw.len(),
            wire_len,
            codec_secs,
            transfer_secs: sim.transfer_secs(wire_len as u64),
        })
    }

    /// Fetch a byte range `[offset, offset + len)` of a stored blob's
    /// bytes (compressed container bytes for `.znn` blobs). The server
    /// slices the range straight out of its spooled mapping.
    pub fn get_range(&mut self, name: &str, offset: u64, len: u64) -> Result<Vec<u8>> {
        write_request(&mut self.stream, Op::Range, name, &encode_range(offset, len))?;
        read_response(&mut self.stream)
    }

    /// Fetch a single tensor of an indexed `{name}.znn` container. Only
    /// the frames covering the tensor travel the wire; they are decoded
    /// as they arrive. Returns the tensor's raw bytes plus the response's
    /// payload bytes on the wire (the bytes-on-wire measure asserted in
    /// tests and reported by the fig10 bench).
    pub fn get_tensor(&mut self, name: &str, tensor: &str) -> Result<(Vec<u8>, u64)> {
        write_request(
            &mut self.stream,
            Op::GetTensor,
            &format!("{name}.znn"),
            tensor.as_bytes(),
        )?;
        let ok = read_response_header(&mut self.stream)?;
        let mut body = ChunkedReader::new(&mut self.stream);
        if !ok {
            let mut msg = Vec::new();
            body.read_to_end(&mut msg)?;
            return Err(Error::Format(format!(
                "hub error: {}",
                String::from_utf8_lossy(&msg)
            )));
        }
        // 24-byte placement header, then a self-contained ZNS1
        // sub-container of the covering frames.
        let mut meta = [0u8; 24];
        body.read_exact(&mut meta)?;
        let _base_raw = u64::from_le_bytes(meta[0..8].try_into().unwrap());
        let rel = u64::from_le_bytes(meta[8..16].try_into().unwrap());
        let len = u64::from_le_bytes(meta[16..24].try_into().unwrap());
        let mut zr = ZnnReader::new(&mut body)?.with_threads(self.threads);
        let data = zr.decode_range(rel, len)?;
        drop(zr);
        body.drain()?; // stay in sync on the keep-alive connection
        Ok((data, body.payload_len()))
    }

    /// List stored blob names.
    pub fn list(&mut self) -> Result<Vec<String>> {
        write_request(&mut self.stream, Op::List, "", b"")?;
        let payload = read_response(&mut self.stream)?;
        let s = String::from_utf8_lossy(&payload);
        Ok(s.split('\n').filter(|x| !x.is_empty()).map(String::from).collect())
    }

    /// Storage stats of a blob: `(total_bytes, n_frames, max_frame)` —
    /// how the server actually holds it (bounded frames, never one
    /// allocation).
    pub fn stat(&mut self, name: &str) -> Result<(u64, usize, usize)> {
        write_request(&mut self.stream, Op::Stat, name, b"")?;
        let payload = read_response(&mut self.stream)?;
        let s = String::from_utf8_lossy(&payload);
        let mut it = s.split_whitespace();
        let parse_err = || Error::Format(format!("bad stat response '{s}'"));
        let total = it.next().and_then(|v| v.parse().ok()).ok_or_else(parse_err)?;
        let frames = it.next().and_then(|v| v.parse().ok()).ok_or_else(parse_err)?;
        let max = it.next().and_then(|v| v.parse().ok()).ok_or_else(parse_err)?;
        Ok((total, frames, max))
    }
}
