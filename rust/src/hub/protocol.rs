//! Length-prefixed binary protocol between hub client and server.
//!
//! Bodies are **chunked**: a sequence of `[len u32][bytes]` wire frames
//! terminated by a zero length, each frame at most [`FRAME_MAX`] bytes.
//! That lets both sides stream arbitrarily large blobs while bounding the
//! memory either side must hold per connection to one frame.
//!
//! ```text
//! request:  [op u8][name_len u32][name bytes][chunked body]
//! response: [status u8][chunked body]
//! body:     ([len u32 in 1..=FRAME_MAX][bytes])* [0 u32]
//! ```
//! ops: 0 = PUT, 1 = GET, 2 = LIST, 3 = SHUTDOWN, 4 = STAT, 5 = RANGE,
//! 6 = GET_TENSOR, 7 = DELETE, 8 = PING.
//! status: 0 = OK, 1 = err (body is a UTF-8 message).
//!
//! RANGE requests a byte range of a stored blob: the body is exactly 16
//! bytes — `[offset u64][len u64]`, little-endian (see [`encode_range`] /
//! [`parse_range`]) — and the response body is the requested bytes,
//! served straight from the server's spooled mapping when available.
//! GET_TENSOR's body is a tensor name; the server answers with a 24-byte
//! placement header followed by a self-contained `ZNS1` sub-container of
//! the covering frames (see `hub::client::HubClient::get_tensor`).
//!
//! **Versioning note — the fleet layer composes these ops, nothing
//! more.** Sharded multi-hub placement, multi-peer striped downloads,
//! rebalance, and the edge read-through cache (see `hub::cluster` /
//! `hub::fleet`) are all composed from the ops above: a stripe is an
//! ordinary RANGE, a repair copy is STAT + RANGE + PUT, a health probe is
//! a PING, and dropping a displaced replica is a DELETE. DELETE and PING
//! arrived with the self-healing fleet (both empty-body, name-in-header
//! requests — an older peer rejects the opcode byte with a clean error,
//! which repair treats as "peer can't, skip"); there is still no version
//! byte to bump.

use crate::error::{Error, Result};
use std::collections::VecDeque;
use std::io::{self, Read, Write};

/// Maximum payload bytes in one wire frame — the server's per-connection
/// buffering bound.
pub const FRAME_MAX: usize = 64 * 1024;

/// Request opcode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    /// Store a blob.
    Put = 0,
    /// Fetch a blob.
    Get = 1,
    /// List stored names (newline-joined payload).
    List = 2,
    /// Stop the server (tests / clean shutdown).
    Shutdown = 3,
    /// Blob storage stats: "total_len n_frames max_frame" (UTF-8).
    Stat = 4,
    /// Fetch a byte range of a blob (body: [`encode_range`] payload).
    Range = 5,
    /// Fetch one tensor of an indexed container (body: tensor name).
    GetTensor = 6,
    /// Remove a stored blob (empty body). Idempotent: the OK payload is
    /// `"1"` when a blob was removed, `"0"` when the name was absent.
    Delete = 7,
    /// Health probe (empty name and body); the OK payload is `"pong"`.
    /// Fleet repair uses it to tell a live peer from a dead one.
    Ping = 8,
}

impl Op {
    /// Parse an opcode byte.
    pub fn from_u8(v: u8) -> Option<Op> {
        match v {
            0 => Some(Op::Put),
            1 => Some(Op::Get),
            2 => Some(Op::List),
            3 => Some(Op::Shutdown),
            4 => Some(Op::Stat),
            5 => Some(Op::Range),
            6 => Some(Op::GetTensor),
            7 => Some(Op::Delete),
            8 => Some(Op::Ping),
            _ => None,
        }
    }
}

/// Serialize a RANGE request body.
pub fn encode_range(offset: u64, len: u64) -> [u8; 16] {
    let mut out = [0u8; 16];
    out[..8].copy_from_slice(&offset.to_le_bytes());
    out[8..].copy_from_slice(&len.to_le_bytes());
    out
}

/// Parse and validate a RANGE request body: exactly 16 bytes, and
/// `offset + len` must not overflow `u64`. Whether the range fits the
/// blob is the server's check; this one guards the arithmetic.
pub fn parse_range(body: &[u8]) -> Result<(u64, u64)> {
    if body.len() != 16 {
        return Err(Error::Format(format!(
            "range body is {} bytes, expected 16",
            body.len()
        )));
    }
    let offset = u64::from_le_bytes(body[..8].try_into().unwrap());
    let len = u64::from_le_bytes(body[8..].try_into().unwrap());
    if offset.checked_add(len).is_none() {
        return Err(Error::Format(format!("range {offset}+{len} overflows u64")));
    }
    Ok((offset, len))
}

// ---------------------------------------------------------------------------
// Chunked body adapters
// ---------------------------------------------------------------------------

/// [`Write`] adapter that emits a chunked body to the inner writer.
/// Small writes coalesce into [`FRAME_MAX`]-sized wire frames; call
/// [`ChunkedWriter::finish`] to flush the final frame and the terminator.
pub struct ChunkedWriter<W: Write> {
    inner: W,
    buf: Vec<u8>,
    written: u64,
}

impl<W: Write> ChunkedWriter<W> {
    /// New chunked body on `inner`.
    pub fn new(inner: W) -> ChunkedWriter<W> {
        ChunkedWriter { inner, buf: Vec::with_capacity(FRAME_MAX), written: 0 }
    }

    /// Payload bytes accepted so far (excluding framing overhead).
    pub fn payload_len(&self) -> u64 {
        self.written
    }

    fn emit_buf(&mut self) -> io::Result<()> {
        if !self.buf.is_empty() {
            self.inner.write_all(&(self.buf.len() as u32).to_le_bytes())?;
            self.inner.write_all(&self.buf)?;
            self.buf.clear();
        }
        Ok(())
    }

    /// Flush pending bytes, write the terminator, flush the inner writer,
    /// and return it.
    pub fn finish(mut self) -> io::Result<W> {
        self.emit_buf()?;
        self.inner.write_all(&0u32.to_le_bytes())?;
        self.inner.flush()?;
        Ok(self.inner)
    }
}

impl<W: Write> Write for ChunkedWriter<W> {
    fn write(&mut self, data: &[u8]) -> io::Result<usize> {
        self.written += data.len() as u64;
        let mut rest = data;
        while !rest.is_empty() {
            let space = FRAME_MAX - self.buf.len();
            let take = space.min(rest.len());
            self.buf.extend_from_slice(&rest[..take]);
            rest = &rest[take..];
            if self.buf.len() == FRAME_MAX {
                self.emit_buf()?;
            }
        }
        Ok(data.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        self.emit_buf()?;
        self.inner.flush()
    }
}

/// [`Read`] adapter over a chunked body. Yields the concatenated payload
/// and stops at the terminator; [`ChunkedReader::drain`] consumes any
/// unread remainder so a keep-alive connection stays in sync.
pub struct ChunkedReader<R: Read> {
    inner: R,
    remaining: usize,
    done: bool,
    consumed: u64,
}

impl<R: Read> ChunkedReader<R> {
    /// New chunked body from `inner`.
    pub fn new(inner: R) -> ChunkedReader<R> {
        ChunkedReader { inner, remaining: 0, done: false, consumed: 0 }
    }

    /// Payload bytes read so far (excluding framing overhead).
    pub fn payload_len(&self) -> u64 {
        self.consumed
    }

    /// Advance to the next wire frame; `false` at the terminator.
    fn next_frame(&mut self) -> io::Result<bool> {
        if self.done {
            return Ok(false);
        }
        let mut len4 = [0u8; 4];
        self.inner.read_exact(&mut len4)?;
        let len = u32::from_le_bytes(len4) as usize;
        if len == 0 {
            self.done = true;
            return Ok(false);
        }
        if len > FRAME_MAX {
            return Err(io::Error::new(
                io::ErrorKind::InvalidData,
                format!("wire frame of {len} bytes exceeds FRAME_MAX"),
            ));
        }
        self.remaining = len;
        Ok(true)
    }

    /// Read one whole wire frame into `buf` (replacing its contents).
    /// Returns `false` (and leaves `buf` empty) at the terminator. This is
    /// the server's PUT path: each stored frame is one bounded allocation.
    pub fn read_frame(&mut self, buf: &mut Vec<u8>) -> io::Result<bool> {
        buf.clear();
        if self.remaining == 0 && !self.next_frame()? {
            return Ok(false);
        }
        buf.resize(self.remaining, 0);
        self.inner.read_exact(buf)?;
        self.consumed += self.remaining as u64;
        self.remaining = 0;
        Ok(true)
    }

    /// Consume (and discard) everything up to the terminator.
    pub fn drain(&mut self) -> io::Result<()> {
        let mut scratch = [0u8; 4096];
        loop {
            let n = self.read(&mut scratch)?;
            if n == 0 {
                return Ok(());
            }
        }
    }
}

impl<R: Read> Read for ChunkedReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        if buf.is_empty() {
            return Ok(0);
        }
        while self.remaining == 0 {
            if !self.next_frame()? {
                return Ok(0);
            }
        }
        let take = self.remaining.min(buf.len());
        self.inner.read_exact(&mut buf[..take])?;
        self.remaining -= take;
        self.consumed += take as u64;
        Ok(take)
    }
}

// ---------------------------------------------------------------------------
// Resumable request parser
// ---------------------------------------------------------------------------

/// Maximum request-name length on the wire.
pub const NAME_MAX: usize = 4096;

/// One parsed unit of a request stream (see [`RequestParser`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ReqEvent {
    /// Opcode and name parsed; a chunked body follows.
    Header {
        /// Request opcode.
        op: Op,
        /// Blob name (may be empty).
        name: String,
    },
    /// One body wire frame (1..=[`FRAME_MAX`] payload bytes).
    Frame(Vec<u8>),
    /// Body terminator: the request is complete. The next byte fed starts
    /// a new request.
    End,
}

enum ParseState {
    /// Waiting for the opcode byte (also the between-requests state).
    Op,
    /// Collecting the 4-byte name length.
    NameLen,
    /// Collecting `len` name bytes.
    Name { len: usize },
    /// Collecting the 4-byte frame length.
    FrameLen,
    /// Collecting `len` frame payload bytes.
    Frame { len: usize },
    /// A previous feed errored; the connection must be dropped.
    Failed,
}

/// Incremental, non-blocking request parser: feed whatever bytes arrived,
/// take the completed [`ReqEvent`]s.
///
/// This is the readiness-driven twin of [`read_request_header`] +
/// [`ChunkedReader`]: instead of pulling from a blocking [`Read`], the
/// caller pushes arbitrary splits of the byte stream with
/// [`RequestParser::feed`] and drains events with
/// [`RequestParser::take`]. Internal buffering is bounded by the largest
/// single wire unit (one frame, [`FRAME_MAX`] bytes) plus the event queue,
/// which holds at most the frames completed by the bytes of one feed —
/// the reactor feeds one socket read (≤ 64 KiB) at a time and drains
/// events before reading again, so per-connection memory stays
/// O([`FRAME_MAX`]).
///
/// Errors (bad opcode, oversized name or frame length, non-UTF-8 name)
/// are sticky: every later `feed` fails too, and the connection should be
/// closed. Truncation is not an error — the parser simply waits for more
/// bytes; use [`RequestParser::mid_request`] to detect a stream that
/// stopped mid-message.
pub struct RequestParser {
    state: ParseState,
    /// Partial fixed-width field or frame payload being collected.
    buf: Vec<u8>,
    /// Opcode of the request being parsed (valid from NameLen onward).
    op: Op,
    events: VecDeque<ReqEvent>,
}

impl Default for RequestParser {
    fn default() -> Self {
        Self::new()
    }
}

impl RequestParser {
    /// New parser positioned at a request boundary.
    pub fn new() -> RequestParser {
        RequestParser {
            state: ParseState::Op,
            buf: Vec::new(),
            op: Op::List,
            events: VecDeque::new(),
        }
    }

    /// Push bytes; completed events become available via
    /// [`RequestParser::take`]. Consumes all of `data` or fails.
    pub fn feed(&mut self, mut data: &[u8]) -> Result<()> {
        while !data.is_empty() {
            match self.state {
                ParseState::Op => {
                    let b = data[0];
                    data = &data[1..];
                    self.op = Op::from_u8(b).ok_or_else(|| {
                        self.state = ParseState::Failed;
                        Error::Format(format!("bad opcode {b}"))
                    })?;
                    self.state = ParseState::NameLen;
                }
                ParseState::NameLen => {
                    if !self.collect(&mut data, 4) {
                        break;
                    }
                    let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
                    self.buf.clear();
                    if len > NAME_MAX {
                        self.state = ParseState::Failed;
                        return Err(Error::Format("name too long".into()));
                    }
                    if len == 0 {
                        self.emit_header(String::new());
                    } else {
                        self.state = ParseState::Name { len };
                    }
                }
                ParseState::Name { len } => {
                    if !self.collect(&mut data, len) {
                        break;
                    }
                    let name = String::from_utf8(std::mem::take(&mut self.buf))
                        .map_err(|_| {
                            self.state = ParseState::Failed;
                            Error::Format("name not utf8".into())
                        })?;
                    self.emit_header(name);
                }
                ParseState::FrameLen => {
                    if !self.collect(&mut data, 4) {
                        break;
                    }
                    let len = u32::from_le_bytes(self.buf[..4].try_into().unwrap()) as usize;
                    self.buf.clear();
                    if len == 0 {
                        self.events.push_back(ReqEvent::End);
                        self.state = ParseState::Op;
                    } else if len > FRAME_MAX {
                        self.state = ParseState::Failed;
                        return Err(Error::Format(format!(
                            "wire frame of {len} bytes exceeds FRAME_MAX"
                        )));
                    } else {
                        self.state = ParseState::Frame { len };
                    }
                }
                ParseState::Frame { len } => {
                    if !self.collect(&mut data, len) {
                        break;
                    }
                    let frame = std::mem::take(&mut self.buf);
                    self.events.push_back(ReqEvent::Frame(frame));
                    self.state = ParseState::FrameLen;
                }
                ParseState::Failed => {
                    return Err(Error::Format("request stream previously errored".into()));
                }
            }
        }
        Ok(())
    }

    fn emit_header(&mut self, name: String) {
        self.events.push_back(ReqEvent::Header { op: self.op, name });
        self.state = ParseState::FrameLen;
    }

    /// Move up to `want - buf.len()` bytes from `data` into the partial
    /// buffer; `true` once the buffer holds `want` bytes.
    fn collect(&mut self, data: &mut &[u8], want: usize) -> bool {
        let need = want - self.buf.len();
        let take = need.min(data.len());
        self.buf.extend_from_slice(&data[..take]);
        *data = &data[take..];
        self.buf.len() == want
    }

    /// Next completed event, if any.
    pub fn take(&mut self) -> Option<ReqEvent> {
        self.events.pop_front()
    }

    /// True while the stream is inside a request (a truncated peer left a
    /// partial message) or undrained events remain. Between requests —
    /// idle keep-alive — this is `false`.
    pub fn mid_request(&self) -> bool {
        !matches!(self.state, ParseState::Op) || !self.buf.is_empty() || !self.events.is_empty()
    }

    /// Bytes currently buffered inside the parser (partial field/frame
    /// plus queued frame payloads) — bounded, asserted by tests.
    pub fn buffered(&self) -> usize {
        let queued: usize = self
            .events
            .iter()
            .map(|e| match e {
                ReqEvent::Frame(f) => f.len(),
                _ => 0,
            })
            .sum();
        self.buf.len() + queued
    }
}

// ---------------------------------------------------------------------------
// Request / response framing
// ---------------------------------------------------------------------------

/// Write a request's fixed header (opcode + name); the caller streams the
/// body through a [`ChunkedWriter`].
pub fn write_request_header(w: &mut impl Write, op: Op, name: &str) -> Result<()> {
    w.write_all(&[op as u8])?;
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name.as_bytes())?;
    Ok(())
}

/// Read a request's fixed header. Returns `(op, name)`; the body follows
/// as a chunked stream.
pub fn read_request_header(r: &mut impl Read) -> Result<(Op, String)> {
    let mut op_b = [0u8; 1];
    r.read_exact(&mut op_b)?;
    let op = Op::from_u8(op_b[0])
        .ok_or_else(|| Error::Format(format!("bad opcode {}", op_b[0])))?;
    Ok((op, read_name(r)?))
}

/// Read the length-prefixed request name (the header minus the opcode —
/// for servers that read the opcode byte separately while polling).
pub fn read_name(r: &mut impl Read) -> Result<String> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let name_len = u32::from_le_bytes(len4) as usize;
    if name_len > NAME_MAX {
        return Err(Error::Format("name too long".into()));
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    String::from_utf8(name).map_err(|_| Error::Format("name not utf8".into()))
}

/// Write a complete request with an in-memory payload (convenience for
/// small bodies; the streaming paths use [`write_request_header`] +
/// [`ChunkedWriter`] directly).
pub fn write_request(w: &mut impl Write, op: Op, name: &str, payload: &[u8]) -> Result<()> {
    write_request_header(w, op, name)?;
    let mut cw = ChunkedWriter::new(&mut *w);
    cw.write_all(payload)?;
    cw.finish()?;
    w.flush()?;
    Ok(())
}

/// Read a complete request, buffering the body. Returns `(op, name,
/// payload)`.
pub fn read_request(r: &mut impl Read) -> Result<(Op, String, Vec<u8>)> {
    let (op, name) = read_request_header(r)?;
    let mut body = ChunkedReader::new(&mut *r);
    let mut payload = Vec::new();
    body.read_to_end(&mut payload)?;
    Ok((op, name, payload))
}

/// Response status byte: request succeeded, body is the payload.
pub const STATUS_OK: u8 = 0;
/// Response status byte: request failed, body is the error message.
pub const STATUS_ERR: u8 = 1;
/// Response status byte: the server is at capacity and shed this
/// connection (written at accept time, before any request). The body is
/// empty and the connection is closed; retry after a backoff.
pub const STATUS_BUSY: u8 = 2;

/// The complete load-shed message a full server writes at accept time:
/// busy status + an empty chunked body (its terminator alone).
pub const BUSY_RESPONSE: [u8; 5] = [STATUS_BUSY, 0, 0, 0, 0];

/// Write a response's status byte; the caller streams the body through a
/// [`ChunkedWriter`].
pub fn write_response_header(w: &mut impl Write, ok: bool) -> Result<()> {
    w.write_all(&[if ok { STATUS_OK } else { STATUS_ERR }])?;
    Ok(())
}

/// Read a response's status byte. A [`STATUS_BUSY`] shed surfaces as
/// [`Error::Busy`] so clients can tell "retry later" from a real error.
pub fn read_response_header(r: &mut impl Read) -> Result<bool> {
    let mut status = [0u8; 1];
    r.read_exact(&mut status)?;
    match status[0] {
        STATUS_BUSY => Err(Error::Busy),
        s => Ok(s == STATUS_OK),
    }
}

/// Write a complete response with an in-memory payload.
pub fn write_response(w: &mut impl Write, ok: bool, payload: &[u8]) -> Result<()> {
    write_response_header(w, ok)?;
    let mut cw = ChunkedWriter::new(&mut *w);
    cw.write_all(payload)?;
    cw.finish()?;
    w.flush()?;
    Ok(())
}

/// Read a complete response, buffering the body; error status becomes
/// `Error::Format`.
pub fn read_response(r: &mut impl Read) -> Result<Vec<u8>> {
    let ok = read_response_header(r)?;
    let mut body = ChunkedReader::new(&mut *r);
    let mut payload = Vec::new();
    body.read_to_end(&mut payload)?;
    if !ok {
        return Err(Error::Format(format!(
            "hub error: {}",
            String::from_utf8_lossy(&payload)
        )));
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let mut buf = Vec::new();
        write_request(&mut buf, Op::Put, "model-a", b"payload").unwrap();
        let (op, name, payload) = read_request(&mut buf.as_slice()).unwrap();
        assert_eq!(op, Op::Put);
        assert_eq!(name, "model-a");
        assert_eq!(payload, b"payload");
    }

    #[test]
    fn response_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, true, b"data").unwrap();
        assert_eq!(read_response(&mut buf.as_slice()).unwrap(), b"data");
        let mut buf = Vec::new();
        write_response(&mut buf, false, b"nope").unwrap();
        assert!(read_response(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_bad_opcode_and_truncation() {
        assert!(read_request(&mut [9u8, 0, 0, 0, 0].as_slice()).is_err());
        let mut buf = Vec::new();
        write_request(&mut buf, Op::Get, "x", b"abc").unwrap();
        assert!(read_request(&mut buf[..buf.len() - 1].as_ref()).is_err());
    }

    #[test]
    fn large_bodies_split_into_bounded_frames() {
        let payload = vec![7u8; FRAME_MAX * 3 + 123];
        let mut buf = Vec::new();
        write_request(&mut buf, Op::Put, "big", &payload).unwrap();
        // wire frames after the 6+3 byte header: 3 full + 1 partial + end
        let (_, _, got) = read_request(&mut buf.as_slice()).unwrap();
        assert_eq!(got, payload);
        // frame-by-frame read sees bounded frames only
        let mut r = buf.as_slice();
        let (_, name) = read_request_header(&mut r).unwrap();
        assert_eq!(name, "big");
        let mut body = ChunkedReader::new(&mut r);
        let mut frame = Vec::new();
        let mut sizes = Vec::new();
        while body.read_frame(&mut frame).unwrap() {
            sizes.push(frame.len());
        }
        assert_eq!(sizes, vec![FRAME_MAX, FRAME_MAX, FRAME_MAX, 123]);
        assert_eq!(body.payload_len(), payload.len() as u64);
    }

    #[test]
    fn empty_body_is_just_a_terminator() {
        let mut buf = Vec::new();
        write_request(&mut buf, Op::List, "", b"").unwrap();
        let (op, name, payload) = read_request(&mut buf.as_slice()).unwrap();
        assert_eq!(op, Op::List);
        assert!(name.is_empty());
        assert!(payload.is_empty());
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf = Vec::new();
        buf.push(Op::Put as u8);
        buf.extend_from_slice(&0u32.to_le_bytes()); // empty name
        buf.extend_from_slice(&((FRAME_MAX + 1) as u32).to_le_bytes());
        buf.extend_from_slice(&vec![0u8; FRAME_MAX + 1]);
        buf.extend_from_slice(&0u32.to_le_bytes());
        assert!(read_request(&mut buf.as_slice()).is_err());
    }

    /// Collect all events of a fully-fed parser into (op, name, body,
    /// ended) for comparison across feed splits.
    fn collect_events(p: &mut RequestParser) -> (Vec<(Op, String)>, Vec<u8>, usize) {
        let mut headers = Vec::new();
        let mut body = Vec::new();
        let mut ends = 0;
        while let Some(ev) = p.take() {
            match ev {
                ReqEvent::Header { op, name } => headers.push((op, name)),
                ReqEvent::Frame(f) => body.extend_from_slice(&f),
                ReqEvent::End => ends += 1,
            }
        }
        (headers, body, ends)
    }

    #[test]
    fn resumable_parser_matches_blocking_reader() {
        let payload = vec![9u8; FRAME_MAX + 500];
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Put, "blob-x", &payload).unwrap();

        // One-shot feed.
        let mut p = RequestParser::new();
        p.feed(&wire).unwrap();
        let (headers, body, ends) = collect_events(&mut p);
        assert_eq!(headers, vec![(Op::Put, "blob-x".to_string())]);
        assert_eq!(body, payload);
        assert_eq!(ends, 1);
        assert!(!p.mid_request());

        // Byte-at-a-time feed produces identical events.
        let mut p = RequestParser::new();
        for b in &wire {
            p.feed(std::slice::from_ref(b)).unwrap();
        }
        let (headers, body, ends) = collect_events(&mut p);
        assert_eq!(headers, vec![(Op::Put, "blob-x".to_string())]);
        assert_eq!(body, payload);
        assert_eq!(ends, 1);
    }

    #[test]
    fn resumable_parser_handles_back_to_back_requests() {
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Get, "a", b"").unwrap();
        write_request(&mut wire, Op::Stat, "b", b"").unwrap();
        let mut p = RequestParser::new();
        p.feed(&wire).unwrap();
        let (headers, body, ends) = collect_events(&mut p);
        assert_eq!(
            headers,
            vec![(Op::Get, "a".to_string()), (Op::Stat, "b".to_string())]
        );
        assert!(body.is_empty());
        assert_eq!(ends, 2);
    }

    #[test]
    fn resumable_parser_rejects_bad_input_sticky() {
        // Bad opcode.
        let mut p = RequestParser::new();
        assert!(p.feed(&[9u8]).is_err());
        assert!(p.feed(&[0u8]).is_err(), "errors are sticky");

        // Oversized frame length.
        let mut p = RequestParser::new();
        let mut wire = vec![Op::Put as u8];
        wire.extend_from_slice(&0u32.to_le_bytes());
        wire.extend_from_slice(&((FRAME_MAX + 1) as u32).to_le_bytes());
        assert!(p.feed(&wire).is_err());

        // Oversized name length.
        let mut p = RequestParser::new();
        let mut wire = vec![Op::Get as u8];
        wire.extend_from_slice(&((NAME_MAX + 1) as u32).to_le_bytes());
        assert!(p.feed(&wire).is_err());
    }

    #[test]
    fn resumable_parser_truncation_is_not_an_error() {
        let mut wire = Vec::new();
        write_request(&mut wire, Op::Put, "t", b"abcdef").unwrap();
        let mut p = RequestParser::new();
        p.feed(&wire[..wire.len() - 1]).unwrap();
        // Header + frame may be out, but no End: the request is incomplete.
        let (_, _, ends) = collect_events(&mut p);
        assert_eq!(ends, 0);
        assert!(p.mid_request());
        // The missing byte completes it.
        p.feed(&wire[wire.len() - 1..]).unwrap();
        assert_eq!(p.take(), Some(ReqEvent::End));
        assert!(!p.mid_request());
    }

    #[test]
    fn drain_skips_unread_body() {
        let mut buf = Vec::new();
        let mut cw = ChunkedWriter::new(&mut buf);
        cw.write_all(&vec![1u8; FRAME_MAX + 10]).unwrap();
        cw.finish().unwrap();
        buf.push(0xEE); // next message after the body
        let mut r = buf.as_slice();
        let mut body = ChunkedReader::new(&mut r);
        let mut first = [0u8; 10];
        body.read_exact(&mut first).unwrap();
        body.drain().unwrap();
        assert_eq!(r, [0xEE]); // positioned exactly after the terminator
    }
}
