//! Length-prefixed binary protocol between hub client and server.
//!
//! ```text
//! request:  [op u8][name_len u32][name bytes][payload_len u64][payload]
//! response: [status u8][payload_len u64][payload]
//! ```
//! ops: 0 = PUT, 1 = GET, 2 = LIST, 3 = SHUTDOWN. status: 0 = OK, 1 = err
//! (payload is a UTF-8 message).

use crate::error::{Error, Result};
use std::io::{Read, Write};

/// Request opcode.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    /// Store a blob.
    Put = 0,
    /// Fetch a blob.
    Get = 1,
    /// List stored names (newline-joined payload).
    List = 2,
    /// Stop the server (tests / clean shutdown).
    Shutdown = 3,
}

impl Op {
    /// Parse an opcode byte.
    pub fn from_u8(v: u8) -> Option<Op> {
        match v {
            0 => Some(Op::Put),
            1 => Some(Op::Get),
            2 => Some(Op::List),
            3 => Some(Op::Shutdown),
            _ => None,
        }
    }
}

/// Write a request frame.
pub fn write_request(w: &mut impl Write, op: Op, name: &str, payload: &[u8]) -> Result<()> {
    w.write_all(&[op as u8])?;
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name.as_bytes())?;
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read a request frame. Returns `(op, name, payload)`.
pub fn read_request(r: &mut impl Read) -> Result<(Op, String, Vec<u8>)> {
    let mut op_b = [0u8; 1];
    r.read_exact(&mut op_b)?;
    let op = Op::from_u8(op_b[0])
        .ok_or_else(|| Error::Format(format!("bad opcode {}", op_b[0])))?;
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let name_len = u32::from_le_bytes(len4) as usize;
    if name_len > 4096 {
        return Err(Error::Format("name too long".into()));
    }
    let mut name = vec![0u8; name_len];
    r.read_exact(&mut name)?;
    let mut len8 = [0u8; 8];
    r.read_exact(&mut len8)?;
    let payload_len = u64::from_le_bytes(len8) as usize;
    let mut payload = vec![0u8; payload_len];
    r.read_exact(&mut payload)?;
    Ok((
        op,
        String::from_utf8(name).map_err(|_| Error::Format("name not utf8".into()))?,
        payload,
    ))
}

/// Write a response frame.
pub fn write_response(w: &mut impl Write, ok: bool, payload: &[u8]) -> Result<()> {
    w.write_all(&[if ok { 0 } else { 1 }])?;
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read a response frame; error status becomes `Error::Format`.
pub fn read_response(r: &mut impl Read) -> Result<Vec<u8>> {
    let mut status = [0u8; 1];
    r.read_exact(&mut status)?;
    let mut len8 = [0u8; 8];
    r.read_exact(&mut len8)?;
    let payload_len = u64::from_le_bytes(len8) as usize;
    let mut payload = vec![0u8; payload_len];
    r.read_exact(&mut payload)?;
    if status[0] != 0 {
        return Err(Error::Format(format!(
            "hub error: {}",
            String::from_utf8_lossy(&payload)
        )));
    }
    Ok(payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_roundtrip() {
        let mut buf = Vec::new();
        write_request(&mut buf, Op::Put, "model-a", b"payload").unwrap();
        let (op, name, payload) = read_request(&mut buf.as_slice()).unwrap();
        assert_eq!(op, Op::Put);
        assert_eq!(name, "model-a");
        assert_eq!(payload, b"payload");
    }

    #[test]
    fn response_roundtrip() {
        let mut buf = Vec::new();
        write_response(&mut buf, true, b"data").unwrap();
        assert_eq!(read_response(&mut buf.as_slice()).unwrap(), b"data");
        let mut buf = Vec::new();
        write_response(&mut buf, false, b"nope").unwrap();
        assert!(read_response(&mut buf.as_slice()).is_err());
    }

    #[test]
    fn rejects_bad_opcode_and_truncation() {
        assert!(read_request(&mut [9u8, 0, 0, 0, 0].as_slice()).is_err());
        let mut buf = Vec::new();
        write_request(&mut buf, Op::Get, "x", b"abc").unwrap();
        assert!(read_request(&mut buf[..buf.len() - 1].as_ref()).is_err());
    }
}
