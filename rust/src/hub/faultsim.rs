//! Deterministic network fault injection for the hub: an in-process TCP
//! proxy that sits between a real [`crate::hub::HubClient`] and a real
//! [`crate::hub::HubServer`] and injects mid-stream connection drops,
//! byte flips, read/write stalls, and truncations on a replayable
//! schedule.
//!
//! ## Shape
//!
//! [`FaultProxy::start`] binds an ephemeral loopback port and shuttles
//! every accepted connection to the upstream address through two relay
//! threads (one per direction). Faults trigger on **byte counts**, not
//! wall-clock time: each direction draws a gap from a seeded
//! [`Xoshiro256`] (same spirit as [`crate::hub::netsim`] — the schedule
//! is a pure function of `(seed, connection index, direction)`), so a
//! failing test replays exactly from its `ZIPNN_FAULT_PROFILE` /
//! `ZIPNN_FAULT_SEED` pair.
//!
//! Two invariants keep fault runs convergent instead of flaky:
//!
//! - **Stored data stays clean.** The client→server direction never
//!   flips or truncates bytes (a corrupted PUT would poison the store
//!   and no retry could ever succeed); random kinds drawn for upstream
//!   are remapped to drops/stalls.
//! - **The fault budget is global per proxy.** Once `max_faults` faults
//!   have been injected the remaining traffic flows clean, so a bounded
//!   retry policy always has a clean attempt available at the end.
//!
//! [`FaultProxy::start_scripted`] replaces the random schedule with an
//! explicit fault list consumed in order across connections — the
//! deterministic "≥3 drops + 1 corrupt frame" resilience test is built
//! on it.

use crate::util::Xoshiro256;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// The fault kinds the proxy can inject.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Sever the connection immediately (the in-flight buffer is lost).
    Drop,
    /// XOR one payload byte with `0x80` and keep relaying.
    Flip,
    /// Sleep the relay for the profile's stall duration, then continue.
    Stall,
    /// Forward a partial buffer, then sever the connection.
    Truncate,
}

/// One entry of a scripted fault schedule (server→client direction):
/// inject `kind` once the *current* connection has relayed `after_bytes`
/// downstream. Entries are consumed front-to-back across connections.
#[derive(Debug, Clone, Copy)]
pub struct ScriptedFault {
    /// Downstream bytes into the connection at which to inject.
    pub after_bytes: u64,
    /// What to inject.
    pub kind: FaultKind,
}

/// A named random-schedule shape: kind weights, byte gaps between
/// faults, stall duration, and the proxy-global fault budget.
#[derive(Debug, Clone, Copy)]
pub struct FaultProfile {
    /// Name matched against `ZIPNN_FAULT_PROFILE`.
    pub name: &'static str,
    /// Relative weight of [`FaultKind::Drop`].
    pub drop_w: u32,
    /// Relative weight of [`FaultKind::Flip`].
    pub flip_w: u32,
    /// Relative weight of [`FaultKind::Stall`].
    pub stall_w: u32,
    /// Relative weight of [`FaultKind::Truncate`].
    pub trunc_w: u32,
    /// Minimum relayed bytes between faults on one direction.
    pub min_gap: u64,
    /// Uniform extra gap on top of `min_gap`.
    pub gap_spread: u64,
    /// How long one [`FaultKind::Stall`] pauses the relay.
    pub stall_ms: u64,
    /// Proxy-global fault budget: once spent, traffic flows clean (this
    /// is what makes bounded retries converge).
    pub max_faults: u64,
}

/// Mostly connection drops: exercises reconnect + ranged tail resume.
pub const DROP_HEAVY: FaultProfile = FaultProfile {
    name: "drop-heavy",
    drop_w: 6,
    flip_w: 0,
    stall_w: 1,
    trunc_w: 1,
    min_gap: 192 * 1024,
    gap_spread: 64 * 1024,
    stall_ms: 40,
    max_faults: 5,
};

/// Mostly byte flips: exercises per-frame checksum rejection and the
/// targeted bad-frame refetch.
pub const CORRUPT_HEAVY: FaultProfile = FaultProfile {
    name: "corrupt-heavy",
    drop_w: 1,
    flip_w: 5,
    stall_w: 1,
    trunc_w: 1,
    min_gap: 160 * 1024,
    gap_spread: 64 * 1024,
    stall_ms: 30,
    max_faults: 5,
};

/// Mostly stalls: exercises timeout handling and goodput degradation
/// without hard failures.
pub const STALL_HEAVY: FaultProfile = FaultProfile {
    name: "stall-heavy",
    drop_w: 1,
    flip_w: 0,
    stall_w: 8,
    trunc_w: 0,
    min_gap: 96 * 1024,
    gap_spread: 32 * 1024,
    stall_ms: 120,
    max_faults: 8,
};

impl FaultProfile {
    /// Look a profile up by its `ZIPNN_FAULT_PROFILE` name.
    pub fn by_name(name: &str) -> Option<FaultProfile> {
        [DROP_HEAVY, CORRUPT_HEAVY, STALL_HEAVY]
            .into_iter()
            .find(|p| p.name == name)
    }
}

/// A replayable fault schedule: profile + seed.
#[derive(Debug, Clone, Copy)]
pub struct FaultSpec {
    /// Schedule shape.
    pub profile: FaultProfile,
    /// Deterministic schedule seed.
    pub seed: u64,
}

impl FaultSpec {
    /// Build a spec from `ZIPNN_FAULT_PROFILE` / `ZIPNN_FAULT_SEED`.
    /// `None` when no profile is set; an unknown profile name is also
    /// `None` (injection silently off beats failing every connect).
    pub fn from_env() -> Option<FaultSpec> {
        let profile = FaultProfile::by_name(&crate::util::env::fault_profile()?)?;
        Some(FaultSpec { profile, seed: crate::util::env::fault_seed().unwrap_or(1) })
    }
}

/// Shared counters: relayed bytes and injected faults by kind, plus the
/// remaining global budget (signed so concurrent decrements below zero
/// stay harmless).
#[derive(Default)]
struct FaultStats {
    bytes_up: AtomicU64,
    bytes_down: AtomicU64,
    drops: AtomicU64,
    flips: AtomicU64,
    stalls: AtomicU64,
    truncs: AtomicU64,
    budget: AtomicI64,
}

/// The per-direction fault schedule a relay thread consults.
enum Schedule {
    /// Profile-driven: seeded gaps and weighted kinds.
    Random {
        rng: Xoshiro256,
        profile: FaultProfile,
        next_at: u64,
        /// Server→client direction (the only one allowed to corrupt).
        down: bool,
        exhausted: bool,
    },
    /// Explicit fault list, shared by all connections, downstream only.
    Script {
        faults: Arc<Mutex<std::collections::VecDeque<ScriptedFault>>>,
        down: bool,
    },
}

impl Schedule {
    /// `Some((kind, stall_ms))` when a fault is due within the next
    /// `n`-byte buffer that starts at relayed offset `seen`, plus the
    /// in-buffer index to apply it at.
    fn due(&mut self, seen: u64, n: u64, stats: &FaultStats) -> Option<(FaultKind, u64, usize)> {
        match self {
            Schedule::Random { rng, profile, next_at, down, exhausted } => {
                if *exhausted || seen + n <= *next_at {
                    return None;
                }
                if stats.budget.fetch_sub(1, Ordering::Relaxed) <= 0 {
                    *exhausted = true;
                    return None;
                }
                let at = *next_at;
                let idx = at.saturating_sub(seen).min(n - 1) as usize;
                let mut kind = draw_kind(rng, profile);
                if !*down {
                    // Upstream must never corrupt stored data.
                    kind = match kind {
                        FaultKind::Flip => FaultKind::Stall,
                        FaultKind::Truncate => FaultKind::Drop,
                        k => k,
                    };
                }
                *next_at = at + profile.min_gap + rng.next_u64() % (profile.gap_spread + 1);
                Some((kind, profile.stall_ms, idx))
            }
            Schedule::Script { faults, down } => {
                if !*down {
                    return None;
                }
                let mut q = faults.lock().unwrap();
                match q.front() {
                    Some(f) if seen + n > f.after_bytes => {
                        let f = q.pop_front().expect("front exists");
                        let idx = f.after_bytes.saturating_sub(seen).min(n - 1) as usize;
                        Some((f.kind, 50, idx))
                    }
                    _ => None,
                }
            }
        }
    }
}

/// Weighted kind draw (weights sum > 0 for every built-in profile).
fn draw_kind(rng: &mut Xoshiro256, p: &FaultProfile) -> FaultKind {
    let total = p.drop_w + p.flip_w + p.stall_w + p.trunc_w;
    if total == 0 {
        return FaultKind::Stall;
    }
    let mut x = (rng.next_u64() % total as u64) as u32;
    if x < p.drop_w {
        return FaultKind::Drop;
    }
    x -= p.drop_w;
    if x < p.flip_w {
        return FaultKind::Flip;
    }
    x -= p.flip_w;
    if x < p.stall_w {
        return FaultKind::Stall;
    }
    FaultKind::Truncate
}

enum Plan {
    Random { seed: u64, profile: FaultProfile },
    Script(Arc<Mutex<std::collections::VecDeque<ScriptedFault>>>),
}

impl Plan {
    fn schedule(&self, conn_id: u64, down: bool) -> Schedule {
        match self {
            Plan::Random { seed, profile } => {
                // splitmix-style stream split so every (connection,
                // direction) pair gets an independent deterministic gap
                // sequence from one user-facing seed.
                let stream = conn_id * 2 + down as u64;
                let mut rng =
                    Xoshiro256::seed_from_u64(seed ^ stream.wrapping_mul(0x9E37_79B9_7F4A_7C15));
                let next_at = profile.min_gap + rng.next_u64() % (profile.gap_spread + 1);
                Schedule::Random { rng, profile: *profile, next_at, down, exhausted: false }
            }
            Plan::Script(faults) => Schedule::Script { faults: Arc::clone(faults), down },
        }
    }
}

/// An in-process fault-injecting TCP proxy in front of a hub server.
pub struct FaultProxy {
    addr: String,
    stop: Arc<AtomicBool>,
    stats: Arc<FaultStats>,
    accept: Option<JoinHandle<()>>,
}

impl FaultProxy {
    /// Start a proxy with a profile-driven random schedule.
    pub fn start(upstream: &str, spec: FaultSpec) -> std::io::Result<FaultProxy> {
        FaultProxy::launch(
            upstream,
            Plan::Random { seed: spec.seed, profile: spec.profile },
            spec.profile.max_faults,
        )
    }

    /// Start a proxy that injects exactly `faults`, in order, on the
    /// server→client direction (client→server stays clean).
    pub fn start_scripted(
        upstream: &str,
        faults: Vec<ScriptedFault>,
    ) -> std::io::Result<FaultProxy> {
        let n = faults.len() as u64;
        FaultProxy::launch(
            upstream,
            Plan::Script(Arc::new(Mutex::new(faults.into_iter().collect()))),
            n,
        )
    }

    fn launch(upstream: &str, plan: Plan, budget: u64) -> std::io::Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(FaultStats::default());
        stats.budget.store(budget as i64, Ordering::Relaxed);
        let upstream = upstream.to_string();
        let accept = {
            let stop = Arc::clone(&stop);
            let stats = Arc::clone(&stats);
            std::thread::spawn(move || accept_loop(listener, &upstream, plan, stats, stop))
        };
        Ok(FaultProxy { addr, stop, stats, accept: Some(accept) })
    }

    /// Address clients connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Server→client bytes relayed (includes protocol framing).
    pub fn bytes_down(&self) -> u64 {
        self.stats.bytes_down.load(Ordering::Relaxed)
    }

    /// Client→server bytes relayed.
    pub fn bytes_up(&self) -> u64 {
        self.stats.bytes_up.load(Ordering::Relaxed)
    }

    /// Injected fault counts `(drops, flips, stalls, truncations)`.
    pub fn fault_counts(&self) -> (u64, u64, u64, u64) {
        (
            self.stats.drops.load(Ordering::Relaxed),
            self.stats.flips.load(Ordering::Relaxed),
            self.stats.stalls.load(Ordering::Relaxed),
            self.stats.truncs.load(Ordering::Relaxed),
        )
    }

    /// Total faults injected so far.
    pub fn faults_injected(&self) -> u64 {
        let (d, f, s, t) = self.fault_counts();
        d + f + s + t
    }

    /// Stop accepting and wind the relay threads down.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for FaultProxy {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: TcpListener,
    upstream: &str,
    plan: Plan,
    stats: Arc<FaultStats>,
    stop: Arc<AtomicBool>,
) {
    let mut conn_id = 0u64;
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        match listener.accept() {
            Ok((client, _)) => {
                conn_id += 1;
                let Ok(server) = TcpStream::connect(upstream) else {
                    // Upstream gone: refuse by closing; the client's
                    // retry policy handles it like any other drop.
                    continue;
                };
                let _ = client.set_nodelay(true);
                let _ = server.set_nodelay(true);
                let (Ok(c2), Ok(s2)) = (client.try_clone(), server.try_clone()) else {
                    continue;
                };
                let up = plan.schedule(conn_id, false);
                let down = plan.schedule(conn_id, true);
                {
                    let (stats, stop) = (Arc::clone(&stats), Arc::clone(&stop));
                    std::thread::spawn(move || relay(client, server, false, up, stats, stop));
                }
                {
                    let (stats, stop) = (Arc::clone(&stats), Arc::clone(&stop));
                    std::thread::spawn(move || relay(s2, c2, true, down, stats, stop));
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => break,
        }
    }
}

/// Shuttle bytes `from` → `to`, applying the schedule's faults. Exits on
/// EOF, socket error, an injected severance, or the proxy stop flag.
fn relay(
    mut from: TcpStream,
    mut to: TcpStream,
    down: bool,
    mut sched: Schedule,
    stats: Arc<FaultStats>,
    stop: Arc<AtomicBool>,
) {
    // Short read timeout so the thread notices the stop flag promptly.
    let _ = from.set_read_timeout(Some(Duration::from_millis(100)));
    let mut buf = [0u8; 16 * 1024];
    let mut seen = 0u64;
    loop {
        if stop.load(Ordering::Relaxed) {
            break;
        }
        let n = match from.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => break,
        };
        let counter = if down { &stats.bytes_down } else { &stats.bytes_up };
        counter.fetch_add(n as u64, Ordering::Relaxed);
        // `Some(keep)`: forward `keep` bytes of this buffer, then sever.
        let mut sever = None;
        if let Some((kind, stall_ms, idx)) = sched.due(seen, n as u64, &stats) {
            match kind {
                FaultKind::Stall => {
                    stats.stalls.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(Duration::from_millis(stall_ms));
                }
                FaultKind::Flip => {
                    stats.flips.fetch_add(1, Ordering::Relaxed);
                    buf[idx] ^= 0x80;
                }
                FaultKind::Drop => {
                    stats.drops.fetch_add(1, Ordering::Relaxed);
                    sever = Some(0);
                }
                FaultKind::Truncate => {
                    stats.truncs.fetch_add(1, Ordering::Relaxed);
                    sever = Some(idx);
                }
            }
        }
        seen += n as u64;
        match sever {
            Some(keep) => {
                if keep > 0 {
                    let _ = to.write_all(&buf[..keep]);
                    let _ = to.flush();
                }
                break;
            }
            None => {
                if to.write_all(&buf[..n]).is_err() {
                    break;
                }
            }
        }
    }
    // Sever both directions so the peer sees a clean EOF/reset rather
    // than a half-open connection.
    let _ = to.shutdown(Shutdown::Both);
    let _ = from.shutdown(Shutdown::Both);
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A trivial upstream echo server for proxy unit tests.
    fn echo_server() -> (String, JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let h = std::thread::spawn(move || {
            // Serve a bounded number of connections, then exit.
            for _ in 0..8 {
                let Ok((mut s, _)) = listener.accept() else { return };
                std::thread::spawn(move || {
                    let mut buf = [0u8; 4096];
                    loop {
                        match s.read(&mut buf) {
                            Ok(0) | Err(_) => break,
                            Ok(n) => {
                                if s.write_all(&buf[..n]).is_err() {
                                    break;
                                }
                            }
                        }
                    }
                });
            }
        });
        (addr, h)
    }

    #[test]
    fn clean_relay_when_budget_zero() {
        let (addr, _h) = echo_server();
        let proxy = FaultProxy::start_scripted(&addr, Vec::new()).unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let msg = vec![0xA5u8; 100_000];
        c.write_all(&msg).unwrap();
        let mut back = vec![0u8; msg.len()];
        c.read_exact(&mut back).unwrap();
        assert_eq!(back, msg);
        assert_eq!(proxy.faults_injected(), 0);
        assert!(proxy.bytes_up() >= msg.len() as u64);
        assert!(proxy.bytes_down() >= msg.len() as u64);
    }

    #[test]
    fn scripted_flip_corrupts_exactly_one_byte() {
        let (addr, _h) = echo_server();
        let proxy = FaultProxy::start_scripted(
            &addr,
            vec![ScriptedFault { after_bytes: 1000, kind: FaultKind::Flip }],
        )
        .unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let msg = vec![0u8; 50_000];
        c.write_all(&msg).unwrap();
        let mut back = vec![0u8; msg.len()];
        c.read_exact(&mut back).unwrap();
        let flipped: Vec<usize> =
            (0..back.len()).filter(|&i| back[i] != msg[i]).collect();
        assert_eq!(flipped.len(), 1, "exactly one byte flipped");
        assert_eq!(back[flipped[0]], 0x80);
        assert_eq!(proxy.fault_counts(), (0, 1, 0, 0));
    }

    #[test]
    fn scripted_drop_severs_connection() {
        let (addr, _h) = echo_server();
        let proxy = FaultProxy::start_scripted(
            &addr,
            vec![ScriptedFault { after_bytes: 10_000, kind: FaultKind::Drop }],
        )
        .unwrap();
        let mut c = TcpStream::connect(proxy.addr()).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        let msg = vec![7u8; 200_000];
        // The echo may die mid-write; both halves eventually error.
        let _ = c.write_all(&msg);
        let mut back = Vec::new();
        let res = c.read_to_end(&mut back);
        // Either an error or a short read — never the full echo.
        assert!(res.is_err() || back.len() < msg.len());
        let (drops, _, _, _) = proxy.fault_counts();
        assert_eq!(drops, 1);
    }

    #[test]
    fn random_schedule_is_deterministic() {
        let spec = FaultSpec { profile: DROP_HEAVY, seed: 42 };
        let mk = || {
            let mut s = Plan::Random { seed: spec.seed, profile: spec.profile }.schedule(1, true);
            let stats = FaultStats::default();
            stats.budget.store(100, Ordering::Relaxed);
            let mut hits = Vec::new();
            let mut seen = 0u64;
            for _ in 0..64 {
                if let Some((kind, _, idx)) = s.due(seen, 64 * 1024, &stats) {
                    hits.push((seen, kind, idx));
                }
                seen += 64 * 1024;
            }
            hits
        };
        let a = mk();
        let b = mk();
        assert_eq!(a.len(), b.len());
        assert!(!a.is_empty(), "drop-heavy must fire within 4 MiB");
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.0, y.0);
            assert_eq!(x.1, y.1);
            assert_eq!(x.2, y.2);
        }
    }

    #[test]
    fn env_spec_parses_known_profiles() {
        assert_eq!(FaultProfile::by_name("drop-heavy").unwrap().name, "drop-heavy");
        assert_eq!(FaultProfile::by_name("corrupt-heavy").unwrap().name, "corrupt-heavy");
        assert_eq!(FaultProfile::by_name("stall-heavy").unwrap().name, "stall-heavy");
        assert!(FaultProfile::by_name("nope").is_none());
    }
}
