//! Model-hub simulator (paper §2.1.1 and §5.3, Fig. 10).
//!
//! A real in-process hub: server and client speak a length-prefixed binary
//! protocol over loopback TCP, models are stored compressed or raw, and
//! end-to-end upload/download timing combines *measured*
//! compression/decompression time with *simulated* WAN transfer time from
//! the paper's measured bandwidth regimes (Hugging Face is not reachable
//! from this environment; see DESIGN.md §2 Substitutions).
//!
//! The server is readiness-driven: a single reactor thread multiplexes
//! every connection over epoll ([`sys`]), per-connection state machines
//! resume the chunked frame codec from partial reads/writes via the
//! [`RequestParser`], and a fixed ≈ncpu worker pool executes ready
//! requests — idle keep-alive connections cost no threads.

pub mod client;
pub mod cluster;
pub(crate) mod conn;
pub mod faultsim;
pub mod fleet;
pub mod netsim;
pub mod protocol;
pub(crate) mod reactor;
pub mod repair;
pub mod server;
pub mod store;
pub mod sys;

pub use client::{HubClient, RetryPolicy, TensorFetch, TransferReport};
pub use cluster::{moved_blobs, HashRing};
pub use faultsim::{FaultKind, FaultProfile, FaultProxy, FaultSpec, ScriptedFault};
pub use fleet::{Fleet, FleetClient, FleetConfig, FleetReport, RebalanceReport, RepairReport};
pub use netsim::{BANDWIDTH_FLOOR_MB_S, NetProfile, NetSim};
pub use protocol::{encode_range, parse_range, Op, ReqEvent, RequestParser, FRAME_MAX, NAME_MAX};
pub use repair::{ClusterConfig, RepairCounters};
pub use server::{HubServer, HubServerBuilder};
pub use store::{PersistStore, RecoveryReport};
