//! Model-hub simulator (paper §2.1.1 and §5.3, Fig. 10).
//!
//! A real in-process hub: server and client speak a length-prefixed binary
//! protocol over loopback TCP, models are stored compressed or raw, and
//! end-to-end upload/download timing combines *measured*
//! compression/decompression time with *simulated* WAN transfer time from
//! the paper's measured bandwidth regimes (Hugging Face is not reachable
//! from this environment; see DESIGN.md §2 Substitutions).

pub mod client;
pub mod netsim;
pub mod protocol;
pub mod server;

pub use client::{HubClient, TransferReport};
pub use netsim::{NetProfile, NetSim};
pub use protocol::FRAME_MAX;
pub use server::HubServer;
