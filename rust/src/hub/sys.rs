//! Thin readiness-polling shim over the platform's C library.
//!
//! The hub reactor needs exactly four operations — register, modify,
//! deregister, wait — so instead of pulling in a dependency this module
//! declares the handful of `libc` symbols it needs directly (the C
//! library is already linked by `std`). Linux gets an **epoll** backend
//! (O(ready) wakeups, the production path); every other Unix gets a
//! portable **poll(2)** backend with the same interface.
//!
//! Both backends are **level-triggered**: an fd keeps reporting ready
//! until the condition is consumed, so the reactor never misses an edge
//! after a partial read/write.

/// One readiness notification.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// The token the fd was registered with.
    pub token: u64,
    /// Readable (or a peer hang-up, which reads as EOF).
    pub readable: bool,
    /// Writable.
    pub writable: bool,
    /// Error/hang-up condition; the owner should drive the fd and let the
    /// resulting `Err`/EOF close it.
    pub error: bool,
}

/// Readiness interest for a registered fd.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interest {
    /// Wake when readable.
    pub read: bool,
    /// Wake when writable.
    pub write: bool,
}

impl Interest {
    /// Read-only interest.
    pub const READ: Interest = Interest { read: true, write: false };
    /// Write-only interest.
    pub const WRITE: Interest = Interest { read: false, write: true };
    /// No wakeups (the fd stays registered; errors still surface).
    pub const NONE: Interest = Interest { read: false, write: false };
}

#[cfg(target_os = "linux")]
pub use epoll::Poller;

#[cfg(all(unix, not(target_os = "linux")))]
pub use pollfd::Poller;

#[cfg(not(unix))]
compile_error!("the hub reactor needs a Unix readiness API (epoll/poll)");

#[cfg(target_os = "linux")]
mod epoll {
    use super::{Event, Interest};
    use std::io;
    use std::os::unix::io::RawFd;

    const EPOLLIN: u32 = 0x001;
    const EPOLLOUT: u32 = 0x004;
    const EPOLLERR: u32 = 0x008;
    const EPOLLHUP: u32 = 0x010;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0o2000000;

    /// Mirror of the kernel's `struct epoll_event`. x86-64 packs it to
    /// match the 32-bit layout; other architectures use natural alignment.
    #[repr(C)]
    #[cfg_attr(target_arch = "x86_64", repr(packed))]
    #[derive(Clone, Copy)]
    struct EpollEvent {
        events: u32,
        data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    /// epoll-backed readiness poller.
    pub struct Poller {
        epfd: RawFd,
        buf: Vec<EpollEvent>,
    }

    impl Poller {
        /// Create the epoll instance.
        pub fn new() -> io::Result<Poller> {
            let epfd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if epfd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Poller {
                epfd,
                buf: vec![EpollEvent { events: 0, data: 0 }; 1024],
            })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            let mut ev = EpollEvent { events: events_of(interest), data: token };
            let arg = if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev };
            if unsafe { epoll_ctl(self.epfd, op, fd, arg) } < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Register `fd` under `token`.
        pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        /// Change a registered fd's interest.
        pub fn reregister(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        /// Remove a registered fd.
        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, Interest::NONE)
        }

        /// Wait up to `timeout_ms` for readiness; fills `out` (cleared
        /// first). A signal interruption returns with `out` empty.
        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            out.clear();
            let n = unsafe {
                epoll_wait(self.epfd, self.buf.as_mut_ptr(), self.buf.len() as i32, timeout_ms)
            };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            for ev in &self.buf[..n as usize] {
                let bits = ev.events;
                out.push(Event {
                    token: ev.data,
                    readable: bits & (EPOLLIN | EPOLLHUP) != 0,
                    writable: bits & EPOLLOUT != 0,
                    error: bits & (EPOLLERR | EPOLLHUP) != 0,
                });
            }
            Ok(())
        }
    }

    impl Drop for Poller {
        fn drop(&mut self) {
            unsafe {
                close(self.epfd);
            }
        }
    }

    fn events_of(interest: Interest) -> u32 {
        let mut bits = 0;
        if interest.read {
            bits |= EPOLLIN;
        }
        if interest.write {
            bits |= EPOLLOUT;
        }
        bits
    }
}

#[cfg(all(unix, not(target_os = "linux")))]
mod pollfd {
    use super::{Event, Interest};
    use std::io;
    use std::os::unix::io::RawFd;

    const POLLIN: i16 = 0x001;
    const POLLOUT: i16 = 0x004;
    const POLLERR: i16 = 0x008;
    const POLLHUP: i16 = 0x010;
    const POLLNVAL: i16 = 0x020;

    /// Mirror of the portable `struct pollfd`.
    #[repr(C)]
    #[derive(Clone, Copy)]
    struct PollFd {
        fd: i32,
        events: i16,
        revents: i16,
    }

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: usize, timeout: i32) -> i32;
    }

    /// poll(2)-backed fallback: keeps the registration list in user space
    /// and rebuilds the pollfd array per wait. O(registered) per call, but
    /// portable everywhere.
    pub struct Poller {
        regs: Vec<(RawFd, u64, Interest)>,
        fds: Vec<PollFd>,
    }

    impl Poller {
        /// Create the poller.
        pub fn new() -> io::Result<Poller> {
            Ok(Poller { regs: Vec::new(), fds: Vec::new() })
        }

        /// Register `fd` under `token`.
        pub fn register(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            if self.regs.iter().any(|(f, _, _)| *f == fd) {
                return Err(io::Error::new(io::ErrorKind::AlreadyExists, "fd registered"));
            }
            self.regs.push((fd, token, interest));
            Ok(())
        }

        /// Change a registered fd's interest.
        pub fn reregister(&mut self, fd: RawFd, token: u64, interest: Interest) -> io::Result<()> {
            for r in &mut self.regs {
                if r.0 == fd {
                    r.1 = token;
                    r.2 = interest;
                    return Ok(());
                }
            }
            Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"))
        }

        /// Remove a registered fd.
        pub fn deregister(&mut self, fd: RawFd) -> io::Result<()> {
            let before = self.regs.len();
            self.regs.retain(|(f, _, _)| *f != fd);
            if self.regs.len() == before {
                return Err(io::Error::new(io::ErrorKind::NotFound, "fd not registered"));
            }
            Ok(())
        }

        /// Wait up to `timeout_ms` for readiness; fills `out` (cleared
        /// first). A signal interruption returns with `out` empty.
        pub fn wait(&mut self, out: &mut Vec<Event>, timeout_ms: i32) -> io::Result<()> {
            out.clear();
            self.fds.clear();
            for (fd, _, interest) in &self.regs {
                let mut events = 0;
                if interest.read {
                    events |= POLLIN;
                }
                if interest.write {
                    events |= POLLOUT;
                }
                self.fds.push(PollFd { fd: *fd, events, revents: 0 });
            }
            let n = unsafe { poll(self.fds.as_mut_ptr(), self.fds.len(), timeout_ms) };
            if n < 0 {
                let err = io::Error::last_os_error();
                if err.kind() == io::ErrorKind::Interrupted {
                    return Ok(());
                }
                return Err(err);
            }
            for (pfd, (_, token, _)) in self.fds.iter().zip(&self.regs) {
                let r = pfd.revents;
                if r == 0 {
                    continue;
                }
                out.push(Event {
                    token: *token,
                    readable: r & (POLLIN | POLLHUP) != 0,
                    writable: r & POLLOUT != 0,
                    error: r & (POLLERR | POLLHUP | POLLNVAL) != 0,
                });
            }
            Ok(())
        }
    }
}

/// Best-effort raise of the process's open-file soft limit toward `want`
/// (capped at the hard limit). Returns the (possibly unchanged) soft
/// limit. Used by stress tests that hold thousands of sockets; failure is
/// not an error — callers scale their connection count to the result.
pub fn raise_nofile_limit(want: u64) -> u64 {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    // RLIMIT_NOFILE is 7 on Linux and 8 on the BSDs/macOS.
    #[cfg(target_os = "linux")]
    const RLIMIT_NOFILE: i32 = 7;
    #[cfg(all(unix, not(target_os = "linux")))]
    const RLIMIT_NOFILE: i32 = 8;
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    let mut lim = RLimit { cur: 0, max: 0 };
    if unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) } != 0 {
        return 1024;
    }
    if lim.cur >= want {
        return lim.cur;
    }
    let new = RLimit { cur: want.min(lim.max), max: lim.max };
    if unsafe { setrlimit(RLIMIT_NOFILE, &new) } == 0 {
        new.cur
    } else {
        lim.cur
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{Read, Write};
    use std::net::{TcpListener, TcpStream};
    use std::os::unix::io::AsRawFd;

    #[test]
    fn poller_reports_readable_and_writable() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut a = TcpStream::connect(addr).unwrap();
        let (mut b, _) = listener.accept().unwrap();
        a.set_nonblocking(true).unwrap();
        b.set_nonblocking(true).unwrap();

        let mut poller = Poller::new().unwrap();
        poller.register(b.as_raw_fd(), 7, Interest::READ).unwrap();

        // Nothing to read yet: a short wait times out empty.
        let mut events = Vec::new();
        poller.wait(&mut events, 10).unwrap();
        assert!(events.iter().all(|e| e.token != 7 || !e.readable));

        // Peer writes -> readable fires with our token.
        a.write_all(b"ping").unwrap();
        let mut got = false;
        for _ in 0..100 {
            poller.wait(&mut events, 100).unwrap();
            if events.iter().any(|e| e.token == 7 && e.readable) {
                got = true;
                break;
            }
        }
        assert!(got, "readable event never arrived");
        let mut buf = [0u8; 8];
        let n = b.read(&mut buf).unwrap();
        assert_eq!(&buf[..n], b"ping");

        // Write interest on an idle socket is immediately ready.
        poller.reregister(b.as_raw_fd(), 7, Interest::WRITE).unwrap();
        poller.wait(&mut events, 1000).unwrap();
        assert!(events.iter().any(|e| e.token == 7 && e.writable));

        poller.deregister(b.as_raw_fd()).unwrap();
        poller.wait(&mut events, 10).unwrap();
        assert!(events.is_empty());
    }

    #[test]
    fn raise_nofile_returns_plausible_limit() {
        let lim = raise_nofile_limit(256);
        assert!(lim >= 256 || lim > 0);
    }
}
