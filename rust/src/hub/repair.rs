//! Self-healing fleet repair: the server-to-server loop that keeps every
//! blob at full replication without a client driving it.
//!
//! Each hub in a fleet runs one repair thread (see
//! [`HubServer::enable_repair`](crate::hub::server::HubServer::enable_repair)).
//! A round works entirely from the hub's own view of the cluster:
//!
//! 1. **Probe** — ping every other member ([`crate::hub::protocol::Op::Ping`],
//!    short timeout, no retries). Only peers that answer are trusted for the
//!    rest of the round; a dead peer's replicas are exactly what repair
//!    exists to re-create elsewhere.
//! 2. **Inventory** — `List` each live peer and union with the local store.
//! 3. **Pull** — for every name this hub owns on the ring but doesn't hold
//!    (a scrubber quarantined it, a disk died, the ring changed), fetch it
//!    from a live holder, verify length + whole-blob checksum against the
//!    holder's `Stat`, and commit through the same
//!    [`store_blob`](crate::hub::server::store_blob) path a PUT uses — so a
//!    persisted hub makes the repaired copy durable before counting it.
//! 4. **Drop** — for every name this hub holds but no longer owns, delete
//!    the local copy *only after* re-statting it on every ring replica in
//!    the same round and checking each replica's length + whole-blob
//!    checksum against the local copy. Stale copies are garbage, but they
//!    are also the last line of defence while the real replicas are
//!    degraded — never drop a byte that isn't provably held, bit-for-bit,
//!    everywhere it belongs.
//!
//! Every per-name failure is skipped, not retried: the next round sees the
//! same gap and tries again. Repair therefore converges (each round only
//! adds verified replicas and removes provably-redundant ones) and is
//! idempotent across hubs — two hubs repairing the same blob concurrently
//! just both end up holding it, which is the goal.

use std::collections::HashSet;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::codec::stream::Checksummer;
use crate::hub::client::{HubClient, RetryPolicy};
use crate::hub::cluster::HashRing;
use crate::hub::protocol::FRAME_MAX;
use crate::hub::server::{store_blob, ServerCtx};
use crate::hub::store::sleep_until;

/// How long a repair round waits on any single peer socket operation.
/// Repair runs in the background against peers that may be mid-crash;
/// a short timeout keeps one wedged peer from stalling the whole round.
const PEER_TIMEOUT: Duration = Duration::from_secs(2);

/// Static cluster view a repairing hub works from: its own identity, the
/// full membership (id → address), and the ring parameters every member
/// must agree on for ownership decisions to line up.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// This hub's node id — must appear in `members`.
    pub self_id: String,
    /// All fleet members as `(node_id, host:port)`, including this hub.
    pub members: Vec<(String, String)>,
    /// Ring replication factor R.
    pub replication: usize,
    /// Virtual nodes per member (all members must use the same value).
    pub vnodes: u32,
}

impl ClusterConfig {
    /// Cluster view with the default vnode count.
    pub fn new(self_id: &str, members: Vec<(String, String)>, replication: usize) -> ClusterConfig {
        ClusterConfig {
            self_id: self_id.to_string(),
            members,
            replication,
            vnodes: crate::hub::cluster::DEFAULT_VNODES,
        }
    }

    fn ring(&self) -> HashRing {
        let mut ring = HashRing::with_vnodes(self.replication, self.vnodes);
        for (id, _) in &self.members {
            ring.add_node(id);
        }
        ring
    }
}

/// What the repair loop has done so far. Tests (and the CLI) read these to
/// prove re-replication was server-driven: a pull counted here happened
/// with no client in the loop.
#[derive(Debug, Default)]
pub struct RepairCounters {
    rounds: AtomicU64,
    pulled: AtomicU64,
    dropped: AtomicU64,
    skipped: AtomicU64,
}

impl RepairCounters {
    /// Completed repair rounds.
    pub fn rounds(&self) -> u64 {
        self.rounds.load(Ordering::Relaxed)
    }

    /// Blobs this hub fetched from a peer and stored because the ring says
    /// it should hold them.
    pub fn pulled(&self) -> u64 {
        self.pulled.load(Ordering::Relaxed)
    }

    /// Stale local copies dropped after every ring replica verified.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Per-name actions abandoned this far (peer unreachable, verify
    /// failed, replica set degraded) — retried on a later round.
    pub fn skipped(&self) -> u64 {
        self.skipped.load(Ordering::Relaxed)
    }
}

/// Live peer handle for one repair round: an open connection plus the
/// blob names it reported.
struct Peer {
    client: HubClient,
    inventory: Vec<String>,
}

/// Background repair thread body. Sleeps `interval`, runs a round,
/// repeats until `stop`. The first round is delayed one interval so a
/// freshly-started fleet finishes binding all members before anyone
/// starts comparing inventories.
pub(crate) fn repair_loop(
    ctx: Arc<ServerCtx>,
    cluster: ClusterConfig,
    interval: Duration,
    stop: Arc<AtomicBool>,
    counters: Arc<RepairCounters>,
) {
    loop {
        sleep_until(&stop, interval);
        if stop.load(Ordering::Relaxed) {
            return;
        }
        repair_round(&ctx, &cluster, &counters);
        counters.rounds.fetch_add(1, Ordering::Relaxed);
    }
}

/// One full probe → inventory → pull → drop pass. Public in the crate so
/// the CLI can run a single client-driven round synchronously.
pub(crate) fn repair_round(ctx: &ServerCtx, cluster: &ClusterConfig, counters: &RepairCounters) {
    let ring = cluster.ring();
    let mut peers: Vec<(String, Peer)> = Vec::new();
    for (id, addr) in &cluster.members {
        if *id == cluster.self_id {
            continue;
        }
        if let Some(peer) = probe_peer(addr) {
            peers.push((id.clone(), peer));
        }
    }

    // Union of every name anyone in the (reachable) fleet holds.
    let local: HashSet<String> = {
        let map = ctx.store.lock().unwrap();
        map.keys().cloned().collect()
    };
    let mut names: Vec<String> = local.iter().cloned().collect();
    for (_, peer) in &peers {
        names.extend(peer.inventory.iter().cloned());
    }
    names.sort();
    names.dedup();

    for name in &names {
        if ctx.stop.load(Ordering::Relaxed) {
            return;
        }
        let replicas = ring.replicas_for(name);
        let owned = replicas.iter().any(|r| *r == cluster.self_id);
        let held = local.contains(name);
        if owned && !held {
            match pull_blob(ctx, name, &mut peers) {
                Ok(true) => counters.pulled.fetch_add(1, Ordering::Relaxed),
                Ok(false) => counters.skipped.fetch_add(1, Ordering::Relaxed),
                Err(_) => counters.skipped.fetch_add(1, Ordering::Relaxed),
            };
        } else if !owned && held {
            // The local copy's identity (length + whole-blob checksum) is
            // what every replica must match before it may be dropped.
            let local_meta = ctx
                .store
                .lock()
                .unwrap()
                .get(name)
                .map(|b| (b.total, b.ck));
            let safe = match local_meta {
                Some((total, ck)) => drop_is_safe(name, total, ck, &replicas, &mut peers),
                None => false, // vanished mid-round (scrubber, Delete)
            };
            if safe {
                if let Some(p) = &ctx.persist {
                    let _commit = p.commit_lock(name);
                    ctx.store.lock().unwrap().remove(name);
                    p.remove(name);
                } else {
                    ctx.store.lock().unwrap().remove(name);
                }
                counters.dropped.fetch_add(1, Ordering::Relaxed);
            } else {
                counters.skipped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Connect + ping + list one member. `None` means the peer is dead or
/// unresponsive this round — its inventory is unknowable and nothing is
/// pulled from or verified against it.
fn probe_peer(addr: &str) -> Option<Peer> {
    let mut client = HubClient::connect_direct(addr)
        .and_then(|c| c.with_timeout(PEER_TIMEOUT))
        .ok()?
        .with_retry_policy(RetryPolicy::none());
    client.ping().ok()?;
    let inventory = client.list().ok()?;
    Some(Peer { client, inventory })
}

/// Fetch `name` from the first live peer that holds it, verify, and store
/// it the way a PUT would. `Ok(false)` = nobody reachable holds it.
fn pull_blob(
    ctx: &ServerCtx,
    name: &str,
    peers: &mut [(String, Peer)],
) -> crate::error::Result<bool> {
    for (_, peer) in peers.iter_mut() {
        if !peer.inventory.iter().any(|n| n == name) {
            continue;
        }
        let (total, _, _, want_ck) = match peer.client.stat_full(name) {
            Ok(s) => s,
            Err(_) => continue,
        };
        let bytes = match peer.client.get_range(name, 0, total) {
            Ok(b) => b,
            Err(_) => continue,
        };
        if bytes.len() as u64 != total {
            continue;
        }
        let mut ck = Checksummer::streaming();
        ck.update(&bytes);
        if ck.finalize() != want_ck {
            // The holder's copy (or the wire) is damaged — its own
            // scrubber will quarantine it; try the next holder.
            continue;
        }
        let frames: Vec<Vec<u8>> = bytes.chunks(FRAME_MAX).map(|c| c.to_vec()).collect();
        if store_blob(ctx, name, frames, total).is_err() {
            return Ok(false);
        }
        return Ok(true);
    }
    Ok(false)
}

/// A stale copy may be dropped only when every ring replica answered this
/// round's probe *and* serves the blob right now *and* its copy matches
/// the local one bit-for-bit (length + whole-blob checksum). Anything
/// less and the stale copy stays — a replica serving a different (older,
/// damaged) version doesn't count as holding the blob, and this copy
/// might be the only good version left.
fn drop_is_safe(
    name: &str,
    total: u64,
    ck: u64,
    replicas: &[&str],
    peers: &mut [(String, Peer)],
) -> bool {
    for owner in replicas {
        let Some((_, peer)) = peers.iter_mut().find(|(id, _)| id == owner) else {
            return false; // replica dead or not a known member
        };
        match peer.client.stat_full(name) {
            Ok((r_total, _, _, r_ck)) if r_total == total && r_ck == ck => {}
            _ => return false,
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cluster_config_ring_orders_ownership_consistently() {
        let members = vec![
            ("a".to_string(), "127.0.0.1:1".to_string()),
            ("b".to_string(), "127.0.0.1:2".to_string()),
            ("c".to_string(), "127.0.0.1:3".to_string()),
        ];
        let ca = ClusterConfig::new("a", members.clone(), 2);
        let cb = ClusterConfig::new("b", members, 2);
        // Every member derives the same ownership from the same view.
        for name in ["m0", "m1", "weights.znn", "tokenizer.json"] {
            assert_eq!(ca.ring().replicas_for(name), cb.ring().replicas_for(name));
            assert_eq!(ca.ring().replicas_for(name).len(), 2);
        }
    }

    #[test]
    fn counters_start_zeroed() {
        let c = RepairCounters::default();
        assert_eq!(
            (c.rounds(), c.pulled(), c.dropped(), c.skipped()),
            (0, 0, 0, 0)
        );
    }
}
