//! The sharded multi-hub fleet: cluster-aware clients that place blobs
//! on a consistent-hash ring ([`crate::hub::cluster`]) with R-way
//! replication, download one blob from many replicas at once, and
//! rebalance only the blobs whose ownership moved on membership change.
//!
//! ## Multi-peer download
//!
//! A fleet download fans out as concurrent `Range` requests at
//! index-derived frame boundaries ([`crate::codec::index::stripe_spans`]):
//! each stripe starts on a `0xF5` frame offset, so a peer's bytes are
//! whole frames that verify independently (the stripe worker prepends
//! the container header it already holds and walks the frames with the
//! wire scanner, per-frame checksums included when the container
//! carries them). Every peer connection runs under the shared
//! [`RetryPolicy`]; a dead or `Busy` replica fails the stripe over to
//! the next replica in ring order. Reassembly is gated on the
//! whole-blob checksum from [`HubClient::stat_full`] — the same
//! end-to-end gate as the single-hub path.
//!
//! Un-indexed or single-frame blobs fall back to the resumable
//! single-peer [`HubClient::download`], with the same replica failover.
//!
//! ## Rebalance
//!
//! [`FleetClient::add_node`] / [`FleetClient::remove_node`] diff the old
//! and new rings ([`crate::hub::cluster::moved_blobs`]) and stream only
//! the blobs that gained a replica, each verified against its source
//! checksum before the copy counts. Removal treats the node as already
//! dead — with R ≥ 2 every blob still has a live source replica. Once a
//! moved blob provably serves from every current replica, the copies the
//! ring displaced are dropped with the `Delete` op — stale replicas stop
//! wasting space the moment they stop being the last line of defence.
//!
//! ## Self-healing
//!
//! Hubs started with a cluster view ([`Fleet::start_durable`],
//! [`crate::hub::HubServer::enable_repair`]) re-replicate and drop
//! server-to-server, with no client involved. [`FleetClient::repair`] is
//! the operator-driven equivalent for fleets running without one:
//! one synchronous pass that copies every under-replicated blob onto its
//! missing replicas (checksum-verified) and deletes provably-redundant
//! stale copies.

use crate::codec::index::{section_span, stripe_spans, TensorIndex, INDEX_FOOTER_LEN, INDEX_MAGIC};
use crate::codec::stream::{scan_wire, Checksummer, WireScan, STREAM_HEADER_LEN};
use crate::codec::{CodecConfig, MappedBytes, TensorMeta, ZnnReader};
use crate::error::{Error, Result};
use crate::hub::client::{HubClient, RetryPolicy, TensorFetch, TransferReport};
use crate::hub::cluster::{moved_blobs, HashRing};
use crate::hub::netsim::NetSim;
use crate::hub::repair::ClusterConfig;
use crate::hub::server::HubServer;
use std::collections::{BTreeMap, BTreeSet, HashMap};
use std::io::Read;
use std::path::Path;
use std::time::Duration;

/// Fleet-client tuning. Defaults come from the `ZIPNN_FLEET_*` env
/// knobs (see [`crate::util::env`]), falling back to R=2, 3 peers, and
/// the default ring geometry.
#[derive(Debug, Clone, Copy)]
pub struct FleetConfig {
    /// Replicas per blob (R).
    pub replication: usize,
    /// Stripes fetched concurrently per download (one peer connection
    /// each).
    pub peers: usize,
    /// Virtual nodes per hub on the ring.
    pub vnodes: u32,
    /// Retry policy applied to every per-peer connection.
    pub retry: RetryPolicy,
}

impl Default for FleetConfig {
    fn default() -> Self {
        FleetConfig {
            replication: crate::util::env::fleet_replication().unwrap_or(2),
            peers: crate::util::env::fleet_peers().unwrap_or(3),
            vnodes: crate::util::env::fleet_vnodes().unwrap_or(64) as u32,
            retry: RetryPolicy::default(),
        }
    }
}

/// What one multi-peer transfer did, on top of the usual
/// [`TransferReport`].
#[derive(Debug, Clone)]
pub struct FleetReport {
    /// End-to-end accounting. `transfer_secs` is the simulated
    /// *aggregate* time: peers transfer in parallel, so it is the
    /// slowest peer's simulated time, not the sum.
    pub report: TransferReport,
    /// Distinct peers that served stripes (1 on the single-peer
    /// fallback).
    pub peers: usize,
    /// Stripes the download was split into.
    pub stripes: usize,
    /// Replica failovers: stripe attempts that moved past a dead,
    /// busy, or corrupt-serving peer.
    pub failovers: u64,
}

/// What a rebalance streamed after a membership change.
#[derive(Debug, Clone)]
pub struct RebalanceReport {
    /// Per blob: the nodes that newly received a copy. Blobs whose
    /// ownership did not move are absent.
    pub moved: Vec<(String, Vec<String>)>,
    /// Total blob bytes streamed to new replicas.
    pub bytes: u64,
    /// Per blob: surviving nodes whose now-displaced copy was deleted
    /// (only after every current replica verifiably served the blob).
    pub dropped: Vec<(String, Vec<String>)>,
}

/// What a client-driven [`FleetClient::repair`] pass did.
#[derive(Debug, Clone, Default)]
pub struct RepairReport {
    /// Per blob: the replicas that were missing it and received a
    /// verified copy.
    pub copied: Vec<(String, Vec<String>)>,
    /// Per blob: non-replica nodes whose stale copy was deleted (only
    /// after every ring replica held the blob).
    pub dropped: Vec<(String, Vec<String>)>,
}

/// Whole-blob checksum matching the hash the server reports via Stat.
fn blob_ck(data: &[u8]) -> u64 {
    let mut ck = Checksummer::streaming();
    ck.update(data);
    ck.finalize()
}

/// Cluster-aware client: a ring of node ids, an id→address map, and a
/// cached connection per node.
pub struct FleetClient {
    ring: HashRing,
    addrs: HashMap<String, String>,
    cfg: FleetConfig,
    clients: HashMap<String, HubClient>,
    threads: usize,
    direct: bool,
}

impl FleetClient {
    /// Build a client over `members` (`(node id, address)` pairs).
    /// Connections are dialed lazily and honor `ZIPNN_FAULT_PROFILE`
    /// like [`HubClient::connect`].
    pub fn connect(members: &[(String, String)], cfg: FleetConfig) -> FleetClient {
        FleetClient::build(members, cfg, false)
    }

    /// Like [`FleetClient::connect`], but connections bypass the
    /// env-armed fault proxy — for tests that wire their own faults and
    /// need exact accounting.
    pub fn connect_direct(members: &[(String, String)], cfg: FleetConfig) -> FleetClient {
        FleetClient::build(members, cfg, true)
    }

    fn build(members: &[(String, String)], cfg: FleetConfig, direct: bool) -> FleetClient {
        let mut ring = HashRing::with_vnodes(cfg.replication, cfg.vnodes);
        let mut addrs = HashMap::new();
        for (id, addr) in members {
            ring.add_node(id);
            addrs.insert(id.clone(), addr.clone());
        }
        FleetClient { ring, addrs, cfg, clients: HashMap::new(), threads: 1, direct }
    }

    /// Worker threads for codec work during transfers.
    pub fn with_threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// The placement ring (read-only; membership changes go through
    /// [`FleetClient::add_node`] / [`FleetClient::remove_node`] so the
    /// rebalance runs).
    pub fn ring(&self) -> &HashRing {
        &self.ring
    }

    /// The replica node ids a stored blob name lives on, primary first.
    pub fn replicas_of(&self, stored: &str) -> Vec<String> {
        self.ring.replicas_for(stored).into_iter().map(String::from).collect()
    }

    fn dial(&self, id: &str) -> Result<HubClient> {
        let addr = self
            .addrs
            .get(id)
            .ok_or_else(|| Error::Invalid(format!("unknown fleet node '{id}'")))?;
        let c = if self.direct {
            HubClient::connect_direct(addr)
        } else {
            HubClient::connect(addr)
        }?;
        Ok(c.with_threads(self.threads).with_retry_policy(self.cfg.retry))
    }

    /// Run `f` on the cached connection to `id`, dialing on first use.
    /// Any error evicts the cached connection so the next use re-dials.
    fn try_on<T>(&mut self, id: &str, f: impl FnOnce(&mut HubClient) -> Result<T>) -> Result<T> {
        if !self.clients.contains_key(id) {
            let c = self.dial(id)?;
            self.clients.insert(id.to_string(), c);
        }
        let r = f(self.clients.get_mut(id).expect("just inserted"));
        if r.is_err() {
            self.clients.remove(id);
        }
        r
    }

    /// Stored blob name for a logical model name.
    fn stored_name(name: &str, compressed: bool) -> String {
        if compressed {
            format!("{name}.znn")
        } else {
            name.to_string()
        }
    }

    /// Upload to every replica of the blob's ring placement. The report
    /// aggregates: `wire_total` and `transfer_secs` sum over replicas
    /// (replica pushes are sequential), the rest describes one copy.
    pub fn upload(
        &mut self,
        name: &str,
        raw: &[u8],
        cfg: Option<CodecConfig>,
        sim: &mut NetSim,
    ) -> Result<TransferReport> {
        let stored = FleetClient::stored_name(name, cfg.is_some());
        self.upload_with(&stored, |c, sim| c.upload(name, raw, cfg.clone(), sim), sim)
    }

    /// Upload compressed **with a tensor index** to every replica — the
    /// index is what later lets downloads stripe at frame boundaries.
    pub fn upload_indexed(
        &mut self,
        name: &str,
        raw: &[u8],
        tensors: Vec<TensorMeta>,
        cfg: CodecConfig,
        sim: &mut NetSim,
    ) -> Result<TransferReport> {
        let stored = format!("{name}.znn");
        self.upload_with(
            &stored,
            |c, sim| c.upload_indexed(name, raw, tensors.clone(), cfg.clone(), sim),
            sim,
        )
    }

    fn upload_with(
        &mut self,
        stored: &str,
        mut f: impl FnMut(&mut HubClient, &mut NetSim) -> Result<TransferReport>,
        sim: &mut NetSim,
    ) -> Result<TransferReport> {
        let replicas = self.replicas_of(stored);
        if replicas.is_empty() {
            return Err(Error::Invalid("fleet has no nodes".into()));
        }
        let mut agg: Option<TransferReport> = None;
        for id in &replicas {
            let rep = self.try_on(id, |c| f(c, sim))?;
            agg = Some(match agg {
                None => rep,
                Some(mut a) => {
                    a.wire_total += rep.wire_total;
                    a.transfer_secs += rep.transfer_secs;
                    a
                }
            });
        }
        Ok(agg.expect("at least one replica"))
    }

    /// Download a blob from the fleet, striping across replicas when the
    /// stored container carries a frame index; decompresses when it was
    /// stored as `.znn`. Byte-identical to the single-hub
    /// [`HubClient::download`], including under replica failure — every
    /// stripe verifies its frames, failed peers fail over in ring order,
    /// and the reassembled blob must hash to the checksum the fleet
    /// reports before it is decoded.
    pub fn download(
        &mut self,
        name: &str,
        compressed: bool,
        sim: &mut NetSim,
    ) -> Result<(Vec<u8>, FleetReport)> {
        let stored = FleetClient::stored_name(name, compressed);
        let replicas = self.replicas_of(&stored);
        if replicas.is_empty() {
            return Err(Error::Invalid("fleet has no nodes".into()));
        }
        // Stat + index from the first live replica.
        let mut meta: Option<(u64, u64, Option<(TensorIndex, Vec<u8>)>)> = None;
        let mut failovers = 0u64;
        let mut last_err: Option<Error> = None;
        for id in &replicas {
            match self.try_on(id, |c| {
                let (total, _, _, ck) = c.stat_full(&stored)?;
                let idx = fetch_remote_index(c, &stored, total)?;
                Ok((total, ck, idx))
            }) {
                Ok(m) => {
                    meta = Some(m);
                    break;
                }
                Err(e) => {
                    failovers += 1;
                    last_err = Some(e);
                }
            }
        }
        let Some((total, stored_ck, idx)) = meta else {
            return Err(last_err.unwrap_or_else(|| Error::Invalid("no replicas".into())));
        };
        let spans = match &idx {
            Some((idx, _)) => stripe_spans(idx, total, self.cfg.peers.max(1)),
            None => vec![(0, total)],
        };
        if spans.len() < 2 {
            // Un-indexed, tiny, or single-frame blob: resumable
            // single-peer path with replica failover.
            return self.download_single_peer(name, compressed, &replicas, failovers, sim);
        }
        let header = idx.expect("spans imply an index").1;
        let results = self.fetch_stripes(&stored, &spans, &replicas, &header);
        let mut buf: Vec<u8> = Vec::with_capacity(total as usize);
        let mut wire_total = 0u64;
        let mut by_peer: BTreeMap<String, u64> = BTreeMap::new();
        for r in results {
            let s = r?;
            failovers += s.failovers;
            wire_total += s.bytes.len() as u64;
            *by_peer.entry(s.node).or_insert(0) += s.bytes.len() as u64;
            buf.extend_from_slice(&s.bytes);
        }
        if buf.len() as u64 != total {
            return Err(Error::Corrupt(format!(
                "striped download assembled {} of {total} bytes",
                buf.len()
            )));
        }
        if blob_ck(&buf) != stored_ck {
            return Err(Error::Corrupt(
                "striped download failed its end-to-end checksum".into(),
            ));
        }
        // Peers transfer in parallel: the simulated aggregate time is
        // the slowest peer's, which is the whole point of striping.
        let transfer_secs = by_peer
            .values()
            .map(|&b| sim.transfer_secs(b))
            .fold(0.0f64, f64::max);
        let peers = by_peer.len();
        let (raw, codec_secs) = decode_blob(buf, compressed, self.threads)?;
        let report = TransferReport {
            name: name.to_string(),
            raw_len: raw.len(),
            wire_len: total as usize,
            wire_total,
            codec_secs,
            transfer_secs,
        };
        Ok((raw, FleetReport { report, peers, stripes: spans.len(), failovers }))
    }

    fn download_single_peer(
        &mut self,
        name: &str,
        compressed: bool,
        replicas: &[String],
        mut failovers: u64,
        sim: &mut NetSim,
    ) -> Result<(Vec<u8>, FleetReport)> {
        let mut last_err: Option<Error> = None;
        for id in replicas {
            match self.try_on(id, |c| c.download(name, compressed, sim)) {
                Ok((raw, report)) => {
                    return Ok((
                        raw,
                        FleetReport { report, peers: 1, stripes: 1, failovers },
                    ))
                }
                Err(e) => {
                    failovers += 1;
                    last_err = Some(e);
                }
            }
        }
        Err(last_err.unwrap_or_else(|| Error::Invalid("no replicas".into())))
    }

    /// Fan the stripes out, one worker per stripe, each trying the
    /// replica list rotated by stripe index (spreading load), each
    /// connection under the fleet retry policy.
    fn fetch_stripes(
        &self,
        stored: &str,
        spans: &[(u64, u64)],
        replicas: &[String],
        header: &[u8],
    ) -> Vec<Result<StripeResult>> {
        std::thread::scope(|s| {
            let handles: Vec<_> = spans
                .iter()
                .enumerate()
                .map(|(i, &(off, len))| {
                    let cands: Vec<(String, String)> = (0..replicas.len())
                        .map(|k| {
                            let id = &replicas[(i + k) % replicas.len()];
                            (id.clone(), self.addrs.get(id).cloned().unwrap_or_default())
                        })
                        .collect();
                    let retry = self.cfg.retry;
                    let direct = self.direct;
                    s.spawn(move || fetch_stripe(stored, off, len, cands, header, retry, direct))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| {
                    h.join()
                        .unwrap_or_else(|_| Err(Error::Invalid("stripe worker panicked".into())))
                })
                .collect()
        })
    }

    /// Fetch one tensor by name, with replica failover. The placement
    /// offset comes from the validated wire meta
    /// ([`HubClient::get_tensor_placed`]).
    pub fn get_tensor(&mut self, name: &str, tensor: &str) -> Result<TensorFetch> {
        let stored = format!("{name}.znn");
        let replicas = self.replicas_of(&stored);
        let mut last_err: Option<Error> = None;
        for id in &replicas {
            match self.try_on(id, |c| c.get_tensor_placed(name, tensor)) {
                Ok(f) => return Ok(f),
                Err(e) => last_err = Some(e),
            }
        }
        Err(last_err.unwrap_or_else(|| Error::Invalid("no replicas".into())))
    }

    /// Every blob name stored anywhere in the fleet.
    pub fn list_all(&mut self) -> Result<Vec<String>> {
        let mut names = BTreeSet::new();
        let ids: Vec<String> = self.ring.nodes().to_vec();
        let mut last_err: Option<Error> = None;
        let mut any = false;
        for id in &ids {
            match self.try_on(id, |c| c.list()) {
                Ok(list) => {
                    any = true;
                    names.extend(list);
                }
                Err(e) => last_err = Some(e),
            }
        }
        if !any {
            return Err(last_err
                .unwrap_or_else(|| Error::Invalid("fleet has no reachable nodes".into())));
        }
        Ok(names.into_iter().collect())
    }

    /// Join `id` at `addr` and stream only the blobs whose ring
    /// ownership moved onto it.
    pub fn add_node(&mut self, id: &str, addr: &str) -> Result<RebalanceReport> {
        let old = self.ring.clone();
        if !self.ring.add_node(id) {
            return Err(Error::Invalid(format!("node '{id}' already in the fleet")));
        }
        self.addrs.insert(id.to_string(), addr.to_string());
        self.rebalance_from(&old)
    }

    /// Remove `id` (treated as already dead: nothing is read from it)
    /// and re-replicate the blobs it owned onto their new replicas.
    /// With R ≥ 2 every such blob still has a live source.
    pub fn remove_node(&mut self, id: &str) -> Result<RebalanceReport> {
        let old = self.ring.clone();
        if !self.ring.remove_node(id) {
            return Err(Error::Invalid(format!("node '{id}' not in the fleet")));
        }
        self.addrs.remove(id);
        self.clients.remove(id);
        self.rebalance_from(&old)
    }

    /// Stream exactly the blobs whose replica set changed between `old`
    /// and the current ring, each verified against its source checksum.
    fn rebalance_from(&mut self, old: &HashRing) -> Result<RebalanceReport> {
        let mut names: BTreeSet<String> = BTreeSet::new();
        let surviving: Vec<String> = old
            .nodes()
            .iter()
            .filter(|id| self.addrs.contains_key(*id))
            .cloned()
            .collect();
        for id in &surviving {
            if let Ok(list) = self.try_on(id, |c| c.list()) {
                names.extend(list);
            }
        }
        let plan = moved_blobs(old, &self.ring, names.iter().map(String::as_str));
        let mut bytes = 0u64;
        let mut dropped: Vec<(String, Vec<String>)> = Vec::new();
        // The simulated clock is irrelevant for a control-plane copy;
        // a throwaway sim keeps the client API uniform.
        let mut sim = NetSim::new(crate::hub::netsim::NetProfile::UPLOAD, 0);
        for (name, gained) in &plan {
            let src = old
                .replicas_for(name)
                .into_iter()
                .find(|id| self.addrs.contains_key(*id))
                .map(String::from)
                .ok_or_else(|| {
                    Error::Invalid(format!("blob '{name}' has no surviving source replica"))
                })?;
            let (total, _, _, ck) = self.try_on(&src, |c| c.stat_full(name))?;
            let blob = self.try_on(&src, |c| c.get_range(name, 0, total))?;
            if blob.len() as u64 != total || blob_ck(&blob) != ck {
                return Err(Error::Corrupt(format!(
                    "rebalance source copy of '{name}' failed its checksum"
                )));
            }
            for dst in gained {
                // cfg None: the stored bytes move verbatim under their
                // stored name (already `.znn`-suffixed when compressed).
                self.try_on(dst, |c| c.upload(name, &blob, None, &mut sim))?;
                bytes += total;
            }
            if let Some(from) = self.drop_displaced(name, old) {
                dropped.push((name.clone(), from));
            }
        }
        Ok(RebalanceReport { moved: plan, bytes, dropped })
    }

    /// Delete `name` from surviving nodes the new ring no longer places
    /// it on — but only once every *current* replica verifiably serves
    /// the same bytes (length + whole-blob checksum all agree), and only
    /// for displaced copies matching those bytes. A replica that can't
    /// be statted, or one serving a divergent (older, damaged) version,
    /// leaves the stale copy in place: while the real replica set is
    /// degraded or inconsistent, a displaced copy is the last line of
    /// defence, not garbage. `None` when nothing was displaced or the
    /// drop wasn't safe.
    fn drop_displaced(&mut self, name: &str, old: &HashRing) -> Option<Vec<String>> {
        let current = self.replicas_of(name);
        let stale: Vec<String> = old
            .replicas_for(name)
            .into_iter()
            .map(String::from)
            .filter(|id| self.addrs.contains_key(id) && !current.contains(id))
            .collect();
        if stale.is_empty() {
            return None;
        }
        // Every current replica must serve the blob and all must agree on
        // its identity — that agreed (length, checksum) is the reference
        // a displaced copy is compared against before deletion.
        let mut reference: Option<(u64, u64)> = None;
        for id in &current {
            let Ok((total, _, _, ck)) = self.try_on(id, |c| c.stat_full(name)) else {
                return None;
            };
            match reference {
                None => reference = Some((total, ck)),
                Some(r) if r == (total, ck) => {}
                Some(_) => return None, // replicas disagree — repair first
            }
        }
        let reference = reference?;
        let mut from = Vec::new();
        for id in &stale {
            // A displaced copy that diverges from what the replicas serve
            // might be the only surviving newest version — keep it.
            match self.try_on(id, |c| c.stat_full(name)) {
                Ok((total, _, _, ck)) if (total, ck) == reference => {}
                _ => continue,
            }
            if matches!(self.try_on(id, |c| c.delete(name)), Ok(true)) {
                from.push(id.clone());
            }
        }
        if from.is_empty() {
            None
        } else {
            Some(from)
        }
    }

    /// Delete a stored blob from every fleet node (idempotent, like the
    /// wire op). Returns how many nodes actually held a copy. Errors
    /// only when *no* node was reachable.
    pub fn delete(&mut self, stored: &str) -> Result<usize> {
        let ids: Vec<String> = self.ring.nodes().to_vec();
        let mut removed = 0usize;
        let mut reached = false;
        let mut last_err: Option<Error> = None;
        for id in &ids {
            match self.try_on(id, |c| c.delete(stored)) {
                Ok(had) => {
                    reached = true;
                    removed += usize::from(had);
                }
                Err(e) => last_err = Some(e),
            }
        }
        if !reached {
            return Err(last_err
                .unwrap_or_else(|| Error::Invalid("fleet has no reachable nodes".into())));
        }
        Ok(removed)
    }

    /// One synchronous, client-driven repair pass over the whole fleet:
    /// every blob missing from one of its ring replicas is copied there
    /// from a live holder (length- and checksum-verified first), and
    /// stale copies on non-replica nodes are deleted once every replica
    /// holds the blob. Unreachable nodes take no part — their blobs are
    /// re-replicated from whoever else holds them, and nothing is
    /// deleted while a replica can't be verified.
    pub fn repair(&mut self) -> Result<RepairReport> {
        let ids: Vec<String> = self.ring.nodes().to_vec();
        let mut inventory: HashMap<String, BTreeSet<String>> = HashMap::new();
        for id in &ids {
            if let Ok(list) = self.try_on(id, |c| c.list()) {
                inventory.insert(id.clone(), list.into_iter().collect());
            }
        }
        if inventory.is_empty() {
            return Err(Error::Invalid("fleet has no reachable nodes".into()));
        }
        let names: BTreeSet<String> = inventory.values().flatten().cloned().collect();
        let mut report = RepairReport::default();
        let mut sim = NetSim::new(crate::hub::netsim::NetProfile::UPLOAD, 0);
        for name in &names {
            let replicas = self.replicas_of(name);
            let missing: Vec<String> = replicas
                .iter()
                .filter(|id| inventory.get(*id).is_some_and(|inv| !inv.contains(name)))
                .cloned()
                .collect();
            if !missing.is_empty() {
                if let Some(bytes) = self.fetch_verified(name, &inventory) {
                    let mut fixed = Vec::new();
                    for dst in &missing {
                        if self.try_on(dst, |c| c.upload(name, &bytes, None, &mut sim)).is_ok() {
                            fixed.push(dst.clone());
                            if let Some(inv) = inventory.get_mut(dst) {
                                inv.insert(name.clone());
                            }
                        }
                    }
                    if !fixed.is_empty() {
                        report.copied.push((name.clone(), fixed));
                    }
                }
            }
            let all_replicas_hold = replicas
                .iter()
                .all(|id| inventory.get(id).is_some_and(|inv| inv.contains(name)));
            if !all_replicas_hold {
                continue;
            }
            let stale: Vec<String> = inventory
                .iter()
                .filter(|(id, inv)| !replicas.contains(*id) && inv.contains(name))
                .map(|(id, _)| id.clone())
                .collect();
            if stale.is_empty() {
                continue;
            }
            // Inventory says every replica holds *a* copy; before deleting
            // anything, stat them all and require agreement on length +
            // whole-blob checksum — that identity is the reference a stale
            // copy must match, or it might be the only newest version.
            let mut reference: Option<(u64, u64)> = None;
            let mut agreed = true;
            for id in &replicas {
                match self.try_on(id, |c| c.stat_full(name)) {
                    Ok((total, _, _, ck)) => match reference {
                        None => reference = Some((total, ck)),
                        Some(r) if r == (total, ck) => {}
                        Some(_) => {
                            agreed = false;
                            break;
                        }
                    },
                    Err(_) => {
                        agreed = false;
                        break;
                    }
                }
            }
            let Some(reference) = reference.filter(|_| agreed) else {
                continue;
            };
            let mut from = Vec::new();
            for id in &stale {
                match self.try_on(id, |c| c.stat_full(name)) {
                    Ok((total, _, _, ck)) if (total, ck) == reference => {}
                    _ => continue,
                }
                if matches!(self.try_on(id, |c| c.delete(name)), Ok(true)) {
                    from.push(id.clone());
                    if let Some(inv) = inventory.get_mut(id) {
                        inv.remove(name);
                    }
                }
            }
            if !from.is_empty() {
                report.dropped.push((name.clone(), from));
            }
        }
        Ok(report)
    }

    /// Fetch `name`'s bytes from the first live holder whose copy passes
    /// the length + whole-blob-checksum gate.
    fn fetch_verified(
        &mut self,
        name: &str,
        inventory: &HashMap<String, BTreeSet<String>>,
    ) -> Option<Vec<u8>> {
        let holders: Vec<String> = inventory
            .iter()
            .filter(|(_, inv)| inv.contains(name))
            .map(|(id, _)| id.clone())
            .collect();
        for src in &holders {
            let Ok((total, _, _, ck)) = self.try_on(src, |c| c.stat_full(name)) else {
                continue;
            };
            let Ok(bytes) = self.try_on(src, |c| c.get_range(name, 0, total)) else {
                continue;
            };
            if bytes.len() as u64 == total && blob_ck(&bytes) == ck {
                return Some(bytes);
            }
        }
        None
    }
}

struct StripeResult {
    node: String,
    bytes: Vec<u8>,
    failovers: u64,
}

/// One stripe worker: try each candidate replica in order; a candidate
/// counts only if its bytes arrive complete *and* its frames verify.
fn fetch_stripe(
    stored: &str,
    off: u64,
    len: u64,
    candidates: Vec<(String, String)>,
    header: &[u8],
    retry: RetryPolicy,
    direct: bool,
) -> Result<StripeResult> {
    let mut last_err: Option<Error> = None;
    for (i, (id, addr)) in candidates.iter().enumerate() {
        let conn = if direct { HubClient::connect_direct(addr) } else { HubClient::connect(addr) };
        let mut c = match conn {
            Ok(c) => c.with_retry_policy(retry),
            Err(e) => {
                last_err = Some(e);
                continue;
            }
        };
        match c.get_range(stored, off, len) {
            Ok(bytes) if bytes.len() as u64 == len => {
                if verify_stripe(header, off, &bytes) {
                    return Ok(StripeResult { node: id.clone(), bytes, failovers: i as u64 });
                }
                last_err = Some(Error::Corrupt(format!(
                    "stripe [{off}, {}) from '{id}' failed frame verification",
                    off + len
                )));
            }
            Ok(bytes) => {
                last_err = Some(Error::Corrupt(format!(
                    "stripe [{off}, {}) from '{id}' arrived short: {} of {len} bytes",
                    off + len,
                    bytes.len()
                )));
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.unwrap_or_else(|| Error::Invalid("stripe has no candidate replicas".into())))
}

/// Scan a stripe's frames. Stripes start on frame boundaries, so
/// prepending the container header (for stripes past the first) yields
/// a well-formed frame sequence the wire scanner can walk — per-frame
/// checksums verify when the container carries them. The final stripe
/// ends in the trailer plus index tail, which the end-to-end checksum
/// covers.
fn verify_stripe(header: &[u8], off: u64, bytes: &[u8]) -> bool {
    let prefixed;
    let view: &[u8] = if off == 0 {
        bytes
    } else {
        prefixed = [header, bytes].concat();
        &prefixed
    };
    match scan_wire(view) {
        // A mid-container stripe ends exactly on a frame boundary: the
        // scanner wants the next frame but verified everything held.
        WireScan::NeedMore { verified } => verified == view.len(),
        // The last stripe: frames + trailer verified; the index tail
        // past the trailer is covered by the end-to-end checksum.
        WireScan::Complete { .. } => true,
        WireScan::Corrupt { .. } => false,
        // Structureless bytes can't be frame-verified mid-stream; the
        // striped path only runs on indexed ZNS1 containers, so this is
        // a corrupt (or mis-sliced) stripe.
        WireScan::Opaque => false,
    }
}

/// Fetch and parse a stored container's tensor index plus its stream
/// header. `Ok(None)` when the blob carries no (plausible) index — the
/// caller falls back to the single-peer path.
fn fetch_remote_index(
    c: &mut HubClient,
    stored: &str,
    total: u64,
) -> Result<Option<(TensorIndex, Vec<u8>)>> {
    if total < (INDEX_FOOTER_LEN + STREAM_HEADER_LEN) as u64 {
        return Ok(None);
    }
    let footer = c.get_range(stored, total - INDEX_FOOTER_LEN as u64, INDEX_FOOTER_LEN as u64)?;
    let Some((off, len)) = section_span(total, &footer) else {
        return Ok(None);
    };
    // Same implausibility cap as the server's index probe: a lying
    // footer must not trigger a huge fetch.
    if len > 1 << 26 {
        return Ok(None);
    }
    let section = c.get_range(stored, off, len as u64)?;
    if section.len() < 4 || section[..4] != INDEX_MAGIC {
        return Ok(None);
    }
    let Ok(idx) = TensorIndex::parse_section(&section) else {
        return Ok(None);
    };
    let header = c.get_range(stored, 0, STREAM_HEADER_LEN as u64)?;
    Ok(Some((idx, header)))
}

/// Decode downloaded container bytes (or pass raw bytes through).
fn decode_blob(buf: Vec<u8>, compressed: bool, threads: usize) -> Result<(Vec<u8>, f64)> {
    if !compressed {
        return Ok((buf, 0.0));
    }
    let t = crate::util::Timer::start();
    let mapped = MappedBytes::from_vec(buf);
    let mut zr = ZnnReader::from_mapped(mapped)?.with_threads(threads);
    let mut out = Vec::new();
    zr.read_to_end(&mut out)?;
    drop(zr);
    Ok((out, t.secs()))
}

/// A local fleet of in-process hubs for tests, benches, and the CLI:
/// N servers on ephemeral loopback ports, with stable logical ids
/// (`hub0`, `hub1`, …) so placement survives a node's address changing
/// (e.g. being fronted by a fault proxy).
pub struct Fleet {
    servers: Vec<Option<HubServer>>,
    ids: Vec<String>,
    addrs: Vec<String>,
}

impl Fleet {
    /// Start `n` hubs with default tuning.
    pub fn start(n: usize) -> Result<Fleet> {
        let mut servers = Vec::with_capacity(n);
        let mut ids = Vec::with_capacity(n);
        let mut addrs = Vec::with_capacity(n);
        for i in 0..n {
            let s = HubServer::start()?;
            ids.push(format!("hub{i}"));
            addrs.push(s.addr().to_string());
            servers.push(Some(s));
        }
        Ok(Fleet { servers, ids, addrs })
    }

    /// Start `n` hubs persisting under `root/hub<i>` (crash-safe
    /// storage, scrubbing every `scrub`), then wire them into a
    /// self-healing cluster: every hub learns the full membership and
    /// runs the background repair loop every `repair` with
    /// `replication`-way placement. Repair can only be enabled after
    /// every member is bound — addresses are ephemeral until then.
    pub fn start_durable(
        n: usize,
        root: &Path,
        replication: usize,
        scrub: Duration,
        repair: Duration,
    ) -> Result<Fleet> {
        let mut servers = Vec::with_capacity(n);
        let mut ids = Vec::with_capacity(n);
        let mut addrs = Vec::with_capacity(n);
        for i in 0..n {
            let s = HubServer::builder()
                .persist_dir(root.join(format!("hub{i}")))
                .scrub_interval(scrub)
                .start()?;
            ids.push(format!("hub{i}"));
            addrs.push(s.addr().to_string());
            servers.push(Some(s));
        }
        let members: Vec<(String, String)> =
            ids.iter().cloned().zip(addrs.iter().cloned()).collect();
        for (i, s) in servers.iter_mut().enumerate() {
            if let Some(s) = s.as_mut() {
                s.enable_repair(ClusterConfig::new(&ids[i], members.clone(), replication), repair);
            }
        }
        Ok(Fleet { servers, ids, addrs })
    }

    /// `(id, address)` membership pairs for a [`FleetClient`].
    pub fn members(&self) -> Vec<(String, String)> {
        self.ids.iter().cloned().zip(self.addrs.iter().cloned()).collect()
    }

    /// Borrow a running node's server — tests reach through this for
    /// recovery reports, persisted blob paths, and repair counters.
    pub fn server(&self, id: &str) -> Option<&HubServer> {
        let i = self.ids.iter().position(|n| n == id)?;
        self.servers[i].as_ref()
    }

    /// A node's dial address.
    pub fn addr_of(&self, id: &str) -> Option<&str> {
        let i = self.ids.iter().position(|n| n == id)?;
        Some(&self.addrs[i])
    }

    /// Kill one node (replica death). Returns `false` for an unknown or
    /// already-stopped id.
    pub fn stop_node(&mut self, id: &str) -> bool {
        let Some(i) = self.ids.iter().position(|n| n == id) else {
            return false;
        };
        match self.servers[i].take() {
            Some(s) => {
                s.shutdown();
                true
            }
            None => false,
        }
    }

    /// Shut every node down.
    pub fn shutdown(mut self) {
        for s in self.servers.iter_mut() {
            if let Some(s) = s.take() {
                s.shutdown();
            }
        }
    }
}
