//! Consistent-hash placement for a fleet of hubs (paper §2.1.1: the
//! hub-scale workload is a *fleet*, not one process).
//!
//! Blob names map to nodes through a classic consistent-hash ring:
//! every node contributes `vnodes` pseudo-random points on a 64-bit
//! ring, a blob hashes to a point, and its R replicas are the first R
//! *distinct* nodes walking clockwise from there. Because each point is
//! a pure function of `(node id, vnode index)`, membership changes move
//! only the blobs whose arcs a joining/leaving node's points cover —
//! the minimal-remapping property the rebalance path and the proptests
//! lean on.
//!
//! The ring deals in *node ids* (stable logical names), not addresses:
//! callers keep an id→address map (see [`crate::hub::fleet`]), so a hub
//! can be re-dialed through a proxy or restarted on a new port without
//! re-placing every blob.

use std::collections::BTreeSet;

/// Default pseudo-random points per node. 64 keeps the max/mean load
/// skew within ~2x across a handful of nodes (see the balance proptest)
/// while membership changes stay O(vnodes · log points).
pub const DEFAULT_VNODES: u32 = 64;

/// FNV-1a over the bytes, finished with a splitmix64 avalanche so
/// single-character name differences spread over the whole ring.
fn hash64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    // splitmix64 finalizer
    h = (h ^ (h >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    h = (h ^ (h >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    h ^ (h >> 31)
}

/// A node's ring point for one virtual-node index.
fn point(node: &str, vnode: u32) -> u64 {
    let mut key = Vec::with_capacity(node.len() + 5);
    key.extend_from_slice(node.as_bytes());
    key.push(b'#');
    key.extend_from_slice(&vnode.to_le_bytes());
    hash64(&key)
}

/// Consistent-hash ring with R-way replication.
#[derive(Debug, Clone)]
pub struct HashRing {
    replication: usize,
    vnodes: u32,
    /// Membership, in join order (stable for display; placement does not
    /// depend on it).
    nodes: Vec<String>,
    /// `(ring point, index into nodes)`, sorted by point. Rebuilt on
    /// membership change — points of surviving nodes never move, which
    /// is what makes remapping minimal.
    points: Vec<(u64, u32)>,
}

impl HashRing {
    /// Empty ring with `replication`-way placement and default vnodes.
    pub fn new(replication: usize) -> HashRing {
        HashRing::with_vnodes(replication, DEFAULT_VNODES)
    }

    /// Empty ring with an explicit virtual-node count per node.
    pub fn with_vnodes(replication: usize, vnodes: u32) -> HashRing {
        HashRing {
            replication: replication.max(1),
            vnodes: vnodes.max(1),
            nodes: Vec::new(),
            points: Vec::new(),
        }
    }

    /// Replication factor R (capped at the node count during lookup).
    pub fn replication(&self) -> usize {
        self.replication
    }

    /// Current member ids, in join order.
    pub fn nodes(&self) -> &[String] {
        &self.nodes
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    /// Add a member. Returns `false` (and changes nothing) when the id
    /// is already present.
    pub fn add_node(&mut self, id: &str) -> bool {
        if self.nodes.iter().any(|n| n == id) {
            return false;
        }
        self.nodes.push(id.to_string());
        self.rebuild();
        true
    }

    /// Remove a member. Returns `false` when the id was not present.
    pub fn remove_node(&mut self, id: &str) -> bool {
        let Some(at) = self.nodes.iter().position(|n| n == id) else {
            return false;
        };
        self.nodes.remove(at);
        self.rebuild();
        true
    }

    fn rebuild(&mut self) {
        self.points.clear();
        self.points.reserve(self.nodes.len() * self.vnodes as usize);
        for (i, node) in self.nodes.iter().enumerate() {
            for v in 0..self.vnodes {
                self.points.push((point(node, v), i as u32));
            }
        }
        self.points.sort_unstable();
    }

    /// The R distinct replica nodes holding `name`, primary first:
    /// the first `replication` distinct nodes clockwise from the name's
    /// ring point (all nodes, when fewer than R are members).
    pub fn replicas_for(&self, name: &str) -> Vec<&str> {
        let want = self.replication.min(self.nodes.len());
        let mut out: Vec<&str> = Vec::with_capacity(want);
        if want == 0 {
            return out;
        }
        let h = hash64(name.as_bytes());
        let start = self.points.partition_point(|&(p, _)| p < h);
        let mut seen = BTreeSet::new();
        for k in 0..self.points.len() {
            let (_, node_idx) = self.points[(start + k) % self.points.len()];
            if seen.insert(node_idx) {
                out.push(self.nodes[node_idx as usize].as_str());
                if out.len() == want {
                    break;
                }
            }
        }
        out
    }

    /// The primary replica for `name` (`None` on an empty ring).
    pub fn primary_for(&self, name: &str) -> Option<&str> {
        self.replicas_for(name).first().copied()
    }

    /// Does `node` hold a replica of `name`?
    pub fn owns(&self, node: &str, name: &str) -> bool {
        self.replicas_for(name).iter().any(|&n| n == node)
    }
}

/// The per-blob rebalance plan for a membership change: for each name,
/// the nodes that must newly receive a copy (its replica set under
/// `new` minus its set under `old`). Names whose ownership did not move
/// are absent — a rebalance streams only these.
pub fn moved_blobs<'a>(
    old: &HashRing,
    new: &HashRing,
    names: impl IntoIterator<Item = &'a str>,
) -> Vec<(String, Vec<String>)> {
    let mut plan = Vec::new();
    for name in names {
        let before: BTreeSet<&str> = old.replicas_for(name).into_iter().collect();
        let gained: Vec<String> = new
            .replicas_for(name)
            .into_iter()
            .filter(|n| !before.contains(n))
            .map(String::from)
            .collect();
        if !gained.is_empty() {
            plan.push((name.to_string(), gained));
        }
    }
    plan
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ring(n: usize, r: usize) -> HashRing {
        let mut ring = HashRing::new(r);
        for i in 0..n {
            assert!(ring.add_node(&format!("hub{i}")));
        }
        ring
    }

    #[test]
    fn placement_is_deterministic_and_distinct() {
        let a = ring(5, 3);
        let b = ring(5, 3);
        for i in 0..100 {
            let name = format!("blob-{i}.znn");
            let ra = a.replicas_for(&name);
            assert_eq!(ra, b.replicas_for(&name));
            assert_eq!(ra.len(), 3);
            let set: BTreeSet<&&str> = ra.iter().collect();
            assert_eq!(set.len(), 3, "replicas must be distinct nodes");
        }
    }

    #[test]
    fn replication_caps_at_membership() {
        let r = ring(2, 3);
        assert_eq!(r.replicas_for("x").len(), 2);
        assert!(HashRing::new(2).replicas_for("x").is_empty());
        assert!(HashRing::new(2).primary_for("x").is_none());
    }

    #[test]
    fn duplicate_and_missing_membership_ops() {
        let mut r = ring(3, 2);
        assert!(!r.add_node("hub1"));
        assert!(!r.remove_node("hub9"));
        assert!(r.remove_node("hub1"));
        assert_eq!(r.len(), 2);
    }

    #[test]
    fn moved_blobs_names_only_gaining_nodes() {
        let old = ring(3, 2);
        let mut new = old.clone();
        new.add_node("hub3");
        let names: Vec<String> = (0..200).map(|i| format!("b{i}")).collect();
        let plan = moved_blobs(&old, &new, names.iter().map(String::as_str));
        for (name, gained) in &plan {
            // Every gaining node really is a new replica of the name.
            let before: BTreeSet<&str> = old.replicas_for(name).into_iter().collect();
            let after: BTreeSet<&str> = new.replicas_for(name).into_iter().collect();
            for g in gained {
                assert!(after.contains(g.as_str()) && !before.contains(g.as_str()));
            }
        }
        // Only the joining node can gain blobs on a pure join.
        assert!(plan
            .iter()
            .all(|(_, gained)| gained.iter().all(|g| g == "hub3")));
        assert!(!plan.is_empty(), "a joining node should take over some arcs");
    }
}
