//! Per-connection state machine for the readiness-driven hub server.
//!
//! A [`Conn`] owns one non-blocking socket and resumes from partial reads
//! and partial writes:
//!
//! - the **read side** feeds whatever bytes `read(2)` returns into the
//!   resumable [`RequestParser`], accumulating PUT body frames until a
//!   request completes (bounded: one wire frame plus one read buffer);
//! - the **write side** walks a small phase machine over the response —
//!   head bytes, then (for GET / RANGE / GET_TENSOR) the body's segments
//!   re-framed as bounded wire frames, then the terminator — picking up
//!   mid-slice after `WouldBlock`. Segments referencing a stored blob are
//!   written straight from its storage (for a spooled blob, the memory
//!   mapping: a range response never copies payload bytes on the server).
//!
//! Connections are half-duplex by design, matching the client: while a
//! request executes on the worker pool or a response drains, the reactor
//! keeps read interest off, so pipelined bytes simply wait in the kernel
//! buffer (and in already-parsed events) until the response completes.

use crate::hub::protocol::{Op, ReqEvent, RequestParser, FRAME_MAX, NAME_MAX};
use crate::hub::server::StoredBlob;
use std::io::{self, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Instant;

/// Read budget per readiness notification: how many buffer-fulls one
/// connection may consume before the reactor moves on (level-triggered
/// polling re-reports the fd if bytes remain, so this only bounds
/// per-wakeup latency for the other connections, never loses data).
const READS_PER_WAKE: usize = 4;

/// One complete parsed request, ready for the worker pool.
#[derive(Debug)]
pub(crate) struct Request {
    /// Opcode.
    pub(crate) op: Op,
    /// Blob name.
    pub(crate) name: String,
    /// Body wire frames (PUT / RANGE / GET_TENSOR; other ops drain).
    pub(crate) frames: Vec<Vec<u8>>,
    /// Total body payload bytes.
    pub(crate) total: u64,
}

/// One piece of a streamed response body.
pub(crate) enum Segment {
    /// Worker-built bytes (placement headers, synthesized trailers).
    Owned(Vec<u8>),
    /// A byte range of a stored blob, written straight from its storage
    /// (the spool mapping for spooled blobs — no server-side copy).
    Blob {
        /// The blob (kept alive for the duration of the write).
        blob: Arc<StoredBlob>,
        /// Byte offset into the blob's payload.
        off: u64,
        /// Byte length.
        len: u64,
    },
}

impl Segment {
    fn len(&self) -> u64 {
        match self {
            Segment::Owned(v) => v.len() as u64,
            Segment::Blob { len, .. } => *len,
        }
    }

    /// Longest contiguous slice starting `pos` bytes into the segment
    /// (`pos < len`). Blob storage may be fragmented into stored frames;
    /// the write machine emits one wire frame per contiguous run.
    fn slice_at(&self, pos: u64) -> &[u8] {
        match self {
            Segment::Owned(v) => &v[pos as usize..],
            Segment::Blob { blob, off, len } => {
                let s = blob.slice_at(off + pos);
                let cap = ((len - pos).min(s.len() as u64)) as usize;
                &s[..cap]
            }
        }
    }
}

/// A response produced by a worker.
pub(crate) enum Response {
    /// Fully serialized response bytes (status + chunked body).
    Small(Vec<u8>),
    /// Head bytes (status), then the segments re-framed as bounded wire
    /// frames, then the terminator.
    Stream {
        /// Raw (unchunked) leading bytes — the status byte.
        head: Vec<u8>,
        /// Body segments, concatenated on the wire.
        segs: Vec<Segment>,
    },
}

/// Outcome of driving the read side.
pub(crate) enum ReadOutcome {
    /// No complete request yet; wait for more bytes.
    NeedMore,
    /// A request completed and should be dispatched.
    Dispatch(Request),
    /// Peer closed or the stream errored; drop the connection.
    Closed,
}

/// Outcome of driving the write side.
pub(crate) enum WriteOutcome {
    /// The socket is full; wait for writability.
    Blocked,
    /// The whole response is out.
    Done,
    /// The stream errored; drop the connection.
    Closed,
}

enum WritePhase {
    /// Writing `head` bytes.
    Head,
    /// Writing the 4-byte length prefix of the current wire frame.
    FrameHeader,
    /// Writing the current wire frame's payload.
    FrameBody,
    /// Writing the 4-byte zero terminator.
    Terminator,
    /// Response fully written.
    Finished,
}

/// Streaming-body progress: which segment, how far into it, and the
/// current wire frame's size.
struct BodyState {
    segs: Vec<Segment>,
    /// Current segment index.
    seg: usize,
    /// Bytes of the current segment already framed out.
    seg_pos: u64,
    /// Payload length of the wire frame in flight (0 = compute the next).
    frame_len: usize,
}

/// Resumable serializer of one response.
struct WriteState {
    head: Vec<u8>,
    /// `None` for `Response::Small` (already fully serialized).
    body: Option<BodyState>,
    /// Position within the phase's byte run (head / len4 / frame).
    pos: usize,
    len4: [u8; 4],
    phase: WritePhase,
}

impl WriteState {
    fn new(resp: Response) -> WriteState {
        let (head, body) = match resp {
            Response::Small(bytes) => (bytes, None),
            Response::Stream { head, segs } => {
                (head, Some(BodyState { segs, seg: 0, seg_pos: 0, frame_len: 0 }))
            }
        };
        WriteState { head, body, pos: 0, len4: [0; 4], phase: WritePhase::Head }
    }
}

/// One hub connection owned by the reactor.
pub(crate) struct Conn {
    pub(crate) stream: TcpStream,
    parser: RequestParser,
    /// Request being assembled (header seen, body incoming).
    cur: Option<Request>,
    write: Option<WriteState>,
    /// A request is executing on the worker pool.
    pub(crate) busy: bool,
    /// Close once the current response finishes (shutdown request).
    pub(crate) close_after_write: bool,
    /// Guards against completions for a previous occupant of this slot.
    pub(crate) gen: u64,
    /// Readiness interest currently registered with the poller.
    pub(crate) interest: crate::hub::sys::Interest,
    /// In-flight body budget: PUT frames beyond this many payload bytes
    /// are counted but not retained (the executor rejects the request).
    max_body: u64,
    last_activity: Instant,
}

impl Conn {
    /// Wrap an accepted (already non-blocking) stream.
    pub(crate) fn new(stream: TcpStream, gen: u64, max_body: u64) -> Conn {
        Conn {
            stream,
            parser: RequestParser::new(),
            cur: None,
            write: None,
            busy: false,
            close_after_write: false,
            gen,
            interest: crate::hub::sys::Interest::READ,
            max_body,
            last_activity: Instant::now(),
        }
    }

    /// True when a response is pending (partially) written.
    pub(crate) fn writing(&self) -> bool {
        self.write.is_some()
    }

    /// A request is in flight (any direction) — used by the stall sweep.
    /// Idle keep-alive connections (between requests) return `false`.
    pub(crate) fn in_flight(&self) -> bool {
        self.busy || self.write.is_some() || self.cur.is_some() || self.parser.mid_request()
    }

    /// Seconds since the connection last made progress.
    pub(crate) fn idle_for(&self, now: Instant) -> std::time::Duration {
        now.duration_since(self.last_activity)
    }

    /// Drain already-parsed events; `Some` when they complete a request
    /// (used to resume pipelined requests after a response finishes).
    pub(crate) fn take_buffered_request(&mut self) -> Option<Request> {
        while let Some(ev) = self.parser.take() {
            match ev {
                ReqEvent::Header { op, name } => {
                    self.cur = Some(Request { op, name, frames: Vec::new(), total: 0 });
                }
                ReqEvent::Frame(frame) => {
                    if let Some(req) = self.cur.as_mut() {
                        req.total += frame.len() as u64;
                        // PUT bodies stream up to the server's in-flight
                        // body budget. Range/GetTensor bodies are tiny by
                        // contract (16 bytes / a tensor name), so retain
                        // at most NAME_MAX bytes. Everything else —
                        // including the empty-by-contract Delete/Ping
                        // bodies — is counted but never retained. Either
                        // way `total` keeps the true count and the
                        // executor rejects oversized requests with a
                        // clean error — the server never buffers past
                        // its budget.
                        let keep = match req.op {
                            Op::Put => req.total <= self.max_body,
                            Op::Range | Op::GetTensor => req.total <= NAME_MAX as u64,
                            _ => false,
                        };
                        if keep {
                            req.frames.push(frame);
                        }
                    }
                }
                ReqEvent::End => {
                    if let Some(req) = self.cur.take() {
                        return Some(req);
                    }
                }
            }
        }
        None
    }

    /// Read until `WouldBlock`, the per-wake budget, or a complete
    /// request. `buf` is the reactor's shared read scratch.
    pub(crate) fn drive_read(&mut self, buf: &mut [u8]) -> ReadOutcome {
        if let Some(req) = self.take_buffered_request() {
            return ReadOutcome::Dispatch(req);
        }
        let mut reads = 0;
        loop {
            if reads >= READS_PER_WAKE {
                // Level-triggered polling re-reports remaining bytes.
                return ReadOutcome::NeedMore;
            }
            match self.stream.read(buf) {
                Ok(0) => return ReadOutcome::Closed,
                Ok(n) => {
                    reads += 1;
                    self.last_activity = Instant::now();
                    if self.parser.feed(&buf[..n]).is_err() {
                        // Protocol violation: drop the connection (the
                        // blocking server did the same).
                        return ReadOutcome::Closed;
                    }
                    if let Some(req) = self.take_buffered_request() {
                        return ReadOutcome::Dispatch(req);
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return ReadOutcome::NeedMore,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                Err(_) => return ReadOutcome::Closed,
            }
        }
    }

    /// Attach a response (the request's execution finished).
    pub(crate) fn start_response(&mut self, resp: Response, close_after: bool) {
        self.busy = false;
        self.close_after_write = close_after;
        self.write = Some(WriteState::new(resp));
        self.last_activity = Instant::now();
    }

    /// Write until done or `WouldBlock`.
    pub(crate) fn drive_write(&mut self) -> WriteOutcome {
        const ZERO4: [u8; 4] = [0; 4];
        let Some(w) = self.write.as_mut() else {
            return WriteOutcome::Done;
        };
        let mut progressed = false;
        let out = loop {
            // Phase transitions first, so every phase below has bytes.
            match w.phase {
                WritePhase::Head => {
                    if w.pos >= w.head.len() {
                        w.pos = 0;
                        w.phase = match &w.body {
                            Some(_) => WritePhase::FrameHeader,
                            None => WritePhase::Finished,
                        };
                        continue;
                    }
                }
                WritePhase::FrameHeader => {
                    let b = w.body.as_mut().expect("body in frame phase");
                    if w.pos == 0 && b.frame_len == 0 {
                        // Compute the next wire frame: skip exhausted (or
                        // empty) segments, then take the longest
                        // contiguous run, bounded by FRAME_MAX.
                        while b.seg < b.segs.len() && b.seg_pos >= b.segs[b.seg].len() {
                            b.seg += 1;
                            b.seg_pos = 0;
                        }
                        if b.seg >= b.segs.len() {
                            w.phase = WritePhase::Terminator;
                            continue;
                        }
                        let avail = b.segs[b.seg].slice_at(b.seg_pos).len().min(FRAME_MAX);
                        if avail == 0 {
                            // Storage shorter than the segment claims:
                            // never emit a premature terminator (the
                            // client would see a short body as success).
                            break WriteOutcome::Closed;
                        }
                        b.frame_len = avail;
                        w.len4 = (avail as u32).to_le_bytes();
                    }
                    if w.pos >= 4 {
                        w.pos = 0;
                        w.phase = WritePhase::FrameBody;
                        continue;
                    }
                }
                WritePhase::FrameBody => {
                    let b = w.body.as_mut().expect("body in frame phase");
                    if w.pos >= b.frame_len {
                        b.seg_pos += b.frame_len as u64;
                        b.frame_len = 0;
                        w.pos = 0;
                        w.phase = WritePhase::FrameHeader;
                        continue;
                    }
                }
                WritePhase::Terminator => {
                    if w.pos >= 4 {
                        w.phase = WritePhase::Finished;
                        continue;
                    }
                }
                WritePhase::Finished => break WriteOutcome::Done,
            }
            let src: &[u8] = match w.phase {
                WritePhase::Head => &w.head[w.pos..],
                WritePhase::FrameHeader => &w.len4[w.pos..],
                WritePhase::FrameBody => {
                    let b = w.body.as_ref().expect("body in frame phase");
                    &b.segs[b.seg].slice_at(b.seg_pos)[w.pos..b.frame_len]
                }
                WritePhase::Terminator => &ZERO4[w.pos..],
                WritePhase::Finished => unreachable!("handled above"),
            };
            match self.stream.write(src) {
                Ok(0) => break WriteOutcome::Closed,
                Ok(n) => {
                    w.pos += n;
                    progressed = true;
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => break WriteOutcome::Blocked,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => break WriteOutcome::Closed,
            }
        };
        if progressed {
            self.last_activity = Instant::now();
        }
        if matches!(out, WriteOutcome::Done) {
            self.write = None;
        }
        out
    }
}
