//! The hub server: a threaded TCP blob store.
//!
//! Blobs are stored as the bounded wire frames they arrived in (≤
//! [`FRAME_MAX`] bytes each), never reassembled: a PUT of an N-byte blob
//! costs the server one frame-sized buffer at a time, and a GET streams
//! the stored frames back out. Peak per-connection memory is therefore
//! O(FRAME_MAX) regardless of blob size.

use crate::error::Result;
use crate::hub::protocol::{
    read_name, write_response, write_response_header, ChunkedReader, ChunkedWriter, Op, FRAME_MAX,
};
use std::collections::HashMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// Poll interval while a keep-alive connection is idle: how quickly a
/// handler notices the stop flag.
const IDLE_POLL: Duration = Duration::from_millis(100);
/// Timeout for reads inside an in-flight request (a stalled client gets
/// its connection dropped instead of pinning a handler thread forever).
const IO_TIMEOUT: Duration = Duration::from_secs(5);

/// One stored blob: the wire frames of its PUT body.
struct StoredBlob {
    frames: Vec<Vec<u8>>,
    total: u64,
}

impl StoredBlob {
    fn max_frame(&self) -> usize {
        self.frames.iter().map(|f| f.len()).max().unwrap_or(0)
    }
}

type Store = Arc<Mutex<HashMap<String, Arc<StoredBlob>>>>;

/// In-process model hub listening on loopback.
pub struct HubServer {
    addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl HubServer {
    /// Start on an ephemeral loopback port.
    pub fn start() -> Result<HubServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let store: Store = Arc::new(Mutex::new(HashMap::new()));
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let stop2 = Arc::clone(&stop);
        let conns2 = Arc::clone(&conns);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let store = Arc::clone(&store);
                let stop3 = Arc::clone(&stop2);
                let h = std::thread::spawn(move || {
                    let _ = handle_conn(stream, store, stop3);
                });
                // reap finished handlers so a long-lived server doesn't
                // accumulate handles without bound
                let mut conns = conns2.lock().unwrap();
                conns.retain(|c| !c.is_finished());
                conns.push(h);
            }
        });
        Ok(HubServer { addr, stop, handle: Some(handle), conns })
    }

    /// Address to connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Request shutdown and join the accept loop plus every connection
    /// handler. Handlers poll the stop flag between requests (and time out
    /// stalled requests), so this returns even with live keep-alive
    /// connections.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // poke the accept loop awake
        let _ = TcpStream::connect(&self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        let conns = std::mem::take(&mut *self.conns.lock().unwrap());
        for h in conns {
            let _ = h.join();
        }
    }
}

impl Drop for HubServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Serve one connection until the peer closes, a request stalls past
/// [`IO_TIMEOUT`], or the stop flag is raised.
fn handle_conn(mut stream: TcpStream, store: Store, stop: Arc<AtomicBool>) -> Result<()> {
    stream.set_read_timeout(Some(IDLE_POLL))?;
    // A peer that stops reading its response must not pin this handler
    // (shutdown joins every handler thread).
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    loop {
        if stop.load(Ordering::Relaxed) {
            return Ok(());
        }
        // Wait for the next request's opcode, polling the stop flag.
        let mut op_b = [0u8; 1];
        match stream.read_exact(&mut op_b) {
            Ok(()) => {}
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                continue;
            }
            Err(_) => return Ok(()), // client closed
        }
        // A request is in flight: allow slower reads, but not forever.
        stream.set_read_timeout(Some(IO_TIMEOUT))?;
        let done = handle_request(op_b[0], &mut stream, &store, &stop)?;
        if done {
            return Ok(());
        }
        stream.set_read_timeout(Some(IDLE_POLL))?;
    }
}

/// Handle one request whose opcode byte has been read. Returns `true` when
/// the connection should close (shutdown request).
fn handle_request(
    op_byte: u8,
    stream: &mut TcpStream,
    store: &Store,
    stop: &AtomicBool,
) -> Result<bool> {
    let op = Op::from_u8(op_byte)
        .ok_or_else(|| crate::error::Error::Format(format!("bad opcode {op_byte}")))?;
    let name = read_name(&mut *stream)?;
    // Every request carries a chunked body (usually just the terminator);
    // ops that don't use it must still consume it to keep the keep-alive
    // connection in sync.
    if op != Op::Put {
        ChunkedReader::new(&mut *stream).drain()?;
    }
    match op {
        Op::Put => {
            let mut body = ChunkedReader::new(&mut *stream);
            let mut frames = Vec::new();
            let mut frame = Vec::new();
            while body.read_frame(&mut frame)? {
                debug_assert!(frame.len() <= FRAME_MAX);
                frames.push(std::mem::take(&mut frame));
            }
            let blob = StoredBlob { total: body.payload_len(), frames };
            store.lock().unwrap().insert(name, Arc::new(blob));
            write_response(stream, true, b"")?;
        }
        Op::Get => {
            let blob = store.lock().unwrap().get(&name).cloned();
            match blob {
                Some(blob) => {
                    write_response_header(stream, true)?;
                    let mut cw = ChunkedWriter::new(&mut *stream);
                    for f in &blob.frames {
                        cw.write_all(f)?;
                    }
                    cw.finish()?;
                }
                None => write_response(stream, false, b"not found")?,
            }
        }
        Op::List => {
            let names: Vec<String> = store.lock().unwrap().keys().cloned().collect();
            write_response(stream, true, names.join("\n").as_bytes())?;
        }
        Op::Stat => {
            let blob = store.lock().unwrap().get(&name).cloned();
            match blob {
                Some(blob) => {
                    let msg =
                        format!("{} {} {}", blob.total, blob.frames.len(), blob.max_frame());
                    write_response(stream, true, msg.as_bytes())?;
                }
                None => write_response(stream, false, b"not found")?,
            }
        }
        Op::Shutdown => {
            stop.store(true, Ordering::Relaxed);
            write_response(stream, true, b"")?;
            return Ok(true);
        }
    }
    Ok(false)
}
