//! The hub server: a readiness-driven TCP blob store.
//!
//! Blobs are stored as the bounded wire frames they arrived in (≤
//! [`FRAME_MAX`] bytes each), never reassembled: a PUT of an N-byte blob
//! costs the server one frame-sized buffer at a time, and a GET streams
//! the stored frames back out. Peak per-connection memory is therefore
//! O(FRAME_MAX) regardless of blob size.
//!
//! Since PR 2 the server is **reactor-based** ([`crate::hub::reactor`]):
//! one thread multiplexes every connection over epoll (poll(2) off
//! Linux), and a fixed worker pool of ≈ncpu threads executes ready
//! PUT/GET/List/Stat work — thousands of idle keep-alive connections cost
//! zero threads. Tune with [`HubServer::builder`] or the `ZIPNN_HUB_WORKERS`
//! / `ZIPNN_HUB_MAX_CONNS` environment variables.

use crate::error::Result;
use crate::hub::conn::{Request, Response};
use crate::hub::protocol::{write_response, write_response_header, Op, FRAME_MAX};
use crate::hub::reactor::{Reactor, ReactorConfig};
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// One stored blob: the wire frames of its PUT body.
pub(crate) struct StoredBlob {
    pub(crate) frames: Vec<Vec<u8>>,
    pub(crate) total: u64,
}

impl StoredBlob {
    fn max_frame(&self) -> usize {
        self.frames.iter().map(|f| f.len()).max().unwrap_or(0)
    }
}

/// Shared blob store (name → frames).
pub(crate) type Store = Arc<Mutex<HashMap<String, Arc<StoredBlob>>>>;

/// Configuration for a [`HubServer`]; construct via [`HubServer::builder`].
pub struct HubServerBuilder {
    workers: Option<usize>,
    max_conns: Option<usize>,
}

impl HubServerBuilder {
    /// Worker threads executing ready requests. Default: the
    /// `ZIPNN_HUB_WORKERS` env var, else `ncpu` (capped at 16).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n.max(1));
        self
    }

    /// Maximum concurrent connections; excess accepts are dropped.
    /// Default: the `ZIPNN_HUB_MAX_CONNS` env var, else 4096.
    pub fn max_conns(mut self, n: usize) -> Self {
        self.max_conns = Some(n.max(1));
        self
    }

    /// Bind an ephemeral loopback port and start the reactor.
    pub fn start(self) -> Result<HubServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let store: Store = Arc::new(Mutex::new(HashMap::new()));
        let cfg = ReactorConfig {
            workers: self.workers.unwrap_or_else(default_workers),
            max_conns: self.max_conns.unwrap_or_else(default_max_conns),
        };
        // Built here so setup failures (poller, self-pipe) surface as an
        // error instead of a silently dead server.
        let reactor = Reactor::new(listener, store, Arc::clone(&stop), cfg)?;
        let handle = std::thread::spawn(move || reactor.run());
        Ok(HubServer { addr, stop, handle: Some(handle) })
    }
}

fn env_usize(key: &str) -> Option<usize> {
    std::env::var(key).ok().and_then(|v| v.parse().ok())
}

fn default_workers() -> usize {
    env_usize("ZIPNN_HUB_WORKERS").unwrap_or_else(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .min(16)
    })
}

fn default_max_conns() -> usize {
    env_usize("ZIPNN_HUB_MAX_CONNS").unwrap_or(4096).max(1)
}

/// In-process model hub listening on loopback.
pub struct HubServer {
    addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl HubServer {
    /// Start on an ephemeral loopback port with default tuning.
    pub fn start() -> Result<HubServer> {
        HubServer::builder().start()
    }

    /// Tune workers / connection cap before starting.
    pub fn builder() -> HubServerBuilder {
        HubServerBuilder { workers: None, max_conns: None }
    }

    /// Address to connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Request shutdown and join the reactor (which joins every worker).
    /// The readiness loop drains — pending completions are flushed to
    /// their sockets — then every connection closes, so this returns even
    /// with live keep-alive connections.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // poke the readiness loop awake
        let _ = TcpStream::connect(&self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HubServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Execute one complete request against the store (runs on a worker
/// thread; touches no sockets). Returns the response plus whether the
/// connection should close once it is written.
pub(crate) fn execute_request(req: Request, store: &Store, stop: &AtomicBool) -> (Response, bool) {
    match req.op {
        Op::Put => {
            debug_assert!(req.frames.iter().all(|f| f.len() <= FRAME_MAX));
            let blob = StoredBlob { total: req.total, frames: req.frames };
            store.lock().unwrap().insert(req.name, Arc::new(blob));
            (Response::Small(small_response(true, b"")), false)
        }
        Op::Get => {
            let blob = store.lock().unwrap().get(&req.name).cloned();
            match blob {
                Some(blob) => {
                    // Status byte via the shared protocol encoder; the
                    // frames + terminator stream from the write machine.
                    let mut head = Vec::with_capacity(1);
                    write_response_header(&mut head, true).expect("infallible write to Vec");
                    (Response::Blob(head, blob), false)
                }
                None => (Response::Small(small_response(false, b"not found")), false),
            }
        }
        Op::List => {
            let names: Vec<String> = store.lock().unwrap().keys().cloned().collect();
            (
                Response::Small(small_response(true, names.join("\n").as_bytes())),
                false,
            )
        }
        Op::Stat => {
            let blob = store.lock().unwrap().get(&req.name).cloned();
            match blob {
                Some(blob) => {
                    let msg =
                        format!("{} {} {}", blob.total, blob.frames.len(), blob.max_frame());
                    (Response::Small(small_response(true, msg.as_bytes())), false)
                }
                None => (Response::Small(small_response(false, b"not found")), false),
            }
        }
        Op::Shutdown => {
            stop.store(true, Ordering::Relaxed);
            (Response::Small(small_response(true, b"")), true)
        }
    }
}

/// Serialize a complete small response (status byte + chunked body).
fn small_response(ok: bool, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 16);
    write_response(&mut out, ok, payload).expect("infallible write to Vec");
    out
}
