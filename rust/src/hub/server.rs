//! The hub server: a threaded TCP blob store.

use crate::error::Result;
use crate::hub::protocol::{read_request, write_response, Op};
use std::collections::HashMap;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

/// In-process model hub listening on loopback.
pub struct HubServer {
    addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl HubServer {
    /// Start on an ephemeral loopback port.
    pub fn start() -> Result<HubServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let store: Arc<Mutex<HashMap<String, Vec<u8>>>> = Arc::new(Mutex::new(HashMap::new()));
        let stop2 = Arc::clone(&stop);
        let handle = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let store = Arc::clone(&store);
                let stop3 = Arc::clone(&stop2);
                // one thread per connection; connections are short-lived
                std::thread::spawn(move || {
                    let _ = handle_conn(stream, store, stop3);
                });
            }
        });
        Ok(HubServer { addr, stop, handle: Some(handle) })
    }

    /// Address to connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Request shutdown and join the accept loop.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // poke the accept loop awake
        let _ = TcpStream::connect(&self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HubServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        let _ = TcpStream::connect(&self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn handle_conn(
    mut stream: TcpStream,
    store: Arc<Mutex<HashMap<String, Vec<u8>>>>,
    stop: Arc<AtomicBool>,
) -> Result<()> {
    loop {
        let (op, name, payload) = match read_request(&mut stream) {
            Ok(r) => r,
            Err(_) => return Ok(()), // client closed
        };
        match op {
            Op::Put => {
                store.lock().unwrap().insert(name, payload);
                write_response(&mut stream, true, b"")?;
            }
            Op::Get => match store.lock().unwrap().get(&name) {
                Some(data) => write_response(&mut stream, true, data)?,
                None => write_response(&mut stream, false, b"not found")?,
            },
            Op::List => {
                let names: Vec<String> =
                    store.lock().unwrap().keys().cloned().collect();
                write_response(&mut stream, true, names.join("\n").as_bytes())?;
            }
            Op::Shutdown => {
                stop.store(true, Ordering::Relaxed);
                write_response(&mut stream, true, b"")?;
                return Ok(());
            }
        }
    }
}
