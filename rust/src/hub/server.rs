//! The hub server: a readiness-driven TCP blob store.
//!
//! Blobs are stored as the bounded wire frames they arrived in (≤
//! [`FRAME_MAX`] bytes each), never reassembled: a PUT of an N-byte blob
//! costs the server one frame-sized buffer at a time, and a GET streams
//! the stored frames back out. Peak per-connection memory is therefore
//! O(FRAME_MAX) regardless of blob size.
//!
//! Since PR 2 the server is **reactor-based** ([`crate::hub::reactor`]):
//! one thread multiplexes every connection over epoll (poll(2) off
//! Linux), and a fixed worker pool of ≈ncpu threads executes ready
//! PUT/GET/List/Stat work — thousands of idle keep-alive connections cost
//! zero threads. Tune with [`HubServer::builder`] or the `ZIPNN_HUB_WORKERS`
//! / `ZIPNN_HUB_MAX_CONNS` environment variables.
//!
//! With a **spool directory** (builder [`HubServerBuilder::spool_dir`] or
//! `ZIPNN_HUB_SPOOL_DIR`), PUT bodies are written to disk and served from
//! a memory mapping: GET responses stream frames straight out of the OS
//! page cache instead of long-lived heap buffers, so the server's resident
//! heap stays flat no matter how many models it holds. The spool file is
//! unlinked right after mapping (Unix), so crashed servers leak nothing —
//! and keep nothing: a restarted spool-only hub starts empty.
//!
//! With a **persist root** (builder [`HubServerBuilder::persist_dir`] or
//! `ZIPNN_HUB_PERSIST`), acknowledged PUTs are instead committed
//! crash-safely to disk (tmp-write → fsync → atomic rename, sidecar
//! record as the commit point — see [`crate::hub::store`]), re-indexed
//! and verified on startup, and re-verified in the background by a scrub
//! thread that quarantines bit rot. [`HubServer::enable_repair`] adds the
//! self-healing fleet loop on top: health probes (`Ping`), inventory
//! exchange, server-to-server re-replication of under-replicated blobs,
//! and `Delete` of stale displaced copies.
//!
//! Blobs are also **byte-range addressable**: `Range` returns any span of
//! the stored bytes, and `GetTensor` uses a container's tensor index (see
//! [`crate::codec::index`]) to ship only the frames covering one tensor —
//! both sliced straight from the spooled mapping with zero payload copies.

use crate::codec::index::{self, ContainerKind, TensorIndex, INDEX_FOOTER_LEN};
use crate::codec::stream::SUPER_CHUNK;
use crate::codec::stream::{sub_container_parts, Checksummer, STREAM_HEADER_LEN};
use crate::codec::STREAM_MAGIC;
use crate::error::Result;
use crate::hub::conn::{Request, Response, Segment};
use crate::hub::protocol::{parse_range, write_response, write_response_header, Op, FRAME_MAX};
use crate::hub::reactor::{Reactor, ReactorConfig};
use crate::hub::repair::{repair_loop, ClusterConfig, RepairCounters};
use crate::hub::store::{scrub_loop, PersistStore, RecoveryReport};
use crate::util::mmap::Mmap;
use std::collections::HashMap;
use std::io::Write;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

/// One stored blob: the wire frames of its PUT body, either owned on the
/// heap or mapped from an (unlinked) spool file.
pub(crate) struct StoredBlob {
    bytes: BlobBytes,
    pub(crate) total: u64,
    /// Whole-blob checksum, computed once at store time and reported by
    /// Stat — resilient clients gate download completion on it.
    pub(crate) ck: u64,
}

/// Whole-blob checksum over a PUT body's frames (matches the client's
/// [`Checksummer::streaming`] hash of the reassembled bytes).
fn frames_ck(frames: &[Vec<u8>]) -> u64 {
    let mut ck = Checksummer::streaming();
    for f in frames {
        ck.update(f);
    }
    ck.finalize()
}

enum BlobBytes {
    /// Heap-resident frames (default), with their cumulative start
    /// offsets (`starts.len() == frames.len()`) for O(log n) range reads.
    Frames { frames: Vec<Vec<u8>>, starts: Vec<u64> },
    /// Page-cache-resident: one mapping, frames as `(offset, len)` spans.
    Mapped { map: Mmap, spans: Vec<(usize, usize)> },
}

impl StoredBlob {
    pub(crate) fn in_memory(frames: Vec<Vec<u8>>, total: u64) -> StoredBlob {
        let ck = frames_ck(&frames);
        let mut starts = Vec::with_capacity(frames.len());
        let mut at = 0u64;
        for f in &frames {
            starts.push(at);
            at += f.len() as u64;
        }
        StoredBlob { bytes: BlobBytes::Frames { frames, starts }, total, ck }
    }

    /// Number of stored wire frames.
    pub(crate) fn n_frames(&self) -> usize {
        match &self.bytes {
            BlobBytes::Frames { frames, .. } => frames.len(),
            BlobBytes::Mapped { spans, .. } => spans.len(),
        }
    }

    /// One stored frame's payload.
    pub(crate) fn frame(&self, idx: usize) -> &[u8] {
        match &self.bytes {
            BlobBytes::Frames { frames, .. } => &frames[idx],
            BlobBytes::Mapped { map, spans } => {
                let (off, len) = spans[idx];
                &map[off..off + len]
            }
        }
    }

    fn max_frame(&self) -> usize {
        (0..self.n_frames()).map(|i| self.frame(i).len()).max().unwrap_or(0)
    }

    /// Longest contiguous stored slice starting at absolute byte offset
    /// `off` (`off < total`). For a spooled blob this is the rest of the
    /// mapping — range responses are written straight from the page
    /// cache; heap blobs return the remainder of the covering frame.
    pub(crate) fn slice_at(&self, off: u64) -> &[u8] {
        match &self.bytes {
            BlobBytes::Mapped { map, .. } => &map[(off as usize).min(map.len())..],
            BlobBytes::Frames { frames, starts } => {
                let i = starts.partition_point(|&s| s <= off).saturating_sub(1);
                match frames.get(i) {
                    Some(f) => &f[((off - starts[i]) as usize).min(f.len())..],
                    None => &[],
                }
            }
        }
    }

    /// Map a committed persist file and serve it page-cache resident,
    /// re-framed as `FRAME_MAX`-sized spans. Errors when mmap can't
    /// engage (non-Unix, `ZIPNN_NO_MMAP`) or the file's length disagrees
    /// with the sidecar — callers fall back to heap frames.
    pub(crate) fn from_mapped_file(path: &Path, total: u64, ck: u64) -> std::io::Result<StoredBlob> {
        if cfg!(not(unix)) || crate::util::env::no_mmap() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::Unsupported,
                "mmap unavailable; keep the blob heap-resident",
            ));
        }
        let map = Mmap::map(&std::fs::File::open(path)?)?;
        if map.len() as u64 != total {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "persisted blob length disagrees with its sidecar",
            ));
        }
        let mut spans = Vec::with_capacity(map.len().div_ceil(FRAME_MAX.max(1)));
        let mut off = 0usize;
        while off < map.len() {
            let len = FRAME_MAX.min(map.len() - off);
            spans.push((off, len));
            off += len;
        }
        Ok(StoredBlob { bytes: BlobBytes::Mapped { map, spans }, total, ck })
    }

    /// Copy an absolute byte range out of the stored frames (used for
    /// small metadata reads — the container header and index section).
    pub(crate) fn read_range(&self, off: u64, len: usize) -> Option<Vec<u8>> {
        let end = off.checked_add(len as u64)?;
        if end > self.total {
            return None;
        }
        let mut out = Vec::with_capacity(len);
        let mut at = off;
        while out.len() < len {
            let s = self.slice_at(at);
            if s.is_empty() {
                return None; // storage shorter than `total` claims
            }
            let take = s.len().min(len - out.len());
            out.extend_from_slice(&s[..take]);
            at += take as u64;
        }
        Some(out)
    }
}

/// Write a PUT body's frames to one spool file, map it, and unlink the
/// file — the mapping keeps the pages alive (Unix), so nothing is left to
/// clean up and GETs are served from the page cache.
fn spool_blob(dir: &Path, frames: &[Vec<u8>], total: u64) -> std::io::Result<StoredBlob> {
    static SEQ: AtomicU64 = AtomicU64::new(0);
    let path = dir.join(format!(
        "blob-{}-{}.spool",
        std::process::id(),
        SEQ.fetch_add(1, Ordering::Relaxed)
    ));
    let result = write_and_map(&path, frames, total);
    // Unlink on every path: on success the mapping holds the pages; on
    // failure (including a partial write) the file is junk.
    let _ = std::fs::remove_file(&path);
    result
}

fn write_and_map(path: &Path, frames: &[Vec<u8>], total: u64) -> std::io::Result<StoredBlob> {
    // No point writing a spool file that could never be served from a
    // mapping: when mmap can't engage, the caller keeps the frames it
    // already holds and no disk I/O happens at all.
    if cfg!(not(unix)) || crate::util::env::no_mmap() {
        return Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "mmap unavailable; keep the blob heap-resident",
        ));
    }
    let mut spans = Vec::with_capacity(frames.len());
    let mut off = 0usize;
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        for frame in frames {
            f.write_all(frame)?;
            spans.push((off, frame.len()));
            off += frame.len();
        }
        f.flush()?;
    }
    // Map directly (no read-back fallback): if the filesystem refuses
    // mmap the PUT falls back to its heap frames with the spool file
    // removed — never a second in-memory copy.
    let map = Mmap::map(&std::fs::File::open(path)?)?;
    if map.len() != off {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "spool file length mismatch",
        ));
    }
    let ck = frames_ck(frames);
    Ok(StoredBlob { bytes: BlobBytes::Mapped { map, spans }, total, ck })
}

/// Shared blob store (name → frames).
pub(crate) type Store = Arc<Mutex<HashMap<String, Arc<StoredBlob>>>>;

/// Everything request execution (and the background scrub/repair loops)
/// needs, bundled once at server start and shared by `Arc`.
pub(crate) struct ServerCtx {
    pub(crate) store: Store,
    pub(crate) stop: Arc<AtomicBool>,
    pub(crate) spool: Option<Arc<Path>>,
    pub(crate) persist: Option<Arc<PersistStore>>,
    pub(crate) max_body: u64,
    pub(crate) origin: Option<Arc<str>>,
}

/// Store one blob body the way this server is configured to: durably
/// committed when persisting (a commit failure fails the request — a
/// persist-configured hub never acknowledges bytes it can't make
/// durable), spooled + mapped when spooling (failure falls back to heap),
/// heap frames otherwise. Shared by PUT, the edge read-through pull, and
/// the fleet repair pull.
pub(crate) fn store_blob(
    ctx: &ServerCtx,
    name: &str,
    frames: Vec<Vec<u8>>,
    total: u64,
) -> std::result::Result<Arc<StoredBlob>, String> {
    if let Some(p) = &ctx.persist {
        // Commit + publish under the per-name commit lock: without it two
        // concurrent same-name PUTs (or a PUT racing a Delete) can leave
        // the served bytes and the on-disk generation pointing at
        // different copies, and a restart or scrub silently reverts what
        // GET serves.
        let _commit = p.commit_lock(name);
        let blob = Arc::new(
            p.persist(name, frames, total)
                .map_err(|e| format!("persist failed: {e}"))?,
        );
        ctx.store
            .lock()
            .unwrap()
            .insert(name.to_string(), Arc::clone(&blob));
        return Ok(blob);
    }
    let blob = if let Some(dir) = &ctx.spool {
        spool_blob(dir, &frames, total).unwrap_or_else(|_| StoredBlob::in_memory(frames, total))
    } else {
        StoredBlob::in_memory(frames, total)
    };
    let blob = Arc::new(blob);
    ctx.store
        .lock()
        .unwrap()
        .insert(name.to_string(), Arc::clone(&blob));
    Ok(blob)
}

/// Configuration for a [`HubServer`]; construct via [`HubServer::builder`].
pub struct HubServerBuilder {
    workers: Option<usize>,
    max_conns: Option<usize>,
    spool_dir: Option<PathBuf>,
    persist_dir: Option<PathBuf>,
    scrub_interval: Option<Duration>,
    io_timeout: Option<Duration>,
    max_body: Option<u64>,
    origin: Option<String>,
}

impl HubServerBuilder {
    /// Worker threads executing ready requests. Default: the
    /// `ZIPNN_HUB_WORKERS` env var, else `ncpu` (capped at 16).
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n.max(1));
        self
    }

    /// Maximum concurrent connections; excess accepts are refused with a
    /// clean busy response ([`crate::error::Error::Busy`] client-side).
    /// Default: the `ZIPNN_HUB_MAX_CONNS` env var, else 4096.
    pub fn max_conns(mut self, n: usize) -> Self {
        self.max_conns = Some(n.max(1));
        self
    }

    /// Stall bound: a connection mid-request (either direction — a
    /// reader that stopped sending, or a slowloris writer that stopped
    /// draining its response) with no progress for this long is reaped.
    /// Default 5 s.
    pub fn io_timeout(mut self, t: Duration) -> Self {
        self.io_timeout = Some(t.max(Duration::from_millis(10)));
        self
    }

    /// In-flight request-body budget in MiB: PUT bodies larger than this
    /// are shed with a clean error instead of buffered. Default: the
    /// `ZIPNN_HUB_MAX_BODY_MB` env var, else 4096 (4 GiB).
    pub fn max_body_mb(mut self, mb: usize) -> Self {
        self.max_body = Some((mb.max(1) as u64) << 20);
        self
    }

    /// Spool PUT bodies to files under `dir` and serve GETs from a memory
    /// mapping of them (page-cache resident instead of heap resident).
    /// Default: the `ZIPNN_HUB_SPOOL_DIR` env var, else off.
    pub fn spool_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.spool_dir = Some(dir.into());
        self
    }

    /// Durable crash-safe storage: commit every acknowledged PUT under
    /// `root` (tmp-write → fsync → atomic rename, sidecar as the commit
    /// point), re-index + verify on startup, and run a background scrub
    /// thread that quarantines bit rot (see [`crate::hub::store`]).
    /// Takes precedence over the spool for PUT bodies — persisted blobs
    /// are already file-backed and mapped. Default: the
    /// `ZIPNN_HUB_PERSIST` env var, else off.
    pub fn persist_dir(mut self, root: impl Into<PathBuf>) -> Self {
        self.persist_dir = Some(root.into());
        self
    }

    /// How often the background scrubber re-verifies every persisted
    /// blob from disk. Only meaningful with a persist root. Default: the
    /// `ZIPNN_HUB_SCRUB_SECS` env var, else 60 s.
    pub fn scrub_interval(mut self, t: Duration) -> Self {
        self.scrub_interval = Some(t.max(Duration::from_millis(10)));
        self
    }

    /// Edge-cache mode: a GET/Range/GetTensor/Stat miss pulls the whole
    /// blob read-through from the hub at `origin` (checksum-verified, one
    /// hop, stored like a local PUT — spooled when a spool dir is set)
    /// and then serves it from the local store; later hits never touch
    /// the origin again. List and Put stay local. Default: the
    /// `ZIPNN_FLEET_ORIGIN` env var, else off.
    pub fn read_through(mut self, origin: impl Into<String>) -> Self {
        self.origin = Some(origin.into());
        self
    }

    /// Bind an ephemeral loopback port and start the reactor. With a
    /// persist root this first re-indexes and verifies the committed
    /// blobs on disk (see [`HubServer::recovery`]) and starts the
    /// background scrubber.
    pub fn start(self) -> Result<HubServer> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?.to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let store: Store = Arc::new(Mutex::new(HashMap::new()));
        let spool_dir = match self.spool_dir.or_else(default_spool_dir) {
            Some(dir) => {
                std::fs::create_dir_all(&dir)?;
                Some(Arc::<Path>::from(dir.as_path()))
            }
            None => None,
        };
        let persist = match self.persist_dir.or_else(crate::util::env::hub_persist_dir) {
            Some(root) => Some(Arc::new(PersistStore::open(root)?)),
            None => None,
        };
        let mut recovery = None;
        if let Some(p) = &persist {
            let (blobs, report) = p.recover()?;
            let mut map = store.lock().unwrap();
            for (name, blob) in blobs {
                map.insert(name, Arc::new(blob));
            }
            drop(map);
            recovery = Some(report);
        }
        let ctx = Arc::new(ServerCtx {
            store,
            stop: Arc::clone(&stop),
            spool: spool_dir,
            persist,
            max_body: self.max_body.unwrap_or_else(default_max_body),
            origin: self
                .origin
                .or_else(crate::util::env::fleet_origin)
                .map(|o| Arc::<str>::from(o.as_str())),
        });
        let cfg = ReactorConfig {
            workers: self.workers.unwrap_or_else(default_workers),
            max_conns: self.max_conns.unwrap_or_else(default_max_conns),
            io_timeout: self.io_timeout.unwrap_or(Duration::from_secs(5)),
            ctx: Arc::clone(&ctx),
        };
        // Built here so setup failures (poller, self-pipe) surface as an
        // error instead of a silently dead server.
        let reactor = Reactor::new(listener, Arc::clone(&stop), cfg)?;
        let handle = std::thread::spawn(move || reactor.run());
        let mut aux = Vec::new();
        if let Some(p) = ctx.persist.clone() {
            let interval = self
                .scrub_interval
                .or_else(|| crate::util::env::hub_scrub_secs().map(Duration::from_secs))
                .unwrap_or(Duration::from_secs(60));
            let scrub_store = Arc::clone(&ctx.store);
            let scrub_stop = Arc::clone(&stop);
            aux.push(std::thread::spawn(move || {
                scrub_loop(p, scrub_store, scrub_stop, interval)
            }));
        }
        Ok(HubServer {
            addr,
            stop,
            handle: Some(handle),
            aux,
            ctx,
            recovery,
            repair_counters: None,
        })
    }
}

fn default_spool_dir() -> Option<PathBuf> {
    crate::util::env::hub_spool_dir()
}

fn default_workers() -> usize {
    crate::util::env::hub_workers().unwrap_or_else(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(2)
            .min(16)
    })
}

fn default_max_conns() -> usize {
    crate::util::env::hub_max_conns().unwrap_or(4096).max(1)
}

fn default_max_body() -> u64 {
    (crate::util::env::hub_max_body_mb().unwrap_or(4096).max(1) as u64) << 20
}

/// In-process model hub listening on loopback.
pub struct HubServer {
    addr: String,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    /// Background scrub/repair threads, joined on shutdown.
    aux: Vec<JoinHandle<()>>,
    ctx: Arc<ServerCtx>,
    recovery: Option<RecoveryReport>,
    repair_counters: Option<Arc<RepairCounters>>,
}

impl HubServer {
    /// Start on an ephemeral loopback port with default tuning.
    pub fn start() -> Result<HubServer> {
        HubServer::builder().start()
    }

    /// Tune workers / connection cap / timeouts before starting.
    pub fn builder() -> HubServerBuilder {
        HubServerBuilder {
            workers: None,
            max_conns: None,
            spool_dir: None,
            persist_dir: None,
            scrub_interval: None,
            io_timeout: None,
            max_body: None,
            origin: None,
        }
    }

    /// Address to connect to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// What startup recovery found on disk (persisted hubs only).
    pub fn recovery(&self) -> Option<&RecoveryReport> {
        self.recovery.as_ref()
    }

    /// Path of the committed persist file serving `name`, if this hub
    /// persists and holds it (tests corrupt it to exercise the scrubber).
    pub fn persisted_blob_path(&self, name: &str) -> Option<PathBuf> {
        self.ctx.persist.as_ref()?.blob_path(name)
    }

    /// Join a self-healing fleet: start the background repair loop with
    /// this hub's identity and the full membership map. Called after
    /// every member is bound (addresses are only known then). The loop
    /// pings peers, exchanges inventories, re-replicates blobs this hub
    /// should hold but doesn't (quarantined, missed, under-replicated)
    /// server-to-server, and deletes stale copies the ring no longer
    /// places here — no client involved.
    pub fn enable_repair(&mut self, cluster: ClusterConfig, interval: Duration) {
        let counters = Arc::new(RepairCounters::default());
        self.repair_counters = Some(Arc::clone(&counters));
        let ctx = Arc::clone(&self.ctx);
        let stop = Arc::clone(&self.stop);
        let interval = interval.max(Duration::from_millis(10));
        self.aux.push(std::thread::spawn(move || {
            repair_loop(ctx, cluster, interval, stop, counters)
        }));
    }

    /// Live repair-loop counters (None until [`HubServer::enable_repair`]).
    pub fn repair_counters(&self) -> Option<&RepairCounters> {
        self.repair_counters.as_deref()
    }

    /// Request shutdown and join the reactor (which joins every worker).
    /// The readiness loop drains — pending completions are flushed to
    /// their sockets — then every connection closes, so this returns even
    /// with live keep-alive connections.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // poke the readiness loop awake
        let _ = TcpStream::connect(&self.addr);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        for h in self.aux.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for HubServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

/// Execute one complete request against the store (runs on a worker
/// thread; touches no sockets). Returns the response plus whether the
/// connection should close once it is written.
pub(crate) fn execute_request(req: Request, ctx: &ServerCtx) -> (Response, bool) {
    match req.op {
        Op::Put => {
            debug_assert!(req.frames.iter().all(|f| f.len() <= FRAME_MAX));
            // Oversized bodies were counted but not retained by the
            // connection (graceful degradation: the budget bounds server
            // memory, the client gets a clean protocol error).
            if req.total > ctx.max_body {
                let msg = format!(
                    "put body of {} bytes exceeds the server's {} byte budget",
                    req.total, ctx.max_body
                );
                return (Response::Small(small_response(false, msg.as_bytes())), false);
            }
            // Persisting commits durably (a failure fails the PUT — never
            // acknowledge bytes that aren't on disk); spooling falls back
            // to heap frames, so there a PUT never fails on account of
            // the optimization.
            match store_blob(ctx, &req.name, req.frames, req.total) {
                Ok(_) => (Response::Small(small_response(true, b"")), false),
                Err(msg) => (Response::Small(small_response(false, msg.as_bytes())), false),
            }
        }
        Op::Get => {
            let blob = lookup(ctx, &req.name);
            match blob {
                Some(blob) => {
                    let len = blob.total;
                    (
                        Response::Stream {
                            head: ok_head(),
                            segs: vec![Segment::Blob { blob, off: 0, len }],
                        },
                        false,
                    )
                }
                None => (Response::Small(small_response(false, b"not found")), false),
            }
        }
        Op::Range => {
            let blob = lookup(ctx, &req.name);
            let Some(blob) = blob else {
                return (Response::Small(small_response(false, b"not found")), false);
            };
            // Malformed ranges (bad body size, u64 overflow, off the end)
            // are clean error responses — the connection stays usable.
            // `total` counts the whole body even where the connection
            // stopped retaining frames (oversized bodies are never
            // buffered), so the mismatch is caught here.
            if req.total != 16 {
                let msg = format!("range body is {} bytes, expected 16", req.total);
                return (Response::Small(small_response(false, msg.as_bytes())), false);
            }
            let body: Vec<u8> = req.frames.concat();
            let (off, len) = match parse_range(&body) {
                Ok(r) => r,
                Err(e) => {
                    return (
                        Response::Small(small_response(false, e.to_string().as_bytes())),
                        false,
                    )
                }
            };
            if off + len > blob.total {
                let msg =
                    format!("range [{off}, {}) out of bounds (total {})", off + len, blob.total);
                return (Response::Small(small_response(false, msg.as_bytes())), false);
            }
            let segs = if len == 0 {
                Vec::new()
            } else {
                vec![Segment::Blob { blob, off, len }]
            };
            (Response::Stream { head: ok_head(), segs }, false)
        }
        Op::GetTensor => {
            let blob = lookup(ctx, &req.name);
            let Some(blob) = blob else {
                return (Response::Small(small_response(false, b"not found")), false);
            };
            if req.total > crate::hub::protocol::NAME_MAX as u64 {
                return (
                    Response::Small(small_response(false, b"tensor name too long")),
                    false,
                );
            }
            let tensor = match String::from_utf8(req.frames.concat()) {
                Ok(t) => t,
                Err(_) => {
                    return (
                        Response::Small(small_response(false, b"tensor name not utf8")),
                        false,
                    )
                }
            };
            match tensor_response(&blob, &tensor) {
                Ok(segs) => (Response::Stream { head: ok_head(), segs }, false),
                Err(msg) => (Response::Small(small_response(false, msg.as_bytes())), false),
            }
        }
        Op::List => {
            let names: Vec<String> = ctx.store.lock().unwrap().keys().cloned().collect();
            (
                Response::Small(small_response(true, names.join("\n").as_bytes())),
                false,
            )
        }
        Op::Stat => {
            let blob = lookup(ctx, &req.name);
            match blob {
                Some(blob) => {
                    // `total frames max_frame checksum` — the trailing
                    // whole-blob checksum is what resilient downloads
                    // verify against.
                    let msg = format!(
                        "{} {} {} {}",
                        blob.total,
                        blob.n_frames(),
                        blob.max_frame(),
                        blob.ck
                    );
                    (Response::Small(small_response(true, msg.as_bytes())), false)
                }
                None => (Response::Small(small_response(false, b"not found")), false),
            }
        }
        Op::Delete => {
            // Idempotent by design: repair loops and rebalance retries
            // re-issue deletes freely; "already gone" must not read as
            // failure. The payload says which case it was. On a persisted
            // hub both removals happen under the per-name commit lock so
            // a racing PUT can't land between them and be half-deleted.
            let (served, persisted) = match &ctx.persist {
                Some(p) => {
                    let _commit = p.commit_lock(&req.name);
                    let served = ctx.store.lock().unwrap().remove(&req.name).is_some();
                    (served, p.remove(&req.name))
                }
                None => (ctx.store.lock().unwrap().remove(&req.name).is_some(), false),
            };
            let payload: &[u8] = if served || persisted { b"1" } else { b"0" };
            (Response::Small(small_response(true, payload)), false)
        }
        Op::Ping => (Response::Small(small_response(true, b"pong")), false),
        Op::Shutdown => {
            ctx.stop.store(true, Ordering::Relaxed);
            (Response::Small(small_response(true, b"")), true)
        }
    }
}

/// Read-path blob lookup: the local store, then — in edge-cache mode —
/// a read-through pull from the origin hub on a miss. The pull runs on
/// the worker thread (blocking client I/O never touches the reactor);
/// concurrent misses of the same blob may pull twice, last store wins —
/// both copies are verified identical bytes, so that is only wasted
/// work, never a wrong answer.
fn lookup(ctx: &ServerCtx, name: &str) -> Option<Arc<StoredBlob>> {
    if let Some(blob) = ctx.store.lock().unwrap().get(name).cloned() {
        return Some(blob);
    }
    let origin = ctx.origin.as_deref()?;
    pull_from_origin(name, origin, ctx)
}

/// Pull one blob from the origin hub into the local store: stat (for the
/// checksum), ranged GET of the whole stored bytes, verify, then store
/// exactly like a local PUT (spooled to disk when configured). One hop
/// only — an origin that is itself an edge would chain, so don't
/// configure rings of edges. `None` on any failure: the caller answers
/// "not found" and the next request retries the pull.
fn pull_from_origin(name: &str, origin: &str, ctx: &ServerCtx) -> Option<Arc<StoredBlob>> {
    // Direct connection: the edge's upstream leg must not be re-routed
    // through an env-armed fault proxy meant for the client under test.
    let mut c = crate::hub::client::HubClient::connect_direct(origin).ok()?;
    let (total, _, _, ck) = c.stat_full(name).ok()?;
    if total > ctx.max_body {
        return None;
    }
    let bytes = c.get_range(name, 0, total).ok()?;
    if bytes.len() as u64 != total {
        return None;
    }
    let mut h = Checksummer::streaming();
    h.update(&bytes);
    if h.finalize() != ck {
        return None;
    }
    let frames: Vec<Vec<u8>> = bytes.chunks(FRAME_MAX).map(<[u8]>::to_vec).collect();
    store_blob(ctx, name, frames, total).ok()
}

/// Serialize a complete small response (status byte + chunked body).
fn small_response(ok: bool, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(payload.len() + 16);
    write_response(&mut out, ok, payload).expect("infallible write to Vec");
    out
}

/// The raw (unchunked) OK status byte heading a streamed response.
fn ok_head() -> Vec<u8> {
    let mut head = Vec::with_capacity(1);
    write_response_header(&mut head, true).expect("infallible write to Vec");
    head
}

/// Parse the tensor index a stored container carries in its tail.
fn blob_tensor_index(blob: &StoredBlob) -> std::result::Result<TensorIndex, String> {
    if blob.total < INDEX_FOOTER_LEN as u64 {
        return Err("container has no tensor index".into());
    }
    let footer = blob
        .read_range(blob.total - INDEX_FOOTER_LEN as u64, INDEX_FOOTER_LEN)
        .ok_or("blob storage inconsistent")?;
    let (off, len) = index::section_span(blob.total, &footer)
        .ok_or("container has no tensor index")?;
    // A lying footer must not make the server materialize the blob: real
    // index sections are tiny (tens of bytes per tensor/frame).
    if len > 1 << 26 {
        return Err("implausible index section size".into());
    }
    let section = blob.read_range(off, len).ok_or("blob storage inconsistent")?;
    TensorIndex::parse_section(&section).map_err(|e| format!("bad tensor index: {e}"))
}

/// Build a GET_TENSOR response body: a 24-byte placement header
/// (`[base_raw u64][tensor_rel u64][tensor_len u64]`) followed by a
/// self-contained `ZNS1` sub-container — the stored header (checksum flag
/// stripped), the frames covering the tensor **sliced straight out of the
/// blob's storage** (the spool mapping when spooled), and a synthesized
/// trailer. The client decodes it with a plain `ZnnReader` and slices
/// `[tensor_rel, tensor_rel + tensor_len)`.
fn tensor_response(
    blob: &Arc<StoredBlob>,
    tensor: &str,
) -> std::result::Result<Vec<Segment>, String> {
    let idx = blob_tensor_index(blob)?;
    if idx.kind != ContainerKind::Streaming {
        return Err("tensor range-GET needs a streaming (ZNS1) container".into());
    }
    let t = idx
        .find(tensor)
        .ok_or_else(|| format!("no tensor '{tensor}' in index"))?;
    let chunk = idx.chunk_size as u64;
    let aligned = idx.aligned_len();
    let n_chunks = aligned.div_ceil(chunk);
    let n_frames = n_chunks.div_ceil(SUPER_CHUNK as u64);
    if idx.frame_offsets.len() as u64 != n_frames {
        return Err("index frame directory disagrees with container".into());
    }
    let header = blob
        .read_range(0, STREAM_HEADER_LEN)
        .filter(|h| h[0..4] == STREAM_MAGIC)
        .ok_or("tensor range-GET needs a streaming (ZNS1) container")?;
    if t.len == 0 {
        // Empty tensor: ship an empty sub-container (header + trailer),
        // no frames, no tail — the client decodes zero bytes.
        let (patched_header, trailer) =
            sub_container_parts(&header, 0, &[]).map_err(|e| e.to_string())?;
        let mut meta = Vec::with_capacity(24 + STREAM_HEADER_LEN);
        meta.extend_from_slice(&t.offset.to_le_bytes());
        meta.extend_from_slice(&0u64.to_le_bytes());
        meta.extend_from_slice(&0u64.to_le_bytes());
        meta.extend_from_slice(&patched_header);
        return Ok(vec![Segment::Owned(meta), Segment::Owned(trailer)]);
    }
    // Covering frames [f0, f1): tensors entirely in the trailer tail
    // cover no frame at all.
    let t_end = t.offset + t.len; // validated against total_len at parse
    let (f0, f1) = if t.offset >= aligned {
        (n_frames, n_frames)
    } else {
        let c0 = t.offset / chunk;
        let c1 = t_end.min(aligned).div_ceil(chunk).min(n_chunks);
        (c0 / SUPER_CHUNK as u64, c1.div_ceil(SUPER_CHUNK as u64))
    };
    let frames_start = if f0 < n_frames { idx.frame_offsets[f0 as usize] } else { idx.trailer_off };
    let frames_end = if f1 < n_frames { idx.frame_offsets[f1 as usize] } else { idx.trailer_off };
    if frames_end < frames_start || frames_end > blob.total {
        return Err("index frame offsets out of bounds".into());
    }
    // Raw bytes the shipped frames decode to, and whether the trailer
    // tail rides along (it must whenever the last frame is included, so
    // the synthesized trailer's total adds up).
    let base_raw = (f0 * SUPER_CHUNK as u64 * chunk).min(aligned);
    let frames_raw = (f1 * SUPER_CHUNK as u64 * chunk).min(aligned) - base_raw;
    let tail: &[u8] = if f1 == n_frames { &idx.tail } else { &[] };
    if t_end > base_raw + frames_raw + tail.len() as u64 || t.offset < base_raw {
        return Err("index tensor span disagrees with frame directory".into());
    }
    let (patched_header, trailer) = sub_container_parts(&header, frames_raw, tail)
        .map_err(|e| e.to_string())?;
    let mut meta = Vec::with_capacity(24 + STREAM_HEADER_LEN);
    meta.extend_from_slice(&base_raw.to_le_bytes());
    meta.extend_from_slice(&(t.offset - base_raw).to_le_bytes());
    meta.extend_from_slice(&t.len.to_le_bytes());
    meta.extend_from_slice(&patched_header);
    let mut segs = vec![Segment::Owned(meta)];
    if frames_end > frames_start {
        segs.push(Segment::Blob {
            blob: Arc::clone(blob),
            off: frames_start,
            len: frames_end - frames_start,
        });
    }
    segs.push(Segment::Owned(trailer));
    Ok(segs)
}
