//! The hub's readiness-driven reactor: one thread multiplexing every
//! connection over an epoll/poll [`Poller`], with request execution on a
//! fixed [`WorkerPool`].
//!
//! ## Shape
//!
//! - The **reactor thread** owns all sockets. It accepts, reads, parses
//!   (via the resumable [`crate::hub::protocol::RequestParser`]) and
//!   writes — all non-blocking. Thousands of idle keep-alive connections
//!   cost one registered fd each and zero threads.
//! - Complete requests are handed to the **worker pool** (≈ncpu threads,
//!   shared [`crate::coordinator::WorkerPool`] primitive). Workers touch
//!   only the blob store, never sockets; they push a completion and wake
//!   the reactor through a self-pipe.
//! - **Shutdown** drains the readiness loop: the stop flag (plus a wake —
//!   a connect from [`crate::hub::HubServer::shutdown`] or the self-pipe)
//!   ends the loop at the end of the current iteration, after pending
//!   completions were flushed to the sockets; dropping the pool then joins
//!   every worker, and dropping the slot table closes every connection.
//!
//! In-flight requests keep the blocking server's stall bound: a
//! connection mid-request (either direction) that makes no progress for
//! [`ReactorConfig::io_timeout`] is dropped by the periodic sweep — this
//! includes slowloris-style stalled *writers* (a peer that stops reading
//! its response); idle between-requests connections are never timed out.
//! Over-cap accepts are shed with a clean
//! [`crate::hub::protocol::BUSY_RESPONSE`] instead of a silent close, so
//! clients can tell "retry later" from a dead server.

use crate::coordinator::pool::WorkerPool;
use crate::hub::conn::{Conn, ReadOutcome, Request, Response, WriteOutcome};
use crate::hub::server::{execute_request, ServerCtx};
use crate::hub::sys::{Event, Interest, Poller};
use std::io::{Read, Write};
use std::net::TcpListener;
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Poller token of the accept socket.
const TOKEN_LISTENER: u64 = 0;
/// Poller token of the self-pipe wake socket.
const TOKEN_WAKER: u64 = 1;
/// First connection token; token = slot index + `TOKEN_BASE`.
const TOKEN_BASE: u64 = 2;
/// Poll tick: upper bound on stop-flag / stall-sweep latency.
const TICK_MS: i32 = 100;
/// After the stop flag: how long in-flight executions/responses may take
/// to flush before connections are closed anyway.
const DRAIN_GRACE: Duration = Duration::from_millis(500);

/// Reactor tuning, fixed at server start.
pub(crate) struct ReactorConfig {
    /// Worker threads executing ready requests.
    pub(crate) workers: usize,
    /// Connection cap; excess accepts are shed with a busy response.
    pub(crate) max_conns: usize,
    /// A connection mid-request (either direction, stalled writers
    /// included) with no progress for this long is dropped by the sweep.
    pub(crate) io_timeout: Duration,
    /// Everything request execution needs — the store, the stop flag,
    /// spool/persist configuration, body budget, edge origin. Shared with
    /// the server's background scrub/repair threads.
    pub(crate) ctx: Arc<ServerCtx>,
}

/// A finished request execution, routed back to its connection.
struct Completion {
    slot: usize,
    gen: u64,
    resp: Response,
    close_after: bool,
}

/// The readiness loop state. Constructed on the caller's thread (so
/// setup errors — poller, self-pipe — surface from
/// [`crate::hub::HubServer::start`]) and then moved into the reactor
/// thread to run.
pub(crate) struct Reactor {
    poller: Poller,
    listener: TcpListener,
    wake_rx: UnixStream,
    wake_tx: Arc<UnixStream>,
    completions: Arc<Mutex<Vec<Completion>>>,
    pool: WorkerPool,
    stop: Arc<AtomicBool>,
    cfg: ReactorConfig,
    /// Connection table; token = index + `TOKEN_BASE`.
    slots: Vec<Option<Conn>>,
    /// Reusable slot indices (merged from `freed` between poll rounds so
    /// a token freed mid-round is never reused within that round).
    free: Vec<usize>,
    freed: Vec<usize>,
    n_conns: usize,
    next_gen: u64,
    read_buf: Vec<u8>,
    last_sweep: Instant,
}

impl Reactor {
    pub(crate) fn new(
        listener: TcpListener,
        stop: Arc<AtomicBool>,
        cfg: ReactorConfig,
    ) -> std::io::Result<Reactor> {
        listener.set_nonblocking(true)?;
        let (wake_rx, wake_tx) = UnixStream::pair()?;
        wake_rx.set_nonblocking(true)?;
        wake_tx.set_nonblocking(true)?;
        let mut poller = Poller::new()?;
        poller.register(listener.as_raw_fd(), TOKEN_LISTENER, Interest::READ)?;
        poller.register(wake_rx.as_raw_fd(), TOKEN_WAKER, Interest::READ)?;
        let pool = WorkerPool::new(cfg.workers);
        Ok(Reactor {
            poller,
            listener,
            wake_rx,
            wake_tx: Arc::new(wake_tx),
            completions: Arc::new(Mutex::new(Vec::new())),
            pool,
            stop,
            cfg,
            slots: Vec::new(),
            free: Vec::new(),
            freed: Vec::new(),
            n_conns: 0,
            next_gen: 0,
            read_buf: vec![0u8; 64 * 1024],
            last_sweep: Instant::now(),
        })
    }

    /// Run until the stop flag is raised or the poller fails, then drain:
    /// in-flight responses get a bounded grace to flush, every connection
    /// closes, and the worker pool joins (via drop after this returns).
    pub(crate) fn run(mut self) {
        let mut events: Vec<Event> = Vec::new();
        loop {
            if self.poller.wait(&mut events, TICK_MS).is_err() {
                break;
            }
            // `events` is a local buffer: iterating it does not borrow
            // `self`, so handlers may mutate the reactor freely.
            for &ev in &events {
                match ev.token {
                    TOKEN_LISTENER => self.accept_all(),
                    TOKEN_WAKER => self.drain_waker(),
                    token => self.drive_slot((token - TOKEN_BASE) as usize, ev),
                }
            }
            self.process_completions();
            self.sweep_stalled();
            self.free.append(&mut self.freed);
            if self.stop.load(Ordering::Relaxed) {
                break;
            }
        }
        self.drain_in_flight();
        // Close every connection, then join the workers (dropping the
        // pool runs queued jobs to completion first).
        self.slots.clear();
        self.pool.close();
    }

    /// Post-stop grace: requests already executing (or responses already
    /// draining) get up to [`DRAIN_GRACE`] to reach the socket, so a
    /// client that asked for shutdown still reads its acknowledgement.
    /// New connections and fresh reads are not served.
    fn drain_in_flight(&mut self) {
        let deadline = Instant::now() + DRAIN_GRACE;
        let mut events: Vec<Event> = Vec::new();
        loop {
            self.process_completions();
            self.free.append(&mut self.freed);
            let pending = self.slots.iter().flatten().any(|c| c.busy || c.writing());
            if !pending || Instant::now() >= deadline {
                break;
            }
            if self.poller.wait(&mut events, 20).is_err() {
                break;
            }
            for &ev in &events {
                match ev.token {
                    TOKEN_LISTENER => {} // no new connections after stop
                    TOKEN_WAKER => self.drain_waker(),
                    token => {
                        // Only flush writes; don't start new request reads.
                        let slot = (token - TOKEN_BASE) as usize;
                        let writing = matches!(
                            self.slots.get(slot),
                            Some(Some(c)) if c.writing()
                        );
                        if writing {
                            self.drive_slot(slot, ev);
                        }
                    }
                }
            }
        }
    }

    /// Accept until `WouldBlock`; over-cap connections are shed with a
    /// best-effort [`crate::hub::protocol::BUSY_RESPONSE`] so the client
    /// sees a clean "retry later" instead of a silent close.
    fn accept_all(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((mut stream, _)) => {
                    if self.n_conns >= self.cfg.max_conns {
                        // Non-blocking: a peer that can't take 5 bytes
                        // right now just sees the close.
                        let _ = stream.set_nonblocking(true);
                        let _ = stream.write_all(&crate::hub::protocol::BUSY_RESPONSE);
                        drop(stream);
                        continue;
                    }
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    let _ = stream.set_nodelay(true);
                    let slot = self.free.pop().unwrap_or_else(|| {
                        self.slots.push(None);
                        self.slots.len() - 1
                    });
                    self.next_gen += 1;
                    let conn = Conn::new(stream, self.next_gen, self.cfg.ctx.max_body);
                    let token = TOKEN_BASE + slot as u64;
                    if self
                        .poller
                        .register(conn.stream.as_raw_fd(), token, Interest::READ)
                        .is_err()
                    {
                        self.free.push(slot);
                        continue;
                    }
                    self.slots[slot] = Some(conn);
                    self.n_conns += 1;
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => break,
            }
        }
    }

    fn drain_waker(&mut self) {
        let mut buf = [0u8; 64];
        while let Ok(n) = self.wake_rx.read(&mut buf) {
            if n == 0 {
                break;
            }
        }
    }

    /// Drive one connection for a readiness event.
    fn drive_slot(&mut self, slot: usize, ev: Event) {
        let Some(mut conn) = self.slots.get_mut(slot).and_then(Option::take) else {
            return;
        };
        let mut close = false;
        if ev.error && conn.busy {
            // The peer vanished while its request executes; the pending
            // completion is discarded by the generation check.
            close = true;
        } else if conn.writing() {
            if ev.writable || ev.error {
                close = self.continue_write(&mut conn);
            }
        } else if !conn.busy && (ev.readable || ev.error) {
            close = self.continue_read(&mut conn, slot);
        }
        self.finish_slot(slot, conn, close);
    }

    /// Read side: parse, and dispatch a completed request.
    fn continue_read(&mut self, conn: &mut Conn, slot: usize) -> bool {
        match conn.drive_read(&mut self.read_buf) {
            ReadOutcome::NeedMore => self.sync_interest(conn, slot),
            ReadOutcome::Closed => true,
            ReadOutcome::Dispatch(req) => self.dispatch(conn, slot, req),
        }
    }

    /// Write side: on completion, close or resume pipelined requests.
    fn continue_write(&mut self, conn: &mut Conn) -> bool {
        match conn.drive_write() {
            WriteOutcome::Blocked => false,
            WriteOutcome::Closed => true,
            WriteOutcome::Done => conn.close_after_write,
        }
    }

    /// Post-drive bookkeeping shared by all paths: either close the slot
    /// or put the connection back with its interest synced (resuming a
    /// buffered pipelined request first).
    fn finish_slot(&mut self, slot: usize, mut conn: Conn, mut close: bool) {
        // After a response fully drained, a pipelined request may already
        // be parsed and waiting.
        while !close && !conn.busy && !conn.writing() {
            match conn.take_buffered_request() {
                Some(req) => close = self.dispatch(&mut conn, slot, req),
                None => break,
            }
        }
        if !close {
            close = self.sync_interest(&mut conn, slot);
        }
        if close {
            let _ = self.poller.deregister(conn.stream.as_raw_fd());
            self.n_conns -= 1;
            self.freed.push(slot);
            // conn drops here, closing the socket
        } else {
            self.slots[slot] = Some(conn);
        }
    }

    /// Hand a request to the worker pool. Returns `true` when the
    /// connection must close (pool unavailable during teardown).
    fn dispatch(&mut self, conn: &mut Conn, slot: usize, req: Request) -> bool {
        conn.busy = true;
        let gen = conn.gen;
        let ctx = Arc::clone(&self.cfg.ctx);
        let completions = Arc::clone(&self.completions);
        let wake = Arc::clone(&self.wake_tx);
        let job = move || {
            let (resp, close_after) = execute_request(req, &ctx);
            completions
                .lock()
                .unwrap()
                .push(Completion { slot, gen, resp, close_after });
            // Failure means the pipe is full (a wake is already pending)
            // or the reactor is gone; both are fine to ignore.
            let _ = (&*wake).write_all(&[1u8]);
        };
        self.pool.execute(job).is_err()
    }

    /// Route finished executions back to their connections and start
    /// writing the responses.
    fn process_completions(&mut self) {
        let done: Vec<Completion> = {
            let mut q = self.completions.lock().unwrap();
            std::mem::take(&mut *q)
        };
        for c in done {
            let Some(mut conn) = self.slots.get_mut(c.slot).and_then(Option::take) else {
                continue; // connection closed while the request executed
            };
            if conn.gen != c.gen || !conn.busy {
                self.slots[c.slot] = Some(conn);
                continue;
            }
            conn.start_response(c.resp, c.close_after);
            let close = self.continue_write(&mut conn);
            self.finish_slot(c.slot, conn, close);
        }
    }

    /// Drop connections stalled mid-request (either direction — a reader
    /// that stopped sending its body, or a slowloris writer that stopped
    /// draining its response) past [`ReactorConfig::io_timeout`]. Idle
    /// keep-alive connections are left alone.
    fn sweep_stalled(&mut self) {
        let now = Instant::now();
        let sweep_every = Duration::from_millis(500).min(self.cfg.io_timeout / 2).max(
            Duration::from_millis(10),
        );
        if now.duration_since(self.last_sweep) < sweep_every {
            return;
        }
        self.last_sweep = now;
        for slot in 0..self.slots.len() {
            let stalled = match &self.slots[slot] {
                Some(c) => c.in_flight() && !c.busy && c.idle_for(now) > self.cfg.io_timeout,
                None => false,
            };
            if stalled {
                if let Some(conn) = self.slots[slot].take() {
                    let _ = self.poller.deregister(conn.stream.as_raw_fd());
                    self.n_conns -= 1;
                    self.freed.push(slot);
                }
            }
        }
    }

    /// Keep the poller's interest for this connection in sync with its
    /// state: write interest while a response drains, no interest while a
    /// request executes, read interest otherwise. Returns `true` when the
    /// poller rejects the fd (close the connection).
    fn sync_interest(&mut self, conn: &mut Conn, slot: usize) -> bool {
        let want = if conn.writing() {
            Interest::WRITE
        } else if conn.busy {
            Interest::NONE
        } else {
            Interest::READ
        };
        if want == conn.interest {
            return false;
        }
        let token = TOKEN_BASE + slot as u64;
        if self
            .poller
            .reregister(conn.stream.as_raw_fd(), token, want)
            .is_err()
        {
            return true;
        }
        conn.interest = want;
        false
    }
}
