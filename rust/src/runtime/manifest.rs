//! `artifacts/manifest.json` parsing: artifact signatures + model presets.

use crate::error::{Error, Result};
use crate::fp::DType;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::Path;

/// Shape + dtype of one artifact input/output or model parameter.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    /// Parameter name (empty for positional artifact I/O).
    pub name: String,
    /// Dimensions.
    pub shape: Vec<usize>,
    /// Element dtype name as written by aot.py (`u8/u16/u32/i32/f32`).
    pub dtype: String,
}

impl TensorSpec {
    /// Element count.
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Byte size of one element.
    pub fn elem_bytes(&self) -> usize {
        match self.dtype.as_str() {
            "u8" => 1,
            "u16" => 2,
            "u32" | "i32" | "f32" => 4,
            _ => 4,
        }
    }

    /// The codec [`DType`] for exported checkpoint bytes.
    pub fn codec_dtype(&self) -> DType {
        match self.dtype.as_str() {
            "u16" => DType::BF16,
            "u8" => DType::I8,
            _ => DType::F32,
        }
    }
}

/// One lowered artifact: file + positional signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (e.g. `lm_small_step`).
    pub name: String,
    /// HLO text filename relative to the artifacts dir.
    pub file: String,
    /// Input signature.
    pub inputs: Vec<TensorSpec>,
    /// Output signature.
    pub outputs: Vec<TensorSpec>,
}

/// One model preset: parameter layout + training config.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    /// `"lm"` or `"cnn"`.
    pub kind: String,
    /// Ordered parameter specs (the flattening contract with Python).
    pub params: Vec<TensorSpec>,
    /// Hyperparameters (vocab, seq_len, batch, ...).
    pub config: BTreeMap<String, usize>,
    /// Checkpoint export dtype (`bf16` or `f32`).
    pub export_dtype: String,
}

impl ModelMeta {
    /// Config value accessor.
    pub fn cfg(&self, key: &str) -> Result<usize> {
        self.config
            .get(key)
            .copied()
            .ok_or_else(|| Error::Artifact(format!("model config missing '{key}'")))
    }

    /// Codec dtype of exported checkpoints.
    pub fn codec_dtype(&self) -> DType {
        match self.export_dtype.as_str() {
            "bf16" => DType::BF16,
            _ => DType::F32,
        }
    }
}

/// The whole parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Artifacts by name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Model presets by name.
    pub models: BTreeMap<String, ModelMeta>,
}

fn parse_tensor_spec(j: &Json) -> Result<TensorSpec> {
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Artifact("tensor spec missing shape".into()))?
        .iter()
        .filter_map(Json::as_usize)
        .collect();
    Ok(TensorSpec {
        name: j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or_default()
            .to_string(),
        shape,
        dtype: j
            .get("dtype")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Artifact("tensor spec missing dtype".into()))?
            .to_string(),
    })
}

impl Manifest {
    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| Error::Artifact(format!("manifest: {e}")))?;
        let mut artifacts = BTreeMap::new();
        for (name, a) in j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| Error::Artifact("manifest missing artifacts".into()))?
        {
            let inputs = a
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Artifact(format!("{name}: missing inputs")))?
                .iter()
                .map(parse_tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| Error::Artifact(format!("{name}: missing outputs")))?
                .iter()
                .map(parse_tensor_spec)
                .collect::<Result<Vec<_>>>()?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    file: a
                        .get("file")
                        .and_then(Json::as_str)
                        .ok_or_else(|| Error::Artifact(format!("{name}: missing file")))?
                        .to_string(),
                    inputs,
                    outputs,
                },
            );
        }
        let mut models = BTreeMap::new();
        if let Some(ms) = j.get("models").and_then(Json::as_obj) {
            for (name, m) in ms {
                let params = m
                    .get("params")
                    .and_then(Json::as_arr)
                    .ok_or_else(|| Error::Artifact(format!("model {name}: params")))?
                    .iter()
                    .map(parse_tensor_spec)
                    .collect::<Result<Vec<_>>>()?;
                let mut config = BTreeMap::new();
                if let Some(c) = m.get("config").and_then(Json::as_obj) {
                    for (k, v) in c {
                        if let Some(u) = v.as_usize() {
                            config.insert(k.clone(), u);
                        }
                    }
                }
                models.insert(
                    name.clone(),
                    ModelMeta {
                        kind: m
                            .get("kind")
                            .and_then(Json::as_str)
                            .unwrap_or("lm")
                            .to_string(),
                        params,
                        config,
                        export_dtype: m
                            .get("export_dtype")
                            .and_then(Json::as_str)
                            .unwrap_or("f32")
                            .to_string(),
                    },
                );
            }
        }
        Ok(Manifest { artifacts, models })
    }

    /// Load from `dir/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let path = dir.as_ref().join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Artifact(format!(
                "cannot read {} — run `make artifacts` first ({e})",
                path.display()
            ))
        })?;
        Manifest::parse(&text)
    }

    /// Artifact lookup.
    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("no artifact '{name}'")))
    }

    /// Model preset lookup.
    pub fn model(&self, name: &str) -> Result<&ModelMeta> {
        self.models
            .get(name)
            .ok_or_else(|| Error::Artifact(format!("no model preset '{name}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "artifacts": {
        "k": {"file": "k.hlo.txt",
              "inputs": [{"shape": [8, 2], "dtype": "u16"}],
              "outputs": [{"shape": [8], "dtype": "u8"}, {"shape": [], "dtype": "f32"}]}
      },
      "models": {
        "lm_tiny": {"kind": "lm", "export_dtype": "bf16",
          "params": [{"name": "embed.weight", "shape": [128, 32], "dtype": "f32"}],
          "config": {"vocab": 128, "batch": 4}}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let a = m.artifact("k").unwrap();
        assert_eq!(a.inputs[0].shape, vec![8, 2]);
        assert_eq!(a.inputs[0].numel(), 16);
        assert_eq!(a.inputs[0].elem_bytes(), 2);
        assert_eq!(a.outputs[1].shape, Vec::<usize>::new());
        let lm = m.model("lm_tiny").unwrap();
        assert_eq!(lm.cfg("vocab").unwrap(), 128);
        assert_eq!(lm.params[0].name, "embed.weight");
        assert_eq!(lm.codec_dtype(), crate::fp::DType::BF16);
        assert!(m.artifact("nope").is_err());
        assert!(lm.cfg("nope").is_err());
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse("not json").is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        // Integration-lite: when `make artifacts` has run, the real
        // manifest must parse and contain the core artifacts.
        if let Ok(m) = Manifest::load("artifacts") {
            for name in [
                "byteplanes_bf16_split",
                "exp_hist_bf16",
                "xor_delta_u32",
                "lm_tiny_step",
                "cnn_tiny_step",
            ] {
                assert!(m.artifact(name).is_ok(), "{name}");
            }
            assert!(m.model("lm_small").is_ok());
        }
    }
}
