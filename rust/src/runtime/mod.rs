//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! Python runs once (`make artifacts`); afterwards this module is the only
//! bridge to the compiled computations. HLO **text** is the interchange
//! format (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1
//! rejects; the text parser reassigns ids — see /opt/xla-example/README.md).

pub mod client;
pub mod literal;
pub mod manifest;

pub use client::Runtime;
pub use literal::{literal_to_bytes, make_literal, make_scalar_f32, make_scalar_u32};
pub use manifest::{ArtifactSpec, Manifest, ModelMeta, TensorSpec};
