//! PJRT runtime: load AOT HLO-text artifacts and execute them from Rust.
//!
//! Python runs once (`make artifacts`); afterwards this module is the only
//! bridge to the compiled computations. HLO **text** is the interchange
//! format (jax ≥ 0.5 emits 64-bit-id protos that xla_extension 0.5.1
//! rejects; the text parser reassigns ids — see /opt/xla-example/README.md).
//!
//! The PJRT pieces ([`client`], [`literal`]) need the `xla` crate, which
//! is not on the offline registry: they are gated behind the `pjrt` cargo
//! feature (vendor the crate and enable the feature to use them). The
//! manifest parser is dependency-free and always available.

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod literal;
pub mod manifest;

#[cfg(feature = "pjrt")]
pub use client::Runtime;
#[cfg(feature = "pjrt")]
pub use literal::{literal_to_bytes, make_literal, make_scalar_f32, make_scalar_u32};
pub use manifest::{ArtifactSpec, Manifest, ModelMeta, TensorSpec};

/// Lazily load one tensor from a ZipNN-compressed model container
/// (`<model>.znnm.znn`): only the chunks covering the tensor (and the
/// model's JSON header) are decoded — over a mapped indexed container
/// this is random access, never a whole-model decompress. This is the
/// runtime-side hook for weight streaming: a trainer resuming a single
/// layer, or an inference server paging tensors in on first use, pulls
/// exactly what it needs from compressed storage.
pub fn load_tensor(
    path: impl AsRef<std::path::Path>,
    name: &str,
) -> crate::error::Result<crate::model::Tensor> {
    crate::model::read_tensor_znn(path, name)
}
