//! Literal construction/extraction helpers keyed by manifest dtype names.

use crate::error::{Error, Result};
use xla::{ElementType, Literal};

/// Build a literal of `dtype`/`shape` from raw little-endian bytes.
pub fn make_literal(dtype: &str, shape: &[usize], bytes: &[u8]) -> Result<Literal> {
    let ty = match dtype {
        "u8" => ElementType::U8,
        "u16" => ElementType::U16,
        "u32" => ElementType::U32,
        "i32" => ElementType::S32,
        "f32" => ElementType::F32,
        other => return Err(Error::Invalid(format!("unsupported dtype '{other}'"))),
    };
    let numel: usize = shape.iter().product();
    let elem = match ty {
        ElementType::U8 => 1,
        ElementType::U16 => 2,
        _ => 4,
    };
    if bytes.len() != numel * elem {
        return Err(Error::Invalid(format!(
            "literal {dtype}{shape:?} needs {} bytes, got {}",
            numel * elem,
            bytes.len()
        )));
    }
    Ok(Literal::create_from_shape_and_untyped_data(ty, shape, bytes)?)
}

/// Scalar f32 literal.
pub fn make_scalar_f32(v: f32) -> Literal {
    Literal::scalar(v)
}

/// Scalar u32 literal.
pub fn make_scalar_u32(v: u32) -> Literal {
    Literal::scalar(v)
}

/// Extract a literal's raw little-endian bytes.
pub fn literal_to_bytes(lit: &Literal) -> Result<Vec<u8>> {
    let ty = lit.ty()?;
    Ok(match ty {
        ElementType::U8 => lit.to_vec::<u8>()?,
        ElementType::U16 => lit
            .to_vec::<u16>()?
            .into_iter()
            .flat_map(|v| v.to_le_bytes())
            .collect(),
        ElementType::U32 => lit
            .to_vec::<u32>()?
            .into_iter()
            .flat_map(|v| v.to_le_bytes())
            .collect(),
        ElementType::S32 => lit
            .to_vec::<i32>()?
            .into_iter()
            .flat_map(|v| v.to_le_bytes())
            .collect(),
        ElementType::F32 => lit
            .to_vec::<f32>()?
            .into_iter()
            .flat_map(|v| v.to_le_bytes())
            .collect(),
        other => return Err(Error::Invalid(format!("unsupported literal type {other:?}"))),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn u16_roundtrip() {
        let bytes: Vec<u8> = (0..16).collect();
        let lit = make_literal("u16", &[8], &bytes).unwrap();
        assert_eq!(literal_to_bytes(&lit).unwrap(), bytes);
        assert_eq!(lit.element_count(), 8);
    }

    #[test]
    fn f32_roundtrip() {
        let vals = [1.5f32, -2.25, 0.0];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = make_literal("f32", &[3], &bytes).unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
    }

    #[test]
    fn wrong_size_rejected() {
        assert!(make_literal("u32", &[4], &[0u8; 15]).is_err());
        assert!(make_literal("f64", &[1], &[0u8; 8]).is_err());
    }

    #[test]
    fn scalars() {
        let l = make_scalar_f32(3.5);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![3.5]);
        let u = make_scalar_u32(7);
        assert_eq!(u.to_vec::<u32>().unwrap(), vec![7]);
    }
}
