//! The PJRT client wrapper: lazy-compiling artifact executor.

use crate::error::{Error, Result};
use crate::runtime::manifest::Manifest;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;
use xla::{Literal, PjRtClient, PjRtLoadedExecutable, XlaComputation};

/// Loads `artifacts/*.hlo.txt` on demand, compiles once per artifact, and
/// executes with positional literal inputs.
pub struct Runtime {
    client: PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    cache: Mutex<HashMap<String, PjRtLoadedExecutable>>,
}

impl Runtime {
    /// Open the artifacts directory (must contain `manifest.json`).
    pub fn open(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu()?;
        Ok(Runtime { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// The parsed manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (cached) the named artifact. Artifacts may be stored
    /// ZipNN-compressed (`<file>.znn`, either container format); those are
    /// decoded through a [`crate::codec::ZnnReader`] over a memory-mapped
    /// container (zero-copy payload reads) — the decompressed HLO text is
    /// spooled to a temp file for the PJRT text parser, never held in
    /// memory alongside it.
    fn executable(&self, name: &str) -> Result<()> {
        let mut cache = self.cache.lock().unwrap();
        if cache.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.artifact(name)?;
        let path = self.dir.join(&spec.file);
        let (text_path, cleanup) = if path.exists() {
            (path, None)
        } else {
            let znn = self.dir.join(format!("{}.znn", spec.file));
            if !znn.exists() {
                return Err(Error::Artifact(format!(
                    "artifact '{}' not found (neither {:?} nor {:?})",
                    name, path, znn
                )));
            }
            // Zero-copy fast path: map the container so decode reads the
            // compressed payload straight from the page cache (falls back
            // to a buffered read off-mmap or under ZIPNN_NO_MMAP=1).
            let mut reader = crate::codec::ZnnReader::open(&znn)?;
            // Unique, sanitized spool path: artifact names may contain
            // path separators, and two Runtimes in one process may
            // compile the same artifact concurrently.
            static SPOOL_SEQ: std::sync::atomic::AtomicU64 =
                std::sync::atomic::AtomicU64::new(0);
            let safe: String = name
                .chars()
                .map(|c| if c.is_ascii_alphanumeric() { c } else { '_' })
                .collect();
            let tmp = std::env::temp_dir().join(format!(
                "zipnn-artifact-{}-{}-{}.hlo.txt",
                std::process::id(),
                SPOOL_SEQ.fetch_add(1, std::sync::atomic::Ordering::Relaxed),
                safe
            ));
            let mut out = std::fs::File::create(&tmp)?;
            std::io::copy(&mut reader, &mut out)?;
            (tmp.clone(), Some(tmp))
        };
        let compile = || -> Result<PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(
                text_path
                    .to_str()
                    .ok_or_else(|| Error::Artifact("non-utf8 path".into()))?,
            )?;
            let comp = XlaComputation::from_proto(&proto);
            Ok(self.client.compile(&comp)?)
        };
        let exe = compile();
        if let Some(tmp) = cleanup {
            let _ = std::fs::remove_file(tmp);
        }
        cache.insert(name.to_string(), exe?);
        Ok(())
    }

    /// Execute an artifact with positional inputs; returns the decomposed
    /// output tuple (aot.py lowers everything with `return_tuple=True`).
    pub fn exec(&self, name: &str, inputs: &[Literal]) -> Result<Vec<Literal>> {
        let spec = self.manifest.artifact(name)?;
        if inputs.len() != spec.inputs.len() {
            return Err(Error::Invalid(format!(
                "{name}: {} inputs given, signature has {}",
                inputs.len(),
                spec.inputs.len()
            )));
        }
        self.executable(name)?;
        let cache = self.cache.lock().unwrap();
        let exe = cache.get(name).expect("just compiled");
        let result = exe.execute::<Literal>(inputs)?;
        let mut lit = result[0][0].to_literal_sync()?;
        let outs = lit.decompose_tuple()?;
        if outs.len() != spec.outputs.len() {
            return Err(Error::Xla(format!(
                "{name}: produced {} outputs, manifest says {}",
                outs.len(),
                spec.outputs.len()
            )));
        }
        Ok(outs)
    }

    /// Pre-compile a set of artifacts (warm-up; keeps first-step timing
    /// out of training loops).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }
}
