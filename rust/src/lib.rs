//! # ZipNN — lossless compression for AI models
//!
//! A reproduction of *"ZipNN: Lossless Compression for AI Models"*
//! (Hershcovitch et al., 2024) as a three-layer Rust + JAX + Pallas stack:
//!
//! - **Layer 3 (this crate)** — the ZipNN codec and pipeline: exponent
//!   extraction, byte grouping, a from-scratch length-limited canonical
//!   Huffman coder, per-chunk auto method selection, a parallel chunked
//!   container format, XOR delta compression with periodic bases, and a
//!   model-hub simulator.
//! - **Layer 2 (build-time JAX)** — training workloads (transformer LM,
//!   residual CNN) whose checkpoints/gradients/optimizer states are the
//!   paper's compression targets, AOT-lowered to HLO text.
//! - **Layer 1 (build-time Pallas)** — byte-plane / histogram / xor-delta /
//!   fused-linear kernels called by the L2 graphs.
//!
//! The [`runtime`] module loads the AOT artifacts via PJRT (`xla` crate) so
//! the Rust binary is self-contained after `make artifacts`; Python never
//! runs on the request path.
//!
//! ## Quick start
//!
//! ```no_run
//! use zipnn::codec::{Compressor, CodecConfig};
//! use zipnn::fp::DType;
//!
//! let raw: Vec<u8> = std::fs::read("model.bin").unwrap();
//! let cfg = CodecConfig::for_dtype(DType::BF16);
//! let compressed = Compressor::new(cfg).compress(&raw).unwrap();
//! let restored = zipnn::codec::decompress(&compressed).unwrap();
//! assert_eq!(raw, restored);
//! ```

pub mod bench_support;
pub mod codec;
pub mod coordinator;
pub mod delta;
pub mod error;
pub mod fp;
pub mod hub;
pub mod huffman;
pub mod lz;
pub mod model;
pub mod runtime;
pub mod stats;
pub mod train;
pub mod util;

pub use error::{Error, Result};
