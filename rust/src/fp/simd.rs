//! Runtime-dispatched SIMD kernels for the byte-group transpose.
//!
//! The k=2 and k=4 split/merge loops in [`bytegroup`](super::bytegroup) are
//! pure byte transposes (16×k or 32×k per vector step) — exactly the shape
//! shuffle/unpack units are built for. This module provides three
//! implementations behind one function-pointer table:
//!
//! - **scalar** — the reference. Always compiled, used as the proptest
//!   oracle, and selected when `ZIPNN_NO_SIMD` is set.
//! - **x86_64** — SSE2 (baseline, no detection needed) and AVX2 (selected
//!   via `is_x86_feature_detected!` once per process). split2 is a
//!   mask/shift + `packus` de-interleave; merge2 is `unpacklo/hi`; split4
//!   extracts each byte plane with shift+mask then re-packs dwords→bytes;
//!   merge4 is a two-level `unpack` interleave. The AVX2 variants add the
//!   cross-lane permutes (`permute4x64` / `permutevar8x32` /
//!   `permute2x128`) that repair the per-128-bit-lane semantics of the
//!   256-bit pack/unpack ops.
//! - **aarch64** — NEON `uzp1/uzp2` (split) and `zip1/zip2` (merge) trees.
//!
//! Kernels are **position-ordered**: `d<p>` holds byte `p` of every
//! element. The exponent-first stream ordering of `.znn` is applied by the
//! callers in `bytegroup.rs`, which map streams to positions around these
//! calls. Every kernel handles arbitrary lengths with a scalar tail; the
//! dispatch decision (env knob + CPUID) is made once and cached in a
//! `OnceLock`, so steady-state callers pay one atomic load.

use std::sync::OnceLock;

type Split2Fn = fn(&[u8], &mut [u8], &mut [u8]);
type Merge2Fn = fn(&[u8], &[u8], &mut [u8]);
type Split4Fn = fn(&[u8], &mut [u8], &mut [u8], &mut [u8], &mut [u8]);
type Merge4Fn = fn(&[u8], &[u8], &[u8], &[u8], &mut [u8]);

/// One ISA's kernel set. Obtain via [`dispatched`] (runtime-selected) or
/// [`scalar`] (the portable reference, also the test oracle).
pub struct Kernels {
    isa: &'static str,
    split2: Split2Fn,
    merge2: Merge2Fn,
    split4: Split4Fn,
    merge4: Merge4Fn,
}

impl Kernels {
    /// Name of the instruction set backing this kernel table
    /// (`"scalar"`, `"sse2"`, `"avx2"`, or `"neon"`).
    pub fn isa(&self) -> &'static str {
        self.isa
    }

    /// Split 2-byte elements into two position streams:
    /// `d0[i] = data[2i]`, `d1[i] = data[2i+1]`.
    pub fn split2(&self, data: &[u8], d0: &mut [u8], d1: &mut [u8]) {
        let n = d0.len();
        assert!(data.len() == 2 * n && d1.len() == n, "split2 length mismatch");
        (self.split2)(data, d0, d1);
    }

    /// Inverse of [`Kernels::split2`]: `out[2i] = s0[i]`, `out[2i+1] = s1[i]`.
    pub fn merge2(&self, s0: &[u8], s1: &[u8], out: &mut [u8]) {
        let n = s0.len();
        assert!(s1.len() == n && out.len() == 2 * n, "merge2 length mismatch");
        (self.merge2)(s0, s1, out);
    }

    /// Split 4-byte elements into four position streams:
    /// `d<p>[i] = data[4i+p]`.
    pub fn split4(&self, data: &[u8], d0: &mut [u8], d1: &mut [u8], d2: &mut [u8], d3: &mut [u8]) {
        let n = d0.len();
        assert!(
            data.len() == 4 * n && d1.len() == n && d2.len() == n && d3.len() == n,
            "split4 length mismatch"
        );
        (self.split4)(data, d0, d1, d2, d3);
    }

    /// Inverse of [`Kernels::split4`]: `out[4i+p] = s<p>[i]`.
    pub fn merge4(&self, s0: &[u8], s1: &[u8], s2: &[u8], s3: &[u8], out: &mut [u8]) {
        let n = s0.len();
        assert!(
            s1.len() == n && s2.len() == n && s3.len() == n && out.len() == 4 * n,
            "merge4 length mismatch"
        );
        (self.merge4)(s0, s1, s2, s3, out);
    }
}

static SCALAR: Kernels = Kernels {
    isa: "scalar",
    split2: split2_scalar,
    merge2: merge2_scalar,
    split4: split4_scalar,
    merge4: merge4_scalar,
};

static DISPATCH: OnceLock<&'static Kernels> = OnceLock::new();

/// The portable scalar kernel set — fallback, oracle, and the
/// `ZIPNN_NO_SIMD` target.
pub fn scalar() -> &'static Kernels {
    &SCALAR
}

/// The kernel set for this process: best detected ISA, or scalar when
/// `ZIPNN_NO_SIMD` is set. Decided once, cached for the process lifetime
/// (the env knob is read at first use, like `ZIPNN_NO_MMAP`).
pub fn dispatched() -> &'static Kernels {
    *DISPATCH.get_or_init(|| select(crate::util::env::no_simd()))
}

/// Dispatch decision, split out from the cache so tests can pin the
/// `no_simd` branch without racing on process-global env state.
fn select(no_simd: bool) -> &'static Kernels {
    if no_simd {
        return &SCALAR;
    }
    best_native()
}

#[cfg(target_arch = "x86_64")]
fn best_native() -> &'static Kernels {
    if std::arch::is_x86_feature_detected!("avx2") {
        &x86::AVX2
    } else {
        // SSE2 is part of the x86_64 baseline: always available.
        &x86::SSE2
    }
}

#[cfg(target_arch = "aarch64")]
fn best_native() -> &'static Kernels {
    if std::arch::is_aarch64_feature_detected!("neon") {
        &neon::NEON
    } else {
        &SCALAR
    }
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn best_native() -> &'static Kernels {
    &SCALAR
}

// --- scalar reference -------------------------------------------------------

fn split2_scalar(data: &[u8], d0: &mut [u8], d1: &mut [u8]) {
    for ((ch, a), b) in data.chunks_exact(2).zip(d0.iter_mut()).zip(d1.iter_mut()) {
        *a = ch[0];
        *b = ch[1];
    }
}

fn merge2_scalar(s0: &[u8], s1: &[u8], out: &mut [u8]) {
    for ((ch, a), b) in out.chunks_exact_mut(2).zip(s0.iter()).zip(s1.iter()) {
        ch[0] = *a;
        ch[1] = *b;
    }
}

fn split4_scalar(data: &[u8], d0: &mut [u8], d1: &mut [u8], d2: &mut [u8], d3: &mut [u8]) {
    for (i, ch) in data.chunks_exact(4).enumerate() {
        d0[i] = ch[0];
        d1[i] = ch[1];
        d2[i] = ch[2];
        d3[i] = ch[3];
    }
}

fn merge4_scalar(s0: &[u8], s1: &[u8], s2: &[u8], s3: &[u8], out: &mut [u8]) {
    for (i, ch) in out.chunks_exact_mut(4).enumerate() {
        ch[0] = s0[i];
        ch[1] = s1[i];
        ch[2] = s2[i];
        ch[3] = s3[i];
    }
}

// --- x86_64: SSE2 + AVX2 ----------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{merge2_scalar, merge4_scalar, split2_scalar, split4_scalar, Kernels};
    use std::arch::x86_64::*;

    pub(super) static SSE2: Kernels = Kernels {
        isa: "sse2",
        split2: split2_sse2,
        merge2: merge2_sse2,
        split4: split4_sse2,
        merge4: merge4_sse2,
    };

    pub(super) static AVX2: Kernels = Kernels {
        isa: "avx2",
        split2: split2_avx2,
        merge2: merge2_avx2,
        split4: split4_avx2,
        merge4: merge4_avx2,
    };

    // SSE2 is baseline on x86_64, so these wrappers are sound everywhere;
    // the AVX2 wrappers are sound because the dispatch table only installs
    // them after `is_x86_feature_detected!("avx2")`.

    fn split2_sse2(data: &[u8], d0: &mut [u8], d1: &mut [u8]) {
        unsafe { split2_sse2_impl(data, d0, d1) }
    }
    fn merge2_sse2(s0: &[u8], s1: &[u8], out: &mut [u8]) {
        unsafe { merge2_sse2_impl(s0, s1, out) }
    }
    fn split4_sse2(data: &[u8], d0: &mut [u8], d1: &mut [u8], d2: &mut [u8], d3: &mut [u8]) {
        unsafe { split4_sse2_impl(data, d0, d1, d2, d3) }
    }
    fn merge4_sse2(s0: &[u8], s1: &[u8], s2: &[u8], s3: &[u8], out: &mut [u8]) {
        unsafe { merge4_sse2_impl(s0, s1, s2, s3, out) }
    }
    fn split2_avx2(data: &[u8], d0: &mut [u8], d1: &mut [u8]) {
        unsafe { split2_avx2_impl(data, d0, d1) }
    }
    fn merge2_avx2(s0: &[u8], s1: &[u8], out: &mut [u8]) {
        unsafe { merge2_avx2_impl(s0, s1, out) }
    }
    fn split4_avx2(data: &[u8], d0: &mut [u8], d1: &mut [u8], d2: &mut [u8], d3: &mut [u8]) {
        unsafe { split4_avx2_impl(data, d0, d1, d2, d3) }
    }
    fn merge4_avx2(s0: &[u8], s1: &[u8], s2: &[u8], s3: &[u8], out: &mut [u8]) {
        unsafe { merge4_avx2_impl(s0, s1, s2, s3, out) }
    }

    #[target_feature(enable = "sse2")]
    unsafe fn split2_sse2_impl(data: &[u8], d0: &mut [u8], d1: &mut [u8]) {
        let n = d0.len();
        let lo8 = _mm_set1_epi16(0x00FF);
        let mut i = 0usize;
        while i + 16 <= n {
            let v0 = _mm_loadu_si128(data.as_ptr().add(2 * i).cast());
            let v1 = _mm_loadu_si128(data.as_ptr().add(2 * i + 16).cast());
            let ev = _mm_packus_epi16(_mm_and_si128(v0, lo8), _mm_and_si128(v1, lo8));
            let od = _mm_packus_epi16(_mm_srli_epi16::<8>(v0), _mm_srli_epi16::<8>(v1));
            _mm_storeu_si128(d0.as_mut_ptr().add(i).cast(), ev);
            _mm_storeu_si128(d1.as_mut_ptr().add(i).cast(), od);
            i += 16;
        }
        split2_scalar(&data[2 * i..], &mut d0[i..], &mut d1[i..]);
    }

    #[target_feature(enable = "sse2")]
    unsafe fn merge2_sse2_impl(s0: &[u8], s1: &[u8], out: &mut [u8]) {
        let n = s0.len();
        let mut i = 0usize;
        while i + 16 <= n {
            let a = _mm_loadu_si128(s0.as_ptr().add(i).cast());
            let b = _mm_loadu_si128(s1.as_ptr().add(i).cast());
            _mm_storeu_si128(out.as_mut_ptr().add(2 * i).cast(), _mm_unpacklo_epi8(a, b));
            _mm_storeu_si128(
                out.as_mut_ptr().add(2 * i + 16).cast(),
                _mm_unpackhi_epi8(a, b),
            );
            i += 16;
        }
        merge2_scalar(&s0[i..], &s1[i..], &mut out[2 * i..]);
    }

    #[target_feature(enable = "sse2")]
    unsafe fn split4_sse2_impl(
        data: &[u8],
        d0: &mut [u8],
        d1: &mut [u8],
        d2: &mut [u8],
        d3: &mut [u8],
    ) {
        let n = d0.len();
        let lo8 = _mm_set1_epi32(0xFF);
        let mut i = 0usize;
        while i + 16 <= n {
            let v0 = _mm_loadu_si128(data.as_ptr().add(4 * i).cast());
            let v1 = _mm_loadu_si128(data.as_ptr().add(4 * i + 16).cast());
            let v2 = _mm_loadu_si128(data.as_ptr().add(4 * i + 32).cast());
            let v3 = _mm_loadu_si128(data.as_ptr().add(4 * i + 48).cast());
            // Byte plane p of 16 u32 lanes: shift + mask leaves one byte
            // per dword (≤ 255, so the signed packs never saturates), then
            // dwords→words→bytes re-pack restores element order.
            for (p, dst) in [&mut *d0, &mut *d1, &mut *d2, &mut *d3].into_iter().enumerate() {
                let sh = 8 * p as i32;
                let x0 = _mm_and_si128(_mm_srl_epi32(v0, _mm_cvtsi32_si128(sh)), lo8);
                let x1 = _mm_and_si128(_mm_srl_epi32(v1, _mm_cvtsi32_si128(sh)), lo8);
                let x2 = _mm_and_si128(_mm_srl_epi32(v2, _mm_cvtsi32_si128(sh)), lo8);
                let x3 = _mm_and_si128(_mm_srl_epi32(v3, _mm_cvtsi32_si128(sh)), lo8);
                let r = _mm_packus_epi16(_mm_packs_epi32(x0, x1), _mm_packs_epi32(x2, x3));
                _mm_storeu_si128(dst.as_mut_ptr().add(i).cast(), r);
            }
            i += 16;
        }
        split4_scalar(
            &data[4 * i..],
            &mut d0[i..],
            &mut d1[i..],
            &mut d2[i..],
            &mut d3[i..],
        );
    }

    #[target_feature(enable = "sse2")]
    unsafe fn merge4_sse2_impl(s0: &[u8], s1: &[u8], s2: &[u8], s3: &[u8], out: &mut [u8]) {
        let n = s0.len();
        let mut i = 0usize;
        while i + 16 <= n {
            let b0 = _mm_loadu_si128(s0.as_ptr().add(i).cast());
            let b1 = _mm_loadu_si128(s1.as_ptr().add(i).cast());
            let b2 = _mm_loadu_si128(s2.as_ptr().add(i).cast());
            let b3 = _mm_loadu_si128(s3.as_ptr().add(i).cast());
            let a = _mm_unpacklo_epi8(b0, b1);
            let b = _mm_unpacklo_epi8(b2, b3);
            let c = _mm_unpackhi_epi8(b0, b1);
            let d = _mm_unpackhi_epi8(b2, b3);
            _mm_storeu_si128(out.as_mut_ptr().add(4 * i).cast(), _mm_unpacklo_epi16(a, b));
            _mm_storeu_si128(
                out.as_mut_ptr().add(4 * i + 16).cast(),
                _mm_unpackhi_epi16(a, b),
            );
            _mm_storeu_si128(
                out.as_mut_ptr().add(4 * i + 32).cast(),
                _mm_unpacklo_epi16(c, d),
            );
            _mm_storeu_si128(
                out.as_mut_ptr().add(4 * i + 48).cast(),
                _mm_unpackhi_epi16(c, d),
            );
            i += 16;
        }
        merge4_scalar(&s0[i..], &s1[i..], &s2[i..], &s3[i..], &mut out[4 * i..]);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn split2_avx2_impl(data: &[u8], d0: &mut [u8], d1: &mut [u8]) {
        let n = d0.len();
        let lo8 = _mm256_set1_epi16(0x00FF);
        let mut i = 0usize;
        while i + 32 <= n {
            let v0 = _mm256_loadu_si256(data.as_ptr().add(2 * i).cast());
            let v1 = _mm256_loadu_si256(data.as_ptr().add(2 * i + 32).cast());
            // 256-bit packus packs within each 128-bit lane; permute4x64
            // 0xD8 ([0,2,1,3]) restores linear order.
            let ev = _mm256_packus_epi16(_mm256_and_si256(v0, lo8), _mm256_and_si256(v1, lo8));
            let od = _mm256_packus_epi16(_mm256_srli_epi16::<8>(v0), _mm256_srli_epi16::<8>(v1));
            let ev = _mm256_permute4x64_epi64::<0xD8>(ev);
            let od = _mm256_permute4x64_epi64::<0xD8>(od);
            _mm256_storeu_si256(d0.as_mut_ptr().add(i).cast(), ev);
            _mm256_storeu_si256(d1.as_mut_ptr().add(i).cast(), od);
            i += 32;
        }
        split2_scalar(&data[2 * i..], &mut d0[i..], &mut d1[i..]);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn merge2_avx2_impl(s0: &[u8], s1: &[u8], out: &mut [u8]) {
        let n = s0.len();
        let mut i = 0usize;
        while i + 32 <= n {
            let a = _mm256_loadu_si256(s0.as_ptr().add(i).cast());
            let b = _mm256_loadu_si256(s1.as_ptr().add(i).cast());
            let lo = _mm256_unpacklo_epi8(a, b);
            let hi = _mm256_unpackhi_epi8(a, b);
            _mm256_storeu_si256(
                out.as_mut_ptr().add(2 * i).cast(),
                _mm256_permute2x128_si256::<0x20>(lo, hi),
            );
            _mm256_storeu_si256(
                out.as_mut_ptr().add(2 * i + 32).cast(),
                _mm256_permute2x128_si256::<0x31>(lo, hi),
            );
            i += 32;
        }
        merge2_scalar(&s0[i..], &s1[i..], &mut out[2 * i..]);
    }

    #[target_feature(enable = "avx2")]
    unsafe fn split4_avx2_impl(
        data: &[u8],
        d0: &mut [u8],
        d1: &mut [u8],
        d2: &mut [u8],
        d3: &mut [u8],
    ) {
        let n = d0.len();
        let lo8 = _mm256_set1_epi32(0xFF);
        // After the in-lane dword→byte packs the 8 result dwords sit in
        // order [0,2,4,6,1,3,5,7]; this permutevar index inverts that.
        let fix = _mm256_setr_epi32(0, 4, 1, 5, 2, 6, 3, 7);
        let mut i = 0usize;
        while i + 32 <= n {
            let v0 = _mm256_loadu_si256(data.as_ptr().add(4 * i).cast());
            let v1 = _mm256_loadu_si256(data.as_ptr().add(4 * i + 32).cast());
            let v2 = _mm256_loadu_si256(data.as_ptr().add(4 * i + 64).cast());
            let v3 = _mm256_loadu_si256(data.as_ptr().add(4 * i + 96).cast());
            for (p, dst) in [&mut *d0, &mut *d1, &mut *d2, &mut *d3].into_iter().enumerate() {
                let sh = _mm_cvtsi32_si128(8 * p as i32);
                let x0 = _mm256_and_si256(_mm256_srl_epi32(v0, sh), lo8);
                let x1 = _mm256_and_si256(_mm256_srl_epi32(v1, sh), lo8);
                let x2 = _mm256_and_si256(_mm256_srl_epi32(v2, sh), lo8);
                let x3 = _mm256_and_si256(_mm256_srl_epi32(v3, sh), lo8);
                let r = _mm256_packus_epi16(
                    _mm256_packs_epi32(x0, x1),
                    _mm256_packs_epi32(x2, x3),
                );
                let r = _mm256_permutevar8x32_epi32(r, fix);
                _mm256_storeu_si256(dst.as_mut_ptr().add(i).cast(), r);
            }
            i += 32;
        }
        split4_scalar(
            &data[4 * i..],
            &mut d0[i..],
            &mut d1[i..],
            &mut d2[i..],
            &mut d3[i..],
        );
    }

    #[target_feature(enable = "avx2")]
    unsafe fn merge4_avx2_impl(s0: &[u8], s1: &[u8], s2: &[u8], s3: &[u8], out: &mut [u8]) {
        let n = s0.len();
        let mut i = 0usize;
        while i + 32 <= n {
            let b0 = _mm256_loadu_si256(s0.as_ptr().add(i).cast());
            let b1 = _mm256_loadu_si256(s1.as_ptr().add(i).cast());
            let b2 = _mm256_loadu_si256(s2.as_ptr().add(i).cast());
            let b3 = _mm256_loadu_si256(s3.as_ptr().add(i).cast());
            let a = _mm256_unpacklo_epi8(b0, b1);
            let b = _mm256_unpacklo_epi8(b2, b3);
            let c = _mm256_unpackhi_epi8(b0, b1);
            let d = _mm256_unpackhi_epi8(b2, b3);
            let lo16a = _mm256_unpacklo_epi16(a, b);
            let hi16a = _mm256_unpackhi_epi16(a, b);
            let lo16c = _mm256_unpacklo_epi16(c, d);
            let hi16c = _mm256_unpackhi_epi16(c, d);
            _mm256_storeu_si256(
                out.as_mut_ptr().add(4 * i).cast(),
                _mm256_permute2x128_si256::<0x20>(lo16a, hi16a),
            );
            _mm256_storeu_si256(
                out.as_mut_ptr().add(4 * i + 32).cast(),
                _mm256_permute2x128_si256::<0x20>(lo16c, hi16c),
            );
            _mm256_storeu_si256(
                out.as_mut_ptr().add(4 * i + 64).cast(),
                _mm256_permute2x128_si256::<0x31>(lo16a, hi16a),
            );
            _mm256_storeu_si256(
                out.as_mut_ptr().add(4 * i + 96).cast(),
                _mm256_permute2x128_si256::<0x31>(lo16c, hi16c),
            );
            i += 32;
        }
        merge4_scalar(&s0[i..], &s1[i..], &s2[i..], &s3[i..], &mut out[4 * i..]);
    }
}

// --- aarch64: NEON ----------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{merge2_scalar, merge4_scalar, split2_scalar, split4_scalar, Kernels};
    use std::arch::aarch64::*;

    pub(super) static NEON: Kernels = Kernels {
        isa: "neon",
        split2: split2_neon,
        merge2: merge2_neon,
        split4: split4_neon,
        merge4: merge4_neon,
    };

    // Sound: the dispatch table only installs these after
    // `is_aarch64_feature_detected!("neon")`.

    fn split2_neon(data: &[u8], d0: &mut [u8], d1: &mut [u8]) {
        unsafe { split2_neon_impl(data, d0, d1) }
    }
    fn merge2_neon(s0: &[u8], s1: &[u8], out: &mut [u8]) {
        unsafe { merge2_neon_impl(s0, s1, out) }
    }
    fn split4_neon(data: &[u8], d0: &mut [u8], d1: &mut [u8], d2: &mut [u8], d3: &mut [u8]) {
        unsafe { split4_neon_impl(data, d0, d1, d2, d3) }
    }
    fn merge4_neon(s0: &[u8], s1: &[u8], s2: &[u8], s3: &[u8], out: &mut [u8]) {
        unsafe { merge4_neon_impl(s0, s1, s2, s3, out) }
    }

    #[target_feature(enable = "neon")]
    unsafe fn split2_neon_impl(data: &[u8], d0: &mut [u8], d1: &mut [u8]) {
        let n = d0.len();
        let mut i = 0usize;
        while i + 16 <= n {
            let v0 = vld1q_u8(data.as_ptr().add(2 * i));
            let v1 = vld1q_u8(data.as_ptr().add(2 * i + 16));
            vst1q_u8(d0.as_mut_ptr().add(i), vuzp1q_u8(v0, v1));
            vst1q_u8(d1.as_mut_ptr().add(i), vuzp2q_u8(v0, v1));
            i += 16;
        }
        split2_scalar(&data[2 * i..], &mut d0[i..], &mut d1[i..]);
    }

    #[target_feature(enable = "neon")]
    unsafe fn merge2_neon_impl(s0: &[u8], s1: &[u8], out: &mut [u8]) {
        let n = s0.len();
        let mut i = 0usize;
        while i + 16 <= n {
            let a = vld1q_u8(s0.as_ptr().add(i));
            let b = vld1q_u8(s1.as_ptr().add(i));
            vst1q_u8(out.as_mut_ptr().add(2 * i), vzip1q_u8(a, b));
            vst1q_u8(out.as_mut_ptr().add(2 * i + 16), vzip2q_u8(a, b));
            i += 16;
        }
        merge2_scalar(&s0[i..], &s1[i..], &mut out[2 * i..]);
    }

    #[target_feature(enable = "neon")]
    unsafe fn split4_neon_impl(
        data: &[u8],
        d0: &mut [u8],
        d1: &mut [u8],
        d2: &mut [u8],
        d3: &mut [u8],
    ) {
        let n = d0.len();
        let mut i = 0usize;
        while i + 16 <= n {
            let v0 = vld1q_u8(data.as_ptr().add(4 * i));
            let v1 = vld1q_u8(data.as_ptr().add(4 * i + 16));
            let v2 = vld1q_u8(data.as_ptr().add(4 * i + 32));
            let v3 = vld1q_u8(data.as_ptr().add(4 * i + 48));
            // Two uzp levels: first by byte parity, then by dword parity.
            let e0 = vuzp1q_u8(v0, v1);
            let e1 = vuzp1q_u8(v2, v3);
            let o0 = vuzp2q_u8(v0, v1);
            let o1 = vuzp2q_u8(v2, v3);
            vst1q_u8(d0.as_mut_ptr().add(i), vuzp1q_u8(e0, e1));
            vst1q_u8(d2.as_mut_ptr().add(i), vuzp2q_u8(e0, e1));
            vst1q_u8(d1.as_mut_ptr().add(i), vuzp1q_u8(o0, o1));
            vst1q_u8(d3.as_mut_ptr().add(i), vuzp2q_u8(o0, o1));
            i += 16;
        }
        split4_scalar(
            &data[4 * i..],
            &mut d0[i..],
            &mut d1[i..],
            &mut d2[i..],
            &mut d3[i..],
        );
    }

    #[target_feature(enable = "neon")]
    unsafe fn merge4_neon_impl(s0: &[u8], s1: &[u8], s2: &[u8], s3: &[u8], out: &mut [u8]) {
        let n = s0.len();
        let mut i = 0usize;
        while i + 16 <= n {
            let b0 = vld1q_u8(s0.as_ptr().add(i));
            let b1 = vld1q_u8(s1.as_ptr().add(i));
            let b2 = vld1q_u8(s2.as_ptr().add(i));
            let b3 = vld1q_u8(s3.as_ptr().add(i));
            let a_lo = vzip1q_u8(b0, b2);
            let a_hi = vzip2q_u8(b0, b2);
            let b_lo = vzip1q_u8(b1, b3);
            let b_hi = vzip2q_u8(b1, b3);
            vst1q_u8(out.as_mut_ptr().add(4 * i), vzip1q_u8(a_lo, b_lo));
            vst1q_u8(out.as_mut_ptr().add(4 * i + 16), vzip2q_u8(a_lo, b_lo));
            vst1q_u8(out.as_mut_ptr().add(4 * i + 32), vzip1q_u8(a_hi, b_hi));
            vst1q_u8(out.as_mut_ptr().add(4 * i + 48), vzip2q_u8(a_hi, b_hi));
            i += 16;
        }
        merge4_scalar(&s0[i..], &s1[i..], &s2[i..], &s3[i..], &mut out[4 * i..]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    /// Lengths hitting every regime: empty, sub-vector, one vector ± 1 for
    /// both the 16- and 32-element step sizes, and multi-vector + tail.
    const LENS: &[usize] = &[
        0, 1, 2, 3, 7, 15, 16, 17, 31, 32, 33, 63, 64, 65, 100, 1000, 4093,
    ];

    fn check_pair(k: &Kernels, s: &Kernels, n: usize, rng: &mut Xoshiro256) {
        // k = 2
        let mut data = vec![0u8; 2 * n];
        rng.fill_bytes(&mut data);
        let (mut a0, mut a1) = (vec![0u8; n], vec![0u8; n]);
        let (mut b0, mut b1) = (vec![0u8; n], vec![0u8; n]);
        k.split2(&data, &mut a0, &mut a1);
        s.split2(&data, &mut b0, &mut b1);
        assert_eq!(a0, b0, "split2 d0 n={n} isa={}", k.isa());
        assert_eq!(a1, b1, "split2 d1 n={n} isa={}", k.isa());
        let mut m_a = vec![0u8; 2 * n];
        let mut m_b = vec![0u8; 2 * n];
        k.merge2(&a0, &a1, &mut m_a);
        s.merge2(&a0, &a1, &mut m_b);
        assert_eq!(m_a, m_b, "merge2 n={n} isa={}", k.isa());
        assert_eq!(m_a, data, "merge2 roundtrip n={n} isa={}", k.isa());

        // k = 4
        let mut data = vec![0u8; 4 * n];
        rng.fill_bytes(&mut data);
        let mut a: Vec<Vec<u8>> = (0..4).map(|_| vec![0u8; n]).collect();
        let mut b: Vec<Vec<u8>> = (0..4).map(|_| vec![0u8; n]).collect();
        {
            let [a0, a1, a2, a3] = &mut a[..] else { unreachable!() };
            k.split4(&data, a0, a1, a2, a3);
            let [b0, b1, b2, b3] = &mut b[..] else { unreachable!() };
            s.split4(&data, b0, b1, b2, b3);
        }
        assert_eq!(a, b, "split4 n={n} isa={}", k.isa());
        let mut m_a = vec![0u8; 4 * n];
        let mut m_b = vec![0u8; 4 * n];
        k.merge4(&a[0], &a[1], &a[2], &a[3], &mut m_a);
        s.merge4(&a[0], &a[1], &a[2], &a[3], &mut m_b);
        assert_eq!(m_a, m_b, "merge4 n={n} isa={}", k.isa());
        assert_eq!(m_a, data, "merge4 roundtrip n={n} isa={}", k.isa());
    }

    #[test]
    fn dispatched_matches_scalar_oracle() {
        let mut rng = Xoshiro256::seed_from_u64(0x51D0);
        for &n in LENS {
            check_pair(dispatched(), scalar(), n, &mut rng);
        }
        // random lengths sweep the tail space more densely
        for _ in 0..200 {
            let n = rng.below(2048);
            check_pair(dispatched(), scalar(), n, &mut rng);
        }
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn every_x86_kernel_set_matches_scalar() {
        // Exercise SSE2 explicitly even when dispatch would pick AVX2.
        let mut rng = Xoshiro256::seed_from_u64(0x51D1);
        for &n in LENS {
            check_pair(&x86::SSE2, scalar(), n, &mut rng);
            if std::arch::is_x86_feature_detected!("avx2") {
                check_pair(&x86::AVX2, scalar(), n, &mut rng);
            }
        }
    }

    #[test]
    fn no_simd_knob_selects_scalar() {
        assert!(std::ptr::eq(select(true), scalar()));
        // The positive branch picks *some* table and never panics.
        assert!(!select(false).isa().is_empty());
    }

    #[test]
    fn scalar_split_is_definitional() {
        // Pin the position-ordered contract independent of the oracle role.
        let data: Vec<u8> = (0..40u8).collect();
        let mut d0 = vec![0u8; 10];
        let mut d1 = vec![0u8; 10];
        let mut d2 = vec![0u8; 10];
        let mut d3 = vec![0u8; 10];
        scalar().split4(&data, &mut d0, &mut d1, &mut d2, &mut d3);
        for i in 0..10 {
            assert_eq!(d0[i], 4 * i as u8);
            assert_eq!(d1[i], 4 * i as u8 + 1);
            assert_eq!(d2[i], 4 * i as u8 + 2);
            assert_eq!(d3[i], 4 * i as u8 + 3);
        }
    }
}
