//! Parameter dtypes (Figure 1 of the paper) and their bit layouts.

use crate::error::{Error, Result};

/// Parameter element type of a model tensor.
///
/// | dtype | sign | exponent | mantissa | exponent share |
/// |-------|------|----------|----------|----------------|
/// | FP32  | 1    | 8        | 23       | 1/4 of bytes   |
/// | BF16  | 1    | 8        | 7        | 1/2 of bytes   |
/// | FP16  | 1    | 5        | 10       | (in high byte) |
/// | F8*   | 1    | 4/5      | 3/2      | (single byte)  |
/// | I8/U8 | —    | —        | —        | quantized      |
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// IEEE-754 binary32.
    F32,
    /// bfloat16: FP32 with the mantissa truncated to 7 bits.
    BF16,
    /// IEEE-754 binary16.
    F16,
    /// 8-bit integer (quantized models).
    I8,
    /// fp8 E4M3 (OCP FP8 "e4m3fn": bias 7, no infinities, one NaN
    /// pattern `S.1111.111`, max finite ±448).
    F8E4M3,
    /// fp8 E5M2 (IEEE-like: bias 15, infinities at `S.11111.00`,
    /// NaN payloads above, max finite ±57344).
    F8E5M2,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::BF16 | DType::F16 => 2,
            DType::I8 | DType::F8E4M3 | DType::F8E5M2 => 1,
        }
    }

    /// Short lowercase name (container/manifest encoding).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::BF16 => "bf16",
            DType::F16 => "f16",
            DType::I8 => "i8",
            DType::F8E4M3 => "f8e4m3",
            DType::F8E5M2 => "f8e5m2",
        }
    }

    /// Parse from [`DType::name`] form.
    pub fn from_name(s: &str) -> Result<DType> {
        match s {
            "f32" | "fp32" | "float32" => Ok(DType::F32),
            "bf16" | "bfloat16" => Ok(DType::BF16),
            "f16" | "fp16" | "float16" => Ok(DType::F16),
            "i8" | "int8" | "u8" => Ok(DType::I8),
            "f8e4m3" | "fp8_e4m3" | "float8_e4m3fn" | "e4m3" => Ok(DType::F8E4M3),
            "f8e5m2" | "fp8_e5m2" | "float8_e5m2" | "e5m2" => Ok(DType::F8E5M2),
            other => Err(Error::Invalid(format!("unknown dtype '{other}'"))),
        }
    }

    /// Stable one-byte tag for container headers.
    pub fn tag(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::BF16 => 1,
            DType::F16 => 2,
            DType::I8 => 3,
            DType::F8E4M3 => 4,
            DType::F8E5M2 => 5,
        }
    }

    /// Inverse of [`DType::tag`].
    pub fn from_tag(t: u8) -> Result<DType> {
        match t {
            0 => Ok(DType::F32),
            1 => Ok(DType::BF16),
            2 => Ok(DType::F16),
            3 => Ok(DType::I8),
            4 => Ok(DType::F8E4M3),
            5 => Ok(DType::F8E5M2),
            other => Err(Error::Corrupt(format!("bad dtype tag {other}"))),
        }
    }

    /// Index (within one little-endian element) of the byte that carries
    /// the exponent bits — the "group 1" stream of the paper.
    ///
    /// - FP32: byte 3 = sign + exp[7:1] (high byte).
    /// - BF16: byte 1 = sign + exp[7:1] (high byte).
    /// - FP16: byte 1 = sign + exp[4:0] + mantissa[9:8].
    /// - I8/F8*: byte 0 (one-byte elements; single group — the fp8
    ///   exponent never leaves its own byte, so "grouping" degenerates
    ///   to a single Huffman stream over the raw bytes).
    pub fn exponent_byte(self) -> usize {
        match self {
            DType::F32 => 3,
            DType::BF16 | DType::F16 => 1,
            DType::I8 | DType::F8E4M3 | DType::F8E5M2 => 0,
        }
    }
}

/// Convert an `f32` to bfloat16 bits with round-to-nearest-even
/// (the conversion used when models are cast for inference, §2.2).
#[inline]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    // round-to-nearest-even on bit 16
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits + round) >> 16) as u16
}

/// Expand bfloat16 bits back to `f32` (exact).
#[inline]
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Convert an `f32` to IEEE binary16 bits, round-to-nearest-even, with
/// proper subnormal/overflow handling.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if man != 0 { 0x200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // subnormal or zero
        if e < -10 {
            return sign;
        }
        let man = man | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rounded = (man + half - 1 + ((man >> shift) & 1)) >> shift;
        return sign | rounded as u16;
    }
    let half = 0x0000_0FFF + ((man >> 13) & 1);
    let man_r = man + half;
    if man_r & 0x0080_0000 != 0 {
        // mantissa overflow bumps exponent
        let e = e + 1;
        if e >= 0x1F {
            return sign | 0x7C00;
        }
        return sign | ((e as u16) << 10);
    }
    sign | ((e as u16) << 10) | ((man_r >> 13) as u16)
}

/// Expand IEEE binary16 bits to `f32` (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: value = man * 2^-24; normalize the leading 1 away
            let p = 31 - man.leading_zeros(); // MSB position of man (0..=9)
            let exp = 103 + p; // 127 + (p - 24)
            let man_f32 = (man << (23 - p)) & 0x007F_FFFF;
            sign | (exp << 23) | man_f32
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

/// Convert an `f32` to fp8 E4M3 ("e4m3fn") bits, round-to-nearest-even.
///
/// E4M3 has no infinities: overflow (and f32 infinity) saturates to the
/// max finite ±448 = `S.1111.110`; f32 NaN maps to the single NaN
/// pattern `S.1111.111`.
pub fn f32_to_f8e4m3_bits(x: f32) -> u8 {
    let bits = x.to_bits();
    let sign = ((bits >> 24) & 0x80) as u8;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // NaN stays NaN; infinity saturates (e4m3fn has none).
        return if man != 0 { sign | 0x7F } else { sign | 0x7E };
    }
    let e = exp - 127 + 7;
    if e >= 16 {
        return sign | 0x7E; // overflow saturates to max finite
    }
    if e <= 0 {
        // subnormal or zero: smallest subnormal is 2^-9
        if e < -3 {
            return sign;
        }
        let man = man | 0x0080_0000; // implicit leading 1
        let shift = (21 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rounded = (man + half - 1 + ((man >> shift) & 1)) >> shift;
        return sign | rounded as u8;
    }
    let half = 0x0007_FFFF + ((man >> 20) & 1);
    let man_r = man + half;
    if man_r & 0x0080_0000 != 0 {
        // mantissa overflow bumps exponent
        let e = e + 1;
        if e >= 16 {
            return sign | 0x7E;
        }
        return sign | ((e as u8) << 3);
    }
    let m3 = (man_r >> 20) as u8;
    if e == 15 && m3 == 7 {
        return sign | 0x7E; // S.1111.111 is NaN, not a finite value
    }
    sign | ((e as u8) << 3) | m3
}

/// Expand fp8 E4M3 bits to `f32` (exact).
pub fn f8e4m3_bits_to_f32(b: u8) -> f32 {
    let sign = ((b & 0x80) as u32) << 24;
    let exp = ((b >> 3) & 0x0F) as u32;
    let man = (b & 0x07) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: value = man * 2^-9
            let p = 31 - man.leading_zeros(); // MSB position (0..=2)
            let e32 = 118 + p; // 127 + (p - 9)
            sign | (e32 << 23) | ((man << (23 - p)) & 0x007F_FFFF)
        }
    } else if exp == 0x0F && man == 0x07 {
        sign | 0x7FC0_0000 // the one NaN pattern
    } else {
        sign | ((exp + 120) << 23) | (man << 20)
    };
    f32::from_bits(bits)
}

/// Convert an `f32` to fp8 E5M2 bits, round-to-nearest-even, IEEE-style
/// (infinities at `S.11111.00`, NaN payloads above).
pub fn f32_to_f8e5m2_bits(x: f32) -> u8 {
    let bits = x.to_bits();
    let sign = ((bits >> 24) & 0x80) as u8;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C | if man != 0 { 0x02 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 31 {
        return sign | 0x7C; // overflow -> inf
    }
    if e <= 0 {
        // subnormal or zero: smallest subnormal is 2^-16
        if e < -2 {
            return sign;
        }
        let man = man | 0x0080_0000;
        let shift = (22 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rounded = (man + half - 1 + ((man >> shift) & 1)) >> shift;
        return sign | rounded as u8;
    }
    let half = 0x000F_FFFF + ((man >> 21) & 1);
    let man_r = man + half;
    if man_r & 0x0080_0000 != 0 {
        let e = e + 1;
        if e >= 31 {
            return sign | 0x7C;
        }
        return sign | ((e as u8) << 2);
    }
    sign | ((e as u8) << 2) | ((man_r >> 21) as u8)
}

/// Expand fp8 E5M2 bits to `f32` (exact).
pub fn f8e5m2_bits_to_f32(b: u8) -> f32 {
    let sign = ((b & 0x80) as u32) << 24;
    let exp = ((b >> 2) & 0x1F) as u32;
    let man = (b & 0x03) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: value = man * 2^-16
            let p = 31 - man.leading_zeros(); // MSB position (0..=1)
            let e32 = 111 + p; // 127 + (p - 16)
            sign | (e32 << 23) | ((man << (23 - p)) & 0x007F_FFFF)
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 21)
    } else {
        sign | ((exp + 112) << 23) | (man << 21)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrips() {
        for d in [
            DType::F32,
            DType::BF16,
            DType::F16,
            DType::I8,
            DType::F8E4M3,
            DType::F8E5M2,
        ] {
            assert_eq!(DType::from_tag(d.tag()).unwrap(), d);
            assert_eq!(DType::from_name(d.name()).unwrap(), d);
        }
        assert!(DType::from_tag(99).is_err());
        assert!(DType::from_name("f64").is_err());
    }

    #[test]
    fn bf16_roundtrip_exact_values() {
        for x in [0.0f32, 1.0, -1.0, 0.5, -0.0078125, 3.140625] {
            let b = f32_to_bf16_bits(x);
            let y = bf16_bits_to_f32(b);
            // Values representable in bf16 survive exactly.
            assert_eq!(f32_to_bf16_bits(y), b);
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between two bf16 values.
        let x = f32::from_bits(0x3F80_8000);
        let b = f32_to_bf16_bits(x);
        assert_eq!(b & 1, 0, "ties must go to even");
    }

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert!(f16_bits_to_f32(0x7E00).is_nan());
    }

    #[test]
    fn f16_roundtrip_representable() {
        let mut rng = crate::util::Xoshiro256::seed_from_u64(5);
        for _ in 0..10_000 {
            // random f16 bit pattern -> f32 -> f16 must be identity
            let h = (rng.next_u32() & 0xFFFF) as u16;
            let x = f16_bits_to_f32(h);
            if x.is_nan() {
                continue; // NaN payloads may differ
            }
            assert_eq!(f32_to_f16_bits(x), h, "h={h:#06x} x={x}");
        }
    }

    #[test]
    fn f16_subnormals() {
        let tiny = f16_bits_to_f32(0x0001); // smallest positive subnormal
        assert!(tiny > 0.0 && tiny < 1e-7);
        assert_eq!(f32_to_f16_bits(tiny), 0x0001);
    }

    #[test]
    fn f8e4m3_known_values() {
        assert_eq!(f32_to_f8e4m3_bits(0.0), 0x00);
        assert_eq!(f32_to_f8e4m3_bits(1.0), 0x38);
        assert_eq!(f32_to_f8e4m3_bits(-1.0), 0xB8);
        assert_eq!(f32_to_f8e4m3_bits(448.0), 0x7E); // max finite
        assert_eq!(f32_to_f8e4m3_bits(1000.0), 0x7E); // saturates: no inf
        assert_eq!(f32_to_f8e4m3_bits(f32::INFINITY), 0x7E);
        assert_eq!(f32_to_f8e4m3_bits(f32::NAN), 0x7F);
        assert_eq!(f8e4m3_bits_to_f32(0x38), 1.0);
        assert_eq!(f8e4m3_bits_to_f32(0x7E), 448.0);
        assert!(f8e4m3_bits_to_f32(0x7F).is_nan());
        assert!(f8e4m3_bits_to_f32(0xFF).is_nan());
        // smallest subnormal: 2^-9
        assert_eq!(f8e4m3_bits_to_f32(0x01), 0.001953125);
    }

    #[test]
    fn f8e5m2_known_values() {
        assert_eq!(f32_to_f8e5m2_bits(0.0), 0x00);
        assert_eq!(f32_to_f8e5m2_bits(1.0), 0x3C);
        assert_eq!(f32_to_f8e5m2_bits(-1.0), 0xBC);
        assert_eq!(f32_to_f8e5m2_bits(57344.0), 0x7B); // max finite
        assert_eq!(f32_to_f8e5m2_bits(1.0e6), 0x7C); // overflow -> inf
        assert_eq!(f32_to_f8e5m2_bits(f32::INFINITY), 0x7C);
        assert_eq!(f8e5m2_bits_to_f32(0x3C), 1.0);
        assert_eq!(f8e5m2_bits_to_f32(0x7C), f32::INFINITY);
        assert!(f8e5m2_bits_to_f32(0x7E).is_nan());
        assert!(f32_to_f8e5m2_bits(f32::NAN) & 0x03 != 0);
        // smallest subnormal: 2^-16
        assert_eq!(f8e5m2_bits_to_f32(0x01), 1.0 / 65536.0);
    }

    #[test]
    fn f8_roundtrip_all_bit_patterns() {
        // Every fp8 bit pattern -> f32 -> fp8 must be identity (NaN
        // payloads excepted; both formats collapse them to one pattern
        // per sign at most).
        for b in 0u16..=0xFF {
            let b = b as u8;
            let x = f8e4m3_bits_to_f32(b);
            if !x.is_nan() {
                assert_eq!(f32_to_f8e4m3_bits(x), b, "e4m3 b={b:#04x} x={x}");
            }
            let y = f8e5m2_bits_to_f32(b);
            if !y.is_nan() {
                assert_eq!(f32_to_f8e5m2_bits(y), b, "e5m2 b={b:#04x} y={y}");
            }
        }
    }

    #[test]
    fn f8_rounds_to_nearest_even() {
        // 1.0 + 2^-4 is exactly halfway between e4m3 values 0x38 and 0x39.
        assert_eq!(f32_to_f8e4m3_bits(1.0625), 0x38, "ties to even");
        // 1.0 + 2^-3 is exactly halfway between e5m2 values 0x3C and 0x3D.
        assert_eq!(f32_to_f8e5m2_bits(1.125), 0x3C, "ties to even");
        // just above the halfway point rounds up
        assert_eq!(f32_to_f8e4m3_bits(1.07), 0x39);
    }
}
