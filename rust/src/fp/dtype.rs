//! Parameter dtypes (Figure 1 of the paper) and their bit layouts.

use crate::error::{Error, Result};

/// Parameter element type of a model tensor.
///
/// | dtype | sign | exponent | mantissa | exponent share |
/// |-------|------|----------|----------|----------------|
/// | FP32  | 1    | 8        | 23       | 1/4 of bytes   |
/// | BF16  | 1    | 8        | 7        | 1/2 of bytes   |
/// | FP16  | 1    | 5        | 10       | (in high byte) |
/// | I8/U8 | —    | —        | —        | quantized      |
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    /// IEEE-754 binary32.
    F32,
    /// bfloat16: FP32 with the mantissa truncated to 7 bits.
    BF16,
    /// IEEE-754 binary16.
    F16,
    /// 8-bit integer (quantized models).
    I8,
}

impl DType {
    /// Size of one element in bytes.
    pub fn size(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::BF16 | DType::F16 => 2,
            DType::I8 => 1,
        }
    }

    /// Short lowercase name (container/manifest encoding).
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::BF16 => "bf16",
            DType::F16 => "f16",
            DType::I8 => "i8",
        }
    }

    /// Parse from [`DType::name`] form.
    pub fn from_name(s: &str) -> Result<DType> {
        match s {
            "f32" | "fp32" | "float32" => Ok(DType::F32),
            "bf16" | "bfloat16" => Ok(DType::BF16),
            "f16" | "fp16" | "float16" => Ok(DType::F16),
            "i8" | "int8" | "u8" => Ok(DType::I8),
            other => Err(Error::Invalid(format!("unknown dtype '{other}'"))),
        }
    }

    /// Stable one-byte tag for container headers.
    pub fn tag(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::BF16 => 1,
            DType::F16 => 2,
            DType::I8 => 3,
        }
    }

    /// Inverse of [`DType::tag`].
    pub fn from_tag(t: u8) -> Result<DType> {
        match t {
            0 => Ok(DType::F32),
            1 => Ok(DType::BF16),
            2 => Ok(DType::F16),
            3 => Ok(DType::I8),
            other => Err(Error::Corrupt(format!("bad dtype tag {other}"))),
        }
    }

    /// Index (within one little-endian element) of the byte that carries
    /// the exponent bits — the "group 1" stream of the paper.
    ///
    /// - FP32: byte 3 = sign + exp[7:1] (high byte).
    /// - BF16: byte 1 = sign + exp[7:1] (high byte).
    /// - FP16: byte 1 = sign + exp[4:0] + mantissa[9:8].
    /// - I8: byte 0 (no exponent; single group).
    pub fn exponent_byte(self) -> usize {
        match self {
            DType::F32 => 3,
            DType::BF16 | DType::F16 => 1,
            DType::I8 => 0,
        }
    }
}

/// Convert an `f32` to bfloat16 bits with round-to-nearest-even
/// (the conversion used when models are cast for inference, §2.2).
#[inline]
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    // round-to-nearest-even on bit 16
    let round = ((bits >> 16) & 1) + 0x7FFF;
    ((bits + round) >> 16) as u16
}

/// Expand bfloat16 bits back to `f32` (exact).
#[inline]
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// Convert an `f32` to IEEE binary16 bits, round-to-nearest-even, with
/// proper subnormal/overflow handling.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN
        return sign | 0x7C00 | if man != 0 { 0x200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1F {
        return sign | 0x7C00; // overflow -> inf
    }
    if e <= 0 {
        // subnormal or zero
        if e < -10 {
            return sign;
        }
        let man = man | 0x0080_0000; // implicit leading 1
        let shift = (14 - e) as u32;
        let half = 1u32 << (shift - 1);
        let rounded = (man + half - 1 + ((man >> shift) & 1)) >> shift;
        return sign | rounded as u16;
    }
    let half = 0x0000_0FFF + ((man >> 13) & 1);
    let man_r = man + half;
    if man_r & 0x0080_0000 != 0 {
        // mantissa overflow bumps exponent
        let e = e + 1;
        if e >= 0x1F {
            return sign | 0x7C00;
        }
        return sign | ((e as u16) << 10);
    }
    sign | ((e as u16) << 10) | ((man_r >> 13) as u16)
}

/// Expand IEEE binary16 bits to `f32` (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x3FF) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign
        } else {
            // subnormal: value = man * 2^-24; normalize the leading 1 away
            let p = 31 - man.leading_zeros(); // MSB position of man (0..=9)
            let exp = 103 + p; // 127 + (p - 24)
            let man_f32 = (man << (23 - p)) & 0x007F_FFFF;
            sign | (exp << 23) | man_f32
        }
    } else if exp == 0x1F {
        sign | 0x7F80_0000 | (man << 13)
    } else {
        sign | ((exp + 127 - 15) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_roundtrips() {
        for d in [DType::F32, DType::BF16, DType::F16, DType::I8] {
            assert_eq!(DType::from_tag(d.tag()).unwrap(), d);
            assert_eq!(DType::from_name(d.name()).unwrap(), d);
        }
        assert!(DType::from_tag(99).is_err());
        assert!(DType::from_name("f64").is_err());
    }

    #[test]
    fn bf16_roundtrip_exact_values() {
        for x in [0.0f32, 1.0, -1.0, 0.5, -0.0078125, 3.140625] {
            let b = f32_to_bf16_bits(x);
            let y = bf16_bits_to_f32(b);
            // Values representable in bf16 survive exactly.
            assert_eq!(f32_to_bf16_bits(y), b);
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1.0 + 2^-8 is exactly halfway between two bf16 values.
        let x = f32::from_bits(0x3F80_8000);
        let b = f32_to_bf16_bits(x);
        assert_eq!(b & 1, 0, "ties must go to even");
    }

    #[test]
    fn f16_known_values() {
        assert_eq!(f32_to_f16_bits(1.0), 0x3C00);
        assert_eq!(f32_to_f16_bits(-2.0), 0xC000);
        assert_eq!(f32_to_f16_bits(0.0), 0x0000);
        assert_eq!(f16_bits_to_f32(0x3C00), 1.0);
        assert_eq!(f16_bits_to_f32(0x7C00), f32::INFINITY);
        assert!(f16_bits_to_f32(0x7E00).is_nan());
    }

    #[test]
    fn f16_roundtrip_representable() {
        let mut rng = crate::util::Xoshiro256::seed_from_u64(5);
        for _ in 0..10_000 {
            // random f16 bit pattern -> f32 -> f16 must be identity
            let h = (rng.next_u32() & 0xFFFF) as u16;
            let x = f16_bits_to_f32(h);
            if x.is_nan() {
                continue; // NaN payloads may differ
            }
            assert_eq!(f32_to_f16_bits(x), h, "h={h:#06x} x={x}");
        }
    }

    #[test]
    fn f16_subnormals() {
        let tiny = f16_bits_to_f32(0x0001); // smallest positive subnormal
        assert!(tiny > 0.0 && tiny < 1e-7);
        assert_eq!(f32_to_f16_bits(tiny), 0x0001);
    }
}
