//! Floating-point format layouts and the ZipNN byte-group transforms.
//!
//! The paper's key observation (§3.1) is that the *exponent* byte of model
//! parameters is highly skewed while sign+mantissa bits are near-uniform.
//! ZipNN therefore rearranges parameter bytes into per-position streams
//! ("byte grouping", with the exponent-carrying group first) before entropy
//! coding each stream independently.

pub mod bytegroup;
pub mod dtype;
pub mod simd;
pub mod stats;

pub use bytegroup::{merge_groups, merge_groups_into, split_groups, split_groups_into, GroupLayout};
pub use dtype::DType;
