//! Exponent-distribution statistics (paper Figure 2) and entropy
//! estimators used by the analysis CLI and the Fig. 2 bench.

use crate::fp::{DType, GroupLayout};
use crate::stats::byte_histogram;

/// Histogram of the *exponent field value* (0–255) over all parameters.
///
/// For FP32/BF16 the 8-bit exponent straddles the top two bits of the high
/// byte pair: `exp = (bits >> (man_bits)) & 0xFF`. We reconstruct it from
/// raw little-endian element bytes.
pub fn exponent_histogram(data: &[u8], dtype: DType) -> [u64; 256] {
    let mut h = [0u64; 256];
    match dtype {
        DType::BF16 => {
            for ch in data.chunks_exact(2) {
                let bits = u16::from_le_bytes([ch[0], ch[1]]);
                h[((bits >> 7) & 0xFF) as usize] += 1;
            }
        }
        DType::F32 => {
            for ch in data.chunks_exact(4) {
                let bits = u32::from_le_bytes([ch[0], ch[1], ch[2], ch[3]]);
                h[((bits >> 23) & 0xFF) as usize] += 1;
            }
        }
        DType::F16 => {
            for ch in data.chunks_exact(2) {
                let bits = u16::from_le_bytes([ch[0], ch[1]]);
                h[((bits >> 10) & 0x1F) as usize] += 1;
            }
        }
        DType::I8 => {
            for &b in data {
                h[b as usize] += 1;
            }
        }
    }
    h
}

/// Summary of an exponent histogram, matching the paper's Fig. 2 claims
/// (~40 distinct values; top-12 covering ≈99.9%).
#[derive(Debug, Clone)]
pub struct ExponentSummary {
    /// Number of exponent values that actually occur.
    pub distinct: usize,
    /// Fraction of parameters covered by the top-12 most frequent values.
    pub top12_coverage: f64,
    /// Shannon entropy of the exponent distribution, bits/symbol.
    pub entropy_bits: f64,
    /// (value, count) sorted by descending count.
    pub top: Vec<(u8, u64)>,
}

/// Summarize an exponent histogram.
pub fn summarize_exponents(hist: &[u64; 256]) -> ExponentSummary {
    let total: u64 = hist.iter().sum();
    let distinct = hist.iter().filter(|&&c| c > 0).count();
    let mut top: Vec<(u8, u64)> = (0..256).map(|i| (i as u8, hist[i])).collect();
    top.sort_by(|a, b| b.1.cmp(&a.1));
    let top12: u64 = top.iter().take(12).map(|&(_, c)| c).sum();
    let entropy = shannon_entropy(hist);
    top.truncate(32);
    ExponentSummary {
        distinct,
        top12_coverage: if total == 0 { 0.0 } else { top12 as f64 / total as f64 },
        entropy_bits: entropy,
        top,
    }
}

/// Shannon entropy of a 256-bin histogram, in bits per symbol.
pub fn shannon_entropy(hist: &[u64; 256]) -> f64 {
    let total: u64 = hist.iter().sum();
    if total == 0 {
        return 0.0;
    }
    let tf = total as f64;
    let mut h = 0.0;
    for &c in hist {
        if c > 0 {
            let p = c as f64 / tf;
            h -= p * p.log2();
        }
    }
    h
}

/// Per-byte-group Shannon entropies of a raw tensor buffer — a fast
/// predictor of per-group compressibility (entropy/8 ≈ best-case ratio).
pub fn group_entropies(data: &[u8], layout: GroupLayout) -> Vec<f64> {
    crate::fp::split_groups(data, layout)
        .map(|groups| {
            groups
                .iter()
                .map(|g| shannon_entropy(&byte_histogram(g)))
                .collect()
        })
        .unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    /// Gaussian bf16 weights must reproduce the paper's Fig.2 shape:
    /// few distinct exponents, top-12 covering ≳99%.
    #[test]
    fn gaussian_bf16_exponent_is_skewed() {
        let mut rng = Xoshiro256::seed_from_u64(2);
        let mut bytes = Vec::with_capacity(2 * 100_000);
        for _ in 0..100_000 {
            let w = (rng.normal() * 0.02) as f32;
            bytes.extend_from_slice(&crate::fp::dtype::f32_to_bf16_bits(w).to_le_bytes());
        }
        let hist = exponent_histogram(&bytes, DType::BF16);
        let s = summarize_exponents(&hist);
        assert!(s.distinct < 70, "distinct={}", s.distinct);
        assert!(s.top12_coverage > 0.99, "top12={}", s.top12_coverage);
        assert!(s.entropy_bits < 4.0, "entropy={}", s.entropy_bits);
    }

    #[test]
    fn uniform_bytes_have_8bit_entropy() {
        let mut rng = Xoshiro256::seed_from_u64(4);
        let mut data = vec![0u8; 1 << 20];
        rng.fill_bytes(&mut data);
        let h = shannon_entropy(&byte_histogram(&data));
        assert!(h > 7.99, "h={h}");
    }

    #[test]
    fn constant_entropy_zero() {
        let data = vec![42u8; 4096];
        assert_eq!(shannon_entropy(&byte_histogram(&data)), 0.0);
    }

    #[test]
    fn f32_exponent_histogram_indexes_correctly() {
        // 1.0f32 has exponent 127.
        let one = 1.0f32.to_le_bytes().repeat(10);
        let h = exponent_histogram(&one, DType::F32);
        assert_eq!(h[127], 10);
        assert_eq!(h.iter().sum::<u64>(), 10);
    }

    #[test]
    fn group_entropy_distinguishes_exp_from_mantissa() {
        let mut rng = Xoshiro256::seed_from_u64(8);
        let mut bytes = Vec::new();
        for _ in 0..50_000 {
            let w = (rng.normal() * 0.05) as f32;
            bytes.extend_from_slice(&crate::fp::dtype::f32_to_bf16_bits(w).to_le_bytes());
        }
        let es = group_entropies(&bytes, GroupLayout::for_dtype(DType::BF16));
        // group 0 = exponent (skewed), group 1 = sign+mantissa (near random)
        assert!(es[0] < 5.0, "exp entropy {}", es[0]);
        assert!(es[1] > 7.0, "mantissa entropy {}", es[1]);
    }
}
