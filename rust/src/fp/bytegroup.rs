//! Byte grouping / exponent extraction (paper §3.1–§3.2, Figures 3 & 5).
//!
//! An array of `k`-byte elements is rearranged into `k` contiguous streams,
//! stream `g` holding byte `g` of every element. Grouping separates the
//! highly-skewed exponent byte from the near-random mantissa bytes so each
//! can be entropy-coded (or skipped) on its own. The transform is its own
//! inverse given the layout, and both directions are hot-path code.

use crate::error::{Error, Result};
use crate::fp::{simd, DType};

/// How elements are split into byte streams.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GroupLayout {
    /// Element size in bytes (= number of groups). 1 means "no grouping".
    pub elem: usize,
    /// Which group carries the exponent byte (little-endian index).
    pub exp_group: usize,
}

impl GroupLayout {
    /// Layout for a dtype: one group per element byte, exponent group
    /// flagged per Figure 3/5 (high byte for FP32/BF16/FP16). One-byte
    /// dtypes (I8, fp8 E4M3/E5M2) degenerate to the flat layout — the
    /// fp8 exponent never leaves its byte, so the win comes from a
    /// single Huffman stream over the skewed raw bytes, not transposes.
    pub fn for_dtype(d: DType) -> GroupLayout {
        GroupLayout { elem: d.size(), exp_group: d.exponent_byte() }
    }

    /// Ungrouped layout (whole bytes as a single stream).
    pub fn flat() -> GroupLayout {
        GroupLayout { elem: 1, exp_group: 0 }
    }

    /// Number of byte groups.
    pub fn groups(&self) -> usize {
        self.elem
    }
}

/// Split `data` into `layout.elem` per-byte-position streams.
///
/// `data.len()` must be a multiple of the element size. Group order in the
/// output is **exponent group first**, then the remaining byte positions in
/// ascending little-endian order — the on-disk stream order of `.znn`.
pub fn split_groups(data: &[u8], layout: GroupLayout) -> Result<Vec<Vec<u8>>> {
    let mut out: Vec<Vec<u8>> = Vec::new();
    split_groups_into(data, layout, &mut out)?;
    Ok(out)
}

/// [`split_groups`] into caller-provided buffers — the allocation-free
/// compression path. `out` is resized to `layout.groups()` vectors of
/// exactly `data.len() / elem` bytes each; existing capacity — and the
/// already-initialized bytes in it — is reused, so a steady-state caller
/// (the streaming codec's scratch arena, whose chunks are all the same
/// size) performs no allocations *and no zero-fills* after warm-up.
pub fn split_groups_into(data: &[u8], layout: GroupLayout, out: &mut Vec<Vec<u8>>) -> Result<()> {
    let k = layout.elem;
    if data.len() % k != 0 {
        return Err(Error::Invalid(format!(
            "buffer of {} bytes is not a multiple of element size {k}",
            data.len()
        )));
    }
    out.resize_with(k, Vec::new);
    let n = data.len() / k;
    for g in out.iter_mut() {
        set_group_len(g, n);
    }
    if k == 1 {
        out[0].copy_from_slice(data);
        return Ok(());
    }
    match k {
        2 => split2(data, layout, out),
        4 => split4(data, layout, out),
        _ => split_generic(data, layout, out),
    }
    Ok(())
}

/// Generic split for `elem` outside {1, 2, 4}: byte position `pos` of
/// every element feeds stream `map[pos]`. Container-valid layouts
/// (`elem <= 16`) use the stack-only map — no `group_order` allocation
/// per super-chunk; larger library-level layouts keep working through
/// the allocating inverse (off the codec hot path).
fn split_generic(data: &[u8], layout: GroupLayout, out: &mut [Vec<u8>]) {
    let k = layout.elem;
    let stack_map;
    let heap_map;
    let map: &[usize] = if k <= 16 {
        stack_map = pos_to_stream(layout);
        &stack_map[..k]
    } else {
        heap_map = pos_to_stream_vec(layout);
        &heap_map
    };
    for pos in 0..k {
        let dst = &mut out[map[pos]];
        for (i, chunk) in data.chunks_exact(k).enumerate() {
            dst[i] = chunk[pos];
        }
    }
}

/// Set a group buffer's length to exactly `n`, writing through spare
/// capacity: shrinking is a pure length set and growth zero-fills only
/// past the buffer's high-water mark. Callers must overwrite all `n`
/// bytes before reading them (every split/merge path here does, as does
/// the decode side's per-group scratch fill), so the per-chunk memset of
/// bytes about to be overwritten is skipped entirely in steady state.
pub(crate) fn set_group_len(g: &mut Vec<u8>, n: usize) {
    if g.len() < n {
        g.resize(n, 0);
    } else {
        g.truncate(n);
    }
}

/// Inverse of [`split_groups`]: interleave the streams back into elements.
pub fn merge_groups(groups: &[Vec<u8>], layout: GroupLayout) -> Result<Vec<u8>> {
    let refs: Vec<&[u8]> = groups.iter().map(|g| g.as_slice()).collect();
    let n: usize = refs.iter().map(|g| g.len()).sum();
    let mut out = vec![0u8; n];
    merge_groups_into(&refs, layout, &mut out)?;
    Ok(out)
}

/// [`merge_groups`] into a caller-provided buffer (`out.len()` must equal
/// the summed group lengths) — the allocation-free decompression path.
pub fn merge_groups_into(groups: &[&[u8]], layout: GroupLayout, out: &mut [u8]) -> Result<()> {
    let k = layout.elem;
    if groups.len() != k {
        return Err(Error::Invalid(format!(
            "expected {k} groups, got {}",
            groups.len()
        )));
    }
    if k == 1 {
        if out.len() != groups[0].len() {
            return Err(Error::Corrupt("merge output size mismatch".into()));
        }
        out.copy_from_slice(groups[0]);
        return Ok(());
    }
    let n = groups[0].len();
    for g in groups {
        if g.len() != n {
            return Err(Error::Corrupt("byte-group streams differ in length".into()));
        }
    }
    if out.len() != n * k {
        return Err(Error::Corrupt("merge output size mismatch".into()));
    }
    match k {
        2 => merge2(groups, layout, out),
        4 => merge4(groups, layout, out),
        _ => merge_generic(groups, layout, out),
    }
    Ok(())
}

/// Generic merge for `elem` outside {1, 2, 4}; mirrors [`split_generic`]
/// (stack map for `elem <= 16`, allocating inverse beyond).
fn merge_generic(groups: &[&[u8]], layout: GroupLayout, out: &mut [u8]) {
    let k = layout.elem;
    let stack_map;
    let heap_map;
    let map: &[usize] = if k <= 16 {
        stack_map = pos_to_stream(layout);
        &stack_map[..k]
    } else {
        heap_map = pos_to_stream_vec(layout);
        &heap_map
    };
    for pos in 0..k {
        let src = groups[map[pos]];
        for (i, chunk) in out.chunks_exact_mut(k).enumerate() {
            chunk[pos] = src[i];
        }
    }
}

/// Byte positions in on-disk stream order: exponent group first, then the
/// remaining byte positions in **descending** significance — matching the
/// paper's Table 2 breakdown order (exp, mantissa-high, ..., mantissa-low).
pub fn group_order(layout: GroupLayout) -> Vec<usize> {
    let mut order = vec![layout.exp_group];
    order.extend((0..layout.elem).rev().filter(|&p| p != layout.exp_group));
    order
}

/// Inverse of [`group_order`] as a fixed-size map (`elem` is validated to
/// be ≤ 16 by the container): `map[byte_position] = stream_index`. Stack
/// only — the per-chunk hot paths must not allocate.
fn pos_to_stream(layout: GroupLayout) -> [usize; 16] {
    let mut map = [0usize; 16];
    map[layout.exp_group] = 0;
    let mut gi = 1;
    for pos in (0..layout.elem).rev() {
        if pos != layout.exp_group {
            map[pos] = gi;
            gi += 1;
        }
    }
    map
}

/// [`pos_to_stream`] for layouts beyond the container's `elem <= 16`
/// ceiling (reachable only through the public split/merge API): the same
/// inverse, heap-allocated.
fn pos_to_stream_vec(layout: GroupLayout) -> Vec<usize> {
    let mut map = vec![0usize; layout.elem];
    for (gi, pos) in group_order(layout).into_iter().enumerate() {
        map[pos] = gi;
    }
    map
}

// --- specialized fast paths -------------------------------------------------
//
// The k=2 / k=4 bodies are pure byte transposes, so they route through the
// runtime-dispatched kernels in [`crate::fp::simd`] (AVX2/SSE2/NEON with a
// scalar fallback; `ZIPNN_NO_SIMD` forces scalar). Kernels are
// position-ordered — this layer's only job is mapping the exponent-first
// stream order onto byte positions before the call.

#[inline]
fn split2(data: &[u8], layout: GroupLayout, out: &mut [Vec<u8>]) {
    // stream 0 = exponent byte (hi for bf16/f16), stream 1 = the other.
    let hi_first = layout.exp_group == 1;
    let (a, b) = out.split_at_mut(1);
    let (g0, g1) = (&mut a[0][..], &mut b[0][..]);
    let k = simd::dispatched();
    if hi_first {
        k.split2(data, g1, g0);
    } else {
        k.split2(data, g0, g1);
    }
}

#[inline]
fn merge2(groups: &[&[u8]], layout: GroupLayout, out: &mut [u8]) {
    let hi_first = layout.exp_group == 1;
    let k = simd::dispatched();
    if hi_first {
        k.merge2(groups[1], groups[0], out);
    } else {
        k.merge2(groups[0], groups[1], out);
    }
}

#[inline]
fn split4(data: &[u8], layout: GroupLayout, out: &mut [Vec<u8>]) {
    let map = pos_to_stream(layout);
    // Split the output vector to get simultaneous &mut to all four streams,
    // then rearrange them so kernel slot `pos` receives stream `map[pos]`.
    let (o0, rest) = out.split_at_mut(1);
    let (o1, rest) = rest.split_at_mut(1);
    let (o2, o3) = rest.split_at_mut(1);
    let mut pos_of = [0usize; 4];
    for (pos, &stream) in map.iter().take(4).enumerate() {
        pos_of[stream] = pos;
    }
    let mut slot: [Option<&mut [u8]>; 4] = [None, None, None, None];
    let streams = [&mut o0[0][..], &mut o1[0][..], &mut o2[0][..], &mut o3[0][..]];
    for (stream, g) in streams.into_iter().enumerate() {
        slot[pos_of[stream]] = Some(g);
    }
    let [d0, d1, d2, d3] = slot.map(|s| s.unwrap());
    simd::dispatched().split4(data, d0, d1, d2, d3);
}

#[inline]
fn merge4(groups: &[&[u8]], layout: GroupLayout, out: &mut [u8]) {
    let map = pos_to_stream(layout);
    simd::dispatched().merge4(
        groups[map[0]],
        groups[map[1]],
        groups[map[2]],
        groups[map[3]],
        out,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Xoshiro256;

    fn roundtrip(layout: GroupLayout, data: &[u8]) {
        let groups = split_groups(data, layout).unwrap();
        assert_eq!(groups.len(), layout.groups());
        let back = merge_groups(&groups, layout).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn bf16_exponent_first() {
        // elements (le): [0x3F80, 0xBF00] -> bytes [80 3F 00 BF]
        let data = [0x80u8, 0x3F, 0x00, 0xBF];
        let layout = GroupLayout::for_dtype(DType::BF16);
        let groups = split_groups(&data, layout).unwrap();
        assert_eq!(groups[0], vec![0x3F, 0xBF], "exponent (hi) bytes first");
        assert_eq!(groups[1], vec![0x80, 0x00]);
        roundtrip(layout, &data);
    }

    #[test]
    fn fp8_layouts_are_flat() {
        for d in [DType::F8E4M3, DType::F8E5M2, DType::I8] {
            let layout = GroupLayout::for_dtype(d);
            assert_eq!(layout, GroupLayout::flat(), "{d:?}");
            let data = [0x38u8, 0xB8, 0x00, 0x7E];
            let groups = split_groups(&data, layout).unwrap();
            assert_eq!(groups.len(), 1);
            assert_eq!(groups[0], data);
            roundtrip(layout, &data);
        }
    }

    #[test]
    fn fp32_group_order() {
        // one element 0x11223344 (le bytes 44 33 22 11); exp byte = idx 3 = 0x11
        let data = [0x44u8, 0x33, 0x22, 0x11];
        let layout = GroupLayout::for_dtype(DType::F32);
        let groups = split_groups(&data, layout).unwrap();
        assert_eq!(groups[0], vec![0x11], "exponent byte first");
        assert_eq!(groups[1], vec![0x22], "then mantissa-high");
        assert_eq!(groups[2], vec![0x33]);
        assert_eq!(groups[3], vec![0x44], "mantissa-low last");
        roundtrip(layout, &data);
    }

    #[test]
    fn roundtrip_all_dtypes_random() {
        let mut rng = Xoshiro256::seed_from_u64(17);
        for d in [DType::F32, DType::BF16, DType::F16, DType::I8] {
            let layout = GroupLayout::for_dtype(d);
            for n in [0usize, 1, 7, 255, 4096] {
                let mut data = vec![0u8; n * d.size()];
                rng.fill_bytes(&mut data);
                roundtrip(layout, &data);
            }
        }
    }

    #[test]
    fn misaligned_rejected() {
        let layout = GroupLayout::for_dtype(DType::F32);
        assert!(split_groups(&[1, 2, 3], layout).is_err());
    }

    #[test]
    fn merge_validates() {
        let layout = GroupLayout::for_dtype(DType::BF16);
        assert!(merge_groups(&[vec![1]], layout).is_err());
        assert!(merge_groups(&[vec![1], vec![2, 3]], layout).is_err());
    }

    #[test]
    fn split_into_reuses_longer_buffers() {
        // The scratch-reuse contract: buffers left over from a *larger*
        // chunk (stale longer contents) must come back truncated to the
        // new length with fully overwritten bytes — no zero-fill relied
        // upon, no stale tail visible.
        let mut rng = Xoshiro256::seed_from_u64(23);
        for d in [DType::BF16, DType::F32] {
            let layout = GroupLayout::for_dtype(d);
            let mut scratch: Vec<Vec<u8>> = Vec::new();
            let mut big = vec![0u8; 64 * d.size()];
            rng.fill_bytes(&mut big);
            split_groups_into(&big, layout, &mut scratch).unwrap();
            for small_n in [64usize, 7, 1, 0, 33] {
                let mut small = vec![0u8; small_n * d.size()];
                rng.fill_bytes(&mut small);
                split_groups_into(&small, layout, &mut scratch).unwrap();
                assert!(scratch.iter().all(|g| g.len() == small_n));
                let back = merge_groups(&scratch, layout).unwrap();
                assert_eq!(back, small, "{d:?} n={small_n}");
            }
        }
    }

    #[test]
    fn generic_k_split_merge_roundtrips() {
        // elem outside {1, 2, 4}: the stack-map cold path. Pin both the
        // roundtrip and the on-disk stream order (exponent group first,
        // then descending byte positions).
        // elem 20 exceeds the container's 16-byte ceiling: only reachable
        // through the public API, served by the allocating inverse.
        let mut rng = Xoshiro256::seed_from_u64(29);
        for (elem, exp_group) in [(3usize, 2), (8, 5), (16, 0), (20, 11)] {
            let layout = GroupLayout { elem, exp_group };
            let mut data = vec![0u8; 45 * elem];
            rng.fill_bytes(&mut data);
            let groups = split_groups(&data, layout).unwrap();
            let order = group_order(layout);
            for (gi, &pos) in order.iter().enumerate() {
                let expect: Vec<u8> =
                    data.chunks_exact(elem).map(|ch| ch[pos]).collect();
                assert_eq!(groups[gi], expect, "elem={elem} stream {gi} (pos {pos})");
            }
            assert_eq!(merge_groups(&groups, layout).unwrap(), data, "elem={elem}");
        }
    }

    #[test]
    fn flat_layout_identity() {
        let data = vec![9u8; 100];
        let groups = split_groups(&data, GroupLayout::flat()).unwrap();
        assert_eq!(groups.len(), 1);
        assert_eq!(groups[0], data);
    }
}
