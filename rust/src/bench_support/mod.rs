//! Minimal benchmarking harness + table printers (criterion is not
//! available offline; `cargo bench` targets use `harness = false` and call
//! into this module to print the paper's tables/series).
//!
//! Besides timing, the harness reports **memory-shape** metrics so the
//! streaming codec's wins are visible in the bench trajectory:
//! [`peak_rss_kb`] (Linux `VmHWM`) and a process-wide allocation counter
//! ([`CountingAlloc`]) a bench binary opts into with
//! `#[global_allocator]`. Benches emit machine-readable results with
//! [`json_line`], one JSON object per line.

use crate::util::Timer;
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

/// Timing statistics of repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    /// Mean seconds.
    pub mean: f64,
    /// Standard deviation (seconds).
    pub std: f64,
    /// Fastest run.
    pub min: f64,
}

/// Run `f` `n` times (after one warm-up) and report timing stats.
pub fn time_n(n: usize, mut f: impl FnMut()) -> Stats {
    f(); // warm-up
    let mut samples = Vec::with_capacity(n);
    for _ in 0..n {
        let t = Timer::start();
        f();
        samples.push(t.secs());
    }
    let mean = samples.iter().sum::<f64>() / n as f64;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n as f64;
    Stats {
        mean,
        std: var.sqrt(),
        min: samples.iter().cloned().fold(f64::INFINITY, f64::min),
    }
}

/// Simple fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row.
    pub fn row(&mut self, cells: &[String]) {
        self.rows.push(cells.to_vec());
    }

    /// Render to stdout.
    pub fn print(&self) {
        let ncols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |ws: &[usize]| {
            let mut s = String::from("+");
            for w in ws {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        println!("{}", line(&widths));
        let mut hdr = String::from("|");
        for (h, w) in self.headers.iter().zip(&widths) {
            hdr.push_str(&format!(" {h:<w$} |"));
        }
        println!("{hdr}");
        println!("{}", line(&widths));
        for row in &self.rows {
            let mut s = String::from("|");
            for (c, w) in row.iter().zip(&widths) {
                s.push_str(&format!(" {c:<w$} |"));
            }
            println!("{s}");
        }
        println!("{}", line(&widths));
    }
}

/// Peak resident set size of this process in KiB (Linux `VmHWM`), `None`
/// where `/proc` is unavailable.
pub fn peak_rss_kb() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            return rest.trim().trim_end_matches("kB").trim().parse().ok();
        }
    }
    None
}

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

/// Counting global allocator: wraps [`System`] and counts every
/// allocation (and reallocation). A bench or test binary opts in with
///
/// ```ignore
/// #[global_allocator]
/// static ALLOC: zipnn::bench_support::CountingAlloc =
///     zipnn::bench_support::CountingAlloc;
/// ```
///
/// and samples [`alloc_count`] around the region of interest. This is how
/// the streaming codec's "allocations independent of input size" claim is
/// asserted.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
}

/// Total allocations since process start (0 unless the binary installed
/// [`CountingAlloc`] as its global allocator).
pub fn alloc_count() -> u64 {
    ALLOC_COUNT.load(Ordering::Relaxed)
}

/// Emit one machine-readable result line:
/// `{"bench":"<name>","<k>":<v>,...}`. Numeric values are printed with
/// enough precision for trend plots; strings pass through JSON-escaped
/// minimally (benches only use plain identifiers).
pub fn json_line(bench: &str, fields: &[(&str, f64)]) {
    let mut s = format!("{{\"bench\":\"{bench}\"");
    for (k, v) in fields {
        if !v.is_finite() {
            // inf/NaN are not valid JSON; a zero-duration division on a
            // coarse clock must not corrupt the result stream
            s.push_str(&format!(",\"{k}\":null"));
        } else if v.fract() == 0.0 && v.abs() < 1e15 {
            s.push_str(&format!(",\"{k}\":{}", *v as i64));
        } else {
            s.push_str(&format!(",\"{k}\":{v:.6}"));
        }
    }
    s.push('}');
    println!("{s}");
}

/// Bench environment knobs: scale factors via env vars so CI stays fast
/// while full runs match the paper's sizes.
pub struct BenchEnv {
    /// Megabytes per model buffer (default 32).
    pub model_mb: f64,
    /// Timing repetitions (default 3).
    pub reps: usize,
}

impl BenchEnv {
    /// Read `ZIPNN_BENCH_MB` / `ZIPNN_BENCH_REPS` from the environment.
    pub fn from_env() -> BenchEnv {
        let model_mb = std::env::var("ZIPNN_BENCH_MB")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(32.0);
        let reps = std::env::var("ZIPNN_BENCH_REPS")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(3);
        BenchEnv { model_mb, reps }
    }

    /// Byte budget for one synthetic model.
    pub fn model_bytes(&self) -> usize {
        (self.model_mb * 1024.0 * 1024.0) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_n_reports() {
        let s = time_n(3, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(s.mean >= 0.001);
        assert!(s.min <= s.mean);
    }

    #[test]
    fn table_renders() {
        let mut t = Table::new(&["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }

    #[test]
    fn peak_rss_present_on_linux() {
        if cfg!(target_os = "linux") {
            let kb = peak_rss_kb().expect("VmHWM on linux");
            assert!(kb > 0);
        }
    }

    #[test]
    fn json_line_smoke() {
        json_line("test", &[("a", 1.0), ("b", 2.5)]); // smoke: no panic
    }
}
