//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the ZipNN library.
#[derive(Error, Debug)]
pub enum Error {
    /// A container or stream failed structural validation.
    #[error("format error: {0}")]
    Format(String),

    /// Compressed data is corrupt (bad magic, truncated payload, checksum
    /// mismatch, impossible code, ...).
    #[error("corrupt data: {0}")]
    Corrupt(String),

    /// The operation's inputs are inconsistent (mismatched sizes, wrong
    /// dtype, delta between different-shaped models, ...).
    #[error("invalid input: {0}")]
    Invalid(String),

    /// An AOT artifact is missing or its manifest is inconsistent.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Underlying PJRT/XLA failure.
    #[error("xla error: {0}")]
    Xla(String),

    /// The hub is at capacity and shed this connection; the operation is
    /// safe to retry after a backoff.
    #[error("hub busy")]
    Busy,

    /// I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
