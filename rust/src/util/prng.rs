//! Deterministic, dependency-free PRNG (xoshiro256**) plus the sampling
//! helpers the synthetic-model generator and tests need (uniform, normal,
//! Zipf). Not cryptographic; reproducibility is the goal.

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed deterministically from a single `u64` via splitmix64.
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Xoshiro256 { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64 random bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32 random bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Fill a byte buffer with random bytes.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        let mut chunks = buf.chunks_exact_mut(8);
        for c in &mut chunks {
            c.copy_from_slice(&self.next_u64().to_le_bytes());
        }
        let rem = chunks.into_remainder();
        if !rem.is_empty() {
            let b = self.next_u64().to_le_bytes();
            rem.copy_from_slice(&b[..rem.len()]);
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

/// Zipf(α) sampler over `[0, n)` via precomputed CDF — models token
/// frequency skew for the embedding-gradient experiments (paper §4.1).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Build a sampler for `n` items with exponent `alpha` (≈1.0 for
    /// natural-language token distributions).
    pub fn new(n: usize, alpha: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(alpha);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    /// Draw one index in `[0, n)`.
    pub fn sample(&self, rng: &mut Xoshiro256) -> usize {
        let u = rng.uniform();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro256::seed_from_u64(42);
        let mut b = Xoshiro256::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_in_range() {
        let mut rng = Xoshiro256::seed_from_u64(1);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Xoshiro256::seed_from_u64(7);
        let n = 100_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn zipf_skewed() {
        let z = Zipf::new(1000, 1.0);
        let mut rng = Xoshiro256::seed_from_u64(3);
        let mut counts = vec![0usize; 1000];
        for _ in 0..50_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        // Head must dominate the tail.
        let head: usize = counts[..10].iter().sum();
        let tail: usize = counts[500..].iter().sum();
        assert!(head > tail, "head={head} tail={tail}");
        // Every sample in range is implied by indexing; top symbol most frequent.
        assert!(counts[0] >= *counts[1..].iter().max().unwrap() / 2);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Xoshiro256::seed_from_u64(9);
        let mut xs: Vec<u32> = (0..256).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..256).collect::<Vec<_>>());
        assert_ne!(xs, (0..256).collect::<Vec<_>>());
    }
}
