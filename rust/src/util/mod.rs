//! Small shared utilities: PRNG, bit I/O, JSON mini-parser, timers,
//! human-readable sizes, read-only memory mapping, and the `ZIPNN_*`
//! environment knobs ([`env`]).

pub mod bitio;
pub mod env;
pub mod human;
pub mod json;
pub mod mmap;
pub mod prng;
pub mod timer;

pub use human::human_bytes;
pub use prng::Xoshiro256;
pub use timer::Timer;

/// Read a little-endian `u32` from `buf` at `off`.
#[inline]
pub fn read_u32_le(buf: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([buf[off], buf[off + 1], buf[off + 2], buf[off + 3]])
}

/// Read a little-endian `u64` from `buf` at `off`.
#[inline]
pub fn read_u64_le(buf: &[u8], off: usize) -> u64 {
    let mut b = [0u8; 8];
    b.copy_from_slice(&buf[off..off + 8]);
    u64::from_le_bytes(b)
}

/// Append a little-endian `u32` to `out`.
#[inline]
pub fn push_u32_le(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Append a little-endian `u64` to `out`.
#[inline]
pub fn push_u64_le(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}
