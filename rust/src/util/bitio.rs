//! LSB-first bit-level writer/reader used by the Huffman coder.
//!
//! Convention: bits are accumulated into a `u64` from the low end
//! (`buf |= code << nbits`), and bytes are emitted little-endian. The
//! matching reader peeks the low `k` bits of its buffer. This is the same
//! orientation zstd/FSE use; it permits branch-light refills via unaligned
//! 64-bit loads.

/// Bit writer: append variable-width codes, LSB-first.
pub struct BitWriter {
    out: Vec<u8>,
    buf: u64,
    nbits: u32,
}

impl BitWriter {
    /// New writer with a capacity hint (in bytes).
    pub fn with_capacity(cap: usize) -> Self {
        BitWriter { out: Vec::with_capacity(cap), buf: 0, nbits: 0 }
    }

    /// Append the low `len` bits of `code`. `len` must be ≤ 24 so that two
    /// back-to-back writes never overflow the 64-bit buffer before a flush.
    #[inline(always)]
    pub fn put(&mut self, code: u32, len: u32) {
        debug_assert!(len <= 24);
        debug_assert!(len == 32 || code < (1 << len));
        self.buf |= (code as u64) << self.nbits;
        self.nbits += len;
        if self.nbits >= 32 {
            self.out.extend_from_slice(&(self.buf as u32).to_le_bytes());
            self.buf >>= 32;
            self.nbits -= 32;
        }
    }

    /// Number of complete bytes emitted so far (excluding the partial tail).
    pub fn bytes_written(&self) -> usize {
        self.out.len()
    }

    /// Flush the tail and return the byte stream.
    pub fn finish(mut self) -> Vec<u8> {
        while self.nbits > 0 {
            self.out.push(self.buf as u8);
            self.buf >>= 8;
            self.nbits = self.nbits.saturating_sub(8);
        }
        self.out
    }
}

/// Bit reader: peek/consume variable-width codes, LSB-first, with fast
/// unaligned 64-bit refills and a safe tail path.
pub struct BitReader<'a> {
    data: &'a [u8],
    /// Next byte to load into the buffer.
    pos: usize,
    buf: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    /// New reader over `data`.
    pub fn new(data: &'a [u8]) -> Self {
        let mut r = BitReader { data, pos: 0, buf: 0, nbits: 0 };
        r.refill();
        r
    }

    /// Top up the buffer to ≥ 56 valid bits (or everything left).
    #[inline(always)]
    pub fn refill(&mut self) {
        if self.pos + 8 <= self.data.len() {
            // Fast path: unaligned 64-bit load, then advance by the whole
            // bytes we actually consumed.
            let w = u64::from_le_bytes(self.data[self.pos..self.pos + 8].try_into().unwrap());
            self.buf |= w << self.nbits;
            let take = (63 - self.nbits) >> 3; // bytes that fit
            self.pos += take as usize;
            self.nbits += take * 8;
        } else {
            while self.nbits <= 56 && self.pos < self.data.len() {
                self.buf |= (self.data[self.pos] as u64) << self.nbits;
                self.pos += 1;
                self.nbits += 8;
            }
        }
    }

    /// Peek the low `len` bits without consuming. Bits past end-of-stream
    /// read as zero.
    #[inline(always)]
    pub fn peek(&self, len: u32) -> u32 {
        debug_assert!(len <= 32);
        (self.buf & ((1u64 << len) - 1)) as u32
    }

    /// Consume `len` bits.
    #[inline(always)]
    pub fn consume(&mut self, len: u32) {
        debug_assert!(len <= self.nbits, "consumed past refill window");
        self.buf >>= len;
        self.nbits -= len;
    }

    /// Read and consume `len` bits (refills as needed).
    #[inline]
    pub fn read(&mut self, len: u32) -> u32 {
        if self.nbits < len {
            self.refill();
        }
        let v = self.peek(len);
        self.consume(len);
        v
    }

    /// Valid bits currently buffered.
    #[inline]
    pub fn available(&self) -> u32 {
        self.nbits
    }

    /// True when the underlying stream and the buffer are both exhausted.
    pub fn is_empty(&self) -> bool {
        self.pos >= self.data.len() && self.nbits == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Xoshiro256;

    #[test]
    fn roundtrip_fixed_widths() {
        let mut w = BitWriter::with_capacity(64);
        for i in 0..1000u32 {
            w.put(i & 0x7F, 7);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for i in 0..1000u32 {
            assert_eq!(r.read(7), i & 0x7F);
        }
    }

    #[test]
    fn roundtrip_mixed_widths() {
        let mut rng = Xoshiro256::seed_from_u64(11);
        let items: Vec<(u32, u32)> = (0..5000)
            .map(|_| {
                let len = 1 + (rng.next_u32() % 20);
                let code = rng.next_u32() & ((1u32 << len) - 1);
                (code, len)
            })
            .collect();
        let mut w = BitWriter::with_capacity(1024);
        for &(c, l) in &items {
            w.put(c, l);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(c, l) in &items {
            assert_eq!(r.read(l), c, "len={l}");
        }
    }

    #[test]
    fn empty_stream() {
        let w = BitWriter::with_capacity(0);
        let bytes = w.finish();
        assert!(bytes.is_empty());
        let r = BitReader::new(&bytes);
        assert!(r.is_empty());
    }

    #[test]
    fn peek_does_not_consume() {
        let mut w = BitWriter::with_capacity(8);
        w.put(0b1011, 4);
        w.put(0b01, 2);
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.peek(4), 0b1011);
        assert_eq!(r.peek(4), 0b1011);
        r.consume(4);
        assert_eq!(r.read(2), 0b01);
    }
}
