//! Minimal JSON parser for the AOT artifact manifest.
//!
//! `serde_json` is not available offline, and the manifest is small and
//! machine-generated, so a ~200-line recursive-descent parser suffices.
//! Supports the full JSON grammar minus `\uXXXX` surrogate pairs (the
//! manifest is ASCII).

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a JSON document.
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    /// Object field access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// String value.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Numeric value.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric value as usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    /// Array items.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Object map.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    s.push(match c {
                        b'n' => '\n',
                        b't' => '\t',
                        b'r' => '\r',
                        b'"' => '"',
                        b'\\' => '\\',
                        b'/' => '/',
                        b'b' => '\u{8}',
                        b'f' => '\u{c}',
                        _ => return Err(format!("unsupported escape at byte {}", self.i)),
                    });
                }
                Some(c) => {
                    // Pass UTF-8 bytes through verbatim.
                    s.push(c as char);
                    self.i += 1;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like() {
        let doc = r#"{
          "artifacts": [
            {"name": "lm_step", "file": "lm_step.hlo.txt",
             "inputs": [{"shape": [4, 8], "dtype": "f32"}],
             "outputs": [{"shape": [], "dtype": "f32"}]}
          ],
          "version": 1, "ok": true, "missing": null
        }"#;
        let j = Json::parse(doc).unwrap();
        assert_eq!(j.get("version").unwrap().as_usize(), Some(1));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("lm_step"));
        let shape = arts[0].get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[1].as_usize(), Some(8));
        assert_eq!(j.get("missing"), Some(&Json::Null));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-1.5e3").unwrap().as_f64(), Some(-1500.0));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""a\nb\"c""#).unwrap();
        assert_eq!(j.as_str(), Some("a\nb\"c"));
    }
}
