//! Dependency-free read-only memory mapping.
//!
//! Like [`crate::hub::sys`], this module declares the handful of libc
//! symbols it needs directly (the C library is already linked by `std`)
//! instead of pulling in a crate. It provides exactly what the zero-copy
//! decode path needs: map a file read-only, hand out a `&[u8]`, drop the
//! mapping, and issue best-effort prefetch hints.
//!
//! Non-Unix platforms get no mapping support ([`Mmap::map`] returns
//! `Unsupported`); callers such as [`crate::codec::ByteSource::open`]
//! fall back to plain buffered streaming, so the fast path degrades
//! instead of failing.
//!
//! ## Safety contract
//!
//! A mapping is only as stable as its backing file: if another process
//! truncates the file while it is mapped, touching the vanished pages
//! raises `SIGBUS`. The callers in this crate map files they own (spool
//! files are unlinked right after mapping) or that the operator points
//! them at; `ZIPNN_NO_MMAP=1` disables mapping everywhere for
//! environments where that contract cannot hold.

use std::fs::File;
use std::io;

/// A read-only memory mapping of an entire file.
///
/// Dereferences to `&[u8]`. The mapping is private (`MAP_PRIVATE`) and
/// never written through, so sharing it across threads is sound.
pub struct Mmap {
    /// Base address (dangling for the empty mapping, which mmap rejects).
    ptr: *mut u8,
    len: usize,
}

// SAFETY: the mapping is created PROT_READ and this type exposes no
// mutation; concurrent reads of immutable pages are safe.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `file` read-only in its entirety.
    ///
    /// Empty files yield an empty mapping without calling `mmap(2)`
    /// (the syscall rejects zero lengths). On non-Unix platforms this
    /// returns `ErrorKind::Unsupported`.
    pub fn map(file: &File) -> io::Result<Mmap> {
        let len = file.metadata()?.len();
        if len == 0 {
            return Ok(Mmap { ptr: std::ptr::NonNull::<u8>::dangling().as_ptr(), len: 0 });
        }
        let len = usize::try_from(len).map_err(|_| {
            io::Error::new(io::ErrorKind::InvalidInput, "file too large to map")
        })?;
        sys::map_file(file, len)
    }

    /// The mapped bytes.
    pub fn as_slice(&self) -> &[u8] {
        // SAFETY: `ptr` is either a live PROT_READ mapping of `len` bytes
        // (until Drop) or dangling with len == 0.
        unsafe { std::slice::from_raw_parts(self.ptr, self.len) }
    }

    /// Mapped length in bytes.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the mapping is empty.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Best-effort `madvise(MADV_SEQUENTIAL)` over the whole mapping:
    /// tells the kernel to read ahead aggressively and drop pages behind
    /// the cursor. Ignored on error or off Unix.
    pub fn advise_sequential(&self) {
        if self.len > 0 {
            sys::advise(self.ptr, 0, self.len, sys::Advice::Sequential);
        }
    }

    /// Best-effort `madvise(MADV_WILLNEED)` on `[off, off + len)`: starts
    /// the page-in of an upcoming range so decode does not stall on
    /// faults. Out-of-range portions are clamped; errors are ignored.
    pub fn advise_willneed(&self, off: usize, len: usize) {
        if off >= self.len || len == 0 {
            return;
        }
        let len = len.min(self.len - off);
        sys::advise(self.ptr, off, len, sys::Advice::WillNeed);
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        if self.len > 0 {
            sys::unmap(self.ptr, self.len);
        }
    }
}

impl std::ops::Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;
    use std::fs::File;
    use std::io;
    use std::os::unix::io::AsRawFd;

    use super::Mmap;

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;
    const MADV_SEQUENTIAL: i32 = 2;
    const MADV_WILLNEED: i32 = 3;
    /// Assumed lower bound on the page size for hint alignment; madvise
    /// needs a page-aligned address, and every supported platform uses
    /// pages of at least 4 KiB (hints on a coarser grain are still valid).
    const PAGE: usize = 4096;

    extern "C" {
        fn mmap(
            addr: *mut c_void,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut c_void;
        fn munmap(addr: *mut c_void, len: usize) -> i32;
        fn madvise(addr: *mut c_void, len: usize, advice: i32) -> i32;
    }

    pub(super) enum Advice {
        Sequential,
        WillNeed,
    }

    pub(super) fn map_file(file: &File, len: usize) -> io::Result<Mmap> {
        // SAFETY: plain mmap of a readable fd; the result is checked
        // against MAP_FAILED before use.
        let ptr = unsafe {
            mmap(
                std::ptr::null_mut(),
                len,
                PROT_READ,
                MAP_PRIVATE,
                file.as_raw_fd(),
                0,
            )
        };
        if ptr == usize::MAX as *mut c_void {
            return Err(io::Error::last_os_error());
        }
        Ok(Mmap { ptr: ptr as *mut u8, len })
    }

    pub(super) fn unmap(ptr: *mut u8, len: usize) {
        // SAFETY: `ptr/len` came from a successful mmap and are unmapped
        // exactly once (Drop).
        unsafe {
            munmap(ptr as *mut c_void, len);
        }
    }

    pub(super) fn advise(base: *mut u8, off: usize, len: usize, advice: Advice) {
        let advice = match advice {
            Advice::Sequential => MADV_SEQUENTIAL,
            Advice::WillNeed => MADV_WILLNEED,
        };
        // Round the start down to a page boundary (madvise requires an
        // aligned address); extend the length to cover the original range.
        let aligned = off & !(PAGE - 1);
        let len = len + (off - aligned);
        // SAFETY: the range lies within the live mapping (clamped by the
        // caller); madvise is a hint and its failure is ignored.
        unsafe {
            madvise(base.add(aligned) as *mut c_void, len, advice);
        }
    }
}

#[cfg(not(unix))]
mod sys {
    use std::fs::File;
    use std::io;

    use super::Mmap;

    pub(super) enum Advice {
        Sequential,
        WillNeed,
    }

    pub(super) fn map_file(_file: &File, _len: usize) -> io::Result<Mmap> {
        Err(io::Error::new(
            io::ErrorKind::Unsupported,
            "memory mapping is not supported on this platform",
        ))
    }

    pub(super) fn unmap(_ptr: *mut u8, _len: usize) {}

    pub(super) fn advise(_base: *mut u8, _off: usize, _len: usize, _advice: Advice) {}
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;
    use std::path::PathBuf;
    use std::sync::atomic::{AtomicU64, Ordering};

    fn tmp_file(tag: &str, contents: &[u8]) -> PathBuf {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let path = std::env::temp_dir().join(format!(
            "zipnn-mmap-test-{}-{}-{tag}",
            std::process::id(),
            SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        let mut f = File::create(&path).unwrap();
        f.write_all(contents).unwrap();
        path
    }

    #[test]
    fn maps_file_contents() {
        let data: Vec<u8> = (0..100_000u32).map(|i| (i % 251) as u8).collect();
        let path = tmp_file("contents", &data);
        {
            let file = File::open(&path).unwrap();
            let map = Mmap::map(&file).unwrap();
            assert_eq!(map.len(), data.len());
            assert_eq!(&map[..], &data[..]);
            // hints must be harmless anywhere in (or past) the range
            map.advise_sequential();
            map.advise_willneed(0, map.len());
            map.advise_willneed(4097, 123);
            map.advise_willneed(map.len(), 1);
            map.advise_willneed(0, 0);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn empty_file_maps_empty() {
        let path = tmp_file("empty", b"");
        {
            let file = File::open(&path).unwrap();
            let map = Mmap::map(&file).unwrap();
            assert!(map.is_empty());
            assert_eq!(&map[..], b"");
            map.advise_sequential();
            map.advise_willneed(0, 1);
        }
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn mapping_outlives_unlink() {
        // The spool path relies on this: map, unlink, keep reading.
        let data = vec![0xA5u8; 64 * 1024];
        let path = tmp_file("unlink", &data);
        let file = File::open(&path).unwrap();
        let map = Mmap::map(&file).unwrap();
        drop(file);
        std::fs::remove_file(&path).unwrap();
        assert_eq!(&map[..], &data[..]);
    }

    #[test]
    fn shared_across_threads() {
        let data: Vec<u8> = (0..32_768u32).map(|i| (i * 7 % 256) as u8).collect();
        let path = tmp_file("threads", &data);
        let file = File::open(&path).unwrap();
        let map = std::sync::Arc::new(Mmap::map(&file).unwrap());
        let mut handles = Vec::new();
        for t in 0..4 {
            let m = std::sync::Arc::clone(&map);
            let expect = data.clone();
            handles.push(std::thread::spawn(move || {
                let lo = t * 8192;
                assert_eq!(&m[lo..lo + 8192], &expect[lo..lo + 8192]);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        std::fs::remove_file(&path).unwrap();
    }
}
