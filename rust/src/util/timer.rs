//! Wall-clock timing helper for benches and metrics.

use std::time::Instant;

/// Simple wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    /// Start timing now.
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Seconds elapsed since start.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Restart and return the elapsed seconds of the previous lap.
    pub fn lap(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Time a closure, returning `(result, seconds)`.
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

#[cfg(test)]
mod tests {
    #[test]
    fn timed_returns_result() {
        let (v, s) = super::timed(|| 41 + 1);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
