//! Human-readable byte sizes and rates for CLI / bench output.

/// Format a byte count as `"1.23 GB"` style.
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KB", "MB", "GB", "TB", "PB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u + 1 < UNITS.len() {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format a throughput in GB/s from bytes and seconds.
pub fn gbps(bytes: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    bytes as f64 / secs / 1e9
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MB");
    }

    #[test]
    fn rate() {
        assert!((gbps(2_000_000_000, 2.0) - 1.0).abs() < 1e-9);
        assert_eq!(gbps(100, 0.0), 0.0);
    }
}
