//! The `ZIPNN_*` environment knobs, in one place.
//!
//! Every runtime tunable the library reads from the environment lives
//! here as a typed accessor, so call sites never re-parse strings and
//! the full surface stays documented in a single table:
//!
//! | Variable                | Type  | Effect                                             |
//! |-------------------------|-------|----------------------------------------------------|
//! | `ZIPNN_NO_SIMD`         | set?  | Force the scalar byte-group transpose kernels      |
//! | `ZIPNN_NO_MMAP`         | set?  | Disable memory-mapped I/O (streaming fallback)     |
//! | `ZIPNN_DECODE_WORKERS`  | usize | Shared-pool size (decode side sets the base)       |
//! | `ZIPNN_ENCODE_WORKERS`  | usize | Encode worker count; can only raise the pool size  |
//! | `ZIPNN_HUB_WORKERS`     | usize | Hub reactor worker threads (default ncpu, max 16)  |
//! | `ZIPNN_HUB_MAX_CONNS`   | usize | Hub concurrent-connection cap (default 4096)       |
//! | `ZIPNN_HUB_SPOOL_DIR`   | path  | Spool hub PUT bodies to files under this directory |
//! | `ZIPNN_HUB_PERSIST`     | path  | Durable content-addressed store root (crash-safe)  |
//! | `ZIPNN_HUB_SCRUB_SECS`  | u64   | Seconds between scrubber passes (default 60)       |
//! | `ZIPNN_HUB_REPAIR_SECS` | u64   | Seconds between fleet repair rounds (default 5)    |
//! | `ZIPNN_HUB_MAX_BODY_MB` | usize | Hub in-flight request-body budget (default 4096)   |
//! | `ZIPNN_FAULT_PROFILE`   | name  | Hub clients connect through a fault-injecting proxy|
//! | `ZIPNN_FAULT_SEED`      | u64   | Deterministic schedule seed for the fault proxy    |
//! | `ZIPNN_FLEET_REPLICATION` | usize | Replicas per blob on the fleet ring (default 2)  |
//! | `ZIPNN_FLEET_PEERS`     | usize | Concurrent peer stripes per fleet download (def. 3)|
//! | `ZIPNN_FLEET_VNODES`    | usize | Virtual nodes per hub on the ring (default 64)     |
//! | `ZIPNN_FLEET_ORIGIN`    | addr  | Hub serves GET misses read-through from this origin|
//!
//! Boolean knobs are "set at all" flags (any value, even empty, turns
//! them on). Numeric knobs ignore unset, unparsable, and zero values —
//! the documented default applies instead. Accessors re-read the
//! environment on every call so tests can toggle knobs at runtime;
//! call sites that must latch a value (e.g. the SIMD dispatch table)
//! cache the result themselves.
//!
//! Bench-harness knobs (`ZIPNN_BENCH_MB`, `ZIPNN_BENCH_REPS`, figure
//! toggles) are intentionally *not* here: they tune test payload sizes,
//! not library behavior, and stay local to `bench_support`.

use std::path::PathBuf;

/// Parse a positive integer knob; unset / unparsable / zero mean
/// "use the default".
fn usize_var(key: &str) -> Option<usize> {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
}

/// `ZIPNN_NO_SIMD`: force the scalar byte-group transpose kernels.
pub fn no_simd() -> bool {
    std::env::var_os("ZIPNN_NO_SIMD").is_some()
}

/// `ZIPNN_NO_MMAP`: disable memory-mapped I/O everywhere (readers fall
/// back to buffered streaming; the hub keeps blobs heap-resident).
pub fn no_mmap() -> bool {
    std::env::var_os("ZIPNN_NO_MMAP").is_some()
}

/// `ZIPNN_DECODE_WORKERS`: shared worker-pool size.
pub fn decode_workers() -> Option<usize> {
    usize_var("ZIPNN_DECODE_WORKERS")
}

/// `ZIPNN_ENCODE_WORKERS`: encode worker count (raise-only on the
/// shared pool, override for writer thread counts).
pub fn encode_workers() -> Option<usize> {
    usize_var("ZIPNN_ENCODE_WORKERS")
}

/// `ZIPNN_HUB_WORKERS`: hub reactor worker threads.
pub fn hub_workers() -> Option<usize> {
    usize_var("ZIPNN_HUB_WORKERS")
}

/// `ZIPNN_HUB_MAX_CONNS`: hub concurrent-connection cap.
pub fn hub_max_conns() -> Option<usize> {
    usize_var("ZIPNN_HUB_MAX_CONNS")
}

/// `ZIPNN_HUB_SPOOL_DIR`: directory for hub PUT spool files.
pub fn hub_spool_dir() -> Option<PathBuf> {
    std::env::var_os("ZIPNN_HUB_SPOOL_DIR").map(PathBuf::from)
}

/// `ZIPNN_HUB_PERSIST`: root directory for the durable store. When set
/// (or when the builder passes a root), PUTs commit via fsync + atomic
/// rename and the hub re-indexes surviving blobs on startup. Takes
/// precedence over the spool dir.
pub fn hub_persist_dir() -> Option<PathBuf> {
    std::env::var_os("ZIPNN_HUB_PERSIST")
        .filter(|v| !v.is_empty())
        .map(PathBuf::from)
}

/// `ZIPNN_HUB_SCRUB_SECS`: seconds between background scrubber passes
/// over the persisted blobs (default 60; persist mode only).
pub fn hub_scrub_secs() -> Option<u64> {
    std::env::var("ZIPNN_HUB_SCRUB_SECS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&n| n > 0)
}

/// `ZIPNN_HUB_REPAIR_SECS`: seconds between self-healing repair rounds
/// on fleet members started with a cluster view (default 5).
pub fn hub_repair_secs() -> Option<u64> {
    std::env::var("ZIPNN_HUB_REPAIR_SECS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .filter(|&n| n > 0)
}

/// `ZIPNN_HUB_MAX_BODY_MB`: cap on request-body bytes the hub holds in
/// flight per request before shedding the request with a clean error.
pub fn hub_max_body_mb() -> Option<usize> {
    usize_var("ZIPNN_HUB_MAX_BODY_MB")
}

/// `ZIPNN_FAULT_PROFILE`: named fault-injection profile (`drop-heavy`,
/// `corrupt-heavy`, `stall-heavy`) routing every [`crate::hub::HubClient`]
/// connection through an in-process fault proxy. Unset = no injection.
pub fn fault_profile() -> Option<String> {
    std::env::var("ZIPNN_FAULT_PROFILE").ok().filter(|v| !v.is_empty())
}

/// `ZIPNN_FAULT_SEED`: seed for the fault proxy's deterministic
/// schedule, so a failing run replays exactly (default 1).
pub fn fault_seed() -> Option<u64> {
    std::env::var("ZIPNN_FAULT_SEED").ok().and_then(|v| v.parse::<u64>().ok())
}

/// `ZIPNN_FLEET_REPLICATION`: replicas per blob (R) on the fleet's
/// consistent-hash ring (default 2).
pub fn fleet_replication() -> Option<usize> {
    usize_var("ZIPNN_FLEET_REPLICATION")
}

/// `ZIPNN_FLEET_PEERS`: concurrent peer stripes a fleet download fans
/// out to (default 3; indexed blobs only — frame boundaries permitting).
pub fn fleet_peers() -> Option<usize> {
    usize_var("ZIPNN_FLEET_PEERS")
}

/// `ZIPNN_FLEET_VNODES`: virtual nodes per hub on the placement ring
/// (default 64).
pub fn fleet_vnodes() -> Option<usize> {
    usize_var("ZIPNN_FLEET_VNODES")
}

/// `ZIPNN_FLEET_ORIGIN`: when set, a hub serves GET/Range/Stat misses
/// read-through from this origin hub address (edge-cache mode).
pub fn fleet_origin() -> Option<String> {
    std::env::var("ZIPNN_FLEET_ORIGIN").ok().filter(|v| !v.is_empty())
}
