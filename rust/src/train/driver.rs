//! LM / CNN training drivers over the AOT artifacts.

use crate::error::{Error, Result};
use crate::model::tensor::{Model, Tensor};
use crate::runtime::{literal_to_bytes, make_literal, make_scalar_f32, make_scalar_u32, Runtime};
use crate::train::data::{CnnBatchGen, TokenGen};
use xla::Literal;

/// Transformer-LM trainer (paper §4.1 RoBERTa-finetune analog).
pub struct LmTrainer<'rt> {
    rt: &'rt Runtime,
    preset: String,
    n_params: usize,
    /// params ++ m ++ v, in manifest order.
    state: Vec<Literal>,
    gen: TokenGen,
    batch: usize,
    seq: usize,
    step_idx: usize,
    /// Loss per executed step.
    pub losses: Vec<f32>,
}

impl<'rt> LmTrainer<'rt> {
    /// Initialize from the `{preset}_init` artifact.
    pub fn new(rt: &'rt Runtime, preset: &str, seed: u64) -> Result<LmTrainer<'rt>> {
        let meta = rt.manifest().model(preset)?.clone();
        if meta.kind != "lm" {
            return Err(Error::Invalid(format!("{preset} is not an lm preset")));
        }
        let n_params = meta.params.len();
        let state = rt.exec(&format!("{preset}_init"), &[make_scalar_u32(seed as u32)])?;
        if state.len() != 3 * n_params {
            return Err(Error::Artifact(format!(
                "{preset}_init returned {} arrays, expected {}",
                state.len(),
                3 * n_params
            )));
        }
        let vocab = meta.cfg("vocab")?;
        Ok(LmTrainer {
            rt,
            preset: preset.to_string(),
            n_params,
            state,
            gen: TokenGen::new(vocab, seed ^ 0xBEEF),
            batch: meta.cfg("batch")?,
            seq: meta.cfg("seq_len")?,
            step_idx: 0,
            losses: Vec::new(),
        })
    }

    fn tokens_literal(&mut self) -> Result<Literal> {
        let bytes = self.gen.batch_bytes(self.batch, self.seq);
        make_literal("i32", &[self.batch, self.seq], &bytes)
    }

    /// Run one Adam step on a fresh batch; returns the loss.
    pub fn step(&mut self, lr: f32) -> Result<f32> {
        let tokens = self.tokens_literal()?;
        let mut inputs: Vec<Literal> = Vec::with_capacity(self.state.len() + 3);
        inputs.append(&mut self.state);
        inputs.push(tokens);
        inputs.push(make_scalar_f32(lr));
        inputs.push(make_scalar_f32(self.step_idx as f32));
        let mut outs = self.rt.exec(&format!("{}_step", self.preset), &inputs)?;
        let loss_lit = outs.pop().expect("loss output");
        let loss = loss_lit.to_vec::<f32>()?[0];
        self.state = outs;
        self.step_idx += 1;
        self.losses.push(loss);
        Ok(loss)
    }

    fn params(&self) -> &[Literal] {
        &self.state[..self.n_params]
    }

    fn export(&self, artifact: &str, inputs: &[Literal], what: &str) -> Result<Model> {
        let outs = self.rt.exec(artifact, inputs)?;
        let meta = self.rt.manifest().model(&self.preset)?;
        let mut model = Model::new(&format!("{}-{}-step{}", self.preset, what, self.step_idx));
        for (spec, lit) in meta.params.iter().zip(&outs) {
            let bytes = literal_to_bytes(lit)?;
            model.tensors.push(Tensor::new(
                &spec.name,
                &spec.shape,
                meta.codec_dtype(),
                bytes,
            )?);
        }
        Ok(model)
    }

    /// Export current parameters as a bf16 checkpoint model.
    pub fn export_model(&self) -> Result<Model> {
        self.export(&format!("{}_export", self.preset), self.params(), "model")
    }

    /// Export gradients at the current parameters (fresh batch).
    pub fn export_grads(&mut self) -> Result<Model> {
        let tokens = self.tokens_literal()?;
        let mut inputs: Vec<Literal> = self.params().to_vec();
        inputs.push(tokens);
        self.export(&format!("{}_grads", self.preset), &inputs, "grads")
    }

    /// Export the Adam first/second moments as two models.
    pub fn export_optimizer(&self) -> Result<(Model, Model)> {
        let m = self.export(
            &format!("{}_export", self.preset),
            &self.state[self.n_params..2 * self.n_params],
            "adam-m",
        )?;
        let v = self.export(
            &format!("{}_export", self.preset),
            &self.state[2 * self.n_params..],
            "adam-v",
        )?;
        Ok((m, v))
    }

    /// Evaluate loss on a fresh batch without updating.
    pub fn eval_loss(&mut self) -> Result<f32> {
        let tokens = self.tokens_literal()?;
        let mut inputs: Vec<Literal> = self.params().to_vec();
        inputs.push(tokens);
        let outs = self.rt.exec(&format!("{}_loss", self.preset), &inputs)?;
        Ok(outs[0].to_vec::<f32>()?[0])
    }
}

/// Residual-CNN trainer (paper §4.2 ResNet-finetune analog).
pub struct CnnTrainer<'rt> {
    rt: &'rt Runtime,
    preset: String,
    n_params: usize,
    /// params ++ momentum.
    state: Vec<Literal>,
    gen: CnnBatchGen,
    batch: usize,
    image: usize,
    channels: usize,
    step_idx: usize,
    /// Loss per executed step.
    pub losses: Vec<f32>,
}

impl<'rt> CnnTrainer<'rt> {
    /// Initialize from the `{preset}_init` artifact.
    pub fn new(rt: &'rt Runtime, preset: &str, seed: u64) -> Result<CnnTrainer<'rt>> {
        let meta = rt.manifest().model(preset)?.clone();
        if meta.kind != "cnn" {
            return Err(Error::Invalid(format!("{preset} is not a cnn preset")));
        }
        let n_params = meta.params.len();
        let state = rt.exec(&format!("{preset}_init"), &[make_scalar_u32(seed as u32)])?;
        Ok(CnnTrainer {
            rt,
            preset: preset.to_string(),
            n_params,
            state,
            gen: CnnBatchGen::new(
                meta.cfg("image")?,
                meta.cfg("channels")?,
                meta.cfg("classes")?,
                seed ^ 0xF00D,
            ),
            batch: meta.cfg("batch")?,
            image: meta.cfg("image")?,
            channels: meta.cfg("channels")?,
            step_idx: 0,
            losses: Vec::new(),
        })
    }

    /// Run one SGD+momentum step; `lr` implements the step schedule.
    pub fn step(&mut self, lr: f32) -> Result<f32> {
        let (imgs, lbls) = self.gen.batch_bytes(self.batch);
        let images = make_literal(
            "f32",
            &[self.batch, self.image, self.image, self.channels],
            &imgs,
        )?;
        let labels = make_literal("i32", &[self.batch], &lbls)?;
        let mut inputs: Vec<Literal> = Vec::with_capacity(self.state.len() + 3);
        inputs.append(&mut self.state);
        inputs.push(images);
        inputs.push(labels);
        inputs.push(make_scalar_f32(lr));
        let mut outs = self.rt.exec(&format!("{}_step", self.preset), &inputs)?;
        let loss = outs.pop().expect("loss").to_vec::<f32>()?[0];
        self.state = outs;
        self.step_idx += 1;
        self.losses.push(loss);
        Ok(loss)
    }

    /// Export current parameters as an fp32 checkpoint model.
    pub fn export_model(&self) -> Result<Model> {
        let outs = self.rt.exec(
            &format!("{}_export", self.preset),
            &self.state[..self.n_params],
        )?;
        let meta = self.rt.manifest().model(&self.preset)?;
        let mut model =
            Model::new(&format!("{}-model-step{}", self.preset, self.step_idx));
        for (spec, lit) in meta.params.iter().zip(&outs) {
            model.tensors.push(Tensor::new(
                &spec.name,
                &spec.shape,
                meta.codec_dtype(),
                literal_to_bytes(lit)?,
            )?);
        }
        Ok(model)
    }
}
