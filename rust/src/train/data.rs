//! Synthetic-but-learnable data generators (the paper's training data is
//! not available; what matters for §4 is a *converging* run — see
//! DESIGN.md §2).

use crate::util::prng::{Xoshiro256, Zipf};

/// Token-sequence generator: a noisy deterministic Markov chain over a
/// Zipf-weighted vocabulary. The LM can learn the transition structure
/// (loss drops), and the Zipf skew reproduces the paper's Fig. 7
/// embedding-sparsity effect: most vocabulary rows see no gradient.
pub struct TokenGen {
    vocab: usize,
    zipf: Zipf,
    rng: Xoshiro256,
    /// Probability of following the deterministic transition.
    coherence: f64,
}

impl TokenGen {
    /// New generator over `vocab` tokens.
    pub fn new(vocab: usize, seed: u64) -> TokenGen {
        TokenGen {
            vocab,
            zipf: Zipf::new(vocab, 1.1),
            rng: Xoshiro256::seed_from_u64(seed),
            coherence: 0.8,
        }
    }

    /// Generate a `[batch, seq]` token matrix as little-endian i32 bytes.
    pub fn batch_bytes(&mut self, batch: usize, seq: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(batch * seq * 4);
        for _ in 0..batch {
            let mut t = self.zipf.sample(&mut self.rng);
            for _ in 0..seq {
                out.extend_from_slice(&(t as i32).to_le_bytes());
                t = if self.rng.uniform() < self.coherence {
                    (t * 31 + 17) % self.vocab
                } else {
                    self.zipf.sample(&mut self.rng)
                };
            }
        }
        out
    }
}

/// Image-batch generator: Gaussian noise plus a class-dependent pattern,
/// so the CNN's loss actually decreases (Fig. 8 needs a converging run
/// with an LR schedule).
pub struct CnnBatchGen {
    image: usize,
    channels: usize,
    classes: usize,
    rng: Xoshiro256,
}

impl CnnBatchGen {
    /// New generator.
    pub fn new(image: usize, channels: usize, classes: usize, seed: u64) -> CnnBatchGen {
        CnnBatchGen { image, channels, classes, rng: Xoshiro256::seed_from_u64(seed) }
    }

    /// Generate `(images_f32_bytes, labels_i32_bytes)` for one batch.
    pub fn batch_bytes(&mut self, batch: usize) -> (Vec<u8>, Vec<u8>) {
        let hw = self.image * self.image * self.channels;
        let mut imgs = Vec::with_capacity(batch * hw * 4);
        let mut lbls = Vec::with_capacity(batch * 4);
        for _ in 0..batch {
            let label = self.rng.below(self.classes);
            lbls.extend_from_slice(&(label as i32).to_le_bytes());
            // class-dependent low-frequency pattern + noise
            let phase = label as f64 / self.classes as f64 * std::f64::consts::TAU;
            for i in 0..self.image {
                for j in 0..self.image {
                    for c in 0..self.channels {
                        let sig = ((i as f64 * 0.7 + c as f64) * phase.cos()
                            + (j as f64 * 0.7) * phase.sin())
                        .sin();
                        let v = (sig * 0.8 + self.rng.normal() * 0.5) as f32;
                        imgs.extend_from_slice(&v.to_le_bytes());
                    }
                }
            }
        }
        (imgs, lbls)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_batch_shape_and_range() {
        let mut g = TokenGen::new(128, 1);
        let bytes = g.batch_bytes(4, 16);
        assert_eq!(bytes.len(), 4 * 16 * 4);
        for c in bytes.chunks_exact(4) {
            let t = i32::from_le_bytes(c.try_into().unwrap());
            assert!((0..128).contains(&t));
        }
    }

    #[test]
    fn tokens_are_skewed_and_batches_sparse() {
        // Frequency skew: the top tokens dominate.
        let mut g = TokenGen::new(512, 2);
        let bytes = g.batch_bytes(64, 64);
        let mut seen = vec![0u32; 512];
        for c in bytes.chunks_exact(4) {
            seen[i32::from_le_bytes(c.try_into().unwrap()) as usize] += 1;
        }
        let mut sorted = seen.clone();
        sorted.sort_unstable_by(|a, b| b.cmp(a));
        let head: u32 = sorted[..51].iter().sum();
        let total: u32 = sorted.iter().sum();
        assert!(
            head as f64 > 0.3 * total as f64,
            "top-10% should dominate: {head}/{total}"
        );
        // Per-batch sparsity (the Fig. 7 embedding-gradient mechanism):
        // one small batch cannot touch most of a large vocab.
        let mut g = TokenGen::new(2048, 3);
        let bytes = g.batch_bytes(8, 64);
        let mut touched = vec![false; 2048];
        for c in bytes.chunks_exact(4) {
            touched[i32::from_le_bytes(c.try_into().unwrap()) as usize] = true;
        }
        let unseen = touched.iter().filter(|&&t| !t).count();
        assert!(unseen > 1024, "most rows untouched per batch: {unseen}");
    }

    #[test]
    fn cnn_batch_shapes() {
        let mut g = CnnBatchGen::new(8, 3, 10, 3);
        let (imgs, lbls) = g.batch_bytes(4);
        assert_eq!(imgs.len(), 4 * 8 * 8 * 3 * 4);
        assert_eq!(lbls.len(), 4 * 4);
        for c in lbls.chunks_exact(4) {
            let l = i32::from_le_bytes(c.try_into().unwrap());
            assert!((0..10).contains(&l));
        }
    }

    #[test]
    fn deterministic() {
        let a = TokenGen::new(64, 9).batch_bytes(2, 8);
        let b = TokenGen::new(64, 9).batch_bytes(2, 8);
        assert_eq!(a, b);
    }
}
