//! Training driver: runs the AOT train-step artifacts from Rust to produce
//! the real checkpoints, gradients and optimizer states the paper
//! compresses (§4). Python never runs here — only PJRT executions of the
//! lowered L2 graphs (which embed the L1 Pallas kernels).

pub mod data;
#[cfg(feature = "pjrt")]
pub mod driver;

pub use data::{CnnBatchGen, TokenGen};
#[cfg(feature = "pjrt")]
pub use driver::{CnnTrainer, LmTrainer};
