//! Checkpoint store with periodic-base delta strategies (paper §4.2,
//! Fig. 9).
//!
//! Three strategies:
//! - `Standalone` — every checkpoint compressed on its own;
//! - `Chain(k)` — consecutive deltas, a full base every `k` checkpoints
//!   (recovery walks ≤ k−1 deltas);
//! - `FixedBase(k)` — every delta taken against the last full base
//!   (recovery needs exactly one delta, compression degrades with
//!   distance).

use crate::codec::{decompress, decompress_path, CodecConfig, Compressor};
use crate::delta::xor::DeltaCodec;
use crate::error::{Error, Result};
use crate::fp::DType;
use std::path::PathBuf;

/// Base placement strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaseStrategy {
    /// No deltas.
    Standalone,
    /// Full base every `k`; delta against the *previous checkpoint*.
    Chain(usize),
    /// Full base every `k`; delta against the *last base*.
    FixedBase(usize),
}

/// How one checkpoint was stored.
#[derive(Debug, Clone)]
pub struct StoredDelta {
    /// Checkpoint index.
    pub index: usize,
    /// Compressed bytes held in memory (empty when spooled to disk).
    pub bytes: Vec<u8>,
    /// On-disk container of a spooled entry; recovery decodes it over a
    /// memory mapping (zero-copy payload reads).
    pub path: Option<PathBuf>,
    /// True if this entry is a full (standalone-compressed) base.
    pub is_base: bool,
    /// Raw checkpoint size.
    pub raw_len: usize,
    /// Compressed size (in memory or on disk).
    pub stored_len: usize,
}

impl StoredDelta {
    /// Compressed size in percent of raw.
    pub fn pct(&self) -> f64 {
        self.stored_len as f64 / self.raw_len as f64 * 100.0
    }
}

/// A checkpoint store applying one [`BaseStrategy`]. Entries live in
/// memory by default; with [`CheckpointStore::with_spool_dir`] they are
/// written to disk and recovered through the mmap-backed decode path.
pub struct CheckpointStore {
    strategy: BaseStrategy,
    codec_cfg: CodecConfig,
    delta: DeltaCodec,
    /// Raw bytes of checkpoints we may still need as delta references.
    prev_raw: Option<Vec<u8>>,
    base_raw: Option<Vec<u8>>,
    entries: Vec<StoredDelta>,
    spool_dir: Option<PathBuf>,
    /// Unique per-store spool-file prefix: stores sharing a directory
    /// (or successive runs in one process) must never collide.
    spool_tag: String,
}

impl CheckpointStore {
    /// New store for checkpoints of `dtype` using `strategy`.
    pub fn new(dtype: DType, strategy: BaseStrategy) -> CheckpointStore {
        use std::sync::atomic::{AtomicU64, Ordering};
        static STORE_SEQ: AtomicU64 = AtomicU64::new(0);
        CheckpointStore {
            strategy,
            codec_cfg: CodecConfig::for_dtype(dtype),
            delta: DeltaCodec::new(dtype),
            prev_raw: None,
            base_raw: None,
            entries: Vec::new(),
            spool_dir: None,
            spool_tag: format!(
                "{}-{}",
                std::process::id(),
                STORE_SEQ.fetch_add(1, Ordering::Relaxed)
            ),
        }
    }

    /// Spool compressed entries to `<dir>/ckpt-<index>.znn` instead of
    /// holding them in memory. [`CheckpointStore::recover`] then opens
    /// each container on the zero-copy mapped fast path, so recovery
    /// reads compressed bytes straight from the page cache.
    pub fn with_spool_dir(mut self, dir: impl Into<PathBuf>) -> Result<CheckpointStore> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)?;
        self.spool_dir = Some(dir);
        Ok(self)
    }

    /// Append a checkpoint; returns a reference to its stored entry.
    ///
    /// Bases are compressed one-shot (`ZNN1`, byte-identical to a direct
    /// [`Compressor::compress`]); deltas are **streamed** — XORed against
    /// the reference one chunk at a time through a
    /// [`crate::codec::ZnnWriter`], so the full delta buffer is never
    /// materialized.
    pub fn push(&mut self, raw: &[u8]) -> Result<&StoredDelta> {
        let idx = self.entries.len();
        let is_base = match self.strategy {
            BaseStrategy::Standalone => true,
            BaseStrategy::Chain(k) | BaseStrategy::FixedBase(k) => {
                if k == 0 {
                    return Err(Error::Invalid("period must be > 0".into()));
                }
                idx % k == 0
            }
        };
        let bytes = if is_base {
            Compressor::new(self.codec_cfg.clone()).compress(raw)?
        } else {
            let reference = match self.strategy {
                BaseStrategy::Chain(_) => self.prev_raw.as_ref(),
                BaseStrategy::FixedBase(_) => self.base_raw.as_ref(),
                BaseStrategy::Standalone => unreachable!(),
            }
            .ok_or_else(|| Error::Invalid("no reference checkpoint".into()))?;
            let mut sink = Vec::new();
            self.delta.encode_to(reference, raw, &mut sink)?;
            sink
        };
        // Keep only the raw bytes the strategy will actually reference.
        match self.strategy {
            BaseStrategy::Standalone => {}
            BaseStrategy::Chain(_) => self.prev_raw = Some(raw.to_vec()),
            BaseStrategy::FixedBase(_) => {
                if is_base {
                    self.base_raw = Some(raw.to_vec());
                }
            }
        }
        let stored_len = bytes.len();
        let (bytes, path) = match &self.spool_dir {
            Some(dir) => {
                let p = dir.join(format!("ckpt-{}-{idx}.znn", self.spool_tag));
                std::fs::write(&p, &bytes)?;
                (Vec::new(), Some(p))
            }
            None => (bytes, None),
        };
        self.entries.push(StoredDelta {
            index: idx,
            bytes,
            path,
            is_base,
            raw_len: raw.len(),
            stored_len,
        });
        Ok(self.entries.last().unwrap())
    }

    /// Decompress a base entry (over a memory mapping when spooled).
    fn load_base(&self, e: &StoredDelta) -> Result<Vec<u8>> {
        match &e.path {
            Some(p) => decompress_path(p, 1),
            None => decompress(&e.bytes),
        }
    }

    /// Apply one stored delta to `base` (mapped zero-copy when spooled).
    fn apply_delta(&self, base: &[u8], e: &StoredDelta) -> Result<Vec<u8>> {
        match &e.path {
            Some(p) => self.delta.decode_from_path(base, p),
            None => self.delta.decode_from(base, e.bytes.as_slice()),
        }
    }

    /// Recover checkpoint `index` by decompressing its base and applying
    /// the delta chain. Deltas are decoded streaming: each step reads the
    /// stored container incrementally and XORs in place against the
    /// running base. Spooled entries are opened on the mmap fast path.
    pub fn recover(&self, index: usize) -> Result<Vec<u8>> {
        let e = self
            .entries
            .get(index)
            .ok_or_else(|| Error::Invalid(format!("no checkpoint {index}")))?;
        if e.is_base {
            return self.load_base(e);
        }
        match self.strategy {
            BaseStrategy::Standalone => unreachable!("non-base under standalone"),
            BaseStrategy::FixedBase(k) => {
                let base_idx = (index / k) * k;
                let base = self.load_base(&self.entries[base_idx])?;
                self.apply_delta(&base, e)
            }
            BaseStrategy::Chain(k) => {
                let base_idx = (index / k) * k;
                let mut cur = self.load_base(&self.entries[base_idx])?;
                for i in base_idx + 1..=index {
                    cur = self.apply_delta(&cur, &self.entries[i])?;
                }
                Ok(cur)
            }
        }
    }

    /// All stored entries.
    pub fn entries(&self) -> &[StoredDelta] {
        &self.entries
    }

    /// Mean compressed percentage over *delta* entries only (Fig. 9
    /// ignores the space of the periodic full bases).
    pub fn mean_delta_pct(&self) -> f64 {
        let deltas: Vec<&StoredDelta> = self.entries.iter().filter(|e| !e.is_base).collect();
        if deltas.is_empty() {
            return f64::NAN;
        }
        deltas.iter().map(|e| e.pct()).sum::<f64>() / deltas.len() as f64
    }

    /// Total stored bytes (bases + deltas, in memory or spooled).
    pub fn total_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.stored_len).sum()
    }
}

impl Drop for CheckpointStore {
    /// Spooled entry files are only reachable through this store's
    /// entries, so they go with it (best-effort; the directory itself is
    /// the caller's).
    fn drop(&mut self) {
        for e in &self.entries {
            if let Some(p) = &e.path {
                let _ = std::fs::remove_file(p);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::dtype::f32_to_bf16_bits;
    use crate::util::Xoshiro256;

    /// Simulated training trajectory: weights drift by decreasing steps.
    fn trajectory(n_ckpts: usize, n_params: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut w: Vec<f64> = (0..n_params).map(|_| rng.normal() * 0.02).collect();
        let mut out = Vec::new();
        for e in 0..n_ckpts {
            let lr = 1e-4 / (1.0 + e as f64 / 4.0);
            for v in w.iter_mut() {
                *v += rng.normal() * lr;
            }
            let mut bytes = Vec::with_capacity(2 * n_params);
            for v in &w {
                bytes.extend_from_slice(&f32_to_bf16_bits(*v as f32).to_le_bytes());
            }
            out.push(bytes);
        }
        out
    }

    #[test]
    fn all_strategies_recover_exactly() {
        let ckpts = trajectory(8, 60_000, 1);
        for strat in [
            BaseStrategy::Standalone,
            BaseStrategy::Chain(4),
            BaseStrategy::FixedBase(4),
        ] {
            let mut store = CheckpointStore::new(DType::BF16, strat);
            for c in &ckpts {
                store.push(c).unwrap();
            }
            for (i, c) in ckpts.iter().enumerate() {
                assert_eq!(&store.recover(i).unwrap(), c, "{strat:?} ckpt {i}");
            }
        }
    }

    #[test]
    fn deltas_beat_standalone() {
        let ckpts = trajectory(6, 80_000, 2);
        let mut standalone = CheckpointStore::new(DType::BF16, BaseStrategy::Standalone);
        let mut chain = CheckpointStore::new(DType::BF16, BaseStrategy::Chain(6));
        for c in &ckpts {
            standalone.push(c).unwrap();
            chain.push(c).unwrap();
        }
        assert!(
            chain.total_bytes() < standalone.total_bytes(),
            "chain {} !< standalone {}",
            chain.total_bytes(),
            standalone.total_bytes()
        );
    }

    #[test]
    fn chain_beats_fixed_base_at_distance() {
        // With a drifting trajectory, consecutive deltas are smaller than
        // deltas against a distant fixed base (Fig. 9's observation).
        let ckpts = trajectory(10, 60_000, 3);
        let mut chain = CheckpointStore::new(DType::BF16, BaseStrategy::Chain(10));
        let mut fixed = CheckpointStore::new(DType::BF16, BaseStrategy::FixedBase(10));
        for c in &ckpts {
            chain.push(c).unwrap();
            fixed.push(c).unwrap();
        }
        assert!(chain.mean_delta_pct() <= fixed.mean_delta_pct() + 1.0);
        // and the *last* fixed-base delta (distance 9) is clearly worse
        let chain_last = chain.entries().last().unwrap().pct();
        let fixed_last = fixed.entries().last().unwrap().pct();
        assert!(fixed_last > chain_last, "fixed {fixed_last} !> chain {chain_last}");
    }

    #[test]
    fn base_cadence() {
        let ckpts = trajectory(9, 10_000, 4);
        let mut s = CheckpointStore::new(DType::BF16, BaseStrategy::Chain(3));
        for c in &ckpts {
            s.push(c).unwrap();
        }
        let bases: Vec<usize> = s
            .entries()
            .iter()
            .filter(|e| e.is_base)
            .map(|e| e.index)
            .collect();
        assert_eq!(bases, vec![0, 3, 6]);
    }

    #[test]
    fn recover_out_of_range_errors() {
        let store = CheckpointStore::new(DType::BF16, BaseStrategy::Standalone);
        assert!(store.recover(0).is_err());
    }

    #[test]
    fn spooled_store_recovers_via_mapped_containers() {
        let dir = std::env::temp_dir().join(format!("zipnn-ckpt-spool-{}", std::process::id()));
        let ckpts = trajectory(6, 40_000, 7);
        for strat in [BaseStrategy::Chain(3), BaseStrategy::FixedBase(3)] {
            let mut store = CheckpointStore::new(DType::BF16, strat).with_spool_dir(&dir).unwrap();
            for c in &ckpts {
                let e = store.push(c).unwrap();
                // entries live on disk, not in memory
                assert!(e.bytes.is_empty());
                let p = e.path.as_ref().expect("spooled entry has a path");
                assert_eq!(std::fs::metadata(p).unwrap().len() as usize, e.stored_len);
            }
            assert!(store.total_bytes() > 0);
            for (i, c) in ckpts.iter().enumerate() {
                assert_eq!(&store.recover(i).unwrap(), c, "{strat:?} ckpt {i}");
            }
        }
        // Dropping a store removes its spooled files.
        let leftover = std::fs::read_dir(&dir).unwrap().count();
        assert_eq!(leftover, 0, "{leftover} spooled checkpoint files leaked");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn spooled_stores_sharing_a_dir_do_not_collide() {
        let dir = std::env::temp_dir().join(format!("zipnn-ckpt-shared-{}", std::process::id()));
        let a_ckpts = trajectory(4, 20_000, 8);
        let b_ckpts = trajectory(4, 20_000, 9);
        let mut a = CheckpointStore::new(DType::BF16, BaseStrategy::Chain(2))
            .with_spool_dir(&dir)
            .unwrap();
        let mut b = CheckpointStore::new(DType::BF16, BaseStrategy::Chain(2))
            .with_spool_dir(&dir)
            .unwrap();
        for (ca, cb) in a_ckpts.iter().zip(&b_ckpts) {
            a.push(ca).unwrap();
            b.push(cb).unwrap();
        }
        for i in 0..4 {
            assert_eq!(&a.recover(i).unwrap(), &a_ckpts[i], "store a ckpt {i}");
            assert_eq!(&b.recover(i).unwrap(), &b_ckpts[i], "store b ckpt {i}");
        }
        drop(a);
        drop(b);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
