//! Checkpoint store with periodic-base delta strategies (paper §4.2,
//! Fig. 9).
//!
//! Three strategies:
//! - `Standalone` — every checkpoint compressed on its own;
//! - `Chain(k)` — consecutive deltas, a full base every `k` checkpoints
//!   (recovery walks ≤ k−1 deltas);
//! - `FixedBase(k)` — every delta taken against the last full base
//!   (recovery needs exactly one delta, compression degrades with
//!   distance).

use crate::codec::{decompress, CodecConfig, Compressor};
use crate::delta::xor::DeltaCodec;
use crate::error::{Error, Result};
use crate::fp::DType;

/// Base placement strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BaseStrategy {
    /// No deltas.
    Standalone,
    /// Full base every `k`; delta against the *previous checkpoint*.
    Chain(usize),
    /// Full base every `k`; delta against the *last base*.
    FixedBase(usize),
}

/// How one checkpoint was stored.
#[derive(Debug, Clone)]
pub struct StoredDelta {
    /// Checkpoint index.
    pub index: usize,
    /// Compressed bytes on disk.
    pub bytes: Vec<u8>,
    /// True if this entry is a full (standalone-compressed) base.
    pub is_base: bool,
    /// Raw checkpoint size.
    pub raw_len: usize,
}

impl StoredDelta {
    /// Compressed size in percent of raw.
    pub fn pct(&self) -> f64 {
        self.bytes.len() as f64 / self.raw_len as f64 * 100.0
    }
}

/// An in-memory checkpoint store applying one [`BaseStrategy`].
pub struct CheckpointStore {
    strategy: BaseStrategy,
    codec_cfg: CodecConfig,
    delta: DeltaCodec,
    /// Raw bytes of checkpoints we may still need as delta references.
    prev_raw: Option<Vec<u8>>,
    base_raw: Option<Vec<u8>>,
    entries: Vec<StoredDelta>,
}

impl CheckpointStore {
    /// New store for checkpoints of `dtype` using `strategy`.
    pub fn new(dtype: DType, strategy: BaseStrategy) -> CheckpointStore {
        CheckpointStore {
            strategy,
            codec_cfg: CodecConfig::for_dtype(dtype),
            delta: DeltaCodec::new(dtype),
            prev_raw: None,
            base_raw: None,
            entries: Vec::new(),
        }
    }

    /// Append a checkpoint; returns a reference to its stored entry.
    ///
    /// Bases are compressed one-shot (`ZNN1`, byte-identical to a direct
    /// [`Compressor::compress`]); deltas are **streamed** — XORed against
    /// the reference one chunk at a time through a
    /// [`crate::codec::ZnnWriter`], so the full delta buffer is never
    /// materialized.
    pub fn push(&mut self, raw: &[u8]) -> Result<&StoredDelta> {
        let idx = self.entries.len();
        let is_base = match self.strategy {
            BaseStrategy::Standalone => true,
            BaseStrategy::Chain(k) | BaseStrategy::FixedBase(k) => {
                if k == 0 {
                    return Err(Error::Invalid("period must be > 0".into()));
                }
                idx % k == 0
            }
        };
        let bytes = if is_base {
            Compressor::new(self.codec_cfg.clone()).compress(raw)?
        } else {
            let reference = match self.strategy {
                BaseStrategy::Chain(_) => self.prev_raw.as_ref(),
                BaseStrategy::FixedBase(_) => self.base_raw.as_ref(),
                BaseStrategy::Standalone => unreachable!(),
            }
            .ok_or_else(|| Error::Invalid("no reference checkpoint".into()))?;
            let mut sink = Vec::new();
            self.delta.encode_to(reference, raw, &mut sink)?;
            sink
        };
        // Keep only the raw bytes the strategy will actually reference.
        match self.strategy {
            BaseStrategy::Standalone => {}
            BaseStrategy::Chain(_) => self.prev_raw = Some(raw.to_vec()),
            BaseStrategy::FixedBase(_) => {
                if is_base {
                    self.base_raw = Some(raw.to_vec());
                }
            }
        }
        self.entries.push(StoredDelta {
            index: idx,
            bytes,
            is_base,
            raw_len: raw.len(),
        });
        Ok(self.entries.last().unwrap())
    }

    /// Recover checkpoint `index` by decompressing its base and applying
    /// the delta chain. Deltas are decoded streaming: each step reads the
    /// stored container incrementally and XORs in place against the
    /// running base.
    pub fn recover(&self, index: usize) -> Result<Vec<u8>> {
        let e = self
            .entries
            .get(index)
            .ok_or_else(|| Error::Invalid(format!("no checkpoint {index}")))?;
        if e.is_base {
            return decompress(&e.bytes);
        }
        match self.strategy {
            BaseStrategy::Standalone => unreachable!("non-base under standalone"),
            BaseStrategy::FixedBase(k) => {
                let base_idx = (index / k) * k;
                let base = decompress(&self.entries[base_idx].bytes)?;
                self.delta.decode_from(&base, e.bytes.as_slice())
            }
            BaseStrategy::Chain(k) => {
                let base_idx = (index / k) * k;
                let mut cur = decompress(&self.entries[base_idx].bytes)?;
                for i in base_idx + 1..=index {
                    cur = self.delta.decode_from(&cur, self.entries[i].bytes.as_slice())?;
                }
                Ok(cur)
            }
        }
    }

    /// All stored entries.
    pub fn entries(&self) -> &[StoredDelta] {
        &self.entries
    }

    /// Mean compressed percentage over *delta* entries only (Fig. 9
    /// ignores the space of the periodic full bases).
    pub fn mean_delta_pct(&self) -> f64 {
        let deltas: Vec<&StoredDelta> = self.entries.iter().filter(|e| !e.is_base).collect();
        if deltas.is_empty() {
            return f64::NAN;
        }
        deltas.iter().map(|e| e.pct()).sum::<f64>() / deltas.len() as f64
    }

    /// Total stored bytes (bases + deltas).
    pub fn total_bytes(&self) -> usize {
        self.entries.iter().map(|e| e.bytes.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fp::dtype::f32_to_bf16_bits;
    use crate::util::Xoshiro256;

    /// Simulated training trajectory: weights drift by decreasing steps.
    fn trajectory(n_ckpts: usize, n_params: usize, seed: u64) -> Vec<Vec<u8>> {
        let mut rng = Xoshiro256::seed_from_u64(seed);
        let mut w: Vec<f64> = (0..n_params).map(|_| rng.normal() * 0.02).collect();
        let mut out = Vec::new();
        for e in 0..n_ckpts {
            let lr = 1e-4 / (1.0 + e as f64 / 4.0);
            for v in w.iter_mut() {
                *v += rng.normal() * lr;
            }
            let mut bytes = Vec::with_capacity(2 * n_params);
            for v in &w {
                bytes.extend_from_slice(&f32_to_bf16_bits(*v as f32).to_le_bytes());
            }
            out.push(bytes);
        }
        out
    }

    #[test]
    fn all_strategies_recover_exactly() {
        let ckpts = trajectory(8, 60_000, 1);
        for strat in [
            BaseStrategy::Standalone,
            BaseStrategy::Chain(4),
            BaseStrategy::FixedBase(4),
        ] {
            let mut store = CheckpointStore::new(DType::BF16, strat);
            for c in &ckpts {
                store.push(c).unwrap();
            }
            for (i, c) in ckpts.iter().enumerate() {
                assert_eq!(&store.recover(i).unwrap(), c, "{strat:?} ckpt {i}");
            }
        }
    }

    #[test]
    fn deltas_beat_standalone() {
        let ckpts = trajectory(6, 80_000, 2);
        let mut standalone = CheckpointStore::new(DType::BF16, BaseStrategy::Standalone);
        let mut chain = CheckpointStore::new(DType::BF16, BaseStrategy::Chain(6));
        for c in &ckpts {
            standalone.push(c).unwrap();
            chain.push(c).unwrap();
        }
        assert!(
            chain.total_bytes() < standalone.total_bytes(),
            "chain {} !< standalone {}",
            chain.total_bytes(),
            standalone.total_bytes()
        );
    }

    #[test]
    fn chain_beats_fixed_base_at_distance() {
        // With a drifting trajectory, consecutive deltas are smaller than
        // deltas against a distant fixed base (Fig. 9's observation).
        let ckpts = trajectory(10, 60_000, 3);
        let mut chain = CheckpointStore::new(DType::BF16, BaseStrategy::Chain(10));
        let mut fixed = CheckpointStore::new(DType::BF16, BaseStrategy::FixedBase(10));
        for c in &ckpts {
            chain.push(c).unwrap();
            fixed.push(c).unwrap();
        }
        assert!(chain.mean_delta_pct() <= fixed.mean_delta_pct() + 1.0);
        // and the *last* fixed-base delta (distance 9) is clearly worse
        let chain_last = chain.entries().last().unwrap().pct();
        let fixed_last = fixed.entries().last().unwrap().pct();
        assert!(fixed_last > chain_last, "fixed {fixed_last} !> chain {chain_last}");
    }

    #[test]
    fn base_cadence() {
        let ckpts = trajectory(9, 10_000, 4);
        let mut s = CheckpointStore::new(DType::BF16, BaseStrategy::Chain(3));
        for c in &ckpts {
            s.push(c).unwrap();
        }
        let bases: Vec<usize> = s
            .entries()
            .iter()
            .filter(|e| e.is_base)
            .map(|e| e.index)
            .collect();
        assert_eq!(bases, vec![0, 3, 6]);
    }

    #[test]
    fn recover_out_of_range_errors() {
        let store = CheckpointStore::new(DType::BF16, BaseStrategy::Standalone);
        assert!(store.recover(0).is_err());
    }
}
